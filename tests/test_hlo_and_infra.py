"""Infrastructure: loop-aware HLO walker, hashing, data pipeline, loader."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.loader import ShardedLoader
from repro.data.synthetic import SyntheticLMDataset
from repro.utils.hashing import mix32, shard_of_key
from repro.utils.hlo import analyze_hlo, xla_cost_analysis


def test_hlo_walker_counts_loop_trips():
    L, B, D = 12, 64, 512

    def f(w, x):
        def body(x, wl):
            return jnp.tanh(x @ wl), None

        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    c = jax.jit(f).lower(w, x).compile()
    cost = analyze_hlo(c.as_text())
    expected = 2.0 * B * D * D * L
    assert 0.9 * expected <= cost.flops <= 1.2 * expected, (cost.flops, expected)
    # XLA's own count misses the trips:
    xla_cost = xla_cost_analysis(c)
    assert "flops" in xla_cost, "XLA stopped reporting flops — update walker"
    assert xla_cost["flops"] < expected / 2


def test_hash_balance():
    keys = jnp.arange(100_000, dtype=jnp.int32)
    for S in (16, 64, 512):
        counts = np.bincount(np.asarray(shard_of_key(keys, S)), minlength=S)
        assert counts.min() > 0.8 * counts.mean()
        assert counts.max() < 1.2 * counts.mean()
    # avalanche: adjacent keys decorrelate
    h = np.asarray(mix32(keys[:1000]))
    assert len(np.unique(h)) == 1000


def test_synthetic_data_learnable_structure():
    ds = SyntheticLMDataset(vocab=128, seq_len=32, seed=0)
    b1 = ds.batch(0, 4)
    b2 = ds.batch(0, 4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # deterministic
    assert b1["tokens"].shape == (4, 32)
    # labels are tokens shifted by one
    b = ds.batch(3, 2)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).mean() > 0.99


def test_sharded_loader_prefetch_order():
    ds = SyntheticLMDataset(vocab=64, seq_len=8, seed=1)
    loader = ShardedLoader(lambda step: ds.batch(step, 2), depth=3)
    steps = [next(loader)[0] for _ in range(5)]
    loader.close()
    assert steps == [0, 1, 2, 3, 4]
