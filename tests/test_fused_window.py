"""Fused-window engine contracts (run_window + elimination pre-pass).

Machine-checked claims:
  1. `run_window(K)` is BIT-IDENTICAL to K sequential `jit_step` calls for
     EVERY schedule (the scan body is the step; the pre-pass sort is the
     same sort, hoisted) — per-step delete outputs AND the final carry.
  2. The scan carry is donated: XLA aliases every PQState buffer through
     the window call (no per-window state copy).
  3. The elimination pre-pass is EXACT: with elimination on, exact
     schedules still linearize like the numpy oracle element for element,
     and matched pairs demonstrably never touch the queue.
  4. Relaxed schedules conserve the element multiset with elimination on.
  5. Rebalance seq renumbering: a near-int32-wrap state is renumbered by
     the next rebalance and keeps linearizing exactly (ROADMAP wrap item).
  6. The bucketed tail compaction (both the bucket-merge path and the
     over-wide-bucket full-sort fallback) preserves oracle linearization
     under forced-small bucket widths.
"""

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core.pqueue.local as L
from repro.core.pqueue import ops as O
from repro.core.pqueue.ref import RefPQ
from repro.core.pqueue.schedules import Schedule
from repro.core.pqueue.state import INF_KEY, check_invariants, make_state
from repro.core.smartpq import NUM_MODES, SmartPQ, SmartPQConfig
from repro.utils.hlo import donation_aliases

S, C, B, K = 8, 512, 32, 5

_TREE = None


def _pq(schedule=None, eliminate=True):
    """SmartPQ with a shared (trained-once) tree; schedule pins all modes."""
    global _TREE
    cfg = SmartPQConfig(
        num_shards=S, capacity=C, npods=2, decision_interval=2,
        mode_schedules=(
            (schedule,) * NUM_MODES if schedule is not None
            else SmartPQConfig().mode_schedules
        ),
        eliminate=eliminate,
    )
    pq = SmartPQ(cfg, tree=_TREE)
    _TREE = pq.tree
    return pq


def _window(seed, key_range=4096, ins_frac=0.5):
    rng = np.random.default_rng(seed)
    ops = jnp.asarray((rng.random((K, B)) > ins_frac).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, key_range, (K, B)).astype(np.int32))
    vals = jnp.asarray(rng.integers(0, 99, (K, B)).astype(np.int32))
    rngs = jax.random.split(jax.random.key(seed), K)
    return ops, keys, vals, rngs


# ---------------------------------------------------------------------------
# 1. bit-identity to the sequential step loop, every schedule
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("schedule", list(Schedule), ids=lambda s: s.name)
def test_run_window_bitmatches_sequential_steps(schedule):
    pq = _pq(schedule)
    ops, keys, vals, rngs = _window(seed=int(schedule) + 1)

    step = jax.jit(pq.step)
    carry = pq.init()
    seq = []
    for t in range(K):
        carry, res = step(carry, ops[t], keys[t], vals[t], rngs[t], 64)
        seq.append((np.asarray(res.keys), np.asarray(res.vals),
                    int(res.n_out), int(carry.stats.mode)))

    carry_w, wres = pq.jit_run_window(pq.init(), ops, keys, vals, rngs, 64)
    for t in range(K):
        np.testing.assert_array_equal(np.asarray(wres.keys)[t], seq[t][0])
        np.testing.assert_array_equal(np.asarray(wres.vals)[t], seq[t][1])
        assert int(wres.n_out[t]) == seq[t][2]
        assert int(wres.mode[t]) == seq[t][3]
    for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(carry_w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_window_adaptive_bitmatches_sequential():
    """Same bit-identity with the real 3-mode switch live (decisions taken
    mid-window, on-device)."""
    pq = _pq(schedule=None)
    ops, keys, vals, rngs = _window(seed=77, ins_frac=0.3)
    step = jax.jit(pq.step)
    carry = pq.init()
    seq = []
    for t in range(K):
        carry, res = step(carry, ops[t], keys[t], vals[t], rngs[t], 512)
        seq.append((np.asarray(res.keys), np.asarray(res.vals)))
    carry_w, wres = pq.jit_run_window(pq.init(), ops, keys, vals, rngs, 512)
    for t in range(K):
        np.testing.assert_array_equal(np.asarray(wres.keys)[t], seq[t][0])
        np.testing.assert_array_equal(np.asarray(wres.vals)[t], seq[t][1])
    for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(carry_w)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# 2. the scan carry is donated (no state copy per window)
# ---------------------------------------------------------------------------


def test_run_window_donates_carry_no_copy():
    pq = _pq(schedule=None)
    carry = pq.init()
    ops, keys, vals, rngs = _window(seed=3)
    args = (carry, ops, keys, vals, rngs, jnp.int32(64))

    compiled = pq.jit_run_window.lower(*args).compile()
    aliases = donation_aliases(compiled)
    n_state_leaves = len(jax.tree.leaves(carry.state))
    assert len(aliases) >= n_state_leaves, (
        f"expected every PQState buffer aliased through the window scan, "
        f"got {len(aliases)} aliases: {aliases}"
    )

    out_carry, _ = pq.jit_run_window(*args)
    assert carry.state.head_keys.is_deleted()
    assert carry.state.tail_keys.is_deleted()
    assert not out_carry.state.head_keys.is_deleted()


# ---------------------------------------------------------------------------
# 3. elimination is exact (and really bypasses the queue)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "schedule", [Schedule.STRICT_FLAT, Schedule.HIER, Schedule.FFWD],
    ids=lambda s: s.name,
)
def test_elimination_exact_vs_oracle(schedule):
    """apply_op_batch(eliminate=True) linearizes like the oracle element for
    element — keys AND vals — under mixed batches with heavy ties and keys
    below the queue minimum (the matched regime)."""
    rng = np.random.default_rng(int(schedule))
    st, ref = make_state(4, 64, head_width=16), RefPQ(4, 64)
    for step in range(12):
        ops = rng.integers(0, 2, 16).astype(np.int32)
        keys = rng.integers(0, 50, 16).astype(np.int32)
        vals = rng.integers(0, 99, 16).astype(np.int32)
        r = O.apply_op_batch(
            st, jnp.asarray(ops), jnp.asarray(keys), jnp.asarray(vals),
            schedule=schedule, npods=2, eliminate=True,
        )
        st = r.state
        ref.insert_batch(keys, vals, mask=ops == O.OP_INSERT)
        rk, rv = ref.delete_min_exact(int((ops == O.OP_DELETE_MIN).sum()))
        n = int(r.n_deleted)
        assert n == len(rk)
        np.testing.assert_array_equal(np.asarray(r.deleted_keys)[:n], rk)
        np.testing.assert_array_equal(np.asarray(r.deleted_vals)[:n], rv)
        ok, msg = check_invariants(st)
        assert ok, msg
    np.testing.assert_array_equal(
        np.sort(np.asarray(st.keys[st.keys < INF_KEY]).ravel()),
        ref.key_multiset(),
    )


def test_elimination_bypasses_queue_state():
    """A batch whose inserts all beat the queue minimum and are all matched
    by deletes leaves the queue state untouched (next_seq included) and
    returns exactly the batch's own minima."""
    st = make_state(4, 64, head_width=16)
    st, _ = O.insert(st, jnp.asarray([100, 200, 300, 400], jnp.int32),
                     jnp.zeros(4, jnp.int32))
    ops = jnp.asarray([0, 0, 1, 1], jnp.int32)  # 2 inserts, 2 deletes
    keys = jnp.asarray([7, 5, INF_KEY, INF_KEY], jnp.int32)
    vals = jnp.asarray([70, 50, 0, 0], jnp.int32)
    r = O.apply_op_batch(st, ops, keys, vals,
                         schedule=Schedule.STRICT_FLAT, eliminate=True)
    np.testing.assert_array_equal(np.asarray(r.deleted_keys)[:2], [5, 7])
    np.testing.assert_array_equal(np.asarray(r.deleted_vals)[:2], [50, 70])
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(r.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_smartpq_counts_eliminated_pairs():
    pq = _pq(schedule=Schedule.STRICT_FLAT)
    carry = pq.init()
    step = jax.jit(pq.step)
    rng = np.random.default_rng(5)
    key = jax.random.key(5)
    for _ in range(6):
        ops = jnp.asarray(rng.integers(0, 2, B).astype(np.int32))
        keys = jnp.asarray(rng.integers(0, 64, B).astype(np.int32))
        key, sub = jax.random.split(key)
        carry, _ = step(carry, ops, keys, jnp.zeros(B, jnp.int32), sub, 64)
    assert int(carry.stats.eliminated) > 0, (
        "low-key insert/delete mix must exercise the elimination pre-pass"
    )


# ---------------------------------------------------------------------------
# 4. relaxed schedules conserve the multiset with elimination on
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "schedule",
    [Schedule.SPRAY_HERLIHY, Schedule.MULTIQ, Schedule.LOCAL,
     Schedule.SPRAY_FRASER],
    ids=lambda s: s.name,
)
def test_elimination_conserves_relaxed(schedule):
    rng = np.random.default_rng(int(schedule) + 10)
    st = make_state(4, 64, head_width=16)
    inserted, deleted = [], []
    for step in range(10):
        ops = rng.integers(0, 2, 16).astype(np.int32)
        keys = rng.integers(0, 80, 16).astype(np.int32)
        r = O.apply_op_batch(
            st, jnp.asarray(ops), jnp.asarray(keys),
            jnp.asarray(keys % 97), schedule=schedule, npods=2,
            rng=jax.random.key(step), eliminate=True,
        )
        st = r.state
        inserted.extend(keys[ops == O.OP_INSERT].tolist())
        deleted.extend(np.asarray(r.deleted_keys)[: int(r.n_deleted)].tolist())
        ok, msg = check_invariants(st)
        assert ok, f"{schedule.name}: {msg}"
    remaining = np.asarray(st.keys[st.keys < INF_KEY]).ravel().tolist()
    np.testing.assert_array_equal(
        np.sort(np.asarray(deleted + remaining)),
        np.sort(np.asarray(inserted)),
        err_msg=f"{schedule.name}: element loss or duplication",
    )


# ---------------------------------------------------------------------------
# 5. seq renumbering at the rebalance (int32 wrap fix)
# ---------------------------------------------------------------------------


def test_seq_renumber_on_near_wrap():
    """Force next_seq within the renumber horizon of int32 wrap; the next
    insert's guarded rebalance must renumber every shard's seqs back to the
    shard population while keeping the linearization exact."""
    rng = np.random.default_rng(11)
    st, ref = make_state(4, 64, head_width=8), RefPQ(4, 64)
    keys = rng.integers(0, 500, 80).astype(np.int32)
    st, _ = O.insert(st, jnp.asarray(keys), jnp.asarray(keys % 97))
    ref.insert_batch(keys, keys % 97)

    offset = jnp.int32(L.SEQ_RENUMBER_THRESHOLD)
    near_wrap = dataclasses.replace(
        st,
        head_seq=st.head_seq + offset,
        tail_seq=st.tail_seq + offset,
        next_seq=st.next_seq + offset,
    )
    ok, msg = check_invariants(near_wrap)
    assert ok, msg
    assert int(jnp.min(near_wrap.next_seq)) > L.SEQ_RENUMBER_THRESHOLD - 1

    more = rng.integers(0, 500, 16).astype(np.int32)
    st2, _ = O.insert(near_wrap, jnp.asarray(more), jnp.asarray(more % 97))
    ref.insert_batch(more, more % 97)
    assert int(jnp.max(st2.next_seq)) <= int(st2.total_size) + 1, (
        "rebalance must renumber seqs positionally, resetting next_seq to "
        "the shard population"
    )
    ok, msg = check_invariants(st2)
    assert ok, msg
    # linearization stays exact after renumbering
    res = O.delete_min(st2, 8, schedule=Schedule.STRICT_FLAT, active=8)
    rk, rv = ref.delete_min_exact(8)
    np.testing.assert_array_equal(np.asarray(res.keys)[: int(res.n_out)], rk)
    np.testing.assert_array_equal(np.asarray(res.vals)[: int(res.n_out)], rv)


# ---------------------------------------------------------------------------
# 6. bucketed tail compaction under forced-small bucket widths
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bucket_width", [4, 16])
def test_bucketed_tail_compaction_exact(monkeypatch, bucket_width):
    """bucket_width=16 keeps every compaction on the bucket-merge path
    (appends of <= 8 always fit); bucket_width=4 forces the over-wide
    fallback.  Both must linearize exactly and uphold I4/I5/I6."""
    monkeypatch.setattr(L, "TAIL_BUCKET_WIDTH", bucket_width)
    rng = np.random.default_rng(100 + bucket_width)
    st, ref = make_state(4, 64, head_width=8), RefPQ(4, 64)
    compacted = False
    for step in range(25):
        # insert-biased (~70/30) so the tail keeps a durable sorted run for
        # the `compacted` probe instead of draining every batch
        ops = (rng.random(8) > 0.7).astype(np.int32)
        keys = rng.integers(0, 300, 8).astype(np.int32)
        vals = rng.integers(0, 99, 8).astype(np.int32)
        r = O.apply_op_batch(
            st, jnp.asarray(ops), jnp.asarray(keys), jnp.asarray(vals),
            schedule=Schedule.STRICT_FLAT, eliminate=bool(step % 2),
        )
        st = r.state
        compacted |= bool(np.any(np.asarray(st.tail_sorted) > 0))
        ref.insert_batch(keys, vals, mask=ops == O.OP_INSERT)
        rk, rv = ref.delete_min_exact(int((ops == O.OP_DELETE_MIN).sum()))
        n = int(r.n_deleted)
        np.testing.assert_array_equal(np.asarray(r.deleted_keys)[:n], rk)
        np.testing.assert_array_equal(np.asarray(r.deleted_vals)[:n], rv)
        ok, msg = check_invariants(st)
        assert ok, msg
    assert compacted, "workload never produced a sorted tail run"
    np.testing.assert_array_equal(
        np.sort(np.asarray(st.keys[st.keys < INF_KEY]).ravel()),
        ref.key_multiset(),
    )
