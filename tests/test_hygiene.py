"""Repo hygiene: fast checks that keep generated artifacts out of git.

PR 5 committed nothing but `__pycache__/*.pyc` files; this gate makes that
class of regression impossible to land again."""

import subprocess
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _tracked_files():
    out = subprocess.run(
        ["git", "ls-files"], cwd=REPO, capture_output=True, text=True,
        check=True,
    )
    return out.stdout.splitlines()


def test_no_bytecode_tracked():
    """No compiled-python artifacts may be tracked by git."""
    bad = [
        f for f in _tracked_files()
        if f.endswith(".pyc") or "__pycache__" in f.split("/")
        or ".pytest_cache" in f.split("/")
    ]
    assert bad == [], f"generated artifacts tracked by git: {bad}"


def test_gitignore_covers_bytecode():
    """The root .gitignore must keep covering the artifact classes."""
    patterns = (REPO / ".gitignore").read_text().splitlines()
    for needed in ("__pycache__/", "*.pyc", ".pytest_cache/"):
        assert needed in patterns, f".gitignore is missing {needed!r}"
