"""Repo hygiene: fast checks that keep generated artifacts out of git.

PR 5 committed nothing but `__pycache__/*.pyc` files; this gate makes that
class of regression impossible to land again."""

import subprocess
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _tracked_files():
    out = subprocess.run(
        ["git", "ls-files"], cwd=REPO, capture_output=True, text=True,
        check=True,
    )
    return out.stdout.splitlines()


def test_no_bytecode_tracked():
    """No compiled-python artifacts may be tracked by git."""
    bad = [
        f for f in _tracked_files()
        if f.endswith(".pyc") or "__pycache__" in f.split("/")
        or ".pytest_cache" in f.split("/")
    ]
    assert bad == [], f"generated artifacts tracked by git: {bad}"


def test_gitignore_covers_bytecode():
    """The root .gitignore must keep covering the artifact classes."""
    patterns = (REPO / ".gitignore").read_text().splitlines()
    for needed in ("__pycache__/", "*.pyc", ".pytest_cache/"):
        assert needed in patterns, f".gitignore is missing {needed!r}"


def test_every_fault_injector_is_exercised():
    """Every injector registered in `repro.faults.INJECTORS` must appear by
    name in tests/test_faults.py — a registry entry with no chaos test is a
    fault path nobody has ever watched fail."""
    import sys

    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.faults import INJECTORS
    finally:
        sys.path.pop(0)
    chaos_src = (REPO / "tests" / "test_faults.py").read_text()
    missing = [name for name in INJECTORS if name not in chaos_src]
    assert missing == [], (
        f"fault injectors with no test coverage in test_faults.py: "
        f"{missing}"
    )
