"""Repo hygiene: fast checks that keep generated artifacts out of git.

PR 5 committed nothing but `__pycache__/*.pyc` files; this gate makes that
class of regression impossible to land again."""

import subprocess
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _tracked_files():
    out = subprocess.run(
        ["git", "ls-files"], cwd=REPO, capture_output=True, text=True,
        check=True,
    )
    return out.stdout.splitlines()


def test_no_bytecode_tracked():
    """No compiled-python artifacts may be tracked by git."""
    bad = [
        f for f in _tracked_files()
        if f.endswith(".pyc") or "__pycache__" in f.split("/")
        or ".pytest_cache" in f.split("/")
    ]
    assert bad == [], f"generated artifacts tracked by git: {bad}"


def test_gitignore_covers_bytecode():
    """The root .gitignore must keep covering the artifact classes."""
    patterns = (REPO / ".gitignore").read_text().splitlines()
    for needed in ("__pycache__/", "*.pyc", ".pytest_cache/"):
        assert needed in patterns, f".gitignore is missing {needed!r}"


def test_every_registered_kernel_dispatches_through_ops():
    """Every kernel in the registry must have a public wrapper in
    kernels/ops.py that resolves its arm through `registry.resolve` — a
    spec with no dispatching wrapper is dead tuning surface, and a wrapper
    outside the registry re-creates the hard-coded-path problem."""
    import sys

    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.kernels import ops as K
        from repro.kernels.registry import REGISTRY
    finally:
        sys.path.pop(0)
    ops_src = (REPO / "src" / "repro" / "kernels" / "ops.py").read_text()
    missing = [
        name for name in REGISTRY
        if not callable(getattr(K, name, None)) or name not in ops_src
    ]
    assert missing == [], (
        f"registered kernels with no registry-dispatched wrapper in "
        f"kernels/ops.py: {missing}"
    )
    assert "REG.resolve(" in ops_src or "registry.resolve(" in ops_src


def test_no_interpret_literals_outside_kernels():
    """Backend dispatch is the registry's job: no tracked .py file outside
    src/repro/kernels/ may pass an ``interpret=`` kwarg (the pre-registry
    hard-coded ``interpret=not _on_tpu()`` pattern)."""
    import re

    pat = re.compile(r"\binterpret\s*=")
    offenders = []
    for f in _tracked_files():
        if not f.endswith(".py") or f.startswith("src/repro/kernels/"):
            continue
        if f == "tests/test_hygiene.py":  # this gate's own docstring
            continue
        for i, line in enumerate((REPO / f).read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{f}:{i}")
    assert offenders == [], (
        f"interpret= literals outside the kernel package: {offenders}"
    )


def test_every_stats_field_reaches_the_registry():
    """Every accounting field — each `SchedulerStats` dataclass field and
    each `SmartPQStats` NamedTuple field — must surface in the engine's
    metrics registry after a `health()` sync (prefixes ``sched_`` /
    ``pq_``).  A stats field that never reaches `repro.obs` is a second
    accounting surface, which is exactly what the unified-telemetry PR
    removed; this gate keeps it removed."""
    import dataclasses
    import sys

    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.core.smartpq import SmartPQStats
        from repro.serve.engine import EngineConfig, ServeEngine
        from repro.serve.scheduler import SchedulerStats

        eng = ServeEngine(None, None, EngineConfig(batch_size=2))
        eng.health()  # syncs every stats surface into the registry
        gauges = eng.obs.metrics.to_dict()["gauges"]
        missing = []
        for f in dataclasses.fields(SchedulerStats):
            prefix = f"sched_{f.name}"
            if not any(k.startswith(prefix) for k in gauges):
                missing.append(f"SchedulerStats.{f.name}")
        for name in SmartPQStats._fields:
            prefix = f"pq_{name}"
            if not any(k.startswith(prefix) for k in gauges):
                missing.append(f"SmartPQStats.{name}")
    finally:
        sys.path.pop(0)
    assert missing == [], (
        f"stats fields never mirrored into the metrics registry: {missing}"
    )


def test_every_fault_injector_is_exercised():
    """Every injector registered in `repro.faults.INJECTORS` must appear by
    name in tests/test_faults.py — a registry entry with no chaos test is a
    fault path nobody has ever watched fail."""
    import sys

    sys.path.insert(0, str(REPO / "src"))
    try:
        from repro.faults import INJECTORS
    finally:
        sys.path.pop(0)
    chaos_src = (REPO / "tests" / "test_faults.py").read_text()
    missing = [name for name in INJECTORS if name not in chaos_src]
    assert missing == [], (
        f"fault injectors with no test coverage in test_faults.py: "
        f"{missing}"
    )
