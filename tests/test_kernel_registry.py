"""Kernel registry contract tests.

Three guarantees the autotuned dispatch layer rests on:

  1. ARM PARITY — every available arm of every registered kernel is
     bit-identical on the spec's validation shapes.  Tuning may only ever
     change speed, never results; this sweep is what makes committing a
     tuning cache safe.
  2. TUNING-CACHE ROUND TRIP — winners persisted by the tuner are what
     `resolve` dispatches after a reload, and the cache file is keyed by
     backend + jax version.
  3. DEGRADED-CACHE SAFETY (chaos) — a missing, corrupt, or
     wrong-backend cache degrades to the spec's safe jnp default; nothing
     raises on the dispatch path.
"""

import json

import numpy as np
import pytest

from repro.kernels import ops as K
from repro.kernels import registry as REG
from repro.kernels import tuning


def _run_arm(spec, coords, arm, seed=0):
    rng = np.random.default_rng(seed)
    args, kwargs = spec.make_inputs(coords, rng)
    fn = getattr(K, spec.name)
    out = fn(*args, arm=arm, **kwargs)
    leaves = out if isinstance(out, tuple) else (out,)
    return [np.asarray(x) for x in leaves]


@pytest.mark.parametrize("name", sorted(REG.REGISTRY))
def test_all_arms_bit_identical(name):
    spec = REG.REGISTRY[name]
    arms = [a.name for a in spec.available_arms()]
    assert spec.default in arms  # the fallback must always be runnable
    for coords in spec.validation_shapes:
        base = _run_arm(spec, coords, arms[0])
        for arm in arms[1:]:
            got = _run_arm(spec, coords, arm)
            assert len(got) == len(base)
            for b, g in zip(base, got):
                np.testing.assert_array_equal(
                    b, g,
                    err_msg=f"{name}: arm {arm!r} != {arms[0]!r} "
                            f"at {dict(coords)}",
                )


def test_resolve_precedence_explicit_then_forced_then_default():
    spec = REG.REGISTRY["topk_smallest"]
    coords = dict(spec.validation_shapes[0])
    # explicit beats everything, and a bogus explicit arm is an error
    with REG.force_arms({"topk_smallest": "ref"}):
        assert REG.resolve("topk_smallest", coords, arm="argsort") == "argsort"
        assert REG.resolve("topk_smallest", coords) == "ref"
    with pytest.raises(ValueError, match="not available"):
        REG.resolve("topk_smallest", coords, arm="no_such_arm")
    # a forced arm that is unavailable on this backend is skipped, not fatal
    with REG.force_arms({"topk_smallest": "compiled@rows_per_block=8"}):
        got = REG.resolve("topk_smallest", coords)
        if not REG.supports_compiled():
            assert got == spec.default
    # wildcard force applies to every kernel that has the arm
    with REG.force_arms({"*": "ref"}):
        assert REG.resolve("topk_smallest", coords) == "ref"
        assert REG.resolve("windowed_merge",
                           dict(REG.REGISTRY["windowed_merge"]
                                .validation_shapes[0])) == "ref"


def test_tuning_cache_round_trip(tmp_path, monkeypatch):
    path = tmp_path / "kernels_test.json"
    monkeypatch.setenv(tuning.CACHE_ENV, str(path))
    tuning.invalidate_cache()
    try:
        coords = {"S": 4, "m": 16}
        rec = tuning.tune_kernel("twochoice_counts", coords,
                                 iters=2, warmup=1)
        assert rec["arm"] in rec["timings"]
        assert rec["us"] == rec["timings"][rec["arm"]]
        assert rec["best"] == min(rec["timings"], key=rec["timings"].get)
        # margin rule: the winner is either the outright fastest arm or
        # the safe default kept because the win was below MIN_SPEEDUP
        spec = REG.REGISTRY["twochoice_counts"]
        if rec["arm"] != rec["best"]:
            assert rec["arm"] == spec.default
            t_def = rec["timings"][spec.default]
            t_best = rec["timings"][rec["best"]]
            assert (t_def < t_best * tuning.MIN_SPEEDUP
                    or t_def - t_best < tuning.MIN_GAIN_US)

        cache = tuning.TuningCache(path)
        cache.put("twochoice_counts", REG.sig(coords), rec)
        saved = cache.save()
        assert saved == path and path.exists()

        # a fresh process-level cache reads the winner back...
        tuning.invalidate_cache()
        assert tuning.cached_winner(
            "twochoice_counts", REG.sig(coords)) == rec["arm"]
        # ...and resolve dispatches it
        assert REG.resolve("twochoice_counts", coords) == rec["arm"]
        # different shape -> no record -> default
        assert REG.resolve("twochoice_counts", {"S": 2, "m": 8}) == \
            REG.REGISTRY["twochoice_counts"].default
    finally:
        tuning.invalidate_cache()


@pytest.mark.chaos
def test_corrupt_or_stale_cache_falls_back_to_default(tmp_path, monkeypatch):
    import jax

    spec = REG.REGISTRY["elim_sort"]
    coords = dict(spec.tuning_shapes[0])
    path = tmp_path / "kernels_broken.json"
    monkeypatch.setenv(tuning.CACHE_ENV, str(path))

    key = tuning.TuningCache.key("elim_sort", REG.sig(coords))
    poisons = [
        ("missing", None),
        ("corrupt json", "{not json"),
        ("wrong payload type", json.dumps([1, 2, 3])),
        ("records not a mapping", json.dumps(
            {"schema": 1, "backend": jax.default_backend(),
             "jax": jax.__version__, "records": []})),
        ("backend mismatch", json.dumps(
            {"schema": 1, "backend": "not_a_backend",
             "jax": jax.__version__,
             "records": {key: {"arm": "ref", "us": 1.0}}})),
        ("jax version mismatch", json.dumps(
            {"schema": 1, "backend": jax.default_backend(),
             "jax": "0.0.0",
             "records": {key: {"arm": "ref", "us": 1.0}}})),
        ("malformed record", json.dumps(
            {"schema": 1, "backend": jax.default_backend(),
             "jax": jax.__version__,
             "records": {key: {"arm": 42}}})),
    ]
    try:
        for label, payload in poisons:
            if path.exists():
                path.unlink()
            if payload is not None:
                path.write_text(payload)
            tuning.invalidate_cache()
            assert tuning.cached_winner("elim_sort", REG.sig(coords)) is None, label
            assert REG.resolve("elim_sort", coords) == spec.default, label
            # the full dispatch path still computes correct results
            out = _run_arm(spec, spec.validation_shapes[0], None)
            ref = _run_arm(spec, spec.validation_shapes[0], "ref")
            for a, b in zip(out, ref):
                np.testing.assert_array_equal(a, b, err_msg=label)
    finally:
        tuning.invalidate_cache()


def test_sssp_segmin_arms_match_bellman_ford():
    """run_sssp must produce the oracle distances under BOTH segment-min
    arms — the relax scatter is on the correctness-critical path."""
    from repro.core.pqueue.schedules import Schedule
    from repro.workloads.graphs import bellman_ford, random_graph
    from repro.workloads.sssp import run_sssp

    g = random_graph(n=96, seed=3)
    ref = bellman_ford(g)
    for arm in ("scatter", "sorted"):
        r = run_sssp(g, Schedule.STRICT_FLAT, m=8, segmin_arm=arm)
        np.testing.assert_array_equal(
            np.asarray(r.dist), ref, err_msg=f"segmin_arm={arm}")


def test_supports_compiled_platforms():
    assert REG.supports_compiled("tpu")
    assert not REG.supports_compiled("cpu")
    assert not REG.supports_compiled("gpu")  # jnp arms, never interpret
