"""8-device check: hierarchical + compressed collectives correctness."""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.collectives import (
    compressed_cross_pod_psum,
    hierarchical_psum,
    reduce_scatter_then_allgather,
)
from repro.distributed.mesh import make_mesh
from repro.distributed.shardmap import shard_map

mesh = make_mesh((2, 4), ("pod", "data"))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))


@partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")), check_vma=False)
def flat(x):
    return jnp.broadcast_to(jax.lax.psum(x, ("pod", "data")), x.shape)


@partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")), check_vma=False)
def hier(x):
    return jnp.broadcast_to(hierarchical_psum(x, ("data",), "pod"), x.shape)


np.testing.assert_allclose(np.asarray(flat(x)), np.asarray(hier(x)), rtol=1e-5, atol=1e-6)
print("hierarchical == flat psum OK")


@partial(shard_map, mesh=mesh, in_specs=(P(("pod", "data")), P(("pod", "data"))),
         out_specs=(P(("pod", "data")), P(("pod", "data"))), check_vma=False)
def compressed(x, err):
    out, new_err = compressed_cross_pod_psum(x[0], ("data",), "pod", err[0])
    return out[None], new_err[None]


err = jnp.zeros_like(x)
exact = np.asarray(flat(x))
total_err = 0.0
# error feedback: accumulated output over steps converges to exact sum
acc_c = np.zeros_like(exact)
acc_e = np.zeros_like(exact)
for step in range(8):
    out, err = compressed(x, err)
    acc_c += np.asarray(out)
    acc_e += exact
rel = np.abs(acc_c - acc_e).max() / (np.abs(acc_e).max() + 1e-9)
assert rel < 0.02, f"error-feedback drift {rel}"
print(f"compressed cross-pod psum error-feedback OK (rel drift {rel:.4f})")


@partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")), check_vma=False)
def rsag(x):
    return jnp.broadcast_to(
        reduce_scatter_then_allgather(x[0], "data", dim=0)[None], x.shape
    )


# shape (1, 64) per device; rs+ag over 'data' (4 devices) on dim0 of (64,)
y = np.asarray(rsag(x))
# compare against psum over data only
@partial(shard_map, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(("pod", "data")), check_vma=False)
def psum_data(x):
    return jnp.broadcast_to(jax.lax.psum(x[0], "data")[None], x.shape)


np.testing.assert_allclose(y, np.asarray(psum_data(x)), rtol=1e-5, atol=1e-6)
print("reduce_scatter+all_gather == psum OK")
print("ALL-COLLECTIVES-OK")
