"""8-device check: elastic rescale of a live training state between meshes
(8 -> 4 devices simulating pod loss) with training continuing identically."""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

import tempfile

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.mesh import make_mesh
from repro.train import checkpoint as ckpt
from repro.train.elastic import (
    fit_spec_to_mesh,
    reshard_state,
    resume_on_new_mesh,
    shardings_for,
)

mesh8 = make_mesh((2, 4), ("pod", "data"))
mesh4 = make_mesh((4,), ("data",), devices=jax.devices()[:4])

spec_tree = {"w": P(("pod", "data"), None), "m": P(("pod", "data"), None)}
state = {
    "w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
    "m": jnp.ones((8, 8), jnp.float32),
}
state8 = reshard_state(state, shardings_for(mesh8, spec_tree))
assert state8["w"].sharding.mesh.shape == {"pod": 2, "data": 4}

# live rescale 8 -> 4 devices ("lost a pod")
spec4 = fit_spec_to_mesh(spec_tree, mesh4)
state4 = reshard_state(state8, shardings_for(mesh4, spec4))
np.testing.assert_array_equal(np.asarray(state4["w"]), np.asarray(state["w"]))
print("live rescale 8->4 OK")

# checkpoint-mediated rescale
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, 3, state8)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored = resume_on_new_mesh(d, like, mesh4, spec4, step=3)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding.mesh.shape == {"data": 4}
print("checkpoint rescale 8->4 OK")

# a train-like update gives identical results on both meshes
def step(s):
    g = s["w"] * 0.1
    return {"w": s["w"] - g, "m": s["m"] * 0.9 + g}

out8 = jax.jit(step)(state8)
out4 = jax.jit(step)(state4)
np.testing.assert_allclose(
    np.asarray(out8["w"]), np.asarray(out4["w"]), rtol=1e-7
)
print("post-rescale step identical OK")
print("ELASTIC-OK")
