"""8-device check: the MULTIQ schedule under shard_map — conservation,
collective-free delete path, and the two-choice window per device.
Run by tests/test_dist.py via subprocess with XLA_FLAGS set."""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

import re
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.pqueue import dist as D
from repro.core.pqueue import ops as O
from repro.core.pqueue.state import INF_KEY, make_state
from repro.distributed.mesh import make_mesh
from repro.distributed.shardmap import shard_map

mesh = make_mesh((2, 4), ("pod", "shard"))
cfg = D.AxisCfg(shard_axes=("shard",), pod_axis="pod")
S_loc, C, n_dev = 2, 64, 8
S_total = n_dev * S_loc
M_LOC = 8
rng = np.random.default_rng(11)

st = make_state(S_total, C)
keys = jnp.asarray(rng.integers(0, 5000, 400), jnp.int32)
vals = jnp.asarray(rng.integers(0, 99, 400), jnp.int32)
st, _ = O.insert(st, keys, vals)
initial = np.sort(np.asarray(st.keys[st.keys < INF_KEY]).ravel())


@partial(
    shard_map,
    mesh=mesh,
    # the tiered PQState pytree shards along the leading axis of every leaf
    in_specs=(P(("pod", "shard")),),
    out_specs=(
        P(("pod", "shard")), P(("pod", "shard")), P(("pod", "shard")),
    ),
    check_vma=False,
)
def multiq_step(state):
    dev = jax.lax.axis_index(("pod", "shard"))
    k = jax.random.fold_in(jax.random.key(7), dev)
    st2, wk, wv, n = D.delete_multiq_dist(state, M_LOC, jnp.int32(M_LOC), k, cfg)
    return st2, wk[None, :], n[None, ...]


out = multiq_step(st)
st2_np, ret_k, ret_n = jax.tree.map(np.asarray, out)
new_keys = np.asarray(st2_np.keys)

# 1. conservation: remaining + returned == initial multiset, globally
returned = ret_k[ret_k < INF_KEY]
remaining = new_keys[new_keys < INF_KEY]
np.testing.assert_array_equal(
    np.sort(np.concatenate([remaining, returned])), initial
)
assert len(returned) > 0
print("MULTIQ-8DEV conservation OK", len(returned), "returned")

# 2. two-choice window: each device's returns come from the heads of its own
# local shards (shard-rank < M_LOC against the pre-delete state)
pre = np.asarray(st.keys).reshape(n_dev, S_loc, C)
for d in range(n_dev):
    heads = pre[d, :, :M_LOC].ravel()
    for k in ret_k.reshape(n_dev, -1)[d]:
        if k < INF_KEY:
            assert k in heads, (d, int(k))
print("MULTIQ-8DEV two-choice window OK")

# 3. the MULTIQ delete path lowers with no cross-device collectives
lowered = jax.jit(multiq_step).lower(st)
hlo = lowered.compile().as_text()
colls = [
    l for l in hlo.splitlines()
    if re.search(
        r"=\s+\S+\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
        r"collective-permute)\(", l,
    )
]
assert not colls, "MULTIQ delete path must be collective-free:\n" + "\n".join(colls)
print("MULTIQ-8DEV collective-free OK")
print("MULTIQ-8DEV-OK")
