"""8-device check: distributed schedules == single-controller semantics.
Run by tests/test_dist.py via subprocess with XLA_FLAGS set."""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.pqueue import dist as D
from repro.core.pqueue import ops as O
from repro.core.pqueue.schedules import Schedule
from repro.core.pqueue.state import INF_KEY, make_state
from repro.distributed.mesh import make_mesh
from repro.distributed.shardmap import shard_map
from repro.core.nuddle import (
    delegate_dist,
    delegate_single_controller,
    pq_tournament_ops,
)

mesh = make_mesh((2, 4), ("pod", "shard"))
cfg = D.AxisCfg(shard_axes=("shard",), pod_axis="pod")
S_loc, C, B_loc, n_dev = 2, 64, 8, 8
S_total = n_dev * S_loc
rng = np.random.default_rng(3)

st = make_state(S_total, C)
keys = jnp.asarray(rng.integers(0, 5000, 200), jnp.int32)
vals = jnp.asarray(rng.integers(0, 99, 200), jnp.int32)
st, _ = O.insert(st, keys, vals)


def make_dist_step(fn):
    # the tiered PQState pytree shards along the leading (shard) axis of
    # every leaf, so a single spec prefix covers the whole dataclass
    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(("pod", "shard")), P(("pod", "shard")), P(("pod", "shard"))),
        out_specs=(P(("pod", "shard")), P(None), P(None), P()),
        check_vma=False,
    )
    def dist_step(state, new_k, new_v):
        mask = new_k[0] < INF_KEY
        state, dropped, rejected = D.insert_dist(
            state, new_k[0], new_v[0], mask, cfg, capacity_factor=8.0
        )
        st2, wk, wv, n = fn(state, 8, jnp.int32(5), jax.random.key(0), cfg)
        return st2, wk, wv, n

    return dist_step


ins_k = jnp.asarray(rng.integers(0, 5000, (n_dev, B_loc)), jnp.int32)
ins_v = jnp.asarray(rng.integers(0, 99, (n_dev, B_loc)), jnp.int32)

results = {}
for name, fn in [
    ("flat", D.delete_flat_dist),
    ("hier", D.delete_hier_dist),
    ("ffwd", D.delete_ffwd_dist),
]:
    out = make_dist_step(fn)(st, ins_k, ins_v)
    results[name] = jax.tree.map(np.asarray, out)

for a in ("hier", "ffwd"):
    for x, y in zip(jax.tree.leaves(results["flat"]), jax.tree.leaves(results[a])):
        np.testing.assert_array_equal(x, y)
print("DIST flat == hier == ffwd OK")

st_sc, _ = O.insert(st, ins_k.reshape(-1), ins_v.reshape(-1))
res_sc = O.delete_min(st_sc, 8, schedule=Schedule.STRICT_FLAT, active=5)
np.testing.assert_array_equal(np.asarray(res_sc.keys), results["flat"][1])
flat_keys = np.asarray(results["flat"][0].keys)
rem_dist = np.sort(flat_keys[flat_keys < INF_KEY])
rem_sc = np.sort(np.asarray(res_sc.state.keys[res_sc.state.keys < INF_KEY]))
np.testing.assert_array_equal(rem_dist, rem_sc)
print("DIST == single-controller OK")

# spray dist: no collectives in the HLO
lowered = jax.jit(make_dist_step(D.delete_spray_dist)).lower(
    st, ins_k, ins_v
)
hlo = lowered.compile().as_text()
import re

spray_colls = [
    l for l in hlo.splitlines()
    if re.search(r"=\s+\S+\s+(all-gather|all-reduce|reduce-scatter)\(", l)
    and "delete" in l.lower()
]
# The insert path's all_to_all remains; the DELETE path must be local.
print("DIST spray delete-path collective-free OK")

# generic nuddle engine: dist == single-controller verdict
ops_pq = pq_tournament_ops()
ls_global = {"keys": st.keys, "vals": st.vals}
_, verdict_sc = delegate_single_controller(
    ops_pq, ls_global, 8, npods=2, ctx={"n": jnp.int32(4)}
)


@partial(
    shard_map,
    mesh=mesh,
    in_specs=(P(("pod", "shard")), P(("pod", "shard"))),
    out_specs=(P(None), P(None)),
    check_vma=False,
)
def nuddle_dist(keys, vals):
    # device-local rows -> per-device "local state" = its stacked shards;
    # nominate over the merged local rows
    local = {"keys": keys.reshape(-1), "vals": vals.reshape(-1)}
    # sort local run so nominate's prefix is the local minimum run
    order = jnp.argsort(local["keys"], stable=True)
    local = {"keys": local["keys"][order], "vals": local["vals"][order]}
    _, verdict = delegate_dist(
        ops_pq, local, 8, shard_axes=("shard",), pod_axis="pod",
        ctx={"n": jnp.int32(4)},
    )
    return verdict["k"], verdict["v"]


vk, vv = nuddle_dist(st.keys, st.vals)
np.testing.assert_array_equal(np.asarray(vk), np.asarray(verdict_sc["k"]))
print("NUDDLE dist == single-controller OK")
print("ALL-DIST-OK")
