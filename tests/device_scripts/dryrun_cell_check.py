"""Guard the dry-run machinery itself: one cheap cell (gemma decode) must
lower + compile on the production mesh and report sane analysis numbers."""

import os

assert "512" in os.environ.get("XLA_FLAGS", "")

import sys

sys.argv = ["dryrun_cell_check"]

from repro.launch.dryrun import lower_cell

rec = lower_cell("gemma-2b", "decode_32k", multi_pod=False, serve_tp_only=True)
assert rec["status"] == "ok", rec
assert rec["n_chips"] == 256
assert rec["flops_per_device"] > 0
assert rec["collective_bytes_per_device"] > 0
assert rec["memory_per_device"]["peak_estimate_bytes"] < 16 * 2**30
assert rec["fits_16gib_hbm"]

rec2 = lower_cell("gemma-2b", "long_500k", multi_pod=False)
assert rec2["status"] == "skipped" and "quadratic" in rec2["reason"]
print("DRYRUN-CELL-OK")
