"""8-device check: expert-parallel shard_map MoE == single-device reference."""

import os

assert "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", "")

import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed.mesh import make_mesh
from repro.models.layers.moe import MoEDims, moe_block, moe_block_ep

mesh = make_mesh((2, 4), ("data", "model"))
dims = MoEDims(n_experts=8, n_experts_pad=8, top_k=2, capacity_factor=4.0)
rng = np.random.default_rng(0)
B, S, D, F, E = 4, 8, 32, 64, 8
x = jnp.asarray(rng.normal(size=(B, S, D)), jnp.float32)
rw = jnp.asarray(rng.normal(size=(D, E)) * 0.3, jnp.float32)
wg = jnp.asarray(rng.normal(size=(E, D, F)) * 0.1, jnp.float32)
wu = jnp.asarray(rng.normal(size=(E, D, F)) * 0.1, jnp.float32)
wd = jnp.asarray(rng.normal(size=(E, F, D)) * 0.1, jnp.float32)

ref, _ = moe_block(x, rw, wg, wu, wd, dims)
out, aux = jax.jit(
    lambda *a: moe_block_ep(*a, dims=dims, mesh=mesh, batch_axes=("data",))
)(x, rw, wg, wu, wd)
err = float(jnp.max(jnp.abs(ref - out)))
assert err < 1e-4, err
print("moe ep matches ref, err", err)

# gradient flows through the shard_map
def loss(wg_):
    o, a = moe_block_ep(x, rw, wg_, wu, wd, dims=dims, mesh=mesh, batch_axes=("data",))
    return jnp.sum(o * o) + a

g = jax.jit(jax.grad(loss))(wg)
assert np.isfinite(np.asarray(g)).all() and float(jnp.max(jnp.abs(g))) > 0
print("moe ep grad OK")
print("MOE-EP-OK")
