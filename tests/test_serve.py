"""Serving substrate: SmartPQ scheduler + engine end-to-end on CPU."""

import numpy as np
import pytest
import jax

from repro.configs.registry import reduced_config
from repro.models.registry import build_model
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.scheduler import Request, SmartPQScheduler


def test_scheduler_priority_order():
    """Interactive (slo 0) requests dispatch before batch (slo 2) ones."""
    sched = SmartPQScheduler(batch_size=32)
    reqs = [Request(uid=i, prompt_len=64, max_new_tokens=4, slo_class=2)
            for i in range(6)]
    reqs += [Request(uid=100 + i, prompt_len=64, max_new_tokens=4, slo_class=0)
             for i in range(2)]
    got = sched.tick(reqs, n_dispatch=0)  # enqueue only
    assert got == []
    out = sched.tick([], n_dispatch=4)
    uids = [r.uid for r in out]
    assert set(uids[:2]) == {100, 101}, f"interactive first, got {uids}"


def test_scheduler_drains():
    sched = SmartPQScheduler(batch_size=16)
    reqs = [Request(uid=i, prompt_len=8, max_new_tokens=2) for i in range(20)]
    dispatched = []
    dispatched += [r.uid for r in sched.tick(reqs[:10], 4)]
    dispatched += [r.uid for r in sched.tick(reqs[10:], 8)]
    for _ in range(10):
        dispatched += [r.uid for r in sched.tick([], 8)]
        if sched.pending == 0:
            break
    assert sorted(dispatched) == list(range(20))
    assert sched.pending == 0


def test_scheduler_tick_window_matches_sequential():
    """tick_window is one fused device call but must dispatch EXACTLY what
    K sequential tick() calls dispatch (the run_window scan is bit-identical
    to the step loop), with the same mode trace."""
    win = SmartPQScheduler(batch_size=16, seed=7)
    seq = SmartPQScheduler(batch_size=16, seed=7)
    reqs = [Request(uid=i, prompt_len=8, max_new_tokens=2, slo_class=i % 3)
            for i in range(24)]
    ticks = [(reqs[:10], 4), (reqs[10:20], 6), (reqs[20:], 6), ([], 8),
             ([], 8)]
    got = win.tick_window(ticks)
    want = [seq.tick(arr, nd) for arr, nd in ticks]
    assert [[r.uid for r in t] for t in got] == [
        [r.uid for r in t] for t in want
    ]
    assert win.pending == seq.pending
    assert win.stats.mode_trace == seq.stats.mode_trace
    assert win.stats.dispatched == seq.stats.dispatched


def test_scheduler_tick_window_matches_sequential_relaxed_mode():
    """Same contract under an rng-DEPENDENT schedule: the window must split
    the scheduler rng exactly as K sequential ticks would, so spray-mode
    dispatches (and the rng state left behind) match bit for bit."""
    from repro.core.pqueue.schedules import Schedule
    from repro.core.smartpq import SmartPQConfig

    def mk():
        return SmartPQScheduler(
            batch_size=16,
            pq_config=SmartPQConfig(
                num_shards=16, capacity=8192, npods=2, decision_interval=4,
                mode_schedules=(Schedule.SPRAY_HERLIHY,) * 3,
            ),
            seed=11,
        )

    win, seq = mk(), mk()
    reqs = [Request(uid=i, prompt_len=8, max_new_tokens=2, slo_class=i % 3)
            for i in range(24)]
    ticks = [(reqs[:10], 4), (reqs[10:20], 6), (reqs[20:], 6), ([], 8),
             ([], 8)]
    got = win.tick_window(ticks)
    want = [seq.tick(arr, nd) for arr, nd in ticks]
    assert [[r.uid for r in t] for t in got] == [
        [r.uid for r in t] for t in want
    ]
    assert win.pending == seq.pending
    # the rng left behind must also agree — a later tick() continues the
    # same stream either way
    more_w = [r.uid for r in win.tick([], 8)]
    more_s = [r.uid for r in seq.tick([], 8)]
    assert more_w == more_s


def test_scheduler_tick_window_drains():
    sched = SmartPQScheduler(batch_size=16)
    reqs = [Request(uid=i, prompt_len=8, max_new_tokens=2) for i in range(20)]
    dispatched = []
    for t in sched.tick_window([(reqs[:10], 4), (reqs[10:], 8)]):
        dispatched += [r.uid for r in t]
    for _ in range(5):
        for t in sched.tick_window([([], 8), ([], 8)]):
            dispatched += [r.uid for r in t]
        if sched.pending == 0:
            break
    assert sorted(dispatched) == list(range(20))
    assert sched.pending == 0


@pytest.mark.slow
def test_engine_end_to_end():
    cfg = reduced_config("llama3.2-3b")
    model = build_model(cfg, remat=False)
    params, _ = model.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, EngineConfig(batch_size=4, max_seq=32))
    # bursty arrivals then drain — the workload pattern that exercises the
    # scheduler's adaptive mode switching
    workload = [[Request(uid=i * 3 + j, prompt_len=8, max_new_tokens=4)
                 for j in range(3)] for i in range(4)]
    summary = eng.run(workload, max_steps=200)
    assert summary["completed"] == 12
    assert all(len(v) > 0 for v in eng.outputs.values())
    assert len(summary["mode_trace"]) > 0


@pytest.mark.slow
def test_engine_windowed_scheduling_end_to_end():
    """sched_window=4 batches scheduler ticks through the fused run_window
    device call; every request must still complete (the admit backlog
    absorbs over-dispatch within a window)."""
    cfg = reduced_config("llama3.2-3b")
    model = build_model(cfg, remat=False)
    params, _ = model.init(jax.random.key(0))
    eng = ServeEngine(
        cfg, params,
        EngineConfig(batch_size=4, max_seq=32, sched_window=4),
    )
    workload = [[Request(uid=i * 3 + j, prompt_len=8, max_new_tokens=4)
                 for j in range(3)] for i in range(4)]
    summary = eng.run(workload, max_steps=300)
    assert summary["completed"] == 12
    assert all(len(v) > 0 for v in eng.outputs.values())
    # one fused window per 4 engine ticks -> the mode trace still records
    # every tick (it comes back from the device per scan step)
    assert len(summary["mode_trace"]) >= summary["steps"]
