"""Serving substrate: SmartPQ scheduler + engine end-to-end on CPU."""

import numpy as np
import pytest
import jax

from repro.configs.registry import reduced_config
from repro.models.registry import build_model
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.scheduler import Request, SmartPQScheduler


def test_scheduler_priority_order():
    """Interactive (slo 0) requests dispatch before batch (slo 2) ones."""
    sched = SmartPQScheduler(batch_size=32)
    reqs = [Request(uid=i, prompt_len=64, max_new_tokens=4, slo_class=2)
            for i in range(6)]
    reqs += [Request(uid=100 + i, prompt_len=64, max_new_tokens=4, slo_class=0)
             for i in range(2)]
    got = sched.tick(reqs, n_dispatch=0)  # enqueue only
    assert got == []
    out = sched.tick([], n_dispatch=4)
    uids = [r.uid for r in out]
    assert set(uids[:2]) == {100, 101}, f"interactive first, got {uids}"


def test_scheduler_drains():
    sched = SmartPQScheduler(batch_size=16)
    reqs = [Request(uid=i, prompt_len=8, max_new_tokens=2) for i in range(20)]
    dispatched = []
    dispatched += [r.uid for r in sched.tick(reqs[:10], 4)]
    dispatched += [r.uid for r in sched.tick(reqs[10:], 8)]
    for _ in range(10):
        dispatched += [r.uid for r in sched.tick([], 8)]
        if sched.pending == 0:
            break
    assert sorted(dispatched) == list(range(20))
    assert sched.pending == 0


@pytest.mark.slow
def test_engine_end_to_end():
    cfg = reduced_config("llama3.2-3b")
    model = build_model(cfg, remat=False)
    params, _ = model.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, EngineConfig(batch_size=4, max_seq=32))
    # bursty arrivals then drain — the workload pattern that exercises the
    # scheduler's adaptive mode switching
    workload = [[Request(uid=i * 3 + j, prompt_len=8, max_new_tokens=4)
                 for j in range(3)] for i in range(4)]
    summary = eng.run(workload, max_steps=200)
    assert summary["completed"] == 12
    assert all(len(v) > 0 for v in eng.outputs.values())
    assert len(summary["mode_trace"]) > 0
