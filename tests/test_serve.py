"""Serving substrate: SmartPQ scheduler + engine end-to-end on CPU."""

import numpy as np
import pytest
import jax

from repro.configs.registry import reduced_config
from repro.models.registry import build_model
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.scheduler import Request, SmartPQScheduler
from repro.workloads.traces import bursty_serve_workload


def test_priority_key_semantics():
    """Pin the priority scheme: SLO-major, shortest-prompt-first minor with
    linear aging (the scheduler module docstring's formula)."""
    # SLO class dominates: the longest interactive prompt still beats the
    # shortest batch prompt (minor term bounded below 1 << 27).
    interactive = Request(uid=0, prompt_len=1 << 20, max_new_tokens=1,
                          slo_class=0)
    batch = Request(uid=1, prompt_len=1, max_new_tokens=1, slo_class=2)
    assert interactive.priority_key(0) < batch.priority_key(0)
    # Within a class at equal age: shorter prompt first.
    short = Request(uid=2, prompt_len=8, max_new_tokens=1, slo_class=1)
    long = Request(uid=3, prompt_len=64, max_new_tokens=1, slo_class=1)
    assert short.priority_key(0) < long.priority_key(0)
    # Aging: each waiting step shaves 4 off the effective prompt length,
    # monotonically down to the class floor (no starvation: an aged long
    # prompt eventually ties the floor and FIFO seq order takes over).
    keys = [long.priority_key(s) for s in range(0, 20)]
    assert all(a >= b for a, b in zip(keys, keys[1:]))
    assert keys[-1] == 1 << 27  # decayed to the slo-1 class floor
    # age 16: 64 - 4*16 = 0 -> the aged long prompt sits at the floor and
    # beats a JUST-ARRIVED short prompt (age 0, minor term 8 > 0)
    assert long.priority_key(16) == 1 << 27
    fresh = Request(uid=4, prompt_len=8, max_new_tokens=1, slo_class=1,
                    arrival_step=16)
    assert long.priority_key(16) < fresh.priority_key(16)


def test_scheduler_priority_order():
    """Interactive (slo 0) requests dispatch before batch (slo 2) ones."""
    sched = SmartPQScheduler(batch_size=32)
    reqs = [Request(uid=i, prompt_len=64, max_new_tokens=4, slo_class=2)
            for i in range(6)]
    reqs += [Request(uid=100 + i, prompt_len=64, max_new_tokens=4, slo_class=0)
             for i in range(2)]
    got = sched.tick(reqs, n_dispatch=0)  # enqueue only
    assert got == []
    out = sched.tick([], n_dispatch=4)
    uids = [r.uid for r in out]
    assert set(uids[:2]) == {100, 101}, f"interactive first, got {uids}"


def test_scheduler_drains():
    sched = SmartPQScheduler(batch_size=16)
    reqs = [Request(uid=i, prompt_len=8, max_new_tokens=2) for i in range(20)]
    dispatched = []
    dispatched += [r.uid for r in sched.tick(reqs[:10], 4)]
    dispatched += [r.uid for r in sched.tick(reqs[10:], 8)]
    for _ in range(10):
        dispatched += [r.uid for r in sched.tick([], 8)]
        if sched.pending == 0:
            break
    assert sorted(dispatched) == list(range(20))
    assert sched.pending == 0


def test_scheduler_arrival_overflow_spills_to_backlog():
    """Arrivals beyond the lane width are NOT dropped: they wait in the
    FIFO arrival backlog (tick) / admission ring (tick_window) and insert
    on later ticks."""
    sched = SmartPQScheduler(batch_size=8)
    reqs = [Request(uid=i, prompt_len=4, max_new_tokens=1) for i in range(20)]
    sched.tick(reqs, n_dispatch=0)
    assert len(sched._arrival_backlog) == 12
    assert sched.pending == 20  # queued on device + backlog
    sched.tick([], n_dispatch=0)
    sched.tick([], n_dispatch=0)
    assert sched._arrival_backlog == []
    dispatched = []
    for _ in range(10):
        dispatched += [r.uid for r in sched.tick([], 8)]
        if sched.pending == 0:
            break
    assert sorted(dispatched) == list(range(20))


def test_scheduler_tick_window_matches_sequential():
    """tick_window is one fused device call but must dispatch EXACTLY what
    K sequential tick() calls dispatch (same lanes, same on-device priority
    keys, same per-tick budgets), with the same mode trace."""
    win = SmartPQScheduler(batch_size=16, seed=7)
    seq = SmartPQScheduler(batch_size=16, seed=7)
    reqs = [Request(uid=i, prompt_len=8, max_new_tokens=2, slo_class=i % 3)
            for i in range(24)]
    arrivals = [reqs[:10], reqs[10:20], reqs[20:], [], []]
    budgets = [4, 6, 6, 8, 8]  # mid-window budgets, not just [free, 0, ...]
    got = win.tick_window(arrivals, budgets)
    want = [seq.tick(arr, nd) for arr, nd in zip(arrivals, budgets)]
    assert [[r.uid for r in t] for t in got] == [
        [r.uid for r in t] for t in want
    ]
    assert win.pending == seq.pending
    assert win.stats.mode_trace == seq.stats.mode_trace
    assert win.stats.dispatched == seq.stats.dispatched
    assert win.stats.inserted == seq.stats.inserted


def test_scheduler_tick_window_matches_sequential_relaxed_mode():
    """Same contract under an rng-DEPENDENT schedule: the window must split
    the scheduler rng exactly as K sequential ticks would, so spray-mode
    dispatches (and the rng state left behind) match bit for bit."""
    from repro.core.pqueue.schedules import Schedule
    from repro.core.smartpq import SmartPQConfig

    def mk():
        return SmartPQScheduler(
            batch_size=16,
            pq_config=SmartPQConfig(
                num_shards=16, capacity=8192, npods=2, decision_interval=4,
                mode_schedules=(Schedule.SPRAY_HERLIHY,) * 3,
            ),
            seed=11,
        )

    win, seq = mk(), mk()
    reqs = [Request(uid=i, prompt_len=8, max_new_tokens=2, slo_class=i % 3)
            for i in range(24)]
    arrivals = [reqs[:10], reqs[10:20], reqs[20:], [], []]
    budgets = [4, 6, 6, 8, 8]
    got = win.tick_window(arrivals, budgets)
    want = [seq.tick(arr, nd) for arr, nd in zip(arrivals, budgets)]
    assert [[r.uid for r in t] for t in got] == [
        [r.uid for r in t] for t in want
    ]
    assert win.pending == seq.pending
    # the rng left behind must also agree — a later tick() continues the
    # same stream either way
    more_w = [r.uid for r in win.tick([], 8)]
    more_s = [r.uid for r in seq.tick([], 8)]
    assert more_w == more_s


def test_scheduler_tick_window_drains():
    sched = SmartPQScheduler(batch_size=16)
    reqs = [Request(uid=i, prompt_len=8, max_new_tokens=2) for i in range(20)]
    dispatched = []
    for t in sched.tick_window([reqs[:10], reqs[10:]], [4, 8]):
        dispatched += [r.uid for r in t]
    for _ in range(5):
        for t in sched.tick_window([[], []], [8, 8]):
            dispatched += [r.uid for r in t]
        if sched.pending == 0:
            break
    assert sorted(dispatched) == list(range(20))
    assert sched.pending == 0


def test_scheduler_ring_overflow_carries_across_windows():
    """A burst beyond the admission ring capacity spills to the host
    backlog and admits on the NEXT window — nothing dropped."""
    sched = SmartPQScheduler(batch_size=8, ring_capacity=16)
    reqs = [Request(uid=i, prompt_len=4, max_new_tokens=1) for i in range(40)]
    sched.tick_window([list(reqs), []], [0, 0])
    assert len(sched._arrival_backlog) == 40 - 16
    assert sched.pending == 40
    dispatched = []
    for _ in range(10):
        for t in sched.tick_window([[], [], [], []], [8] * 4):
            dispatched += [r.uid for r in t]
        if sched.pending == 0:
            break
    assert sorted(dispatched) == list(range(40))


def test_window_budgets_forecast():
    """The slot-availability forecast: window-start free slots at tick 0,
    `remaining`-predicted completions (+ slot recycling) on later ticks;
    forecast=False reproduces the window-start-budget baseline."""
    eng = ServeEngine(None, None, EngineConfig(
        batch_size=4, max_seq=32, sched_window=8, forecast=False,
    ))
    assert eng._window_budgets(8) == [4, 0, 0, 0, 0, 0, 0, 0]
    eng.ecfg.forecast = True
    # empty engine: recycling projects tick-0 admissions to free slots
    # one service-estimate later
    eng._service_est = 3.0
    assert eng._window_budgets(8) == [4, 0, 0, 4, 0, 0, 4, 0]
    # occupy two slots with known remaining: they free at ticks 2 and 5
    eng.active[0] = Request(uid=0, prompt_len=4, max_new_tokens=2)
    eng.active[1] = Request(uid=1, prompt_len=4, max_new_tokens=5)
    eng.remaining[0] = 2
    eng.remaining[1] = 5
    b = eng._window_budgets(8)
    assert b[0] == 2  # free slots now
    assert b[2] >= 1 and b[5] >= 1  # deterministic completions admit there
    # EOS hazard adds expected early stops once it accumulates to 1
    eng.ecfg.eos_hazard = 0.5
    bh = eng._window_budgets(8)
    assert sum(bh) > sum(b)


def _burst_workload(n_ticks=4, per_tick=3, ntok=4):
    return [
        [Request(uid=i * per_tick + j, prompt_len=8, max_new_tokens=ntok)
         for j in range(per_tick)]
        for i in range(n_ticks)
    ]


@pytest.mark.parametrize("K", [4, 16])
def test_engine_window_same_completion_set(K):
    """Regression: sched_window > 1 must drain a workload to the SAME
    completion set (and identical per-request outputs) as sched_window == 1
    — windowing changes dispatch granularity, never correctness."""
    base = ServeEngine(None, None, EngineConfig(batch_size=4, max_seq=32))
    s1 = base.run(_burst_workload(), max_steps=400)
    win = ServeEngine(None, None, EngineConfig(
        batch_size=4, max_seq=32, sched_window=K,
    ))
    sk = win.run(_burst_workload(), max_steps=400)
    assert s1["completed"] == sk["completed"] == 12
    assert set(base.outputs) == set(win.outputs)
    assert base.outputs == win.outputs  # same slots-agnostic token streams


def test_engine_backlog_parks_past_max_steps():
    """Dispatches popped from the device queue past max_steps must park in
    the admit backlog — a later run() call admits them instead of losing
    them."""
    eng = ServeEngine(None, None, EngineConfig(
        batch_size=2, max_seq=32, sched_window=4,
    ))
    # short service estimate -> the forecast budgets every tick, so the
    # window pops dispatches for ticks max_steps will never run
    eng._service_est = 1.0
    # 8 requests in tick 0; max_steps=2 cuts the first window after two
    # engine ticks, with dispatches for later ticks already popped
    wl = [[Request(uid=i, prompt_len=4, max_new_tokens=2) for i in range(8)]]
    s = eng.run(wl, max_steps=2)
    assert s["steps"] == 2
    assert s["completed"] < 8
    parked = len(eng._backlog)
    pending = eng.scheduler.pending
    assert parked + pending + sum(r is not None for r in eng.active) \
        + s["completed"] == 8
    assert parked > 0  # the cut window had already popped extra dispatches
    s2 = eng.run([], max_steps=400)
    assert s["completed"] + s2["completed"] == 8
    assert eng._backlog == [] and eng.scheduler.pending == 0


def test_engine_forecast_improves_throughput():
    """Acceptance: on an open-loop bursty trace, mid-window admission
    strictly increases throughput (tokens per engine step) — equivalently
    drains in fewer steps — vs the window-start-budget baseline, at
    sched_window in {4, 16}."""
    for K in (4, 16):
        results = {}
        for forecast in (False, True):
            eng = ServeEngine(None, None, EngineConfig(
                batch_size=4, max_seq=64, sched_window=K, forecast=forecast,
            ))
            wl = bursty_serve_workload(
                steps=24, rates=(6.0, 0.5), mean_dwell=(8.0, 8.0), seed=1
            )
            s = eng.run(wl, max_steps=4000)
            total = sum(len(eng.outputs[u]) for u in eng.outputs)
            assert s["completed"] == len(eng.outputs)
            results[forecast] = total / s["steps"]
        assert results[True] > results[False], (
            f"K={K}: forecast {results[True]:.3f} tok/step must beat "
            f"baseline {results[False]:.3f}"
        )


@pytest.mark.slow
def test_engine_end_to_end():
    cfg = reduced_config("llama3.2-3b")
    model = build_model(cfg, remat=False)
    params, _ = model.init(jax.random.key(0))
    eng = ServeEngine(cfg, params, EngineConfig(batch_size=4, max_seq=32))
    # bursty arrivals then drain — the workload pattern that exercises the
    # scheduler's adaptive mode switching
    workload = [[Request(uid=i * 3 + j, prompt_len=8, max_new_tokens=4)
                 for j in range(3)] for i in range(4)]
    summary = eng.run(workload, max_steps=200)
    assert summary["completed"] == 12
    assert all(len(v) > 0 for v in eng.outputs.values())
    assert len(summary["mode_trace"]) > 0


@pytest.mark.parametrize("K", [1, 4, 16])
def test_tick_window_request_conservation(K):
    """Property: across randomized arrival/budget streams, every submitted
    request is exactly one of {inserted on device, waiting in the backlog,
    shed at admission, evicted by the cap} — and the dispatch side balances
    too (inserted == dispatched + still queued on device).  Run with an
    overload controller attached and a tiny ring/backlog cap so ALL four
    buckets are live at once."""
    from repro.core.smartpq import MODE_AWARE, SmartPQConfig
    from repro.serve.overload import OverloadConfig

    rng = np.random.default_rng(1000 + K)
    sched = SmartPQScheduler(
        batch_size=8,
        pq_config=SmartPQConfig(
            num_shards=4, capacity=1024, decision_interval=4,
            initial_mode=MODE_AWARE,
        ),
        seed=K,
        ring_capacity=16,
        overload=OverloadConfig(
            targets=(2.0, 4.0, 8.0), backlog_cap=24, min_samples=4,
            window=64,
        ),
    )
    total = 0
    uid = 0
    for w in range(8):
        arrivals = []
        for t in range(K):
            n = int(rng.integers(0, 24))
            arrivals.append([
                Request(
                    uid=uid + i, prompt_len=int(rng.integers(1, 64)),
                    max_new_tokens=2, slo_class=int(rng.integers(0, 3)),
                    arrival_step=w * K + t,
                )
                for i in range(n)
            ])
            uid += n
            total += n
        budgets = [int(rng.integers(0, 6)) for _ in range(K)]
        sched.tick_window(arrivals, budgets)
        st = sched.stats
        on_device = int(sched.carry.state.total_size)
        backlog = len(sched._arrival_backlog)
        assert st.inserted + backlog + st.shed + st.evicted == total, (
            f"window {w}: conservation broken "
            f"(inserted={st.inserted} backlog={backlog} shed={st.shed} "
            f"evicted={st.evicted} != arrivals={total})"
        )
        assert st.inserted == st.dispatched + on_device
        # host map == in-flight work only (memory bound)
        assert len(sched._requests) == on_device + backlog
        assert backlog <= sched.overload.config.backlog_cap
    # the tight targets/cap must actually exercise the drop buckets,
    # otherwise this property test silently degrades to the happy path
    assert sched.stats.shed + sched.stats.evicted > 0


@pytest.mark.slow
def test_engine_windowed_scheduling_end_to_end():
    """sched_window=4 batches scheduler ticks through the fused window
    device call; every request must still complete (the admit backlog
    absorbs over-dispatch within a window)."""
    cfg = reduced_config("llama3.2-3b")
    model = build_model(cfg, remat=False)
    params, _ = model.init(jax.random.key(0))
    eng = ServeEngine(
        cfg, params,
        EngineConfig(batch_size=4, max_seq=32, sched_window=4),
    )
    workload = [[Request(uid=i * 3 + j, prompt_len=8, max_new_tokens=4)
                 for j in range(3)] for i in range(4)]
    summary = eng.run(workload, max_steps=300)
    assert summary["completed"] == 12
    assert all(len(v) > 0 for v in eng.outputs.values())
    # one fused window per 4 engine ticks -> the mode trace still records
    # every tick (it comes back from the device per scan step)
    assert len(summary["mode_trace"]) >= summary["steps"]
