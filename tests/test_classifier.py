"""Decision-tree classifier: §3.1.2/§4.2.1 of the paper."""

import numpy as np
import jax.numpy as jnp

from repro.core.classifier.cost_model import (
    MeshGeom,
    Workload,
    best_mode,
    mode_throughputs,
    throughput,
)
from repro.core.classifier.dataset import make_test_set, make_training_set
from repro.core.classifier.features import (
    CLASS_AWARE,
    CLASS_MULTIQ,
    CLASS_NEUTRAL,
    CLASS_OBLIVIOUS,
    NUM_CLASSES,
    NUM_MODES,
    featurize,
)
from repro.core.classifier.inference import pack_tree, tree_predict
from repro.core.classifier.tree import train_tree


def test_cost_model_regimes():
    """The paper's qualitative regimes (Figs 1/7/9) hold in the 3-mode cost
    model, plus the MultiQueue mixed-contention regime."""
    # insert-heavy: a collective-free relaxed mode wins (delegation latency
    # wasted); with the MultiQueue in the cast, its tighter envelope makes
    # it the usual winner over plain spray.
    insert_heavy = Workload(512, 65536, 1 << 20, 0.9)
    assert best_mode(insert_heavy) in (CLASS_OBLIVIOUS, CLASS_MULTIQ)
    # delete-heavy tiny queue: relaxation saturates for BOTH relaxed modes,
    # only exact delegation does useful work.
    delete_heavy_small = Workload(512, 4096, 1 << 20, 0.1)
    assert best_mode(delete_heavy_small) == CLASS_AWARE
    # mixed contention, medium queue: the MultiQueue regime — spray's
    # envelope hurts, delegation's latency hurts, two-choice wins.
    mixed_medium = Workload(64, 8192, 1 << 24, 0.6)
    assert best_mode(mixed_medium) == CLASS_MULTIQ
    # pure-delete waste-free corner (huge queue): spray's single probe beats
    # multiq's double probe — OBLIVIOUS must survive as a decisive label.
    drain_huge = Workload(64, 1 << 23, 1 << 26, 0.0)
    assert best_mode(drain_huge) == CLASS_OBLIVIOUS
    # single pod, few clients -> close to neutral (paper §3.1.2(1)(i))
    w = Workload(8, 16384, 1 << 16, 0.5)
    for mode in range(NUM_MODES):
        assert throughput(mode, w, g=MeshGeom(npods=1)) > 0


def test_multiq_envelope_monotonicity():
    """MULTIQ's effective throughput dominates spray's whenever relaxation
    waste is material, and its waste fraction is never larger."""
    from repro.core.classifier.cost_model import _waste_fraction, TPU_V5E

    for d, z, p in [(64, 8192, 0.5), (128, 16384, 0.3), (32, 4096, 0.6)]:
        w = Workload(d, z, 1 << 24, p)
        assert _waste_fraction(w, TPU_V5E, CLASS_MULTIQ) <= _waste_fraction(
            w, TPU_V5E, CLASS_OBLIVIOUS
        )
        ts = mode_throughputs(w)
        assert ts[CLASS_MULTIQ] >= ts[CLASS_OBLIVIOUS]


def test_tree_training_deterministic_and_accurate():
    X, y = make_training_set()
    t1 = train_tree(X, y, NUM_CLASSES, max_depth=8)
    t2 = train_tree(X, y, NUM_CLASSES, max_depth=8)
    assert [(n.feature, n.threshold) for n in t1.nodes] == [
        (n.feature, n.threshold) for n in t2.nodes
    ]
    assert (t1.predict(X) == y).mean() > 0.93
    assert t1.depth() <= 8

    Xt, yt, _ = make_test_set(1500)
    acc = (t1.predict(Xt) == yt).mean()
    assert acc > 0.8, f"test accuracy {acc} (paper reports 87.9%)"


def test_packed_tree_matches_host_tree():
    X, y = make_training_set()
    tree = train_tree(X, y, NUM_CLASSES, max_depth=8)
    packed = pack_tree(tree)
    Xt, _, _ = make_test_set(300, seed=11)
    host = tree.predict(Xt)
    dev = np.array([int(tree_predict(packed, jnp.asarray(x))) for x in Xt])
    np.testing.assert_array_equal(host, dev)


def test_misprediction_cost_metric():
    """Paper §4.2.1: ((X - Y)/Y) over mispredicted workloads, where X is the
    best mode's throughput and Y the PREDICTED mode's (the basis rows hold
    every mode's throughput, indexed by class id).  We check the machinery;
    the value lands in EXPERIMENTS.md."""
    X, y = make_training_set()
    tree = train_tree(X, y, NUM_CLASSES)
    Xt, yt, basis = make_test_set(800, seed=5)
    pred = tree.predict(Xt)
    wrong = (pred != yt) & (pred != CLASS_NEUTRAL) & (yt != CLASS_NEUTRAL)
    costs = []
    for i in np.where(wrong)[0]:
        t = basis[i]
        best, chosen = max(t), t[pred[i]]
        costs.append((best - chosen) / max(chosen, 1e-9))
    assert all(np.isfinite(costs))
    if costs:  # geometric mean misprediction cost
        gm = float(np.exp(np.mean(np.log(np.maximum(costs, 1e-9)))))
        assert gm < 10.0


def test_featurize_shapes():
    f = featurize(64, 1024, 2048, 0.5)
    assert f.shape == (4,) and f.dtype == np.float32
    fb = featurize([1, 2], [10, 20], [100, 200], [0.1, 0.9])
    assert fb.shape == (2, 4)
