"""Decision-tree classifier: §3.1.2/§4.2.1 of the paper."""

import numpy as np
import jax.numpy as jnp

from repro.core.classifier.cost_model import (
    MeshGeom,
    Workload,
    best_mode,
    throughput,
)
from repro.core.classifier.dataset import make_test_set, make_training_set
from repro.core.classifier.features import (
    CLASS_AWARE,
    CLASS_NEUTRAL,
    CLASS_OBLIVIOUS,
    NUM_CLASSES,
    featurize,
)
from repro.core.classifier.inference import pack_tree, tree_predict
from repro.core.classifier.tree import train_tree


def test_cost_model_regimes():
    """The paper's qualitative regimes (Figs 1/7/9) hold in the cost model."""
    insert_heavy = Workload(512, 65536, 1 << 20, 0.9)
    delete_heavy_small = Workload(512, 4096, 1 << 20, 0.1)
    assert best_mode(insert_heavy) == CLASS_OBLIVIOUS
    assert best_mode(delete_heavy_small) == CLASS_AWARE
    # single pod, few clients -> close to neutral (paper §3.1.2(1)(i))
    w = Workload(8, 16384, 1 << 16, 0.5)
    t_o = throughput(CLASS_OBLIVIOUS, w, g=MeshGeom(npods=1))
    t_a = throughput(CLASS_AWARE, w, g=MeshGeom(npods=1))
    assert t_o > 0 and t_a > 0


def test_tree_training_deterministic_and_accurate():
    X, y = make_training_set()
    t1 = train_tree(X, y, NUM_CLASSES, max_depth=8)
    t2 = train_tree(X, y, NUM_CLASSES, max_depth=8)
    assert [(n.feature, n.threshold) for n in t1.nodes] == [
        (n.feature, n.threshold) for n in t2.nodes
    ]
    assert (t1.predict(X) == y).mean() > 0.93
    assert t1.depth() <= 8

    Xt, yt, _ = make_test_set(1500)
    acc = (t1.predict(Xt) == yt).mean()
    assert acc > 0.8, f"test accuracy {acc} (paper reports 87.9%)"


def test_packed_tree_matches_host_tree():
    X, y = make_training_set()
    tree = train_tree(X, y, NUM_CLASSES, max_depth=8)
    packed = pack_tree(tree)
    Xt, _, _ = make_test_set(300, seed=11)
    host = tree.predict(Xt)
    dev = np.array([int(tree_predict(packed, jnp.asarray(x))) for x in Xt])
    np.testing.assert_array_equal(host, dev)


def test_misprediction_cost_metric():
    """Paper §4.2.1: ((X - Y)/Y) over mispredicted workloads is finite and
    reported; we check the machinery, the value lands in EXPERIMENTS.md."""
    X, y = make_training_set()
    tree = train_tree(X, y, NUM_CLASSES)
    Xt, yt, basis = make_test_set(800, seed=5)
    pred = tree.predict(Xt)
    wrong = (pred != yt) & (pred != CLASS_NEUTRAL) & (yt != CLASS_NEUTRAL)
    costs = []
    for i in np.where(wrong)[0]:
        t = basis[i]
        hi, lo = max(t), min(t)
        costs.append((hi - lo) / max(lo, 1e-9))
    if costs:  # geometric mean misprediction cost
        gm = float(np.exp(np.mean(np.log(np.maximum(costs, 1e-9)))))
        assert gm < 10.0


def test_featurize_shapes():
    f = featurize(64, 1024, 2048, 0.5)
    assert f.shape == (4,) and f.dtype == np.float32
    fb = featurize([1, 2], [10, 20], [100, 200], [0.1, 0.9])
    assert fb.shape == (2, 4)
