"""SmartPQ adaptive behavior — the paper's §3 contributions."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT
from repro.core.pqueue.state import INF_KEY
from repro.core.smartpq import (
    MODE_AWARE,
    MODE_MULTIQ,
    MODE_OBLIVIOUS,
    SmartPQ,
    SmartPQConfig,
)

CFG = SmartPQConfig(num_shards=8, capacity=512, npods=2, decision_interval=2)


def _batches(rng, n, B, ins_frac, key_range=1 << 20):
    for i in range(n):
        ops = (rng.random(B) > ins_frac).astype(np.int32)
        keys = rng.integers(0, key_range, B).astype(np.int32)
        yield jnp.asarray(ops), jnp.asarray(keys), jnp.zeros(B, jnp.int32)


def test_adapts_to_contention_change():
    """Insert burst -> oblivious; delete storm on a small queue -> aware."""
    pq = SmartPQ(CFG)
    carry = pq.init()
    step = jax.jit(pq.step)
    rng = np.random.default_rng(1)
    key = jax.random.key(0)
    modes = []
    for phase_frac in (0.95, 0.05):
        for ops, keys, vals in _batches(rng, 20, 32, phase_frac):
            key, sub = jax.random.split(key)
            carry, _ = step(carry, ops, keys, vals, sub, 512)
            modes.append(int(carry.stats.mode))
    assert MODE_OBLIVIOUS in modes[:20], "insert phase should run oblivious"
    assert MODE_AWARE in modes[20:], "delete storm should trigger delegation"
    assert int(carry.stats.transitions) >= 1


def test_zero_copy_transition():
    """Key idea 3: the mode flip changes NO queue data — state before a
    decision step equals state after it minus exactly the batch effects.
    We verify by running the same batch under both fixed modes from the
    same state: the underlying representation is identical (same pytree
    shapes, same sharding, same buffers semantics)."""
    pq = SmartPQ(CFG)
    carry = pq.init()
    rng = np.random.default_rng(2)
    key = jax.random.key(1)
    # fill
    step = jax.jit(pq.step)
    for ops, keys, vals in _batches(rng, 5, 32, 1.0):
        key, sub = jax.random.split(key)
        carry, _ = step(carry, ops, keys, vals, sub, 512)

    mode_steps = pq.make_mode_steps()
    ops = jnp.full((32,), OP_DELETE_MIN, jnp.int32)
    keys = jnp.full((32,), INF_KEY, jnp.int32)
    vals = jnp.zeros((32,), jnp.int32)
    # mode steps DONATE their state argument — keep copies to run both modes
    # from the same starting state
    st_obl = jax.tree.map(jnp.copy, carry.state)
    st_aw = jax.tree.map(jnp.copy, carry.state)
    r_obl = mode_steps[MODE_OBLIVIOUS](st_obl, ops, keys, vals, key)
    r_aw = mode_steps[MODE_AWARE](st_aw, ops, keys, vals, key)
    # identical state layout, identical multiset semantics
    assert jax.tree.structure(r_obl.state) == jax.tree.structure(r_aw.state)
    for a, b in zip(jax.tree.leaves(r_obl.state), jax.tree.leaves(r_aw.state)):
        assert a.shape == b.shape and a.dtype == b.dtype
    # conservation: both removed the same NUMBER of elements
    assert int(r_obl.n_out) == int(r_aw.n_out)


def test_aware_mode_exact_oblivious_relaxed():
    """Aware (hier) returns the true minima; oblivious (spray) stays within
    the envelope — on the same starting state."""
    from repro.core.pqueue.ref import RefPQ
    from repro.core.pqueue import ops as O

    pq = SmartPQ(CFG)
    carry = pq.init()
    rng = np.random.default_rng(3)
    key = jax.random.key(2)
    step = jax.jit(pq.step)
    ref = RefPQ(CFG.num_shards, CFG.capacity)
    for ops, keys, vals in _batches(rng, 6, 32, 1.0, key_range=4096):
        key, sub = jax.random.split(key)
        carry, _ = step(carry, ops, keys, vals, sub, 512)
        ref.insert_batch(np.asarray(keys), np.asarray(vals),
                         mask=np.asarray(ops) == OP_INSERT)

    mode_steps = pq.make_mode_steps()
    ops = jnp.full((16,), OP_DELETE_MIN, jnp.int32)
    keys = jnp.full((16,), INF_KEY, jnp.int32)
    # mode steps donate their state argument — copy per call
    r_aw = mode_steps[MODE_AWARE](
        jax.tree.map(jnp.copy, carry.state), ops, keys,
        jnp.zeros(16, jnp.int32), key,
    )
    exact_k, _ = ref.delete_min_exact(16)
    np.testing.assert_array_equal(np.asarray(r_aw.keys)[: int(r_aw.n_out)], exact_k)

    ref2 = RefPQ(CFG.num_shards, CFG.capacity)
    ref2._items = list(ref._items)  # post-delete state? use fresh oracle
    r_ob = mode_steps[MODE_OBLIVIOUS](
        jax.tree.map(jnp.copy, carry.state), ops, keys,
        jnp.zeros(16, jnp.int32), key,
    )
    got = np.asarray(r_ob.keys)[: int(r_ob.n_out)]
    # envelope vs the PRE-delete oracle
    ref3 = RefPQ(CFG.num_shards, CFG.capacity)
    ref3._items = sorted(ref._items + list(zip(exact_k.tolist(),
                                               [0]*len(exact_k),
                                               range(len(exact_k)),
                                               [0]*len(exact_k))))
    ok, msg = ref3.check_spray_result(got, 16)
    assert ok, msg


def test_three_mode_schedule_in_one_scanned_program():
    """Tentpole acceptance: ONE compiled program (a single jitted lax.scan,
    so every step carries all three lax.switch branches) driven through a
    phase trace whose features force oblivious -> multiq -> aware."""
    cfg = SmartPQConfig(num_shards=8, capacity=1024, npods=2,
                        decision_interval=2)
    pq = SmartPQ(cfg)
    B = 128
    rng = np.random.default_rng(0)
    # (num_clients, insert_frac, steps): phase 1 is insert-heavy with many
    # clients (neutral band -> keeps the initial OBLIVIOUS mode) and grows
    # the queue to ~3.5k; phase 2 is a mixed load from few clients on the
    # medium queue (the MultiQueue regime); phase 3 is delete-heavy (the
    # delegation regime).
    phases = [(512, 0.95, 30), (16, 0.6, 12), (64, 0.3, 12)]
    ops_all, keys_all, clients_all = [], [], []
    for d, p, steps in phases:
        for _ in range(steps):
            ops_all.append((rng.random(B) > p).astype(np.int32))
            keys_all.append(rng.integers(0, 16384, B).astype(np.int32))
            clients_all.append(d)
    xs = (
        jnp.asarray(np.stack(ops_all)),
        jnp.asarray(np.stack(keys_all)),
        jnp.zeros((len(ops_all), B), jnp.int32),
        jnp.asarray(clients_all, jnp.int32),
        jax.random.split(jax.random.key(1), len(ops_all)),
    )

    @jax.jit
    def scanned(carry, xs):
        def body(c, x):
            ops, keys, vals, d, k = x
            c2, _ = pq.step(c, ops, keys, vals, k, d)
            return c2, c2.stats.mode

        return jax.lax.scan(body, carry, xs)

    carry, modes = scanned(pq.init(), xs)
    modes = np.asarray(modes).tolist()
    p1, p2 = phases[0][2], phases[0][2] + phases[1][2]
    assert MODE_OBLIVIOUS in modes[:p1], f"phase 1 modes: {modes[:p1]}"
    assert MODE_MULTIQ in modes[p1:p2], f"phase 2 modes: {modes[p1:p2]}"
    assert MODE_AWARE in modes[p2:], f"phase 3 modes: {modes[p2:]}"
    assert {MODE_OBLIVIOUS, MODE_MULTIQ, MODE_AWARE} <= set(modes)
    assert int(carry.stats.transitions) >= 2


def test_all_mode_branches_in_compiled_program():
    """The jitted step lowers all NUM_MODES switch branches into one
    program: each mode's schedule is structurally distinct, and forcing the
    carry mode exercises each branch without recompilation."""
    pq = SmartPQ(CFG)
    step = jax.jit(pq.step)
    rng = np.random.default_rng(7)
    key = jax.random.key(9)
    carry = pq.init()
    for ops, keys, vals in _batches(rng, 4, 32, 1.0, key_range=4096):
        key, sub = jax.random.split(key)
        carry, _ = step(carry, ops, keys, vals, sub, 512)
    ops = jnp.full((32,), OP_DELETE_MIN, jnp.int32)
    keys = jnp.full((32,), INF_KEY, jnp.int32)
    vals = jnp.zeros((32,), jnp.int32)
    outs = {}
    for mode in (MODE_OBLIVIOUS, MODE_MULTIQ, MODE_AWARE):
        forced = carry._replace(
            stats=carry.stats._replace(
                mode=jnp.int32(mode),
                # park the decision counter so no re-decision overrides us
                step=jnp.int32(1),
            )
        )
        c2, res = step(forced, ops, keys, vals, key, 8)
        assert int(c2.stats.mode) == mode
        outs[mode] = np.asarray(res.keys)[: int(res.n_out)]
    assert step._cache_size() == 1, "mode forcing must not recompile"
    # aware is exact: its result is the true ascending minima; the relaxed
    # branches may differ from it (and do, generically) but stay sorted.
    for mode, got in outs.items():
        assert np.all(np.diff(got) >= 0), (mode, got)


def test_neutral_keeps_current_mode():
    pq = SmartPQ(CFG)
    carry = pq.init()
    # force mode AWARE then feed a neutral-ish workload: mode must not flip
    # unless the tree says oblivious/aware explicitly (hysteresis).
    stats = carry.stats._replace(mode=jnp.int32(MODE_AWARE))
    carry = carry._replace(stats=stats)
    step = jax.jit(pq.step)
    rng = np.random.default_rng(4)
    key = jax.random.key(3)
    flips = 0
    prev = MODE_AWARE
    for ops, keys, vals in _batches(rng, 10, 32, 0.5):
        key, sub = jax.random.split(key)
        carry, _ = step(carry, ops, keys, vals, sub, 8)
        m = int(carry.stats.mode)
        flips += int(m != prev)
        prev = m
    assert flips <= 2, "mode oscillation under steady workload"
