"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU).
Contract: lexicographic (key, val); callers pass unique tags as vals.
Arms are pinned by name (`arm=` / `registry.force_arms`); the all-arm
parity sweep lives in tests/test_kernel_registry.py."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.pqueue.state import INF_KEY
from repro.kernels import ref as REF
from repro.kernels.ops import merge_sorted_runs, topk_smallest

RNG = np.random.default_rng(0)


@pytest.mark.parametrize(
    "R,N,k",
    [(8, 256, 16), (4, 128, 8), (16, 512, 32), (3, 100, 7), (1, 64, 64),
     (8, 64, 5), (5, 1024, 128), (2, 37, 3)],
)
@pytest.mark.parametrize("dtype", [np.int32, np.int16])
def test_topk_exact(R, N, k, dtype):
    lo, hi = (0, 50) if dtype == np.int32 else (-30, 30)  # heavy duplicates
    keys = RNG.integers(lo, hi, (R, N)).astype(dtype)
    vals = np.tile(np.arange(N, dtype=np.int32), (R, 1))
    kk, kv = topk_smallest(jnp.asarray(keys), jnp.asarray(vals), k)
    rk, rv = REF.topk_smallest_ref(jnp.asarray(keys), jnp.asarray(vals), k)
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(rv))


@pytest.mark.parametrize(
    "S,C,R", [(4, 64, 16), (8, 128, 128), (2, 256, 7), (1, 64, 1), (6, 512, 100)]
)
def test_merge_exact(S, C, R):
    buf_k = np.full((S, C), INF_KEY, np.int32)
    buf_v = np.zeros((S, C), np.int32)
    run_k = np.full((S, R), INF_KEY, np.int32)
    run_v = np.full((S, R), 1 << 20, np.int32)
    for s in range(S):
        n = RNG.integers(0, C + 1)
        buf_k[s, :n] = np.sort(RNG.integers(0, 200, n)).astype(np.int32)
        buf_v[s, :n] = np.arange(n)
        n = RNG.integers(0, R + 1)
        run_k[s, :n] = np.sort(RNG.integers(0, 200, n)).astype(np.int32)
        run_v[s, :n] = (1 << 20) + np.arange(n)
    args = tuple(jnp.asarray(a) for a in (buf_k, buf_v, run_k, run_v))
    mk, mv = merge_sorted_runs(*args)
    rk, rv = REF.merge_sorted_runs_ref(*args)
    np.testing.assert_array_equal(np.asarray(mk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(mv), np.asarray(rv))


@pytest.mark.parametrize("R,N", [(1, 16), (4, 64), (6, 37), (8, 128), (3, 100)])
def test_elim_sort_exact(R, N):
    """The elimination-match full sort (bitonic network on (key, tag) pairs)
    must be bit-identical to the stable-argsort reference under heavy
    duplicates and INF-masked lanes — the pre-pass exactness contract."""
    from repro.kernels.ops import elim_sort

    keys = RNG.integers(0, 12, (R, N)).astype(np.int32)  # heavy ties
    keys[RNG.random((R, N)) < 0.3] = INF_KEY  # masked non-insert lanes
    tags = np.tile(np.arange(N, dtype=np.int32), (R, 1))
    kk, kt = elim_sort(jnp.asarray(keys), jnp.asarray(tags),
                       arm="interpret@rows_per_block=8")
    rk, rt = REF.elim_sort_ref(jnp.asarray(keys), jnp.asarray(tags))
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(kt), np.asarray(rt))
    # and against the dispatching wrapper's jnp path
    from repro.core.pqueue.local import sort_op_log

    sk, st = sort_op_log(jnp.asarray(keys), arm="argsort")
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(sk))
    np.testing.assert_array_equal(np.asarray(kt), np.asarray(st))


def test_topk_all_equal_keys_stable():
    keys = np.zeros((2, 64), np.int32)
    vals = np.tile(np.arange(64, dtype=np.int32), (2, 1))
    kk, kv = topk_smallest(jnp.asarray(keys), jnp.asarray(vals), 8)
    np.testing.assert_array_equal(np.asarray(kv), np.tile(np.arange(8), (2, 1)))


def test_merge_against_local_semantics():
    """The kernel path must agree with core.pqueue.local.merge_sorted keys."""
    from repro.core.pqueue.local import merge_sorted

    S, C, R = 4, 64, 16
    buf_k = np.full((S, C), INF_KEY, np.int32)
    buf_v = np.zeros((S, C), np.int32)
    sizes = np.zeros(S, np.int32)
    for s in range(S):
        n = RNG.integers(0, C - R)
        buf_k[s, :n] = np.sort(RNG.integers(0, 500, n)).astype(np.int32)
        sizes[s] = n
    run_k = np.full((S, R), INF_KEY, np.int32)
    counts = np.zeros(S, np.int32)
    for s in range(S):
        n = RNG.integers(0, R + 1)
        run_k[s, :n] = np.sort(RNG.integers(0, 500, n)).astype(np.int32)
        counts[s] = n
    jk = lambda a: jnp.asarray(a)
    nk, _, _, _ = merge_sorted(
        jk(buf_k), jk(buf_v), jk(run_k), jk(np.zeros_like(run_k)),
        jk(sizes), jk(counts),
    )
    mk, _ = merge_sorted_runs(jk(buf_k), jk(buf_v), jk(run_k), jk(np.zeros_like(run_k)))
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(mk))


@pytest.mark.parametrize(
    "S,H,R", [(4, 64, 16), (8, 128, 128), (2, 256, 7), (1, 64, 1),
              (6, 100, 60), (3, 8, 8)]
)
def test_windowed_merge_exact(S, H, R):
    """The windowed-merge kernel (full H+R window, nothing dropped) must be
    bit-identical to BOTH the lexicographic reference and the
    positional-stable rank merge in local.merge_head_run."""
    from repro.core.pqueue.local import merge_head_run
    from repro.kernels.ops import windowed_merge

    head_k = np.full((S, H), INF_KEY, np.int32)
    head_v = np.zeros((S, H), np.int32)
    head_q = np.zeros((S, H), np.int32)
    run_k = np.full((S, R), INF_KEY, np.int32)
    run_v = np.zeros((S, R), np.int32)
    run_q = np.zeros((S, R), np.int32)
    for s in range(S):
        n = RNG.integers(0, H + 1)
        head_k[s, :n] = np.sort(RNG.integers(0, 60, n)).astype(np.int32)  # ties
        head_v[s, :n] = RNG.integers(0, 1 << 20, n)
        head_q[s, :n] = np.arange(n)
        n = RNG.integers(0, R + 1)
        run_k[s, :n] = np.sort(RNG.integers(0, 60, n)).astype(np.int32)
        run_v[s, :n] = RNG.integers(0, 1 << 20, n)
        run_q[s, :n] = 1000 + np.arange(n)
    args = tuple(jnp.asarray(a)
                 for a in (head_k, head_v, head_q, run_k, run_v, run_q))
    ker = windowed_merge(*args, arm="interpret@rows_per_block=4")
    ref = windowed_merge(*args, arm="ref")
    jnp_path = merge_head_run(*args, arm="rank")
    for a, b, c in zip(ker, ref, jnp_path):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_tiered_insert_kernel_path_matches():
    """A full tiered insert through the Pallas windowed-merge == jnp path."""
    from repro.core.pqueue import ops as O
    from repro.core.pqueue.state import make_state
    from repro.kernels import registry as REG

    rng = np.random.default_rng(5)
    keys = jnp.asarray(rng.integers(0, 300, 96), jnp.int32)
    vals = jnp.asarray(rng.integers(0, 99, 96), jnp.int32)
    st_ref, _ = O.insert(make_state(4, 64, head_width=16), keys, vals)
    with REG.force_arms({"windowed_merge": "interpret@rows_per_block=4"}):
        st_ker, _ = O.insert(make_state(4, 64, head_width=16), keys, vals)
    for a, b in zip(
        __import__("jax").tree.leaves(st_ref), __import__("jax").tree.leaves(st_ker)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
