"""Generic Nuddle delegation engine — the paper's §2 genericity claim."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.nuddle import (
    delegate_single_controller,
    delegate_window,
    pq_tournament_ops,
    sorted_set_ops,
)
from repro.core.pqueue import ops as O
from repro.core.pqueue.state import make_state


def _filled_state(seed=3, n=150):
    rng = np.random.default_rng(seed)
    st = make_state(8, 64)
    st, _ = O.insert(
        st,
        jnp.asarray(rng.integers(0, 5000, n), jnp.int32),
        jnp.asarray(rng.integers(0, 99, n), jnp.int32),
    )
    return st


def test_pq_plugin_matches_peek():
    st = _filled_state()
    ls = {"keys": st.keys, "vals": st.vals}
    _, verdict = delegate_single_controller(
        pq_tournament_ops(), ls, 8, npods=2, ctx={"n": jnp.int32(5)}
    )
    exp_k, exp_v = O.peek_min(st, 8)
    np.testing.assert_array_equal(np.asarray(verdict["k"]), np.asarray(exp_k))
    np.testing.assert_array_equal(np.asarray(verdict["v"]), np.asarray(exp_v))


def test_pq_plugin_commit_removes_prefixes():
    st = _filled_state()
    ls = {"keys": st.keys, "vals": st.vals}
    n = jnp.int32(5)
    new_states, verdict = delegate_single_controller(
        pq_tournament_ops(), ls, 8, npods=2, ctx={"n": n}
    )
    # every shard removed exactly its elements below the global cutoff
    cutoff = np.asarray(verdict["k"])[int(n) - 1]
    for s in range(st.num_shards):
        before = np.asarray(st.keys[s])
        after = np.asarray(new_states["keys"][s])
        removed = int(np.sum(before < cutoff))
        np.testing.assert_array_equal(after[: 64 - removed], before[removed:])


def test_sorted_set_plugin():
    st = _filled_state()
    ls = {"keys": st.keys, "vals": st.vals}
    present = int(st.keys[0, 0])
    absent = 999_999
    _, verdict = delegate_single_controller(
        sorted_set_ops(jnp.asarray([present, absent], jnp.int32)), ls, 0, npods=2
    )
    assert list(np.asarray(verdict["hit"])) == [True, False]


def test_delegate_window_matches_sequential():
    """K fused delegation rounds == K sequential delegate calls, bit for
    bit (states and every per-round verdict)."""
    st = _filled_state()
    ls = {"keys": st.keys, "vals": st.vals}
    K = 4
    ctxs = {"n": jnp.asarray([5, 3, 8, 1], jnp.int32)}

    seq_states = {k: jnp.asarray(v) for k, v in ls.items()}
    seq_verdicts = []
    for t in range(K):
        seq_states, v = delegate_single_controller(
            pq_tournament_ops(), seq_states, 8, npods=2,
            ctx={"n": ctxs["n"][t]},
        )
        seq_verdicts.append(v)

    win_states, win_verdicts = jax.jit(
        lambda s, c: delegate_window(pq_tournament_ops(), s, 8, 2, c)
    )(ls, ctxs)
    for k in ls:
        np.testing.assert_array_equal(
            np.asarray(win_states[k]), np.asarray(seq_states[k])
        )
    for t in range(K):
        for k in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(win_verdicts[k])[t],
                np.asarray(seq_verdicts[t][k]),
            )


def test_npods_invariance():
    """The two-phase combine gives the same verdict for any pod split —
    delegation is associative."""
    st = _filled_state()
    ls = {"keys": st.keys, "vals": st.vals}
    verdicts = []
    for npods in (1, 2, 4, 8):
        _, v = delegate_single_controller(
            pq_tournament_ops(), ls, 8, npods=npods, ctx={"n": jnp.int32(8)}
        )
        verdicts.append(np.asarray(v["k"]))
    for v in verdicts[1:]:
        np.testing.assert_array_equal(verdicts[0], v)
