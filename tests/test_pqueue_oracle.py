"""PQ semantics vs the numpy oracle: exact schedules bit-match, relaxed
schedules satisfy the SprayList envelope + multiset conservation."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.pqueue import ops as O
from repro.core.pqueue.ref import RefPQ
from repro.core.pqueue.schedules import Schedule
from repro.core.pqueue.state import INF_KEY, check_invariants, make_state


@pytest.mark.parametrize("S,C,B", [(8, 64, 16), (4, 128, 32), (16, 32, 8)])
def test_strict_matches_oracle(S, C, B):
    rng = np.random.default_rng(0)
    st, ref = make_state(S, C), RefPQ(S, C)
    for step in range(12):
        keys = rng.integers(0, 10000, B).astype(np.int32)
        vals = rng.integers(0, 100, B).astype(np.int32)
        st, dropped = O.insert(st, jnp.asarray(keys), jnp.asarray(vals))
        assert int(jnp.sum(dropped)) == ref.insert_batch(keys, vals)
        ok, msg = check_invariants(st)
        assert ok, msg

        n_del = int(rng.integers(0, B))
        res = O.delete_min(st, B, schedule=Schedule.STRICT_FLAT, active=n_del)
        st = res.state
        rk, rv = ref.delete_min_exact(n_del)
        got_k = np.asarray(res.keys)[: int(res.n_out)]
        got_v = np.asarray(res.vals)[: int(res.n_out)]
        assert int(res.n_out) == len(rk)
        np.testing.assert_array_equal(got_k, rk)
        np.testing.assert_array_equal(got_v, rv)
        ok, msg = check_invariants(st)
        assert ok, msg
    np.testing.assert_array_equal(
        np.sort(np.asarray(st.keys[st.keys < INF_KEY]).ravel()),
        ref.key_multiset(),
    )


def _filled(S=8, C=64, n=200, seed=3):
    rng = np.random.default_rng(seed)
    st = make_state(S, C)
    ref = RefPQ(S, C)
    keys = rng.integers(0, 5000, n).astype(np.int32)
    vals = rng.integers(0, 99, n).astype(np.int32)
    st, _ = O.insert(st, jnp.asarray(keys), jnp.asarray(vals))
    ref.insert_batch(keys, vals)
    return st, ref


def test_exact_schedules_agree():
    """STRICT_FLAT == HIER == FFWD — the 'same structure, different access
    path' property that makes SmartPQ transitions free."""
    st, _ = _filled()
    a = O.delete_min(st, 8, schedule=Schedule.STRICT_FLAT, active=8)
    b = O.delete_min(st, 8, schedule=Schedule.HIER, active=8, npods=4)
    c = O.delete_min(st, 8, schedule=Schedule.FFWD, active=8)
    for res in (b, c):
        np.testing.assert_array_equal(np.asarray(a.keys), np.asarray(res.keys))
        np.testing.assert_array_equal(np.asarray(a.vals), np.asarray(res.vals))
        np.testing.assert_array_equal(
            np.asarray(a.state.keys), np.asarray(res.state.keys)
        )


@pytest.mark.parametrize(
    "variant",
    [
        Schedule.SPRAY_HERLIHY,
        Schedule.SPRAY_FRASER,
        Schedule.LOCAL,
        Schedule.MULTIQ,
    ],
)
def test_relaxed_envelope_and_conservation(variant):
    st, ref = _filled()
    res = O.delete_min(st, 8, schedule=variant, active=8, rng=jax.random.key(42))
    got = np.asarray(res.keys)[: int(res.n_out)]
    if variant != Schedule.LOCAL:
        ok, msg = ref.check_spray_result(got, 8)
        assert ok, msg
    assert ref.remove_multiset(got), "returned keys not present in queue"
    rem = np.sort(np.asarray(res.state.keys[res.state.keys < INF_KEY]).ravel())
    np.testing.assert_array_equal(rem, ref.key_multiset())
    ok, msg = check_invariants(res.state)
    assert ok, msg


def test_multiq_rank_error_oracle():
    """Rank-error oracle for the MULTIQ schedule: every deleteMin batch sits
    within the deterministic two-choice window (first m entries of some
    shard — strictly tighter than the spray window), and the global rank
    error stays within the probabilistic multiq_bound envelope across many
    rng draws."""
    from repro.core.pqueue.schedules import multiq_bound

    m = 8
    violations = total = 0
    for trial in range(20):
        st, ref = _filled(S=8, C=64, n=400, seed=100 + trial)
        res = O.delete_min(
            st, m, schedule=Schedule.MULTIQ, active=m,
            rng=jax.random.key(1000 + trial),
        )
        got = np.asarray(res.keys)[: int(res.n_out)]
        ok, msg = ref.check_multiq_result(got, m)
        assert ok, f"trial {trial}: {msg}"
        v, t = ref.global_envelope_violations(got, m, bound=multiq_bound(8, m))
        violations += v
        total += t
        # spray-style bound must also hold (multiq is strictly tighter)
        v_spray, _ = ref.global_envelope_violations(got, m)
        assert v_spray <= v
    assert total > 0
    # w.h.p. bound: allow a small statistical tail, not systematic violation
    assert violations / total < 0.05, (violations, total)


def test_multiq_tighter_than_spray_observed():
    """Observed mean global rank error of MULTIQ <= spray on identical
    states/seeds — the property that earns the mode its regime."""

    def mean_rank(schedule, trials=15, m=8):
        errs = []
        for t in range(trials):
            st, ref = _filled(S=8, C=64, n=400, seed=200 + t)
            all_keys = np.sort(ref.key_multiset())
            res = O.delete_min(
                st, m, schedule=schedule, active=m, rng=jax.random.key(t)
            )
            got = np.asarray(res.keys)[: int(res.n_out)]
            errs.extend(
                int(np.searchsorted(all_keys, k, side="left")) for k in got
            )
        return float(np.mean(errs))

    assert mean_rank(Schedule.MULTIQ) <= mean_rank(Schedule.SPRAY_HERLIHY) + 1.0


def test_mixed_op_batch_linearization():
    st, ref = _filled()
    rng = np.random.default_rng(7)
    ops = rng.integers(0, 2, 16).astype(np.int32)
    keys = rng.integers(0, 5000, 16).astype(np.int32)
    vals = rng.integers(0, 99, 16).astype(np.int32)
    r = O.apply_op_batch(
        st, jnp.asarray(ops), jnp.asarray(keys), jnp.asarray(vals),
        schedule=Schedule.STRICT_FLAT,
    )
    ref.insert_batch(keys, vals, mask=ops == O.OP_INSERT)
    rk, _ = ref.delete_min_exact(int((ops == O.OP_DELETE_MIN).sum()))
    np.testing.assert_array_equal(
        np.asarray(r.deleted_keys)[: int(r.n_deleted)], rk
    )


def test_empty_queue_delete():
    st = make_state(4, 16)
    res = O.delete_min(st, 8, schedule=Schedule.STRICT_FLAT, active=8)
    assert int(res.n_out) == 0
    assert np.all(np.asarray(res.keys) == INF_KEY)


def test_capacity_overflow_reported():
    st = make_state(2, 4)  # tiny capacity
    keys = jnp.arange(32, dtype=jnp.int32)
    st, dropped = O.insert(st, keys, jnp.zeros(32, jnp.int32))
    assert int(st.total_size) == 8
    assert int(jnp.sum(dropped)) == 32 - 8
