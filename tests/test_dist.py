"""Multi-device behavior (subprocess, 8 virtual CPU devices)."""

import pytest


@pytest.mark.slow
def test_dist_pq_schedules(device_script_runner):
    out = device_script_runner("dist_pq_check.py")
    assert "ALL-DIST-OK" in out


@pytest.mark.slow
def test_multiq_dist(device_script_runner):
    out = device_script_runner("multiq_8dev.py")
    assert "MULTIQ-8DEV-OK" in out


@pytest.mark.slow
def test_collectives(device_script_runner):
    out = device_script_runner("collectives_check.py")
    assert "ALL-COLLECTIVES-OK" in out


@pytest.mark.slow
def test_moe_ep(device_script_runner):
    out = device_script_runner("moe_ep_check.py")
    assert "MOE-EP-OK" in out


@pytest.mark.slow
def test_elastic_rescale(device_script_runner):
    out = device_script_runner("elastic_check.py")
    assert "ELASTIC-OK" in out


@pytest.mark.slow
def test_dryrun_cell(device_script_runner):
    out = device_script_runner("dryrun_cell_check.py", n_devices=512)
    assert "DRYRUN-CELL-OK" in out
