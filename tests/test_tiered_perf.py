"""Tiered-layout performance contracts.

Three machine-checked claims of the head/tail PQState restructure:

  1. hot-path cost is proportional to the batch, NOT the capacity — the
     compiled steady-state step (rebalance conds on their fall-through
     branch) must grow sublinearly when C quadruples at fixed batch;
  2. the donated step paths really are zero-copy — XLA's
     input_output_alias table must alias the carry through, and the donated
     buffers must actually be consumed;
  3. the benchmark runner's --smoke lane emits the machine-readable
     BENCH_pq.json trajectory file with a stable schema.
"""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.pqueue import ops as O
from repro.core.pqueue.schedules import Schedule
from repro.core.pqueue.state import make_state
from repro.utils.hlo import donation_aliases, xla_cost_analysis

ROOT = Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# 1. capacity-sublinear hot path (xla_cost_analysis regression)
# ---------------------------------------------------------------------------


def _hot_path_cost(schedule, capacity, S=16, B=64):
    """FLOPs / bytes of the compiled steady-state step: the rebalance
    lax.conds are forced onto their identity/no-overflow branch, which is
    exactly the program the queue runs between (rare, amortized)
    rebalances."""
    st = make_state(S, capacity)

    @jax.jit
    def step(state, ops, keys, vals, k):
        return O.apply_op_batch(
            state, ops, keys, vals, schedule=schedule, rng=k, npods=2
        )

    args = (st, jnp.zeros(B, jnp.int32), jnp.zeros(B, jnp.int32),
            jnp.zeros(B, jnp.int32), jax.random.key(0))
    compiled = step.lower(*args).compile()
    cost = xla_cost_analysis(compiled)
    return float(cost.get("flops", 0.0)), float(cost.get("bytes accessed", 0.0))


def _tail_passthrough_bytes(capacity, S=16):
    """Bytes the donated tail arrays (keys/vals/seq) account for when the
    steady-state program merely threads them through (read + write), which
    is ALL the hot path does to the tail now — appends, compaction, and
    refill-consume are cond-guarded or window-scalar ops."""
    from repro.core.pqueue.state import DEFAULT_HEAD_WIDTH

    T = capacity - min(DEFAULT_HEAD_WIDTH, capacity)
    return 2 * 3 * S * T * 4


@pytest.mark.parametrize("schedule", list(Schedule), ids=lambda s: s.name)
def test_step_cost_capacity_sublinear(schedule, monkeypatch):
    """C: 4096 -> 16384 (4x) at fixed batch must grow hot-path FLOPs ~not
    at all (every compute op is head/batch-windowed), and the bytes BEYOND
    the donated tail pass-through must be capacity-INDEPENDENT — the
    sliding-window tail means steady state never reads or writes a tail
    element at all, it only threads the buffers through."""
    monkeypatch.setattr(
        jax.lax, "cond", lambda pred, true_fn, false_fn, *ops_: false_fn(*ops_)
    )
    f1, b1 = _hot_path_cost(schedule, 4096)
    f2, b2 = _hot_path_cost(schedule, 16384)
    assert f2 <= f1 * 1.2, (
        f"{schedule.name}: hot-path FLOPs scale with capacity "
        f"({f1:.0f} -> {f2:.0f})"
    )
    hot1 = max(b1 - _tail_passthrough_bytes(4096), 0.0)
    hot2 = max(b2 - _tail_passthrough_bytes(16384), 0.0)
    assert hot2 <= hot1 * 1.5 + (1 << 16), (
        f"{schedule.name}: hot-path bytes beyond the tail pass-through "
        f"scale with capacity ({hot1:.0f} -> {hot2:.0f}; raw {b1:.0f} -> "
        f"{b2:.0f})"
    )


# ---------------------------------------------------------------------------
# 2. donation: the step paths alias the carry (no state copy)
# ---------------------------------------------------------------------------


def _smartpq():
    from repro.core.smartpq import SmartPQ, SmartPQConfig

    return SmartPQ(SmartPQConfig(num_shards=8, capacity=512, npods=2,
                                 decision_interval=4))


def test_jit_step_donates_carry_no_copy():
    pq = _smartpq()
    carry = pq.init()
    B = 16
    ops = jnp.zeros((B,), jnp.int32)
    keys = jnp.arange(B, dtype=jnp.int32)
    vals = jnp.ones((B,), jnp.int32)
    args = (carry, ops, keys, vals, jax.random.key(0), jnp.int32(8))

    compiled = pq.jit_step.lower(*args).compile()
    aliases = donation_aliases(compiled)
    n_state_leaves = len(jax.tree.leaves(carry.state))
    assert len(aliases) >= n_state_leaves, (
        f"expected every PQState buffer aliased input->output, got "
        f"{len(aliases)} aliases: {aliases}"
    )

    out_carry, _ = pq.jit_step(*args)
    # the donated buffers were really consumed (no hidden copy kept them)
    assert carry.state.head_keys.is_deleted()
    assert carry.state.tail_keys.is_deleted()
    assert not out_carry.state.head_keys.is_deleted()


def test_mode_steps_donate_state():
    pq = _smartpq()
    mode_steps = pq.make_mode_steps()
    st = pq.init().state
    B = 16
    keys = jnp.asarray(np.arange(B), jnp.int32)
    st, _ = O.insert(st, keys, keys)
    res = mode_steps[0](st, jnp.ones((B,), jnp.int32), keys, keys,
                        jax.random.key(1))
    assert st.head_keys.is_deleted(), "mode step must donate its state"
    assert not res.state.head_keys.is_deleted()


# ---------------------------------------------------------------------------
# 3. BENCH_pq.json smoke lane
# ---------------------------------------------------------------------------


def test_bench_smoke_writes_json(tmp_path):
    out = tmp_path / "BENCH_pq.json"
    proc = subprocess.run(
        [sys.executable, str(ROOT / "benchmarks" / "run.py"), "--smoke",
         "--json", str(out)],
        cwd=ROOT, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    data = json.loads(out.read_text())
    assert data["schema"] == 1
    recs = data["records"]
    assert {r.get("schedule") for r in recs} >= {
        "STRICT_FLAT", "SPRAY_HERLIHY", "MULTIQ"
    }
    for r in recs:  # stable before/after-diffable schema
        for key in ("suite", "name", "us_per_call", "derived"):
            assert key in r, (key, r)
        assert r["us_per_call"] > 0  # every smoke record feeds the 2x gate
        if "us_per_step" in r:
            assert r["us_per_step"] > 0
    # the PQWorkload-driven ins0 slice carries full workload coordinates
    ins0 = [r for r in recs if r["name"].startswith("smoke/ins0/")]
    assert len(ins0) == 3
    for r in ins0:
        for key in ("schedule", "capacity", "num_clients", "num_shards",
                    "size", "insert_frac"):
            assert key in r, (key, r)
    # the application-workload and serving probes ride the same smoke lane
    assert {r["name"] for r in recs} >= {
        "smoke/workloads_sssp", "smoke/workloads_des", "smoke/serve_slo"
    }


@pytest.mark.slow
def test_bench_smoke_check_regression_gate(tmp_path):
    """`--smoke --check` compares fresh medians against the committed
    BENCH_pq.json by record name and exits non-zero past the ratio.  The
    committed baseline was measured in this container, so the default 2x
    gate must pass; an absurdly tight ratio must trip it (proving the gate
    actually compares)."""
    out = tmp_path / "fresh.json"
    proc = subprocess.run(
        [sys.executable, str(ROOT / "benchmarks" / "run.py"), "--smoke",
         "--json", str(out), "--check"],
        cwd=ROOT, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, (proc.stdout[-2000:], proc.stderr[-2000:])
    assert "check ok" in proc.stderr, proc.stderr[-2000:]

    proc = subprocess.run(
        [sys.executable, str(ROOT / "benchmarks" / "run.py"), "--smoke",
         "--json", str(out), "--check", "--check-ratio", "0.0001"],
        cwd=ROOT, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode != 0
    assert "regressed" in proc.stderr, proc.stderr[-2000:]
