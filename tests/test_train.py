"""Training substrate: optimizer precision modes, checkpoint/restart,
failure injection, straggler watchdog, loss-goes-down."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import reduced_config
from repro.train import checkpoint as ckpt
from repro.train.fault import FailureInjector, StragglerWatchdog
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
)


def _quadratic_params():
    return {"w": jnp.asarray(np.linspace(-2, 2, 512), jnp.float32).reshape(2, 256)}


@pytest.mark.parametrize("state_dtype", ["fp32", "bf16", "int8"])
def test_adamw_converges_quadratic(state_dtype):
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, state_dtype=state_dtype)
    params = _quadratic_params()
    state = adamw_init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, cfg)
    assert float(loss(params)) < 1e-2, state_dtype


def test_int8_states_memory_shapes():
    cfg = AdamWConfig(state_dtype="int8")
    params = {"big": jnp.zeros((8, 512)), "tiny": jnp.zeros((3,))}
    st = adamw_init(params, cfg)
    q, scale = st.m["big"]
    assert q.dtype == jnp.int8 and q.shape == (8, 512)
    assert scale.shape == (8, 2)
    assert st.m["tiny"].dtype == jnp.float32  # non-block-aligned fallback
    # v stays bf16 in int8 mode (dynamic-range; see optimizer module doc)
    assert st.v["big"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(10, dtype=jnp.float32),
        "n": {"b": jnp.ones((3, 4), jnp.bfloat16), "c": jnp.int32(7)},
    }
    ckpt.save(tmp_path, 5, tree)
    assert ckpt.latest_step(tmp_path) == 5
    out = ckpt.restore(tmp_path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # newer step wins LATEST
    ckpt.save(tmp_path, 9, tree)
    assert ckpt.latest_step(tmp_path) == 9


def test_train_loss_decreases(tmp_path):
    cfg = reduced_config("llama3.2-3b")
    res = run(cfg, LoopConfig(steps=30, batch_size=4, ckpt_dir=None, seed=0))
    first, last = np.mean(res["losses"][:5]), np.mean(res["losses"][-5:])
    assert last < first - 0.1, (first, last)


def test_failure_injection_and_restart(tmp_path):
    """Kill at step 12, restart from the step-10 checkpoint, finish."""
    cfg = reduced_config("gemma-2b")
    loop = LoopConfig(steps=20, batch_size=2, ckpt_every=5, ckpt_dir=str(tmp_path))
    injector = FailureInjector(fail_at=(12,))
    with pytest.raises(RuntimeError, match="injected failure"):
        run(cfg, loop, injector=injector)
    assert ckpt.latest_step(tmp_path) == 10

    res = run(cfg, loop)  # restart: resumes from 10
    assert res["resumed_from"] == 10
    assert res["steps_done"] == 20


def test_straggler_watchdog():
    w = StragglerWatchdog(threshold=2.0, warmup_steps=1)
    for s in range(5):
        assert w.observe(s, 1.0) is None
    ev = w.observe(5, 5.0)
    assert ev is not None and ev["dt"] == 5.0
    # the straggler didn't poison the EWMA
    assert w.observe(6, 1.1) is None


def test_elastic_restore_dtype_and_structure(tmp_path):
    """Restore onto a differently-typed target (elastic rescale path)."""
    tree = {"w": jnp.ones((4, 8), jnp.float32)}
    ckpt.save(tmp_path, 1, tree)
    like = {"w": jnp.zeros((4, 8), jnp.bfloat16)}
    out = ckpt.restore(tmp_path, like)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["w"], np.float32), 1.0)
