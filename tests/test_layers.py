"""Layer-level correctness: attention chunking, SSD duality, MoE capacity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.layers.attention import (
    AttnDims,
    KVCacheSlice,
    _attend_dense,
    attend_chunked,
    decode_attend,
)
from repro.models.layers.moe import MoEDims, moe_block
from repro.models.layers.ssm import (
    SSMDims,
    SSMState,
    ssd_decode_step,
    ssd_forward,
)


def _qkv(B=2, S=256, Hq=4, Hkv=2, hd=32, seed=0):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(B, S, Hq, hd)), jnp.float32)
    k = jnp.asarray(r.normal(size=(B, S, Hkv, hd)), jnp.float32)
    v = jnp.asarray(r.normal(size=(B, S, Hkv, hd)), jnp.float32)
    return q, k, v


def test_chunked_attention_exact():
    """Flash-style chunking is exact, not approximate."""
    dims = AttnDims(n_heads=4, n_kv_heads=2, head_dim=32)
    q, k, v = _qkv()
    B, S = q.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    dense = _attend_dense(q, k, v, dims, pos, pos)
    for chunk in (32, 64, 128):
        out = attend_chunked(q, k, v, dims, pos, pos, kv_chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(dense), rtol=2e-5, atol=2e-5
        )


def test_gqa_grouping():
    """GQA with q_per_kv=2 equals MHA with duplicated KV heads."""
    dims_gqa = AttnDims(n_heads=4, n_kv_heads=2, head_dim=32)
    dims_mha = AttnDims(n_heads=4, n_kv_heads=4, head_dim=32)
    q, k, v = _qkv()
    B, S = q.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    out_g = _attend_dense(q, k, v, dims_gqa, pos, pos)
    k_dup = jnp.repeat(k, 2, axis=2)
    v_dup = jnp.repeat(v, 2, axis=2)
    out_m = _attend_dense(q, k_dup, v_dup, dims_mha, pos, pos)
    np.testing.assert_allclose(
        np.asarray(out_g), np.asarray(out_m), rtol=1e-4, atol=1e-5
    )


def test_decode_matches_full_recompute():
    """Incremental decode attention == full-sequence attention at the last
    position (per-request lengths respected)."""
    dims = AttnDims(n_heads=2, n_kv_heads=2, head_dim=16)
    B, S, hd = 2, 32, 16
    r = np.random.default_rng(1)
    k_hist = jnp.asarray(r.normal(size=(B, S, 2, hd)), jnp.float32)
    v_hist = jnp.asarray(r.normal(size=(B, S, 2, hd)), jnp.float32)
    q_new = jnp.asarray(r.normal(size=(B, 1, 2, hd)), jnp.float32)
    k_new = jnp.asarray(r.normal(size=(B, 1, 2, hd)), jnp.float32)
    v_new = jnp.asarray(r.normal(size=(B, 1, 2, hd)), jnp.float32)

    length = jnp.int32(S - 4)
    cache = KVCacheSlice(k=k_hist, v=v_hist)
    out, _ = decode_attend(q_new, cache, k_new, v_new, dims, length, kv_chunk=8)

    # reference: full attention over the first `length` entries + the new one
    k_full = jnp.concatenate([k_hist[:, : S - 4], k_new], axis=1)
    v_full = jnp.concatenate([v_hist[:, : S - 4], v_new], axis=1)
    pos = jnp.broadcast_to(jnp.arange(S - 3, dtype=jnp.int32), (B, S - 3))
    qpos = jnp.full((B, 1), S - 4, jnp.int32)
    ref = _attend_dense(q_new, k_full, v_full, dims, qpos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_ssd_chunked_equals_recurrent():
    dims = SSMDims(d_model=32, d_inner=64, head_dim=16, d_state=8, n_groups=2, chunk=8)
    kg = jax.random.split(jax.random.key(2), 4)
    params = {
        "in_proj": 0.3 * jax.random.normal(kg[0], (32, dims.in_proj_out)),
        "conv_w": 0.3 * jax.random.normal(kg[1], (4, dims.conv_channels)),
        "conv_b": jnp.zeros((dims.conv_channels,)),
        "A_log": jnp.log(jnp.arange(1, dims.n_heads + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((dims.n_heads,)),
        "D": jnp.ones((dims.n_heads,)),
        "out_proj": 0.3 * jax.random.normal(kg[2], (64, 32)),
    }
    x = jax.random.normal(kg[3], (2, 16, 32))
    y_full, h_full, _tail = ssd_forward(x, params, dims)
    st = SSMState(
        h=jnp.zeros((2, dims.n_heads, 8, 16)),
        conv=jnp.zeros((2, 3, dims.conv_channels)),
    )
    ys = []
    for t in range(16):
        y_t, st = ssd_decode_step(x[:, t : t + 1, :], st, params, dims)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_full), np.asarray(st.h), atol=1e-4)


def test_ssd_conv_tail_continuation():
    """Prefill conv tail + decode step == longer full forward."""
    dims = SSMDims(d_model=16, d_inner=32, head_dim=8, d_state=4, n_groups=1, chunk=4)
    kg = jax.random.split(jax.random.key(5), 4)
    params = {
        "in_proj": 0.3 * jax.random.normal(kg[0], (16, dims.in_proj_out)),
        "conv_w": 0.3 * jax.random.normal(kg[1], (4, dims.conv_channels)),
        "conv_b": jnp.zeros((dims.conv_channels,)),
        "A_log": jnp.log(jnp.arange(1, dims.n_heads + 1, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((dims.n_heads,)),
        "D": jnp.ones((dims.n_heads,)),
        "out_proj": 0.3 * jax.random.normal(kg[2], (32, 16)),
    }
    x = jax.random.normal(kg[3], (1, 12, 16))
    y_all, h_all, _ = ssd_forward(x, params, dims)
    y_pre, h_pre, tail = ssd_forward(x[:, :8, :], params, dims)
    st = SSMState(h=h_pre, conv=tail)
    ys = []
    for t in range(8, 12):
        y_t, st = ssd_decode_step(x[:, t : t + 1, :], st, params, dims)
        ys.append(y_t)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y_all[:, 8:, :]), atol=1e-4
    )


def test_moe_capacity_drops_renormalize():
    dims = MoEDims(n_experts=4, n_experts_pad=4, top_k=2, capacity_factor=0.25)
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(2, 8, 16)), jnp.float32)
    rw = jnp.asarray(r.normal(size=(16, 4)), jnp.float32)
    wg = jnp.asarray(r.normal(size=(4, 16, 32)) * 0.1, jnp.float32)
    wu = jnp.asarray(r.normal(size=(4, 16, 32)) * 0.1, jnp.float32)
    wd = jnp.asarray(r.normal(size=(4, 32, 16)) * 0.1, jnp.float32)
    out, aux = moe_block(x, rw, wg, wu, wd, dims)
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0


def test_moe_padded_experts_never_selected():
    dims = MoEDims(n_experts=3, n_experts_pad=4, top_k=3, capacity_factor=4.0)
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(1, 16, 8)), jnp.float32)
    rw = jnp.asarray(r.normal(size=(8, 4)), jnp.float32)
    # make pad expert's weights enormous: if it were ever selected the
    # output would blow up
    wg = jnp.asarray(np.concatenate([r.normal(size=(3, 8, 16)) * 0.1,
                                     np.full((1, 8, 16), 1e6)]), jnp.float32)
    wu = jnp.asarray(np.concatenate([r.normal(size=(3, 8, 16)) * 0.1,
                                     np.full((1, 8, 16), 1e6)]), jnp.float32)
    wd = jnp.asarray(np.concatenate([r.normal(size=(3, 16, 8)) * 0.1,
                                     np.full((1, 16, 8), 1e6)]), jnp.float32)
    out, _ = moe_block(x, rw, wg, wu, wd, dims)
    assert float(jnp.max(jnp.abs(out))) < 1e3
