"""The Pallas kernel path through the actual PQ tournament must be
bit-identical to the stable-argsort path (position-tag trick)."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pqueue import ops as O
from repro.core.pqueue.local import topk_of_merged
from repro.core.pqueue.schedules import Schedule
from repro.core.pqueue.state import INF_KEY, make_state


def test_topk_kernel_path_matches_argsort():
    rng = np.random.default_rng(0)
    for n, m in [(64, 8), (100, 16), (256, 5)]:
        keys = jnp.asarray(rng.integers(0, 40, n), jnp.int32)  # heavy ties
        vals = jnp.asarray(rng.integers(0, 1 << 20, n), jnp.int32)
        k_ref, v_ref = topk_of_merged(keys, vals, m, arm="argsort")
        k_ker, v_ker = topk_of_merged(keys, vals, m,
                                      arm="interpret@rows_per_block=8")
        np.testing.assert_array_equal(np.asarray(k_ref), np.asarray(k_ker))
        np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v_ker))


def test_delete_min_identical_through_kernel():
    """A full strict deleteMin with the kernel tournament == the jnp path."""
    from repro.kernels import registry as REG

    rng = np.random.default_rng(1)
    st = make_state(4, 64)
    keys = jnp.asarray(rng.integers(0, 300, 120), jnp.int32)
    st, _ = O.insert(st, keys, keys % 97)

    res_ref = O.delete_min(st, 8, schedule=Schedule.STRICT_FLAT, active=8)
    with REG.force_arms({"topk_smallest": "interpret@rows_per_block=8"}):
        res_ker = O.delete_min(st, 8, schedule=Schedule.STRICT_FLAT, active=8)
    np.testing.assert_array_equal(np.asarray(res_ref.keys), np.asarray(res_ker.keys))
    np.testing.assert_array_equal(np.asarray(res_ref.vals), np.asarray(res_ker.vals))
    np.testing.assert_array_equal(
        np.asarray(res_ref.state.keys), np.asarray(res_ker.state.keys)
    )


def test_int8_kv_decode_matches_bf16():
    """int8 KV cache (per-token-head scales) must track the bf16 decode:
    identical argmax tokens over a greedy rollout (It-8)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import reduced_config
    from repro.models.io import init_caches
    from repro.models.registry import build_model

    cfg = reduced_config("llama3.2-3b")
    m_bf = build_model(cfg, remat=False)
    m_i8 = build_model(cfg, remat=False, kv_int8=True)
    params, _ = m_bf.init(jax.random.key(0))
    B, S = 2, 64
    c_bf = init_caches(cfg, B, S)
    c_i8 = init_caches(cfg, B, S, kv_int8=True)
    lengths = jnp.zeros((B,), jnp.int32)
    tok = jnp.full((B, 1), 7, jnp.int32)
    d_bf = jax.jit(m_bf.decode_step)
    d_i8 = jax.jit(m_i8.decode_step)
    for t in range(5):
        lb, c_bf = d_bf(params, c_bf, tok, lengths)
        li, c_i8 = d_i8(params, c_i8, tok, lengths)
        lengths = lengths + 1
        nb = jnp.argmax(lb, -1)
        ni = jnp.argmax(li, -1)
        np.testing.assert_array_equal(np.asarray(nb), np.asarray(ni))
        pb = jax.nn.softmax(lb.astype(jnp.float32))
        pi = jax.nn.softmax(li.astype(jnp.float32))
        assert float(jnp.max(jnp.abs(pb - pi))) < 0.05, t
        tok = nb[:, None].astype(jnp.int32)
