"""Chaos tests: every `repro.faults` injector driven through the stack.

The contract under test is uniform — an injected fault is either ABSORBED
(sanitized, clamped, spilled to the backlog, or rolled back and retried) or
it SURFACES as a typed error from `repro.core.errors`.  Silent corruption
is the only forbidden outcome.  `tests/test_hygiene.py` asserts every name
in `faults.INJECTORS` appears here.

All tests use deliberately small queue geometries: each `SmartPQ` instance
carries its own jit cache, so small shards/capacities keep compile time in
check without changing any code path.
"""

import dataclasses
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.errors import (  # noqa: E402
    TraceCorruptError,
    WindowValidationError,
)
from repro.core.pqueue.ops import OP_INSERT  # noqa: E402
from repro.core.smartpq import (  # noqa: E402
    MODE_AWARE,
    NUM_MODES,
    SmartPQ,
    SmartPQConfig,
)
from repro.faults import FaultSpec, inject  # noqa: E402
from repro.serve.engine import EngineConfig, ServeEngine  # noqa: E402
from repro.serve.overload import OverloadConfig  # noqa: E402
from repro.serve.scheduler import Request, SmartPQScheduler  # noqa: E402
from repro.workloads.traces import (  # noqa: E402
    load_trace,
    open_loop_requests,
    phased_trace,
    poisson_arrival_counts,
    replay,
    save_trace,
)

pytestmark = pytest.mark.chaos

PHASES = [
    dict(num_clients=16, key_range=1_000, insert_frac=0.8),
    dict(num_clients=16, key_range=1_000, insert_frac=0.3),
]


def _pq(validate=True, **kw):
    return SmartPQ(SmartPQConfig(
        num_shards=4, capacity=512, decision_interval=4, validate=validate,
        **kw,
    ))


def _sched_cfg(validate=False):
    return SmartPQConfig(
        num_shards=4, capacity=1024, decision_interval=4,
        initial_mode=MODE_AWARE, validate=validate,
    )


def _reqs(n, uid0=0, step=0):
    return [
        Request(uid=uid0 + i, prompt_len=8 + i, max_new_tokens=4,
                slo_class=i % 3, arrival_step=step)
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# trace-level faults: sanitize / tolerate / typed load error
# ---------------------------------------------------------------------------


def test_nonfinite_keys_rejected_and_counted():
    """`nonfinite_keys`: every poisoned insert lane is refused at the
    admission boundary into stats.rejected; the replayed state still
    passes the full invariant sweep (validate=True inside replay)."""
    trace = phased_trace(PHASES, steps_per_phase=4, seed=3)
    bad = inject(trace, FaultSpec(kind="nonfinite_keys", seed=1, rate=0.3))
    expected = int(((bad.ops == OP_INSERT) & ~np.isfinite(bad.keys)).sum())
    assert expected > 0, "injector produced no non-finite insert lanes"
    carry, _ = replay(_pq(), bad)  # validate_carry runs post-window
    assert int(carry.stats.rejected) == expected


def test_duplicate_keys_storm_absorbed():
    """`duplicate_keys`: equal-key storms are legal input — nothing is
    rejected and every invariant holds at adversarial duplicate density."""
    trace = phased_trace(PHASES, steps_per_phase=4, seed=5)
    dup = inject(trace, FaultSpec(kind="duplicate_keys", seed=2, rate=0.9))
    assert not np.array_equal(dup.keys, trace.keys)
    carry, _ = replay(_pq(), dup)  # invariant sweep inside
    assert int(carry.stats.rejected) == 0


@pytest.mark.parametrize("variant", ["truncate", "flip"])
def test_corrupt_trace_npz_surfaces_typed_error(tmp_path, variant):
    """`corrupt_trace_npz`: a damaged npz must never half-load — the loader
    raises `TraceCorruptError` with its stable code."""
    trace = phased_trace(PHASES, steps_per_phase=2, seed=7)
    p = tmp_path / "trace.npz"
    save_trace(p, trace)
    healthy = load_trace(p)  # round-trips before injection
    assert np.array_equal(healthy.ops, trace.ops)
    inject(p, FaultSpec(
        kind="corrupt_trace_npz", seed=3, rate=0.5, variant=variant,
    ))
    from repro.obs import get_default

    before = get_default().metrics.value("errors_total", code="TRACE_CORRUPT")
    with pytest.raises(TraceCorruptError) as ei:
        load_trace(p)
    assert ei.value.code == "TRACE_CORRUPT"
    assert str(p) in str(ei.value)
    # the raise site counted the typed error in the process registry
    after = get_default().metrics.value("errors_total", code="TRACE_CORRUPT")
    assert after == before + 1


# ---------------------------------------------------------------------------
# serving-workload faults: bounded backlogs, forecast independence
# ---------------------------------------------------------------------------


def test_ring_overflow_storm_bounded_and_accounted():
    """`ring_overflow_storm`: arrival bursts far beyond the admission ring
    spill to the host backlog; with the overload controller attached the
    backlog stays hard-capped and EVERY arrival is accounted for —
    inserted, still-backlogged, shed, or evicted.  This is the chaos
    memory-bound test `_collect`'s docstring points at."""
    counts = poisson_arrival_counts(24, 6.0, seed=3)
    storm = inject(
        open_loop_requests(counts, seed=3),
        FaultSpec(kind="ring_overflow_storm", rate=1 / 8, magnitude=2.0),
    )
    total = sum(len(s) for s in storm)
    cap = 64
    sched = SmartPQScheduler(
        batch_size=8, pq_config=_sched_cfg(), seed=0, ring_capacity=16,
        overload=OverloadConfig(
            targets=(8.0, 16.0, 32.0), backlog_cap=cap, min_samples=4,
        ),
    )
    assert max(len(s) for s in storm) > sched.ring_capacity, (
        "storm never exceeded the ring — the fault was not exercised"
    )
    K = 4
    for w in range(0, len(storm), K):
        chunk = storm[w:w + K]
        sched.tick_window(chunk, [4] * len(chunk))
        # memory bound, checked at every window boundary:
        assert len(sched._arrival_backlog) <= cap
        assert len(sched._requests) == (
            int(sched.carry.state.total_size) + len(sched._arrival_backlog)
        ), "host map leaked entries beyond in-flight work"
    st = sched.stats
    assert st.inserted + len(sched._arrival_backlog) + st.shed \
        + st.evicted == total, "an arrival vanished without accounting"
    assert st.evicted + st.shed > 0, (
        "storm was absorbed without ever tripping the bounded-backlog "
        "paths — grow the storm"
    )


@pytest.mark.parametrize("variant", ["low", "high"])
def test_forecast_extreme_every_request_completes(variant):
    """`forecast_extreme`: the slot forecast is advisory only — pinning the
    service estimate to a pathological extreme (max over-admission or
    starvation-grade under-admission) must not lose a single request."""
    counts = poisson_arrival_counts(12, 2.0, seed=9)
    workload = open_loop_requests(counts, seed=9)
    total = sum(len(s) for s in workload)
    assert total > 0
    eng = ServeEngine(None, None, EngineConfig(
        batch_size=8, max_seq=256, sched_window=4, forecast=True,
    ), seed=1)
    inject(eng, FaultSpec(
        kind="forecast_extreme", variant=variant, magnitude=64.0,
    ))
    summary = eng.run(workload, max_steps=800)
    assert summary["completed"] == total


# ---------------------------------------------------------------------------
# core-state / classifier faults: clamp, rollback, typed window error
# ---------------------------------------------------------------------------


def test_oob_tree_class_clamped_to_valid_mode():
    """`oob_tree_class`: a corrupted packed tree emits classes far outside
    [0, NUM_MODES); the step's keep-rule + clamp must keep the realized
    mode trace in range — never an out-of-range lax.switch branch."""
    pq = _pq(validate=True)
    inject(pq, FaultSpec(kind="oob_tree_class", seed=4, rate=1.0))
    trace = phased_trace(PHASES, steps_per_phase=8, seed=11)
    carry, res = replay(pq, trace)  # invariant sweep inside
    modes = np.asarray(res.mode)
    assert ((modes >= 0) & (modes < NUM_MODES)).all(), (
        f"realized modes left [0, {NUM_MODES}): {np.unique(modes)}"
    )
    assert 0 <= int(carry.stats.mode) < NUM_MODES


def test_corrupt_state_rolls_back_and_surfaces_typed_error():
    """`corrupt_state`: corruption that PREDATES the checkpoint cannot be
    healed by retry — both validation passes trip, the checkpoint is
    restored, and a typed `WindowValidationError` surfaces.  Zero-op ticks
    are essential: a dispatching tick re-sorts the head and would heal the
    injected inversion before validation ever sees it."""
    from repro.obs import Observability

    obs = Observability()  # standalone schedulers default to NULL: pass one
    sched = SmartPQScheduler(
        batch_size=8, pq_config=_sched_cfg(validate=True), seed=0, obs=obs,
    )
    sched.tick(_reqs(6), 0)  # healthy, validated window populates the queue
    assert sched.stats.failed_windows == 0
    pending_before = sched.pending
    sched.carry = inject(sched.carry, FaultSpec(kind="corrupt_state", seed=1))
    with pytest.raises(WindowValidationError) as ei:
        sched.tick([], 0)
    assert ei.value.code == "WINDOW_VALIDATION"
    assert ei.value.first and ei.value.retry  # both attempts' violations
    assert sched.stats.failed_windows == 1
    assert sched.pending == pending_before, "rollback lost host mirrors"
    # The windowed path hits the same contract (corruption persists in the
    # restored checkpoint, so it trips again).
    with pytest.raises(WindowValidationError):
        sched.tick_window([[], []], [0, 0])
    assert sched.stats.failed_windows == 2
    assert sched.pending == pending_before
    # Every raise site is counted: one WINDOW_VALIDATION per double-trip,
    # one INVARIANT per detected violation (>= 1 per failed attempt).
    assert obs.metrics.value("errors_total", code="WINDOW_VALIDATION") == 2
    assert obs.metrics.value("errors_total", code="INVARIANT") >= 4
    assert obs.metrics.value("sched_window_rollbacks_total") == 2


def test_validator_tripwire_recovery_succeeds():
    """`validator_tripwire` (1 trip): the first validation pass reports a
    synthetic violation, the window rolls back and the conservative
    fallback retry validates clean — the SUCCESS arm of window recovery.
    Dispatch keeps working afterwards."""
    from repro.obs import Observability

    hook = inject(None, FaultSpec(kind="validator_tripwire", magnitude=1))
    obs = Observability()
    sched = SmartPQScheduler(
        batch_size=8, pq_config=_sched_cfg(), seed=0, validate_hook=hook,
        obs=obs,
    )
    reqs = _reqs(4)
    sched.tick(reqs, 0)  # trips once -> rollback -> fallback retry heals
    assert sched.stats.recovered_windows == 1
    assert sched.stats.failed_windows == 0
    # The recovery arm is observable: a rollback and a recovery counted,
    # no WINDOW_VALIDATION (nothing surfaced to the caller).
    assert obs.metrics.value("sched_windows_recovered_total") == 1
    assert obs.metrics.value("sched_window_rollbacks_total") == 1
    assert obs.metrics.value("errors_total", code="WINDOW_VALIDATION") == 0
    assert sched.pending == len(reqs), "recovered window lost arrivals"
    out = sched.tick([], 4)
    assert {r.uid for r in out} <= {r.uid for r in reqs}
    assert len(out) == 4, "dispatch broken after recovery"


def test_validator_tripwire_double_trip_surfaces_error():
    """`validator_tripwire` (2 trips): the retry trips too -> typed error,
    state restored; once the tripwire exhausts, the very next window runs
    clean — proof the queue itself was never corrupted."""
    hook = inject(None, FaultSpec(kind="validator_tripwire", magnitude=2))
    sched = SmartPQScheduler(
        batch_size=8, pq_config=_sched_cfg(), seed=0, validate_hook=hook,
    )
    with pytest.raises(WindowValidationError):
        sched.tick(_reqs(4), 0)
    assert sched.stats.failed_windows == 1
    assert sched.pending == 0, "failed window must leave no trace"
    sched.tick(_reqs(4), 0)  # tripwire exhausted: clean window
    assert sched.stats.failed_windows == 1
    assert sched.pending == 4


def test_unknown_fault_kind_is_rejected():
    with pytest.raises(KeyError):
        inject(None, FaultSpec(kind="not_a_registered_fault"))


# ---------------------------------------------------------------------------
# durability injectors: torn WAL, damaged snapshots, process crash
# ---------------------------------------------------------------------------


def _store(tmp_path, obs=None, **kw):
    from repro.serve.durability import DurabilityConfig, DurableStore

    return DurableStore(DurabilityConfig(dir=tmp_path / "store", **kw),
                        obs=obs)


def _log_windows(store, n=4):
    reqs = open_loop_requests(
        poisson_arrival_counts(n, 3.0, seed=1), seed=1
    )
    for t in range(n):
        store.log_window(t, [reqs[t]])
        store.log_commit(t + 1)
    return reqs


@pytest.mark.parametrize("variant", ["", "flip", "garbage"])
def test_torn_wal_prefix_recovered_and_truncated(tmp_path, variant):
    """`torn_wal`: whatever shape the torn tail takes, recovery returns
    the intact record prefix, truncates the file to it, and a second
    recovery is clean — never an exception, never a half-parsed record."""
    from repro.serve.durability import WriteAheadLog

    store = _store(tmp_path)
    _log_windows(store, n=4)
    store.close()
    whole = WriteAheadLog(store.wal.path).recover()[0]
    assert len(whole) == 8  # 4 windows + 4 commits

    inject(store, FaultSpec(kind="torn_wal", variant=variant, rate=0.5))
    records, dropped_r, dropped_b = WriteAheadLog(store.wal.path).recover()
    assert records == whole[: len(records)], "recovered prefix diverged"
    if variant == "flip":
        assert len(records) < len(whole), "flip went undetected"
    else:
        assert dropped_b > 0 and dropped_r >= 1
    again, r2, b2 = WriteAheadLog(store.wal.path).recover()
    assert again == records and r2 == 0 and b2 == 0, (
        "truncation did not leave a clean log"
    )


@pytest.mark.parametrize("variant", ["truncate", "delete"])
def test_partial_snapshot_falls_back_to_older(tmp_path, variant):
    """`partial_snapshot`: a snapshot missing/truncating a payload shard
    must be skipped WITH accounting and recovery must land on the older
    intact snapshot."""
    from repro.obs import Observability

    obs = Observability()
    store = _store(tmp_path, obs=obs)
    like = {"x": np.arange(8, dtype=np.int32)}
    store.snapshot(4, {"x": np.arange(8, dtype=np.int32)}, {"tag": "old"})
    store.snapshot(8, {"x": np.arange(8, dtype=np.int32) * 2},
                   {"tag": "new"})
    inject(store, FaultSpec(kind="partial_snapshot", variant=variant))
    got = store.load_newest_valid(like)
    assert got is not None, "older intact snapshot was not found"
    step, tree, extra = got
    assert step == 4 and extra["tag"] == "old"
    assert np.array_equal(np.asarray(tree["x"]), np.arange(8))
    assert store.stats.snapshots_skipped_invalid == 1
    # the absorbed corruption is counted at the absorb site
    assert obs.metrics.value("errors_total", code="SNAPSHOT_CORRUPT") == 1
    assert obs.metrics.value("snapshots_total") == 2


@pytest.mark.parametrize("variant", ["", "garbage"])
def test_stale_manifest_recovery_scans_to_valid(tmp_path, variant):
    """`stale_manifest`: a LATEST pointer naming a step that is not on
    disk (default) or an unparseable manifest on the newest step
    ('garbage') — recovery scans newest-first and still loads a valid
    snapshot."""
    store = _store(tmp_path)
    like = {"x": np.zeros(4, np.int64)}
    store.snapshot(2, {"x": np.full(4, 2, np.int64)}, {"s": 2})
    store.snapshot(6, {"x": np.full(4, 6, np.int64)}, {"s": 6})
    inject(store, FaultSpec(kind="stale_manifest", variant=variant))
    got = store.load_newest_valid(like)
    assert got is not None
    step, tree, extra = got
    want = 2 if variant == "garbage" else 6
    assert step == want and extra["s"] == want
    assert int(np.asarray(tree["x"])[0]) == want


def test_crash_at_step_marker_disarms_inline():
    """`crash_at_step` with an existing marker (a prior incarnation
    already crashed) must be a transparent no-op wrapper — the engine
    completes normally.  The live-fire SIGKILL path is exercised in the
    subprocess drills of tests/test_durability.py."""
    import tempfile

    with tempfile.NamedTemporaryFile() as marker:
        eng = ServeEngine(None, None, EngineConfig(batch_size=4), seed=0)
        inject(eng, FaultSpec(
            kind="crash_at_step", magnitude=0.0, variant=marker.name,
        ))
        wl = open_loop_requests(
            poisson_arrival_counts(6, 2.0, seed=2), seed=2
        )
        summary = eng.run(wl, max_steps=64)
        assert summary["completed"] == sum(len(t) for t in wl)


def test_crash_at_step_kills_the_process():
    """`crash_at_step` unarmed (no marker): the wrapped step must SIGKILL
    the process at the chosen engine step — verified in a subprocess."""
    import subprocess

    code = (
        "import sys; sys.path.insert(0, 'src')\n"
        "from repro.faults import FaultSpec, inject\n"
        "from repro.serve.engine import EngineConfig, ServeEngine\n"
        "from repro.workloads.traces import (open_loop_requests,"
        " poisson_arrival_counts)\n"
        "eng = ServeEngine(None, None, EngineConfig(batch_size=2), seed=0)\n"
        "inject(eng, FaultSpec(kind='crash_at_step', magnitude=2.0))\n"
        "wl = open_loop_requests(poisson_arrival_counts(4, 2.0, 3), seed=3)\n"
        "eng.run(wl, max_steps=32)\n"
        "print('survived')\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=str(Path(__file__).resolve().parents[1]),
        capture_output=True, text=True, timeout=300,
    )
    assert proc.returncode == -9, (
        f"expected SIGKILL, got rc={proc.returncode}\n{proc.stderr[-2000:]}"
    )
    assert "survived" not in proc.stdout
