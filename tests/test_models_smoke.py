"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; decode continuation from prefill."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import get_config, list_configs, reduced_config
from repro.models.io import init_caches, input_specs
from repro.models.model import cross_entropy_loss
from repro.models.params import padded_vocab
from repro.models.registry import build_model

ARCHS = list_configs()
B, S = 2, 64


def _batch(cfg):
    batch = {
        "tokens": jnp.ones((B, S), jnp.int32),
        "labels": jnp.ones((B, S), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.ones((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.ones(
            (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_forward(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg, remat=False)
    params, specs = model.init(jax.random.key(0))
    assert jax.tree.structure(params) == jax.tree.structure(specs)
    batch = _batch(cfg)
    logits, aux = jax.jit(model.train_logits)(params, batch)
    assert logits.shape == (B, S, padded_vocab(cfg))
    loss = cross_entropy_loss(logits, batch["labels"], cfg.vocab)
    assert np.isfinite(float(loss))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg = reduced_config(arch)
    model = build_model(cfg, remat=False)
    params, _ = model.init(jax.random.key(1))
    batch = {k: v for k, v in _batch(cfg).items() if k != "labels"}
    logits, caches = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, padded_vocab(cfg))
    dl, caches2 = jax.jit(model.decode_step)(
        params, caches, jnp.ones((B, 1), jnp.int32),
        jnp.full((B,), S - 1, jnp.int32),
    )
    assert dl.shape == (B, padded_vocab(cfg))
    assert np.isfinite(np.asarray(dl, np.float32)).all()
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_dimensions(arch):
    """The FULL configs carry the published dimensions (exercised only via
    the dry-run; here we assert the numbers themselves)."""
    cfg = get_config(arch)
    published = {
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "mamba2-780m": (48, 1536, 1, 1, 0, 50280),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab)
    assert got == published, (arch, got, published)
    if arch == "jamba-1.5-large-398b":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 2
        assert cfg.hybrid_period == 8  # Mamba:attn 7:1
    if "moe" in arch and "granite" in arch:
        assert cfg.moe.top_k == 8
    if arch == "mamba2-780m":
        assert cfg.ssm.d_state == 128


def test_input_specs_cover_all_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            leaves = jax.tree.leaves(specs)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)


def test_long_context_skips_documented():
    n_skipped = 0
    for arch in ARCHS:
        cfg = get_config(arch)
        ok, why = shape_applicable(cfg, SHAPES["long_500k"])
        if not ok:
            assert "quadratic" in why
            n_skipped += 1
    assert n_skipped == 8  # all but mamba2 + jamba
