"""Application-workload subsystem contracts (`repro.workloads`).

Machine-checked claims:
  1. SSSP distances are bit-equal to the Bellman-Ford oracle under EVERY
     exact schedule (STRICT_FLAT, HIER/Nuddle, FFWD) on a >=512-vertex
     random graph, and the relaxed schedules (SPRAY, MULTIQ) converge to
     the same distances with a bounded wasted-relaxation overhead.
  2. The adaptive SmartPQ driver converges too, and its recorded op log
     is a well-formed replayable trace.
  3. The DES hold-model's per-step pop sequence is bit-equal to a host
     `heapq` oracle of the same linearization under an exact schedule.
  4. Trace record -> save -> load -> replay round-trips bit-identically
     through `run_window` (outputs AND final carry).
  5. The phased DES trace drives the adaptive engine through >= 2 distinct
     modes with at least one transition (ISSUE 5 acceptance).
  6. Every registry workload produces a replayable trace, and
     `dataset.examples_from_trace` turns traces into well-formed labeled
     examples.
"""

import numpy as np
import jax
import pytest

from repro.core.classifier.features import NUM_CLASSES, NUM_MODES
from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT, OP_NOP
from repro.core.pqueue.schedules import Schedule
from repro.core.pqueue.state import INF_KEY
from repro.workloads import (
    bellman_ford,
    hold_model_oracle,
    random_graph,
    registry,
    run_hold_model,
    run_sssp,
    run_sssp_smartpq,
    traces,
)
from repro.workloads.registry import default_pq

GRAPH = random_graph(n=512, seed=0)
REF = bellman_ford(GRAPH)
SMALL_GRAPH = random_graph(n=128, seed=1)
SMALL_REF = bellman_ford(SMALL_GRAPH)


# ---------------------------------------------------------------------------
# 1. SSSP vs Bellman-Ford
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "schedule", [Schedule.STRICT_FLAT, Schedule.HIER, Schedule.FFWD],
    ids=lambda s: s.name,
)
def test_sssp_exact_bitmatches_bellman_ford(schedule):
    """Acceptance: distances bit-equal to the oracle for every exact
    schedule on the 512-vertex graph."""
    r = run_sssp(GRAPH, schedule, m=32, seed=1)
    assert r.converged, f"{schedule.name} did not drain the queue"
    np.testing.assert_array_equal(r.dist, REF)


@pytest.mark.parametrize(
    "schedule", [Schedule.SPRAY_HERLIHY, Schedule.MULTIQ],
    ids=lambda s: s.name,
)
def test_sssp_relaxed_converges_with_bounded_waste(schedule):
    """Relaxed schedules are label-correcting: same distances at
    convergence, wasted pops stay a bounded fraction of total pops."""
    r = run_sssp(SMALL_GRAPH, schedule, m=32, seed=1)
    assert r.converged
    np.testing.assert_array_equal(r.dist, SMALL_REF)
    assert r.pops > 0
    # waste is real but must not dominate: every relaxed run on this graph
    # stays well under parity with useful pops
    assert r.wasted < r.pops, (r.wasted, r.pops)
    assert r.wasted <= 2 * SMALL_GRAPH.n, (
        f"{schedule.name}: wasted {r.wasted} pops vs n={SMALL_GRAPH.n}"
    )


def test_sssp_adaptive_converges_and_records():
    pq = default_pq(head_width=256)
    r, trace = run_sssp_smartpq(SMALL_GRAPH, pq, m=16, seed=2, record=True)
    assert r.converged
    np.testing.assert_array_equal(r.dist, SMALL_REF)
    assert set(r.modes.tolist()) <= set(range(NUM_MODES))
    # the recorded op log covers every executed step at the pipelined width
    assert trace.num_steps == r.steps
    assert trace.width == 16 * SMALL_GRAPH.deg_cap + 16
    assert set(np.unique(trace.ops)) <= {OP_INSERT, OP_DELETE_MIN, OP_NOP}


# ---------------------------------------------------------------------------
# 2/3. DES hold model vs heapq oracle
# ---------------------------------------------------------------------------


def test_des_hold_model_bitmatches_heapq_oracle():
    B, K = 32, 48
    pq = default_pq(mode_schedules=(Schedule.STRICT_FLAT,) * NUM_MODES)
    res = run_hold_model(pq, B=B, K=K, seed=3)
    oracle = hold_model_oracle(B, K, seed=3)
    assert res.events == sum(len(o) for o in oracle)
    for t in range(K):
        got = res.popped[t][: res.n_out[t]]
        np.testing.assert_array_equal(
            got, np.asarray(oracle[t], np.int32), err_msg=f"step {t}"
        )


def test_des_hold_model_relaxed_conserves_events():
    """A relaxed schedule may transiently under-serve (two-choice lanes
    can land on short shards) but the hold churn never loses an event:
    served + still-queued always balances initial + rescheduled."""
    B, K = 32, 24
    exact = run_hold_model(
        default_pq(mode_schedules=(Schedule.STRICT_FLAT,) * NUM_MODES),
        B=B, K=K, seed=4,
    )
    relaxed = run_hold_model(
        default_pq(mode_schedules=(Schedule.MULTIQ,) * NUM_MODES),
        B=B, K=K, seed=4,
    )
    n_init = 4 * B
    for res in (exact, relaxed):
        # step t reschedules exactly the events step t-1 served, so
        # conservation pins the final backlog to n_init - last serve.
        rescheduled = int(np.sum(res.n_out[:-1]))
        assert res.events + res.final_size == n_init + rescheduled
    assert relaxed.events <= exact.events  # exact serves maximally
    assert exact.events - relaxed.events <= K * 2  # bounded under-service


# ---------------------------------------------------------------------------
# 4. trace record/replay round-trip
# ---------------------------------------------------------------------------


def test_trace_roundtrip_bit_identical(tmp_path):
    trace = traces.phase_flip_trace(B=32, steps_per_phase=4, seed=7)
    path = tmp_path / "trace.npz"
    traces.save_trace(path, trace)
    loaded = traces.load_trace(path)
    for a, b in zip(trace[:4], loaded[:4]):
        np.testing.assert_array_equal(a, b)
    assert loaded.seed == trace.seed

    pq = default_pq(num_shards=8, capacity=512)
    c1, r1 = traces.replay(pq, trace)
    c2, r2 = traces.replay(pq, loaded)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_recorded_des_trace_replays_bit_identically():
    """A recorder-captured op log (state-dependent keys!) replayed through
    run_window reproduces the live run's pops bit for bit: the trace's
    init prefill restores the driver's starting state, and the exact
    schedule pins the linearization."""
    B, K = 16, 16
    pq = default_pq(mode_schedules=(Schedule.STRICT_FLAT,) * NUM_MODES)
    res = run_hold_model(pq, B=B, K=K, seed=5, record=True)
    carry, rep = traces.replay(pq, res.trace)
    assert int(np.sum(np.asarray(rep.n_out))) == res.events
    np.testing.assert_array_equal(np.asarray(rep.keys)[:, :B], res.popped)


# ---------------------------------------------------------------------------
# 5. phased DES trace drives >= 2 modes (acceptance)
# ---------------------------------------------------------------------------


def test_bursty_des_trace_transitions_adaptive_modes():
    trace = traces.bursty_des_trace(seed=5)
    pq = default_pq(num_shards=8, capacity=1024)
    carry, res = traces.replay(pq, trace)
    modes = {int(m) for m in np.asarray(res.mode)}
    assert len(modes) >= 2, f"adaptive engine never switched: {modes}"
    assert int(carry.stats.transitions) >= 1
    assert modes <= set(range(NUM_MODES))


# ---------------------------------------------------------------------------
# 6. registry enumeration + classifier examples from traces
# ---------------------------------------------------------------------------


def test_registry_enumerates_replayable_traces():
    assert set(registry.names()) == {
        "sssp", "des_hold", "des_bursty", "phase_flip", "size_ramp",
        "mix_drift",
    }
    pq = default_pq(num_shards=8, capacity=4096, head_width=256)
    for name in registry.names():
        spec = registry.get(name)
        trace = spec.make_trace(True, 11)  # quick
        assert trace.ops.shape == trace.keys.shape == trace.vals.shape
        assert trace.num_clients.shape == (trace.num_steps,)
        assert trace.ops.dtype == np.int32
        carry, res = traces.replay(pq, trace)
        assert int(np.asarray(res.n_out).sum()) >= 0
        ks = np.asarray(res.keys)
        valid = ks < INF_KEY
        assert np.all(np.diff(np.where(valid, ks, INF_KEY), axis=1) >= 0), (
            f"{name}: replay outputs not ascending"
        )


def test_examples_from_trace_wellformed():
    from repro.core.classifier.dataset import (
        examples_from_trace,
        make_trace_training_set,
    )

    X, y = examples_from_trace(traces.size_ramp_trace(seed=9), window=4)
    assert X.shape[1] == 4 and X.dtype == np.float32
    assert len(X) == len(y)
    assert np.all((0 <= y) & (y < NUM_CLASSES))
    # the ramp sweeps size: features must not be constant
    assert np.std(X[:, 1]) > 0

    Xt, yt = make_trace_training_set(seeds=(0,), window=4)
    assert len(Xt) == len(yt) > 0
    # application-shaped streams must exercise more than one label
    assert len(np.unique(yt)) >= 2


def test_registry_rejects_unknown_name():
    with pytest.raises(KeyError):
        registry.get("nope")
