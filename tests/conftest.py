"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches see
1 device; multi-device tests spawn subprocesses with their own flags."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
SRC = REPO / "src"
sys.path.insert(0, str(SRC))
DEVICE_SCRIPTS = Path(__file__).parent / "device_scripts"


def run_device_script(name: str, n_devices: int = 8, timeout: int = 900):
    """Run tests/device_scripts/<name> in a subprocess with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC)
    proc = subprocess.run(
        [sys.executable, str(DEVICE_SCRIPTS / name)],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert proc.returncode == 0, (
        f"{name} failed\nSTDOUT:\n{proc.stdout[-4000:]}\nSTDERR:\n{proc.stderr[-4000:]}"
    )
    return proc.stdout


@pytest.fixture(scope="session")
def device_script_runner():
    return run_device_script


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """Drop compiled executables after each test module.

    jax 0.4.37's CPU backend segfaults inside `backend_compile` once a few
    hundred distinct programs have been compiled in one process (observed
    deterministically at ~130 tests into the suite, in a trivial program
    that compiles fine standalone).  Programs rarely repeat across modules,
    so releasing the jit caches at module boundaries costs nothing and
    keeps the accumulated compiler state below the crash threshold."""
    yield
    import jax

    jax.clear_caches()
