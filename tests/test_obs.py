"""Telemetry layer tests: registry semantics, trace export, zero-perturbation.

Three contracts from the observability PR:

  * `MetricsRegistry` — labeled counters/gauges/histograms with upper-edge
    percentiles that are EXACT on the integer step clock, partial-label
    bucket merging, Prometheus text exposition, and an atomic-persist
    round trip;
  * `Tracer` — Chrome trace-event export whose window/tick span structure
    mirrors the executed schedule (window spans == executed windows, tick
    spans nest inside their window, mode-transition instants == the
    device's own `stats.transitions` counter);
  * zero perturbation — running with telemetry fully on yields dispatch
    streams and a carry fingerprint BIT-IDENTICAL to running with the
    disabled bundle, and the per-op overhead stays within the 1.05x
    budget (the obs_overhead bench's acceptance bar).
"""

import json
import math
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.smartpq import (  # noqa: E402
    MODE_AWARE,
    SmartPQConfig,
    carry_fingerprint,
)
from repro.obs import (  # noqa: E402
    LATENCY_STEP_EDGES,
    MetricsRegistry,
    Observability,
    Tracer,
    get_default,
)
from repro.serve.engine import EngineConfig, ServeEngine  # noqa: E402
from repro.serve.scheduler import Request, SmartPQScheduler  # noqa: E402
from repro.workloads.traces import bursty_serve_workload  # noqa: E402


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counters_gauges_and_labels():
    m = MetricsRegistry()
    m.inc("a")
    m.inc("a", n=2.0)
    m.inc("a", code="X")
    m.set_gauge("g", 3.5, shard=1)
    assert m.value("a") == 3.0
    assert m.value("a", code="X") == 1.0
    assert m.value("g", shard=1) == 3.5
    assert m.value("never_written") == 0.0
    d = m.to_dict()
    assert d["schema"] == 1
    assert d["counters"]['a{code="X"}'] == 1.0
    # compact() (the heartbeat payload) carries counters AND gauges
    assert m.compact()['g{shard="1"}'] == 3.5


def test_disabled_registry_is_noop():
    m = MetricsRegistry(enabled=False)
    m.inc("a")
    m.set_gauge("g", 1.0)
    m.observe("h", 1.0)
    d = m.to_dict()
    assert d["counters"] == {} and d["gauges"] == {} and d["histograms"] == {}


def test_percentiles_exact_on_integer_edges():
    """Upper-edge estimates coincide with true order statistics when the
    observations land on edges — the property the SLO gates rely on."""
    m = MetricsRegistry()
    for v in range(1, 51):  # all within the per-integer edge range (0..64)
        m.observe("lat", float(v), edges=LATENCY_STEP_EDGES)
    assert m.percentile("lat", 50) == 25.0
    assert m.percentile("lat", 99) == 50.0
    assert m.hist_count("lat") == 50
    assert m.hist_sum("lat") == sum(range(1, 51))
    s = m.summary("lat")
    assert (s["count"], s["p50"], s["p99"]) == (50, 25.0, 50.0)
    # beyond the per-integer range the estimate is the conservative upper
    # edge of the coarse bucket
    m.clear()
    for v in range(1, 101):
        m.observe("lat", float(v), edges=LATENCY_STEP_EDGES)
    assert m.percentile("lat", 99) == 128.0  # 99 lands in the (96, 128] bucket


def test_partial_label_percentile_merges_buckets():
    """percentile(name) with a partial label set merges bucket counts
    across series — the true pooled distribution, not an average of
    per-series percentiles."""
    m = MetricsRegistry()
    for c in (0, 1):
        for v in (1, 2, 3, 4):
            m.observe("lat", v + 4 * c, edges=LATENCY_STEP_EDGES, slo=c)
    assert m.percentile("lat", 50) == 4.0  # pooled 1..8
    assert m.percentile("lat", 50, slo=0) == 2.0
    assert m.percentile("lat", 50, slo=1) == 6.0
    assert m.hist_count("lat", slo=1) == 4
    assert m.hist_count("lat") == 8


def test_tail_bucket_reports_observed_max_and_empty_is_nan():
    m = MetricsRegistry()
    assert math.isnan(m.percentile("lat", 99))
    m.observe("lat", 5000.0, edges=LATENCY_STEP_EDGES)
    assert m.percentile("lat", 99) == 5000.0  # beyond the last edge


def test_prometheus_exposition():
    m = MetricsRegistry()
    m.inc("errors_total", code="INVARIANT")
    m.set_gauge("depth", 4)
    m.observe("lat", 2.0, edges=(1.0, 2.0, 4.0))
    text = m.to_prometheus()
    assert "# TYPE errors_total counter" in text
    assert 'errors_total{code="INVARIANT"} 1' in text
    assert "# TYPE depth gauge" in text
    assert "# TYPE lat histogram" in text
    assert 'lat_bucket{le="1"} 0' in text
    assert 'lat_bucket{le="2"} 1' in text  # cumulative
    assert 'lat_bucket{le="+Inf"} 1' in text
    assert "lat_sum 2" in text and "lat_count 1" in text


def test_registry_persistence_round_trip(tmp_path):
    m = MetricsRegistry()
    m.inc("errors_total", n=3, code="INVARIANT")
    m.set_gauge("pq_mode", 2.0)
    for v in (1.0, 8.0, 9.0, 700.0):
        m.observe("lat", v, edges=LATENCY_STEP_EDGES, slo=0)
    path = m.save(tmp_path / "metrics.json")
    m2 = MetricsRegistry()
    m2.load(path)
    assert m2.to_dict() == m.to_dict()
    assert m2.percentile("lat", 99, slo=0) == m.percentile("lat", 99, slo=0)
    # loaded canonical edges keep governing fresh observations
    m2.observe("lat", 2.0, slo=1)
    assert m2.hist_count("lat") == 5


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_rollback_truncation_and_bounded_buffer():
    tr = Tracer(enabled=True, max_events=4)
    tr.instant("kept")
    mark = tr.mark()
    tr.instant("rolled_back")
    with tr.span("rolled_back_span"):
        pass
    tr.truncate(mark)
    assert [e["name"] for e in tr.events] == ["kept"]
    for i in range(10):
        tr.instant(f"x{i}")
    assert len(tr.events) == 4
    assert tr.to_chrome()["otherData"]["dropped_events"] == 7


def test_disabled_tracer_emits_nothing():
    tr = Tracer(enabled=False)
    tr.instant("a")
    with tr.span("s"):
        pass
    tr.span_at("b", 0.0, 1.0)
    assert tr.events == []


def test_observability_is_identity_under_deepcopy():
    """Checkpoint deep-copies must NOT fork telemetry history."""
    import copy

    obs = Observability(metrics=True, tracing=True)
    assert copy.deepcopy(obs) is obs and copy.copy(obs) is obs


# ---------------------------------------------------------------------------
# trace export: the timeline mirrors the executed schedule
# ---------------------------------------------------------------------------


def test_trace_export_round_trip(tmp_path):
    """A K=16 bursty serving run exports valid Chrome trace JSON whose
    window spans count the executed windows, whose tick spans nest inside
    their windows, and whose mode-transition instants equal the device's
    own transition counter."""
    K = 16
    wl = bursty_serve_workload(steps=32, seed=3)
    eng = ServeEngine(None, None, EngineConfig(
        batch_size=4, sched_window=K, tracing=True,
    ), seed=3)
    summary = eng.run(wl, max_steps=4000)
    assert summary["completed"] == sum(len(a) for a in wl)

    path = eng.obs.tracer.export(tmp_path / "trace.json")
    payload = json.loads(path.read_text())
    assert payload["displayTimeUnit"] == "ms"
    assert payload["otherData"]["dropped_events"] == 0
    evs = payload["traceEvents"]
    assert evs, "empty timeline from a traced run"
    for ev in evs:  # Chrome trace-event schema (the Perfetto contract)
        assert {"name", "ph", "ts", "pid", "tid", "cat"} <= set(ev)
        assert ev["ph"] in ("X", "i")
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0

    windows = [e for e in evs if e["name"] == "window"]
    ticks = [e for e in evs if e["name"] == "tick"]
    assert len(windows) == summary["steps"] // K
    assert len(ticks) == K * len(windows)
    eps = 1e-3
    for t in ticks:  # every tick span nests inside some window span
        assert any(
            w["ts"] - eps <= t["ts"]
            and t["ts"] + t["dur"] <= w["ts"] + w["dur"] + eps
            for w in windows
        ), f"tick span at ts={t['ts']} outside every window span"
    assert sum(w["args"]["dispatched"] for w in windows) == sum(
        t["args"]["dispatched"] for t in ticks
    )

    transitions = [e for e in evs if e["name"] == "mode_transition"]
    assert len(transitions) == int(eng.scheduler.carry.stats.transitions), (
        "timeline transition instants diverge from the device counter"
    )
    for e in transitions:  # each carries the classifier's feature vector
        assert len(e["args"]["features"]) >= 1
        assert e["args"]["from_mode"] != e["args"]["to_mode"]


# ---------------------------------------------------------------------------
# zero perturbation: obs on == obs off, bit for bit
# ---------------------------------------------------------------------------


def _drive_windows(obs):
    sched = SmartPQScheduler(
        batch_size=8,
        pq_config=SmartPQConfig(
            num_shards=4, capacity=1024, decision_interval=4,
            initial_mode=MODE_AWARE,
        ),
        seed=5, obs=obs,
    )
    out_uids, uid = [], 0
    K = 4
    for w in range(4):
        arrivals = []
        for t in range(K):
            arrivals.append([
                Request(uid=uid + i, prompt_len=8 + (uid + i) % 32,
                        max_new_tokens=4, slo_class=(uid + i) % 3,
                        arrival_step=w * K + t)
                for i in range(4)
            ])
            uid += 4
        out = sched.tick_window(arrivals, [2] * K)
        out_uids.append([[r.uid for r in tick] for tick in out])
    return out_uids, sched


def test_obs_on_off_dispatch_streams_bit_identical():
    u_off, s_off = _drive_windows(Observability(metrics=False, tracing=False))
    u_on, s_on = _drive_windows(Observability(metrics=True, tracing=True))
    assert u_on == u_off, "telemetry perturbed the dispatch stream"
    assert carry_fingerprint(s_on.carry) == carry_fingerprint(s_off.carry), (
        "telemetry perturbed the device carry"
    )
    # and the instrumented session actually observed the run
    m = s_on.obs.metrics
    assert m.value("sched_windows_total") == 4
    assert m.value("sched_ticks_total") == 16
    assert len([e for e in s_on.obs.tracer.events
                if e["name"] == "window"]) == 4


@pytest.mark.slow
def test_obs_overhead_within_budget():
    """The obs_overhead bench's acceptance bar: telemetry fully on costs
    <= 1.05x per-op on the delete-dominated window path (interleaved
    timing; both sessions run the same compiled program)."""
    repo = Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(repo))
    try:
        from benchmarks.obs_overhead import measure
    finally:
        sys.path.pop(0)
    r = measure(iters=10)
    assert r["identical"]
    assert r["ratio"] <= 1.05, (
        f"telemetry overhead {r['ratio']:.3f}x exceeds the 1.05x budget "
        f"(on {r['us_per_op_on']:.3f} vs off {r['us_per_op_off']:.3f} "
        f"us/op)"
    )


# ---------------------------------------------------------------------------
# kernel-arm resolution notes land in the process-global registry
# ---------------------------------------------------------------------------


def test_kernel_resolution_noted_in_default_registry():
    from repro.kernels import registry as REG

    coords = {"R": 1, "N": 256, "k": 16, "dtype": "int32"}
    arm = REG.resolve("topk_smallest", coords)
    assert arm in [a.name for a in REG.REGISTRY["topk_smallest"].arms]
    counters = get_default().metrics.to_dict()["counters"]
    noted = {
        k: v for k, v in counters.items()
        if k.startswith("kernel_resolutions_total")
    }
    assert sum(noted.values()) >= 1, "arm resolution left no counter"
    assert any('kernel="topk_smallest"' in k for k in noted)
