"""Durable serving: WAL framing, crash-consistent snapshots, recovery
bit-identity, and the supervisor's restart policy.

The recovery contract under test (the tentpole's acceptance bar): kill a
durable serving run at an arbitrary point and restart it, and the
finished run is BIT-IDENTICAL to one that was never interrupted —
completion sets, per-request done steps, emitted tokens, the device
carry's fingerprint, and the request-conservation ledger
(``inserted + arrival_backlog + shed + evicted == arrivals``) all match
exactly.  The in-process tests cover clean pause/resume and the
snapshot/WAL plumbing; the slow subprocess drills SIGKILL a real worker
mid-window for K in {1, 16} and diff its artifacts against an
uninterrupted reference.
"""

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "src"))

from repro.core import persist  # noqa: E402
from repro.core.errors import (  # noqa: E402
    CrashLoopError,
    SnapshotCorruptError,
)
from repro.serve.durability import (  # noqa: E402
    DurabilityConfig,
    DurableStore,
    WriteAheadLog,
)
from repro.serve.engine import EngineConfig, ServeEngine  # noqa: E402
from repro.serve.supervisor import (  # noqa: E402
    Supervisor,
    SupervisorConfig,
)
from repro.workloads.traces import bursty_serve_workload  # noqa: E402


# ---------------------------------------------------------------------------
# WAL framing
# ---------------------------------------------------------------------------


def test_wal_roundtrip(tmp_path):
    wal = WriteAheadLog(tmp_path / "wal.log")
    recs = [{"kind": "window", "step0": i, "arrivals": [[]]}
            for i in range(5)]
    for r in recs:
        wal.append(r)
    wal.sync()
    wal.close()
    got, dropped_r, dropped_b = WriteAheadLog(tmp_path / "wal.log").recover()
    assert got == recs and dropped_r == 0 and dropped_b == 0


def test_wal_torn_tail_truncated_not_crashed(tmp_path):
    """A partial final frame (crash mid-append) is detected by the CRC
    framing and truncated away; the intact prefix survives."""
    path = tmp_path / "wal.log"
    wal = WriteAheadLog(path)
    wal.append({"a": 1})
    wal.append({"b": 2})
    wal.sync()
    wal.close()
    blob = path.read_bytes()
    path.write_bytes(blob[:-3])  # tear the last frame
    got, dropped_r, dropped_b = WriteAheadLog(path).recover()
    assert got == [{"a": 1}] and dropped_r == 1 and dropped_b > 0
    again, r2, b2 = WriteAheadLog(path).recover()
    assert again == got and r2 == 0 and b2 == 0, "truncate was not durable"


def test_wal_append_after_recovery_continues_cleanly(tmp_path):
    path = tmp_path / "wal.log"
    wal = WriteAheadLog(path)
    wal.append({"n": 0})
    wal.sync()
    wal.close()
    path.write_bytes(path.read_bytes() + b"\x07garbage")
    wal2 = WriteAheadLog(path)
    assert wal2.recover()[0] == [{"n": 0}]
    wal2.append({"n": 1})
    wal2.sync()
    wal2.close()
    assert WriteAheadLog(path).recover()[0] == [{"n": 0}, {"n": 1}]


# ---------------------------------------------------------------------------
# persist: atomic snapshot tree + newest-valid recovery rule
# ---------------------------------------------------------------------------


def _tree(k: int):
    return {"a": np.arange(6, dtype=np.int64) + k,
            "b": {"c": np.full((2, 3), float(k), np.float32)}}


def test_save_tree_roundtrip_and_latest(tmp_path):
    persist.save_tree(tmp_path, 3, _tree(3), extra={"tag": "x"})
    persist.save_tree(tmp_path, 7, _tree(7))
    assert persist.latest_step(tmp_path) == 7
    assert persist.available_steps(tmp_path) == [7, 3]
    tree, manifest = persist.load_tree(tmp_path, _tree(0), 3)
    assert manifest["extra"] == {"tag": "x"}
    assert np.array_equal(np.asarray(tree["a"]), np.arange(6) + 3)
    assert np.asarray(tree["b"]["c"]).dtype == np.float32


def test_newest_valid_skips_corrupt_snapshot(tmp_path):
    persist.save_tree(tmp_path, 2, _tree(2))
    persist.save_tree(tmp_path, 5, _tree(5))
    shard = persist.step_dir(tmp_path, 5) / "shard_0.npz"
    shard.write_bytes(shard.read_bytes()[:40])  # torn write
    with pytest.raises(SnapshotCorruptError):
        persist.validate_step(tmp_path, 5)
    assert persist.newest_valid_step(tmp_path) == 2


def test_prune_keeps_newest_and_latest(tmp_path):
    for s in (1, 2, 3, 4):
        persist.save_tree(tmp_path, s, _tree(s))
    removed = persist.prune_steps(tmp_path, keep=2)
    assert removed == 2
    assert persist.available_steps(tmp_path) == [4, 3]


def test_atomic_savez_replaces_never_tears(tmp_path):
    p = tmp_path / "t.npz"
    persist.atomic_savez(p, x=np.arange(4))
    persist.atomic_savez(p, x=np.arange(9))
    with np.load(p) as z:
        assert z["x"].shape == (9,)
    assert not list(tmp_path.glob(".t.npz.*")), "tmp files leaked"


# ---------------------------------------------------------------------------
# DurableStore: snapshot cadence + WAL suffix selection
# ---------------------------------------------------------------------------


def test_store_snapshot_cadence_and_suffix(tmp_path):
    store = DurableStore(DurabilityConfig(
        dir=tmp_path, snapshot_interval=2, keep_snapshots=2,
    ))
    for w in range(4):
        store.log_window(w * 4, [[]])
        store.log_commit((w + 1) * 4)
        store.window_committed()
        if store.should_snapshot():
            store.snapshot((w + 1) * 4, {"x": np.arange(3)}, {"w": w})
    assert store.stats.snapshots_written == 2  # after windows 2 and 4
    assert store.stats.last_snapshot_step == 16
    # replay suffix after the step-8 snapshot: windows starting at >= 8
    fresh = DurableStore(DurabilityConfig(dir=tmp_path))
    suffix = fresh.window_suffix(8)
    assert [r["step0"] for r in suffix] == [8, 12]
    got = fresh.load_newest_valid({"x": np.zeros(3, np.int64)})
    assert got is not None and got[0] == 16 and got[2] == {"w": 3}
    assert (tmp_path / "heartbeat.json").exists()
    store.close()


def test_store_empty_dir_recovers_to_nothing(tmp_path):
    store = DurableStore(DurabilityConfig(dir=tmp_path / "new"))
    assert store.read_wal() == []
    assert store.load_newest_valid({"x": np.zeros(2)}) is None
    store.close()


# ---------------------------------------------------------------------------
# engine: health surface, pause/resume bit-identity
# ---------------------------------------------------------------------------


def _engine(tmp_path=None, K=1, seed=3, **kw):
    return ServeEngine(None, None, EngineConfig(
        batch_size=4, sched_window=K,
        durable_dir=None if tmp_path is None else str(tmp_path),
        snapshot_interval=3, **kw,
    ), seed=seed)


def _fingerprints(eng):
    from repro.core.smartpq import carry_fingerprint

    return (
        dict(eng.done_step),
        {u: list(v) for u, v in eng.outputs.items()},
        carry_fingerprint(eng.scheduler.carry),
    )


def test_health_surface_and_conservation():
    wl = bursty_serve_workload(steps=12, seed=5)
    eng = _engine(K=4, seed=5)
    eng.run(wl, max_steps=200)
    h = eng.health()
    total = sum(len(t) for t in wl)
    assert h["inserted"] + h["arrival_backlog"] + h["shed"] \
        + h["evicted"] == total
    assert h["inserted"] == h["dispatched"] + h["on_device"]
    assert h["completed"] == len(eng.done_step)
    assert h["durability"] is None and h["overload"] is None
    for key in ("recovered_windows", "failed_windows", "admit_backlog",
                "free_slots", "pq_transitions", "service_est"):
        assert key in h


@pytest.mark.parametrize("K", [1, 4])
def test_pause_resume_bit_identical(tmp_path, K):
    """A durable run paused at a window boundary and resumed by a FRESH
    engine (snapshot restore, no replay needed) finishes bit-identical to
    an uninterrupted durable run."""
    wl = bursty_serve_workload(steps=16, seed=3)
    ref = _engine(tmp_path / "ref", K=K)
    ref.run(wl, max_steps=500)

    e1 = _engine(tmp_path / "cut", K=K)
    e1.run(wl, max_steps=8)
    assert e1._step == 8
    e2 = _engine(tmp_path / "cut", K=K)
    e2.run(wl, max_steps=500)

    assert _fingerprints(ref) == _fingerprints(e2)
    hr, h2 = ref.health(), e2.health()
    for k in ("inserted", "dispatched", "shed", "evicted", "completed",
              "on_device", "arrival_backlog"):
        assert hr[k] == h2[k], k
    for e in (ref, e1, e2):
        e.durability.close()


def test_recover_replays_wal_suffix_after_torn_commit(tmp_path):
    """Simulate a crash mid-window: log_window written, no commit, state
    not snapshotted — a fresh engine's recover() must replay the window
    and land on the same state the crashed engine reached."""
    wl = bursty_serve_workload(steps=8, seed=9)
    live = _engine(tmp_path / "d", K=4, seed=9)
    # run two windows by hand through the durable path
    for w in range(2):
        arr = [wl[w * 4 + i] for i in range(4)]
        live.durability.log_window(w * 4, arr)
        live._advance(arr, w * 4, 1 << 62)
        if w == 0:
            live.durability.log_commit(live._step)
    # crash here: window 1 logged but uncommitted, nothing snapshotted
    live_prints = _fingerprints(live)
    live.durability.close()

    fresh = _engine(tmp_path / "d", K=4, seed=9)
    info = fresh.recover()
    assert info["snapshot_step"] is None
    assert info["replayed_windows"] == 2
    assert _fingerprints(fresh) == live_prints
    assert fresh.durability.stats.replayed_windows == 2
    fresh.durability.close()


def test_recover_rejects_carry_fingerprint_mismatch(tmp_path):
    wl = bursty_serve_workload(steps=4, seed=2)
    eng = _engine(tmp_path / "d", K=1, seed=2)
    eng.run(wl, max_steps=4)
    eng.durability.close()
    # doctor the manifest's stamped fingerprint: restore must refuse
    snap_root = Path(tmp_path / "d") / "snapshots"
    step = persist.latest_step(snap_root)
    mpath = persist.step_dir(snap_root, step) / "manifest.json"
    m = json.loads(mpath.read_text())
    m["extra"]["carry_crc"] ^= 0xDEAD
    mpath.write_text(json.dumps(m))
    # shard CRCs still validate -> load succeeds -> fingerprint check fires
    fresh = _engine(tmp_path / "d", K=1, seed=2)
    with pytest.raises(SnapshotCorruptError):
        fresh.recover()
    fresh.durability.close()


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------

_SUP_CFG = SupervisorConfig(
    heartbeat_timeout=1.0, startup_timeout=10.0, poll_interval=0.02,
    backoff_base=0.02, backoff_max=0.1, max_restarts=3, crash_window=60.0,
)


def _script_child(tmp_path, body: str):
    p = tmp_path / "child.py"
    p.write_text(body)
    return [sys.executable, str(p)]


def test_supervisor_restarts_until_success(tmp_path):
    """Child crashes twice then succeeds: two restarts, outcome completed."""
    argv = _script_child(tmp_path, f"""
import os, sys
count = "{tmp_path}/count"
n = int(open(count).read()) if os.path.exists(count) else 0
open(count, "w").write(str(n + 1))
sys.exit(0 if n >= 2 else 1)
""")
    rep = Supervisor(argv, tmp_path / "hb.json", _SUP_CFG).run()
    assert rep.outcome == "completed"
    assert rep.restarts == 2
    assert rep.exit_codes == [1, 1, 0]
    assert rep.hang_kills == 0


def test_supervisor_kills_hung_child(tmp_path):
    """Child heartbeats once then wedges: the stale-heartbeat watchdog
    SIGKILLs it; the restarted incarnation (marker present) exits clean."""
    argv = _script_child(tmp_path, f"""
import json, os, sys, time
marker = "{tmp_path}/ran_once"
if os.path.exists(marker):
    sys.exit(0)
open(marker, "w").write("1")
open("{tmp_path}/hb.json", "w").write(json.dumps({{"step": 1}}))
time.sleep(120)  # wedged: no further heartbeats
""")
    t0 = time.time()
    rep = Supervisor(argv, tmp_path / "hb.json", _SUP_CFG).run()
    assert rep.outcome == "completed"
    assert rep.hang_kills == 1
    assert rep.exit_codes[0] == -9
    assert time.time() - t0 < 60, "watchdog did not fire promptly"


def test_supervisor_circuit_breaker_trips(tmp_path):
    from repro.obs import get_default

    argv = _script_child(tmp_path, "import sys; sys.exit(1)\n")
    before = get_default().metrics.value("errors_total", code="CRASH_LOOP")
    with pytest.raises(CrashLoopError) as ei:
        Supervisor(argv, tmp_path / "hb.json", _SUP_CFG).run()
    assert ei.value.code == "CRASH_LOOP"
    assert len(ei.value.exit_codes) == _SUP_CFG.max_restarts + 1
    after = get_default().metrics.value("errors_total", code="CRASH_LOOP")
    assert after == before + 1  # the raise site counted the typed error


# ---------------------------------------------------------------------------
# subprocess crash drills (slow lane): SIGKILL mid-window, bit-identical
# recovery for K in {1, 16}
# ---------------------------------------------------------------------------


def _worker(store, out, *, K, kill_at=None, marker=None, steps=24, seed=3):
    cmd = [
        sys.executable, "-m", "repro.serve.worker",
        "--dir", str(store), "--out", str(out),
        "--steps", str(steps), "--seed", str(seed),
        "--window", str(K), "--snapshot-interval", "3",
    ]
    if kill_at is not None:
        cmd += ["--sigkill-at-step", str(kill_at)]
    if marker is not None:
        cmd += ["--crash-marker", str(marker)]
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        cmd, cwd=str(REPO), env=env, capture_output=True, text=True,
        timeout=600,
    )


@pytest.mark.slow
@pytest.mark.parametrize("K", [1, 16])
def test_sigkill_recovery_bit_identical(tmp_path, K):
    """THE acceptance drill: SIGKILL a durable worker mid-window (after
    the WAL append, before the commit), restart it, and diff every
    artifact against an uninterrupted run — completion set, per-request
    done steps, emitted-token CRC, device-carry fingerprint, and the
    request-conservation ledger must all be bit-identical."""
    # seed-chosen kill point: mid-run, not window-aligned for K=16
    kill_at = 9
    ref = _worker(tmp_path / "ref_store", tmp_path / "ref.json", K=K)
    assert ref.returncode == 0, ref.stderr[-3000:]

    crash = _worker(
        tmp_path / "c_store", tmp_path / "c.json", K=K,
        kill_at=kill_at, marker=tmp_path / "marker",
    )
    assert crash.returncode == -9, (
        f"worker did not die by SIGKILL: rc={crash.returncode}\n"
        f"{crash.stderr[-3000:]}"
    )
    assert not (tmp_path / "c.json").exists(), "dead worker wrote results"
    assert (tmp_path / "c_store" / "wal.log").exists()

    restart = _worker(
        tmp_path / "c_store", tmp_path / "c.json", K=K,
        kill_at=kill_at, marker=tmp_path / "marker",  # same cmdline
    )
    assert restart.returncode == 0, restart.stderr[-3000:]

    a = json.loads((tmp_path / "ref.json").read_text())
    b = json.loads((tmp_path / "c.json").read_text())
    for key in ("completions", "done_step", "outputs_crc", "carry_crc",
                "conservation"):
        assert a[key] == b[key], f"{key} diverged after crash+recovery"
    assert b["conservation"]["admitted_ok"]
    assert b["conservation"]["dispatch_ok"]
    dur = b["health"]["durability"]
    assert dur["replayed_windows"] >= 1, "recovery replayed nothing"


@pytest.mark.slow
def test_supervised_worker_survives_crash(tmp_path):
    """End to end: the Supervisor runs the worker, the worker SIGKILLs
    itself mid-window, the supervisor restarts it, and the supervised
    result matches an uninterrupted reference."""
    ref = _worker(tmp_path / "ref_store", tmp_path / "ref.json", K=4)
    assert ref.returncode == 0, ref.stderr[-3000:]

    argv = [
        sys.executable, "-m", "repro.serve.worker",
        "--dir", str(tmp_path / "s_store"), "--out", str(tmp_path / "s.json"),
        "--steps", "24", "--seed", "3", "--window", "4",
        "--snapshot-interval", "3",
        "--sigkill-at-step", "9", "--crash-marker", str(tmp_path / "m"),
    ]
    env = {"PYTHONPATH": str(REPO / "src")}
    sup = Supervisor(
        argv, tmp_path / "s_store" / "heartbeat.json",
        SupervisorConfig(heartbeat_timeout=60.0, startup_timeout=300.0,
                         poll_interval=0.05, backoff_base=0.05),
        env=env,
    )
    rep = sup.run()
    assert rep.outcome == "completed"
    assert rep.restarts == 1 and rep.exit_codes == [-9, 0]
    a = json.loads((tmp_path / "ref.json").read_text())
    b = json.loads((tmp_path / "s.json").read_text())
    assert a["carry_crc"] == b["carry_crc"]
    assert a["completions"] == b["completions"]
