"""Hypothesis-style property tests — the queue's invariants under arbitrary
workloads (paper-level guarantees, machine-checked).

Runs under real `hypothesis` when installed; otherwise a minimal seeded
stand-in below provides the same `given/settings/strategies` surface
(deterministic per-test example streams), so the tier-1 lane never depends
on an optional package.
"""

import zlib

import numpy as np
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # container has no hypothesis — seeded stand-in

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [
                    elem.draw(rng)
                    for _ in range(int(rng.integers(min_size, max_size + 1)))
                ]
            )

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.draw(rng) for e in elems))

    def settings(max_examples=20, deadline=None):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # NOTE: no functools.wraps — copying fn's signature would make
            # pytest treat the strategy params as fixtures.
            def wrapper():
                for ex in range(getattr(wrapper, "_max_examples", 20)):
                    rng = np.random.default_rng(
                        (zlib.crc32(fn.__name__.encode()) << 16) + ex
                    )
                    fn(**{k: s.draw(rng) for k, s in strategies.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 20)
            return wrapper

        return deco


from repro.core.pqueue import ops as O
from repro.core.pqueue.ref import RefPQ
from repro.core.pqueue.schedules import Schedule, multiq_bound, spray_bound
from repro.core.pqueue.state import INF_KEY, check_invariants, make_state

S, C, B = 4, 32, 8  # fixed shapes keep jit cache warm across examples

op_batch = st.lists(
    st.tuples(st.integers(0, 1), st.integers(0, 999)), min_size=1, max_size=6
)


@settings(max_examples=25, deadline=None)
@given(batches=st.lists(op_batch, min_size=1, max_size=5), seed=st.integers(0, 2**20))
def test_strict_linearizes_like_oracle(batches, seed):
    """I3: any interleaving of batched insert/deleteMin matches the oracle's
    inserts-then-deletes linearization, element for element."""
    stq, ref = make_state(S, C), RefPQ(S, C)
    for batch in batches:
        ops = np.array([o for o, _ in batch] + [0] * (B - len(batch)), np.int32)
        keys = np.array([k for _, k in batch] + [INF_KEY] * (B - len(batch)), np.int32)
        # pad lanes are invalid inserts (key == INF)
        r = O.apply_op_batch(
            stq, jnp.asarray(ops), jnp.asarray(keys), jnp.asarray(keys % 97),
            schedule=Schedule.STRICT_FLAT, rng=jax.random.key(seed),
        )
        stq = r.state
        ref.insert_batch(keys, keys % 97, mask=(ops == 0) & (keys < INF_KEY))
        rk, _ = ref.delete_min_exact(int(((ops == 1)).sum()))
        np.testing.assert_array_equal(
            np.asarray(r.deleted_keys)[: int(r.n_deleted)], rk
        )
        ok, msg = check_invariants(stq)
        assert ok, msg
    np.testing.assert_array_equal(
        np.sort(np.asarray(stq.keys[stq.keys < INF_KEY]).ravel()),
        ref.key_multiset(),
    )


@settings(max_examples=25, deadline=None)
@given(
    keys=st.lists(st.integers(0, 999), min_size=8, max_size=40),
    m_del=st.integers(1, B),
    seed=st.integers(0, 2**20),
)
def test_spray_envelope(keys, m_del, seed):
    """Every spray-returned key ranks within spray_bound(S, m) of the head,
    and the multiset is conserved."""
    stq, ref = make_state(S, C), RefPQ(S, C)
    arr = np.asarray(keys[: 4 * B], np.int32)
    for i in range(0, len(arr), B):
        chunk = arr[i : i + B]
        pad = np.full(B - len(chunk), INF_KEY, np.int32)
        kb = np.concatenate([chunk, pad])
        stq, _ = O.insert(stq, jnp.asarray(kb), jnp.asarray(kb % 97))
        ref.insert_batch(kb, kb % 97)
    res = O.delete_min(
        stq, B, schedule=Schedule.SPRAY_HERLIHY, active=m_del,
        rng=jax.random.key(seed),
    )
    got = np.asarray(res.keys)[: int(res.n_out)]
    ok, msg = ref.check_spray_result(got, B)
    assert ok, msg
    assert ref.remove_multiset(got)
    rem = np.sort(np.asarray(res.state.keys[res.state.keys < INF_KEY]).ravel())
    np.testing.assert_array_equal(rem, ref.key_multiset())


@settings(max_examples=25, deadline=None)
@given(
    keys=st.lists(st.integers(0, 999), min_size=8, max_size=40),
    m_del=st.integers(1, B),
    seed=st.integers(0, 2**20),
)
def test_multiq_envelope(keys, m_del, seed):
    """Every MULTIQ-returned key sits within the first m entries of some
    shard (deterministic two-choice window), and the multiset is conserved."""
    stq, ref = make_state(S, C), RefPQ(S, C)
    arr = np.asarray(keys[: 4 * B], np.int32)
    for i in range(0, len(arr), B):
        chunk = arr[i : i + B]
        kb = np.concatenate([chunk, np.full(B - len(chunk), INF_KEY, np.int32)])
        stq, _ = O.insert(stq, jnp.asarray(kb), jnp.asarray(kb % 97))
        ref.insert_batch(kb, kb % 97)
    res = O.delete_min(
        stq, B, schedule=Schedule.MULTIQ, active=m_del,
        rng=jax.random.key(seed),
    )
    got = np.asarray(res.keys)[: int(res.n_out)]
    ok, msg = ref.check_multiq_result(got, B)
    assert ok, msg
    assert ref.remove_multiset(got)
    rem = np.sort(np.asarray(res.state.keys[res.state.keys < INF_KEY]).ravel())
    np.testing.assert_array_equal(rem, ref.key_multiset())
    ok, msg = check_invariants(res.state)
    assert ok, msg


@settings(max_examples=15, deadline=None)
@given(
    batches=st.lists(op_batch, min_size=2, max_size=5),
    seed=st.integers(0, 2**20),
)
def test_no_loss_or_duplication_across_schedules(batches, seed):
    """I3 across ALL THREE SmartPQ modes: drive the identical randomized op
    stream (same seeds) through spray, multiq, and hier; each run must
    conserve the element multiset exactly — everything inserted is either
    still in the queue or was returned by a deleteMin, no key lost, none
    duplicated.  The three runs are independent (relaxed schedules remove
    different elements) but every one must balance its own books."""
    for schedule in (Schedule.SPRAY_HERLIHY, Schedule.MULTIQ, Schedule.HIER):
        stq = make_state(S, C)
        inserted, deleted = [], []
        for step, batch in enumerate(batches):
            ops = np.array([o for o, _ in batch] + [1] * (B - len(batch)), np.int32)
            keys = np.array(
                [k for _, k in batch] + [INF_KEY] * (B - len(batch)), np.int32
            )
            r = O.apply_op_batch(
                stq, jnp.asarray(ops), jnp.asarray(keys), jnp.asarray(keys % 97),
                schedule=schedule, rng=jax.random.key(seed + step), npods=2,
            )
            stq = r.state
            inserted.extend(keys[(ops == 0) & (keys < INF_KEY)].tolist())
            got = np.asarray(r.deleted_keys)[: int(r.n_deleted)]
            deleted.extend(got.tolist())
            ok, msg = check_invariants(stq)
            assert ok, f"{schedule.name}: {msg}"
        remaining = np.asarray(stq.keys[stq.keys < INF_KEY]).ravel().tolist()
        np.testing.assert_array_equal(
            np.sort(np.asarray(deleted + remaining)),
            np.sort(np.asarray(inserted)),
            err_msg=f"{schedule.name}: element loss or duplication",
        )


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 60), seed=st.integers(0, 2**20))
def test_delete_all_returns_sorted(n, seed):
    """Draining the whole queue with exact deletes yields a global sort."""
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, 500, n).astype(np.int32)
    stq = make_state(S, C)
    for i in range(0, n, B):
        chunk = arr[i : i + B]
        kb = np.concatenate([chunk, np.full(B - len(chunk), INF_KEY, np.int32)])
        stq, _ = O.insert(stq, jnp.asarray(kb), jnp.asarray(kb))
    out = []
    for _ in range(-(-n // B)):
        res = O.delete_min(stq, B, schedule=Schedule.STRICT_FLAT, active=B)
        stq = res.state
        out.extend(np.asarray(res.keys)[: int(res.n_out)].tolist())
    np.testing.assert_array_equal(np.asarray(out), np.sort(arr))
    assert int(stq.total_size) == 0


# -- tiered head/tail layout: I4 (boundary) + I5 (staging accounting) --------
#
# H < C below forces real head/tail traffic: boundary splits, spills,
# cond-guarded refills — the paths the default-H tests (H == C, tail width 0)
# never exercise.

H_TIER, C_TIER = 8, 64

import functools


@functools.lru_cache(maxsize=None)
def _tier_step(schedule):
    """Jitted fixed-shape op-batch step — keeps the example sweep on the
    compiled path (one compile per schedule)."""

    @jax.jit
    def step(state, ops, keys, vals, rng):
        return O.apply_op_batch(
            state, ops, keys, vals, schedule=schedule, rng=rng, npods=2
        )

    return step


_tier_insert = jax.jit(O.insert)


@functools.lru_cache(maxsize=None)
def _tier_delete(schedule):
    @jax.jit
    def d(state, rng):
        return O.delete_min(state, B, schedule=schedule, active=B, rng=rng)

    return d


@settings(max_examples=12, deadline=None)
@given(batches=st.lists(op_batch, min_size=2, max_size=6), seed=st.integers(0, 2**20))
def test_tiered_exact_bitmatches_oracle(batches, seed):
    """With the tail arena active (H=8 < C=64), STRICT_FLAT still linearizes
    like the oracle ELEMENT FOR ELEMENT — keys and vals — across insert
    splits, spills, and refills (the I4 seq-ordering guarantee)."""
    stq, ref = make_state(S, C_TIER, head_width=H_TIER), RefPQ(S, C_TIER)
    rng = np.random.default_rng(seed)
    for batch in batches:
        ops = np.array([o for o, _ in batch] + [0] * (B - len(batch)), np.int32)
        keys = np.array([k for _, k in batch] + [INF_KEY] * (B - len(batch)), np.int32)
        vals = rng.integers(0, 100, B).astype(np.int32)
        r = _tier_step(Schedule.STRICT_FLAT)(
            stq, jnp.asarray(ops), jnp.asarray(keys), jnp.asarray(vals),
            jax.random.key(seed),
        )
        stq = r.state
        ref.insert_batch(keys, vals, mask=(ops == 0) & (keys < INF_KEY))
        rk, rv = ref.delete_min_exact(int(((ops == 1)).sum()))
        n = int(r.n_deleted)
        np.testing.assert_array_equal(np.asarray(r.deleted_keys)[:n], rk)
        np.testing.assert_array_equal(np.asarray(r.deleted_vals)[:n], rv)
        ok, msg = check_invariants(stq)
        assert ok, msg
    np.testing.assert_array_equal(
        np.sort(np.asarray(stq.keys[stq.keys < INF_KEY]).ravel()),
        ref.key_multiset(),
    )


@settings(max_examples=8, deadline=None)
@given(
    batches=st.lists(op_batch, min_size=2, max_size=5),
    seed=st.integers(0, 2**20),
)
def test_tier_invariants_all_schedules(batches, seed):
    """I4/I5 hold after every op batch of every SmartPQ mode when the tail
    arena is active, and each run conserves its element multiset (I3)."""
    for schedule in (Schedule.SPRAY_HERLIHY, Schedule.MULTIQ, Schedule.HIER,
                     Schedule.LOCAL):
        stq = make_state(S, C_TIER, head_width=H_TIER)
        inserted, deleted = [], []
        for step, batch in enumerate(batches):
            ops = np.array([o for o, _ in batch] + [1] * (B - len(batch)), np.int32)
            keys = np.array(
                [k for _, k in batch] + [INF_KEY] * (B - len(batch)), np.int32
            )
            r = _tier_step(schedule)(
                stq, jnp.asarray(ops), jnp.asarray(keys),
                jnp.asarray(keys % 97), jax.random.key(seed + step),
            )
            stq = r.state
            inserted.extend(keys[(ops == 0) & (keys < INF_KEY)].tolist())
            deleted.extend(
                np.asarray(r.deleted_keys)[: int(r.n_deleted)].tolist()
            )
            ok, msg = check_invariants(stq)
            assert ok, f"{schedule.name}: {msg}"
        remaining = np.asarray(stq.keys[stq.keys < INF_KEY]).ravel().tolist()
        np.testing.assert_array_equal(
            np.sort(np.asarray(deleted + remaining)),
            np.sort(np.asarray(inserted)),
            err_msg=f"{schedule.name}: element loss or duplication",
        )


@settings(max_examples=6, deadline=None)
@given(n=st.integers(30, 120), seed=st.integers(0, 2**20))
def test_tiered_drain_returns_sorted(n, seed):
    """Draining a tiered queue (repeated refills) yields the global sort."""
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, 500, n).astype(np.int32)
    stq = make_state(S, C_TIER, head_width=H_TIER)
    for i in range(0, n, B):
        chunk = arr[i : i + B]
        kb = np.concatenate([chunk, np.full(B - len(chunk), INF_KEY, np.int32)])
        stq, _ = _tier_insert(stq, jnp.asarray(kb), jnp.asarray(kb))
    out = []
    for _ in range(-(-n // B)):
        res = _tier_delete(Schedule.STRICT_FLAT)(stq, jax.random.key(0))
        stq = res.state
        out.extend(np.asarray(res.keys)[: int(res.n_out)].tolist())
        ok, msg = check_invariants(stq)
        assert ok, msg
    np.testing.assert_array_equal(np.asarray(out), np.sort(arr))
    assert int(stq.total_size) == 0


def test_tiered_capacity_overflow_drops_largest():
    """The cond-guarded overflow branch keeps the C smallest of the union
    and reports the rest — same accounting as the classic merge."""
    stq = make_state(2, 8, head_width=4)  # C=8 per shard, tail arena of 4
    keys = jnp.arange(64, dtype=jnp.int32)
    stq, dropped = O.insert(stq, keys, jnp.zeros(64, jnp.int32))
    assert int(stq.total_size) == 16
    assert int(jnp.sum(dropped)) == 64 - 16
    ok, msg = check_invariants(stq)
    assert ok, msg
    # the survivors are the 8 smallest routed to each shard
    kept = np.sort(np.asarray(stq.keys[stq.keys < INF_KEY]).ravel())
    from repro.utils.hashing import shard_of_key

    dest = np.asarray(shard_of_key(keys, 2))
    want = np.sort(np.concatenate(
        [np.sort(np.arange(64)[dest == s])[:8] for s in range(2)]
    ))
    np.testing.assert_array_equal(kept, want)


def test_spray_bound_monotone():
    for m in (1, 8, 64):
        prev = 0
        for S_ in (2, 4, 16, 64, 256):
            b = spray_bound(S_, m)
            assert b >= prev or b >= m
            prev = b


def test_multiq_bound_tighter_than_spray():
    """The two-choice envelope is never looser than the spray envelope, and
    asymptotically much tighter (the S log^2 S vs S log log S gap)."""
    for m in (1, 8, 64, 512):
        for S_ in (2, 4, 16, 64, 256, 1024):
            assert multiq_bound(S_, m) <= spray_bound(S_, m)
        assert multiq_bound(1024, m) * 4 < spray_bound(1024, m)
