"""repro.faults — deterministic fault injection for the PQ/serving stack.

SmartPQ's pitch is staying fast *and correct* "under all various contention
scenarios"; this harness manufactures the scenarios the happy path never
produces.  Every injector is a pure-ish transform behind a seed-driven
`FaultSpec`, so a chaos test is just: build the healthy object, inject,
drive it, and assert the contract — either the stack absorbs the fault
(sanitization, clamping, backlog spill, window rollback) or it surfaces a
typed error from `repro.core.errors`.  Silent corruption is the only
forbidden outcome, and `tests/test_hygiene.py` asserts every registered
injector is exercised by at least one test.

Injector domains (heterogeneous by design — faults enter at different
layers):

  name                 injects into            adversarial condition
  -------------------------------------------------------------------------
  nonfinite_keys       workloads.traces.Trace  NaN/±inf priority keys on
                                               insert lanes (float batch)
  duplicate_keys       workloads.traces.Trace  equal-key storms across lanes
  ring_overflow_storm  serve workload          arrivals compressed into
                       (List[List[Request]])   bursts of >= ring capacity
  corrupt_trace_npz    saved npz path          truncated / bit-flipped file
  oob_tree_class       SmartPQ                 packed tree emitting classes
                                               outside [0, NUM_CLASSES)
  forecast_extreme     ServeEngine             service-time estimate pinned
                                               to a pathological extreme
  corrupt_state        SmartPQCarry            head tier scrambled (I1/I2
                                               violations) — the rollback
                                               drill's trigger
  validator_tripwire   (none — returns a hook) validation reports a
                                               synthetic violation N times,
                                               then heals — exercises the
                                               rollback+retry SUCCESS path
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import numpy as np

from repro.core.errors import InvariantViolation
from repro.core.pqueue.ops import OP_INSERT
from repro.core.pqueue.state import INF_KEY


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection, fully determined by (kind, seed, rate, magnitude,
    variant) — the same spec always produces the same fault."""

    kind: str
    seed: int = 0
    rate: float = 0.25  # fraction of lanes/steps/bytes affected
    magnitude: float = 1.0  # injector-specific scale (storm factor, trips)
    variant: str = ""  # injector-specific discriminator


INJECTORS: Dict[str, Callable] = {}


def _injector(name: str):
    def reg(fn):
        INJECTORS[name] = fn
        return fn

    return reg


def inject(target, spec: FaultSpec):
    """Dispatch `target` through the injector `spec.kind` names."""
    if spec.kind not in INJECTORS:
        raise KeyError(
            f"unknown fault kind {spec.kind!r}; registered: "
            f"{sorted(INJECTORS)}"
        )
    return INJECTORS[spec.kind](target, spec)


# ---------------------------------------------------------------------------
# trace-level injectors
# ---------------------------------------------------------------------------


@_injector("nonfinite_keys")
def nonfinite_keys(trace, spec: FaultSpec):
    """Poison a `Trace` with non-finite float priority keys.

    Returns an in-memory Trace whose ``keys`` array is float32 with a
    `spec.rate` fraction of insert lanes set to NaN/+inf/-inf (cycled).
    The admission boundary (`ops.sanitize_keys`, run by `SmartPQ.step` /
    `run_window` on float batches) must reject exactly those lanes into
    `stats.rejected` — IEEE sort order never reaches the queue."""
    rng = np.random.default_rng(spec.seed)
    keys = trace.keys.astype(np.float32)
    ins = trace.ops == OP_INSERT
    hit = ins & (rng.random(trace.ops.shape) < spec.rate)
    fills = np.array([np.nan, np.inf, -np.inf], np.float32)
    keys[hit] = fills[np.arange(int(hit.sum())) % 3]
    return trace._replace(keys=keys)


@_injector("duplicate_keys")
def duplicate_keys(trace, spec: FaultSpec):
    """Equal-key storm: a `spec.rate` fraction of insert lanes copy the key
    of another (seed-chosen) insert lane of the same step.  Duplicates are
    legal inputs — the per-shard seq tiebreak must keep the linearization
    stable and every invariant intact; this injector exists to prove the
    path at adversarial density, not to trigger an error."""
    rng = np.random.default_rng(spec.seed)
    keys = trace.keys.copy()
    for t in range(trace.ops.shape[0]):
        lanes = np.flatnonzero(trace.ops[t] == OP_INSERT)
        if lanes.size < 2:
            continue
        victims = lanes[rng.random(lanes.size) < spec.rate]
        if victims.size:
            sources = rng.choice(lanes, victims.size)
            keys[t, victims] = keys[t, sources]
    return trace._replace(keys=keys)


@_injector("corrupt_trace_npz")
def corrupt_trace_npz(path, spec: FaultSpec):
    """Damage a saved trace npz on disk: ``variant='truncate'`` keeps only
    the leading `1 - rate` fraction of the file; ``variant='flip'`` XORs
    random bytes in the middle.  `traces.load_trace` must surface a typed
    `TraceCorruptError` — never a half-loaded trace."""
    from pathlib import Path

    rng = np.random.default_rng(spec.seed)
    p = Path(path)
    blob = bytearray(p.read_bytes())
    if spec.variant == "flip":
        n = max(int(len(blob) * spec.rate), 1)
        for i in rng.integers(len(blob) // 4, len(blob), n):
            blob[int(i)] ^= 0xFF
        p.write_bytes(bytes(blob))
    else:  # truncate
        keep = max(int(len(blob) * (1.0 - spec.rate)), 16)
        p.write_bytes(bytes(blob[:keep]))
    return p


# ---------------------------------------------------------------------------
# serving-workload injectors
# ---------------------------------------------------------------------------


@_injector("ring_overflow_storm")
def ring_overflow_storm(workload, spec: FaultSpec):
    """Compress an open-loop serve workload's arrivals into periodic storms.

    Every `1/rate` steps, all requests that would have arrived over the
    inter-storm span (scaled by `magnitude`, repeating requests with fresh
    uids when magnitude > 1) land in ONE step — sized to blow past the
    admission ring so the host backlog spill + bounded-backlog shed paths
    run.  Steps between storms are empty."""
    period = max(int(round(1.0 / max(spec.rate, 1e-6))), 1)
    flat = [r for step in workload for r in step]
    out: List[List] = [[] for _ in workload]
    if not flat:
        return out
    uid_next = max(r.uid for r in flat) + 1
    reps = max(int(round(spec.magnitude)), 1)
    for t in range(0, len(workload), period):
        lo = (t // period) * len(flat) // ((len(workload) + period - 1)
                                           // period)
        hi = (t // period + 1) * len(flat) // ((len(workload) + period - 1)
                                               // period)
        storm = []
        for rep in range(reps):
            for r in flat[lo:hi]:
                if rep == 0:
                    storm.append(dataclasses.replace(r, arrival_step=t))
                else:
                    storm.append(dataclasses.replace(
                        r, uid=uid_next, arrival_step=t
                    ))
                    uid_next += 1
        out[t] = storm
    return out


@_injector("forecast_extreme")
def forecast_extreme(engine, spec: FaultSpec):
    """Pin the engine's service-time EMA to a pathological extreme:
    ``variant='low'`` (estimate ~0 -> the forecast over-admits maximally,
    flooding the admit backlog), anything else -> `magnitude` steps
    (under-admission starvation when huge).  Correctness must never depend
    on the forecast — every request still completes."""
    engine._service_est = 1e-6 if spec.variant == "low" else float(
        max(spec.magnitude, 1.0)
    )
    return engine


# ---------------------------------------------------------------------------
# core-state / classifier injectors
# ---------------------------------------------------------------------------


@_injector("oob_tree_class")
def oob_tree_class(pq, spec: FaultSpec):
    """Corrupt the packed decision tree so inference emits classes outside
    [0, NUM_CLASSES): alternating negative and huge labels on a `rate`
    fraction of nodes (seeded).  The step's keep-rule + pre-switch clamp
    must degrade this to a valid mode — never an out-of-range
    `lax.switch` branch."""
    import jax.numpy as jnp

    rng = np.random.default_rng(spec.seed)
    label = np.asarray(pq.packed.label).copy()
    hit = rng.random(label.shape) < spec.rate
    if not hit.any():
        hit[rng.integers(label.size)] = True
    n = int(hit.sum())
    label[hit] = np.where(np.arange(n) % 2 == 0, -3, 1 << 20)
    pq.packed = pq.packed._replace(label=jnp.asarray(label))
    return pq


@_injector("corrupt_state")
def corrupt_state(carry, spec: FaultSpec):
    """Scramble one shard's hot head tier in a `SmartPQCarry`: reverse the
    head prefix when the shard is non-empty (breaks I1's ascending order),
    or plant a finite key in the INF padding of an empty shard (breaks I2).
    The `SmartPQConfig.validate` guard tier must detect it; the scheduler's
    window recovery must roll back and — since the corruption predates the
    checkpoint — surface a typed `WindowValidationError`."""
    import jax.numpy as jnp

    rng = np.random.default_rng(spec.seed)
    hk = np.asarray(carry.state.head_keys).copy()
    hs = np.asarray(carry.state.head_size)
    s = int(rng.integers(hk.shape[0]))
    n = int(hs[s])
    if n >= 2:
        hk[s, :n] = hk[s, :n][::-1]
        if hk[s, 0] == hk[s, n - 1]:  # all-equal prefix: force descent
            hk[s, 0] = hk[s, n - 1] + 1
    else:
        hk[s, hk.shape[1] - 1] = 5  # finite key inside INF padding (I2)
    return carry._replace(
        state=dataclasses.replace(carry.state, head_keys=jnp.asarray(hk))
    )


@_injector("validator_tripwire")
def validator_tripwire(_target, spec: FaultSpec):
    """Return a validation hook that reports a synthetic violation for the
    first `int(magnitude)` calls, then heals.  Wired into
    `SmartPQScheduler.validate_hook`, it deterministically exercises the
    checkpoint -> rollback -> conservative-retry -> SUCCESS path (a real
    corruption predating the checkpoint can only exercise the error
    path)."""
    trips = max(int(spec.magnitude), 1)
    calls = {"n": 0}

    def hook(_state) -> List[InvariantViolation]:
        calls["n"] += 1
        if calls["n"] <= trips:
            return [InvariantViolation(
                "I0", -1,
                f"injected tripwire ({calls['n']}/{trips})",
            )]
        return []

    return hook


__all__ = [
    "FaultSpec", "INJECTORS", "inject",
    "nonfinite_keys", "duplicate_keys", "corrupt_trace_npz",
    "ring_overflow_storm", "forecast_extreme", "oob_tree_class",
    "corrupt_state", "validator_tripwire",
]
