"""repro.faults — deterministic fault injection for the PQ/serving stack.

SmartPQ's pitch is staying fast *and correct* "under all various contention
scenarios"; this harness manufactures the scenarios the happy path never
produces.  Every injector is a pure-ish transform behind a seed-driven
`FaultSpec`, so a chaos test is just: build the healthy object, inject,
drive it, and assert the contract — either the stack absorbs the fault
(sanitization, clamping, backlog spill, window rollback) or it surfaces a
typed error from `repro.core.errors`.  Silent corruption is the only
forbidden outcome, and `tests/test_hygiene.py` asserts every registered
injector is exercised by at least one test.

Injector domains (heterogeneous by design — faults enter at different
layers):

  name                 injects into            adversarial condition
  -------------------------------------------------------------------------
  nonfinite_keys       workloads.traces.Trace  NaN/±inf priority keys on
                                               insert lanes (float batch)
  duplicate_keys       workloads.traces.Trace  equal-key storms across lanes
  ring_overflow_storm  serve workload          arrivals compressed into
                       (List[List[Request]])   bursts of >= ring capacity
  corrupt_trace_npz    saved npz path          truncated / bit-flipped file
  oob_tree_class       SmartPQ                 packed tree emitting classes
                                               outside [0, NUM_CLASSES)
  forecast_extreme     ServeEngine             service-time estimate pinned
                                               to a pathological extreme
  corrupt_state        SmartPQCarry            head tier scrambled (I1/I2
                                               violations) — the rollback
                                               drill's trigger
  validator_tripwire   (none — returns a hook) validation reports a
                                               synthetic violation N times,
                                               then heals — exercises the
                                               rollback+retry SUCCESS path
  crash_at_step        ServeEngine             SIGKILL the process when the
                                               engine reaches step N (one-
                                               shot via a marker file, so a
                                               supervised restart survives)
  torn_wal             durable dir / wal path  torn tail on the write-ahead
                                               log: truncated mid-frame,
                                               CRC-flipped, or garbage
                                               appended
  partial_snapshot     durable dir / snap root newest snapshot loses or
                                               truncates a payload shard
                                               (crash mid-snapshot-write)
  stale_manifest       durable dir / snap root manifest damaged or LATEST
                                               pointing at a step that is
                                               not on disk
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import numpy as np

from repro.core.errors import InvariantViolation
from repro.core.pqueue.ops import OP_INSERT
from repro.core.pqueue.state import INF_KEY


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One injection, fully determined by (kind, seed, rate, magnitude,
    variant) — the same spec always produces the same fault."""

    kind: str
    seed: int = 0
    rate: float = 0.25  # fraction of lanes/steps/bytes affected
    magnitude: float = 1.0  # injector-specific scale (storm factor, trips)
    variant: str = ""  # injector-specific discriminator


INJECTORS: Dict[str, Callable] = {}


def _injector(name: str):
    def reg(fn):
        INJECTORS[name] = fn
        return fn

    return reg


def inject(target, spec: FaultSpec):
    """Dispatch `target` through the injector `spec.kind` names."""
    if spec.kind not in INJECTORS:
        raise KeyError(
            f"unknown fault kind {spec.kind!r}; registered: "
            f"{sorted(INJECTORS)}"
        )
    return INJECTORS[spec.kind](target, spec)


# ---------------------------------------------------------------------------
# trace-level injectors
# ---------------------------------------------------------------------------


@_injector("nonfinite_keys")
def nonfinite_keys(trace, spec: FaultSpec):
    """Poison a `Trace` with non-finite float priority keys.

    Returns an in-memory Trace whose ``keys`` array is float32 with a
    `spec.rate` fraction of insert lanes set to NaN/+inf/-inf (cycled).
    The admission boundary (`ops.sanitize_keys`, run by `SmartPQ.step` /
    `run_window` on float batches) must reject exactly those lanes into
    `stats.rejected` — IEEE sort order never reaches the queue."""
    rng = np.random.default_rng(spec.seed)
    keys = trace.keys.astype(np.float32)
    ins = trace.ops == OP_INSERT
    hit = ins & (rng.random(trace.ops.shape) < spec.rate)
    fills = np.array([np.nan, np.inf, -np.inf], np.float32)
    keys[hit] = fills[np.arange(int(hit.sum())) % 3]
    return trace._replace(keys=keys)


@_injector("duplicate_keys")
def duplicate_keys(trace, spec: FaultSpec):
    """Equal-key storm: a `spec.rate` fraction of insert lanes copy the key
    of another (seed-chosen) insert lane of the same step.  Duplicates are
    legal inputs — the per-shard seq tiebreak must keep the linearization
    stable and every invariant intact; this injector exists to prove the
    path at adversarial density, not to trigger an error."""
    rng = np.random.default_rng(spec.seed)
    keys = trace.keys.copy()
    for t in range(trace.ops.shape[0]):
        lanes = np.flatnonzero(trace.ops[t] == OP_INSERT)
        if lanes.size < 2:
            continue
        victims = lanes[rng.random(lanes.size) < spec.rate]
        if victims.size:
            sources = rng.choice(lanes, victims.size)
            keys[t, victims] = keys[t, sources]
    return trace._replace(keys=keys)


@_injector("corrupt_trace_npz")
def corrupt_trace_npz(path, spec: FaultSpec):
    """Damage a saved trace npz on disk: ``variant='truncate'`` keeps only
    the leading `1 - rate` fraction of the file; ``variant='flip'`` XORs
    random bytes in the middle.  `traces.load_trace` must surface a typed
    `TraceCorruptError` — never a half-loaded trace."""
    from pathlib import Path

    rng = np.random.default_rng(spec.seed)
    p = Path(path)
    blob = bytearray(p.read_bytes())
    if spec.variant == "flip":
        n = max(int(len(blob) * spec.rate), 1)
        for i in rng.integers(len(blob) // 4, len(blob), n):
            blob[int(i)] ^= 0xFF
        p.write_bytes(bytes(blob))
    else:  # truncate
        keep = max(int(len(blob) * (1.0 - spec.rate)), 16)
        p.write_bytes(bytes(blob[:keep]))
    return p


# ---------------------------------------------------------------------------
# serving-workload injectors
# ---------------------------------------------------------------------------


@_injector("ring_overflow_storm")
def ring_overflow_storm(workload, spec: FaultSpec):
    """Compress an open-loop serve workload's arrivals into periodic storms.

    Every `1/rate` steps, all requests that would have arrived over the
    inter-storm span (scaled by `magnitude`, repeating requests with fresh
    uids when magnitude > 1) land in ONE step — sized to blow past the
    admission ring so the host backlog spill + bounded-backlog shed paths
    run.  Steps between storms are empty."""
    period = max(int(round(1.0 / max(spec.rate, 1e-6))), 1)
    flat = [r for step in workload for r in step]
    out: List[List] = [[] for _ in workload]
    if not flat:
        return out
    uid_next = max(r.uid for r in flat) + 1
    reps = max(int(round(spec.magnitude)), 1)
    for t in range(0, len(workload), period):
        lo = (t // period) * len(flat) // ((len(workload) + period - 1)
                                           // period)
        hi = (t // period + 1) * len(flat) // ((len(workload) + period - 1)
                                               // period)
        storm = []
        for rep in range(reps):
            for r in flat[lo:hi]:
                if rep == 0:
                    storm.append(dataclasses.replace(r, arrival_step=t))
                else:
                    storm.append(dataclasses.replace(
                        r, uid=uid_next, arrival_step=t
                    ))
                    uid_next += 1
        out[t] = storm
    return out


@_injector("forecast_extreme")
def forecast_extreme(engine, spec: FaultSpec):
    """Pin the engine's service-time EMA to a pathological extreme:
    ``variant='low'`` (estimate ~0 -> the forecast over-admits maximally,
    flooding the admit backlog), anything else -> `magnitude` steps
    (under-admission starvation when huge).  Correctness must never depend
    on the forecast — every request still completes."""
    engine._service_est = 1e-6 if spec.variant == "low" else float(
        max(spec.magnitude, 1.0)
    )
    return engine


# ---------------------------------------------------------------------------
# core-state / classifier injectors
# ---------------------------------------------------------------------------


@_injector("oob_tree_class")
def oob_tree_class(pq, spec: FaultSpec):
    """Corrupt the packed decision tree so inference emits classes outside
    [0, NUM_CLASSES): alternating negative and huge labels on a `rate`
    fraction of nodes (seeded).  The step's keep-rule + pre-switch clamp
    must degrade this to a valid mode — never an out-of-range
    `lax.switch` branch."""
    import jax.numpy as jnp

    rng = np.random.default_rng(spec.seed)
    label = np.asarray(pq.packed.label).copy()
    hit = rng.random(label.shape) < spec.rate
    if not hit.any():
        hit[rng.integers(label.size)] = True
    n = int(hit.sum())
    label[hit] = np.where(np.arange(n) % 2 == 0, -3, 1 << 20)
    pq.packed = pq.packed._replace(label=jnp.asarray(label))
    return pq


@_injector("corrupt_state")
def corrupt_state(carry, spec: FaultSpec):
    """Scramble one shard's hot head tier in a `SmartPQCarry`: reverse the
    head prefix when the shard is non-empty (breaks I1's ascending order),
    or plant a finite key in the INF padding of an empty shard (breaks I2).
    The `SmartPQConfig.validate` guard tier must detect it; the scheduler's
    window recovery must roll back and — since the corruption predates the
    checkpoint — surface a typed `WindowValidationError`."""
    import jax.numpy as jnp

    rng = np.random.default_rng(spec.seed)
    hk = np.asarray(carry.state.head_keys).copy()
    hs = np.asarray(carry.state.head_size)
    s = int(rng.integers(hk.shape[0]))
    n = int(hs[s])
    if n >= 2:
        hk[s, :n] = hk[s, :n][::-1]
        if hk[s, 0] == hk[s, n - 1]:  # all-equal prefix: force descent
            hk[s, 0] = hk[s, n - 1] + 1
    else:
        hk[s, hk.shape[1] - 1] = 5  # finite key inside INF padding (I2)
    return carry._replace(
        state=dataclasses.replace(carry.state, head_keys=jnp.asarray(hk))
    )


@_injector("validator_tripwire")
def validator_tripwire(_target, spec: FaultSpec):
    """Return a validation hook that reports a synthetic violation for the
    first `int(magnitude)` calls, then heals.  Wired into
    `SmartPQScheduler.validate_hook`, it deterministically exercises the
    checkpoint -> rollback -> conservative-retry -> SUCCESS path (a real
    corruption predating the checkpoint can only exercise the error
    path)."""
    trips = max(int(spec.magnitude), 1)
    calls = {"n": 0}

    def hook(_state) -> List[InvariantViolation]:
        calls["n"] += 1
        if calls["n"] <= trips:
            return [InvariantViolation(
                "I0", -1,
                f"injected tripwire ({calls['n']}/{trips})",
            )]
        return []

    return hook


# ---------------------------------------------------------------------------
# durability injectors (serve/durability.py + core/persist.py)
# ---------------------------------------------------------------------------


def _durable_paths(target):
    """Resolve a crash-injection target to (wal_path, snapshots_root):
    accepts a DurableStore, a durable directory, or a direct file path."""
    from pathlib import Path

    if hasattr(target, "wal") and hasattr(target, "snap_root"):
        return Path(target.wal.path), Path(target.snap_root)
    p = Path(target)
    if p.is_dir():
        return p / "wal.log", p / "snapshots"
    return p, p.parent / "snapshots"


@_injector("crash_at_step")
def crash_at_step(engine, spec: FaultSpec):
    """Arm a process-suicide tripwire: the wrapped `engine.step` SIGKILLs
    the process the moment the engine-step clock reaches
    ``int(spec.magnitude)`` — after that window's arrivals were WAL-logged
    but before its commit, i.e. exactly the torn mid-window crash the
    recovery path must absorb.  ``spec.variant``, when set, is a marker
    file path making the kill ONE-SHOT: the marker is written (and
    fsynced) immediately before the SIGKILL, so under a supervisor the
    restarted incarnation re-arms the injector, finds the marker, and
    runs through cleanly — the crash-drill harness in one injector."""
    import os
    import signal

    kill_at = max(int(spec.magnitude), 0)
    marker = spec.variant or None
    orig = engine.step

    def step(arrivals, dispatched=None):
        if engine._step >= kill_at:
            if marker is None or not os.path.exists(marker):
                if marker is not None:
                    from repro.core import persist

                    persist.atomic_write_text(marker, "crashed\n")
                os.kill(os.getpid(), signal.SIGKILL)
        return orig(arrivals, dispatched)

    engine.step = step
    return engine


@_injector("torn_wal")
def torn_wal(target, spec: FaultSpec):
    """Tear the write-ahead log's tail the way a crash mid-append does.
    ``variant='flip'`` XORs one byte inside the LAST frame's payload (CRC
    mismatch); ``variant='garbage'`` appends a frame header whose length
    promises bytes that never made it to disk; default truncates a
    `spec.rate` fraction of the final frame.  `WriteAheadLog.recover` must
    return the intact record prefix and truncate the file — never raise,
    never yield a half-parsed record."""
    import struct

    rng = np.random.default_rng(spec.seed)
    wal_path, _ = _durable_paths(target)
    blob = bytearray(wal_path.read_bytes())
    if spec.variant == "garbage":
        blob += struct.pack("<II", 1 << 20, 0xDEADBEEF) + b"\x00" * 7
    elif spec.variant == "flip" and len(blob) > 8:
        blob[len(blob) - 1 - int(rng.integers(min(len(blob) - 8, 16)))] ^= 0xFF
    else:
        # walk frames to find the last one, cut inside it
        off, frames = 0, []
        while off + 8 <= len(blob):
            length = struct.unpack_from("<I", blob, off)[0]
            if off + 8 + length > len(blob):
                break
            frames.append((off, 8 + length))
            off += 8 + length
        if frames:
            start, size = frames[-1]
            cut = start + max(int(size * (1.0 - spec.rate)), 1)
            del blob[cut:]
    wal_path.write_bytes(bytes(blob))
    return wal_path


@_injector("partial_snapshot")
def partial_snapshot(target, spec: FaultSpec):
    """Damage the NEWEST snapshot's payload: ``variant='delete'`` removes a
    seed-chosen shard npz, default truncates it to the leading `1 - rate`
    fraction (the torn write a non-atomic snapshot would leave).
    `validate_step` must raise `SnapshotCorruptError` for this step and
    `load_newest_valid` must fall back to an older snapshot (or fresh
    init) with `snapshots_skipped_invalid` accounting."""
    from repro.core import persist

    rng = np.random.default_rng(spec.seed)
    _, snap_root = _durable_paths(target)
    steps = persist.available_steps(snap_root)
    if not steps:
        raise FileNotFoundError(f"no snapshots under {snap_root}")
    d = persist.step_dir(snap_root, steps[0])
    shards = sorted(d.glob("shard_*.npz"))
    victim = shards[int(rng.integers(len(shards)))]
    if spec.variant == "delete":
        victim.unlink()
    else:
        blob = victim.read_bytes()
        victim.write_bytes(blob[: max(int(len(blob) * (1 - spec.rate)), 8)])
    return d


@_injector("stale_manifest")
def stale_manifest(target, spec: FaultSpec):
    """Damage snapshot METADATA rather than payload: ``variant='garbage'``
    overwrites the newest step's manifest.json with unparseable bytes;
    default rewrites LATEST to point at a step that does not exist on
    disk.  Recovery must shrug — scan the remaining steps newest-first
    and load the newest one that validates."""
    _, snap_root = _durable_paths(target)
    if spec.variant == "garbage":
        from repro.core import persist

        steps = persist.available_steps(snap_root)
        if not steps:
            raise FileNotFoundError(f"no snapshots under {snap_root}")
        d = persist.step_dir(snap_root, steps[0])
        (d / "manifest.json").write_text("{torn json" + "\x00" * 16)
        return d
    latest = snap_root / "LATEST"
    latest.write_text(f"step_{10**9 + spec.seed}")
    return latest


__all__ = [
    "FaultSpec", "INJECTORS", "inject",
    "nonfinite_keys", "duplicate_keys", "corrupt_trace_npz",
    "ring_overflow_storm", "forecast_extreme", "oob_tree_class",
    "corrupt_state", "validator_tripwire",
    "crash_at_step", "torn_wal", "partial_snapshot", "stale_manifest",
]
