"""Continuous-batching scheduler built on SmartPQ — the paper's technique as
a first-class serving feature.

Every pending request lives in the adaptive priority queue keyed by

    priority_key = slo_class << 28 | arrival_order ... (smaller = sooner)

Each engine step:
  arrivals  -> insert batch          (insert-dominated under bursts)
  dispatch  -> delete_min batch      (deleteMin-dominated under backlog)

which is EXACTLY the contention profile the paper's classifier switches on:
bursty arrival phases run the queue in NUMA-oblivious (spray) mode; drain
phases flip it to the NUMA-aware (hierarchical delegation) mode.  The queue
state itself is device-resident; the scheduler host loop only moves compact
request descriptors — the ffwd cache-line analogue.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pqueue.state import INF_KEY
from repro.core.smartpq import SmartPQ, SmartPQConfig
from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT


@dataclasses.dataclass
class Request:
    uid: int
    prompt_len: int
    max_new_tokens: int
    slo_class: int = 1  # 0 = interactive, 1 = standard, 2 = batch
    arrival_step: int = 0
    tokens_done: int = 0

    def priority_key(self, step: int) -> int:
        # slo-major, then arrival order (FIFO within class); headroom-aware
        # boost for requests close to completion (frees KV pages sooner).
        age = max(step - self.arrival_step, 0)
        key = (self.slo_class << 27) + max(self.prompt_len - 4 * age, 0)
        return int(min(key, INF_KEY - 1))


@dataclasses.dataclass
class SchedulerStats:
    inserted: int = 0
    dispatched: int = 0
    rejected: int = 0
    mode_trace: List[int] = dataclasses.field(default_factory=list)


class SmartPQScheduler:
    """Host-side continuous batching driver over the device-resident PQ."""

    def __init__(
        self,
        batch_size: int,
        pq_config: Optional[SmartPQConfig] = None,
        seed: int = 0,
    ):
        from repro.core.smartpq import MODE_AWARE

        self.batch = batch_size
        # Start in the exact (Nuddle) mode: a near-empty queue must respect
        # SLO order strictly; the classifier relaxes to oblivious only once
        # arrival pressure makes the queue deep enough that the spray
        # envelope is harmless.
        self.pq = SmartPQ(pq_config or SmartPQConfig(
            num_shards=16, capacity=8192, npods=2, decision_interval=4,
            initial_mode=MODE_AWARE,
        ))
        self.carry = self.pq.init()
        self._step_fn = self.pq.jit_step  # donated carry: zero-copy steps
        self._requests: Dict[int, Request] = {}
        self._rng = jax.random.key(seed)
        self._step = 0
        self.stats = SchedulerStats()

    def submit(self, reqs: List[Request]):
        for r in reqs:
            self._requests[r.uid] = r

    def tick(self, arrivals: List[Request], n_dispatch: int) -> List[Request]:
        """One scheduler step: enqueue arrivals, dequeue up to n_dispatch."""
        self.submit(arrivals)
        B = self.batch
        ops = np.full(B, OP_DELETE_MIN, np.int32)
        keys = np.full(B, INF_KEY, np.int32)
        vals = np.zeros(B, np.int32)
        na = min(len(arrivals), B)
        for i, r in enumerate(arrivals[:B]):
            ops[i] = OP_INSERT
            keys[i] = r.priority_key(self._step)
            vals[i] = r.uid
        # remaining lanes request deletions (bounded by n_dispatch)
        n_del = min(n_dispatch, B - na)
        for i in range(na + n_del, B):
            ops[i] = OP_DELETE_MIN  # masked out via active count
        self._rng, sub = jax.random.split(self._rng)
        # active deletions bounded by n_del: build op vector accordingly
        ops[na + n_del:] = OP_INSERT
        keys[na + n_del:] = INF_KEY  # no-op inserts (masked invalid)

        self.carry, res = self._step_fn(
            self.carry,
            jnp.asarray(ops),
            jnp.asarray(keys),
            jnp.asarray(vals),
            sub,
            512,
        )
        self._step += 1
        out_vals = np.asarray(res.vals)[: int(res.n_out)]
        out_keys = np.asarray(res.keys)[: int(res.n_out)]
        dispatched = [
            self._requests[int(v)]
            for k, v in zip(out_keys, out_vals)
            if k < INF_KEY and int(v) in self._requests
        ]
        self.stats.inserted += na
        self.stats.dispatched += len(dispatched)
        self.stats.mode_trace.append(int(self.carry.stats.mode))
        return dispatched

    @property
    def pending(self) -> int:
        return int(self.carry.state.total_size)
