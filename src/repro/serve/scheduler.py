"""Continuous-batching scheduler built on SmartPQ — the paper's technique as
a first-class serving feature.

Every pending request lives in the adaptive priority queue keyed by

    priority_key = (slo_class << 27) + max(prompt_len - 4 * age, 0)

(smaller = sooner).  The high bits are SLO-major: an interactive request
(slo 0) always sorts ahead of every standard (slo 1) and batch (slo 2)
request, because the minor term is bounded by prompt_len < 2**27.  The
minor term is shortest-prompt-first with linear aging: each scheduler step
a request waits shaves 4 off its effective prompt length, so long prompts
cannot starve behind a stream of short ones — an aged request decays to
the head of its SLO class (minor term 0), where FIFO order re-emerges from
the queue's insertion-seq tiebreak.  `test_priority_key_semantics` pins
these invariants.

Each engine step:
  arrivals  -> insert batch          (insert-dominated under bursts)
  dispatch  -> delete_min batch      (deleteMin-dominated under backlog)

which is EXACTLY the contention profile the paper's classifier switches on:
bursty arrival phases run the queue in NUMA-oblivious (spray) mode; drain
phases flip it to the NUMA-aware (hierarchical delegation) mode.  The queue
state itself is device-resident; the scheduler host loop only moves compact
request descriptors — the ffwd cache-line analogue.

Two dispatch granularities:
  tick()        one step, one device call — the interactive path.
  tick_window() K ticks fused into ONE device call.  Arrivals ride a
                device-resident admission ring — fixed-capacity
                (key-fields, uid) arrays threaded through the scan — that
                each tick consumes into its insert lanes, and every tick
                carries its own dispatch budget, so completions the engine
                forecasts mid-window turn into dispatches at the tick they
                happen instead of waiting for the next window.  Priority
                keys are computed on-device at the admitting tick with the
                same aging formula `Request.priority_key` uses, so the
                dispatch stream is bit-identical to K sequential tick()
                calls with the same per-tick budgets (tested, including
                rng-dependent spray mode).  Arrivals that overflow the lane
                width wait in the ring for the next tick; ring overflow
                waits in a host-side arrival backlog — nothing is dropped
                (tick() used to silently drop arrivals beyond the lane
                width; both paths now spill to the backlog).

Overload hardening (opt-in, `overload=` / `SmartPQConfig.validate`):

  admission     an `OverloadController` filters arrivals BEFORE submit —
                SHEDDING classes are rejected with explicit per-class
                accounting (`stats.shed`), and the arrival backlog is
                hard-capped (`stats.evicted`), so host memory stays bounded
                under any storm.  The controller's mode vote threads into
                the device step as `mode_override` (-1 = classifier rules),
                forcing relaxed MULTIQ while best-effort classes drown.
  recovery      with the guard tier armed (pq validate flag or a
                `validate_hook`), every tick/window runs against a
                pre-window checkpoint (deep-copied carry + host mirrors —
                the copy MUST precede the donated device call).  A window
                that trips validation rolls back and retries ONCE on a
                conservative fallback queue (all-STRICT schedules,
                elimination off, same state layout); if the retry trips
                too, the checkpoint is restored again and a typed
                `WindowValidationError` surfaces — the queue is never left
                corrupt, the window's work simply did not happen.  The
                checkpoint restores rng and step too, so a recovered
                window replays the exact subkey stream.
"""

from __future__ import annotations

import copy
import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import InvariantViolation, WindowValidationError
from repro.core.pqueue.state import INF_KEY
from repro.core.smartpq import SmartPQ, SmartPQConfig
from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT
from repro.obs import NULL, Observability
from repro.serve.overload import OverloadConfig, OverloadController


@dataclasses.dataclass
class Request:
    uid: int
    prompt_len: int
    max_new_tokens: int
    slo_class: int = 1  # 0 = interactive, 1 = standard, 2 = batch
    arrival_step: int = 0
    tokens_done: int = 0

    def priority_key(self, step: int) -> int:
        # SLO-major, shortest-prompt-first minor with linear aging (see
        # module docstring).  Must stay in lockstep with the on-device
        # computation in SmartPQScheduler._window_scan.
        age = max(step - self.arrival_step, 0)
        key = (self.slo_class << 27) + max(self.prompt_len - 4 * age, 0)
        return int(min(key, INF_KEY - 1))


@dataclasses.dataclass
class SchedulerStats:
    inserted: int = 0
    dispatched: int = 0
    rejected: int = 0
    shed: int = 0  # refused at admission by the overload controller
    evicted: int = 0  # dropped from the backlog by the cap
    recovered_windows: int = 0  # rolled back + fallback retry succeeded
    failed_windows: int = 0  # rolled back twice -> WindowValidationError
    mode_trace: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SchedulerCheckpoint:
    """Everything a window can mutate, deep enough to restore twice.

    `carry` holds its own buffer copies (the live carry is DONATED to the
    device step — checkpointing after the call would capture deleted
    buffers), and `restore` re-copies on the way out so one checkpoint
    survives rollback -> retry -> rollback."""

    carry: object
    rng: jax.Array
    step: int
    backlog: List[Request]
    requests: Dict[int, Request]
    stats: SchedulerStats
    overload: Optional[OverloadController]
    last_mode: int = -1  # tracer's transition-edge memory (rolls back too)


class SmartPQScheduler:
    """Host-side continuous batching driver over the device-resident PQ."""

    def __init__(
        self,
        batch_size: int,
        pq_config: Optional[SmartPQConfig] = None,
        seed: int = 0,
        ring_capacity: int = 1024,
        overload: OverloadController | OverloadConfig | None = None,
        validate_hook: Optional[
            Callable[[object], List[InvariantViolation]]
        ] = None,
        obs: Optional[Observability] = None,
    ):
        from repro.core.smartpq import MODE_AWARE

        self.batch = batch_size
        # Admission-ring width: arrivals beyond this per window spill to the
        # host-side backlog (FIFO), so correctness never depends on it.
        self.ring_capacity = ring_capacity
        # Start in the exact (Nuddle) mode: a near-empty queue must respect
        # SLO order strictly; the classifier relaxes to oblivious only once
        # arrival pressure makes the queue deep enough that the spray
        # envelope is harmless.
        self.pq = SmartPQ(pq_config or SmartPQConfig(
            num_shards=16, capacity=8192, npods=2, decision_interval=4,
            initial_mode=MODE_AWARE,
        ))
        self.carry = self.pq.init()
        self._step_fn = self.pq.jit_step  # donated carry: zero-copy steps
        self._window_fn = jax.jit(
            functools.partial(self._window_scan, self.pq),
            donate_argnums=(0,),
        )
        self._requests: Dict[int, Request] = {}
        self._arrival_backlog: List[Request] = []  # submitted, not yet inserted
        self._rng = jax.random.key(seed)
        self._step = 0
        self.stats = SchedulerStats()
        # Observability: shared registry + tracer (the engine passes its
        # own so every layer writes one surface; standalone schedulers get
        # the disabled NULL bundle — every write early-outs).
        self.obs = obs if obs is not None else NULL
        # Host mirror of the device mode — the tracer's transition-edge
        # detector (events == device `stats.transitions` increments).
        self._last_mode = int(self.pq.config.initial_mode)
        if isinstance(overload, OverloadConfig):
            overload = OverloadController(overload)
        self.overload = overload
        if overload is not None and getattr(overload, "obs", None) is None:
            overload.obs = self.obs
        # Extra validation hook (state -> violations); chaos tests use it to
        # trip the recovery path deterministically.  Guarded execution is on
        # iff the pq's validate flag or a hook is set.
        self.validate_hook = validate_hook
        self._fb: Optional[SmartPQ] = None  # lazy conservative fallback
        # Optional write-ahead-log sink (kind, payload) -> None: the
        # durability layer attaches it so every shed/evict decision leaves
        # an audit record in the WAL next to the admissions it filtered.
        self.wal_sink: Optional[Callable[[str, Dict], None]] = None

    def submit(self, reqs: List[Request]):
        for r in reqs:
            self._requests[r.uid] = r

    def requeue(self, reqs: List[Request]) -> None:
        """Return dispatched-but-unserved requests to the queue (via the
        FIFO arrival backlog, so they re-insert ahead of newer arrivals
        with their original arrival step — aging keeps accruing).  The
        engine's admit-backlog relief valve: bounded backlogs without
        dropping work that already passed the shed filter."""
        self.submit(reqs)
        self._arrival_backlog.extend(reqs)

    def _pack_tick(self, arrivals: List[Request], n_dispatch: int):
        """Build one tick's (ops, keys, vals) lane vectors + arrival count."""
        B = self.batch
        ops = np.full(B, OP_DELETE_MIN, np.int32)
        keys = np.full(B, INF_KEY, np.int32)
        vals = np.zeros(B, np.int32)
        na = min(len(arrivals), B)
        for i, r in enumerate(arrivals[:B]):
            ops[i] = OP_INSERT
            keys[i] = r.priority_key(self._step)
            vals[i] = r.uid
        # remaining lanes request deletions (bounded by n_dispatch); lanes
        # beyond the budget become no-op inserts (INF key, masked invalid)
        n_del = min(n_dispatch, B - na)
        ops[na + n_del:] = OP_INSERT
        keys[na + n_del:] = INF_KEY
        return ops, keys, vals, na

    def _collect(self, out_keys: np.ndarray, out_vals: np.ndarray,
                 n_out: int) -> List[Request]:
        # Dispatched descriptors leave the host map — `_requests` holds
        # in-flight requests only, so host memory tracks queue depth, not
        # request history (asserted by the chaos memory-bound test).
        out = []
        for k, v in zip(out_keys[:n_out], out_vals[:n_out]):
            if k < INF_KEY:
                r = self._requests.pop(int(v), None)
                if r is not None:
                    out.append(r)
        return out

    # -- overload hooks --------------------------------------------------------

    def _admit(self, arrivals: List[Request]) -> List[Request]:
        """Admission filter: SHEDDING classes are rejected here, before the
        requests ever reach `_requests` — an explicit, counted drop."""
        if self.overload is None:
            return arrivals
        kept, shed = self.overload.admit(arrivals)
        self.stats.shed += len(shed)
        if shed and self.wal_sink is not None:
            self.wal_sink("shed", {
                "step": self._step,
                "uids": [r.uid for r in shed],
                "classes": [r.slo_class for r in shed],
            })
        return kept

    def _enforce_backlog_cap(self) -> None:
        if self.overload is None:
            return
        evicted = self.overload.evict(self._arrival_backlog)
        for r in evicted:
            self._requests.pop(r.uid, None)
        self.stats.evicted += len(evicted)
        if evicted and self.wal_sink is not None:
            self.wal_sink("evict", {
                "step": self._step,
                "uids": [r.uid for r in evicted],
                "classes": [r.slo_class for r in evicted],
            })

    def _mode_override(self) -> int:
        return self.overload.mode_override() if self.overload else -1

    def _observe(
        self, dispatched: List[Tuple[Request, int]], step: int
    ) -> None:
        """Feed the controller: completed queueing delays (each request
        stamped with its actual dispatch tick) + censored waits of
        everything still awaiting dispatch, then run the control law.

        The censored pass walks `_requests` — on-device queue AND host
        backlog — not just the backlog: under hard overload a starved
        class stops completing entirely, so its (stale) completed samples
        read as healthy while hundreds of its requests age invisibly
        inside the device queue.  `_collect` pops dispatched uids, so the
        walk is O(requests in flight), bounded by queue capacity."""
        if self.overload is None:
            return
        for r, at in dispatched:
            self.overload.observe(r.slo_class, at - r.arrival_step)
        for r in self._requests.values():
            self.overload.observe_pending(r.slo_class, step - r.arrival_step)
        self.overload.update()

    # -- guarded execution: checkpoint / validate / rollback -------------------

    @property
    def _guard_active(self) -> bool:
        return self.pq.config.validate or self.validate_hook is not None

    def checkpoint(self) -> SchedulerCheckpoint:
        return SchedulerCheckpoint(
            carry=jax.tree.map(jnp.copy, self.carry),
            rng=self._rng,
            step=self._step,
            backlog=list(self._arrival_backlog),
            requests=dict(self._requests),
            stats=dataclasses.replace(
                self.stats, mode_trace=list(self.stats.mode_trace)
            ),
            overload=copy.deepcopy(self.overload),
            last_mode=self._last_mode,
        )

    def restore(self, ckpt: SchedulerCheckpoint) -> None:
        # Re-copy the carry: the restored buffers will be donated to the
        # next device call, and the checkpoint must survive a second
        # restore (rollback -> retry -> rollback).
        self.carry = jax.tree.map(jnp.copy, ckpt.carry)
        self._rng = ckpt.rng
        self._step = ckpt.step
        self._arrival_backlog = list(ckpt.backlog)
        self._requests = dict(ckpt.requests)
        self.stats = dataclasses.replace(
            ckpt.stats, mode_trace=list(ckpt.stats.mode_trace)
        )
        if ckpt.last_mode >= 0:
            self._last_mode = ckpt.last_mode
        if ckpt.overload is not None and self.overload is not None:
            # In-place: the engine may hold a reference to the controller.
            self.overload.__dict__.update(
                copy.deepcopy(ckpt.overload).__dict__
            )

    # -- durable persistence (WAL snapshot surface) ----------------------------

    def snapshot_arrays(self) -> Dict[str, object]:
        """The scheduler's device-array state as a pytree for
        `persist.save_tree`: the full carry (PQState + stats) and the raw
        rng key data (typed keys don't serialize; `wrap_key_data` restores
        the exact stream, which spray/multiq determinism depends on)."""
        return {
            "carry": self.carry,
            "rng": jax.random.key_data(self._rng),
        }

    def restore_arrays(self, arrays: Dict[str, object]) -> None:
        self.carry = arrays["carry"]
        self._rng = jax.random.wrap_key_data(jnp.asarray(arrays["rng"]))

    def host_state(self) -> Dict[str, object]:
        """JSON-able host-side state: step clock, backlog, in-flight map
        (insertion order preserved — `_observe` iterates it, so order is
        part of bit-identical recovery), stats, overload controller."""
        req_dict = dataclasses.asdict
        return {
            "step": self._step,
            "backlog": [req_dict(r) for r in self._arrival_backlog],
            "requests": [req_dict(r) for r in self._requests.values()],
            "stats": {
                **{
                    f.name: getattr(self.stats, f.name)
                    for f in dataclasses.fields(self.stats)
                    if f.name != "mode_trace"
                },
                "mode_trace": list(self.stats.mode_trace),
            },
            "overload": (
                self.overload.state_dict()
                if self.overload is not None else None
            ),
        }

    def load_host_state(self, d: Dict[str, object]) -> None:
        self._step = int(d["step"])
        self._arrival_backlog = [
            Request(**{k: int(v) for k, v in rd.items()})
            for rd in d["backlog"]
        ]
        self._requests = {}
        for rd in d["requests"]:
            r = Request(**{k: int(v) for k, v in rd.items()})
            self._requests[r.uid] = r
        st = dict(d["stats"])
        self.stats = SchedulerStats(
            **{k: v for k, v in st.items() if k != "mode_trace"},
            mode_trace=list(st.get("mode_trace", [])),
        )
        if self.stats.mode_trace:
            self._last_mode = int(self.stats.mode_trace[-1])
        if d.get("overload") is not None and self.overload is not None:
            self.overload.load_state_dict(d["overload"])

    def _validate(self) -> List[InvariantViolation]:
        viols: List[InvariantViolation] = []
        if self.validate_hook is not None:
            viols.extend(self.validate_hook(self.carry.state) or [])
        if self.pq.config.validate:
            from repro.core.pqueue.state import invariant_violations

            viols.extend(invariant_violations(self.carry.state))
        return viols

    def _fallback_pq(self) -> SmartPQ:
        """Conservative retry queue: every mode pinned to the exact STRICT
        schedule, elimination off — the least clever, most checkable
        configuration that still shares the PQState layout (so the rolled-
        back carry threads straight through)."""
        if self._fb is None:
            from repro.core.pqueue.schedules import Schedule
            from repro.core.smartpq import NUM_MODES

            cfg = dataclasses.replace(
                self.pq.config,
                mode_schedules=(Schedule.STRICT_FLAT,) * NUM_MODES,
                eliminate=False,
            )
            self._fb = SmartPQ(cfg)
            self._fb_step_fn = self._fb.jit_step
            self._fb_window_fn = jax.jit(
                functools.partial(self._window_scan, self._fb),
                donate_argnums=(0,),
            )
        return self._fb

    def _run_guarded(self, run):
        """Execute `run(fallback)` under the window-recovery contract.

        Observability contract: a rolled-back attempt's trace events are
        truncated away (its work never happened — the timeline must agree
        with the state), replaced by an explicit `rollback` instant; every
        detected invariant violation bumps ``errors_total{code=INVARIANT}``
        and a double-trip bumps ``errors_total{code=WINDOW_VALIDATION}``
        before the typed error surfaces."""
        if not self._guard_active:
            return run(False)
        m, tr = self.obs.metrics, self.obs.tracer
        ckpt = self.checkpoint()
        mark = tr.mark()
        out = run(False)
        viols = self._validate()
        if not viols:
            return out
        m.inc("errors_total", n=len(viols), code="INVARIANT")
        m.inc("sched_window_rollbacks_total")
        tr.truncate(mark)
        tr.instant("rollback", cat="guard", attempt=0,
                   violations=len(viols), step=self._step)
        self.restore(ckpt)
        mark = tr.mark()
        out = run(True)
        retry = self._validate()
        if retry:
            m.inc("errors_total", n=len(retry), code="INVARIANT")
            m.inc("errors_total", code="WINDOW_VALIDATION")
            tr.truncate(mark)
            tr.instant("window_failed", cat="guard",
                       violations=len(retry), step=self._step)
            self.restore(ckpt)
            self.stats.failed_windows += 1
            raise WindowValidationError(viols, retry)
        self.stats.recovered_windows += 1
        m.inc("sched_windows_recovered_total")
        tr.instant("window_recovered", cat="guard", step=self._step)
        return out

    # -- per-step path ---------------------------------------------------------

    def tick(self, arrivals: List[Request], n_dispatch: int) -> List[Request]:
        """One scheduler step: enqueue arrivals, dequeue up to n_dispatch.

        Arrivals beyond the lane width join the FIFO arrival backlog and
        insert on later ticks (ahead of newer arrivals) — the same
        spill-don't-drop contract the windowed admission ring implements."""
        arrivals = list(arrivals)
        return self._run_guarded(
            lambda fb: self._tick_impl(arrivals, n_dispatch, fb)
        )

    def _tick_impl(
        self, arrivals: List[Request], n_dispatch: int, fallback: bool
    ) -> List[Request]:
        arrivals = self._admit(arrivals)
        self.submit(arrivals)
        queue = self._arrival_backlog + list(arrivals)
        na = min(len(queue), self.batch)
        self._arrival_backlog = queue[na:]
        self._enforce_backlog_cap()
        ops, keys, vals, na = self._pack_tick(queue[:na], n_dispatch)
        ov = jnp.int32(self._mode_override())
        self._rng, sub = jax.random.split(self._rng)

        step_fn = self._step_fn
        if fallback:
            self._fallback_pq()
            step_fn = self._fb_step_fn
        tr = self.obs.tracer
        t0 = tr.now_us() if tr.enabled else 0.0
        # Features ride along as an extra graph output in EVERY call (the
        # same compiled program whether telemetry reads them or not), so
        # the dispatch stream is bit-identical with obs on vs off.
        self.carry, res, feats = step_fn(
            self.carry,
            jnp.asarray(ops),
            jnp.asarray(keys),
            jnp.asarray(vals),
            sub,
            512,
            mode_override=ov,
            return_features=True,
        )
        self._step += 1
        dispatched = self._collect(
            np.asarray(res.keys), np.asarray(res.vals), int(res.n_out)
        )
        self.stats.inserted += na
        self.stats.dispatched += len(dispatched)
        mode = int(self.carry.stats.mode)
        self.stats.mode_trace.append(mode)
        self.obs.metrics.inc("sched_ticks_total")
        if tr.enabled:
            tr.span_at("tick", t0, tr.now_us() - t0, cat="sched",
                       step=self._step, mode=mode, arrivals=na,
                       dispatched=len(dispatched), fallback=fallback)
            if mode != self._last_mode:
                tr.instant(
                    "mode_transition", cat="mode", ts=t0,
                    from_mode=self._last_mode, to_mode=mode,
                    step=self._step,
                    features=np.asarray(feats, np.float32).tolist(),
                )
        self._last_mode = mode
        self._observe([(r, self._step) for r in dispatched], self._step)
        return dispatched

    # -- fused windowed admission ---------------------------------------------

    def _window_scan(
        self, pq, carry, ring, avail_by_tick, budgets, step0, rngs, mode_ov
    ):
        """K scheduler ticks as ONE fused lax.scan over `SmartPQ.step`.

        `ring` is the admission ring: fixed-capacity (slo, prompt_len,
        arrival_step, uid) int32 arrays.  Each tick consumes the FIFO
        prefix of ring entries that have arrived by that tick (bounded by
        the lane width), computes their priority keys on-device with the
        tick's step number — bit-identical to host `Request.priority_key`
        — and spends that tick's dispatch budget on delete lanes.  The
        consumed count threads through the scan, so a burst that overflows
        one tick's lanes admits on the following ticks of the SAME window.
        `pq` is bound by functools.partial (main queue or the conservative
        fallback); `mode_ov` is the window's mode-override scalar (-1 =
        classifier rules), identical at every tick of the window.
        """
        slo, plen, astep, uid = ring
        B = self.batch
        R = slo.shape[0]
        lane = jnp.arange(B, dtype=jnp.int32)

        def body(state, x):
            cr, head = state
            t, budget, avail, rng = x
            step = step0 + t
            n_arr = jnp.clip(avail - head, 0, B)
            idx = jnp.minimum(head + lane, R - 1)
            is_arr = lane < n_arr
            age = jnp.maximum(step - astep[idx], 0)
            pkey = (slo[idx] << 27) + jnp.maximum(plen[idx] - 4 * age, 0)
            pkey = jnp.minimum(pkey, INF_KEY - 1)
            n_del = jnp.clip(budget, 0, B - n_arr)
            is_del = (lane >= n_arr) & (lane < n_arr + n_del)
            ops = jnp.where(
                is_del, OP_DELETE_MIN, OP_INSERT
            ).astype(jnp.int32)
            keys = jnp.where(is_arr, pkey, INF_KEY).astype(jnp.int32)
            vals = jnp.where(is_arr, uid[idx], 0).astype(jnp.int32)
            cr2, res, feats = pq.step(
                cr, ops, keys, vals, rng, 512, mode_override=mode_ov,
                return_features=True,
            )
            # Ring entries already arrived but beyond this tick's lane
            # width — the device-visible admission-spill counter (host
            # ring overflow is accounted separately, in the backlog).
            deferred = jnp.maximum(avail - head - n_arr, 0)
            cr2 = cr2._replace(stats=cr2.stats._replace(
                ring_deferred=cr2.stats.ring_deferred + deferred
            ))
            return (cr2, head + n_arr), (
                res.keys, res.vals, res.n_out, cr2.stats.mode,
                feats, cr2.stats.eliminated,
            )

        K = budgets.shape[0]
        t_idx = jnp.arange(K, dtype=jnp.int32)
        (carry, head), (dk, dv, dn, dm, df, de) = jax.lax.scan(
            body, (carry, jnp.int32(0)), (t_idx, budgets, avail_by_tick, rngs)
        )
        return carry, head, dk, dv, dn, dm, df, de

    def tick_window(
        self,
        arrivals: Sequence[List[Request]],
        budgets: Sequence[int],
    ) -> List[List[Request]]:
        """K scheduler ticks in ONE device call, budgeted per tick.

        `arrivals[t]` is the request list arriving at tick t; `budgets[t]`
        caps that tick's dispatches (the engine derives mid-window budgets
        from its slot-availability forecast; `[free, 0, 0, ...]` reproduces
        the window-start-budget baseline).  Arrivals — prefixed by any
        backlog from earlier windows — load into the device admission ring
        once, and the fused scan consumes them at their arrival ticks, so
        the host moves one compact descriptor batch per window instead of
        K lists.  Returns the per-tick dispatch lists — bit-identical to K
        sequential `tick(arrivals[t], budgets[t])` calls (same lanes, same
        rng stream, same mode trace).  Ring overflow stays in the host
        backlog for the next window; nothing is dropped without accounting:
        with an overload controller attached, SHEDDING-class arrivals are
        refused at admission (stats.shed) and the backlog cap evicts
        (stats.evicted) — otherwise the backlog is unbounded as before."""
        K = len(arrivals)
        if K == 0:
            return []
        if len(budgets) != K:
            raise ValueError(
                f"budgets must give one dispatch cap per tick: "
                f"{len(budgets)} budgets for {K} ticks"
            )
        arrivals = [list(reqs) for reqs in arrivals]
        return self._run_guarded(
            lambda fb: self._window_impl(arrivals, budgets, fb)
        )

    def _window_impl(
        self,
        arrivals: List[List[Request]],
        budgets: Sequence[int],
        fallback: bool,
    ) -> List[List[Request]]:
        K = len(arrivals)
        arrivals = [self._admit(reqs) for reqs in arrivals]
        for reqs in arrivals:
            self.submit(reqs)

        # Load the ring: backlog first (FIFO), available at tick 0; this
        # window's arrivals become available at their own tick.  Overflow
        # beyond the fixed capacity returns to the backlog untouched.
        R = self.ring_capacity
        pending = [(r, 0) for r in self._arrival_backlog] + [
            (r, t) for t, reqs in enumerate(arrivals) for r in reqs
        ]
        loaded = pending[:R]
        slo = np.zeros(R, np.int32)
        plen = np.zeros(R, np.int32)
        astep = np.zeros(R, np.int32)
        uid = np.zeros(R, np.int32)
        avail_tick = np.full(len(loaded), 0, np.int32)
        for i, (r, t) in enumerate(loaded):
            slo[i] = r.slo_class
            plen[i] = r.prompt_len
            astep[i] = r.arrival_step
            uid[i] = r.uid
            avail_tick[i] = t
        # entries are FIFO by (tick, submission order) already — backlog
        # carries tick 0 and arrivals append in tick order
        avail_by_tick = np.searchsorted(
            avail_tick, np.arange(K), side="right"
        ).astype(np.int32)

        ov = jnp.int32(self._mode_override())
        step0 = self._step
        subs = []
        for _ in range(K):
            self._step += 1  # priority keys age per tick, as in tick()
            # split exactly as K sequential tick() calls would — the rng
            # stream (and self._rng afterwards) must match bit for bit,
            # otherwise spray/multiq modes diverge from the per-step path
            self._rng, sub = jax.random.split(self._rng)
            subs.append(sub)

        window_fn = self._window_fn
        if fallback:
            self._fallback_pq()
            window_fn = self._fb_window_fn
        tr = self.obs.tracer
        elim0 = int(self.carry.stats.eliminated) if tr.enabled else 0
        t_win = tr.now_us() if tr.enabled else 0.0
        self.carry, head, dk, dv, dn, dm, df, de = window_fn(
            self.carry,
            (jnp.asarray(slo), jnp.asarray(plen), jnp.asarray(astep),
             jnp.asarray(uid)),
            jnp.asarray(avail_by_tick),
            jnp.asarray(np.asarray(budgets, np.int32)),
            jnp.int32(step0),
            jnp.stack(subs),
            ov,
        )
        consumed = int(head)
        self._arrival_backlog = [r for r, _ in pending[consumed:]]
        self._enforce_backlog_cap()

        out_k = np.asarray(dk)
        out_v = np.asarray(dv)
        n_out = np.asarray(dn)
        modes = np.asarray(dm)
        dispatched_per_tick = []
        all_dispatched: List[Tuple[Request, int]] = []
        for t in range(K):
            d = self._collect(out_k[t], out_v[t], int(n_out[t]))
            dispatched_per_tick.append(d)
            all_dispatched.extend((r, step0 + t + 1) for r in d)
            self.stats.dispatched += len(d)
            self.stats.mode_trace.append(int(modes[t]))
        self.stats.inserted += consumed
        self.obs.metrics.inc("sched_windows_total")
        self.obs.metrics.inc("sched_ticks_total", n=K)
        if tr.enabled:
            self._trace_window(
                tr, t_win, step0, K, consumed, fallback, modes,
                np.asarray(df), np.asarray(de), elim0,
                [len(d) for d in dispatched_per_tick],
            )
        self._last_mode = int(modes[-1])
        self._observe(all_dispatched, self._step)
        return dispatched_per_tick

    def _trace_window(
        self, tr, t_win, step0, K, consumed, fallback, modes,
        feats, elim_cum, elim0, n_disp,
    ) -> None:
        """Emit the window span + K synthesized tick spans + transition
        instants.  The device executes all K ticks in ONE dispatch, so the
        tick spans subdivide the real window interval into K equal logical
        slots — their ARGS (mode, dispatches, eliminations, admissions)
        are the real per-tick values from the scan outputs."""
        dur = tr.now_us() - t_win
        tr.span_at(
            "window", t_win, dur, cat="sched", step0=step0, ticks=K,
            admitted=consumed, dispatched=int(sum(n_disp)),
            fallback=fallback,
        )
        slot = dur / K
        last = self._last_mode
        for t in range(K):
            mode = int(modes[t])
            ts = t_win + t * slot
            tr.span_at(
                "tick", ts, slot, cat="sched", step=step0 + t + 1,
                mode=mode, dispatched=n_disp[t],
                eliminated=int(elim_cum[t]) - (
                    int(elim_cum[t - 1]) if t else elim0
                ),
            )
            if mode != last:
                tr.instant(
                    "mode_transition", cat="mode", ts=ts,
                    from_mode=last, to_mode=mode, step=step0 + t + 1,
                    features=np.asarray(
                        feats[t], np.float32
                    ).tolist(),
                )
            last = mode

    @property
    def pending(self) -> int:
        """Requests awaiting dispatch: queued on device + arrival backlog."""
        return int(self.carry.state.total_size) + len(self._arrival_backlog)
