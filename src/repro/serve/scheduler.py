"""Continuous-batching scheduler built on SmartPQ — the paper's technique as
a first-class serving feature.

Every pending request lives in the adaptive priority queue keyed by

    priority_key = slo_class << 28 | arrival_order ... (smaller = sooner)

Each engine step:
  arrivals  -> insert batch          (insert-dominated under bursts)
  dispatch  -> delete_min batch      (deleteMin-dominated under backlog)

which is EXACTLY the contention profile the paper's classifier switches on:
bursty arrival phases run the queue in NUMA-oblivious (spray) mode; drain
phases flip it to the NUMA-aware (hierarchical delegation) mode.  The queue
state itself is device-resident; the scheduler host loop only moves compact
request descriptors — the ffwd cache-line analogue.

Two dispatch granularities:
  tick()        one step, one device call — the interactive path.
  tick_window() K ticks fused into ONE device call via SmartPQ.run_window —
                mode decisions (and the elimination pre-pass that serves
                same-window insert/deleteMin matches without touching the
                queue) happen on-device mid-window, so per-request scheduler
                overhead amortizes K-fold.  The per-tick dispatch lists come
                back identical to K sequential tick() calls.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pqueue.state import INF_KEY
from repro.core.smartpq import SmartPQ, SmartPQConfig
from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT


@dataclasses.dataclass
class Request:
    uid: int
    prompt_len: int
    max_new_tokens: int
    slo_class: int = 1  # 0 = interactive, 1 = standard, 2 = batch
    arrival_step: int = 0
    tokens_done: int = 0

    def priority_key(self, step: int) -> int:
        # slo-major, then arrival order (FIFO within class); headroom-aware
        # boost for requests close to completion (frees KV pages sooner).
        age = max(step - self.arrival_step, 0)
        key = (self.slo_class << 27) + max(self.prompt_len - 4 * age, 0)
        return int(min(key, INF_KEY - 1))


@dataclasses.dataclass
class SchedulerStats:
    inserted: int = 0
    dispatched: int = 0
    rejected: int = 0
    mode_trace: List[int] = dataclasses.field(default_factory=list)


class SmartPQScheduler:
    """Host-side continuous batching driver over the device-resident PQ."""

    def __init__(
        self,
        batch_size: int,
        pq_config: Optional[SmartPQConfig] = None,
        seed: int = 0,
    ):
        from repro.core.smartpq import MODE_AWARE

        self.batch = batch_size
        # Start in the exact (Nuddle) mode: a near-empty queue must respect
        # SLO order strictly; the classifier relaxes to oblivious only once
        # arrival pressure makes the queue deep enough that the spray
        # envelope is harmless.
        self.pq = SmartPQ(pq_config or SmartPQConfig(
            num_shards=16, capacity=8192, npods=2, decision_interval=4,
            initial_mode=MODE_AWARE,
        ))
        self.carry = self.pq.init()
        self._step_fn = self.pq.jit_step  # donated carry: zero-copy steps
        self._requests: Dict[int, Request] = {}
        self._rng = jax.random.key(seed)
        self._step = 0
        self.stats = SchedulerStats()

    def submit(self, reqs: List[Request]):
        for r in reqs:
            self._requests[r.uid] = r

    def _pack_tick(self, arrivals: List[Request], n_dispatch: int):
        """Build one tick's (ops, keys, vals) lane vectors + arrival count."""
        B = self.batch
        ops = np.full(B, OP_DELETE_MIN, np.int32)
        keys = np.full(B, INF_KEY, np.int32)
        vals = np.zeros(B, np.int32)
        na = min(len(arrivals), B)
        for i, r in enumerate(arrivals[:B]):
            ops[i] = OP_INSERT
            keys[i] = r.priority_key(self._step)
            vals[i] = r.uid
        # remaining lanes request deletions (bounded by n_dispatch); lanes
        # beyond the budget become no-op inserts (INF key, masked invalid)
        n_del = min(n_dispatch, B - na)
        ops[na + n_del:] = OP_INSERT
        keys[na + n_del:] = INF_KEY
        return ops, keys, vals, na

    def _collect(self, out_keys: np.ndarray, out_vals: np.ndarray,
                 n_out: int) -> List[Request]:
        return [
            self._requests[int(v)]
            for k, v in zip(out_keys[:n_out], out_vals[:n_out])
            if k < INF_KEY and int(v) in self._requests
        ]

    def tick(self, arrivals: List[Request], n_dispatch: int) -> List[Request]:
        """One scheduler step: enqueue arrivals, dequeue up to n_dispatch."""
        self.submit(arrivals)
        ops, keys, vals, na = self._pack_tick(arrivals, n_dispatch)
        self._rng, sub = jax.random.split(self._rng)

        self.carry, res = self._step_fn(
            self.carry,
            jnp.asarray(ops),
            jnp.asarray(keys),
            jnp.asarray(vals),
            sub,
            512,
        )
        self._step += 1
        dispatched = self._collect(
            np.asarray(res.keys), np.asarray(res.vals), int(res.n_out)
        )
        self.stats.inserted += na
        self.stats.dispatched += len(dispatched)
        self.stats.mode_trace.append(int(self.carry.stats.mode))
        return dispatched

    def tick_window(
        self, ticks: List[Tuple[List[Request], int]]
    ) -> List[List[Request]]:
        """K scheduler ticks in ONE device call (SmartPQ.run_window).

        `ticks` is a list of (arrivals, n_dispatch) pairs.  Returns the
        per-tick dispatch lists — identical to calling tick() K times (the
        fused scan is bit-identical to the sequential step loop), at one
        K-th of the dispatch overhead.  Requests that arrive and win a
        dispatch slot within the same window ride the on-device elimination
        pre-pass and never touch the queue state."""
        K = len(ticks)
        if K == 0:
            return []
        packed = []
        subs = []
        for arrivals, n_dispatch in ticks:
            self.submit(arrivals)
            packed.append(self._pack_tick(arrivals, n_dispatch))
            self._step += 1  # priority keys age per tick, as in tick()
            # split exactly as K sequential tick() calls would — the rng
            # stream (and self._rng afterwards) must match bit for bit,
            # otherwise spray/multiq modes diverge from the per-step path
            self._rng, sub = jax.random.split(self._rng)
            subs.append(sub)
        ops = np.stack([p[0] for p in packed])
        keys = np.stack([p[1] for p in packed])
        vals = np.stack([p[2] for p in packed])
        subs = jnp.stack(subs)

        self.carry, wres = self.pq.jit_run_window(
            self.carry,
            jnp.asarray(ops),
            jnp.asarray(keys),
            jnp.asarray(vals),
            subs,
            512,
        )
        out_k = np.asarray(wres.keys)
        out_v = np.asarray(wres.vals)
        n_out = np.asarray(wres.n_out)
        modes = np.asarray(wres.mode)
        dispatched_per_tick = []
        for t in range(K):
            d = self._collect(out_k[t], out_v[t], int(n_out[t]))
            dispatched_per_tick.append(d)
            self.stats.inserted += packed[t][3]
            self.stats.dispatched += len(d)
            self.stats.mode_trace.append(int(modes[t]))
        return dispatched_per_tick

    @property
    def pending(self) -> int:
        return int(self.carry.state.total_size)
