"""repro.serve.supervisor — keep a durable serving worker alive.

The durability layer (WAL + snapshots) makes the engine's state survive
process death; this module supplies the process half of the story: run
the engine as a CHILD process, detect death and hangs, and restart it —
recovery is then just the child's own `ServeEngine.recover()` running at
startup, so a supervised restart and a manual restart are the same code
path.

Detection:
  death   `Popen.poll()` — any nonzero/signal exit is a crash; exit 0
          means the workload drained and the supervisor is done.
  hangs   a heartbeat file the durability layer atomically rewrites at
          every window commit.  A child that stays alive but stops
          committing (deadlock, livelock, stuck device call) goes stale;
          after `heartbeat_timeout` seconds the supervisor SIGKILLs it
          and treats it as a crash.  The timeout only arms once the
          child has produced its FIRST heartbeat (startup — imports,
          compilation — is covered by `startup_timeout`).

Restart policy:
  backoff  bounded exponential: ``backoff_base * 2**n`` capped at
           ``backoff_max`` seconds between attempts, reset by a healthy
           stretch (a heartbeat newer than the last crash).
  breaker  a crash-loop circuit breaker: more than `max_restarts`
           crashes within the sliding `crash_window` seconds raises a
           typed `CrashLoopError` instead of restarting forever — a
           crash that recovery cannot get past (corrupt store, broken
           binary) must surface, not spin.
"""

from __future__ import annotations

import dataclasses
import os
import signal
import subprocess
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.core.errors import CrashLoopError


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    heartbeat_timeout: float = 30.0  # stale-heartbeat kill threshold (s)
    startup_timeout: float = 120.0  # first-heartbeat grace (imports/jit)
    poll_interval: float = 0.05  # child/heartbeat polling cadence (s)
    backoff_base: float = 0.2  # first restart delay (s)
    backoff_max: float = 5.0  # exponential backoff cap (s)
    max_restarts: int = 5  # circuit breaker: crashes tolerated ...
    crash_window: float = 120.0  # ... within this sliding window (s)


@dataclasses.dataclass
class SupervisorReport:
    outcome: str  # "completed" | "crash_loop"
    restarts: int
    exit_codes: List[int]
    hang_kills: int
    wall_s: float

    def as_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


class Supervisor:
    """Run `argv` as a child until it exits 0, restarting on crash/hang.

    `heartbeat` is the path the child's durability layer rewrites at every
    window commit (``<durable_dir>/heartbeat.json``); its mtime is the
    liveness signal.  The supervisor never reads engine internals — the
    heartbeat and the exit code are the whole protocol, which is what lets
    it supervise any worker binary."""

    def __init__(
        self,
        argv: Sequence[str],
        heartbeat: str | Path,
        config: SupervisorConfig = SupervisorConfig(),
        env: Optional[Dict[str, str]] = None,
    ):
        self.argv = list(argv)
        self.heartbeat = Path(heartbeat)
        self.cfg = config
        self.env = env
        self._crash_times: List[float] = []

    # -- internals ---------------------------------------------------------

    def _heartbeat_age(self) -> Optional[float]:
        try:
            return time.time() - self.heartbeat.stat().st_mtime
        except OSError:
            return None

    def _spawn(self) -> subprocess.Popen:
        env = dict(os.environ)
        if self.env:
            env.update(self.env)
        return subprocess.Popen(self.argv, env=env)

    def _watch(self, child: subprocess.Popen) -> tuple[int, bool]:
        """Wait for exit or hang; returns (exit_code, hang_killed)."""
        t_start = time.time()
        seen_heartbeat = False
        while True:
            code = child.poll()
            if code is not None:
                return code, False
            age = self._heartbeat_age()
            if age is not None and age < self.cfg.startup_timeout:
                # a heartbeat younger than startup grace exists; once one
                # is observed, the (tighter) stale threshold arms
                if age < self.cfg.heartbeat_timeout:
                    seen_heartbeat = True
            if seen_heartbeat and age is not None \
                    and age > self.cfg.heartbeat_timeout:
                self._kill(child)
                return child.wait(), True
            if not seen_heartbeat \
                    and time.time() - t_start > self.cfg.startup_timeout:
                self._kill(child)
                return child.wait(), True
            time.sleep(self.cfg.poll_interval)

    @staticmethod
    def _kill(child: subprocess.Popen) -> None:
        try:
            child.send_signal(signal.SIGKILL)
        except (ProcessLookupError, PermissionError):
            pass

    def _record_crash(self, now: float) -> None:
        self._crash_times.append(now)
        cutoff = now - self.cfg.crash_window
        self._crash_times = [t for t in self._crash_times if t >= cutoff]

    # -- main loop ---------------------------------------------------------

    def run(self) -> SupervisorReport:
        t0 = time.time()
        exit_codes: List[int] = []
        hang_kills = 0
        restarts = 0
        attempt = 0
        while True:
            child = self._spawn()
            code, hanged = self._watch(child)
            exit_codes.append(code)
            hang_kills += int(hanged)
            if code == 0 and not hanged:
                return SupervisorReport(
                    outcome="completed",
                    restarts=restarts,
                    exit_codes=exit_codes,
                    hang_kills=hang_kills,
                    wall_s=time.time() - t0,
                )
            now = time.time()
            self._record_crash(now)
            if len(self._crash_times) > self.cfg.max_restarts:
                from repro.obs import get_default

                get_default().metrics.inc("errors_total", code="CRASH_LOOP")
                raise CrashLoopError(
                    len(self._crash_times), self.cfg.crash_window,
                    exit_codes,
                )
            # healthy stretch resets the exponential ladder: a crash long
            # after the previous one is flapping, not a loop
            if len(self._crash_times) == 1:
                attempt = 0
            delay = min(
                self.cfg.backoff_base * (2 ** attempt),
                self.cfg.backoff_max,
            )
            attempt += 1
            restarts += 1
            time.sleep(delay)


def main(argv: Optional[Sequence[str]] = None) -> int:
    """CLI: ``python -m repro.serve.supervisor --heartbeat H -- cmd ...``"""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--heartbeat", required=True)
    ap.add_argument("--heartbeat-timeout", type=float, default=30.0)
    ap.add_argument("--startup-timeout", type=float, default=120.0)
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--crash-window", type=float, default=120.0)
    ap.add_argument("--backoff-base", type=float, default=0.2)
    ap.add_argument("--backoff-max", type=float, default=5.0)
    ap.add_argument("cmd", nargs=argparse.REMAINDER,
                    help="child command (prefix with --)")
    args = ap.parse_args(argv)
    cmd = args.cmd[1:] if args.cmd[:1] == ["--"] else args.cmd
    if not cmd:
        ap.error("no child command given")
    sup = Supervisor(cmd, args.heartbeat, SupervisorConfig(
        heartbeat_timeout=args.heartbeat_timeout,
        startup_timeout=args.startup_timeout,
        max_restarts=args.max_restarts,
        crash_window=args.crash_window,
        backoff_base=args.backoff_base,
        backoff_max=args.backoff_max,
    ))
    report = sup.run()
    print(report.as_dict())
    return 0 if report.outcome == "completed" else 1


if __name__ == "__main__":
    sys.exit(main())


__all__ = ["Supervisor", "SupervisorConfig", "SupervisorReport", "main"]
