"""repro.serve.overload — SLO-class graceful degradation under overload.

The serving tier's admission path was previously open-loop: every arrival
was accepted, queueing in unbounded host lists until served.  Under
sustained overload (offered load > capacity) that design fails exactly the
clients the SLO-major priority key was built to protect — the backlog grows
without bound, memory grows with it, and once the engine's own over-
admission FIFO (which is *not* priority ordered) fills, even class-0
latency collapses.

This module closes the loop.  An `OverloadController` watches, per SLO
class, (a) backlog depth and (b) a sliding window of queueing delays, and
compares the window p99 against per-class targets.  Classes degrade
independently through three states with hysteresis:

  OK        -> admit everything
  DEGRADED  -> admit, but vote to force the PQ into relaxed MULTIQ mode
               (cheap approximate deleteMin buys throughput back at the
               cost of strict order — exactly the SmartPQ adaptation axis,
               commandeered as a load-shedding lever for best-effort work)
  SHEDDING  -> reject new arrivals of this class at admission, with
               explicit per-class drop accounting

Class 0 (interactive) is protected: it never enters SHEDDING and never
votes for relaxed mode — under overload the lower classes are sacrificed
so the highest class's p99 stays within target (the BENCH_pq overload
sweep's acceptance bar).  Backlogs are additionally hard-capped: `evict`
drops the newest lowest-class entries once the cap is hit, bounding memory
under any arrival storm (asserted in tests/test_faults.py).

Degradation decisions use *censored* observations too: under hard overload
a starved class completes nothing, so completion-time samples alone would
read as "no data, all fine".  Callers therefore also feed the current
waiting time of still-pending requests (`observe_pending`); a request that
has already waited past target is evidence of violation even though it
hasn't finished.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

# Controller states, ordered by severity.
OK = 0
DEGRADED = 1
SHEDDING = 2

_STATE_NAMES = {OK: "ok", DEGRADED: "degraded", SHEDDING: "shedding"}


@dataclasses.dataclass(frozen=True)
class OverloadConfig:
    """Per-class queueing-delay targets (steps) and controller knobs.

    ``targets[c]`` is the p99 queueing-delay budget for SLO class c;
    classes beyond the tuple reuse the last entry.  A class DEGRADES when
    its observed p99 crosses ``degrade_margin * target`` and SHEDS when it
    crosses ``target`` (class 0 exempt from shedding).  Recovery requires
    the p99 to fall below ``recover_margin * target`` — the hysteresis gap
    prevents flapping at the boundary."""

    targets: Tuple[float, ...] = (8.0, 32.0, 128.0)
    backlog_cap: int = 4096  # across all classes; evict() enforces
    window: int = 256  # queueing-delay samples kept per class
    degrade_margin: float = 0.75
    recover_margin: float = 0.5
    min_samples: int = 8  # below this, a class never escalates

    def target(self, slo_class: int) -> float:
        c = min(max(int(slo_class), 0), len(self.targets) - 1)
        return float(self.targets[c])


@dataclasses.dataclass
class OverloadStats:
    shed: Dict[int, int] = dataclasses.field(default_factory=dict)
    evicted: Dict[int, int] = dataclasses.field(default_factory=dict)
    degraded_ticks: int = 0  # ticks where >=1 class voted MULTIQ
    shedding_ticks: int = 0  # ticks where >=1 class was SHEDDING

    def total_shed(self) -> int:
        return sum(self.shed.values()) + sum(self.evicted.values())


class OverloadController:
    """Per-SLO-class backlog/latency watchdog driving graceful degradation.

    Protocol per scheduler tick:
      1. `observe(cls, delay)` for each completion's queueing delay, and
         `observe_pending(cls, waited)` for still-queued requests (censored
         samples — counted only when already past target).
      2. `update(backlog_by_class)` recomputes per-class states.
      3. `admit(requests)` filters arrivals (returns kept, shed).
      4. `mode_override()` yields the PQ mode vote (-1 = none).
      5. `evict(backlog)` trims the backlog to the cap.
    """

    def __init__(self, config: OverloadConfig | None = None, obs=None):
        self.config = config or OverloadConfig()
        self.state: Dict[int, int] = {}
        self.stats = OverloadStats()
        self._samples: Dict[int, List[float]] = {}
        self._censored: Dict[int, int] = {}  # pending-past-target counts
        # Observability bundle (repro.obs.Observability) — state
        # transitions emit counters + timeline instants through it.  The
        # owning scheduler/engine attaches its own; None stays silent.
        self.obs = obs

    def _set_state(self, c: int, new: int) -> None:
        """The ONLY place a class's state changes: every edge is counted
        (``overload_transitions_total{slo=,to=}``) and lands on the
        timeline as an `overload_state` instant."""
        old = self.state.get(c, OK)
        self.state[c] = new
        if new == old or self.obs is None:
            return
        self.obs.metrics.inc(
            "overload_transitions_total", slo=c, to=_STATE_NAMES[new]
        )
        self.obs.tracer.instant(
            "overload_state", cat="overload", slo=c,
            from_state=_STATE_NAMES[old], to_state=_STATE_NAMES[new],
        )

    # -- observation ------------------------------------------------------

    def observe(self, slo_class: int, delay: float) -> None:
        buf = self._samples.setdefault(int(slo_class), [])
        buf.append(float(delay))
        if len(buf) > self.config.window:
            del buf[: len(buf) - self.config.window]

    def observe_pending(self, slo_class: int, waited: float) -> None:
        # Censored: the eventual delay is >= waited; it only becomes
        # evidence once it already exceeds the class target.
        if float(waited) > self.config.target(slo_class):
            c = int(slo_class)
            self._censored[c] = self._censored.get(c, 0) + 1

    def p99(self, slo_class: int) -> float:
        buf = self._samples.get(int(slo_class), [])
        if not buf:
            return 0.0
        return float(np.percentile(np.asarray(buf), 99))

    # -- control law ------------------------------------------------------

    def update(self, backlog_by_class: Dict[int, int] | None = None) -> None:
        cfg = self.config
        for c in set(self._samples) | set(self._censored) | set(self.state):
            tgt = cfg.target(c)
            n = len(self._samples.get(c, []))
            censored = self._censored.get(c, 0)
            # Censored observations saturate the percentile: enough
            # past-target waiters means the true p99 exceeds target no
            # matter what the completed samples say.
            p = self.p99(c)
            if censored >= max(cfg.min_samples, (n + censored) // 100 + 1):
                p = max(p, tgt + 1.0)
            cur = self.state.get(c, OK)
            if n + censored < cfg.min_samples:
                continue
            if cur == OK:
                if p > tgt and c > 0:
                    self._set_state(c, SHEDDING)
                elif p > cfg.degrade_margin * tgt:
                    self._set_state(c, DEGRADED)
            elif cur == DEGRADED:
                if p > tgt and c > 0:
                    self._set_state(c, SHEDDING)
                elif p < cfg.recover_margin * tgt:
                    self._set_state(c, OK)
            elif cur == SHEDDING:
                if p < cfg.recover_margin * tgt:
                    self._set_state(c, OK)
                elif p < cfg.degrade_margin * tgt:
                    self._set_state(c, DEGRADED)
        self._censored.clear()
        if any(s == DEGRADED for s in self.state.values()):
            self.stats.degraded_ticks += 1
        if any(s == SHEDDING for s in self.state.values()):
            self.stats.shedding_ticks += 1

    # -- actuation --------------------------------------------------------

    def admit(self, requests: Sequence) -> Tuple[list, list]:
        """Split arrivals into (kept, shed) by the current per-class state.
        Shed requests are counted in `stats.shed` — drops are explicit,
        never silent."""
        kept, shed = [], []
        for r in requests:
            c = int(getattr(r, "slo_class", 0))
            if self.state.get(c, OK) == SHEDDING and c > 0:
                shed.append(r)
                self.stats.shed[c] = self.stats.shed.get(c, 0) + 1
            else:
                kept.append(r)
        return kept, shed

    def mode_override(self) -> int:
        """PQ mode vote: MULTIQ (1) while any best-effort class (c > 0) is
        DEGRADED or worse, else -1 (no override — the classifier rules).
        Relaxed deleteMin trades strict SLO order for throughput, which is
        the right trade while ONLY lower classes are drowning — the mode is
        queue-global, so the vote is gated on the protected class being
        healthy: the moment class 0 leaves OK, the override drops and
        strict SLO order returns (measured: an ungated override inverts
        class-0 priority under mixed overload and multiplies its p99)."""
        from repro.core.smartpq import MODE_MULTIQ

        if self.state.get(0, OK) != OK:
            return -1
        if any(
            s >= DEGRADED for c, s in self.state.items() if c > 0
        ):
            return int(MODE_MULTIQ)
        return -1

    def evict(self, backlog: List) -> List[object]:
        """Trim `backlog` (in place) to `config.backlog_cap`, dropping the
        newest lowest-SLO-class entries first; returns the evicted
        requests.  This bounds host memory under arrival storms no matter
        what the admission filter let through."""
        cap = self.config.backlog_cap
        excess = len(backlog) - cap
        if excess <= 0:
            return []
        # Sort victim candidates: lowest class last (class asc), newest
        # last within class — then peel from the end.
        order = sorted(
            range(len(backlog)),
            key=lambda i: (
                int(getattr(backlog[i], "slo_class", 0)),
                int(getattr(backlog[i], "arrival_step", i)),
            ),
        )
        victims = set(order[-excess:])
        evicted = [backlog[i] for i in sorted(victims)]
        backlog[:] = [r for i, r in enumerate(backlog) if i not in victims]
        for r in evicted:
            c = int(getattr(r, "slo_class", 0))
            self.stats.evicted[c] = self.stats.evicted.get(c, 0) + 1
        return evicted

    # -- persistence ------------------------------------------------------

    def state_dict(self) -> Dict[str, object]:
        """JSON-able controller state (everything `update` evolves —
        per-class states, sliding sample windows, censored counts, stats).
        The durability layer stores this inside the snapshot manifest;
        `load_state_dict` must restore it bit-for-bit, or a replayed
        window would shed a different request set than the original run."""
        return {
            "state": {str(c): s for c, s in self.state.items()},
            "samples": {str(c): list(v) for c, v in self._samples.items()},
            "censored": {str(c): n for c, n in self._censored.items()},
            "stats": {
                "shed": {str(c): n for c, n in self.stats.shed.items()},
                "evicted": {
                    str(c): n for c, n in self.stats.evicted.items()
                },
                "degraded_ticks": self.stats.degraded_ticks,
                "shedding_ticks": self.stats.shedding_ticks,
            },
        }

    def load_state_dict(self, d: Dict[str, object]) -> None:
        self.state = {int(c): int(s) for c, s in d["state"].items()}
        self._samples = {
            int(c): [float(x) for x in v]
            for c, v in d["samples"].items()
        }
        self._censored = {
            int(c): int(n) for c, n in d["censored"].items()
        }
        st = d["stats"]
        self.stats = OverloadStats(
            shed={int(c): int(n) for c, n in st["shed"].items()},
            evicted={int(c): int(n) for c, n in st["evicted"].items()},
            degraded_ticks=int(st["degraded_ticks"]),
            shedding_ticks=int(st["shedding_ticks"]),
        )

    # -- reporting --------------------------------------------------------

    def snapshot(self) -> Dict[str, object]:
        return {
            "state": {
                c: _STATE_NAMES[s] for c, s in sorted(self.state.items())
            },
            "p99": {c: self.p99(c) for c in sorted(self._samples)},
            "shed": dict(self.stats.shed),
            "evicted": dict(self.stats.evicted),
            "degraded_ticks": self.stats.degraded_ticks,
            "shedding_ticks": self.stats.shedding_ticks,
        }


__all__ = [
    "OK", "DEGRADED", "SHEDDING",
    "OverloadConfig", "OverloadStats", "OverloadController",
]
