from repro.serve.scheduler import (  # noqa: F401
    Request,
    SchedulerCheckpoint,
    SchedulerStats,
    SmartPQScheduler,
)
from repro.serve.engine import ServeEngine, EngineConfig  # noqa: F401
from repro.serve.overload import (  # noqa: F401
    OverloadConfig,
    OverloadController,
)
from repro.serve.durability import (  # noqa: F401
    DurabilityConfig,
    DurabilityStats,
    DurableStore,
    WriteAheadLog,
)
from repro.serve.supervisor import (  # noqa: F401
    Supervisor,
    SupervisorConfig,
    SupervisorReport,
)
