from repro.serve.scheduler import SmartPQScheduler, Request  # noqa: F401
from repro.serve.engine import ServeEngine, EngineConfig  # noqa: F401
