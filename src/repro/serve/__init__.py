from repro.serve.scheduler import (  # noqa: F401
    Request,
    SchedulerCheckpoint,
    SchedulerStats,
    SmartPQScheduler,
)
from repro.serve.engine import ServeEngine, EngineConfig  # noqa: F401
from repro.serve.overload import (  # noqa: F401
    OverloadConfig,
    OverloadController,
)
