"""repro.serve.worker — a durable serving process the supervisor can run.

One self-contained incarnation of the serving engine: regenerate the
deterministic open-loop workload from (seed, steps) — the stateless
request-stream contract means every incarnation sees the identical
arrival schedule — build a durable `ServeEngine` rooted at ``--dir``,
and `run()` it.  `run()` begins with `recover()`, so a worker started on
a directory holding a snapshot + WAL resumes exactly where the previous
incarnation died; a worker started on an empty directory is a fresh run.
Either way the finishing incarnation writes one atomic result JSON with
the summary, the structured `health()` surface, the completion set, and
the request-conservation ledger — the artifacts the crash-recovery tests
diff bit-for-bit between an uninterrupted run and a killed-and-recovered
one.

``--sigkill-at-step N`` arms the `crash_at_step` fault injector: the
process SIGKILLs itself when the engine-step clock reaches N, after the
window's arrivals hit the WAL but before its commit.  With
``--crash-marker PATH`` the kill is one-shot (the marker is written just
before dying), so the same command line works as a supervised child:
first incarnation crashes, the restart finds the marker and completes.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional, Sequence

from repro.core import persist
from repro.serve.engine import EngineConfig, ServeEngine


def build_engine(args: argparse.Namespace) -> ServeEngine:
    slo = None
    if args.slo_targets:
        slo = tuple(float(x) for x in args.slo_targets.split(","))
    ecfg = EngineConfig(
        batch_size=args.batch,
        sched_window=args.window,
        slo_targets=slo,
        durable_dir=args.dir,
        wal_fsync=not args.no_fsync,
        snapshot_interval=args.snapshot_interval,
        keep_snapshots=args.keep_snapshots,
    )
    return ServeEngine(None, None, ecfg, seed=args.seed)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", required=True,
                    help="durable store root (WAL + snapshots + heartbeat)")
    ap.add_argument("--out", default=None,
                    help="result JSON path (atomic write)")
    ap.add_argument("--steps", type=int, default=48,
                    help="workload length in engine steps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--window", type=int, default=1,
                    help="scheduler window K (ticks per fused device call)")
    ap.add_argument("--max-steps", type=int, default=10_000)
    ap.add_argument("--snapshot-interval", type=int, default=4)
    ap.add_argument("--keep-snapshots", type=int, default=2)
    ap.add_argument("--no-fsync", action="store_true")
    ap.add_argument("--slo-targets", default="",
                    help="comma-separated p99 targets; empty = open loop")
    ap.add_argument("--sigkill-at-step", type=int, default=-1,
                    help="SIGKILL self at this engine step (fault drill)")
    ap.add_argument("--crash-marker", default="",
                    help="marker file making --sigkill-at-step one-shot")
    args = ap.parse_args(argv)

    from repro.workloads.traces import bursty_serve_workload

    workload: List[List] = bursty_serve_workload(
        steps=args.steps, seed=args.seed
    )
    total_requests = sum(len(tick) for tick in workload)

    eng = build_engine(args)
    if args.sigkill_at_step >= 0:
        from repro.faults import FaultSpec, inject

        inject(eng, FaultSpec(
            "crash_at_step",
            magnitude=float(args.sigkill_at_step),
            variant=args.crash_marker,
        ))

    summary = eng.run(workload, max_steps=args.max_steps)
    health = eng.health()

    # Request conservation: every submitted arrival is accounted for as
    # inserted, still backlogged, shed, or evicted — and every insert is
    # either dispatched or still on device.  The recovery tests assert
    # this ledger matches an uninterrupted run's exactly.
    conservation = {
        "total_requests": total_requests,
        "inserted": health["inserted"],
        "arrival_backlog": health["arrival_backlog"],
        "shed": health["shed"],
        "evicted": health["evicted"],
        "dispatched": health["dispatched"],
        "on_device": health["on_device"],
        "admitted_ok": (
            health["inserted"] + health["arrival_backlog"]
            + health["shed"] + health["evicted"] == total_requests
        ),
        "dispatch_ok": (
            health["inserted"]
            == health["dispatched"] + health["on_device"]
        ),
    }

    from repro.core.smartpq import carry_fingerprint

    done = sorted(eng.done_step)
    result = {
        "summary": {k: v for k, v in summary.items() if k != "wall_s"},
        "wall_s": summary["wall_s"],
        "health": health,
        "conservation": conservation,
        "completions": done,
        "done_step": {str(u): eng.done_step[u] for u in done},
        "outputs_crc": _outputs_crc(eng.outputs),
        "carry_crc": carry_fingerprint(eng.scheduler.carry),
    }
    if args.out:
        persist.atomic_write_json(args.out, result, indent=2)
    else:
        import json

        print(json.dumps(result["conservation"]))
    eng.durability.close()
    ok = conservation["admitted_ok"] and conservation["dispatch_ok"]
    return 0 if ok else 3


def _outputs_crc(outputs) -> int:
    """Order-insensitive CRC over every request's emitted token list —
    completion CONTENT identity, complementing the carry fingerprint's
    device-state identity."""
    import json
    import zlib

    blob = json.dumps(
        {str(u): outputs[u] for u in sorted(outputs)},
        separators=(",", ":"),
    ).encode()
    return zlib.crc32(blob) & 0xFFFFFFFF


if __name__ == "__main__":
    sys.exit(main())


__all__ = ["build_engine", "main"]
