"""repro.serve.durability — write-ahead admission log + crash-consistent
snapshots for the serving tier.

The serving engine's correctness story so far (window rollback, overload
accounting, conservation invariants) lives in process memory: a SIGKILL
between windows loses the device queue, the in-flight request map, the
backlogs, and the overload controller — exactly the state that cannot be
reconstructed after the fact.  This module makes the window loop durable
with the classic database recipe, specialized to the engine's determinism
guarantees:

  WAL        every window's arrivals are appended to a CRC-framed
             write-ahead log and fsynced BEFORE the window executes;
             a commit record (fsynced) marks the window done.  Sheds and
             evictions are logged too — informational (replay re-derives
             them deterministically), but they make the drop accounting
             auditable from the log alone.  Torn tails (a crash mid-
             append) are DETECTED by the frame CRC and truncated away on
             recovery, never crashed on; only unacknowledged records —
             ones whose fsync never returned — can be lost, which is the
             WAL contract.
  SNAPSHOT   every `snapshot_interval` windows the full scheduler/engine
             state — PQState pytree, rng key, admission ring backlogs,
             in-flight maps, overload controller, stats, step counters —
             is written via `repro.core.persist.save_tree` (tmp + rename
             + manifest + per-shard CRC, the same machinery as training
             checkpoints) with the host-side state in the manifest's
             `extra` and the carry's `carry_fingerprint` stamped in for
             end-to-end integrity.
  RECOVERY   load the NEWEST VALID snapshot (corrupt/partial/stale ones
             are skipped with accounting, falling back to older ones or a
             fresh init), then replay the WAL's window suffix through the
             ordinary deterministic `tick_window` path.  Because every
             input of a window (arrivals, rng stream, budgets-from-state,
             controller state) is either in the snapshot or in the WAL,
             the replayed run is bit-identical to the uninterrupted one —
             the crash-recovery tests assert completion sets, request
             conservation, and the carry fingerprint match exactly.

Framing: each WAL record is ``<u32 len><u32 crc32(payload)><payload>``
(little-endian, payload = compact JSON).  No record spans a frame; a
frame that fails the length or CRC check ends the readable prefix.
"""

from __future__ import annotations

import dataclasses
import json
import os
import struct
import time
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.core import persist
from repro.serve.scheduler import Request

_FRAME = struct.Struct("<II")  # payload length, payload crc32
_MAX_RECORD = 1 << 28  # sanity bound: a "length" beyond this is corruption


def request_to_dict(r: Request) -> Dict[str, int]:
    return dataclasses.asdict(r)


def request_from_dict(d: Dict[str, int]) -> Request:
    return Request(**{k: int(v) for k, v in d.items()})


@dataclasses.dataclass
class DurabilityConfig:
    """Knobs for the WAL + snapshot layer.

    ``fsync=False`` keeps the append/commit ordering but skips the
    physical sync — the benchmark's "how much of the overhead is the
    disk" probe; a production run leaves it on."""

    dir: str | Path
    fsync: bool = True
    snapshot_interval: int = 4  # windows between snapshots (>=1)
    keep_snapshots: int = 2


@dataclasses.dataclass
class DurabilityStats:
    """Counters surfaced through `ServeEngine.health()["durability"]`."""

    records_appended: int = 0
    bytes_appended: int = 0
    commits: int = 0
    last_commit_step: int = -1
    torn_records_dropped: int = 0
    torn_bytes_dropped: int = 0
    replayed_windows: int = 0
    replayed_records: int = 0
    snapshots_written: int = 0
    snapshots_skipped_invalid: int = 0
    last_snapshot_step: int = -1

    def as_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


class WriteAheadLog:
    """Append-only CRC-framed record log with torn-tail recovery."""

    def __init__(self, path: str | Path, fsync: bool = True):
        self.path = Path(path)
        self.fsync = fsync
        self._fh = None  # opened lazily, AFTER recover() truncated the tail

    # -- read side ---------------------------------------------------------

    def recover(self) -> Tuple[List[dict], int, int]:
        """Scan the log, parse every whole valid frame, and TRUNCATE the
        file to that prefix.  Returns ``(records, dropped_records,
        dropped_bytes)`` — a torn tail (short header, short payload, CRC
        mismatch, unparseable JSON) is an expected crash artifact, not an
        error."""
        if not self.path.exists():
            return [], 0, 0
        blob = self.path.read_bytes()
        records: List[dict] = []
        off = 0
        while off + _FRAME.size <= len(blob):
            length, crc = _FRAME.unpack_from(blob, off)
            start = off + _FRAME.size
            if length > _MAX_RECORD or start + length > len(blob):
                break
            payload = blob[start:start + length]
            if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                break
            try:
                records.append(json.loads(payload.decode("utf-8")))
            except ValueError:
                break
            off = start + length
        dropped_bytes = len(blob) - off
        if dropped_bytes:
            with open(self.path, "r+b") as f:
                f.truncate(off)
                if self.fsync:
                    f.flush()
                    os.fsync(f.fileno())
        # dropped record count: at most one frame is torn; anything beyond
        # it is unreadable, so count frames conservatively as >= 1
        dropped_records = 1 if dropped_bytes else 0
        return records, dropped_records, dropped_bytes

    # -- write side --------------------------------------------------------

    def _handle(self):
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "ab")
        return self._fh

    def append(self, record: dict) -> int:
        """Buffered append of one frame; returns the frame's byte size.
        Call `sync()` to make everything appended so far durable."""
        payload = json.dumps(record, separators=(",", ":")).encode("utf-8")
        frame = _FRAME.pack(
            len(payload), zlib.crc32(payload) & 0xFFFFFFFF
        ) + payload
        self._handle().write(frame)
        return len(frame)

    def sync(self) -> None:
        fh = self._handle()
        fh.flush()
        if self.fsync:
            os.fsync(fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None


class DurableStore:
    """The engine-facing durability surface: one WAL + a snapshot tree +
    a heartbeat file, rooted at ``cfg.dir``.

    Layout:
      <dir>/wal.log                  — CRC-framed admission/commit log
      <dir>/snapshots/step_<N>/      — persist.save_tree manifests
      <dir>/heartbeat.json           — liveness beacon (step + wall time),
                                       atomically rewritten at every
                                       commit; the supervisor watches its
                                       mtime to detect hangs
    """

    def __init__(self, cfg: DurabilityConfig, obs=None):
        if obs is None:
            from repro.obs import NULL as obs  # disabled bundle
        self.cfg = cfg
        self.obs = obs
        self.root = Path(cfg.dir)
        self.root.mkdir(parents=True, exist_ok=True)
        self.wal = WriteAheadLog(self.root / "wal.log", fsync=cfg.fsync)
        self.snap_root = self.root / "snapshots"
        self.heartbeat_path = self.root / "heartbeat.json"
        self.stats = DurabilityStats()
        self._windows_since_snapshot = 0
        self._records: Optional[List[dict]] = None  # recover() cache
        self.suppress_events = False  # replay re-derives sheds/evicts

    # -- WAL façade --------------------------------------------------------

    def read_wal(self) -> List[dict]:
        """Recover-read the log once (truncating any torn tail) and cache
        the parsed records for this process."""
        if self._records is None:
            records, dropped_r, dropped_b = self.wal.recover()
            self._records = records
            self.stats.torn_records_dropped += dropped_r
            self.stats.torn_bytes_dropped += dropped_b
        return self._records

    def _append(self, record: dict) -> None:
        n = self.wal.append(record)
        self.stats.records_appended += 1
        self.stats.bytes_appended += n

    def log_window(self, step0: int,
                   arrivals_by_tick: List[List[Request]]) -> None:
        """WRITE-AHEAD: durably record a window's admissions before any of
        them execute — fsynced, so a crash mid-window can replay it."""
        self._append({
            "kind": "window",
            "step0": int(step0),
            "arrivals": [
                [request_to_dict(r) for r in tick]
                for tick in arrivals_by_tick
            ],
        })
        self.wal.sync()
        self.obs.metrics.inc("wal_syncs_total", kind="window")
        self.obs.tracer.instant("wal_fsync", cat="durability",
                                kind="window", step0=int(step0))

    def log_event(self, kind: str, payload: Dict[str, Any]) -> None:
        """Buffered informational record (shed/evict) — made durable by
        the window's commit sync.  Suppressed during replay: the replayed
        window re-derives the same drops deterministically, and double-
        logging would corrupt the audit trail."""
        if self.suppress_events:
            return
        self._append({"kind": kind, **payload})

    def log_commit(self, step: int,
                   health: Optional[Dict[str, Any]] = None) -> None:
        rec = {"kind": "commit", "step": int(step)}
        if health:
            rec["health"] = health
        self._append(rec)
        self.wal.sync()
        self.stats.commits += 1
        self.stats.last_commit_step = int(step)
        self.obs.metrics.inc("wal_syncs_total", kind="commit")
        self.obs.tracer.instant("wal_fsync", cat="durability",
                                kind="commit", step=int(step))
        beat = {"step": int(step), "time": time.time(),
                "commits": self.stats.commits}
        if health:
            # the last known metrics snapshot rides the heartbeat, so a
            # hang/crash post-mortem reads counters, not just a step
            beat["metrics"] = health
        persist.atomic_write_json(
            self.heartbeat_path, beat,
            fsync=False,  # advisory liveness beacon, not a recovery input
        )

    def window_suffix(self, after_step: int) -> List[dict]:
        """The committed-or-torn window records to replay after a snapshot
        taken at engine step `after_step` (window records whose first tick
        is at or past it)."""
        return [
            r for r in self.read_wal()
            if r.get("kind") == "window" and r["step0"] >= after_step
        ]

    # -- snapshots ---------------------------------------------------------

    def should_snapshot(self) -> bool:
        return (
            self._windows_since_snapshot >= max(self.cfg.snapshot_interval, 1)
        )

    def window_committed(self) -> None:
        self._windows_since_snapshot += 1

    def snapshot(self, step: int, arrays: Any,
                 host_state: Dict[str, Any]) -> Path:
        """Crash-consistent snapshot: array pytree in CRC'd npz shards,
        host state in the manifest `extra` — atomic via tmp+rename, so a
        crash mid-snapshot leaves the previous snapshot intact."""
        with self.obs.tracer.span("snapshot", cat="durability",
                                  step=int(step)):
            path = persist.save_tree(
                self.snap_root, int(step), arrays,
                extra=host_state, fsync=self.cfg.fsync,
            )
            persist.prune_steps(self.snap_root, self.cfg.keep_snapshots)
        self._windows_since_snapshot = 0
        self.stats.snapshots_written += 1
        self.stats.last_snapshot_step = int(step)
        self.obs.metrics.inc("snapshots_total")
        return path

    def load_newest_valid(
        self, like: Any
    ) -> Optional[Tuple[int, Any, Dict[str, Any]]]:
        """Load the newest snapshot that validates (manifest + shard CRCs
        + leaf index), skipping damaged ones with accounting.  Returns
        ``(step, arrays, host_state)`` or None when nothing valid exists
        (recovery then replays the whole WAL from a fresh init)."""
        from repro.core.errors import SnapshotCorruptError

        steps = persist.available_steps(self.snap_root)
        pointed = persist.latest_step(self.snap_root)
        if pointed is not None and pointed in steps:
            steps.remove(pointed)
            steps.insert(0, pointed)
        for step in steps:
            try:
                tree, manifest = persist.load_tree(
                    self.snap_root, like, step, validate=True
                )
            except SnapshotCorruptError:
                # absorbed with accounting — an older snapshot (or fresh
                # init) takes over; the error is still OBSERVED
                self.stats.snapshots_skipped_invalid += 1
                self.obs.metrics.inc(
                    "errors_total", code="SNAPSHOT_CORRUPT"
                )
                self.obs.tracer.instant(
                    "snapshot_skipped", cat="durability", step=int(step)
                )
                continue
            return step, tree, manifest["extra"]
        return None

    def close(self) -> None:
        self.wal.close()


__all__ = [
    "DurabilityConfig", "DurabilityStats", "DurableStore",
    "WriteAheadLog", "request_to_dict", "request_from_dict",
]
