"""Serving engine: prefill/decode with continuous batching via SmartPQ.

Host loop (single-controller; multi-host serving shards the same jitted
steps over the production mesh):

  while True:
      arrivals  -> scheduler.tick()  (SmartPQ insert/delete on device)
      new reqs  -> prefill_step      (fills KV cache slots)
      all slots -> serve_step        (one token for every active slot)
      finished  -> release slots

KV memory is slot-paged: a fixed pool of `batch_size` cache slots; the
scheduler admits a request only when a slot is free (capacity-rejected
inserts retry next tick — the same MoE-style overflow contract the PQ's
`route_capped` uses).

With `sched_window > 1` the engine batches K scheduler ticks into one
fused device call (`SmartPQScheduler.tick_window`) and spreads the
window's dispatch budget across ticks with a slot-availability forecast:
tick 0 gets the free slots visible at window start, and tick t adds the
slots predicted to free during the window — the count of active slots
whose `remaining` token budget runs out by tick t, plus an expected-value
EOS-hazard term for early stops.  The forecast is advisory only:
over-admissions park in the engine's admit backlog and fill slots as they
actually free, so completions never depend on it (disable with
`forecast=False` to reproduce the window-start-budget baseline, whose
dispatch stream is bit-identical to K sequential single ticks).

`cfg=None` runs a model-free synthetic decode (next token derived from
the current token, never EOS) — the same engine loop without building a
model, used by the SLO benchmarks and the fast-lane window-semantics
tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.scheduler import Request, SmartPQScheduler


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 8  # concurrent decode slots
    max_seq: int = 512
    eos_token: int = 2
    kv_chunk: int = 2048
    # Scheduler dispatch granularity: >1 batches K ticks into ONE fused
    # device call (scheduler.tick_window) instead of K per-step dispatches.
    sched_window: int = 1
    # Mid-window admission: derive per-tick dispatch budgets from the
    # slot-availability forecast instead of freezing the window-start free
    # count.  Off -> budgets [free, 0, ..., 0], the pre-forecast baseline.
    forecast: bool = True
    # Per-step probability an active slot stops early (EOS) — folded into
    # the forecast as an expected-completions term.  0 trusts `remaining`
    # alone (exact for synthetic decode, conservative for real models).
    eos_hazard: float = 0.0
    # Overload control: per-SLO-class p99 queueing-delay targets (engine
    # steps).  None (default) -> open-loop admission, exactly the
    # pre-overload engine.  Set -> an OverloadController gates admission
    # (shed/degrade low classes, cap backlogs) so the highest class's p99
    # holds under sustained overload.
    slo_targets: Optional[Tuple[float, ...]] = None
    # Host backlog bound (scheduler arrival backlog eviction cap + engine
    # admit-backlog requeue threshold) — only enforced with control on.
    backlog_cap: int = 4096
    # Arm the PQ's runtime guard tier (SmartPQConfig.validate): every
    # scheduler window validates invariants against a pre-window
    # checkpoint, rolling back + retrying conservatively on violation.
    validate: bool = False


class ServeEngine:
    """Small-model serving loop (CPU-runnable end-to-end example)."""

    def __init__(self, cfg: Optional[ModelConfig], params,
                 engine_cfg: EngineConfig, mesh=None, seed: int = 0):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.params = params
        B, S = engine_cfg.batch_size, engine_cfg.max_seq
        if cfg is not None:
            from repro.models.io import init_caches
            from repro.models.registry import build_model

            self.model = build_model(cfg, mesh=mesh, remat=False,
                                     kv_chunk=engine_cfg.kv_chunk)
            self.caches = init_caches(cfg, B, S)
            self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        else:  # model-free synthetic decode: scheduler/engine loop only
            self.model = None
            self.caches = ()
            self._decode = jax.jit(_synthetic_decode)
        overload = None
        if engine_cfg.slo_targets is not None:
            from repro.serve.overload import OverloadConfig, OverloadController

            overload = OverloadController(OverloadConfig(
                targets=tuple(engine_cfg.slo_targets),
                backlog_cap=engine_cfg.backlog_cap,
            ))
        self.overload = overload
        pq_config = None
        if engine_cfg.validate:
            from repro.core.smartpq import MODE_AWARE, SmartPQConfig

            # The scheduler's default queue geometry, with the runtime
            # guard tier armed.
            pq_config = SmartPQConfig(
                num_shards=16, capacity=8192, npods=2, decision_interval=4,
                initial_mode=MODE_AWARE, validate=True,
            )
        self.scheduler = SmartPQScheduler(
            batch_size=64, seed=seed, pq_config=pq_config, overload=overload,
        )
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.lengths = jnp.zeros((B,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * B
        self.remaining = np.zeros(B, np.int64)
        self.outputs: Dict[int, List[int]] = {}
        self._backlog: List[Request] = []  # dispatched, awaiting a free slot
        # SLO accounting (engine-step clock): arrival -> admission -> done.
        self.arrival_step: Dict[int, int] = {}
        self.admit_step: Dict[int, int] = {}
        self.done_step: Dict[int, int] = {}
        self.slo: Dict[int, int] = {}  # uid -> SLO class (set at arrival)
        # EMA of observed service times (tokens emitted per completed
        # request) — the forecast's slot-recycling horizon.  The prior only
        # matters for the first window; completions tighten it online.
        self._service_est = 8.0
        self._step = 0

    # -- admission -------------------------------------------------------------

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _admit(self, reqs: List[Request]):
        reqs = self._backlog + list(reqs)
        slots = self._free_slots()
        self._backlog = reqs[len(slots):]
        if (
            self.overload is not None
            and len(self._backlog) > self.ecfg.backlog_cap
        ):
            # The admit backlog is NOT priority-ordered — a forecast gone
            # wrong (see faults.forecast_extreme) could grow it without
            # bound and serve it FIFO, inverting SLO order.  Overflow goes
            # BACK to the priority queue instead of being dropped: already-
            # admitted work is never lost, and it re-dispatches in SLO
            # order when slots actually free.
            overflow = self._backlog[self.ecfg.backlog_cap:]
            del self._backlog[self.ecfg.backlog_cap:]
            self.scheduler.requeue(overflow)
        for slot, req in zip(slots, reqs):
            # Prompt "prefill" for the example engine: teacher-forced decode
            # of the prompt tokens (prompt = synthetic [uid-derived] tokens).
            self.active[slot] = req
            self.remaining[slot] = req.max_new_tokens
            self.outputs[req.uid] = []
            self.admit_step[req.uid] = self._step
            self.tokens = self.tokens.at[slot, 0].set(req.uid % 100 + 3)
            self.lengths = self.lengths.at[slot].set(0)

    def _note_arrivals(self, arrivals: List[Request], step: int):
        """Stamp arrival time on the engine-step clock: the scheduler's
        aging term and the SLO latency records both key off it."""
        for r in arrivals:
            r.arrival_step = step
            self.arrival_step[r.uid] = step
            self.slo[r.uid] = r.slo_class

    # -- slot-availability forecast ---------------------------------------------

    def _window_budgets(self, K: int) -> List[int]:
        """Per-tick dispatch budgets for the next K-tick window.

        budgets[0] is the free-slot count at window start (the baseline's
        whole budget).  With the forecast on, budgets[t>0] adds the slots
        predicted to free at tick t: (a) active slots whose `remaining`
        token budget runs out (a slot with remaining == t frees for
        admission at tick t), (b) the accumulated-and-floored expectation
        of EOS early stops among slots still running, and (c) SLOT
        RECYCLING — every predicted admission is itself projected to hold
        its slot for `_service_est` ticks and free it again, so long
        windows keep their slots saturated instead of predicting only one
        generation of completions.  Over-prediction is safe: dispatches
        beyond the queue depth are no-ops, and over-admissions park in the
        admit backlog until a slot actually frees."""
        budgets = [len(self._free_slots())] + [0] * (K - 1)
        if not self.ecfg.forecast:
            return budgets
        rem = [int(self.remaining[i]) for i, r in enumerate(self.active)
               if r is not None]
        # (a) deterministic completions of the currently active slots
        frees = [0] * K
        for r in rem:
            if 1 <= r < K:
                frees[r] += 1
        # (b) expected EOS early stops, credited as they accumulate to 1
        h = self.ecfg.eos_hazard
        if h > 0.0:
            acc, credited = 0.0, 0
            for t in range(1, K):
                acc += h * sum(1 for r in rem if r > t)
                frees[t] += int(acc) - credited
                credited = int(acc)
        # (c) recycle: an admission at tick t frees its slot at t + est
        est = max(int(round(self._service_est)), 1)
        for t in range(1, K):
            if t - 1 + est < K:
                frees[t - 1 + est] += budgets[t - 1]
            budgets[t] += frees[t]
        return budgets

    # -- stepping ---------------------------------------------------------------

    def step(self, arrivals: List[Request],
             dispatched: Optional[List[Request]] = None) -> List[int]:
        """One engine tick.  Returns uids completed this step.  `dispatched`
        is pre-computed when the run loop batches scheduling through
        `tick_window`; otherwise the scheduler steps inline."""
        if dispatched is None:
            n_free = len(self._free_slots())
            dispatched = self.scheduler.tick(arrivals, n_dispatch=n_free)
        self._admit(dispatched)

        logits, self.caches = self._decode(
            self.params, self.caches, self.tokens, self.lengths
        )
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int64)
        self.lengths = self.lengths + (
            jnp.asarray([r is not None for r in self.active], jnp.int32)
        )
        self.tokens = jnp.asarray(next_tok[:, None].astype(np.int32))

        done = []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.outputs[req.uid].append(int(next_tok[i]))
            self.remaining[i] -= 1
            hit_eos = int(next_tok[i]) == self.ecfg.eos_token
            full = int(np.asarray(self.lengths)[i]) >= self.ecfg.max_seq - 1
            if self.remaining[i] <= 0 or hit_eos or full:
                done.append(req.uid)
                self.done_step[req.uid] = self._step
                self._service_est = (
                    0.9 * self._service_est + 0.1 * len(self.outputs[req.uid])
                )
                self.active[i] = None
        self._step += 1
        return done

    def run(self, workload: List[List[Request]], max_steps: int = 10_000):
        """Drive until the workload drains.  Returns summary stats.

        With `sched_window > 1` the scheduler runs one fused device call per
        K engine ticks; each tick's dispatch budget comes from
        `_window_budgets` — mid-window completions admit at the tick the
        forecast predicts them, and any over-admission parks in the admit
        backlog until a slot actually frees."""
        t0 = time.time()
        completed = 0
        step = 0
        K = max(1, self.ecfg.sched_window)
        while step < max_steps:
            if K > 1:
                arr = [
                    workload[step + i] if step + i < len(workload) else []
                    for i in range(K)
                ]
                for i, a in enumerate(arr):
                    self._note_arrivals(a, step + i)
                for d in self.scheduler.tick_window(arr, self._window_budgets(K)):
                    if step >= max_steps:
                        # already popped from the device queue — park for
                        # admission on a later run() instead of losing them
                        self._backlog.extend(d)
                        continue
                    completed += len(self.step([], dispatched=d))
                    step += 1
            else:
                arrivals = workload[step] if step < len(workload) else []
                self._note_arrivals(arrivals, step)
                completed += len(self.step(arrivals))
                step += 1
            if (
                step >= len(workload)
                and self.scheduler.pending == 0
                and not self._backlog
                and all(r is None for r in self.active)
            ):
                break
        sst = self.scheduler.stats
        return {
            "steps": step,
            "completed": completed,
            "wall_s": time.time() - t0,
            "mode_trace": sst.mode_trace,
            "pq_transitions": int(self.scheduler.carry.stats.transitions),
            "shed": sst.shed,
            "evicted": sst.evicted,
            "recovered_windows": sst.recovered_windows,
        }

    # -- SLO accounting ----------------------------------------------------------

    def latency_records(self) -> Dict[str, np.ndarray]:
        """Per-completed-request latency vectors on the engine-step clock:
        queueing delay (arrival -> slot admission), end-to-end latency, and
        per-token latency (end-to-end / tokens emitted) — the inputs to the
        serve_slo benchmark's p50/p99 records."""
        uids = sorted(self.done_step)
        queueing = np.array(
            [self.admit_step[u] - self.arrival_step.get(u, 0) for u in uids],
            np.float64,
        )
        e2e = np.array(
            [self.done_step[u] - self.arrival_step.get(u, 0) + 1 for u in uids],
            np.float64,
        )
        tokens = np.array(
            [max(len(self.outputs.get(u, ())), 1) for u in uids], np.float64
        )
        return {
            "uids": np.array(uids, np.int64),
            "slo": np.array([self.slo.get(u, 1) for u in uids], np.int64),
            "queueing_steps": queueing,
            "e2e_steps": e2e,
            "per_token_steps": e2e / tokens,
            "tokens": tokens,
        }


def _synthetic_decode(params, caches, tokens, lengths):
    """Model-free decode stub with the `decode_step` signature: the next
    token is a pure function of the current one and never hits the default
    EOS id (2), so completion timing is driven entirely by
    `max_new_tokens` — deterministic ground truth for scheduler tests and
    SLO benchmarks."""
    del params, lengths
    nxt = (tokens[:, 0] % 97) + 3
    return jax.nn.one_hot(nxt, 128, dtype=jnp.float32), caches
