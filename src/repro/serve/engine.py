"""Serving engine: prefill/decode with continuous batching via SmartPQ.

Host loop (single-controller; multi-host serving shards the same jitted
steps over the production mesh):

  while True:
      arrivals  -> scheduler.tick()  (SmartPQ insert/delete on device)
      new reqs  -> prefill_step      (fills KV cache slots)
      all slots -> serve_step        (one token for every active slot)
      finished  -> release slots

KV memory is slot-paged: a fixed pool of `batch_size` cache slots; the
scheduler admits a request only when a slot is free (capacity-rejected
inserts retry next tick — the same MoE-style overflow contract the PQ's
`route_capped` uses).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.io import init_caches
from repro.models.registry import build_model
from repro.serve.scheduler import Request, SmartPQScheduler


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 8  # concurrent decode slots
    max_seq: int = 512
    eos_token: int = 2
    kv_chunk: int = 2048
    # Scheduler dispatch granularity: >1 batches K ticks into ONE fused
    # SmartPQ.run_window device call (scheduler.tick_window) instead of K
    # per-step dispatches.  Dispatch decisions for the window are made with
    # the slot budget visible at the window start; over-admissions park in
    # the engine's admit backlog and fill slots as they free.
    sched_window: int = 1


class ServeEngine:
    """Small-model serving loop (CPU-runnable end-to-end example)."""

    def __init__(self, cfg: ModelConfig, params, engine_cfg: EngineConfig,
                 mesh=None, seed: int = 0):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.model = build_model(cfg, mesh=mesh, remat=False,
                                 kv_chunk=engine_cfg.kv_chunk)
        self.params = params
        self.scheduler = SmartPQScheduler(batch_size=64, seed=seed)
        B, S = engine_cfg.batch_size, engine_cfg.max_seq
        self.caches = init_caches(cfg, B, S)
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.lengths = jnp.zeros((B,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * B
        self.remaining = np.zeros(B, np.int64)
        self.outputs: Dict[int, List[int]] = {}
        self._backlog: List[Request] = []  # dispatched, awaiting a free slot
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        self._step = 0

    # -- admission -------------------------------------------------------------

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _admit(self, reqs: List[Request]):
        reqs = self._backlog + list(reqs)
        slots = self._free_slots()
        self._backlog = reqs[len(slots):]
        for slot, req in zip(slots, reqs):
            # Prompt "prefill" for the example engine: teacher-forced decode
            # of the prompt tokens (prompt = synthetic [uid-derived] tokens).
            self.active[slot] = req
            self.remaining[slot] = req.max_new_tokens
            self.outputs[req.uid] = []
            self.tokens = self.tokens.at[slot, 0].set(req.uid % 100 + 3)
            self.lengths = self.lengths.at[slot].set(0)

    # -- stepping ---------------------------------------------------------------

    def step(self, arrivals: List[Request],
             dispatched: Optional[List[Request]] = None) -> List[int]:
        """One engine tick.  Returns uids completed this step.  `dispatched`
        is pre-computed when the run loop batches scheduling through
        `tick_window`; otherwise the scheduler steps inline."""
        if dispatched is None:
            n_free = len(self._free_slots())
            dispatched = self.scheduler.tick(arrivals, n_dispatch=n_free)
        self._admit(dispatched)

        logits, self.caches = self._decode(
            self.params, self.caches, self.tokens, self.lengths
        )
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int64)
        self.lengths = self.lengths + (
            jnp.asarray([r is not None for r in self.active], jnp.int32)
        )
        self.tokens = jnp.asarray(next_tok[:, None].astype(np.int32))

        done = []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.outputs[req.uid].append(int(next_tok[i]))
            self.remaining[i] -= 1
            hit_eos = int(next_tok[i]) == self.ecfg.eos_token
            full = int(np.asarray(self.lengths)[i]) >= self.ecfg.max_seq - 1
            if self.remaining[i] <= 0 or hit_eos or full:
                done.append(req.uid)
                self.active[i] = None
        self._step += 1
        return done

    def run(self, workload: List[List[Request]], max_steps: int = 10_000):
        """Drive until the workload drains.  Returns summary stats.

        With `sched_window > 1` the scheduler runs one fused device call per
        K engine ticks: the window's dispatch budget is the free-slot count
        at its start (ticks past the first carry budget 0 — completions that
        free slots mid-window are absorbed by the admit backlog and the next
        window's budget)."""
        t0 = time.time()
        completed = 0
        step = 0
        K = max(1, self.ecfg.sched_window)
        while step < max_steps:
            if K > 1:
                arr = [
                    workload[step + i] if step + i < len(workload) else []
                    for i in range(K)
                ]
                budget = len(self._free_slots())
                ticks = [(arr[0], budget)] + [(a, 0) for a in arr[1:]]
                for d in self.scheduler.tick_window(ticks):
                    if step >= max_steps:
                        # already popped from the device queue — park for
                        # admission on a later run() instead of losing them
                        self._backlog.extend(d)
                        continue
                    completed += len(self.step([], dispatched=d))
                    step += 1
            else:
                arrivals = workload[step] if step < len(workload) else []
                completed += len(self.step(arrivals))
                step += 1
            if (
                step >= len(workload)
                and self.scheduler.pending == 0
                and not self._backlog
                and all(r is None for r in self.active)
            ):
                break
        return {
            "steps": step,
            "completed": completed,
            "wall_s": time.time() - t0,
            "mode_trace": self.scheduler.stats.mode_trace,
            "pq_transitions": int(self.scheduler.carry.stats.transitions),
        }
