"""Serving engine: prefill/decode with continuous batching via SmartPQ.

Host loop (single-controller; multi-host serving shards the same jitted
steps over the production mesh):

  while True:
      arrivals  -> scheduler.tick()  (SmartPQ insert/delete on device)
      new reqs  -> prefill_step      (fills KV cache slots)
      all slots -> serve_step        (one token for every active slot)
      finished  -> release slots

KV memory is slot-paged: a fixed pool of `batch_size` cache slots; the
scheduler admits a request only when a slot is free (capacity-rejected
inserts retry next tick — the same MoE-style overflow contract the PQ's
`route_capped` uses).

With `sched_window > 1` the engine batches K scheduler ticks into one
fused device call (`SmartPQScheduler.tick_window`) and spreads the
window's dispatch budget across ticks with a slot-availability forecast:
tick 0 gets the free slots visible at window start, and tick t adds the
slots predicted to free during the window — the count of active slots
whose `remaining` token budget runs out by tick t, plus an expected-value
EOS-hazard term for early stops.  The forecast is advisory only:
over-admissions park in the engine's admit backlog and fill slots as they
actually free, so completions never depend on it (disable with
`forecast=False` to reproduce the window-start-budget baseline, whose
dispatch stream is bit-identical to K sequential single ticks).

`cfg=None` runs a model-free synthetic decode (next token derived from
the current token, never EOS) — the same engine loop without building a
model, used by the SLO benchmarks and the fast-lane window-semantics
tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.serve.scheduler import Request, SmartPQScheduler


@dataclasses.dataclass
class EngineConfig:
    batch_size: int = 8  # concurrent decode slots
    max_seq: int = 512
    eos_token: int = 2
    kv_chunk: int = 2048
    # Scheduler dispatch granularity: >1 batches K ticks into ONE fused
    # device call (scheduler.tick_window) instead of K per-step dispatches.
    sched_window: int = 1
    # Mid-window admission: derive per-tick dispatch budgets from the
    # slot-availability forecast instead of freezing the window-start free
    # count.  Off -> budgets [free, 0, ..., 0], the pre-forecast baseline.
    forecast: bool = True
    # Per-step probability an active slot stops early (EOS) — folded into
    # the forecast as an expected-completions term.  0 trusts `remaining`
    # alone (exact for synthetic decode, conservative for real models).
    eos_hazard: float = 0.0
    # Overload control: per-SLO-class p99 queueing-delay targets (engine
    # steps).  None (default) -> open-loop admission, exactly the
    # pre-overload engine.  Set -> an OverloadController gates admission
    # (shed/degrade low classes, cap backlogs) so the highest class's p99
    # holds under sustained overload.
    slo_targets: Optional[Tuple[float, ...]] = None
    # Host backlog bound (scheduler arrival backlog eviction cap + engine
    # admit-backlog requeue threshold) — only enforced with control on.
    backlog_cap: int = 4096
    # Arm the PQ's runtime guard tier (SmartPQConfig.validate): every
    # scheduler window validates invariants against a pre-window
    # checkpoint, rolling back + retrying conservatively on violation.
    validate: bool = False
    # Durability: a directory arms the write-ahead log + crash-consistent
    # snapshot layer (repro.serve.durability) — every window's arrivals
    # are fsynced before execution, commits mark them done, and
    # `recover()` (run automatically at the top of `run()`) restores the
    # newest valid snapshot and replays the WAL suffix bit-identically.
    # None (default) keeps the engine fully in-memory, exactly as before.
    durable_dir: Optional[str] = None
    wal_fsync: bool = True  # fsync WAL appends/commits (off: bench probe)
    snapshot_interval: int = 4  # windows between snapshots
    keep_snapshots: int = 2
    # Observability: the engine ALWAYS carries a metrics registry
    # (`engine.obs.metrics` — health(), conservation checks, and the SLO
    # benchmarks read through it).  `tracing` additionally arms the
    # window-timeline tracer (Chrome trace via `engine.obs.tracer`;
    # buffers grow with run length, hence opt-in), and `profile_dir`
    # wraps run() in a jax.profiler trace writing an xplane dump there,
    # with per-window TraceAnnotations labeling the dispatches.
    tracing: bool = False
    profile_dir: Optional[str] = None


class ServeEngine:
    """Small-model serving loop (CPU-runnable end-to-end example)."""

    def __init__(self, cfg: Optional[ModelConfig], params,
                 engine_cfg: EngineConfig, mesh=None, seed: int = 0):
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.params = params
        B, S = engine_cfg.batch_size, engine_cfg.max_seq
        if cfg is not None:
            from repro.models.io import init_caches
            from repro.models.registry import build_model

            self.model = build_model(cfg, mesh=mesh, remat=False,
                                     kv_chunk=engine_cfg.kv_chunk)
            self.caches = init_caches(cfg, B, S)
            self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))
        else:  # model-free synthetic decode: scheduler/engine loop only
            self.model = None
            self.caches = ()
            self._decode = jax.jit(_synthetic_decode)
        from repro.obs import Observability

        # One observability bundle for every layer below (scheduler,
        # overload controller, durability) — a single metrics registry is
        # what makes health() a thin view instead of a hand-copied ledger.
        self.obs = Observability(metrics=True, tracing=engine_cfg.tracing)
        overload = None
        if engine_cfg.slo_targets is not None:
            from repro.serve.overload import OverloadConfig, OverloadController

            overload = OverloadController(OverloadConfig(
                targets=tuple(engine_cfg.slo_targets),
                backlog_cap=engine_cfg.backlog_cap,
            ), obs=self.obs)
        self.overload = overload
        pq_config = None
        if engine_cfg.validate:
            from repro.core.smartpq import MODE_AWARE, SmartPQConfig

            # The scheduler's default queue geometry, with the runtime
            # guard tier armed.
            pq_config = SmartPQConfig(
                num_shards=16, capacity=8192, npods=2, decision_interval=4,
                initial_mode=MODE_AWARE, validate=True,
            )
        self.scheduler = SmartPQScheduler(
            batch_size=64, seed=seed, pq_config=pq_config, overload=overload,
            obs=self.obs,
        )
        self.tokens = jnp.zeros((B, 1), jnp.int32)
        self.lengths = jnp.zeros((B,), jnp.int32)
        self.active: List[Optional[Request]] = [None] * B
        self.remaining = np.zeros(B, np.int64)
        self.outputs: Dict[int, List[int]] = {}
        self._backlog: List[Request] = []  # dispatched, awaiting a free slot
        # SLO accounting (engine-step clock): arrival -> admission -> done.
        self.arrival_step: Dict[int, int] = {}
        self.admit_step: Dict[int, int] = {}
        self.done_step: Dict[int, int] = {}
        self.slo: Dict[int, int] = {}  # uid -> SLO class (set at arrival)
        # EMA of observed service times (tokens emitted per completed
        # request) — the forecast's slot-recycling horizon.  The prior only
        # matters for the first window; completions tighten it online.
        self._service_est = 8.0
        self._step = 0
        self.durability = None
        self._recovered = False
        if engine_cfg.durable_dir is not None:
            from repro.serve.durability import (
                DurabilityConfig, DurableStore,
            )

            self.durability = DurableStore(DurabilityConfig(
                dir=engine_cfg.durable_dir,
                fsync=engine_cfg.wal_fsync,
                snapshot_interval=engine_cfg.snapshot_interval,
                keep_snapshots=engine_cfg.keep_snapshots,
            ), obs=self.obs)
            # shed/evict decisions leave audit records next to admissions
            self.scheduler.wal_sink = self.durability.log_event

    # -- admission -------------------------------------------------------------

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.active) if r is None]

    def _admit(self, reqs: List[Request]):
        reqs = self._backlog + list(reqs)
        slots = self._free_slots()
        self._backlog = reqs[len(slots):]
        if (
            self.overload is not None
            and len(self._backlog) > self.ecfg.backlog_cap
        ):
            # The admit backlog is NOT priority-ordered — a forecast gone
            # wrong (see faults.forecast_extreme) could grow it without
            # bound and serve it FIFO, inverting SLO order.  Overflow goes
            # BACK to the priority queue instead of being dropped: already-
            # admitted work is never lost, and it re-dispatches in SLO
            # order when slots actually free.
            overflow = self._backlog[self.ecfg.backlog_cap:]
            del self._backlog[self.ecfg.backlog_cap:]
            self.scheduler.requeue(overflow)
        for slot, req in zip(slots, reqs):
            # Prompt "prefill" for the example engine: teacher-forced decode
            # of the prompt tokens (prompt = synthetic [uid-derived] tokens).
            self.active[slot] = req
            self.remaining[slot] = req.max_new_tokens
            self.outputs[req.uid] = []
            self.admit_step[req.uid] = self._step
            self.tokens = self.tokens.at[slot, 0].set(req.uid % 100 + 3)
            self.lengths = self.lengths.at[slot].set(0)

    def _note_arrivals(self, arrivals: List[Request], step: int):
        """Stamp arrival time on the engine-step clock: the scheduler's
        aging term and the SLO latency records both key off it."""
        for r in arrivals:
            r.arrival_step = step
            self.arrival_step[r.uid] = step
            self.slo[r.uid] = r.slo_class

    # -- slot-availability forecast ---------------------------------------------

    def _window_budgets(self, K: int) -> List[int]:
        """Per-tick dispatch budgets for the next K-tick window.

        budgets[0] is the free-slot count at window start (the baseline's
        whole budget).  With the forecast on, budgets[t>0] adds the slots
        predicted to free at tick t: (a) active slots whose `remaining`
        token budget runs out (a slot with remaining == t frees for
        admission at tick t), (b) the accumulated-and-floored expectation
        of EOS early stops among slots still running, and (c) SLOT
        RECYCLING — every predicted admission is itself projected to hold
        its slot for `_service_est` ticks and free it again, so long
        windows keep their slots saturated instead of predicting only one
        generation of completions.  Over-prediction is safe: dispatches
        beyond the queue depth are no-ops, and over-admissions park in the
        admit backlog until a slot actually frees."""
        budgets = [len(self._free_slots())] + [0] * (K - 1)
        if not self.ecfg.forecast:
            return budgets
        rem = [int(self.remaining[i]) for i, r in enumerate(self.active)
               if r is not None]
        # (a) deterministic completions of the currently active slots
        frees = [0] * K
        for r in rem:
            if 1 <= r < K:
                frees[r] += 1
        # (b) expected EOS early stops, credited as they accumulate to 1
        h = self.ecfg.eos_hazard
        if h > 0.0:
            acc, credited = 0.0, 0
            for t in range(1, K):
                acc += h * sum(1 for r in rem if r > t)
                frees[t] += int(acc) - credited
                credited = int(acc)
        # (c) recycle: an admission at tick t frees its slot at t + est
        est = max(int(round(self._service_est)), 1)
        for t in range(1, K):
            if t - 1 + est < K:
                frees[t - 1 + est] += budgets[t - 1]
            budgets[t] += frees[t]
        return budgets

    # -- stepping ---------------------------------------------------------------

    def step(self, arrivals: List[Request],
             dispatched: Optional[List[Request]] = None) -> List[int]:
        """One engine tick.  Returns uids completed this step.  `dispatched`
        is pre-computed when the run loop batches scheduling through
        `tick_window`; otherwise the scheduler steps inline."""
        if dispatched is None:
            n_free = len(self._free_slots())
            dispatched = self.scheduler.tick(arrivals, n_dispatch=n_free)
        self._admit(dispatched)

        logits, self.caches = self._decode(
            self.params, self.caches, self.tokens, self.lengths
        )
        next_tok = np.asarray(jnp.argmax(logits, axis=-1), np.int64)
        self.lengths = self.lengths + (
            jnp.asarray([r is not None for r in self.active], jnp.int32)
        )
        self.tokens = jnp.asarray(next_tok[:, None].astype(np.int32))

        done = []
        for i, req in enumerate(self.active):
            if req is None:
                continue
            self.outputs[req.uid].append(int(next_tok[i]))
            self.remaining[i] -= 1
            hit_eos = int(next_tok[i]) == self.ecfg.eos_token
            full = int(np.asarray(self.lengths)[i]) >= self.ecfg.max_seq - 1
            if self.remaining[i] <= 0 or hit_eos or full:
                done.append(req.uid)
                self.done_step[req.uid] = self._step
                self._service_est = (
                    0.9 * self._service_est + 0.1 * len(self.outputs[req.uid])
                )
                self.active[i] = None
                self._observe_completion(req.uid)
        self._step += 1
        return done

    def _observe_completion(self, uid: int) -> None:
        """Per-class latency histograms at the completion site — the
        registry views `latency_records()`'s offline vectors were computed
        from, but incremental, labeled by SLO class, and readable mid-run
        (`obs.metrics.summary("latency_queue_steps", slo=c)`)."""
        m = self.obs.metrics
        if not m.enabled:
            return
        from repro.obs import LATENCY_STEP_EDGES, PER_TOKEN_EDGES

        c = self.slo.get(uid, 1)
        arrived = self.arrival_step.get(uid, 0)
        queueing = self.admit_step[uid] - arrived
        e2e = self.done_step[uid] - arrived + 1
        tokens = max(len(self.outputs.get(uid, ())), 1)
        m.observe("latency_queue_steps", queueing,
                  edges=LATENCY_STEP_EDGES, slo=c)
        m.observe("latency_e2e_steps", e2e, edges=LATENCY_STEP_EDGES, slo=c)
        m.observe("latency_per_token_steps", e2e / tokens,
                  edges=PER_TOKEN_EDGES, slo=c)
        m.inc("tokens_emitted_total", n=tokens)
        m.inc("requests_completed_total", slo=c)

    def _advance(
        self,
        arrivals_by_tick: List[List[Request]],
        step0: int,
        max_steps: int,
    ) -> Tuple[int, int]:
        """Execute one scheduling window (K ticks, or a single `tick()`
        step when sched_window == 1) starting at engine step `step0`.
        Returns (completions, engine steps advanced).  This is THE window
        execution path: `run()` drives it live and `recover()` replays WAL
        windows through it, so an interrupted run and its replay share
        every instruction."""
        if self.ecfg.profile_dir is not None:
            from repro.obs.profiling import annotate

            with annotate(f"serve_window@{step0}"):
                return self._advance_impl(arrivals_by_tick, step0, max_steps)
        return self._advance_impl(arrivals_by_tick, step0, max_steps)

    def _advance_impl(
        self,
        arrivals_by_tick: List[List[Request]],
        step0: int,
        max_steps: int,
    ) -> Tuple[int, int]:
        if len(arrivals_by_tick) == 1 and self.ecfg.sched_window <= 1:
            self._note_arrivals(arrivals_by_tick[0], step0)
            return len(self.step(arrivals_by_tick[0])), 1
        for i, a in enumerate(arrivals_by_tick):
            self._note_arrivals(a, step0 + i)
        K = len(arrivals_by_tick)
        completed, step = 0, step0
        for d in self.scheduler.tick_window(
            arrivals_by_tick, self._window_budgets(K)
        ):
            if step >= max_steps:
                # already popped from the device queue — park for
                # admission on a later run() instead of losing them
                self._backlog.extend(d)
                continue
            completed += len(self.step([], dispatched=d))
            step += 1
        return completed, step - step0

    def run(self, workload: List[List[Request]], max_steps: int = 10_000):
        """Drive until the workload drains.  Returns summary stats.

        With `sched_window > 1` the scheduler runs one fused device call per
        K engine ticks; each tick's dispatch budget comes from
        `_window_budgets` — mid-window completions admit at the tick the
        forecast predicts them, and any over-admission parks in the admit
        backlog until a slot actually frees.

        With durability armed (`EngineConfig.durable_dir`) the loop runs on
        the GLOBAL step clock: `recover()` executes first (restoring any
        snapshot + replaying the WAL suffix), and the workload is indexed
        by absolute engine step, so a restarted process hands `run` the
        same full workload and it resumes exactly where the crash cut it.
        Each window's arrivals are WAL-logged + fsynced before execution
        and committed after; every `snapshot_interval` windows the full
        state is snapshotted crash-consistently."""
        from repro.obs.profiling import trace_session

        t0 = time.time()
        durable = self.durability is not None
        if durable and not self._recovered:
            self.recover()
        with trace_session(self.ecfg.profile_dir):
            completed, step, start = self._run_loop(
                workload, max_steps, durable
            )
        sst = self.scheduler.stats
        return {
            "steps": step - start,
            "completed": completed,
            "wall_s": time.time() - t0,
            "mode_trace": sst.mode_trace,
            "pq_transitions": int(self.scheduler.carry.stats.transitions),
            "shed": sst.shed,
            "evicted": sst.evicted,
            "recovered_windows": sst.recovered_windows,
        }

    def _run_loop(self, workload, max_steps, durable):
        completed = 0
        start = self._step if durable else 0
        step = start
        K = max(1, self.ecfg.sched_window)
        while step < max_steps:
            # Durable windows never straddle the max_steps horizon: a
            # window's arrivals are fed (and WAL-logged) as a unit, so a
            # mid-window cap would leave _step behind the fed prefix and a
            # resumed run would double-feed the tail ticks.  Clamping keeps
            # "engine step clock == workload ticks consumed" invariant that
            # resume relies on; non-durable runs keep the legacy park-in-
            # backlog behavior bit-for-bit.
            Kw = min(K, max_steps - step) if durable else K
            arr = [
                workload[step + i] if step + i < len(workload) else []
                for i in range(Kw)
            ]
            if durable:
                self.durability.log_window(step, arr)
            done, nsteps = self._advance(arr, step, max_steps)
            completed += done
            step += nsteps
            if durable:
                # Heartbeats carry the compact metrics snapshot, so hang
                # diagnosis (supervisor) sees the last known counters,
                # not just a step number.
                self._sync_registry()
                self.durability.log_commit(
                    self._step, health=self.obs.metrics.compact()
                )
                self.durability.window_committed()
                if self.durability.should_snapshot():
                    self.snapshot()
            if (
                step >= len(workload)
                and self.scheduler.pending == 0
                and not self._backlog
                and all(r is None for r in self.active)
            ):
                break
        if durable:
            # final snapshot: a clean restart needs no replay at all
            self.snapshot()
        return completed, step, start

    # -- durability: snapshot / recover -----------------------------------------

    def _snapshot_arrays(self) -> Dict[str, object]:
        return {
            "sched": self.scheduler.snapshot_arrays(),
            "tokens": self.tokens,
            "lengths": self.lengths,
            "remaining": np.asarray(self.remaining),
        }

    def _restore_arrays(self, arrays: Dict[str, object]) -> None:
        self.scheduler.restore_arrays(arrays["sched"])
        self.tokens = jnp.asarray(arrays["tokens"])
        self.lengths = jnp.asarray(arrays["lengths"])
        self.remaining = np.asarray(arrays["remaining"], np.int64)

    def _host_state(self) -> Dict[str, object]:
        req = dataclasses.asdict
        return {
            "step": self._step,
            "service_est": self._service_est,
            "active": [None if r is None else req(r) for r in self.active],
            "backlog": [req(r) for r in self._backlog],
            "outputs": {str(u): v for u, v in self.outputs.items()},
            "arrival_step": {
                str(u): s for u, s in self.arrival_step.items()
            },
            "admit_step": {str(u): s for u, s in self.admit_step.items()},
            "done_step": {str(u): s for u, s in self.done_step.items()},
            "slo": {str(u): c for u, c in self.slo.items()},
        }

    def _load_host_state(self, d: Dict[str, object]) -> None:
        self._step = int(d["step"])
        self._service_est = float(d["service_est"])
        self.active = [
            None if rd is None
            else Request(**{k: int(v) for k, v in rd.items()})
            for rd in d["active"]
        ]
        self._backlog = [
            Request(**{k: int(v) for k, v in rd.items()})
            for rd in d["backlog"]
        ]
        self.outputs = {
            int(u): [int(t) for t in v] for u, v in d["outputs"].items()
        }
        self.arrival_step = {
            int(u): int(s) for u, s in d["arrival_step"].items()
        }
        self.admit_step = {
            int(u): int(s) for u, s in d["admit_step"].items()
        }
        self.done_step = {int(u): int(s) for u, s in d["done_step"].items()}
        self.slo = {int(u): int(c) for u, c in d["slo"].items()}

    def snapshot(self):
        """Crash-consistent snapshot of the FULL serving state at the
        current window boundary: scheduler carry + rng + ring backlogs +
        in-flight maps + overload controller + engine slots/outputs/SLO
        clocks, with the carry's fingerprint stamped into the manifest."""
        from repro.core.smartpq import carry_fingerprint

        host = {
            "engine": self._host_state(),
            "scheduler": self.scheduler.host_state(),
            "carry_crc": carry_fingerprint(self.scheduler.carry),
        }
        return self.durability.snapshot(
            self._step, self._snapshot_arrays(), host
        )

    def recover(self) -> Dict[str, object]:
        """Restore from the durable store: load the newest VALID snapshot
        (corrupt/partial/stale ones are skipped with accounting), then
        replay the WAL's window suffix through `_advance` — the exact code
        path the original run used — so completion sets, conservation
        accounting, and the carry bits reconverge with an uninterrupted
        run.  Idempotent on a fresh directory (no snapshot, empty WAL:
        nothing happens).  Called automatically by `run()`.

        Replay executes each logged window to completion (the original
        `max_steps` cap is not re-applied); durable runs are expected to
        use drain-bounded horizons, not mid-window step caps."""
        from repro.core.errors import SnapshotCorruptError

        d = self.durability
        info: Dict[str, object] = {
            "snapshot_step": None, "replayed_windows": 0, "wal_records": 0,
        }
        loaded = d.load_newest_valid(self._snapshot_arrays())
        base_step = 0
        if loaded is not None:
            snap_step, arrays, host = loaded
            self._restore_arrays(arrays)
            self._load_host_state(host["engine"])
            self.scheduler.load_host_state(host["scheduler"])
            if host.get("carry_crc") is not None:
                from repro.core.smartpq import carry_fingerprint

                got = carry_fingerprint(self.scheduler.carry)
                if got != host["carry_crc"]:
                    self.obs.metrics.inc(
                        "errors_total", code="SNAPSHOT_CORRUPT"
                    )
                    raise SnapshotCorruptError(
                        f"carry fingerprint mismatch after restore "
                        f"(manifest {host['carry_crc']:#x}, got {got:#x})",
                        path=str(d.snap_root),
                    )
            base_step = self._step
            info["snapshot_step"] = snap_step
        records = d.read_wal()
        info["wal_records"] = len(records)
        windows = d.window_suffix(base_step)
        d.suppress_events = True
        try:
            for rec in windows:
                from repro.serve.durability import request_from_dict

                arr = [
                    [request_from_dict(x) for x in tick]
                    for tick in rec["arrivals"]
                ]
                self._advance(arr, int(rec["step0"]), 1 << 62)
                d.stats.replayed_windows += 1
                d.stats.replayed_records += 1
        finally:
            d.suppress_events = False
        info["replayed_windows"] = len(windows)
        self._recovered = True
        self.obs.metrics.inc("engine_recoveries_total")
        self.obs.tracer.instant(
            "recovery", cat="durability",
            snapshot_step=info["snapshot_step"],
            replayed_windows=len(windows),
        )
        return info

    # -- structured health -------------------------------------------------------

    def _sync_registry(self) -> None:
        """Mirror every accounting surface into the metrics registry:
        each `SchedulerStats` field becomes a ``sched_<name>`` gauge, each
        `SmartPQStats` field a ``pq_<name>`` gauge (vector fields, e.g.
        the per-mode step counts, become one labeled series per index),
        plus the engine's own slot/backlog/clock gauges.  Field iteration
        is PROGRAMMATIC — a stats field added in a later PR shows up here
        (and in the hygiene gate) without touching this function."""
        m = self.obs.metrics
        if not m.enabled:
            return
        from repro.core.smartpq import SmartPQStats

        sst = self.scheduler.stats
        for f in dataclasses.fields(sst):
            v = getattr(sst, f.name)
            if f.name == "mode_trace":
                m.set_gauge("sched_mode_trace_len", len(v))
            else:
                m.set_gauge(f"sched_{f.name}", v)
        for name, leaf in zip(
            SmartPQStats._fields, self.scheduler.carry.stats
        ):
            arr = np.asarray(leaf)
            if arr.ndim == 0:
                m.set_gauge(f"pq_{name}", float(arr))
            else:
                for i, x in enumerate(arr.tolist()):
                    m.set_gauge(f"pq_{name}", float(x), index=i)
        m.set_gauge("engine_step", self._step)
        m.set_gauge("engine_completed", len(self.done_step))
        m.set_gauge("engine_active_slots",
                    sum(r is not None for r in self.active))
        m.set_gauge("engine_free_slots", len(self._free_slots()))
        m.set_gauge("engine_admit_backlog", len(self._backlog))
        m.set_gauge("sched_arrival_backlog",
                    len(self.scheduler._arrival_backlog))
        m.set_gauge("pq_on_device",
                    int(self.scheduler.carry.state.total_size))
        m.set_gauge("sched_pending", self.scheduler.pending)
        m.set_gauge("engine_service_est", float(self._service_est))

    def health(self) -> Dict[str, object]:
        """One structured health/accounting surface: everything the
        supervisor, the benchmarks, and the conservation checks need, so
        none of them poke engine/scheduler attributes directly.  Counter
        semantics: ``inserted + arrival_backlog + shed + evicted`` equals
        total submitted arrivals, and ``inserted == dispatched +
        on_device`` (the request-conservation invariant).

        The values are READS FROM THE METRICS REGISTRY (synced just
        before), not hand-copied attributes — `repro.obs` is the single
        source of truth, and the hygiene gate asserts every stats field
        reaches it."""
        self._sync_registry()
        g = self.obs.metrics.value
        return {
            "step": int(g("engine_step")),
            "completed": int(g("engine_completed")),
            "active_slots": int(g("engine_active_slots")),
            "free_slots": int(g("engine_free_slots")),
            "admit_backlog": int(g("engine_admit_backlog")),
            "arrival_backlog": int(g("sched_arrival_backlog")),
            "on_device": int(g("pq_on_device")),
            "pending": int(g("sched_pending")),
            "inserted": int(g("sched_inserted")),
            "dispatched": int(g("sched_dispatched")),
            "shed": int(g("sched_shed")),
            "evicted": int(g("sched_evicted")),
            "rejected": int(g("pq_rejected")),
            "recovered_windows": int(g("sched_recovered_windows")),
            "failed_windows": int(g("sched_failed_windows")),
            "pq_transitions": int(g("pq_transitions")),
            "service_est": float(g("engine_service_est")),
            "overload": (
                self.overload.snapshot() if self.overload is not None
                else None
            ),
            "durability": (
                self.durability.stats.as_dict()
                if self.durability is not None else None
            ),
        }

    # -- SLO accounting ----------------------------------------------------------

    def latency_records(self) -> Dict[str, np.ndarray]:
        """Per-completed-request latency vectors on the engine-step clock:
        queueing delay (arrival -> slot admission), end-to-end latency, and
        per-token latency (end-to-end / tokens emitted) — the inputs to the
        serve_slo benchmark's p50/p99 records."""
        uids = sorted(self.done_step)
        queueing = np.array(
            [self.admit_step[u] - self.arrival_step.get(u, 0) for u in uids],
            np.float64,
        )
        e2e = np.array(
            [self.done_step[u] - self.arrival_step.get(u, 0) + 1 for u in uids],
            np.float64,
        )
        tokens = np.array(
            [max(len(self.outputs.get(u, ())), 1) for u in uids], np.float64
        )
        return {
            "uids": np.array(uids, np.int64),
            "slo": np.array([self.slo.get(u, 1) for u in uids], np.int64),
            "queueing_steps": queueing,
            "e2e_steps": e2e,
            "per_token_steps": e2e / tokens,
            "tokens": tokens,
        }


def _synthetic_decode(params, caches, tokens, lengths):
    """Model-free decode stub with the `decode_step` signature: the next
    token is a pure function of the current one and never hits the default
    EOS id (2), so completion timing is driven entirely by
    `max_new_tokens` — deterministic ground truth for scheduler tests and
    SLO benchmarks."""
    del params, lengths
    nxt = (tokens[:, 0] % 97) + 3
    return jax.nn.one_hot(nxt, 128, dtype=jnp.float32), caches
