from repro.configs.base import ModelConfig, ShapeConfig, SHAPES  # noqa: F401
from repro.configs.registry import get_config, list_configs, reduced_config  # noqa: F401
