"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536  [arXiv:2403.19887; hf]

Layout: period-8 superblocks (9 of them), attention at in-block position 4,
SSD elsewhere; MoE FFN every 2nd layer (odd positions), dense FFN otherwise
— the Jamba paper's a=1/m=8, e=2 configuration.  Jamba's Mamba layers are
Mamba-1; implemented with the SSD layer (DESIGN.md hardware-adaptation note).
"""

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    head_dim=128,
    act="silu",
    norm="rms",
    rope_theta=10000.0,  # Jamba attention layers use no RoPE in-paper; kept
    moe=MoEConfig(n_experts=16, top_k=2, every=2),
    ssm=SSMConfig(d_inner=16384, head_dim=64, d_state=16, n_groups=8, chunk=128),
    hybrid_period=8,
    hybrid_attn_pos=4,
)
