"""llama-3.2-vision-11b [vlm] — cross-attention image layers; frontend STUBBED.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

Cross-attention layers every 5th layer (8 total) attend to stubbed patch
embeddings (input_specs() provides (B, n_image_tokens, d_model)); the ViT
tower is out of scope per the pool instructions (backbone only).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    head_dim=128,
    act="silu",
    norm="rms",
    rope_theta=500000.0,
    cross_attn_every=5,
    n_image_tokens=1024,
)
