"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128  [arXiv:2405.21060; unverified]

Pure SSD stack: each layer is norm -> SSD -> residual (no attention, no MLP
— d_ff=0 per the pool spec).  d_inner = 2*d_model = 3072, headdim 64.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab=50280,
    act="silu",
    norm="rms",
    tie_embeddings=True,
    ssm=SSMConfig(d_inner=3072, head_dim=64, d_state=128, n_groups=1, chunk=256),
)
