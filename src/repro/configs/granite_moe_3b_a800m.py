"""granite-moe-3b-a800m [moe] — 40 experts top-8.

32L d_model=1536 24H (GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

40 experts pad to 48 for the 16-wide expert-parallel axis (router pins the
8 pad experts to -inf; DESIGN.md).
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    act="silu",
    norm="rms",
    rope_theta=10000.0,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=40, top_k=8, every=1),
)
