"""Config system: architectures, input shapes, run settings.

Every assigned architecture gets one `src/repro/configs/<id>.py` exporting
CONFIG with the exact published dimensions; `registry.py` resolves
`--arch <id>` strings and builds reduced smoke variants.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    every: int = 1  # MoE ffn every `every` layers (others dense)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_inner: int
    head_dim: int = 64
    d_state: int = 128
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    act: str = "silu"  # silu (SwiGLU) | gelu (GeGLU) | gelu_mlp (plain)
    norm: str = "rms"  # rms | layer
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 500000.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (jamba): period layout; attention at `attn_pos`, SSD elsewhere
    hybrid_period: int = 0
    hybrid_attn_pos: int = 0
    # encdec (whisper)
    n_encoder_layers: int = 0
    # vlm: cross-attention every k-th layer; stubbed image tokens
    cross_attn_every: int = 0
    n_image_tokens: int = 1024
    embed_scale: bool = False  # gemma multiplies embeddings by sqrt(d_model)
    max_position: int = 1 << 20

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (reported in DESIGN/EXPERIMENTS)."""
        D, F, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd = self.resolved_head_dim
        attn = D * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * D
        if self.act in ("silu", "gelu"):
            ffn_dense = 3 * D * F
        else:
            ffn_dense = 2 * D * F
        total = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            s = self.ssm
            per = (
                D * (2 * s.d_inner + 2 * s.n_groups * s.d_state + s.d_inner // s.head_dim)
                + s.d_inner * D
            )
            return total + L * per
        n_attn_layers = L
        n_ffn = L
        if self.family == "hybrid":
            n_attn_layers = L // self.hybrid_period
            s = self.ssm
            per_ssm = (
                D * (2 * s.d_inner + 2 * s.n_groups * s.d_state + s.d_inner // s.head_dim)
                + s.d_inner * D
            )
            total += (L - n_attn_layers) * per_ssm
        total += n_attn_layers * attn
        if self.moe:
            n_moe = n_ffn // self.moe.every
            total += n_moe * (self.moe.n_experts * 3 * D * F + D * self.moe.n_experts)
            total += (n_ffn - n_moe) * ffn_dense
        else:
            total += n_ffn * ffn_dense
        if self.family == "encdec":
            total += self.n_encoder_layers * (attn + ffn_dense)
        if self.cross_attn_every:
            total += (L // self.cross_attn_every) * attn
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        D, F = self.d_model, self.d_ff
        n_ffn = self.n_layers if self.family != "hybrid" else self.n_layers
        n_moe = n_ffn // self.moe.every
        moe_total = n_moe * self.moe.n_experts * 3 * D * F
        moe_active = n_moe * self.moe.top_k * 3 * D * F
        return full - moe_total + moe_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def step_fn(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step", "decode": "serve_step"}[
            self.kind
        ]


SHAPES: dict = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic token mixing -> SSM / hybrid only.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
        return False, "full-attention arch: 500k decode is quadratic — skipped (DESIGN.md)"
    return True, ""
