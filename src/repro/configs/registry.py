"""--arch <id> resolution + reduced smoke-test variants."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

_ARCH_MODULES: Dict[str, str] = {
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "granite-8b": "repro.configs.granite_8b",
    "gemma-2b": "repro.configs.gemma_2b",
    "whisper-base": "repro.configs.whisper_base",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b_a400m",
    "mamba2-780m": "repro.configs.mamba2_780m",
    "llama-3.2-vision-11b": "repro.configs.llama3_2_vision_11b",
}


def list_configs():
    return sorted(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_configs()}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def reduced_config(arch: str) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests: few layers, narrow
    width, small vocab/experts — preserves every structural property
    (GQA ratios, MoE routing, hybrid period, enc-dec, cross-attn)."""
    cfg = get_config(arch)
    updates = dict(
        name=cfg.name + "-reduced",
        n_layers=min(cfg.n_layers, cfg.hybrid_period or 4),
        d_model=256,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        head_dim=64,
        d_ff=512 if cfg.d_ff else 0,
        vocab=1024,
        n_image_tokens=32 if cfg.cross_attn_every else 1024,
        max_position=65536,
    )
    if cfg.family == "encdec":
        updates["n_encoder_layers"] = 2
        updates["n_layers"] = 2
    if cfg.cross_attn_every:
        updates["n_layers"] = 2 * cfg.cross_attn_every  # keep 2 cross layers
    if cfg.moe:
        updates["moe"] = MoEConfig(
            n_experts=4, top_k=min(cfg.moe.top_k, 2), every=cfg.moe.every,
            capacity_factor=2.0,
        )
    if cfg.ssm:
        updates["ssm"] = SSMConfig(
            d_inner=512, head_dim=64, d_state=16, n_groups=2, chunk=32
        )
    if cfg.family == "hybrid":
        updates["n_layers"] = cfg.hybrid_period
    return dataclasses.replace(cfg, **updates)
