"""whisper-base [audio] — encoder-decoder; conv frontend STUBBED.

6L (decoder; +6L encoder) d_model=512 8H (kv=8) d_ff=2048 vocab=51865
[arXiv:2212.04356; unverified]

The conv1d x2 audio stem is a stub per the pool instructions: input_specs()
provides precomputed frame embeddings (B, S, 512) for the encoder; shape
cells size the encoder sequence = the cell's seq_len.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,  # decoder layers
    n_encoder_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    head_dim=64,
    act="gelu_mlp",  # plain GELU MLP with biases
    norm="layer",
    qkv_bias=True,
    rope_theta=10000.0,  # whisper uses learned/sinusoidal pos; RoPE stands in
    tie_embeddings=True,
)
