"""Discrete-event-simulation drivers over the adaptive PQ.

The classic **hold model** (Vaucher & Duval's PQ benchmark, and the DES
workload used to evaluate MultiQueues): each of B logical servers holds its
current event for a random time and reschedules it — pop the B most
imminent events, insert B future ones at ``popped_time + hold``.  The
insert keys depend on the *popped* keys, so the stream cannot be
pregenerated: the event loop is its own donated `lax.scan` whose body is
`SmartPQ.step` (the state-dependent-key sibling of `run_window`, same
fusion, same on-device decisions), pipelined by one step — step t inserts
the events step t-1 popped.

The **bursty M/M/1 variant** (`traces.bursty_des_trace`) pregenerates an
absolute-time arrival process instead, so its whole event loop runs inside
a single `run_window` replay — arrival bursts grow the queue, service
phases drain it, and the adaptive engine flips modes mid-window.

Exactness probe: with an exact schedule pinned, the per-step popped key
sequence is bit-equal to `hold_model_oracle` (a host `heapq` simulation of
the same linearization) — the DES analogue of SSSP's Bellman-Ford check.
"""

from __future__ import annotations

import functools
import heapq
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT, OP_NOP
from repro.core.pqueue.state import INF_KEY, make_state


class DESResult(NamedTuple):
    popped: np.ndarray  # (K, B) per-step event times, ascending, INF-padded
    n_out: np.ndarray  # (K,)
    modes: np.ndarray  # (K,) on-device mode trace
    transitions: int
    events: int  # total events served
    final_size: int  # events still queued after the horizon
    trace: Optional[object] = None  # traces.Trace when record=True


def sample_holds(
    K: int, B: int, mean_hold: int = 64, seed: int = 0
) -> np.ndarray:
    """Quantized-exponential hold times >= 1 (the hold-model's service
    distribution), shared by the device driver and the heapq oracle."""
    rng = np.random.default_rng(seed)
    return np.maximum(
        rng.exponential(mean_hold, (K, B)).astype(np.int32), 1
    )


def initial_events(
    n_init: int, mean_hold: int = 64, seed: int = 0
) -> np.ndarray:
    rng = np.random.default_rng(seed + 1)
    return rng.integers(0, max(mean_hold, 2), n_init).astype(np.int32)


def make_hold_engine(
    pq,  # SmartPQ (pin mode_schedules to one exact schedule for the oracle)
    B: int = 32,
    K: int = 64,
    num_clients: int | None = None,
):
    """Hold-model engine: K steps fused into one donated scan.

    Step t: insert the events step t-1 popped, rescheduled at
    ``popped + holds[t]``; pop the B most imminent.  Step 0 pops from the
    ``n_init`` (default 4B) pre-filled initial events, so a standing
    backlog of ``n_init - B`` churns through the queue.  Total batch width
    is 2B (B insert lanes + B delete lanes), so the head tier needs
    H >= 2B.  The returned ``run(seed, ...)`` closure reuses ONE jitted
    scan program, so benchmarks can time warm runs."""
    if num_clients is None:
        num_clients = B
    lane = jnp.arange(B, dtype=jnp.int32)
    del_ops = jnp.full((B,), OP_DELETE_MIN, jnp.int32)
    del_keys = jnp.full((B,), INF_KEY, jnp.int32)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run_all(carry, xs):
        def body(c, x):
            pqc, prev_k, prev_n = c
            holds_t, r = x
            valid = lane < prev_n
            ins_k = jnp.where(
                valid, jnp.where(valid, prev_k, 0) + holds_t, INF_KEY
            )
            ops = jnp.concatenate(
                [jnp.where(valid, OP_INSERT, OP_NOP), del_ops]
            )
            keys = jnp.concatenate([ins_k, del_keys])
            vals = jnp.concatenate([lane, jnp.zeros((B,), jnp.int32)])
            pqc, res = pq.step(pqc, ops, keys, vals, r, num_clients)
            n = jnp.minimum(res.n_out, B)
            return (pqc, res.keys[:B], n), (
                res.keys[:B], n, pqc.stats.mode, ops, keys, vals
            )

        return jax.lax.scan(body, carry, xs)

    def run(seed: int = 0, mean_hold: int = 64, n_init: int | None = None,
            record: bool = False) -> DESResult:
        if n_init is None:
            n_init = 4 * B
        from repro.workloads.traces import prefill

        holds = jnp.asarray(sample_holds(K, B, mean_hold, seed))
        init_k = initial_events(n_init, mean_hold, seed)
        st = make_state(pq.config.num_shards, pq.config.capacity,
                        head_width=pq.config.head_width)
        st = prefill(st, init_k, np.arange(n_init, dtype=np.int32))
        pqc = pq.init()._replace(state=st)
        carry = (pqc, jnp.full((B,), INF_KEY, jnp.int32), jnp.int32(0))
        rngs = jax.random.split(jax.random.key(seed), K)
        carry, (pk, n_out, modes, ops_log, keys_log, vals_log) = run_all(
            carry, (holds, rngs)
        )
        trace = None
        if record:
            from repro.workloads.traces import Trace

            trace = Trace(
                ops=np.asarray(ops_log), keys=np.asarray(keys_log),
                vals=np.asarray(vals_log),
                num_clients=np.full((K,), num_clients, np.int32), seed=seed,
                init_keys=init_k,
                init_vals=np.arange(n_init, dtype=np.int32),
            )
        return DESResult(
            popped=np.asarray(pk), n_out=np.asarray(n_out),
            modes=np.asarray(modes),
            transitions=int(carry[0].stats.transitions),
            events=int(np.sum(np.asarray(n_out))),
            final_size=int(carry[0].state.total_size), trace=trace,
        )

    return run


def run_hold_model(
    pq,
    B: int = 32,
    K: int = 64,
    mean_hold: int = 64,
    seed: int = 0,
    num_clients: int | None = None,
    n_init: int | None = None,
    record: bool = False,
) -> DESResult:
    """One-shot hold-model run (see `make_hold_engine`)."""
    run = make_hold_engine(pq, B=B, K=K, num_clients=num_clients)
    return run(seed=seed, mean_hold=mean_hold, n_init=n_init, record=record)


def hold_model_oracle(
    B: int, K: int, mean_hold: int = 64, seed: int = 0,
    n_init: int | None = None,
) -> list:
    """Host `heapq` reference of the same linearization (inserts before
    deletes within a step; holds indexed by ascending pop order — exactly
    the device driver's lane order).  Returns per-step ascending pop
    lists."""
    if n_init is None:
        n_init = 4 * B
    holds = sample_holds(K, B, mean_hold, seed)
    heap = initial_events(n_init, mean_hold, seed).tolist()
    heapq.heapify(heap)
    out, prev = [], []
    for t in range(K):
        for i, k in enumerate(prev):
            heapq.heappush(heap, int(k) + int(holds[t, i]))
        prev = [heapq.heappop(heap) for _ in range(min(B, len(heap)))]
        out.append(prev)
    return out
