"""Name → workload-driver registry.

`benchmarks/workloads_bench.py`, `benchmarks/run.py`, and the tests
enumerate application workloads through this table instead of hard-coding
driver imports.  Every entry can produce a replayable `Trace` via
``spec.make_trace(quick, seed)`` — recorders actually run their driver
(SSSP / DES hold-model) and capture its op log; generators synthesize the
stream on the host.  ``default_pq`` caches one trained decision tree so
enumerating the registry doesn't retrain per workload.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

from repro.core.pqueue.schedules import Schedule
from repro.workloads import des, graphs, sssp, traces

_TREE = None


def default_pq(
    num_shards: int = 8,
    capacity: int = 4096,
    head_width: int | None = None,
    npods: int = 2,
    decision_interval: int = 2,
    mode_schedules: Tuple[Schedule, ...] | None = None,
    eliminate: bool = True,
):
    """A SmartPQ with the module-cached decision tree (trained once per
    process — the tree depends only on the training set, not the config)."""
    global _TREE
    from repro.core.smartpq import SmartPQ, SmartPQConfig

    kwargs = dict(
        num_shards=num_shards, capacity=capacity, head_width=head_width,
        npods=npods, decision_interval=decision_interval,
        eliminate=eliminate,
    )
    if mode_schedules is not None:
        kwargs["mode_schedules"] = mode_schedules
    pq = SmartPQ(SmartPQConfig(**kwargs), tree=_TREE)
    _TREE = pq.tree
    return pq


def _sssp_trace(quick: bool, seed: int) -> traces.Trace:
    g = graphs.random_graph(n=128 if quick else 512, seed=seed)
    pq = default_pq(head_width=256)
    _, trace = sssp.run_sssp_smartpq(g, pq, m=16, seed=seed, record=True)
    return trace


def _des_hold_trace(quick: bool, seed: int) -> traces.Trace:
    pq = default_pq()
    res = des.run_hold_model(
        pq, B=32, K=16 if quick else 64, seed=seed, record=True
    )
    return res.trace


def _des_bursty_trace(quick: bool, seed: int) -> traces.Trace:
    phases = traces.BURSTY_PHASES_QUICK if quick else traces.BURSTY_PHASES
    return traces.bursty_des_trace(phases=phases, seed=seed)


def _phase_flip_trace(quick: bool, seed: int) -> traces.Trace:
    return traces.phase_flip_trace(
        steps_per_phase=4 if quick else 12, seed=seed
    )


def _size_ramp_trace(quick: bool, seed: int) -> traces.Trace:
    return traces.size_ramp_trace(
        steps_per_phase=4 if quick else 10, seed=seed
    )


def _mix_drift_trace(quick: bool, seed: int) -> traces.Trace:
    return traces.mix_drift_trace(steps=16 if quick else 48, seed=seed)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    description: str
    kind: str  # "recorder" (runs a driver) | "generator" (host synthesis)
    make_trace: Callable[[bool, int], traces.Trace]


WORKLOADS: Dict[str, WorkloadSpec] = {
    s.name: s
    for s in (
        WorkloadSpec(
            "sssp", "adaptive wavefront-Dijkstra op log (recorded)",
            "recorder", _sssp_trace,
        ),
        WorkloadSpec(
            "des_hold", "DES hold-model churn op log (recorded)",
            "recorder", _des_hold_trace,
        ),
        WorkloadSpec(
            "des_bursty", "bursty M/M/1-style DES arrival process",
            "generator", _des_bursty_trace,
        ),
        WorkloadSpec(
            "phase_flip", "insert-storm/delete-storm square wave",
            "generator", _phase_flip_trace,
        ),
        WorkloadSpec(
            "size_ramp", "queue-size ramp up / plateau / drain",
            "generator", _size_ramp_trace,
        ),
        WorkloadSpec(
            "mix_drift", "gradual insert-fraction drift 0.9 -> 0.1",
            "generator", _mix_drift_trace,
        ),
    )
}


def get(name: str) -> WorkloadSpec:
    if name not in WORKLOADS:
        raise KeyError(
            f"unknown workload {name!r}; registered: {sorted(WORKLOADS)}"
        )
    return WORKLOADS[name]


def names() -> Tuple[str, ...]:
    return tuple(sorted(WORKLOADS))
