"""Operation-trace record/replay — the workload pipeline's interchange form.

A `Trace` is an application-shaped op stream captured as dense ``(K, B)``
windows: per-step op codes (`OP_INSERT` / `OP_DELETE_MIN` / `OP_NOP` lane
padding), insert keys/vals (INF-masked), the per-step active-client count
(the paper's #Threads feature), and the rng seed the replay derives its
per-step keys from.  The format is deliberately exactly what
`SmartPQ.run_window` consumes, so

    carry, res = replay(pq, trace)

is ONE donated fused-window dispatch and is bit-reproducible: the same
trace replayed twice (or saved to npz, reloaded, and replayed) produces
identical delete outputs, identical mode traces, and an identical final
carry.  Three trace sources feed the pipeline:

  * **recorders** — the SSSP and DES drivers log the op batches their
    event loops actually issued (`run_sssp_smartpq(record=True)`,
    `run_hold_model(record=True)`);
  * **phased generators** — insert-storm→delete-storm flips, size ramps,
    mix drift, and the bursty M/M/1-style DES arrival process: the
    time-varying contention of the paper's Figs. 10/11, in replayable form;
  * **the paper's phase tables** — `TABLE2` / `TABLE3` (paper Tables 2/3)
    live here as the single source of truth: `benchmarks/fig10_dynamic.py`
    and the tests replay the SAME schedules via `phased_trace`.

`classifier.dataset.examples_from_trace` converts any trace into labeled
training examples, closing the loop: the decision tree can be trained on
application-shaped feature distributions instead of only the analytic grid.
"""

from __future__ import annotations

from pathlib import Path
from typing import Dict, List, NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.errors import TraceCorruptError
from repro.core.pqueue import ops as O
from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT, OP_NOP
from repro.core.pqueue.state import INF_KEY


@jax.jit
def _prefill_jit(state, keys, vals):
    st, _ = O.insert(state, keys, vals)
    return st


def prefill(state, keys, vals):
    """One jitted bulk insert — every driver's pre-fill path.  (An eager
    `ops.insert` dispatches the tiered pipeline op by op and costs ~1s at
    C=4096 on XLA:CPU; jitted it is sub-millisecond.)"""
    return _prefill_jit(
        state, jnp.asarray(keys, jnp.int32), jnp.asarray(vals, jnp.int32)
    )


_EMPTY = np.zeros(0, np.int32)


class Trace(NamedTuple):
    """A replayable op stream in `run_window` form (host numpy arrays).

    ``init_keys`` / ``init_vals`` capture elements the recording driver
    pre-filled BEFORE its first step (DES initial events, the SSSP
    source) — `replay` inserts them into the fresh carry so the replayed
    queue sees the same starting state the driver did."""

    ops: np.ndarray  # (K, B) int32 op codes (OP_NOP pads inactive lanes)
    keys: np.ndarray  # (K, B) int32 insert keys, INF for non-insert lanes
    vals: np.ndarray  # (K, B) int32 payloads
    num_clients: np.ndarray  # (K,) int32 active clients per step
    seed: int  # rng stream id: replay rngs = split(key(seed), K)
    init_keys: np.ndarray = _EMPTY  # pre-fill before step 0
    init_vals: np.ndarray = _EMPTY

    @property
    def num_steps(self) -> int:
        return int(self.ops.shape[0])

    @property
    def width(self) -> int:
        return int(self.ops.shape[1])


def trace_rngs(trace: Trace) -> jax.Array:
    """The (K,) per-step key array every replay of this trace uses."""
    return jax.random.split(jax.random.key(trace.seed), trace.num_steps)


def save_trace(path, trace: Trace) -> None:
    """Persist to the small npz interchange format (int32 throughout).

    Atomic (tmp + fsync + rename via `repro.core.persist`): a crash mid-
    save leaves the previous trace or none — the truncated-npz corruption
    `faults.corrupt_trace_npz` simulates can only be injected, never
    produced by this writer."""
    from repro.core.persist import atomic_savez

    atomic_savez(
        path, compressed=True,
        ops=trace.ops.astype(np.int32),
        keys=trace.keys.astype(np.int32), vals=trace.vals.astype(np.int32),
        num_clients=trace.num_clients.astype(np.int32),
        seed=np.int64(trace.seed),
        init_keys=trace.init_keys.astype(np.int32),
        init_vals=trace.init_vals.astype(np.int32),
    )


def load_trace(path) -> Trace:
    """Load + validate an npz trace.  A damaged file (truncation, flipped
    bytes, missing arrays — see `faults.corrupt_trace_npz`) surfaces a
    typed `TraceCorruptError`; a half-loaded trace is never returned."""
    try:
        with np.load(Path(path)) as z:
            trace = Trace(
                ops=z["ops"], keys=z["keys"], vals=z["vals"],
                num_clients=z["num_clients"], seed=int(z["seed"]),
                init_keys=z["init_keys"], init_vals=z["init_vals"],
            )
    except TraceCorruptError:
        raise
    except Exception as e:  # zipfile/np errors are implementation details
        from repro.obs import get_default

        get_default().metrics.inc("errors_total", code="TRACE_CORRUPT")
        raise TraceCorruptError(
            f"unreadable npz ({type(e).__name__}: {e})", path=str(path)
        ) from e
    validate_trace(trace, path=str(path))
    return trace


def validate_trace(trace: Trace, path: str | None = None) -> Trace:
    """Structural validation of a trace: consistent (K, B) shapes, integral
    op codes restricted to {INSERT, DELETE_MIN, NOP}, matched pre-fill
    arrays.  Raises `TraceCorruptError` — used by `load_trace` on every
    deserialization and available to callers ingesting foreign traces."""

    def bad(detail: str):
        from repro.obs import get_default

        get_default().metrics.inc("errors_total", code="TRACE_CORRUPT")
        raise TraceCorruptError(detail, path=path)

    ops = np.asarray(trace.ops)
    if ops.ndim != 2:
        bad(f"ops must be (K, B); got shape {ops.shape}")
    if not np.issubdtype(ops.dtype, np.integer):
        bad(f"ops dtype must be integral; got {ops.dtype}")
    for name in ("keys", "vals"):
        arr = np.asarray(getattr(trace, name))
        if arr.shape != ops.shape:
            bad(f"{name} shape {arr.shape} != ops shape {ops.shape}")
    nc = np.asarray(trace.num_clients)
    if nc.shape != (ops.shape[0],):
        bad(f"num_clients shape {nc.shape} != ({ops.shape[0]},)")
    legal = np.isin(ops, (OP_INSERT, OP_DELETE_MIN, OP_NOP))
    if not legal.all():
        t, b = np.argwhere(~legal)[0]
        bad(f"illegal op code {int(ops[t, b])} at step {int(t)} lane "
            f"{int(b)}")
    if np.asarray(trace.init_keys).shape != np.asarray(
        trace.init_vals
    ).shape:
        bad("init_keys / init_vals length mismatch")
    return trace


def replay(pq, trace: Trace, carry=None):
    """Replay the whole trace through ONE donated `run_window` call.

    `carry` defaults to a fresh `pq.init()` pre-filled with the trace's
    ``init_keys`` (the recording driver's starting state); a caller-passed
    carry is used as-is — and DONATED either way (its buffers are deleted;
    thread the returned carry).  Returns (carry, WindowResult): per-step
    delete outputs + the on-device mode trace, bit-identical across
    replays of the same trace."""
    if carry is None:
        carry = pq.init()
        if trace.init_keys.size:
            carry = carry._replace(
                state=prefill(carry.state, trace.init_keys, trace.init_vals)
            )
    carry, res = pq.jit_run_window(
        carry, jnp.asarray(trace.ops), jnp.asarray(trace.keys),
        jnp.asarray(trace.vals), trace_rngs(trace),
        jnp.asarray(trace.num_clients),
    )
    if pq.config.validate:
        # Guard tier: one post-window invariant sweep (raises a typed
        # InvariantViolation) — the replay analogue of the scheduler's
        # validated windows.
        pq.validate_carry(carry)
    return carry, res


# ---------------------------------------------------------------------------
# phased generators
# ---------------------------------------------------------------------------

# Paper Table 2 traces (time, size is emergent; we pin the driving
# features).  Consumed by benchmarks/fig10_dynamic.py AND the replay tests —
# one source of truth for the phase schedules.
TABLE2: Dict[str, List[dict]] = {
    "a_keyrange": [  # vary key range (50 threads, 75-25 mix)
        dict(num_clients=50, key_range=100_000, insert_frac=0.75),
        dict(num_clients=50, key_range=2_000, insert_frac=0.75),
        dict(num_clients=50, key_range=1 << 20, insert_frac=0.75),
        dict(num_clients=50, key_range=10_000, insert_frac=0.75),
        dict(num_clients=50, key_range=50_000_000, insert_frac=0.75),
    ],
    "b_threads": [  # vary #threads (65-35 mix, range 20M)
        dict(num_clients=57, key_range=20_000_000, insert_frac=0.65),
        dict(num_clients=29, key_range=20_000_000, insert_frac=0.65),
        dict(num_clients=15, key_range=20_000_000, insert_frac=0.65),
        dict(num_clients=43, key_range=20_000_000, insert_frac=0.65),
        dict(num_clients=15, key_range=20_000_000, insert_frac=0.65),
    ],
    "c_mix": [  # vary op mix (22 threads, range 5M)
        dict(num_clients=22, key_range=5_000_000, insert_frac=0.5),
        dict(num_clients=22, key_range=5_000_000, insert_frac=1.0),
        dict(num_clients=22, key_range=5_000_000, insert_frac=0.3),
        dict(num_clients=22, key_range=5_000_000, insert_frac=1.0),
        dict(num_clients=22, key_range=5_000_000, insert_frac=0.0),
    ],
}

# Paper Table 3: multiple features vary at once (subset of the 15 phases).
TABLE3: List[dict] = [
    dict(num_clients=57, key_range=10_000_000, insert_frac=0.5),
    dict(num_clients=36, key_range=10_000_000, insert_frac=0.7),
    dict(num_clients=36, key_range=20_000_000, insert_frac=0.5),
    dict(num_clients=36, key_range=20_000_000, insert_frac=0.8),
    dict(num_clients=50, key_range=20_000_000, insert_frac=0.8),
    dict(num_clients=50, key_range=100_000_000, insert_frac=0.5),
    dict(num_clients=57, key_range=100_000_000, insert_frac=0.5),
    dict(num_clients=22, key_range=100_000_000, insert_frac=1.0),
    dict(num_clients=22, key_range=100_000_000, insert_frac=0.5),
    dict(num_clients=57, key_range=200_000_000, insert_frac=0.0),
    dict(num_clients=57, key_range=200_000_000, insert_frac=1.0),
    dict(num_clients=57, key_range=20_000_000, insert_frac=0.0),
    dict(num_clients=29, key_range=20_000_000, insert_frac=0.8),
    dict(num_clients=29, key_range=20_000_000, insert_frac=0.5),
]


def phased_trace(
    phases: Sequence[dict],
    steps_per_phase: int = 8,
    width: int | None = None,
    seed: int = 0,
) -> Trace:
    """Uniform-random op stream following a phase schedule.

    Each phase dict pins (num_clients, key_range, insert_frac) for
    `steps_per_phase` steps — the TABLE2/TABLE3 entries drop straight in.
    Lane width is max(num_clients) across phases; steps with fewer active
    clients pad the remaining lanes with OP_NOP (inert everywhere,
    including the decision features)."""
    B = width or max(int(p["num_clients"]) for p in phases)
    rng = np.random.default_rng(seed)
    K = len(phases) * steps_per_phase
    ops = np.full((K, B), OP_NOP, np.int32)
    keys = np.full((K, B), INF_KEY, np.int32)
    vals = np.zeros((K, B), np.int32)
    nc = np.zeros((K,), np.int32)
    t = 0
    for ph in phases:
        d = min(int(ph["num_clients"]), B)
        for _ in range(steps_per_phase):
            is_ins = rng.random(d) < float(ph["insert_frac"])
            ops[t, :d] = np.where(is_ins, OP_INSERT, OP_DELETE_MIN)
            k = rng.integers(
                0, max(int(ph["key_range"]), 1), d
            ).astype(np.int64)
            k = np.minimum(k, INF_KEY - 1).astype(np.int32)
            keys[t, :d] = np.where(is_ins, k, INF_KEY)
            vals[t, :d] = np.where(is_ins, k % 97, 0)
            nc[t] = d  # the clients actually issuing ops this step
            t += 1
    return Trace(ops=ops, keys=keys, vals=vals, num_clients=nc, seed=seed)


def phase_flip_trace(
    B: int = 64, steps_per_phase: int = 12, n_flips: int = 4,
    key_range: int = 1 << 14, seed: int = 0,
) -> Trace:
    """Adversarial insert-storm → delete-storm square wave: each flip
    inverts the op mix edge-to-edge, the worst case for a sticky mode."""
    phases = [
        dict(num_clients=B, key_range=key_range,
             insert_frac=0.95 if i % 2 == 0 else 0.05)
        for i in range(n_flips)
    ]
    return phased_trace(phases, steps_per_phase=steps_per_phase, seed=seed)


def size_ramp_trace(
    B: int = 64, steps_per_phase: int = 10, key_range: int = 1 << 14,
    seed: int = 0,
) -> Trace:
    """Queue-size ramp: insert-only growth, a mixed steady plateau, then a
    delete-only drain — sweeps the Size feature across its whole range
    while the mix stays piecewise-constant."""
    phases = [
        dict(num_clients=B, key_range=key_range, insert_frac=1.0),
        dict(num_clients=B, key_range=key_range, insert_frac=1.0),
        dict(num_clients=B, key_range=key_range, insert_frac=0.5),
        dict(num_clients=B, key_range=key_range, insert_frac=0.0),
        dict(num_clients=B, key_range=key_range, insert_frac=0.0),
    ]
    return phased_trace(phases, steps_per_phase=steps_per_phase, seed=seed)


def mix_drift_trace(
    B: int = 64, steps: int = 48, key_range: int = 1 << 14, seed: int = 0,
) -> Trace:
    """Gradual mix drift 0.9 → 0.1: no phase edges at all, so a classifier
    trained only on piecewise-constant grids sees in-between mixtures."""
    phases = [
        dict(num_clients=B, key_range=key_range,
             insert_frac=0.9 - 0.8 * t / max(steps - 1, 1))
        for t in range(steps)
    ]
    return phased_trace(phases, steps_per_phase=1, seed=seed)


# The canonical bursty M/M/1 phase profile (num_clients, arrival_frac,
# steps) and its seconds-scale variant — shared by the registry, the
# workloads_des benchmark, and the mode-transition acceptance test.
BURSTY_PHASES = ((512, 0.95, 30), (16, 0.6, 12), (64, 0.3, 12))
BURSTY_PHASES_QUICK = ((512, 0.95, 8), (16, 0.6, 4), (64, 0.3, 4))


# ---------------------------------------------------------------------------
# open-loop arrival processes (the serving tier's request streams)
# ---------------------------------------------------------------------------
#
# PQ op traces above are CLOSED-loop: each lane is a client that blocks on
# its own op.  The serving tier needs OPEN-loop streams — arrivals keep
# coming whether or not the engine keeps up (the MultiQueue serving regime
# of Williams et al., arXiv 2504.11652) — so backlog, queueing delay, and
# SLO tail latency are properties of the schedule, not the generator.
# Requests are a STATELESS uid stream: every attribute (slo_class,
# prompt_len, max_new_tokens) is a hash of the uid alone, so a trace with
# millions of synthetic clients costs O(arrivals materialized), any slice
# of the stream regenerates without history, and two runs over the same
# (seed, uid range) see identical clients.


def _hash_u32(x: np.ndarray, salt: int) -> np.ndarray:
    """splitmix32-style avalanche hash: uid -> iid uniform uint32."""
    salted = (salt * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = (np.asarray(x, np.uint64) + np.uint64(salted)) \
        & np.uint64(0xFFFFFFFF)
    z = (z ^ (z >> np.uint64(16))) * np.uint64(0x85EBCA6B) \
        & np.uint64(0xFFFFFFFF)
    z = (z ^ (z >> np.uint64(13))) * np.uint64(0xC2B2AE35) \
        & np.uint64(0xFFFFFFFF)
    return (z ^ (z >> np.uint64(16))).astype(np.uint32)


def poisson_arrival_counts(
    steps: int, rate: float, seed: int = 0
) -> np.ndarray:
    """Open-loop Poisson arrivals: iid per-step counts at `rate`."""
    return np.random.default_rng(seed).poisson(
        rate, steps
    ).astype(np.int32)


def mmpp_arrival_counts(
    steps: int,
    rates: Sequence[float] = (12.0, 0.5),
    mean_dwell: Sequence[float] = (16.0, 32.0),
    seed: int = 0,
) -> np.ndarray:
    """Markov-modulated Poisson arrivals (bursty open-loop load).

    A hidden Markov chain over `len(rates)` states emits Poisson counts at
    the state's rate and advances to the next state with probability
    1/mean_dwell[state] per step (geometric dwell times) — the canonical
    ON/OFF burst process whose ON phases drive the queue into the
    insert-storm contention regime."""
    rng = np.random.default_rng(seed)
    counts = np.zeros(steps, np.int32)
    state = 0
    for t in range(steps):
        counts[t] = rng.poisson(rates[state])
        if rng.random() < 1.0 / float(mean_dwell[state]):
            state = (state + 1) % len(rates)
    return counts


def open_loop_requests(
    counts: np.ndarray,
    seed: int = 0,
    uid_base: int = 0,
    slo_weights: Sequence[float] = (0.25, 0.5, 0.25),
    prompt_range: tuple = (4, 64),
    new_tokens_range: tuple = (2, 16),
):
    """Materialize per-step serving `Request` lists from arrival counts.

    Returns a list of length `len(counts)`; step t holds `counts[t]`
    requests.  uids are consecutive from `uid_base`, and every request
    attribute derives from `_hash_u32(uid, seed*salt)` — the stateless
    stream contract above.  slo_class is drawn from `slo_weights`
    (interactive/standard/batch); prompt lengths and decode budgets are
    uniform over their ranges."""
    from repro.serve.scheduler import Request  # serve dep kept call-local

    cum = np.concatenate([[0], np.cumsum(counts.astype(np.int64))])
    total = int(cum[-1])
    uids = uid_base + np.arange(total, dtype=np.int64)
    cw = np.cumsum(np.asarray(slo_weights, np.float64))
    cw = cw / cw[-1]
    u_slo = _hash_u32(uids, seed * 3 + 1).astype(np.float64) / 2**32
    slo = np.searchsorted(cw, u_slo, side="right").astype(np.int64)
    plo, phi = prompt_range
    prompt = plo + _hash_u32(uids, seed * 3 + 2) % max(phi - plo, 1)
    tlo, thi = new_tokens_range
    ntok = tlo + _hash_u32(uids, seed * 3 + 3) % max(thi - tlo, 1)
    workload = []
    for t in range(len(counts)):
        lo, hi = int(cum[t]), int(cum[t + 1])
        workload.append([
            Request(
                uid=int(uids[i]), prompt_len=int(prompt[i]),
                max_new_tokens=int(ntok[i]), slo_class=int(slo[i]),
                arrival_step=t,
            )
            for i in range(lo, hi)
        ])
    return workload


def bursty_serve_workload(
    steps: int = 64,
    rates: Sequence[float] = (12.0, 0.5),
    mean_dwell: Sequence[float] = (16.0, 32.0),
    seed: int = 0,
):
    """The serve_slo benchmark's canonical open-loop bursty trace: MMPP
    arrival counts fed through the stateless request stream."""
    return open_loop_requests(
        mmpp_arrival_counts(steps, rates, mean_dwell, seed=seed), seed=seed
    )


def bursty_des_trace(
    B: int = 128,
    phases: Sequence[tuple] = BURSTY_PHASES,
    mean_interarrival: int = 3,
    seed: int = 0,
) -> Trace:
    """Bursty M/M/1-style discrete-event arrival process, pregenerated so
    the event loop runs entirely inside `run_window`.

    Event keys are ABSOLUTE arrival times: a shared exponential clock
    advances per arrival, so the key range grows with simulated time and
    the queue rides the burst (arrival-heavy ON phases grow it,
    service-heavy phases drain it) — the phased contention that makes the
    adaptive mode switch pay.  Each phase tuple is (num_clients,
    arrival_frac, steps)."""
    rng = np.random.default_rng(seed)
    K = sum(int(p[2]) for p in phases)
    ops = np.full((K, B), OP_NOP, np.int32)
    keys = np.full((K, B), INF_KEY, np.int32)
    vals = np.zeros((K, B), np.int32)
    nc = np.zeros((K,), np.int32)
    clock = 0.0
    t = 0
    for num_clients, arrival_frac, steps in phases:
        for _ in range(int(steps)):
            n_arr = int(round(arrival_frac * B))
            n_srv = B - n_arr
            ia = rng.exponential(mean_interarrival, n_arr)
            times = clock + np.cumsum(ia)
            clock = float(times[-1]) if n_arr else clock
            ops[t, :n_arr] = OP_INSERT
            keys[t, :n_arr] = np.minimum(times, INF_KEY - 1).astype(np.int32)
            vals[t, :n_arr] = np.arange(n_arr, dtype=np.int32)
            ops[t, n_arr : n_arr + n_srv] = OP_DELETE_MIN
            nc[t] = num_clients
            t += 1
    return Trace(ops=ops, keys=keys, vals=vals, num_clients=nc, seed=seed)
