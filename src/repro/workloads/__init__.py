"""repro.workloads — on-device application drivers for the adaptive PQ.

The paper motivates concurrent priority queues with graph search and
discrete event simulation (§1); this package supplies those applications
as first-class workload drivers plus a trace record/replay pipeline:

  * `graphs` / `sssp` — CSR random graphs, the Bellman-Ford oracle, and
    the batched wavefront-Dijkstra engine (fixed-schedule and adaptive
    SmartPQ forms) with an empirical wasted-relaxation counter;
  * `des` — the hold-model churn driver (state-dependent keys, its own
    fused scan) and the heapq oracle; the bursty M/M/1 arrival variant
    lives in `traces` as a pregenerated stream;
  * `traces` — the `Trace` npz interchange format, `replay` through
    `SmartPQ.run_window`, the phased/adversarial generators, and the
    paper's Table 2/3 phase schedules (single source of truth for
    `benchmarks/fig10_dynamic.py` and the tests);
  * `registry` — name → driver enumeration for benchmarks and tests.
"""

from repro.workloads.graphs import Graph, bellman_ford, random_graph
from repro.workloads.sssp import (
    SSSPResult,
    make_smartpq_sssp_engine,
    make_sssp_engine,
    run_sssp,
    run_sssp_smartpq,
)
from repro.workloads.des import (
    DESResult,
    hold_model_oracle,
    make_hold_engine,
    run_hold_model,
)
from repro.workloads.traces import (
    Trace,
    bursty_des_trace,
    bursty_serve_workload,
    load_trace,
    mix_drift_trace,
    mmpp_arrival_counts,
    open_loop_requests,
    phase_flip_trace,
    phased_trace,
    poisson_arrival_counts,
    prefill,
    replay,
    save_trace,
    size_ramp_trace,
)
from repro.workloads.registry import WORKLOADS, WorkloadSpec, default_pq

__all__ = [
    "Graph", "bellman_ford", "random_graph",
    "SSSPResult", "make_smartpq_sssp_engine", "make_sssp_engine",
    "run_sssp", "run_sssp_smartpq",
    "DESResult", "hold_model_oracle", "make_hold_engine", "run_hold_model",
    "Trace", "bursty_des_trace", "bursty_serve_workload", "load_trace",
    "mix_drift_trace", "mmpp_arrival_counts", "open_loop_requests",
    "phase_flip_trace", "phased_trace", "poisson_arrival_counts", "prefill",
    "replay", "save_trace", "size_ramp_trace",
    "WORKLOADS", "WorkloadSpec", "default_pq",
]
