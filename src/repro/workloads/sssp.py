"""Batched wavefront-Dijkstra / Δ-stepping SSSP over the concurrent PQ.

The paper motivates concurrent priority queues with exactly this loop (§1):
each step deleteMins an m-wide wavefront of tentative (distance, vertex)
pairs, relaxes the popped vertices' out-edges, and inserts improved
tentative distances back.  Everything runs on-device inside a `lax.scan`:

  * the wavefront pop is a schedule deleteMin (`SCHEDULE_FNS` for a fixed
    schedule, or the full adaptive `SmartPQ.step` for the SmartPQ driver);
  * edge relaxation gathers the padded adjacency rows of the popped
    vertices — a static ``(m, deg_cap)`` block — and folds the candidate
    distances into the dense distance array with ONE bulk-synchronous
    segment-min (`kernels.ops.segment_min_into`, a tunable registry
    kernel: direct scatter vs sort-dedup-scatter, bit-identical arms);
  * candidates that strictly improved re-enter the queue via `ops.insert`
    (masked lanes carry INF keys and cost nothing — the any-live-insert
    guard skips the whole pipeline when nothing improved).

Wasted relaxations: a popped pair whose distance exceeds the current
tentative distance is *stale* — the priority-inversion cost relaxed
schedules pay, and the quantity the classifier cost model's ``relax_alpha``
models analytically.  The driver counts them empirically (``wasted`` /
``pops``), which is what makes SSSP a measurement instrument and not just
a demo: exact schedules must show zero waste beyond same-batch collisions,
relaxed schedules trade waste for collective-free pops.

Correctness does not depend on the schedule: the loop is label-correcting
(like Δ-stepping), so ANY schedule that returns real queue elements
converges to the exact distances once the queue drains — exact schedules
just get there with fewer wasted pops.  The oracle is
`graphs.bellman_ford`; the exact-schedule distances are bit-equal to it.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pqueue import ops as O
from repro.core.pqueue import schedules as SCH
from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT, OP_NOP
from repro.core.pqueue.schedules import Schedule
from repro.core.pqueue.state import DEFAULT_HEAD_WIDTH, INF_KEY, make_state
from repro.kernels.ops import segment_min_into
from repro.workloads.graphs import Graph


class SSSPResult(NamedTuple):
    dist: np.ndarray  # (n,) int32 tentative distances (exact on convergence)
    pops: int  # total deleteMin pops served
    wasted: int  # stale pops (priority-inversion cost, empirical)
    improved: int  # relaxations that strictly improved a distance
    steps: int  # scan steps executed
    converged: bool  # queue drained before the step budget
    modes: Optional[np.ndarray] = None  # (steps,) SmartPQ mode trace
    transitions: int = 0


def _relax(dist, pop_k, pop_v, n_out, nbr, wgt, segmin_arm=None):
    """One bulk relaxation: fold the popped wavefront's out-edges into
    `dist` (the `segment_min_into` registry kernel — its arms are
    bit-identical, so the result is arm-independent) and emit the
    strictly-improving candidates as an INF-masked insert batch of static
    width m * deg_cap.

    Returns (dist, ins_keys, ins_vals, n_wasted, n_improved)."""
    n = dist.shape[0]
    m = pop_k.shape[0]
    lane = jnp.arange(m, dtype=jnp.int32)
    valid = lane < n_out
    u = jnp.clip(pop_v, 0, n - 1)
    fresh = valid & (pop_k <= dist[u])  # stale pops carry d > dist[u]
    n_wasted = jnp.sum(valid & ~fresh).astype(jnp.int32)

    vs = nbr[u]  # (m, deg_cap), sentinel n beyond degree
    ws = wgt[u]
    edge_ok = fresh[:, None] & (vs < n)
    d_src = jnp.where(fresh, pop_k, 0)  # keep the add overflow-free
    nd = jnp.where(edge_ok, d_src[:, None] + ws, INF_KEY)
    v_safe = jnp.where(edge_ok, vs, 0)
    improved = edge_ok & (nd < dist[v_safe])
    n_improved = jnp.sum(improved).astype(jnp.int32)

    # segment-min: out-of-range sentinel targets drop out of the fold
    tgt = jnp.where(edge_ok, vs, n)
    dist = segment_min_into(dist, tgt.ravel(), nd.ravel(), arm=segmin_arm)

    ins_keys = jnp.where(improved, nd, INF_KEY).ravel()
    ins_vals = v_safe.ravel()
    return dist, ins_keys, ins_vals, n_wasted, n_improved


def _init_dist_and_state(graph: Graph, num_shards, capacity, head_width, src):
    from repro.workloads.traces import prefill

    dist = jnp.full((graph.n,), INF_KEY, jnp.int32).at[src].set(0)
    st = make_state(num_shards, capacity, head_width=head_width)
    st = prefill(st, np.asarray([0], np.int32), np.asarray([src], np.int32))
    return dist, st


def make_sssp_engine(
    graph: Graph,
    schedule: Schedule,
    m: int = 32,
    num_shards: int = 8,
    capacity: int = 4096,
    head_width: int | None = None,
    npods: int = 2,
    chunk: int = 8,
    segmin_arm: str | None = None,
):
    """Fixed-schedule SSSP engine: chunks of `chunk` scan steps run
    on-device; the host only checks queue emptiness between chunks.  The
    returned ``run(src, seed, max_steps)`` closure reuses ONE jitted chunk
    program across calls, so benchmarks can time warm runs.  ``segmin_arm``
    pins the relax segment-min arm (None = registry dispatch)."""
    fn = SCH.SCHEDULE_FNS[schedule]
    nbr, wgt = graph.nbr, graph.wgt

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run_chunk(carry, rngs):
        def body(c, r):
            state, dist, pops, wasted, improved = c
            res = fn(state, m, jnp.int32(m), r, npods)
            dist, ins_k, ins_v, w, imp = _relax(
                dist, res.keys, res.vals, res.n_out, nbr, wgt,
                segmin_arm=segmin_arm,
            )
            state, _ = O.insert(res.state, ins_k, ins_v)
            return (state, dist, pops + res.n_out, wasted + w,
                    improved + imp), None

        c2, _ = jax.lax.scan(body, carry, rngs)
        return c2

    def run(src: int = 0, seed: int = 0, max_steps: int = 4096) -> SSSPResult:
        dist, st = _init_dist_and_state(
            graph, num_shards, capacity, head_width, src
        )
        # distinct zero buffers: the donated carry may not alias leaves
        carry = (st, dist, jnp.zeros((), jnp.int32),
                 jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        key = jax.random.key(seed)
        steps = 0
        while steps < max_steps:
            key, sub = jax.random.split(key)
            carry = run_chunk(carry, jax.random.split(sub, chunk))
            steps += chunk
            if int(carry[0].total_size) == 0:
                break
        st, dist, pops, wasted, improved = carry
        return SSSPResult(
            dist=np.asarray(dist), pops=int(pops), wasted=int(wasted),
            improved=int(improved), steps=steps,
            converged=int(st.total_size) == 0,
        )

    return run


def run_sssp(
    graph: Graph,
    schedule: Schedule,
    m: int = 32,
    num_shards: int = 8,
    capacity: int = 4096,
    head_width: int | None = None,
    npods: int = 2,
    src: int = 0,
    seed: int = 0,
    chunk: int = 8,
    max_steps: int = 4096,
    segmin_arm: str | None = None,
) -> SSSPResult:
    """One-shot fixed-schedule SSSP (see `make_sssp_engine`)."""
    run = make_sssp_engine(
        graph, schedule, m=m, num_shards=num_shards, capacity=capacity,
        head_width=head_width, npods=npods, chunk=chunk,
        segmin_arm=segmin_arm,
    )
    return run(src=src, seed=seed, max_steps=max_steps)


def make_smartpq_sssp_engine(
    graph: Graph,
    pq,  # SmartPQ — its config fixes shards/capacity/modes
    m: int = 16,
    chunk: int = 8,
    num_clients: int | None = None,
    segmin_arm: str | None = None,
):
    """Adaptive SSSP engine through `SmartPQ.step` — the full decision
    stack (featurization, packed-tree inference, N-mode switch,
    elimination) runs in the scan body, fed by the application's own op
    stream.

    The wavefront is pipelined by one step: step t inserts the improving
    candidates step t-1 relaxed, then pops the next m-wide wavefront — one
    mixed (insert, deleteMin) batch per step, which is exactly the op-log
    shape the trace recorder captures.  Batch width B = m * deg_cap + m;
    the SmartPQ head tier must satisfy H >= B (H-sizing rule in state.py).

    ``run(src, seed, max_steps, record)`` returns (SSSPResult, trace)
    where trace is a `traces.Trace` of the recorded (ops, keys, vals)
    windows when record=True, else None."""
    D = graph.deg_cap
    b_ins = m * D
    B = b_ins + m
    H = min(pq.config.head_width or DEFAULT_HEAD_WIDTH, pq.config.capacity)
    if B > H:
        raise ValueError(
            f"adaptive SSSP batch width {B} (m={m} * deg_cap={D} + m) "
            f"exceeds the hot head tier H={H} (H-sizing rule in state.py)"
        )
    if num_clients is None:
        num_clients = m
    nbr, wgt = graph.nbr, graph.wgt
    del_ops = jnp.full((m,), OP_DELETE_MIN, jnp.int32)
    del_keys = jnp.full((m,), INF_KEY, jnp.int32)

    @functools.partial(jax.jit, donate_argnums=(0,))
    def run_chunk(carry, rngs):
        def body(c, r):
            pqc, dist, pend_k, pend_v, pops, wasted, improved = c
            ins_ops = jnp.where(pend_k < INF_KEY, OP_INSERT, OP_NOP)
            ops = jnp.concatenate([ins_ops, del_ops])
            keys = jnp.concatenate([pend_k, del_keys])
            vals = jnp.concatenate([pend_v, jnp.zeros((m,), jnp.int32)])
            pqc, res = pq.step(pqc, ops, keys, vals, r, num_clients)
            dist, ins_k, ins_v, w, imp = _relax(
                dist, res.keys[:m], res.vals[:m], res.n_out, nbr, wgt,
                segmin_arm=segmin_arm,
            )
            c2 = (pqc, dist, ins_k, ins_v, pops + res.n_out, wasted + w,
                  improved + imp)
            return c2, (ops, keys, vals, pqc.stats.mode)

        return jax.lax.scan(body, carry, rngs)

    def run(src: int = 0, seed: int = 0, max_steps: int = 4096,
            record: bool = False):
        dist, st = _init_dist_and_state(
            graph, pq.config.num_shards, pq.config.capacity,
            pq.config.head_width, src,
        )
        pqc = pq.init()._replace(state=st)
        pend_k = jnp.full((b_ins,), INF_KEY, jnp.int32)
        pend_v = jnp.zeros((b_ins,), jnp.int32)
        # distinct zero buffers: the donated carry may not alias leaves
        carry = (pqc, dist, pend_k, pend_v, jnp.zeros((), jnp.int32),
                 jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        key = jax.random.key(seed)
        steps = 0
        log_ops, log_keys, log_vals, log_modes = [], [], [], []
        while steps < max_steps:
            key, sub = jax.random.split(key)
            carry, (o, k, v, mo) = run_chunk(
                carry, jax.random.split(sub, chunk)
            )
            steps += chunk
            log_modes.append(np.asarray(mo))
            if record:
                log_ops.append(np.asarray(o))
                log_keys.append(np.asarray(k))
                log_vals.append(np.asarray(v))
            pqc, pend_k = carry[0], carry[2]
            pending = int(jnp.sum(pend_k < INF_KEY))
            if int(pqc.state.total_size) == 0 and pending == 0:
                break
        pqc, dist = carry[0], carry[1]
        # the pipelined lag means a drained queue with pending candidates
        # is NOT converged: their out-edges were never relaxed
        pending = int(jnp.sum(carry[2] < INF_KEY))
        result = SSSPResult(
            dist=np.asarray(dist), pops=int(carry[4]), wasted=int(carry[5]),
            improved=int(carry[6]), steps=steps,
            converged=int(pqc.state.total_size) == 0 and pending == 0,
            modes=np.concatenate(log_modes),
            transitions=int(pqc.stats.transitions),
        )
        trace = None
        if record:
            from repro.workloads.traces import Trace

            trace = Trace(
                ops=np.concatenate(log_ops),
                keys=np.concatenate(log_keys),
                vals=np.concatenate(log_vals),
                num_clients=np.full((steps,), num_clients, np.int32),
                seed=seed,
                init_keys=np.asarray([0], np.int32),
                init_vals=np.asarray([src], np.int32),
            )
        return result, trace

    return run


def run_sssp_smartpq(
    graph: Graph,
    pq,
    m: int = 16,
    src: int = 0,
    seed: int = 0,
    chunk: int = 8,
    max_steps: int = 4096,
    num_clients: int | None = None,
    record: bool = False,
    segmin_arm: str | None = None,
):
    """One-shot adaptive SSSP (see `make_smartpq_sssp_engine`)."""
    run = make_smartpq_sssp_engine(
        graph, pq, m=m, chunk=chunk, num_clients=num_clients,
        segmin_arm=segmin_arm,
    )
    return run(src=src, seed=seed, max_steps=max_steps, record=record)
