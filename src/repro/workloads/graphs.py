"""Graph containers for the SSSP workload driver.

Two views of the same random graph:

  * a host CSR triple ``(indptr, indices, weights)`` — the reference form
    the Bellman-Ford oracle iterates over;
  * a device **padded adjacency** ``(n, deg_cap)`` pair of neighbor /
    weight arrays (sentinel neighbor id ``n`` marks padding) — the
    static-shape form the `lax.scan` relaxation step gathers from: every
    popped wavefront vertex contributes exactly ``deg_cap`` relaxation
    lanes, masked lanes carry INF keys, so the per-step op batch has a
    fixed width of ``m * deg_cap`` insert lanes.

Degree is capped at construction (``deg_cap``), not at conversion, so the
oracle and the device driver always see the identical edge multiset.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax.numpy as jnp

from repro.core.pqueue.state import INF_KEY


class Graph(NamedTuple):
    """CSR on the host + padded adjacency on the device."""

    n: int
    deg_cap: int
    # host CSR (numpy) — the Bellman-Ford reference iterates these
    indptr: np.ndarray  # (n + 1,) int32
    indices: np.ndarray  # (nnz,) int32
    weights: np.ndarray  # (nnz,) int32
    # device padded adjacency — the scan body gathers these
    nbr: jnp.ndarray  # (n, deg_cap) int32, sentinel n beyond degree
    wgt: jnp.ndarray  # (n, deg_cap) int32, 0 beyond degree

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])


def random_graph(
    n: int = 512, avg_deg: int = 4, deg_cap: int = 8, max_weight: int = 64,
    seed: int = 0,
) -> Graph:
    """Poisson-degree random digraph with positive int weights.

    Out-degree is clipped to ``deg_cap`` so the padded adjacency is lossless
    (the oracle and the driver relax the same edges)."""
    rng = np.random.default_rng(seed)
    indptr = np.zeros(n + 1, np.int32)
    indices, weights = [], []
    for u in range(n):
        deg = min(int(rng.poisson(avg_deg)) + 1, deg_cap, n - 1)
        vs = rng.choice(n, size=deg, replace=False)
        vs = vs[vs != u][:deg_cap]
        for v in vs:
            indices.append(int(v))
            weights.append(int(rng.integers(1, max_weight)))
        indptr[u + 1] = len(indices)
    indices = np.asarray(indices, np.int32)
    weights = np.asarray(weights, np.int32)

    nbr = np.full((n, deg_cap), n, np.int32)  # sentinel n == "no edge"
    wgt = np.zeros((n, deg_cap), np.int32)
    for u in range(n):
        lo, hi = int(indptr[u]), int(indptr[u + 1])
        nbr[u, : hi - lo] = indices[lo:hi]
        wgt[u, : hi - lo] = weights[lo:hi]
    return Graph(
        n=n, deg_cap=deg_cap, indptr=indptr, indices=indices,
        weights=weights, nbr=jnp.asarray(nbr), wgt=jnp.asarray(wgt),
    )


def bellman_ford(graph: Graph, src: int = 0) -> np.ndarray:
    """Exact distances — the SSSP oracle.  Returns (n,) int32 with
    unreachable vertices at INF_KEY (matching the device driver's
    sentinel), computed in int64 so relaxations cannot overflow."""
    n = graph.n
    dist = np.full(n, np.int64(INF_KEY))
    dist[src] = 0
    u_of_edge = np.repeat(
        np.arange(n, dtype=np.int64), np.diff(graph.indptr).astype(np.int64)
    )
    v_of_edge = graph.indices.astype(np.int64)
    w_of_edge = graph.weights.astype(np.int64)
    for _ in range(n):
        cand = dist[u_of_edge] + w_of_edge
        cand[dist[u_of_edge] >= INF_KEY] = INF_KEY
        nd = dist.copy()
        np.minimum.at(nd, v_of_edge, cand)
        if np.array_equal(nd, dist):
            break
        dist = nd
    return dist.astype(np.int32)
