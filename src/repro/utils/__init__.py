from repro.utils.hashing import mix32, shard_of_key  # noqa: F401
from repro.utils.treeutil import tree_bytes, tree_count  # noqa: F401
