"""Loop-aware HLO cost accounting — the roofline's measurement layer.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE (verified:
a lax.scan of L layers reports 1 layer of FLOPs), and naive text greps
under-count collectives the same way.  Since every model here runs depth
under lax.scan, the roofline needs a call-graph walk:

  total(comp) = own_ops(comp) + Σ_child total(child) * multiplicity(child)

where multiplicity is the while op's `known_trip_count` backend_config
(emitted by XLA for counted loops), 1 for calls/fusions, and max() over
conditional branches.  Per computation we account:

  * dot FLOPs: 2 * numel(result) * prod(contracted dims)  (shapes resolved
    through a per-computation symbol table),
  * collective bytes: output bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute (+ -start forms),
  * HBM-traffic proxy: output bytes of top-level ops in non-fusion
    computations (fusion internals are not materialized).

All numbers are PER DEVICE (the HLO module is the per-partition SPMD
program), matching memory_analysis()'s convention.
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s4": 1, "u4": 1,
    "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s+->\s+.*\{")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All 'dtype[dims]' occurrences in a type string."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((dt, dims))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _parse_shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel_first(type_str: str) -> Tuple[Optional[Tuple[int, ...]], int]:
    shapes = _parse_shapes(type_str)
    if not shapes:
        return None, 0
    dt, dims = shapes[0]
    n = 1
    for d in dims:
        n *= d
    return dims, n


@dataclasses.dataclass
class _Comp:
    name: str
    is_entry: bool = False
    flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    out_bytes: float = 0.0  # top-level op output bytes (HBM proxy)
    called_via_fusion: bool = False
    children: List[Tuple[str, float]] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class HLOCost:
    flops: float
    collective_bytes: float
    collective_by_op: Dict[str, float]
    collective_counts: Dict[str, float]
    hbm_bytes_proxy: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze_hlo(text: str) -> HLOCost:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    symbols: Dict[str, Tuple[int, ...]] = {}
    fusion_called: set = set()

    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line)
        if hdr:
            cur = _Comp(name=hdr.group(2), is_entry=bool(hdr.group(1)))
            comps[cur.name] = cur
            symbols = {}
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue

        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        dims, numel = _numel_first(type_str)
        if dims is not None:
            symbols[name] = dims

        cur.out_bytes += _bytes_of(type_str)

        # -- dots ------------------------------------------------------------
        if op == "dot":
            cm = _CONTRACT_RE.search(line)
            k = 1
            if cm:
                args = line.split("dot(", 1)[1]
                ops_m = _OPERAND_RE.findall(args.split(")", 1)[0])
                lhs_shape = symbols.get(ops_m[0]) if ops_m else None
                if lhs_shape is not None:
                    for d in cm.group(1).split(","):
                        if d and int(d) < len(lhs_shape):
                            k *= lhs_shape[int(d)]
            cur.flops += 2.0 * numel * k

        # -- collectives -----------------------------------------------------
        base = None
        for c in COLLECTIVE_OPS:
            if op == c or op == c + "-start":
                base = c
                break
        if base is not None:
            nbytes = _bytes_of(type_str)
            cur.coll_bytes[base] += nbytes
            cur.coll_counts[base] += 1

        # -- call graph --------------------------------------------------------
        if op == "while":
            body = cond = None
            bm = re.search(r"body=%([\w\.\-]+)", line)
            cm2 = re.search(r"condition=%([\w\.\-]+)", line)
            tm = _TRIP_RE.search(line)
            trips = float(tm.group(1)) if tm else 1.0
            if bm:
                cur.children.append((bm.group(1), trips))
            if cm2:
                cur.children.append((cm2.group(1), trips + 1))
        elif op in ("fusion", "call", "custom-call", "reduce", "sort", "map",
                    "reduce-window", "scatter", "select-and-scatter",
                    "all-reduce", "reduce-scatter"):
            for child in _CALLS_RE.findall(line):
                cur.children.append((child, 1.0))
                if op == "fusion":
                    fusion_called.add(child)
        elif op == "conditional":
            bm = _BRANCHES_RE.search(line)
            if bm:
                branches = _OPERAND_RE.findall(bm.group(1))
                for b in branches:
                    # max-cost semantics approximated by weighting one full
                    # visit per branch then taking max at aggregation time is
                    # complex; weight each branch by 1 (upper bound).
                    cur.children.append((b, 1.0))

    for fname in fusion_called:
        if fname in comps:
            comps[fname].called_via_fusion = True

    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HLOCost(0.0, 0.0, {}, {}, 0.0)

    memo: Dict[str, Tuple[float, Dict[str, float], Dict[str, float], float]] = {}
    visiting: set = set()

    def walk(name: str):
        if name in memo:
            return memo[name]
        if name not in comps or name in visiting:
            return (0.0, {}, {}, 0.0)
        visiting.add(name)
        c = comps[name]
        flops = c.flops
        coll = dict(c.coll_bytes)
        counts = dict(c.coll_counts)
        obytes = 0.0 if c.called_via_fusion else c.out_bytes
        for child, mult in c.children:
            cf, cc, cn, cb = walk(child)
            flops += mult * cf
            for k2, v in cc.items():
                coll[k2] = coll.get(k2, 0.0) + mult * v
            for k2, v in cn.items():
                counts[k2] = counts.get(k2, 0.0) + mult * v
            obytes += mult * cb
        visiting.discard(name)
        memo[name] = (flops, coll, counts, obytes)
        return memo[name]

    flops, coll, counts, obytes = walk(entry.name)
    return HLOCost(
        flops=flops,
        collective_bytes=sum(coll.values()),
        collective_by_op=coll,
        collective_counts=counts,
        hbm_bytes_proxy=obytes,
    )


# -- legacy helper (entry-level only; kept for comparison) --------------------


def collective_bytes(hlo_text: str):
    cost = analyze_hlo(hlo_text)
    return cost.collective_bytes, cost.collective_by_op, cost.collective_counts


def flops_and_bytes(cost: dict) -> Tuple[float, float]:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    return flops, nbytes


_ALIAS_BLOCK_RE = re.compile(r"input_output_alias=\{(.*?)\}\s*,\s*\w+=",
                             re.DOTALL)
_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\(\s*(\d+)\s*,\s*\{([\d,\s]*)\}\s*,\s*([\w-]+)\s*\)"
)


def donation_aliases(compiled):
    """Parse the compiled module's ``input_output_alias`` table (the record
    XLA emits when buffer donation succeeded).

    Returns a list of (output_index, param_number, param_index, kind)
    tuples — empty when nothing is aliased, i.e. when every donated input
    would still be copied.  This is the no-copy assertion the tiered PQ's
    donated step paths are pinned with (donated carries must alias through,
    otherwise each step pays a full O(S*C) state copy)."""
    text = compiled.as_text()
    m = _ALIAS_BLOCK_RE.search(text)
    if not m:
        return []
    return [
        (tuple(int(x) for x in out.split(",") if x.strip()),
         int(param),
         tuple(int(x) for x in pidx.split(",") if x.strip()),
         kind)
        for out, param, pidx, kind in _ALIAS_ENTRY_RE.findall(m.group(1))
    ]


def xla_cost_analysis(compiled) -> dict:
    """Version-stable `compiled.cost_analysis()`: older jax returns a list of
    per-module dicts (one entry per partition), newer returns the dict
    directly.  Always hands back a dict (empty when XLA reports nothing)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
