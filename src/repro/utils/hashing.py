"""Integer hashing used to route keys to shards.

The paper load-balances client threads across server threads round-robin
(Fig. 5, initServer).  The bulk-synchronous analogue is hash-routing each key
to a shard so that (a) load is balanced regardless of key distribution and
(b) the per-shard key stream looks uniform, which is what the SprayList-style
relaxed deletion (`spray` schedule) relies on for its top-K envelope.
"""

from __future__ import annotations

import jax.numpy as jnp

# Knuth multiplicative hashing constant (2^32 / phi), and a xorshift finisher
# (splitmix-style) so that adjacent keys land on unrelated shards.
_GOLDEN = jnp.uint32(0x9E3779B1)


def mix32(x: jnp.ndarray) -> jnp.ndarray:
    """32-bit finalizer (xorshift-multiply avalanche). Input any int dtype."""
    h = x.astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * _GOLDEN
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> 16)
    return h


def shard_of_key(keys: jnp.ndarray, num_shards: int) -> jnp.ndarray:
    """Shard id in [0, num_shards) for each key. Balanced for any key dist."""
    return (mix32(keys) % jnp.uint32(num_shards)).astype(jnp.int32)
