"""Small pytree utilities shared across substrates."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_bytes(tree) -> int:
    """Total bytes of all array leaves."""
    return sum(
        np.prod(x.shape) * x.dtype.itemsize
        for x in jax.tree.leaves(tree)
        if hasattr(x, "shape")
    )


def tree_count(tree) -> int:
    """Total number of scalar elements across leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def tree_cast(tree, dtype):
    """Cast all inexact leaves to `dtype`."""
    def _cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, tree)


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)
