from repro.data.synthetic import SyntheticLMDataset  # noqa: F401
from repro.data.loader import ShardedLoader  # noqa: F401
