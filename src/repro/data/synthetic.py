"""Deterministic synthetic LM data — structured enough that loss decreases.

Token streams are Markov-ish: token_{t+1} = (a * token_t + b + noise) % V
with per-sequence (a, b), so a model can reduce loss well below uniform —
the train-demo's success criterion (EXPERIMENTS.md §Examples).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    noise: float = 0.02
    fixed_map: bool = False  # one global (a, b): a memorizable bigram task
    # (per-sequence (a, b) requires in-context inference — much harder)

    def batch(self, step: int, batch_size: int):
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        if self.fixed_map:
            a = np.full((batch_size, 1), 5)
            b = np.full((batch_size, 1), 131 % self.vocab)
        else:
            a = rng.integers(1, 17, (batch_size, 1))
            b = rng.integers(0, self.vocab, (batch_size, 1))
        t0 = rng.integers(0, self.vocab, (batch_size, 1))
        toks = np.zeros((batch_size, self.seq_len + 1), np.int64)
        toks[:, :1] = t0
        for t in range(self.seq_len):
            nxt = (a[:, 0] * toks[:, t] + b[:, 0]) % self.vocab
            flip = rng.random(batch_size) < self.noise
            nxt = np.where(flip, rng.integers(0, self.vocab, batch_size), nxt)
            toks[:, t + 1] = nxt
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
