"""Sharded, prefetching host loader.

Single-controller version of the multi-host input pipeline: the loader
produces the GLOBAL batch, places it with the batch sharding, and prefetches
`depth` batches ahead on a background thread so host data work overlaps
device steps.  Under multi-host jax.distributed each process would build
only its addressable shard (`process_slice`), same interface.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np


class ShardedLoader:
    def __init__(
        self,
        make_batch: Callable[[int], dict],
        sharding=None,
        depth: int = 2,
        start_step: int = 0,
    ):
        self._make = make_batch
        self._sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            if self._sharding is not None:
                batch = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), batch, self._sharding
                )
            try:
                self._q.put((step, batch), timeout=1.0)
            except queue.Full:
                if self._stop.is_set():
                    return
                continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
