"""repro — SmartPQ: an adaptive distributed priority queue for TPU pod hierarchies.

Reproduction + TPU adaptation of:
  "SmartPQ: An Adaptive Concurrent Priority Queue for NUMA Architectures"
  (Giannoula, Strati, Siakavaras, Goumas, Koziris — CS.DC 2024)

Public API re-exports are LAZY (module __getattr__): `python -m
repro.launch.dryrun` must be able to set XLA_FLAGS before anything imports
jax, and importing this package must therefore stay jax-free.
"""

__version__ = "1.0.0"

_EXPORTS = {
    "PQState": ("repro.core.pqueue.state", "PQState"),
    "make_state": ("repro.core.pqueue.state", "make_state"),
    "insert": ("repro.core.pqueue.ops", "insert"),
    "delete_min": ("repro.core.pqueue.ops", "delete_min"),
    "peek_min": ("repro.core.pqueue.ops", "peek_min"),
    "apply_op_batch": ("repro.core.pqueue.ops", "apply_op_batch"),
    "Schedule": ("repro.core.pqueue.ops", "Schedule"),
    "SmartPQ": ("repro.core.smartpq", "SmartPQ"),
    "SmartPQConfig": ("repro.core.smartpq", "SmartPQConfig"),
}


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        module, attr = _EXPORTS[name]
        return getattr(importlib.import_module(module), attr)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_EXPORTS))
