"""Production mesh definition (a FUNCTION — importing this module never
touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    from repro.distributed.mesh import _axis_type_kwargs

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))
