import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. builds the step function for the shape kind (train/prefill/serve),
  3. lowers against ShapeDtypeStruct inputs (no allocation), compiles,
  4. prints memory_analysis() (fits-per-device proof) and cost_analysis(),
  5. parses the optimized HLO for collective bytes (roofline term 3),
  6. writes a JSON record to experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all --multi-pod both
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import get_config, list_configs
from repro.distributed.sharding import ShardingRules, strip_pod
from repro.launch.mesh import make_production_mesh
from repro.models.io import input_specs
from repro.models.params import init_params
from repro.train.optimizer import AdamWConfig, adamw_init, opt_state_specs
from repro.train.steps import (
    batch_spec_tree,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.utils.hlo import analyze_hlo, xla_cost_analysis

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# Per-arch optimizer state dtype (memory fit on the single pod, DESIGN.md).
STATE_DTYPE = {
    "jamba-1.5-large-398b": "int8",
    "qwen2.5-32b": "bf16",
    "llama-3.2-vision-11b": "bf16",
    "granite-8b": "bf16",
}


def abstract_state(cfg, mesh, rules, opt_cfg, serving: bool = False):
    """Params/opt-state as ShapeDtypeStructs + matching spec trees —
    no 398B allocation ever happens.  Serving stores params in bf16
    (there is no optimizer to need fp32 masters)."""
    box = {}

    def capture(key):
        p, s = init_params(cfg, key, rules, mesh.shape.get("model", 16))
        box["specs"] = s
        if serving:
            p = jax.tree.map(
                lambda x: x.astype(jnp.bfloat16)
                if jnp.issubdtype(x.dtype, jnp.floating)
                else x,
                p,
            )
        return p

    params_sds = jax.eval_shape(capture, jax.random.key(0))
    param_specs = box["specs"]
    opt_sds = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_sds)
    opt_specs = opt_state_specs(params_sds, param_specs, opt_cfg)
    return params_sds, param_specs, opt_sds, opt_specs


def _strip(spec_tree, mesh):
    """Drop pod axis from spec trees when the mesh has none."""
    if "pod" in mesh.axis_names:
        return spec_tree

    def fix(spec):
        entries = []
        for e in spec:
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a != "pod")
                entries.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                entries.append(None if e == "pod" else e)
        return P(*entries)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def _sh(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def lower_cell(arch: str, shape_name: str, multi_pod: bool, kv_chunk: int = 2048,
               cast_before_scan: bool = False, serve_tp_only: bool = False,
               microbatches: int = 1, auto_policy: bool = False,
               kv_int8: bool = False, tag_suffix: str = ""):
    """Lower + compile one cell; returns the result record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = strip_pod(ShardingRules(), mesh)
    n_batch_devs = mesh.shape.get("pod", 1) * mesh.shape.get("data", 1)
    if shape.global_batch % n_batch_devs != 0:
        from repro.distributed.sharding import drop_batch_axes

        rules = drop_batch_axes(rules)
    # TP-only serving placement only when bf16 params fit comfortably next
    # to the KV cache when replicated over 'data' (<= ~4 GiB/device).
    model_axis = mesh.shape.get("model", 1)
    params_fit_tp = cfg.param_count() * 2 / model_axis <= 2 * 2**30
    if serve_tp_only and shape.kind in ("prefill", "decode") and params_fit_tp:
        from repro.distributed.sharding import tp_only_params

        rules = tp_only_params(rules)
    if auto_policy and shape.kind == "train":
        from repro.distributed.policy import apply_policy

        rules = apply_policy(cfg, mesh, rules, global_batch=shape.global_batch)
    opt_cfg = AdamWConfig(state_dtype=STATE_DTYPE.get(arch, "fp32"))

    t0 = time.time()
    use_int8 = kv_int8 and cfg.family in ("dense", "moe")
    specs_in = input_specs(cfg, shape, kv_int8=use_int8)
    batch_specs = _strip(
        batch_spec_tree(cfg, shape, ShardingRules(), mesh, kv_int8=use_int8), mesh
    )
    batch_sh = _sh(mesh, batch_specs)

    if shape.kind == "train":
        step, model = make_train_step(
            cfg, mesh, opt_cfg, rules=rules, remat=True, kv_chunk=kv_chunk,
            cast_before_scan=cast_before_scan, microbatches=microbatches,
        )
        params_sds, p_specs, opt_sds, o_specs = abstract_state(
            cfg, mesh, rules, opt_cfg
        )
        p_sh, o_sh = _sh(mesh, p_specs), _sh(mesh, o_specs)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, batch_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_sds, opt_sds, specs_in)
    elif shape.kind == "prefill":
        step, model = make_prefill_step(cfg, mesh, kv_chunk=kv_chunk, rules=rules,
                                        cast_before_scan=cast_before_scan)
        params_sds, p_specs, _, _ = abstract_state(cfg, mesh, rules, opt_cfg,
                                                     serving=True)
        p_sh = _sh(mesh, p_specs)
        jitted = jax.jit(step, in_shardings=(p_sh, batch_sh))
        lowered = jitted.lower(params_sds, specs_in)
    else:  # decode / serve
        step, model = make_serve_step(cfg, mesh, kv_chunk=max(kv_chunk, 4096),
                                      rules=rules, kv_int8=kv_int8,
                                      cast_before_scan=cast_before_scan)
        params_sds, p_specs, _, _ = abstract_state(cfg, mesh, rules, opt_cfg,
                                                     serving=True)
        p_sh = _sh(mesh, p_specs)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, batch_sh),
            out_shardings=batch_sh,
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_sds, specs_in)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()  # PER-DEVICE (SPMD module stats)
    cost = xla_cost_analysis(compiled)
    hlo_cost = analyze_hlo(compiled.as_text())  # loop-aware, per-device

    n_chips = 1
    for _, v in mesh.shape.items():
        n_chips *= v

    rec = {
        "arch": arch,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "ok",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        # raw XLA numbers (per device, while-bodies counted once):
        "xla_flops_body_once": float(cost.get("flops", 0.0)),
        "xla_bytes_body_once": float(cost.get("bytes accessed", 0.0)),
        # loop-aware walker numbers (per device):
        "flops_per_device": hlo_cost.flops,
        "hbm_bytes_proxy_per_device": hlo_cost.hbm_bytes_proxy,
        "collective_bytes_per_device": hlo_cost.collective_bytes,
        "collective_bytes_by_op": hlo_cost.collective_by_op,
        "collective_counts": hlo_cost.collective_counts,
        "memory_per_device": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec["memory_per_device"]["peak_estimate_bytes"] = peak
    fits = peak <= 16 * 2**30
    rec["fits_16gib_hbm"] = bool(fits)
    print(f"[dryrun] {arch} x {shape_name} x {'2pod' if multi_pod else '1pod'}: "
          f"compile {t_compile:.0f}s | peak/device {peak / 2**30:.2f} GiB "
          f"({'FITS' if fits else 'OVER'}) | flops/dev {hlo_cost.flops:.3e} | "
          f"coll/dev {hlo_cost.collective_bytes / 2**30:.3f} GiB")
    print("  memory_analysis:", mem)
    interesting = {k: v for k, v in cost.items()
                   if k in ("flops", "bytes accessed", "transcendentals")}
    print("  cost_analysis:", interesting)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", choices=("true", "false", "both"), default="false")
    ap.add_argument("--kv-chunk", type=int, default=2048)
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--cast-before-scan", action="store_true",
                    help="perf: bf16-cast stacked params outside the scan")
    ap.add_argument("--serve-tp-only", action="store_true",
                    help="perf: serving params TP-sharded, data-replicated")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="perf: gradient accumulation slices (train shapes)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="perf: int8 KV cache with per-(token,head) scales "
                         "(decode shapes, dense/moe families)")
    ap.add_argument("--auto-policy", action="store_true",
                    help="perf: per-arch parallelism policy (replicate block "
                         "weights for TP-starved models)")
    ap.add_argument("--tag", default="", help="suffix for output JSON names")
    args = ap.parse_args()

    archs = list_configs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"true": [True], "false": [False], "both": [False, True]}[args.multi_pod]

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                tag = f"{arch}_{shape}_{'2pod' if mp else '1pod'}{args.tag}"
                try:
                    rec = lower_cell(
                        arch, shape, mp, kv_chunk=args.kv_chunk,
                        cast_before_scan=args.cast_before_scan,
                        serve_tp_only=args.serve_tp_only,
                        microbatches=args.microbatches,
                        auto_policy=args.auto_policy,
                        kv_int8=args.kv_int8,
                    )
                except Exception as e:  # noqa: BLE001 — report, keep going
                    rec = {
                        "arch": arch, "shape": shape, "multi_pod": mp,
                        "status": "error", "error": repr(e),
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures.append(tag)
                    print(f"[dryrun] FAIL {tag}: {e!r}")
                (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=2))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("[dryrun] all cells OK")


if __name__ == "__main__":
    main()
