"""Training launcher.

Single-host CPU (default) runs reduced configs end-to-end; with
--dry-devices 512 it builds the production mesh for AOT compile checks
(use dryrun.py for the full cell sweep).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt --resume auto
"""

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--state-dtype", default="fp32",
                    choices=("fp32", "bf16", "int8"))
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=("auto", "never"))
    ap.add_argument("--dry-devices", type=int, default=0)
    args = ap.parse_args()

    if args.dry_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.dry_devices}"
        )

    from repro.configs.registry import get_config, reduced_config
    from repro.data.synthetic import SyntheticLMDataset
    from repro.train.loop import LoopConfig, run
    from repro.train.optimizer import AdamWConfig

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    if args.resume == "never" and args.ckpt_dir:
        import shutil

        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    data = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq, fixed_map=True)
    res = run(
        cfg,
        LoopConfig(
            steps=args.steps,
            batch_size=args.batch,
            ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir,
        ),
        opt_cfg=AdamWConfig(lr=args.lr, state_dtype=args.state_dtype),
        data=data,
        install_signals=True,
    )
    print(
        f"[train] {cfg.name}: steps={res['steps_done']} "
        f"loss {res['losses'][0]:.3f} -> {res['losses'][-1]:.3f} "
        f"resumed_from={res['resumed_from']} events={len(res['events'])}"
    )


if __name__ == "__main__":
    main()
