"""Serving launcher: SmartPQ continuous batching over a synthetic workload.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --reduced \
      --requests 24 --slots 4
"""

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--burst", type=int, default=6)
    args = ap.parse_args()

    import numpy as np
    import jax

    from repro.configs.registry import get_config, reduced_config
    from repro.models.registry import build_model
    from repro.serve.engine import EngineConfig, ServeEngine
    from repro.serve.scheduler import Request

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg, remat=False)
    params, _ = model.init(jax.random.key(0))
    engine = ServeEngine(
        cfg, params, EngineConfig(batch_size=args.slots, max_seq=args.max_seq)
    )

    rng = np.random.default_rng(0)
    workload, uid = [], 0
    while uid < args.requests:
        arrivals = []
        for _ in range(min(args.burst, args.requests - uid)):
            arrivals.append(
                Request(
                    uid=uid,
                    prompt_len=int(rng.integers(4, 16)),
                    max_new_tokens=int(rng.integers(2, 6)),
                    slo_class=int(rng.integers(0, 3)),
                )
            )
            uid += 1
        workload.append(arrivals)
        workload.extend([[]] * 4)

    summary = engine.run(workload, max_steps=10_000)
    print(
        f"[serve] {cfg.name}: {summary['completed']}/{args.requests} requests "
        f"in {summary['steps']} steps ({summary['wall_s']:.1f}s), "
        f"pq transitions={summary['pq_transitions']}"
    )


if __name__ == "__main__":
    main()
