"""Cache init + input_specs — ShapeDtypeStruct stand-ins for the dry-run.

`input_specs(cfg, shape)` returns the exact input pytree each step function
lowers against (weak-type-correct, shardable, no allocation).  `init_caches`
builds real zero caches for smoke tests and serving; `cache_specs` builds
the ShapeDtypeStruct mirror for decode dry-runs.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.params import padded_vocab

Tree = Dict[str, Any]


def _cache_shapes(cfg: ModelConfig, B: int, S_max: int, dtype=jnp.bfloat16,
                  kv_int8: bool = False):
    """Family-specific cache pytree of (shape, dtype) tuples."""
    hd = cfg.resolved_head_dim
    Hkv = cfg.n_kv_heads
    fam = cfg.family
    out: Tree = {}
    if fam in ("dense", "moe"):
        kv_dtype = jnp.int8 if kv_int8 else dtype
        out["k"] = ((cfg.n_layers, B, S_max, Hkv, hd), kv_dtype)
        out["v"] = ((cfg.n_layers, B, S_max, Hkv, hd), kv_dtype)
        if kv_int8:
            out["k_scale"] = ((cfg.n_layers, B, S_max, Hkv), jnp.bfloat16)
            out["v_scale"] = ((cfg.n_layers, B, S_max, Hkv), jnp.bfloat16)
    elif fam == "ssm":
        s = cfg.ssm
        H = s.d_inner // s.head_dim
        conv_ch = s.d_inner + 2 * s.n_groups * s.d_state
        out["ssm_h"] = ((cfg.n_layers, B, H, s.d_state, s.head_dim), jnp.float32)
        out["ssm_conv"] = ((cfg.n_layers, B, s.d_conv - 1, conv_ch), jnp.float32)
    elif fam == "hybrid":
        s = cfg.ssm
        H = s.d_inner // s.head_dim
        conv_ch = s.d_inner + 2 * s.n_groups * s.d_state
        nsb = cfg.n_layers // cfg.hybrid_period
        nm = cfg.hybrid_period - 1
        out["k"] = ((nsb, B, S_max, Hkv, hd), dtype)
        out["v"] = ((nsb, B, S_max, Hkv, hd), dtype)
        out["ssm_h"] = ((nsb, nm, B, H, s.d_state, s.head_dim), jnp.float32)
        out["ssm_conv"] = ((nsb, nm, B, s.d_conv - 1, conv_ch), jnp.float32)
    elif fam == "encdec":
        Ld = cfg.n_layers
        S_enc = S_max  # encoder context sized like the cell's seq_len
        out["k"] = ((Ld, B, S_max, Hkv, hd), dtype)
        out["v"] = ((Ld, B, S_max, Hkv, hd), dtype)
        out["xk"] = ((Ld, B, S_enc, Hkv, hd), dtype)
        out["xv"] = ((Ld, B, S_enc, Hkv, hd), dtype)
    elif fam == "vlm":
        k = cfg.cross_attn_every
        ng = cfg.n_layers // k
        out["k"] = ((ng, k, B, S_max, Hkv, hd), dtype)
        out["v"] = ((ng, k, B, S_max, Hkv, hd), dtype)
        out["xk"] = ((ng, B, cfg.n_image_tokens, Hkv, hd), dtype)
        out["xv"] = ((ng, B, cfg.n_image_tokens, Hkv, hd), dtype)
    else:
        raise ValueError(fam)
    return out


def init_caches(cfg: ModelConfig, B: int, S_max: int, dtype=jnp.bfloat16,
                kv_int8: bool = False) -> Tree:
    return jax.tree.map(
        lambda sd: jnp.zeros(sd[0], sd[1]),
        _cache_shapes(cfg, B, S_max, dtype, kv_int8),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )


def cache_specs(cfg: ModelConfig, B: int, S_max: int, dtype=jnp.bfloat16,
                kv_int8: bool = False) -> Tree:
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(sd[0], sd[1]),
        _cache_shapes(cfg, B, S_max, dtype, kv_int8),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig, kv_int8: bool = False) -> Tree:
    """The step function's input pytree as ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
    emb = lambda *s: jax.ShapeDtypeStruct(s, jnp.bfloat16)

    if shape.kind == "train":
        batch: Tree = {"tokens": tok(B, S), "labels": tok(B, S)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = emb(B, S, D)  # conv frontend stubbed
        if cfg.family == "vlm":
            batch["image_embeds"] = emb(B, cfg.n_image_tokens, D)
        return batch

    if shape.kind == "prefill":
        batch = {"tokens": tok(B, S)}
        if cfg.family == "encdec":
            batch["enc_embeds"] = emb(B, S, D)
        if cfg.family == "vlm":
            batch["image_embeds"] = emb(B, cfg.n_image_tokens, D)
        return batch

    if shape.kind == "decode":
        use_int8 = kv_int8 and cfg.family in ("dense", "moe")
        return {
            "tokens": tok(B, 1),
            "lengths": tok(B),
            "caches": cache_specs(cfg, B, S, kv_int8=use_int8),
        }
    raise ValueError(shape.kind)
