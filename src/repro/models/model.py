"""Unified model: train / prefill / decode paths for all six families.

Depth always runs under lax.scan over layer-stacked params (hybrid scans
period-8 superblocks; vlm scans cross-attn groups) — compile time at 512
devices stays proportional to ONE block, not the full depth.

Sharding is applied as with_sharding_constraint at block boundaries using
the rules in distributed/sharding.py; when mesh is None (CPU smoke tests)
constraints are no-ops.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, constraint, strip_pod
from repro.models.layers.attention import (
    AttnDims,
    attend_chunked,
    project_qkv,
)
from repro.models.layers.mlp import dense_mlp, gated_mlp
from repro.models.layers.moe import MoEDims, moe_block
from repro.models.layers.norm import layer_norm, rms_norm
from repro.models.layers.rope import apply_rope
from repro.models.layers.ssm import (
    SSMDims,
    SSMState,
    ssd_decode_step,
    ssd_forward,
)
from repro.models.params import init_params, padded_experts, padded_vocab

Tree = Dict[str, Any]


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    mesh: Optional[Mesh] = None
    rules: Optional[ShardingRules] = None
    compute_dtype: Any = jnp.bfloat16
    kv_chunk: int = 2048
    remat: bool = True
    model_axis_size: int = 16
    # perf knobs (EXPERIMENTS.md §Perf iterations)
    cast_before_scan: bool = False  # bf16-cast stacked params OUTSIDE the
    # layer scan: ZeRO gathers then move bf16 (half the collective bytes)
    kv_int8: bool = False  # int8 KV cache with per-(token, head) scales —
    # halves the decode memory sweep (dense/moe families; It-8)

    def __post_init__(self):
        if self.mesh is not None and self.rules is None:
            self.rules = strip_pod(ShardingRules(), self.mesh)
        self.attn_dims = AttnDims(
            n_heads=self.cfg.n_heads,
            n_kv_heads=self.cfg.n_kv_heads,
            head_dim=self.cfg.resolved_head_dim,
            rope_theta=self.cfg.rope_theta,
        )
        if self.cfg.ssm:
            s = self.cfg.ssm
            self.ssm_dims = SSMDims(
                d_model=self.cfg.d_model,
                d_inner=s.d_inner,
                head_dim=s.head_dim,
                d_state=s.d_state,
                n_groups=s.n_groups,
                d_conv=s.d_conv,
                chunk=s.chunk,
            )
        if self.cfg.moe:
            self.moe_dims = MoEDims(
                n_experts=self.cfg.moe.n_experts,
                n_experts_pad=padded_experts(self.cfg, self.model_axis_size),
                top_k=self.cfg.moe.top_k,
                capacity_factor=self.cfg.moe.capacity_factor,
            )

    # -- helpers -------------------------------------------------------------

    def _c(self, x, spec):
        if self.mesh is None:
            return x
        return constraint(x, self.mesh, spec)

    def _norm(self, x, scale, bias=None):
        if self.cfg.norm == "layer":
            return layer_norm(x, scale, bias)
        return rms_norm(x, scale)

    def _cast(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if jnp.issubdtype(x.dtype, jnp.floating)
            else x,
            tree,
        )

    def _w(self, w, rule_name: str):
        """FSDP weight-gather: constrain a per-layer weight slice to its
        COMPUTE sharding — the param rule minus the leading scan dim and
        minus the 'data' (ZeRO) factor.  Without this, XLA resolves the
        (batch@data x weight@data) contraction conflict by gathering the
        ACTIVATION instead (observed: a 432 GiB/step all-gather of the FFN
        hidden on qwen train)."""
        if self.mesh is None:
            return w
        from jax.sharding import PartitionSpec as P

        spec = getattr(self.rules or ShardingRules(), rule_name)
        entries = list(spec)
        if len(entries) == w.ndim + 1:  # strip the scanned layer dim
            entries = entries[1:]

        def fix(e):
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a != "data")
                return kept if len(kept) > 1 else (kept[0] if kept else None)
            return None if e == "data" else e

        entries = [fix(e) for e in entries][: w.ndim]
        entries += [None] * (w.ndim - len(entries))
        return constraint(w, self.mesh, P(*entries))

    def init(self, rng: jax.Array) -> Tuple[Tree, Tree]:
        return init_params(self.cfg, rng, self.rules or ShardingRules(),
                           self.model_axis_size)

    # -- sublayers -------------------------------------------------------------

    def _attn_full(self, x, p, q_pos, kv_pos, collect_cache: bool):
        """Self-attention over a full sequence.  Returns (y, (k, v)|None)."""
        r = self.rules or ShardingRules()
        h = self._norm(x, p["norm"], p.get("norm_b"))
        h = self._c(h, r.act_seq)
        bias = (p["bq"], p["bk"], p["bv"]) if "bq" in p else None
        q, k, v = project_qkv(
            h, self._w(p["wq"], "wq"), self._w(p["wk"], "wkv"),
            self._w(p["wv"], "wkv"), self.attn_dims, q_pos, kv_pos, bias
        )
        out = attend_chunked(
            q, k, v, self.attn_dims, q_pos, kv_pos, kv_chunk=self.kv_chunk
        )
        B, S = out.shape[:2]
        y = out.reshape(B, S, -1) @ self._w(p["wo"], "wo")
        y = self._c(y, r.act_btd)
        cache = (k, v) if collect_cache else None
        return x + y, cache

    @staticmethod
    def _q8_kv(x):  # (B, 1, H, hd) -> (int8 values, (B,1,H) scale)
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) + 1e-8
        s = amax / 127.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]), -127, 127)
        return q.astype(jnp.int8), s.astype(jnp.bfloat16)

    def _attn_decode(self, x, p, cache_k, cache_v, lengths, scales=None):
        """One-token self-attention against a per-request-length cache.
        `scales`: (k_scale, v_scale) (B, S, Hkv) for the int8-KV path."""
        r = self.rules or ShardingRules()
        B = x.shape[0]
        h = self._norm(x, p["norm"], p.get("norm_b"))
        bias = (p["bq"], p["bk"], p["bv"]) if "bq" in p else None
        qpos = lengths[:, None]
        q, k_new, v_new = project_qkv(
            h, self._w(p["wq"], "wq"), self._w(p["wk"], "wkv"),
            self._w(p["wv"], "wkv"), self.attn_dims, qpos, qpos, bias
        )
        bi = jnp.arange(B)
        if scales is not None:
            ks, vs = scales
            k_q, k_s = self._q8_kv(k_new)
            v_q, v_s = self._q8_kv(v_new)
            cache_k = cache_k.at[bi, lengths].set(k_q[:, 0])
            cache_v = cache_v.at[bi, lengths].set(v_q[:, 0])
            ks = ks.at[bi, lengths].set(k_s[:, 0])
            vs = vs.at[bi, lengths].set(v_s[:, 0])
            scales = (ks, vs)
        else:
            cache_k = cache_k.at[bi, lengths].set(k_new[:, 0].astype(cache_k.dtype))
            cache_v = cache_v.at[bi, lengths].set(v_new[:, 0].astype(cache_v.dtype))
        S_max = cache_k.shape[1]
        pos = jnp.broadcast_to(jnp.arange(S_max, dtype=jnp.int32), (B, S_max))
        valid = pos < (lengths[:, None] + 1)
        out = attend_chunked(
            q,
            cache_k if scales is not None else cache_k.astype(q.dtype),
            cache_v if scales is not None else cache_v.astype(q.dtype),
            self.attn_dims,
            qpos,
            pos,
            kv_valid=valid,
            kv_chunk=self.kv_chunk,
            k_scale=scales[0] if scales is not None else None,
            v_scale=scales[1] if scales is not None else None,
        )
        y = out.reshape(B, 1, -1) @ self._w(p["wo"], "wo")
        if scales is not None:
            return x + y, cache_k, cache_v, scales
        return x + y, cache_k, cache_v

    def _cross_attn(self, x, p, ctx_k, ctx_v, gate=None):
        """Cross-attention to precomputed context K/V (no RoPE, non-causal)."""
        r = self.rules or ShardingRules()
        dims = dataclasses.replace(self.attn_dims, causal=False)
        h = self._norm(x, p["norm"], p.get("norm_b"))
        B, S, _ = h.shape
        q = (h @ self._w(p["wq"], "wq")).reshape(B, S, dims.n_heads, dims.head_dim)
        if "bq" in p:
            q = q + p["bq"].reshape(1, 1, dims.n_heads, dims.head_dim)
        qpos = jnp.zeros((B, S), jnp.int32)
        kpos = jnp.zeros((B, ctx_k.shape[1]), jnp.int32)
        out = attend_chunked(
            q, ctx_k, ctx_v, dims, qpos, kpos, kv_chunk=self.kv_chunk
        )
        y = out.reshape(B, S, -1) @ self._w(p["wo"], "wo")
        if gate is not None:
            y = jnp.tanh(gate).astype(y.dtype) * y
        return x + self._c(y, r.act_btd)

    def _context_kv(self, p, ctx):
        """Project a context (image / encoder states) into cross K/V."""
        dims = self.attn_dims
        B, S, _ = ctx.shape
        k = (ctx @ self._w(p["wk"], "wkv")).reshape(B, S, dims.n_kv_heads, dims.head_dim)
        v = (ctx @ self._w(p["wv"], "wkv")).reshape(B, S, dims.n_kv_heads, dims.head_dim)
        if "bk" in p:
            k = k + p["bk"].reshape(1, 1, dims.n_kv_heads, dims.head_dim)
            v = v + p["bv"].reshape(1, 1, dims.n_kv_heads, dims.head_dim)
        return k, v

    def _ffn(self, x, p):
        """Megatron column->row parallel FFN with the hidden PINNED to
        (B, S, F@model).  Without the pin, sharding propagation from the
        sequence-parallel attention zone put the hidden at S@model and XLA
        materialized a full (B, S_full, F_full) gather per layer (432
        GiB/step on qwen train)."""
        r = self.rules or ShardingRules()
        h = self._norm(x, p["norm"], p.get("norm_b"))
        h = self._c(h, r.act_btd)
        if self.cfg.act == "gelu_mlp":
            g = self._c(h @ self._w(p["w_in"], "w_in") + p["b_in"], r.act_ffn)
            mid = jax.nn.gelu(g, approximate=True)
            y = mid @ self._w(p["w_out"], "w_out") + p["b_out"]
        else:
            g = self._c(h @ self._w(p["w_gate"], "w_in"), r.act_ffn)
            u = self._c(h @ self._w(p["w_up"], "w_in"), r.act_ffn)
            act = jax.nn.silu if self.cfg.act == "silu" else (
                lambda v: jax.nn.gelu(v, approximate=True)
            )
            mid = self._c(act(g) * u, r.act_ffn)
            y = mid @ self._w(p["w_down"], "w_out")
        return x + self._c(y, r.act_btd)

    def _moe_ffn(self, x, p):
        r = self.rules or ShardingRules()
        h = self._norm(x, p["norm"])
        if self.mesh is not None and "model" in self.mesh.axis_names:
            from repro.models.layers.moe import moe_block_ep

            tok = r.tokens[0]
            if isinstance(tok, str):
                batch_axes = (tok,)
            else:
                batch_axes = tuple(tok) if tok else ()
            y, aux = moe_block_ep(
                h, p["router"], p["e_gate"], p["e_up"], p["e_down"],
                self.moe_dims, self.mesh, batch_axes,
            )
        else:
            y, aux = moe_block(
                h, p["router"], p["e_gate"], p["e_up"], p["e_down"], self.moe_dims
            )
        return x + self._c(y, r.act_btd), aux

    def _ssm_cstr(self):
        """Head-dim sharding callback for SSD internals (None when the mesh
        can't shard H or there is no mesh)."""
        if self.mesh is None or "model" not in self.mesh.axis_names:
            return None
        n_model = self.mesh.shape["model"]
        if self.ssm_dims.n_heads % n_model or self.ssm_dims.d_inner % n_model:
            return None
        from jax.sharding import PartitionSpec as P

        mesh = self.mesh
        r = self.rules or ShardingRules()
        batch_entry = r.act_btd[0]  # respects drop_batch_axes

        def cstr(a, axis):
            entries = [None] * a.ndim
            entries[0] = batch_entry
            entries[axis if axis >= 0 else a.ndim + axis] = "model"
            return constraint(a, mesh, P(*entries))

        return cstr

    def _ssm_layer(self, x, p, h0=None):
        h = self._norm(x, p["norm"])
        p = dict(p)
        p["in_proj"] = self._w(p["in_proj"], "ssm_in")
        p["out_proj"] = self._w(p["out_proj"], "ssm_out")
        y, h_last, conv_tail = ssd_forward(
            h, p, self.ssm_dims, h0, cstr=self._ssm_cstr()
        )
        return x + y, h_last, conv_tail

    # -- family stacks: full sequence -----------------------------------------

    def _maybe_remat(self, fn):
        return jax.checkpoint(fn) if self.remat else fn

    def _pre_scan(self, stacked):
        """Optionally move the compute-dtype cast outside the scan so the
        per-layer ZeRO all-gathers transfer bf16, not fp32."""
        return self._cast(stacked) if self.cast_before_scan else stacked

    def _stack_full(self, params, x, positions, collect_cache: bool):
        """Returns (x, caches) — caches is a family-specific pytree of
        stacked per-layer state (decode feeds on it)."""
        cfg = self.cfg
        fam = cfg.family
        cast = self._cast

        if fam in ("dense", "moe", "vlm"):
            every = cfg.moe.every if cfg.moe else 0

            def body(carry, layer):
                x, aux = carry
                ap = cast(layer["attn"])
                x, kv = self._attn_full(x, ap, positions, positions, collect_cache)
                if cfg.moe:
                    x, a = self._moe_ffn(x, cast(layer["moe"]))
                    aux = aux + a
                else:
                    x = self._ffn(x, cast(layer["mlp"]))
                return (x, aux), kv

            if fam == "vlm":
                return self._vlm_stack_full(params, x, positions, collect_cache)

            stacked = {"attn": params["attn"]}
            if cfg.moe:
                stacked["moe"] = params["moe"]
            else:
                stacked["mlp"] = params["mlp"]
            (x, aux), kvs = jax.lax.scan(
                self._maybe_remat(body), (x, jnp.float32(0)), self._pre_scan(stacked)
            )
            return x, {"k": kvs[0], "v": kvs[1]} if collect_cache else None, aux

        if fam == "ssm":

            def body(carry, layer):
                x, _ = carry
                x, h_last, conv_tail = self._ssm_layer(x, cast(layer))
                return (x, jnp.float32(0)), (h_last, conv_tail)

            (x, _), states = jax.lax.scan(
                self._maybe_remat(body), (x, jnp.float32(0)), self._pre_scan(params["ssm"])
            )
            cache = (
                {"ssm_h": states[0], "ssm_conv": states[1]} if collect_cache else None
            )
            return x, cache, jnp.float32(0)

        if fam == "hybrid":
            return self._hybrid_stack_full(params, x, positions, collect_cache)

        raise ValueError(fam)

    def _vlm_stack_full(self, params, x, positions, collect_cache):
        cfg = self.cfg
        cast = self._cast
        k = cfg.cross_attn_every
        L = cfg.n_layers
        ng = L // k
        reshaped_attn = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["attn"]
        )
        reshaped_mlp = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["mlp"]
        )

        def body(carry, group):
            x, aux = carry
            kvs = []
            for i in range(k - 1):
                ap = cast(jax.tree.map(lambda a: a[i], group["attn"]))
                x, kv = self._attn_full(x, ap, positions, positions, collect_cache)
                kvs.append(kv)
                x = self._ffn(x, cast(jax.tree.map(lambda a: a[i], group["mlp"])))
            # k-th layer: self-attn + gated cross-attn + mlp
            ap = cast(jax.tree.map(lambda a: a[k - 1], group["attn"]))
            x, kv = self._attn_full(x, ap, positions, positions, collect_cache)
            kvs.append(kv)
            cp = cast(group["cross"])
            ck, cv = self._context_kv(cp, self._img_ctx)
            x = self._cross_attn(x, cp, ck, cv, gate=group["cross"]["gate"])
            x = self._ffn(x, cast(jax.tree.map(lambda a: a[k - 1], group["mlp"])))
            if collect_cache:
                kv_stack = (
                    jnp.stack([c[0] for c in kvs]),
                    jnp.stack([c[1] for c in kvs]),
                    ck,
                    cv,
                )
            else:
                kv_stack = None
            return (x, aux), kv_stack

        stacked = {
            "attn": reshaped_attn,
            "mlp": reshaped_mlp,
            "cross": params["cross"],
        }
        (x, aux), kvs = jax.lax.scan(
            self._maybe_remat(body), (x, jnp.float32(0)), self._pre_scan(stacked)
        )
        cache = None
        if collect_cache:
            cache = {"k": kvs[0], "v": kvs[1], "xk": kvs[2], "xv": kvs[3]}
        return x, cache, aux

    def _hybrid_stack_full(self, params, x, positions, collect_cache):
        cfg = self.cfg
        cast = self._cast
        period = cfg.hybrid_period
        attn_pos = cfg.hybrid_attn_pos
        every = cfg.moe.every

        def body(carry, sb):
            x, aux = carry
            kv = None
            h_states, conv_tails = [], []
            mi = di = si = 0
            for pos in range(period):
                if pos == attn_pos:
                    ap = cast(sb["attn"])
                    x, kv = self._attn_full(
                        x, ap, positions, positions, collect_cache
                    )
                else:
                    sp = cast(jax.tree.map(lambda a: a[si], sb["ssm"]))
                    x, h_last, conv_tail = self._ssm_layer(x, sp)
                    h_states.append(h_last)
                    conv_tails.append(conv_tail)
                    si += 1
                if pos % every == 1:  # MoE on odd positions
                    x, a = self._moe_ffn(
                        x, cast(jax.tree.map(lambda m: m[mi], sb["moe"]))
                    )
                    aux = aux + a
                    mi += 1
                else:
                    x = self._ffn(
                        x, cast(jax.tree.map(lambda m: m[di], sb["mlp"]))
                    )
                    di += 1
            out = None
            if collect_cache:
                out = (kv[0], kv[1], jnp.stack(h_states), jnp.stack(conv_tails))
            return (x, aux), out

        stacked = {
            "attn": params["attn"],
            "ssm": params["ssm"],
            "moe": params["moe"],
            "mlp": params["mlp"],
        }
        (x, aux), caches = jax.lax.scan(
            self._maybe_remat(body), (x, jnp.float32(0)), self._pre_scan(stacked)
        )
        cache = None
        if collect_cache:
            cache = {
                "k": caches[0],
                "v": caches[1],
                "ssm_h": caches[2],
                "ssm_conv": caches[3],
            }
        return x, cache, aux

    # -- public entry points ---------------------------------------------------

    def _embed(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.compute_dtype)
        if self.cfg.embed_scale:
            x = x * jnp.asarray(self.cfg.d_model ** 0.5, self.compute_dtype)
        r = self.rules or ShardingRules()
        return self._c(x, r.act_btd)

    def _unembed(self, params, x):
        r = self.rules or ShardingRules()
        x = self._norm(
            x, params["final_norm"].astype(self.compute_dtype),
            params.get("final_norm_b"),
        )
        if self.cfg.tie_embeddings:
            logits = x @ params["embed"].astype(self.compute_dtype).T
        else:
            logits = x @ params["head"].astype(self.compute_dtype)
        return self._c(logits, r.logits)

    def train_logits(self, params, batch: Tree):
        """batch: tokens (B,S) [+ enc_embeds | image_embeds].  Returns
        (logits (B,S,V_pad), aux_loss)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = self._embed(params, tokens)

        if cfg.family == "encdec":
            enc = batch["enc_embeds"].astype(self.compute_dtype)
            enc_out = self._encoder(params, enc)
            x, aux = self._decoder_full(params, x, positions, enc_out, False)[:2]
            return self._unembed(params, x), aux

        if cfg.family == "vlm":
            self._img_ctx = batch["image_embeds"].astype(self.compute_dtype)
            self._img_kv = None
        x, _, aux = self._stack_full(params, x, positions, collect_cache=False)
        return self._unembed(params, x), aux

    def _encoder(self, params, enc_x):
        """Whisper encoder: non-causal self-attn + MLP stack."""
        B, S, _ = enc_x.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        dims = dataclasses.replace(self.attn_dims, causal=False)
        cast = self._cast

        def body(x, layer):
            ap = cast(layer["attn"])
            saved = self.attn_dims
            self.attn_dims = dims
            x, _ = self._attn_full(x, ap, positions, positions, False)
            self.attn_dims = saved
            x = self._ffn(x, cast(layer["mlp"]))
            return x, None

        x, _ = jax.lax.scan(
            self._maybe_remat(body),
            enc_x,
            self._pre_scan({"attn": params["enc_attn"], "mlp": params["enc_mlp"]}),
        )
        return x

    def _decoder_full(self, params, x, positions, enc_out, collect_cache):
        cast = self._cast

        def body(carry, layer):
            x, aux = carry
            x, kv = self._attn_full(
                x, cast(layer["attn"]), positions, positions, collect_cache
            )
            cp = cast(layer["cross"])
            ck, cv = self._context_kv(cp, enc_out)
            x = self._cross_attn(x, cp, ck, cv)
            x = self._ffn(x, cast(layer["mlp"]))
            out = (kv[0], kv[1], ck, cv) if collect_cache else None
            return (x, aux), out

        (x, aux), caches = jax.lax.scan(
            self._maybe_remat(body),
            (x, jnp.float32(0)),
            self._pre_scan({
                "attn": params["dec_attn"],
                "cross": params["dec_cross"],
                "mlp": params["dec_mlp"],
            }),
        )
        cache = None
        if collect_cache:
            cache = {"k": caches[0], "v": caches[1], "xk": caches[2], "xv": caches[3]}
        return x, aux, cache

    def prefill(self, params, batch: Tree):
        """Full-context forward collecting decode caches.
        Returns (last_logits (B, V_pad), caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        x = self._embed(params, tokens)
        if cfg.family == "encdec":
            enc_out = self._encoder(
                params, batch["enc_embeds"].astype(self.compute_dtype)
            )
            x, _, cache = self._decoder_full(params, x, positions, enc_out, True)
        elif cfg.family == "vlm":
            self._img_ctx = batch["image_embeds"].astype(self.compute_dtype)
            self._img_kv = None
            x, cache, _ = self._stack_full(params, x, positions, collect_cache=True)
        else:
            x, cache, _ = self._stack_full(params, x, positions, collect_cache=True)
        logits = self._unembed(params, x[:, -1:, :])[:, 0, :]
        return logits, cache

    # -- decode ---------------------------------------------------------------

    def decode_step(self, params, caches: Tree, tokens, lengths):
        """One decode step.  tokens (B, 1), lengths (B,) current cache fill.
        Returns (logits (B, V_pad), caches)."""
        cfg = self.cfg
        cast = self._cast
        x = self._embed(params, tokens)
        fam = cfg.family

        if fam in ("dense", "moe"):
            int8_kv = self.kv_int8 and "k_scale" in caches

            def body(x, inp):
                if int8_kv:
                    layer, ck, cv, ks, vs = inp
                    x, ck, cv, (ks, vs) = self._attn_decode(
                        x, cast(layer["attn"]), ck, cv, lengths, scales=(ks, vs)
                    )
                else:
                    layer, ck, cv = inp
                    x, ck, cv = self._attn_decode(
                        x, cast(layer["attn"]), ck, cv, lengths
                    )
                if cfg.moe:
                    x, _ = self._moe_ffn(x, cast(layer["moe"]))
                else:
                    x = self._ffn(x, cast(layer["mlp"]))
                return x, (ck, cv, ks, vs) if int8_kv else (ck, cv)

            stacked = {"attn": params["attn"]}
            stacked["moe" if cfg.moe else "mlp"] = params["moe" if cfg.moe else "mlp"]
            if int8_kv:
                x, kvs = jax.lax.scan(
                    body, x,
                    (self._pre_scan(stacked), caches["k"], caches["v"],
                     caches["k_scale"], caches["v_scale"]),
                )
                caches = {"k": kvs[0], "v": kvs[1],
                          "k_scale": kvs[2], "v_scale": kvs[3]}
            else:
                x, kvs = jax.lax.scan(
                    body, x, (self._pre_scan(stacked), caches["k"], caches["v"])
                )
                caches = {"k": kvs[0], "v": kvs[1]}

        elif fam == "ssm":

            def body(x, inp):
                layer, h, conv = inp
                hn = self._norm(x, layer["norm"])
                y, st = ssd_decode_step(
                    hn, SSMState(h=h, conv=conv), cast(layer), self.ssm_dims
                )
                return x + y, (st.h, st.conv)

            x, states = jax.lax.scan(
                body, x, (self._pre_scan(params["ssm"]), caches["ssm_h"], caches["ssm_conv"])
            )
            caches = {"ssm_h": states[0], "ssm_conv": states[1]}

        elif fam == "hybrid":
            x, caches = self._hybrid_decode(params, caches, x, lengths)

        elif fam == "encdec":

            def body(x, inp):
                layer, ck, cv, xk, xv = inp
                x, ck, cv = self._attn_decode(x, cast(layer["attn"]), ck, cv, lengths)
                cp = cast(layer["cross"])
                x = self._cross_attn(x, cp, xk.astype(x.dtype), xv.astype(x.dtype))
                x = self._ffn(x, cast(layer["mlp"]))
                return x, (ck, cv)

            stacked = {
                "attn": params["dec_attn"],
                "cross": params["dec_cross"],
                "mlp": params["dec_mlp"],
            }
            x, kvs = jax.lax.scan(
                body,
                x,
                (self._pre_scan(stacked), caches["k"], caches["v"], caches["xk"], caches["xv"]),
            )
            caches = {"k": kvs[0], "v": kvs[1], "xk": caches["xk"], "xv": caches["xv"]}

        elif fam == "vlm":
            x, caches = self._vlm_decode(params, caches, x, lengths)
        else:
            raise ValueError(fam)

        logits = self._unembed(params, x)[:, 0, :]
        return logits, caches

    def _hybrid_decode(self, params, caches, x, lengths):
        cfg = self.cfg
        cast = self._cast
        period, attn_pos = cfg.hybrid_period, cfg.hybrid_attn_pos
        every = cfg.moe.every

        def body(x, inp):
            sb, ck, cv, hs, conv = inp
            mi = di = si = 0
            new_h, new_conv = [], []
            for pos in range(period):
                if pos == attn_pos:
                    x, ck, cv = self._attn_decode(x, cast(sb["attn"]), ck, cv, lengths)
                else:
                    sp = cast(jax.tree.map(lambda a: a[si], sb["ssm"]))
                    hn = self._norm(x, sp["norm"])
                    y, st = ssd_decode_step(
                        hn, SSMState(h=hs[si], conv=conv[si]), sp, self.ssm_dims
                    )
                    x = x + y
                    new_h.append(st.h)
                    new_conv.append(st.conv)
                    si += 1
                if pos % every == 1:
                    x, _ = self._moe_ffn(
                        x, cast(jax.tree.map(lambda m: m[mi], sb["moe"]))
                    )
                    mi += 1
                else:
                    x = self._ffn(x, cast(jax.tree.map(lambda m: m[di], sb["mlp"])))
                    di += 1
            return x, (ck, cv, jnp.stack(new_h), jnp.stack(new_conv))

        stacked = {k: params[k] for k in ("attn", "ssm", "moe", "mlp")}
        x, outs = jax.lax.scan(
            body,
            x,
            (self._pre_scan(stacked), caches["k"], caches["v"], caches["ssm_h"], caches["ssm_conv"]),
        )
        return x, {"k": outs[0], "v": outs[1], "ssm_h": outs[2], "ssm_conv": outs[3]}

    def _vlm_decode(self, params, caches, x, lengths):
        cfg = self.cfg
        cast = self._cast
        k = cfg.cross_attn_every
        ng = cfg.n_layers // k
        reshaped_attn = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["attn"]
        )
        reshaped_mlp = jax.tree.map(
            lambda a: a.reshape((ng, k) + a.shape[1:]), params["mlp"]
        )

        def body(x, inp):
            group, ck, cv, xk, xv = inp
            new_k, new_v = [], []
            for i in range(k):
                ap = cast(jax.tree.map(lambda a: a[i], group["attn"]))
                x, cki, cvi = self._attn_decode(x, ap, ck[i], cv[i], lengths)
                new_k.append(cki)
                new_v.append(cvi)
                if i == k - 1:
                    cp = cast(group["cross"])
                    x = self._cross_attn(
                        x, cp, xk.astype(x.dtype), xv.astype(x.dtype),
                        gate=group["cross"]["gate"],
                    )
                x = self._ffn(x, cast(jax.tree.map(lambda a: a[i], group["mlp"])))
            return x, (jnp.stack(new_k), jnp.stack(new_v))

        stacked = {
            "attn": reshaped_attn,
            "mlp": reshaped_mlp,
            "cross": params["cross"],
        }
        x, kvs = jax.lax.scan(
            body, x, (self._pre_scan(stacked), caches["k"], caches["v"], caches["xk"], caches["xv"])
        )
        return x, {"k": kvs[0], "v": kvs[1], "xk": caches["xk"], "xv": caches["xv"]}


def cross_entropy_loss(
    logits: jnp.ndarray,  # (B, S, V_pad)
    labels: jnp.ndarray,  # (B, S)
    vocab: int,
) -> jnp.ndarray:
    """Vocab-parallel-safe CE: padded columns masked, fp32 statistics.

    Memory note (§Perf It-6): the mask is applied in the LOGITS dtype and
    the f32 convert feeds straight into the max/sum reductions, so XLA
    fuses it — no materialized fp32 (B, S, V) copy (4.2 GiB/device for a
    128k vocab at the vision cell)."""
    V_pad = logits.shape[-1]
    if V_pad > vocab:
        mask = jnp.arange(V_pad) < vocab
        neg = jnp.asarray(-1e30, logits.dtype)
        logits = jnp.where(mask, logits, neg)
    # max/sum with inline f32 accumulation (fusible convert+reduce).
    m = jnp.max(logits.astype(jnp.float32), axis=-1)
    se = jnp.sum(
        jnp.exp(logits.astype(jnp.float32) - m[..., None]), axis=-1
    )
    lse = m + jnp.log(se)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[
        ..., 0
    ].astype(jnp.float32)
    return jnp.mean(lse - picked)
