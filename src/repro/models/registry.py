"""Model construction from configs."""

from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig
from repro.models.model import Model

MODEL_FAMILIES = ("dense", "moe", "hybrid", "ssm", "encdec", "vlm")


def build_model(
    cfg: ModelConfig,
    mesh=None,
    compute_dtype=None,
    kv_chunk: int = 2048,
    remat: bool = True,
    model_axis_size: Optional[int] = None,
    rules=None,
    cast_before_scan: bool = False,
    kv_int8: bool = False,
) -> Model:
    import jax.numpy as jnp

    if model_axis_size is None:
        model_axis_size = mesh.shape.get("model", 1) if mesh is not None else 1
    return Model(
        cfg=cfg,
        mesh=mesh,
        rules=rules,
        compute_dtype=compute_dtype or jnp.bfloat16,
        kv_chunk=kv_chunk,
        remat=remat,
        model_axis_size=max(model_axis_size, 1),
        cast_before_scan=cast_before_scan,
        kv_int8=kv_int8,
    )
