"""Parameter initialization + PartitionSpec trees for every family.

Params are nested dicts with layer-stacked leaves (leading scan axis) so a
single lax.scan covers the depth — the only way 72-layer/512-device
programs compile in reasonable time.  Spec trees mirror the param trees
exactly (jax.tree.map-able into NamedShardings).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ShardingRules, pad_to_multiple

Tree = Dict[str, Any]

VOCAB_PAD = 128  # pad vocab to multiples of 128 (16-wide TP x 8 lanes)


def padded_vocab(cfg: ModelConfig) -> int:
    return pad_to_multiple(cfg.vocab, VOCAB_PAD)


def padded_experts(cfg: ModelConfig, model_axis: int = 16) -> int:
    assert cfg.moe is not None
    return pad_to_multiple(cfg.moe.n_experts, model_axis)


def _init(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.normal(key, shape, dtype)


class _KeyGen:
    def __init__(self, key):
        self.key = key

    def __call__(self):
        self.key, sub = jax.random.split(self.key)
        return sub


def _attn_params(kg, cfg: ModelConfig, n: int, rules: ShardingRules):
    D = cfg.d_model
    hd = cfg.resolved_head_dim
    qo, kvo = cfg.n_heads * hd, cfg.n_kv_heads * hd
    s = 0.02
    p = {
        "norm": jnp.zeros((n, D)),
        "wq": _init(kg(), (n, D, qo), s),
        "wk": _init(kg(), (n, D, kvo), s),
        "wv": _init(kg(), (n, D, kvo), s),
        "wo": _init(kg(), (n, qo, D), s / math.sqrt(2 * max(cfg.n_layers, 1))),
    }
    spec = {
        "norm": P(None, None),
        "wq": rules.wq,
        "wk": rules.wkv,
        "wv": rules.wkv,
        "wo": rules.wo,
    }
    if cfg.qkv_bias:
        p |= {
            "bq": jnp.zeros((n, qo)),
            "bk": jnp.zeros((n, kvo)),
            "bv": jnp.zeros((n, kvo)),
        }
        spec |= {"bq": rules.qkv_bias, "bk": rules.qkv_bias, "bv": rules.qkv_bias}
    if cfg.norm == "layer":
        p["norm_b"] = jnp.zeros((n, D))
        spec["norm_b"] = P(None, None)
    return p, spec


def _mlp_params(kg, cfg: ModelConfig, n: int, rules: ShardingRules):
    D, F = cfg.d_model, cfg.d_ff
    s = 0.02
    if cfg.act == "gelu_mlp":  # whisper: plain MLP with biases
        p = {
            "norm": jnp.zeros((n, D)),
            "norm_b": jnp.zeros((n, D)),
            "w_in": _init(kg(), (n, D, F), s),
            "b_in": jnp.zeros((n, F)),
            "w_out": _init(kg(), (n, F, D), s / math.sqrt(2 * cfg.n_layers)),
            "b_out": jnp.zeros((n, D)),
        }
        spec = {
            "norm": P(None, None),
            "norm_b": P(None, None),
            "w_in": rules.w_in,
            "b_in": rules.qkv_bias,
            "w_out": rules.w_out,
            "b_out": P(None, None),
        }
    else:
        p = {
            "norm": jnp.zeros((n, D)),
            "w_gate": _init(kg(), (n, D, F), s),
            "w_up": _init(kg(), (n, D, F), s),
            "w_down": _init(kg(), (n, F, D), s / math.sqrt(2 * cfg.n_layers)),
        }
        spec = {
            "norm": P(None, None),
            "w_gate": rules.w_in,
            "w_up": rules.w_in,
            "w_down": rules.w_out,
        }
    return p, spec


def _moe_params(kg, cfg: ModelConfig, n: int, rules: ShardingRules, e_pad: int):
    D, F = cfg.d_model, cfg.d_ff
    s = 0.02
    p = {
        "norm": jnp.zeros((n, D)),
        "router": _init(kg(), (n, D, e_pad), s),
        "e_gate": _init(kg(), (n, e_pad, D, F), s),
        "e_up": _init(kg(), (n, e_pad, D, F), s),
        "e_down": _init(kg(), (n, e_pad, F, D), s / math.sqrt(2 * cfg.n_layers)),
    }
    spec = {
        "norm": P(None, None),
        "router": rules.router,
        "e_gate": rules.expert_in,
        "e_up": rules.expert_in,
        "e_down": rules.expert_out,
    }
    return p, spec


def _ssm_params(kg, cfg: ModelConfig, n: int, rules: ShardingRules):
    from repro.models.layers.ssm import SSMDims

    sc = cfg.ssm
    dims = SSMDims(
        d_model=cfg.d_model,
        d_inner=sc.d_inner,
        head_dim=sc.head_dim,
        d_state=sc.d_state,
        n_groups=sc.n_groups,
        d_conv=sc.d_conv,
        chunk=sc.chunk,
    )
    s = 0.02
    H = dims.n_heads
    p = {
        "norm": jnp.zeros((n, cfg.d_model)),
        "in_proj": _init(kg(), (n, cfg.d_model, dims.in_proj_out), s),
        "conv_w": _init(kg(), (n, dims.d_conv, dims.conv_channels), 0.1),
        "conv_b": jnp.zeros((n, dims.conv_channels)),
        "A_log": jnp.tile(jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32))[None], (n, 1)),
        "dt_bias": jnp.zeros((n, H)),
        "D": jnp.ones((n, H)),
        "out_proj": _init(kg(), (n, dims.d_inner, cfg.d_model), s / math.sqrt(2 * cfg.n_layers)),
    }
    spec = {
        "norm": P(None, None),
        "in_proj": rules.ssm_in,
        "conv_w": rules.conv_kernel,
        "conv_b": rules.ssm_small,
        "A_log": P(None, None),
        "dt_bias": P(None, None),
        "D": P(None, None),
        "out_proj": rules.ssm_out,
    }
    return p, spec, dims


def init_params(
    cfg: ModelConfig, rng: jax.Array, rules: ShardingRules, model_axis: int = 16
) -> Tuple[Tree, Tree]:
    """Returns (params, spec_tree) with identical structure."""
    kg = _KeyGen(rng)
    V = padded_vocab(cfg)
    D = cfg.d_model
    params: Tree = {
        "embed": _init(kg(), (V, D), 1.0 / math.sqrt(D)),
        "final_norm": jnp.zeros((D,)),
    }
    specs: Tree = {"embed": rules.embed, "final_norm": rules.norm_scale}
    if cfg.norm == "layer":
        params["final_norm_b"] = jnp.zeros((D,))
        specs["final_norm_b"] = rules.norm_scale
    if not cfg.tie_embeddings:
        params["head"] = _init(kg(), (D, V), 1.0 / math.sqrt(D))
        specs["head"] = rules.head

    fam = cfg.family
    if fam in ("dense", "moe"):
        L = cfg.n_layers
        a, sa = _attn_params(kg, cfg, L, rules)
        params["attn"], specs["attn"] = a, sa
        if cfg.moe:
            e_pad = padded_experts(cfg, model_axis)
            every = cfg.moe.every
            n_moe = L // every
            m, sm = _moe_params(kg, cfg, n_moe, rules, e_pad)
            params["moe"], specs["moe"] = m, sm
            if every > 1:
                d, sd = _mlp_params(kg, cfg, L - n_moe, rules)
                params["mlp"], specs["mlp"] = d, sd
        else:
            d, sd = _mlp_params(kg, cfg, L, rules)
            params["mlp"], specs["mlp"] = d, sd

    elif fam == "ssm":
        s, ss, _ = _ssm_params(kg, cfg, cfg.n_layers, rules)
        params["ssm"], specs["ssm"] = s, ss

    elif fam == "hybrid":
        period = cfg.hybrid_period
        nsb = cfg.n_layers // period  # superblocks
        n_mamba = period - 1
        a, sa = _attn_params(kg, cfg, nsb, rules)
        params["attn"], specs["attn"] = a, sa
        s, ss, _ = _ssm_params(kg, cfg, nsb * n_mamba, rules)
        # reshape leading to (nsb, n_mamba, ...)
        params["ssm"] = jax.tree.map(
            lambda x: x.reshape((nsb, n_mamba) + x.shape[1:]), s
        )
        specs["ssm"] = jax.tree.map(
            lambda sp: P(*((None,) + tuple(sp))), ss
        )
        e_pad = padded_experts(cfg, model_axis)
        n_moe_sb = period // cfg.moe.every // 1  # MoE slots per superblock
        n_moe_sb = period // cfg.moe.every - 0  # every=2 -> 4
        m, sm = _moe_params(kg, cfg, nsb * n_moe_sb, rules, e_pad)
        params["moe"] = jax.tree.map(
            lambda x: x.reshape((nsb, n_moe_sb) + x.shape[1:]), m
        )
        specs["moe"] = jax.tree.map(lambda sp: P(*((None,) + tuple(sp))), sm)
        n_dense_sb = period - n_moe_sb
        d, sd = _mlp_params(kg, cfg, nsb * n_dense_sb, rules)
        params["mlp"] = jax.tree.map(
            lambda x: x.reshape((nsb, n_dense_sb) + x.shape[1:]), d
        )
        specs["mlp"] = jax.tree.map(lambda sp: P(*((None,) + tuple(sp))), sd)

    elif fam == "encdec":
        Le, Ld = cfg.n_encoder_layers, cfg.n_layers
        ea, sea = _attn_params(kg, cfg, Le, rules)
        em, sem = _mlp_params(kg, cfg, Le, rules)
        params["enc_attn"], specs["enc_attn"] = ea, sea
        params["enc_mlp"], specs["enc_mlp"] = em, sem
        da, sda = _attn_params(kg, cfg, Ld, rules)
        dx, sdx = _attn_params(kg, cfg, Ld, rules)  # cross-attn
        dm, sdm = _mlp_params(kg, cfg, Ld, rules)
        params["dec_attn"], specs["dec_attn"] = da, sda
        params["dec_cross"], specs["dec_cross"] = dx, sdx
        params["dec_mlp"], specs["dec_mlp"] = dm, sdm

    elif fam == "vlm":
        L = cfg.n_layers
        k = cfg.cross_attn_every
        a, sa = _attn_params(kg, cfg, L, rules)
        d, sd = _mlp_params(kg, cfg, L, rules)
        params["attn"], specs["attn"] = a, sa
        params["mlp"], specs["mlp"] = d, sd
        nx = L // k
        x, sx = _attn_params(kg, cfg, nx, rules)
        params["cross"], specs["cross"] = x, sx
        params["cross"]["gate"] = jnp.zeros((nx,))
        specs["cross"]["gate"] = P(None)
    else:
        raise ValueError(fam)

    return params, specs
