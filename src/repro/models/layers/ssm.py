"""Mamba-2 SSD (state-space duality) block — chunked scan formulation.

Follows the Mamba-2 paper's chunked algorithm (arXiv:2405.21060 §6):
within-chunk terms are attention-like batched einsums (MXU-friendly),
across-chunk state flows through a short lax.scan — O(S·N·P) work, no
(S, S) materialization, which is what makes `long_500k` serveable.

Decode keeps a constant-size recurrent state (B, H, N, P) + a (K-1)-deep
conv tail: one token costs O(H·N·P) — attention-free decode.

Jamba note (DESIGN.md): Jamba-1.5's Mamba layers are Mamba-1; we implement
both archs with this SSD layer (SSD subsumes S6 up to the scalar-vs-diag A
parameterization) and record the substitution as a hardware-adaptation
choice: SSD's chunk matmuls map onto the MXU, S6's per-element scan does
not.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_inner: int  # = expand * d_model
    head_dim: int  # P
    d_state: int  # N
    n_groups: int  # G (B/C shared across heads within a group)
    d_conv: int = 4
    chunk: int = 128

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def in_proj_out(self) -> int:
        # [z, x, B, C, dt]
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


class SSMState(NamedTuple):
    h: jnp.ndarray  # (B, H, N, P) recurrent state
    conv: jnp.ndarray  # (B, K-1, conv_channels) conv tail


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via K shifted multiply-adds (partitioning-
    friendly: no conv op to shard).  x: (B, S, C), w: (K, C), b: (C,)."""
    K = w.shape[0]
    out = x * w[-1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + shifted * w[K - 1 - i]
    return jax.nn.silu(out + b)


def _split_proj(zxbcdt: jnp.ndarray, dims: SSMDims):
    d, g, n, hh = dims.d_inner, dims.n_groups, dims.d_state, dims.n_heads
    z = zxbcdt[..., :d]
    xbc = zxbcdt[..., d : d + dims.conv_channels]
    dt = zxbcdt[..., d + dims.conv_channels :]  # (..., H)
    return z, xbc, dt


def ssd_forward(
    x_in: jnp.ndarray,  # (B, S, D)
    params: dict,
    dims: SSMDims,
    h0: jnp.ndarray | None = None,  # (B, H, N, P) initial state
    cstr=None,  # Callable[(array, head_axis:int), array] — shard H@'model'
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence SSD.  Returns (y (B,S,D), final_state (B,H,N,P),
    conv_tail (B, K-1, conv_channels)) — the tail feeds decode.

    `cstr(arr, axis)` pins the HEAD dim of every chunk tensor to the model
    axis; without it XLA replicates the (B,NC,H,Q,Q) score blocks per
    device (observed: 170 GiB/device, 1.5 TB of resharding gathers for
    jamba train)."""
    B, S, D = x_in.shape
    H, P, N, G = dims.n_heads, dims.head_dim, dims.d_state, dims.n_groups
    Q = dims.chunk
    assert S % Q == 0, (S, Q)
    NC = S // Q
    if cstr is None:
        cstr = lambda a, axis: a
    # Big chunk einsums run in the input dtype (bf16 on TPU); decay /
    # cumulative terms stay fp32 for stability.  fp32 chunk tensors were
    # the memory bottleneck of mamba2 train (27 GiB/device).
    ed = x_in.dtype

    zxbcdt = x_in @ params["in_proj"]  # (B, S, in_proj_out)
    zxbcdt = cstr(zxbcdt, -1)  # flat feature dim over 'model'
    z, xbc, dt = _split_proj(zxbcdt, dims)
    conv_tail = xbc[:, S - (dims.d_conv - 1) :, :].astype(jnp.float32)
    xbc = cstr(_causal_conv(xbc, params["conv_w"], params["conv_b"]), -1)
    xs = xbc[..., : dims.d_inner].reshape(B, S, H, P)
    Bm = xbc[..., dims.d_inner : dims.d_inner + G * N].reshape(B, S, G, N)
    Cm = xbc[..., dims.d_inner + G * N :].reshape(B, S, G, N)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)

    # -- chunk views --------------------------------------------------------
    xs_c = cstr(xs.reshape(B, NC, Q, H, P).astype(ed), 3)
    B_c = Bm.reshape(B, NC, Q, G, N).astype(ed)  # G small: replicated
    C_c = Cm.reshape(B, NC, Q, G, N).astype(ed)
    dt_c = cstr(dt.reshape(B, NC, Q, H), 3)
    dA = dt_c * A  # (B, NC, Q, H)
    dA_cum = cstr(jnp.cumsum(dA, axis=2), 3)  # within-chunk

    hpg = H // G  # heads per B/C group

    # Intra-chunk (attention-like): scores[i,j] = C_i·B_j * exp(Acum_i-Acum_j)*dt_j , j<=i
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", C_c, B_c)  # (B,NC,G,Q,Q)
    CB = cstr(jnp.repeat(CB, hpg, axis=2), 2)  # (B,NC,H,Q,Q)
    seg = dA_cum.transpose(0, 1, 3, 2)  # (B,NC,H,Q)
    L = jnp.exp(
        jnp.clip(seg[..., :, None] - seg[..., None, :], -60.0, 0.0)
    )  # (B,NC,H,Q,Q)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    scores = (
        jnp.where(causal, CB.astype(jnp.float32) * L, 0.0)
        * dt_c.transpose(0, 1, 3, 2)[..., None, :]
    )
    scores = cstr(scores.astype(ed), 2)
    y_intra = cstr(jnp.einsum("bchqk,bckhp->bcqhp", scores, xs_c), 3)

    # Chunk states: S_c = sum_j exp(Acum_Q - Acum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(
        jnp.clip(dA_cum[:, :, -1:, :] - dA_cum, -60.0, 0.0)
    )  # (B,NC,Q,H)
    wgt = (decay_to_end * dt_c).astype(ed)  # (B,NC,Q,H)
    B_h = jnp.repeat(B_c, hpg, axis=3).reshape(B, NC, Q, H, N)
    chunk_state = cstr(
        jnp.einsum("bcqhn,bcqhp,bcqh->bchnp", B_h, xs_c, wgt).astype(jnp.float32),
        2,
    )

    # Inter-chunk recurrence over NC chunks.
    chunk_decay = cstr(
        jnp.exp(jnp.clip(dA_cum[:, :, -1, :], -60.0, 0.0)), 2
    )  # (B,NC,H)

    def scan_body(h_prev, inp):
        s_c, d_c = inp  # (B,H,N,P), (B,H)
        h_new = cstr(h_prev * d_c[..., None, None] + s_c, 1)
        return h_new, h_prev  # emit the state ENTERING this chunk

    h_init = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((B, H, N, P), jnp.float32)
    )
    h_last, h_in = jax.lax.scan(
        scan_body,
        h_init,
        (
            jnp.moveaxis(chunk_state, 1, 0),
            jnp.moveaxis(chunk_decay, 1, 0),
        ),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)  # (B,NC,H,N,P) state entering each chunk

    # Inter-chunk output: y_i += C_i · exp(Acum_i) h_in
    C_h = jnp.repeat(C_c, hpg, axis=3).reshape(B, NC, Q, H, N)
    in_decay = jnp.exp(jnp.clip(dA_cum, -60.0, 0.0)).astype(ed)  # (B,NC,Q,H)
    y_inter = cstr(
        jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", C_h, h_in.astype(ed), in_decay), 3
    )

    y = (y_intra.astype(jnp.float32) + y_inter.astype(jnp.float32)).reshape(
        B, S, H, P
    )
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(
        jnp.float32
    )
    y = cstr(y.reshape(B, S, dims.d_inner), -1)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return (y.astype(x_in.dtype)) @ params["out_proj"], h_last, conv_tail


def ssd_decode_step(
    x_in: jnp.ndarray,  # (B, 1, D)
    state: SSMState,
    params: dict,
    dims: SSMDims,
) -> Tuple[jnp.ndarray, SSMState]:
    """One-token recurrent update."""
    B = x_in.shape[0]
    H, P, N, G = dims.n_heads, dims.head_dim, dims.d_state, dims.n_groups

    zxbcdt = x_in[:, 0, :] @ params["in_proj"]  # (B, F)
    z, xbc, dt = _split_proj(zxbcdt, dims)

    # Conv tail update: window = [conv_state, xbc]
    window = jnp.concatenate([state.conv, xbc[:, None, :]], axis=1)  # (B,K,C)
    w = params["conv_w"]  # (K, C)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"])
    new_conv = window[:, 1:, :]

    xs = conv_out[..., : dims.d_inner].reshape(B, H, P)
    Bm = conv_out[..., dims.d_inner : dims.d_inner + G * N].reshape(B, G, N)
    Cm = conv_out[..., dims.d_inner + G * N :].reshape(B, G, N)

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt_v = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    decay = jnp.exp(dt_v * A)  # (B,H)

    hpg = H // G
    B_h = jnp.repeat(Bm, hpg, axis=1)  # (B,H,N)
    C_h = jnp.repeat(Cm, hpg, axis=1)
    h = state.h * decay[..., None, None] + jnp.einsum(
        "bhn,bhp,bh->bhnp", B_h, xs.astype(jnp.float32), dt_v
    )
    y = jnp.einsum("bhn,bhnp->bhp", C_h, h)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xs
    y = y.reshape(B, dims.d_inner) * jax.nn.silu(z.astype(jnp.float32))
    out = (y.astype(x_in.dtype)) @ params["out_proj"]
    return out[:, None, :], SSMState(h=h, conv=new_conv)
