"""Normalization layers (fp32 statistics, param-dtype outputs)."""

from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5
) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)
