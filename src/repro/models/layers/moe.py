"""Top-k MoE with capacity-based dispatch (expert-parallel over 'model').

Dispatch works per top-k SLOT (a Python loop of K ≤ 8 iterations), never
materializing a (K*T, D) buffer:

  slot k: scatter its (T, D) tokens into the (E, cap, D) expert buffer
  experts: one batched einsum over (E sharded, cap, D)
  combine: slot k gathers its (T, D) outputs and ACCUMULATES — token-aligned
           add, no scatter at all.

Positions-in-expert come from a k-major masked cumsum (slot 0 wins capacity
ties over slot 1, etc.).  E is padded to a multiple of the model-axis width
(granite-moe's 40 -> 48) with router logits pinned to -inf on pads;
overflow drops the assignment and the gate renormalizes.

`constrain` (optional) pins token-major intermediates to the batch axes —
without it XLA replicated the dispatch chain at 512 devices (42 GiB/device
observed); with it the whole dispatch is ~(T/n_batch_devices) local.

The expert-capacity overflow selection is the same top-k primitive as the
PQ tournament — on TPU both lower to the bitonic_topk kernel
(DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec  # noqa: F401  (shard_map specs)


@dataclasses.dataclass(frozen=True)
class MoEDims:
    n_experts: int  # real experts
    n_experts_pad: int  # padded to model-axis multiple
    top_k: int
    capacity_factor: float = 1.25


def moe_block(
    x: jnp.ndarray,  # (B, S, D)
    router_w: jnp.ndarray,  # (D, E_pad)
    w_gate: jnp.ndarray,  # (E_pad, D, F)
    w_up: jnp.ndarray,  # (E_pad, D, F)
    w_down: jnp.ndarray,  # (E_pad, F, D)
    dims: MoEDims,
    constrain_tokens: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
    constrain_experts: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux_loss ()) — aux is the standard
    load-balancing loss (Switch §2.2)."""
    B, S, D = x.shape
    T = B * S
    E, K = dims.n_experts_pad, dims.top_k
    ct = constrain_tokens or (lambda a: a)
    ce = constrain_experts or (lambda a: a)
    xt = ct(x.reshape(T, D))

    logits = xt.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T, E)
    e_iota = jnp.arange(E, dtype=jnp.int32)
    logits = jnp.where(e_iota[None, :] < dims.n_experts, logits, -1e30)
    logits = ct(logits)
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, sel = jax.lax.top_k(probs, K)  # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    sel, gate_vals = ct(sel), ct(gate_vals)

    # Load-balancing aux loss over REAL experts.
    me = jnp.mean(probs[:, : dims.n_experts], axis=0)
    occ = jnp.zeros((E,), jnp.float32)
    for k in range(K):
        occ = occ + jnp.mean(jax.nn.one_hot(sel[:, k], E, dtype=jnp.float32), axis=0)
    aux = dims.n_experts * jnp.sum(me * occ[: dims.n_experts])

    cap = int(max(1, (T * K / E) * dims.capacity_factor))
    cap = min(cap, T)

    # Positions-in-expert, k-major (slot 0 first): per-slot masked cumsum
    # plus offsets of all previous slots.
    base = jnp.zeros((E,), jnp.int32)  # tokens already placed per expert
    buf = jnp.zeros((E, cap, D), x.dtype)
    slot_pos = []
    for k in range(K):
        onehot = jax.nn.one_hot(sel[:, k], E, dtype=jnp.int32)  # (T, E)
        onehot = ct(onehot)
        within = jnp.cumsum(onehot, axis=0) - onehot  # exclusive
        pos_k = jnp.sum(within * onehot, axis=1) + base[sel[:, k]]  # (T,)
        base = base + jnp.sum(onehot, axis=0)
        keep = pos_k < cap
        e_safe = jnp.where(keep, sel[:, k], E)
        p_safe = jnp.where(keep, pos_k, 0)
        buf = buf.at[e_safe, p_safe].set(xt, mode="drop")
        slot_pos.append((e_safe, p_safe, keep))

    buf = ce(buf)
    # Expert compute (E sharded over 'model' by the param specs).
    g = ce(jnp.einsum("ecd,edf->ecf", buf, w_gate))
    u = ce(jnp.einsum("ecd,edf->ecf", buf, w_up))
    h = jax.nn.silu(g) * u
    out_buf = ce(jnp.einsum("ecf,efd->ecd", h, w_down))  # (E, cap, D)

    # Combine: per-slot token-aligned gather + weighted accumulate.
    out = jnp.zeros((T, D), jnp.float32)
    for k, (e_safe, p_safe, keep) in enumerate(slot_pos):
        gathered = ct(out_buf[e_safe, p_safe].astype(jnp.float32))  # (T, D)
        w = gate_vals[:, k].astype(jnp.float32) * keep
        out = out + gathered * w[:, None]
    return ct(out).reshape(B, S, D).astype(x.dtype), aux


def moe_block_ep(
    x: jnp.ndarray,  # (B, S, D) — batch-sharded, REPLICATED over 'model'
    router_w: jnp.ndarray,  # (D, E_pad) replicated
    w_gate: jnp.ndarray,  # (E_pad@model, D, F)
    w_up: jnp.ndarray,
    w_down: jnp.ndarray,  # (E_pad@model, F, D)
    dims: MoEDims,
    mesh,
    batch_axes: Tuple[str, ...],
    model_axis: str = "model",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE as shard_map — the TPU-native dispatch for a
    replicated-activation layout.

    Because the residual stream is replicated across the model axis, every
    model-column already HAS every token: dispatch requires NO communication
    at all.  Each column routes its local-batch tokens to the experts it
    owns, runs them, and the per-column partial outputs all-reduce over the
    model axis (the row-parallel pattern, same as the dense FFN's w_down).

    Observed at 512 devices vs. the naive scatter formulation: per-device
    FLOPs drop 16x (experts actually shard) and dispatch collectives drop
    from ~1.8 TB to one (B_loc, S, D) psum per layer.

    Capacity note: the slot budget is per (column, batch-row) —
    cap_loc = T_loc * K / E_pad * cf — so overflow drops are decided
    locally (documented divergence from the global-capacity formulation).
    """
    from jax.sharding import PartitionSpec as P

    B, S, D = x.shape
    E, K = dims.n_experts_pad, dims.top_k
    n_cols = mesh.shape[model_axis]
    assert E % n_cols == 0, (E, n_cols)
    E_loc = E // n_cols

    def body(xb, rw, wg, wu, wd):
        Bl, Sl, _ = xb.shape
        T = Bl * Sl
        xt = xb.reshape(T, D)
        logits = xt.astype(jnp.float32) @ rw.astype(jnp.float32)
        e_iota = jnp.arange(E, dtype=jnp.int32)
        logits = jnp.where(e_iota[None, :] < dims.n_experts, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, sel = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
        )

        me_frac = jnp.mean(probs[:, : dims.n_experts], axis=0)
        occ = jnp.zeros((E,), jnp.float32)
        for k in range(K):
            occ = occ + jnp.mean(
                jax.nn.one_hot(sel[:, k], E, dtype=jnp.float32), axis=0
            )
        aux = dims.n_experts * jnp.sum(me_frac * occ[: dims.n_experts])
        if batch_axes:
            aux = jax.lax.pmean(aux, batch_axes)  # replicate across batch rows

        col = jax.lax.axis_index(model_axis)
        cap = int(max(1, (T * K / E) * dims.capacity_factor))
        cap = min(cap, T)

        buf = jnp.zeros((E_loc, cap, D), xb.dtype)
        slot_meta = []
        base = jnp.zeros((E_loc,), jnp.int32)
        for k in range(K):
            ek = sel[:, k]
            is_local = (ek // E_loc) == col
            le = jnp.where(is_local, ek % E_loc, E_loc)
            onehot = jax.nn.one_hot(le, E_loc, dtype=jnp.int32)  # (T, E_loc)
            within = jnp.cumsum(onehot, axis=0) - onehot
            pos = jnp.sum(within * onehot, axis=1) + jnp.where(
                is_local, base[jnp.minimum(le, E_loc - 1)], 0
            )
            base = base + jnp.sum(onehot, axis=0)
            keep = is_local & (pos < cap)
            e_safe = jnp.where(keep, le, E_loc)
            p_safe = jnp.where(keep, pos, 0)
            buf = buf.at[e_safe, p_safe].set(xt, mode="drop")
            slot_meta.append((e_safe, p_safe, keep))

        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = jax.nn.silu(g) * u
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd)  # (E_loc, cap, D)

        out = jnp.zeros((T, D), jnp.float32)
        for k, (e_safe, p_safe, keep) in enumerate(slot_meta):
            gathered = out_buf.at[e_safe, p_safe].get(
                mode="fill", fill_value=0.0
            ).astype(jnp.float32)
            w = gate_vals[:, k].astype(jnp.float32) * keep
            out = out + gathered * w[:, None]
        out = jax.lax.psum(out, model_axis)  # row-parallel combine
        return out.reshape(Bl, Sl, D).astype(xb.dtype), aux

    from repro.distributed.shardmap import shard_map

    bspec = batch_axes if batch_axes else None
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),
            P(None, None),
            P(model_axis, None, None),
            P(model_axis, None, None),
            P(model_axis, None, None),
        ),
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )
    return fn(x, router_w, w_gate, w_up, w_down)
