"""Rotary position embeddings (half-rotation layout, LLaMA convention)."""

from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)


def apply_rope(
    x: jnp.ndarray,  # (..., S, H, head_dim)
    positions: jnp.ndarray,  # (..., S) int32 absolute positions
    theta: float = 10000.0,
) -> jnp.ndarray:
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)  # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
