"""GQA/MQA attention with RoPE — train, prefill, and decode paths.

Sharding strategy (DESIGN.md §3): head counts in the assigned pool rarely
divide the 16-wide model axis (qwen 40H, gemma 8H, granite-moe 24H), so
heads are NEVER a sharded dim.  Instead:
  * projections shard on flat feature dims (always multiples of 16),
  * the query SEQUENCE shards over 'model' (sequence parallelism) while K/V
    are materialized full-length per device (one all-gather per layer,
    inserted by SPMD from the sharding constraints),
  * scores are bounded by chunking over the KV length (flash-style
    lax.scan with running max/sum), so 32k/500k contexts never materialize
    an (S, S) matrix.

All paths take an explicit `q_positions` so the same code serves training
(iota), chunked prefill (offset iota), and decode (cache length).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.rope import apply_rope

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnDims:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 500000.0
    causal: bool = True
    qk_scale: Optional[float] = None

    @property
    def q_out(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_out(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads


def project_qkv(
    x: jnp.ndarray,  # (B, S, D)
    wq: jnp.ndarray,  # (D, Hq*hd)
    wk: jnp.ndarray,  # (D, Hkv*hd)
    wv: jnp.ndarray,  # (D, Hkv*hd)
    dims: AttnDims,
    q_positions: jnp.ndarray,  # (B, S)
    kv_positions: jnp.ndarray,  # (B, S)
    bias: Optional[Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]] = None,
    rope: bool = True,
):
    B, S, _ = x.shape
    q = x @ wq
    k = x @ wk
    v = x @ wv
    if bias is not None:
        bq, bk, bv = bias
        q, k, v = q + bq, k + bk, v + bv
    q = q.reshape(B, S, dims.n_heads, dims.head_dim)
    k = k.reshape(B, S, dims.n_kv_heads, dims.head_dim)
    v = v.reshape(B, S, dims.n_kv_heads, dims.head_dim)
    if rope:
        q = apply_rope(q, q_positions, dims.rope_theta)
        k = apply_rope(k, kv_positions, dims.rope_theta)
    return q, k, v


def _scale(dims: AttnDims) -> float:
    return dims.qk_scale if dims.qk_scale is not None else dims.head_dim ** -0.5


def attend_chunked(
    q: jnp.ndarray,  # (B, Sq, Hq, hd)
    k: jnp.ndarray,  # (B, Skv, Hkv, hd) — bf16/f32, or int8 with k_scale
    v: jnp.ndarray,  # (B, Skv, Hkv, hd)
    dims: AttnDims,
    q_positions: jnp.ndarray,  # (B, Sq) absolute positions (causal mask)
    kv_positions: jnp.ndarray,  # (B, Skv)
    kv_valid: Optional[jnp.ndarray] = None,  # (B, Skv) bool
    kv_chunk: int = 2048,
    k_scale: Optional[jnp.ndarray] = None,  # (B, Skv, Hkv) int8-KV scales
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Flash-style attention: scan over KV chunks with running (max, sum,
    acc) — the live score block is (B, Hq, Sq, kv_chunk).  Exact (not an
    approximation).  Returns (B, Sq, Hq, hd).

    int8 KV path: when k/v are int8 with per-(token, head) scales, each
    chunk is dequantized INSIDE the scan body — the peak working set stays
    int8-cache + one bf16 chunk (the decode memory-roofline win)."""
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[1]
    G = dims.q_per_kv
    scale = _scale(dims)

    def _dq(x, s):
        if s is None:
            return x
        return x.astype(jnp.float32) * s.astype(jnp.float32)[..., None]

    if Skv <= kv_chunk:
        kd = _dq(k, k_scale).astype(q.dtype) if k_scale is not None else k
        vd = _dq(v, v_scale).astype(q.dtype) if v_scale is not None else v
        return _attend_dense(q, kd, vd, dims, q_positions, kv_positions, kv_valid)

    assert Skv % kv_chunk == 0, (Skv, kv_chunk)
    n_chunks = Skv // kv_chunk

    kc = k.reshape(B, n_chunks, kv_chunk, dims.n_kv_heads, hd)
    vc = v.reshape(B, n_chunks, kv_chunk, dims.n_kv_heads, hd)
    ksc = (
        k_scale.reshape(B, n_chunks, kv_chunk, dims.n_kv_heads)
        if k_scale is not None else None
    )
    vsc = (
        v_scale.reshape(B, n_chunks, kv_chunk, dims.n_kv_heads)
        if v_scale is not None else None
    )
    pc = kv_positions.reshape(B, n_chunks, kv_chunk)
    if kv_valid is None:
        kv_valid = jnp.ones((B, Skv), bool)
    mc = kv_valid.reshape(B, n_chunks, kv_chunk)

    qh = (q * scale).astype(jnp.float32).reshape(B, Sq, dims.n_kv_heads, G, hd)

    def body(carry, chunk):
        m_run, l_run, acc = carry
        if ksc is not None:
            kcb, vcb, pcb, mcb, kscb, vscb = chunk
            kcb = _dq(kcb, kscb)
            vcb = _dq(vcb, vscb)
        else:
            kcb, vcb, pcb, mcb = chunk  # (B, C, Hkv, hd), ..., (B, C)
        # scores: (B, Sq, Hkv, G, C)
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc", qh, kcb.astype(jnp.float32)
        )
        mask = mcb[:, None, None, None, :]
        if dims.causal:
            mask = mask & (
                pcb[:, None, None, None, :] <= q_positions[:, :, None, None, None]
            )
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bqkgc,bckd->bqkgd", p, vcb.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, dims.n_kv_heads, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, dims.n_kv_heads, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, dims.n_kv_heads, G, hd), jnp.float32)
    chunks = (
        jnp.moveaxis(kc, 1, 0),
        jnp.moveaxis(vc, 1, 0),
        jnp.moveaxis(pc, 1, 0),
        jnp.moveaxis(mc, 1, 0),
    )
    if ksc is not None:
        chunks = chunks + (jnp.moveaxis(ksc, 1, 0), jnp.moveaxis(vsc, 1, 0))
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), chunks)
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


def _attend_dense(
    q, k, v, dims: AttnDims, q_positions, kv_positions, kv_valid=None
) -> jnp.ndarray:
    """Direct-scores path for short KV (train seq 4k, single chunks)."""
    B, Sq, Hq, hd = q.shape
    G = dims.q_per_kv
    scale = _scale(dims)
    qh = (q * scale).astype(jnp.float32).reshape(B, Sq, dims.n_kv_heads, G, hd)
    s = jnp.einsum("bqkgd,bckd->bqkgc", qh, k.astype(jnp.float32))
    mask = jnp.ones((B, 1, 1, 1, k.shape[1]), bool)
    if kv_valid is not None:
        mask = mask & kv_valid[:, None, None, None, :]
    if dims.causal:
        mask = mask & (
            kv_positions[:, None, None, None, :]
            <= q_positions[:, :, None, None, None]
        )
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgc,bckd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, hd).astype(q.dtype)


class KVCacheSlice(NamedTuple):
    """One layer's decode cache."""

    k: jnp.ndarray  # (B, S_max, Hkv, hd)
    v: jnp.ndarray  # (B, S_max, Hkv, hd)


def decode_attend(
    q: jnp.ndarray,  # (B, 1, Hq, hd) — already roped at position `length`
    cache: KVCacheSlice,
    new_k: jnp.ndarray,  # (B, 1, Hkv, hd) roped
    new_v: jnp.ndarray,
    dims: AttnDims,
    length: jnp.ndarray,  # () int32 — tokens already in cache
    kv_chunk: int = 4096,
) -> Tuple[jnp.ndarray, KVCacheSlice]:
    """One-token decode: append to cache, attend over valid prefix."""
    B, _, Hkv, hd = new_k.shape
    S_max = cache.k.shape[1]
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, new_k, length, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, new_v, length, axis=1)
    pos = jnp.arange(S_max, dtype=jnp.int32)[None, :].repeat(B, 0)
    valid = pos < (length + 1)
    qpos = jnp.full((B, 1), length, jnp.int32)
    out = attend_chunked(
        q, k, v, dims, qpos, pos, kv_valid=valid, kv_chunk=kv_chunk
    )
    return out, KVCacheSlice(k, v)
