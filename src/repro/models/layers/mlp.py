"""Gated MLPs (SwiGLU / GeGLU) and the plain GELU MLP (whisper)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gated_mlp(
    x: jnp.ndarray,  # (B, S, D)
    w_gate: jnp.ndarray,  # (D, F)
    w_up: jnp.ndarray,  # (D, F)
    w_down: jnp.ndarray,  # (F, D)
    act: str = "silu",
) -> jnp.ndarray:
    g = x @ w_gate
    u = x @ w_up
    if act == "silu":
        h = jax.nn.silu(g) * u
    elif act == "gelu":  # GeGLU (gemma)
        h = jax.nn.gelu(g, approximate=True) * u
    else:
        raise ValueError(act)
    return h @ w_down


def dense_mlp(
    x: jnp.ndarray,
    w_in: jnp.ndarray,  # (D, F)
    b_in: jnp.ndarray,  # (F,)
    w_out: jnp.ndarray,  # (F, D)
    b_out: jnp.ndarray,  # (D,)
) -> jnp.ndarray:
    h = jax.nn.gelu(x @ w_in + b_in, approximate=True)
    return h @ w_out + b_out
