"""Structured error taxonomy for the PQ/serving stack.

Every failure the overload/fault layer can surface is a typed exception
with a stable machine-readable ``code`` — callers (the window-recovery
path, the chaos tests, operational tooling) dispatch on the type or the
code, never on message text.  The taxonomy is deliberately small:

  PQError                    base — anything raised by this stack
  ├─ InvariantViolation      a PQState invariant (I1–I6) failed a runtime
  │                          validation pass (`SmartPQConfig.validate`)
  ├─ TraceCorruptError       a Trace npz failed to load or to validate
  │                          (truncated file, bad op codes, shape mismatch)
  ├─ WindowValidationError   a scheduler window tripped validation AND the
  │                          conservative fallback retry (STRICT, forecast
  │                          off) failed too — carries the violations of
  │                          both attempts; the pre-window checkpoint has
  │                          been restored when this is raised
  ├─ SnapshotCorruptError    a persisted snapshot directory failed
  │                          validation (missing/truncated shard, CRC
  │                          mismatch, stale manifest) — recovery absorbs
  │                          it by falling back to an older valid snapshot
  └─ CrashLoopError          the serve supervisor's circuit breaker
                             opened: the child crashed more than the
                             restart budget allows inside the crash window
"""

from __future__ import annotations

from typing import List, Optional


class PQError(Exception):
    """Base of the taxonomy; ``code`` is stable across releases."""

    code = "PQ_ERROR"


class InvariantViolation(PQError):
    """One PQState invariant failed a runtime validation pass.

    ``invariant`` is the state.py docstring's identifier ("I1".."I6"),
    ``shard`` the offending shard (or -1 for whole-state violations)."""

    code = "INVARIANT"

    def __init__(self, invariant: str, shard: int, detail: str):
        self.invariant = invariant
        self.shard = int(shard)
        self.detail = detail
        super().__init__(f"{invariant} shard={shard}: {detail}")


class TraceCorruptError(PQError):
    """A Trace npz could not be loaded/validated (truncation, flipped
    bytes, out-of-range op codes, inconsistent shapes)."""

    code = "TRACE_CORRUPT"

    def __init__(self, detail: str, path: Optional[str] = None):
        self.detail = detail
        self.path = path
        super().__init__(
            f"corrupt trace{f' {path}' if path else ''}: {detail}"
        )


class WindowValidationError(PQError):
    """A scheduler window failed validation and so did its one-shot
    conservative retry.  State has been rolled back to the pre-window
    checkpoint before this is raised — the queue is NOT corrupted; the
    window's work simply did not happen."""

    code = "WINDOW_VALIDATION"

    def __init__(
        self,
        first: List[InvariantViolation],
        retry: List[InvariantViolation],
    ):
        self.first = list(first)
        self.retry = list(retry)
        super().__init__(
            f"window validation failed and fallback retry failed too "
            f"(first: {[str(v) for v in first]}; "
            f"retry: {[str(v) for v in retry]})"
        )


class SnapshotCorruptError(PQError):
    """A persisted snapshot directory (`repro.core.persist` manifest tree)
    failed validation: missing or truncated shard, shard CRC mismatch, or
    a stale manifest naming files that do not exist.  Recovery treats this
    as a skip signal — load the newest snapshot that validates — so it
    only propagates when a caller demands one specific step."""

    code = "SNAPSHOT_CORRUPT"

    def __init__(self, detail: str, path: Optional[str] = None):
        self.detail = detail
        self.path = path
        super().__init__(
            f"corrupt snapshot{f' {path}' if path else ''}: {detail}"
        )


class CrashLoopError(PQError):
    """The serve supervisor's circuit breaker opened: its child process
    died more than `max_restarts` times inside `crash_window` seconds.
    Carries the observed exit codes so operators can tell a crash loop
    (same code repeating) from flapping infrastructure."""

    code = "CRASH_LOOP"

    def __init__(self, restarts: int, window_s: float, exit_codes):
        self.restarts = int(restarts)
        self.window_s = float(window_s)
        self.exit_codes = list(exit_codes)
        super().__init__(
            f"crash loop: {restarts} restarts within {window_s:.1f}s "
            f"(exit codes {self.exit_codes})"
        )
