"""Structured error taxonomy for the PQ/serving stack.

Every failure the overload/fault layer can surface is a typed exception
with a stable machine-readable ``code`` — callers (the window-recovery
path, the chaos tests, operational tooling) dispatch on the type or the
code, never on message text.  The taxonomy is deliberately small:

  PQError                    base — anything raised by this stack
  ├─ InvariantViolation      a PQState invariant (I1–I6) failed a runtime
  │                          validation pass (`SmartPQConfig.validate`)
  ├─ TraceCorruptError       a Trace npz failed to load or to validate
  │                          (truncated file, bad op codes, shape mismatch)
  └─ WindowValidationError   a scheduler window tripped validation AND the
                             conservative fallback retry (STRICT, forecast
                             off) failed too — carries the violations of
                             both attempts; the pre-window checkpoint has
                             been restored when this is raised
"""

from __future__ import annotations

from typing import List, Optional


class PQError(Exception):
    """Base of the taxonomy; ``code`` is stable across releases."""

    code = "PQ_ERROR"


class InvariantViolation(PQError):
    """One PQState invariant failed a runtime validation pass.

    ``invariant`` is the state.py docstring's identifier ("I1".."I6"),
    ``shard`` the offending shard (or -1 for whole-state violations)."""

    code = "INVARIANT"

    def __init__(self, invariant: str, shard: int, detail: str):
        self.invariant = invariant
        self.shard = int(shard)
        self.detail = detail
        super().__init__(f"{invariant} shard={shard}: {detail}")


class TraceCorruptError(PQError):
    """A Trace npz could not be loaded/validated (truncation, flipped
    bytes, out-of-range op codes, inconsistent shapes)."""

    code = "TRACE_CORRUPT"

    def __init__(self, detail: str, path: Optional[str] = None):
        self.detail = detail
        self.path = path
        super().__init__(
            f"corrupt trace{f' {path}' if path else ''}: {detail}"
        )


class WindowValidationError(PQError):
    """A scheduler window failed validation and so did its one-shot
    conservative retry.  State has been rolled back to the pre-window
    checkpoint before this is raised — the queue is NOT corrupted; the
    window's work simply did not happen."""

    code = "WINDOW_VALIDATION"

    def __init__(
        self,
        first: List[InvariantViolation],
        retry: List[InvariantViolation],
    ):
        self.first = list(first)
        self.retry = list(retry)
        super().__init__(
            f"window validation failed and fallback retry failed too "
            f"(first: {[str(v) for v in first]}; "
            f"retry: {[str(v) for v in retry]})"
        )
