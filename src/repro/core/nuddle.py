"""Nuddle — the generic delegation engine (paper §2).

The paper's claim: Nuddle converts ANY concurrent NUMA-oblivious structure
into a NUMA-aware one, because the delegation layer only needs (a) a way for
clients to hand compact request frames to servers and (b) the base
structure's own concurrent operations for servers to execute.

The TPU translation factors delegation the same way.  A structure is
delegable if it provides three shard-local callables (the analogue of the
base algorithm's red-colored core ops in paper Figs. 4-6):

    nominate(local_state, m)   -> frame          shard-local candidate frame
    combine(frame_a, frame_b)  -> frame          associative frame merge
    commit(local_state, frame, ctx) -> state     apply the global verdict

`delegate()` then runs the generic two-phase hierarchical reduction:
frames all-gather within the pod (fast tier), combine; pod frames cross the
pod axis (compact — the request/response cache-line analogue), combine;
verdict broadcasts implicitly (the reduction is replicated) and every shard
commits locally.  The PQ tournament is one instantiation; `SortedSetOps`
below is a second, structurally different one (batch membership + extract-
range), demonstrating the genericity claim.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue.state import INF_KEY


@dataclasses.dataclass(frozen=True)
class DelegableOps:
    """The structure-specific plugin (base-algorithm core ops)."""

    nominate: Callable[[Any, int], Any]  # local_state, m -> frame
    combine: Callable[[Any, Any], Any]  # frame, frame -> frame
    commit: Callable[[Any, Any, Any], Any]  # local_state, verdict, ctx -> state


def delegate_single_controller(
    ops: DelegableOps,
    local_states: Any,  # pytree with leading shard axis S
    m: int,
    npods: int,
    ctx: Any = None,
):
    """Single-controller semantic path (tests/benches): performs the same
    two-phase combine tree the distributed path performs, vectorized."""
    S = jax.tree.leaves(local_states)[0].shape[0]
    assert S % npods == 0
    frames = jax.vmap(lambda s: ops.nominate(s, m))(local_states)

    def reduce_frames(fr, n):
        """Associative pairwise reduction over leading axis of size n."""
        def body(f):
            half = jax.tree.map(lambda x: x[: x.shape[0] // 2], f)
            rest = jax.tree.map(lambda x: x[x.shape[0] // 2 :], f)
            return jax.vmap(ops.combine)(half, rest)

        while n > 1:
            assert n % 2 == 0, "shard count must be a power of two"
            frames_ = body(fr)
            fr, n = frames_, n // 2
        return jax.tree.map(lambda x: x[0], fr)

    # Phase 1: per-pod combine.  Phase 2: cross-pod combine.
    per_pod = jax.tree.map(
        lambda x: x.reshape(npods, S // npods, *x.shape[1:]), frames
    )
    pod_frames = jax.vmap(lambda f: reduce_frames(f, S // npods))(per_pod)
    verdict = reduce_frames(pod_frames, npods)
    new_states = jax.vmap(lambda s: ops.commit(s, verdict, ctx))(local_states)
    return new_states, verdict


def delegate_dist(
    ops: DelegableOps,
    local_state: Any,  # this device's shard-local state
    m: int,
    shard_axes: Tuple[str, ...],
    pod_axis: str | None,
    ctx: Any = None,
):
    """Distributed delegation under shard_map: all_gather-combine within the
    pod, then only combined pod frames cross `pod_axis`."""
    frame = ops.nominate(local_state, m)

    def gather_combine(fr, axes):
        gathered = jax.tree.map(
            lambda x: jax.lax.all_gather(x, axes, tiled=False), fr
        )
        n = jax.tree.leaves(gathered)[0].shape[0]
        out = jax.tree.map(lambda x: x[0], gathered)
        for i in range(1, n):
            out = ops.combine(out, jax.tree.map(lambda x: x[i], gathered))
        return out

    pod_frame = gather_combine(frame, shard_axes)
    verdict = (
        gather_combine(pod_frame, (pod_axis,)) if pod_axis else pod_frame
    )
    return ops.commit(local_state, verdict, ctx), verdict


def delegate_window(
    ops: DelegableOps,
    local_states: Any,  # pytree with leading shard axis S
    m: int,
    npods: int,
    ctxs: Any = None,  # pytree with leading round axis K (or None + length)
    length: int | None = None,
):
    """K delegation rounds fused into one `lax.scan` — the window analogue
    of the paper's serve_requests() loop, where a server thread serves a
    whole BATCH of client requests per wakeup instead of one.

    Each scan iteration runs the full two-phase hierarchical reduction
    (`delegate_single_controller`), so a K-round window costs one device
    dispatch instead of K.  Returns (final_states, stacked verdicts) —
    bit-identical to K sequential delegate calls (tested)."""

    def body(states, ctx):
        new_states, verdict = delegate_single_controller(
            ops, states, m, npods, ctx
        )
        return new_states, verdict

    return jax.lax.scan(body, local_states, ctxs, length=length)


# ---------------------------------------------------------------------------
# Genericity demo #1: the PQ tournament as a DelegableOps plugin.
# ---------------------------------------------------------------------------


def pq_tournament_ops() -> DelegableOps:
    """Priority-queue deleteMin as delegation: nominate = sorted prefix,
    combine = 2-way merge keeping m smallest, commit = remove won prefix."""
    from repro.core.pqueue import local as L

    def nominate(local_state, m):
        keys, vals = local_state["keys"], local_state["vals"]
        return {"k": keys[:m], "v": vals[:m]}

    def combine(a, b):
        from repro.core.pqueue.local import topk_of_merged

        m = a["k"].shape[0]
        k, v = topk_of_merged(
            jnp.concatenate([a["k"], b["k"]]),
            jnp.concatenate([a["v"], b["v"]]),
            m,
        )
        return {"k": k, "v": v}

    def commit(local_state, verdict, ctx):
        n = ctx["n"]
        cutoff = verdict["k"][jnp.maximum(n - 1, 0)]
        keys = local_state["keys"]
        take = jnp.where(n > 0, jnp.sum(keys < cutoff), 0).astype(jnp.int32)
        C = keys.shape[0]
        idx = jnp.minimum(jnp.arange(C, dtype=jnp.int32) + take, C - 1)
        in_rng = (jnp.arange(C, dtype=jnp.int32) + take) < C
        return {
            "keys": jnp.where(in_rng, keys[idx], INF_KEY),
            "vals": jnp.where(in_rng, local_state["vals"][idx], 0),
        }

    return DelegableOps(nominate, combine, commit)


# ---------------------------------------------------------------------------
# Genericity demo #2: a sorted-set (skip-list stand-in) with batch contains
# + extract-below — structurally different frames (bitmaps, not runs).
# ---------------------------------------------------------------------------


def sorted_set_ops(query_keys: jnp.ndarray) -> DelegableOps:
    """Batch membership: nominate = local hit bitmap for `query_keys`,
    combine = OR, commit = identity (read-only op).  Shows that delegation
    frames need not be candidate runs at all."""

    def nominate(local_state, m):
        keys = local_state["keys"]
        hit = jnp.isin(query_keys, keys, assume_unique=False)
        return {"hit": hit}

    def combine(a, b):
        return {"hit": a["hit"] | b["hit"]}

    def commit(local_state, verdict, ctx):
        return local_state

    return DelegableOps(nominate, combine, commit)
