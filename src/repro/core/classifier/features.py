"""Classification features — Table 1 of the paper, unchanged.

| Feature           | Paper definition                      | TPU reading           |
|-------------------|---------------------------------------|-----------------------|
| #Threads          | active threads issuing ops            | active client devices |
| Size              | current queue size                    | sum(state.size)       |
| Key_range         | range of keys in the workload         | key-universe width    |
| % insert/deleteMin| op mix                                | insert fraction       |

Features are log/linear-normalized before hitting the tree: trees don't need
normalization for accuracy, but normalized thresholds make the packed
on-device tree robust to the int32/float32 boundary.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

FEATURE_NAMES = ("num_clients", "size", "key_range", "insert_frac")

# Class labels — §3.1.2 (1), generalized from the paper's 2-way (oblivious /
# aware) choice to an N-way mode set.  INVARIANT: classes 0..NUM_MODES-1 are
# algorithmic modes and double as the `lax.switch` branch index in SmartPQ;
# CLASS_NEUTRAL is always the LAST class (== NUM_MODES) and means "tie — keep
# the current mode" (hysteresis, §3.1.2 (1)(ii)).  Adding a mode = append its
# class id before NEUTRAL, give the cost model a throughput() arm, and give
# SmartPQConfig.mode_schedules a schedule for it.
CLASS_OBLIVIOUS = 0  # run the base algorithm directly (spray, collective-free)
CLASS_MULTIQ = 1  # relaxed MultiQueue: two-choice sampling, bounded rank error
CLASS_AWARE = 2  # delegate: Nuddle pod-hierarchical schedule
NUM_MODES = 3
CLASS_NEUTRAL = NUM_MODES  # tie sentinel — never a switch branch
NUM_CLASSES = NUM_MODES + 1
MODE_NAMES = ("oblivious", "multiq", "aware")


def featurize(
    num_clients, size, key_range, insert_frac
) -> np.ndarray:
    """Vectorized feature transform -> float32 (..., 4)."""
    num_clients = np.asarray(num_clients, np.float64)
    size = np.asarray(size, np.float64)
    key_range = np.asarray(key_range, np.float64)
    insert_frac = np.asarray(insert_frac, np.float64)
    f = np.stack(
        [
            np.log2(np.maximum(num_clients, 1.0)),
            np.log2(np.maximum(size, 1.0)),
            np.log2(np.maximum(key_range, 1.0)),
            insert_frac,
        ],
        axis=-1,
    )
    return f.astype(np.float32)


def featurize_jnp(
    num_clients: jnp.ndarray,
    size: jnp.ndarray,
    key_range: jnp.ndarray,
    insert_frac: jnp.ndarray,
) -> jnp.ndarray:
    """jnp mirror of `featurize` (same normalization) — the device-side
    feature path SmartPQ's in-graph decision (and the fused window engine's
    scan body) evaluates every step, replacing the paper's host round-trip.
    Scalar inputs -> (4,) float32."""

    def lg2(x):
        return jnp.log2(jnp.maximum(x.astype(jnp.float32), 1.0))

    return jnp.stack(
        [
            lg2(jnp.asarray(num_clients)),
            lg2(jnp.asarray(size)),
            lg2(jnp.asarray(key_range)),
            jnp.asarray(insert_frac).astype(jnp.float32),
        ]
    )
