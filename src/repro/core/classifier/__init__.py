from repro.core.classifier.tree import DecisionTree, train_tree  # noqa: F401
from repro.core.classifier.inference import PackedTree, pack_tree, tree_predict  # noqa: F401
from repro.core.classifier.features import (  # noqa: F401
    FEATURE_NAMES,
    MODE_NAMES,
    NUM_CLASSES,
    NUM_MODES,
    CLASS_NEUTRAL,
    CLASS_OBLIVIOUS,
    CLASS_MULTIQ,
    CLASS_AWARE,
    featurize,
)
from repro.core.classifier.cost_model import (  # noqa: F401
    HardwareModel,
    TPU_V5E,
    schedule_cost,
    mode_throughputs,
    best_mode,
)
