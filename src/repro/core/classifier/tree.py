"""CART decision-tree trainer (pure numpy — sklearn is not available here).

The paper generates its classifier with scikit-learn (§3.1.2) and reports a
tree of ~180 nodes, depth 8.  This trainer reproduces the relevant subset:
Gini-impurity binary splits on continuous features, max-depth / min-samples
stopping, no pruning.  Determinism: ties in gain break toward the lower
feature index, then lower threshold — so retraining on the same data yields
the identical tree (important for reproducible EXPERIMENTS.md numbers).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class _Node:
    feature: int = -1  # -1 => leaf
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    label: int = 0
    n_samples: int = 0


@dataclasses.dataclass
class DecisionTree:
    nodes: List[_Node]
    num_features: int
    num_classes: int
    max_depth: int

    # -- inference ----------------------------------------------------------

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.atleast_2d(np.asarray(X, np.float32))
        out = np.empty(X.shape[0], np.int32)
        for i, x in enumerate(X):
            n = 0
            node = self.nodes[0]
            while node.feature >= 0:
                n = node.left if x[node.feature] <= node.threshold else node.right
                node = self.nodes[n]
            out[i] = node.label
        return out

    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    @property
    def num_leaves(self) -> int:
        return sum(1 for n in self.nodes if n.feature < 0)

    def depth(self) -> int:
        def _d(i: int) -> int:
            n = self.nodes[i]
            if n.feature < 0:
                return 0
            return 1 + max(_d(n.left), _d(n.right))

        return _d(0)


def _gini(counts: np.ndarray) -> float:
    total = counts.sum()
    if total == 0:
        return 0.0
    p = counts / total
    return float(1.0 - np.sum(p * p))


def _best_split(
    X: np.ndarray, y: np.ndarray, num_classes: int
) -> Optional[tuple]:
    """Exhaustive best (feature, threshold) by Gini gain. O(F * N log N)."""
    n, F = X.shape
    parent_counts = np.bincount(y, minlength=num_classes)
    parent_gini = _gini(parent_counts)
    best = None  # (gain, feature, threshold)
    for f in range(F):
        order = np.argsort(X[:, f], kind="stable")
        xs, ys = X[order, f], y[order]
        left = np.zeros(num_classes, np.int64)
        right = parent_counts.astype(np.int64).copy()
        for i in range(n - 1):
            c = ys[i]
            left[c] += 1
            right[c] -= 1
            if xs[i] == xs[i + 1]:
                continue
            nl, nr = i + 1, n - i - 1
            gain = parent_gini - (nl * _gini(left) + nr * _gini(right)) / n
            thr = float((xs[i] + xs[i + 1]) / 2.0)
            key = (-gain, f, thr)
            if best is None or key < best:
                best = key
    if best is None or -best[0] <= 1e-12:
        return None
    return (-best[0], best[1], best[2])


def train_tree(
    X: np.ndarray,
    y: np.ndarray,
    num_classes: int,
    max_depth: int = 8,
    min_samples_split: int = 8,
    min_samples_leaf: int = 4,
) -> DecisionTree:
    """Paper defaults: depth 8 (§3.1.2 (4) reports depth 8, ~180 nodes)."""
    X = np.asarray(X, np.float32)
    y = np.asarray(y, np.int32)
    nodes: List[_Node] = []

    def build(idx: np.ndarray, depth: int) -> int:
        counts = np.bincount(y[idx], minlength=num_classes)
        me = len(nodes)
        nodes.append(_Node(label=int(np.argmax(counts)), n_samples=len(idx)))
        if (
            depth >= max_depth
            or len(idx) < min_samples_split
            or np.count_nonzero(counts) <= 1
        ):
            return me
        split = _best_split(X[idx], y[idx], num_classes)
        if split is None:
            return me
        _, f, thr = split
        go_left = X[idx, f] <= thr
        li, ri = idx[go_left], idx[~go_left]
        if len(li) < min_samples_leaf or len(ri) < min_samples_leaf:
            return me
        nodes[me].feature = f
        nodes[me].threshold = thr
        nodes[me].left = build(li, depth + 1)
        nodes[me].right = build(ri, depth + 1)
        return me

    build(np.arange(len(y)), 0)
    return DecisionTree(
        nodes=nodes,
        num_features=X.shape[1],
        num_classes=num_classes,
        max_depth=max_depth,
    )
