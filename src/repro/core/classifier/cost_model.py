"""Two-tier interconnect cost model — ground-truth generator for the tree.

The paper trains on 5525 workloads *measured* on a 4-node Xeon (§3.1.2-3).
This container has no NUMA/ICI hardware, so ground truth comes from an
analytical per-step model of the two algorithmic modes, built from the same
terms the roofline analysis uses (DESIGN.md §5-6):

  OBLIVIOUS (= spray, the alistarh base algorithm): collective-free local
    pops.  Raw step time is tiny, but relaxed deleteMin returns elements up
    to `spray_bound(S, m)` ranks from the head; the *application* pays for
    each inversion (SSSP re-relaxations, scheduler re-queues, DES rollbacks).
    Modeled as a multiplicative effective-throughput penalty
        w = clip(alpha * rank_err * delete_frac, 0, w_max),
        rank_err = envelope / size, discounted by duplicate density
    — the message-passing analogue of the head-contention the paper's
    oblivious mode suffers under deleteMin-dominated load.

  MULTIQ (= relaxed MultiQueue, Williams & Sanders 2021): collective-free
    like spray, but every deleter probes TWO sub-queue cached minima and
    pops from the smaller — two-choice load balancing shrinks the rank-error
    envelope from spray's m + S*(log2 S + 1)^2 to m + O(S log log S)
    (`multiq_bound`).  Pays for it with double the probe traffic per
    deleter, so on waste-free workloads spray stays marginally cheaper.

  AWARE (= hier, the Nuddle delegation): exact two-phase tournament.  Pays
    an intra-pod gather (fast ICI), a pod-axis candidate exchange (slow
    tier — the compact request/response frames of Nuddle), and two
    collective launch latencies; delivers exact semantics (no waste).

Qualitative regimes reproduced (paper Figs. 1, 7, 9 + the MultiQueue
mixed-contention regime of Engineering MultiQueues):
  * insert-dominated / huge queues    -> OBLIVIOUS (delegation latency wasted,
                                         relaxation free, fewest probes)
  * deleteMin-dominated, queue deep
    enough to absorb the two-choice
    envelope but not the spray one    -> MULTIQ (mixed-contention regime)
  * deleteMin-dominated, small queues
    or many clients                   -> AWARE (contention analogue)
  * few clients / single pod          -> NEUTRAL band (paper §3.1.2 (1)(i))

Divergence from the paper (documented in EXPERIMENTS.md): with very large
queues the relaxation penalty vanishes (rank error is relative), so
deleteMin-dominated + huge-queue workloads favor OBLIVIOUS here, whereas
size-independent cache-line contention keeps Nuddle ahead on real NUMA
hardware.  This is a physical property of the message-passing translation,
not a modeling bug.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.classifier.features import (
    CLASS_AWARE,
    CLASS_MULTIQ,
    CLASS_NEUTRAL,
    CLASS_OBLIVIOUS,
    NUM_MODES,
)
from repro.core.pqueue.schedules import multiq_bound, spray_bound


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # B/s per chip
    ici_bw: float = 50e9  # B/s per link, intra-pod
    dci_bw: float = 12.5e9  # B/s per link, cross-pod tier
    lat_ici: float = 2e-6  # s per intra-pod collective phase
    lat_dci: float = 30e-6  # s per cross-pod collective phase
    vpu_rate: float = 1e11  # key compare/merge element-ops per s per chip
    relax_alpha: float = 3.0  # wasted ops per fully-inverted deletion
    # Cap on the wasted-work fraction.  At envelope saturation (rank error
    # ~1) essentially every relaxed deletion returns junk the application
    # re-queues, so the cap must sit close enough to 1 that a saturated
    # relaxed mode cannot out-throughput the exact mode on raw step speed
    # alone — otherwise the delete-storm regime (paper Fig. 9, deleteMin-
    # dominated) mislabels as OBLIVIOUS.
    relax_wmax: float = 0.999
    bytes_per_item: int = 8  # key + value
    cand_slack: float = 1.5  # expected-case candidate oversampling factor


TPU_V5E = HardwareModel()


@dataclasses.dataclass(frozen=True)
class MeshGeom:
    npods: int = 2
    chips_per_pod: int = 256

    @property
    def chips(self) -> int:
        return self.npods * self.chips_per_pod


@dataclasses.dataclass(frozen=True)
class Workload:
    """One contention workload — the paper's Table 1 feature tuple plus the
    per-client batch the bulk-synchronous translation needs."""

    num_clients: int  # active client devices
    size: int  # current queue size
    key_range: int
    insert_frac: float  # [0, 1]
    ops_per_client: int = 64


def _geom_active(w: Workload, g: MeshGeom):
    """Pods/chips actually hosting active clients."""
    chips_pod = min(max(w.num_clients, 1), g.chips_per_pod)
    pods = max(min(g.npods, -(-w.num_clients // g.chips_per_pod)), 1)
    return chips_pod, pods


def _insert_cost(w: Workload, hw: HardwareModel, g: MeshGeom) -> float:
    """Shared by both modes: hash-route all_to_all + local sorted merge."""
    b_ins = w.num_clients * w.ops_per_client * w.insert_frac
    if b_ins <= 0:
        return 0.0
    chips_pod, pods = _geom_active(w, g)
    bytes_total = b_ins * hw.bytes_per_item
    cross = bytes_total * (pods - 1) / pods
    local = bytes_total - cross
    t_route = local / (hw.ici_bw * max(w.num_clients, 1)) + hw.lat_ici
    if pods > 1:
        t_route += cross / (hw.dci_bw * pods) + hw.lat_dci
    # Rank-merge (searchsorted + scatter) of each shard's incoming run.
    per_shard = b_ins / max(w.num_clients, 1)
    t_merge = per_shard * math.log2(max(w.size + b_ins, 2)) / hw.vpu_rate
    return t_route + t_merge


def _rank_error(w: Workload, b_del: float, mode: int = CLASS_OBLIVIOUS) -> float:
    """Expected relative rank displacement of a relaxed deletion, in [0, 1].
    The envelope is the mode's: spray pays the full O(S log^2 S) window,
    multiq's two-choice sampling pays only O(S log log S)."""
    S = max(w.num_clients, 1)
    m = int(max(b_del, 1))
    envelope = multiq_bound(S, m) if mode == CLASS_MULTIQ else spray_bound(S, m)
    distinct = max(min(w.size, w.key_range), 1)
    dup_discount = max(w.size / distinct, 1.0)  # equal keys are interchangeable
    return min(envelope / max(w.size, 1), 1.0) / dup_discount


def _delete_cost_oblivious(w: Workload, hw: HardwareModel, g: MeshGeom) -> float:
    """Spray: collective-free local window pops."""
    b_del = w.num_clients * w.ops_per_client * (1.0 - w.insert_frac)
    if b_del <= 0:
        return 0.0
    S = max(w.num_clients, 1)
    m_s = b_del / S
    window = m_s + (math.log2(max(S, 2)) + 1) ** 2
    return window * math.log2(max(window, 2)) / hw.vpu_rate


def _delete_cost_multiq(w: Workload, hw: HardwareModel, g: MeshGeom) -> float:
    """Relaxed MultiQueue: collective-free two-choice pops.  Each of the
    b_del deleters reads TWO cached sub-queue minima and compares (the probe
    term — double spray's single landing), then the chosen sub-queues serve
    balanced prefix pops (expected max load m/S + O(log log S))."""
    b_del = w.num_clients * w.ops_per_client * (1.0 - w.insert_frac)
    if b_del <= 0:
        return 0.0
    S = max(w.num_clients, 1)
    probes = 2.0 * b_del  # two min-cache reads + one compare per deleter
    load = b_del / S + math.log2(math.log2(max(S, 4))) + 1.0
    pops = load * math.log2(max(load, 2.0))
    return (probes + pops) / hw.vpu_rate


def _delete_cost_aware(w: Workload, hw: HardwareModel, g: MeshGeom) -> float:
    """Nuddle hierarchical tournament: exact, two collective phases.
    Expected-case single-round selection: every shard nominates
    slack * m/S candidates (two-round fallback amortized into `cand_slack`)."""
    b_del = w.num_clients * w.ops_per_client * (1.0 - w.insert_frac)
    if b_del <= 0:
        return 0.0
    m = max(b_del, 1.0)
    chips_pod, pods = _geom_active(w, g)
    S = max(w.num_clients, 1)
    cand = hw.cand_slack * m / S + 8.0  # per-shard nomination

    # Phase 1 (ICI): all-gather per-pod candidates + replicated k-way merge.
    ph1_bytes = cand * chips_pod * hw.bytes_per_item
    pod_cand = cand * chips_pod
    t1 = ph1_bytes / hw.ici_bw  # ring all-gather: each chip receives all cands
    t1 += hw.lat_ici
    t1 += pod_cand * math.log2(max(chips_pod, 2)) / hw.vpu_rate  # k-way merge

    # Phase 2 (DCI, pod axis only): compact pod-winner frames.
    if pods > 1:
        per_pod = hw.cand_slack * m / pods + 8.0
        ph2_bytes = per_pod * pods * hw.bytes_per_item
        t2 = ph2_bytes / hw.dci_bw + hw.lat_dci
        t2 += per_pod * pods * math.log2(max(pods, 2)) / hw.vpu_rate
    else:
        t2 = 0.0

    # Prefix removal (local shift) — HBM touch of the shard frontier.
    t3 = (m / S) * hw.bytes_per_item / hw.hbm_bw
    return t1 + t2 + t3


def _delete_cost_flat(w: Workload, hw: HardwareModel, g: MeshGeom) -> float:
    """lotan_shavit: one flat global gather — all candidates cross DCI."""
    b_del = w.num_clients * w.ops_per_client * (1.0 - w.insert_frac)
    if b_del <= 0:
        return 0.0
    m = max(b_del, 1.0)
    D = max(w.num_clients, 1)
    chips_pod, pods = _geom_active(w, g)
    cand = hw.cand_slack * m / D + 8.0
    bytes_total = cand * D * hw.bytes_per_item
    t = bytes_total / hw.ici_bw + hw.lat_ici
    if pods > 1:
        t += bytes_total * (pods - 1) / pods / hw.dci_bw + hw.lat_dci
    t += cand * D * math.log2(max(D, 2)) / hw.vpu_rate
    return t


def _waste_fraction(
    w: Workload, hw: HardwareModel, mode: int = CLASS_OBLIVIOUS
) -> float:
    """Fraction of a relaxed mode's work lost to priority inversion."""
    b_del = w.num_clients * w.ops_per_client * (1.0 - w.insert_frac)
    if b_del <= 0:
        return 0.0
    rank_err = _rank_error(w, b_del, mode)
    return min(hw.relax_alpha * rank_err * (1.0 - w.insert_frac), hw.relax_wmax)


_DELETE_COSTS = {
    CLASS_OBLIVIOUS: _delete_cost_oblivious,
    CLASS_MULTIQ: _delete_cost_multiq,
    CLASS_AWARE: _delete_cost_aware,
}

_RELAXED_MODES = (CLASS_OBLIVIOUS, CLASS_MULTIQ)  # modes paying inversion waste


def schedule_cost(
    mode: int, w: Workload, hw: HardwareModel = TPU_V5E, g: MeshGeom = MeshGeom()
) -> float:
    """Seconds per bulk step for an algorithmic mode (class id < NUM_MODES)."""
    if mode not in _DELETE_COSTS:
        raise ValueError(f"no cost for mode {mode}")
    return _insert_cost(w, hw, g) + _DELETE_COSTS[mode](w, hw, g)


def throughput(mode: int, w: Workload, hw=TPU_V5E, g=MeshGeom()) -> float:
    """*Effective* ops/second — the paper's metric, with relaxed-mode
    throughput discounted by the wasted-work fraction (see module doc)."""
    t = schedule_cost(mode, w, hw, g)
    total_ops = w.num_clients * w.ops_per_client
    raw = total_ops / max(t, 1e-12)
    if mode in _RELAXED_MODES:
        raw *= 1.0 - _waste_fraction(w, hw, mode)
    return raw


def mode_throughputs(
    w: Workload, hw: HardwareModel = TPU_V5E, g: MeshGeom = MeshGeom()
) -> tuple:
    """Effective throughput of every algorithmic mode, indexed by class id."""
    return tuple(throughput(m, w, hw, g) for m in range(NUM_MODES))


def best_mode(
    w: Workload,
    hw: HardwareModel = TPU_V5E,
    g: MeshGeom = MeshGeom(),
    neutral_band: float = 0.07,
) -> int:
    """Label: argmax-throughput mode, or NEUTRAL when the runner-up is inside
    the tie band.  The paper uses an absolute 1.5 Mops/s band (§3.1.2 (4)); a
    relative band is the scale-free equivalent for a 512-chip mesh."""
    ts = mode_throughputs(w, hw, g)
    order = sorted(range(NUM_MODES), key=lambda m: ts[m], reverse=True)
    hi, second = ts[order[0]], ts[order[1]]
    if hi <= 0 or (hi - second) / hi < neutral_band:
        return CLASS_NEUTRAL
    return order[0]
