"""Training / test workload generation — §3.1.2 (3) of the paper.

The paper sweeps 5525 training workloads and 10780 random test workloads.
Same scale here: a structured grid for training (so the tree sees the regime
boundaries) and uniform-random tuples for testing (so accuracy is measured
off-grid, like the paper's random test set).

Beyond the grid, `examples_from_trace` converts any `repro.workloads`
operation trace (recorded SSSP/DES op logs, phased/adversarial generator
streams) into labeled examples, and `make_mixed_training_set` folds them
into the grid — so the tree can be trained on the correlated feature paths
real applications walk, not just independent grid points
(`benchmarks/classifier_eval.py` reports accuracy on both distributions).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.classifier.cost_model import (
    MeshGeom,
    TPU_V5E,
    Workload,
    best_mode,
    mode_throughputs,
)
from repro.core.classifier.features import featurize

# Paper-aligned sweep values (§4 uses sizes 1K..8M, ranges 2K..200M,
# threads 1..64; rescaled to a 512-chip fleet).
TRAIN_CLIENTS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 384, 512)
TRAIN_SIZES = (256, 1024, 4096, 16384, 65536, 262144, 1048576, 8388608)
TRAIN_RANGES = (2048, 16384, 131072, 1048576, 16777216, 201326592)
TRAIN_MIXES = (0.0, 0.1, 0.25, 0.4, 0.5, 0.6, 0.75, 0.9, 1.0)
# 11 * 8 * 6 * 9 = 4752 training workloads (paper: 5525).


def make_training_set(
    hw=TPU_V5E, geom: MeshGeom = MeshGeom()
) -> Tuple[np.ndarray, np.ndarray]:
    feats, labels = [], []
    for d in TRAIN_CLIENTS:
        for z in TRAIN_SIZES:
            for k in TRAIN_RANGES:
                for p in TRAIN_MIXES:
                    w = Workload(d, z, k, p)
                    feats.append(featurize(d, z, k, p))
                    labels.append(best_mode(w, hw, geom))
    return np.stack(feats), np.asarray(labels, np.int32)


def examples_from_trace(
    trace, window: int = 8, hw=TPU_V5E, geom: MeshGeom = MeshGeom()
) -> Tuple[np.ndarray, np.ndarray]:
    """Labeled classifier examples from a recorded/generated op trace.

    Walks the trace in decision-interval-sized windows, deriving the
    Table-1 feature tuple the on-device featurizer would see — active
    clients from the trace, queue size from the running insert/delete
    balance (clamped at empty, like the real queue), per-window insert
    fraction and key spread — and labels each window with the cost model's
    `best_mode`.  This is how application-shaped distributions (bursty
    phases, drifting mixes, SSSP/DES op logs) enter the training set: same
    analytic ground truth as the grid, feature vectors from real streams.
    """
    from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT
    from repro.core.pqueue.state import INF_KEY

    ops, keys, nc = trace.ops, trace.keys, trace.num_clients
    K = ops.shape[0]
    feats, labels = [], []
    # recorded traces carry their driver's pre-fill; it is the standing
    # backlog every window's Size feature rides on
    size = int(np.sum(trace.init_keys < INF_KEY)) if trace.init_keys.size \
        else 0
    for lo in range(0, K, window):
        hi = min(lo + window, K)
        o, k = ops[lo:hi], keys[lo:hi]
        ins = (o == OP_INSERT) & (k < INF_KEY)
        n_ins = int(np.sum(ins))
        n_del = int(np.sum(o == OP_DELETE_MIN))
        size = max(size + n_ins - n_del, 0)
        frac = n_ins / max(n_ins + n_del, 1)
        ik = k[ins]
        key_range = int(ik.max()) - int(ik.min()) + 1 if ik.size else 1
        d = max(int(round(float(np.mean(nc[lo:hi])))), 1)
        w = Workload(d, max(size, 1), max(key_range, 1), frac)
        feats.append(featurize(d, max(size, 1), max(key_range, 1), frac))
        labels.append(best_mode(w, hw, geom))
    return np.stack(feats), np.asarray(labels, np.int32)


def _standard_traces(seeds: Tuple[int, ...]):
    """The generator slice of `repro.workloads` (host-synthesized phased /
    adversarial streams — no driver execution, so building the training
    set stays cheap).  Imported lazily: workloads sits above the classifier
    in the layering."""
    from repro.workloads import traces as T

    for seed in seeds:
        yield T.phase_flip_trace(seed=seed)
        yield T.size_ramp_trace(seed=seed)
        yield T.mix_drift_trace(seed=seed)
        yield T.bursty_des_trace(seed=seed)


def make_trace_training_set(
    seeds: Tuple[int, ...] = (0, 1, 2, 3, 4, 5), window: int = 4,
    hw=TPU_V5E, geom: MeshGeom = MeshGeom(),
) -> Tuple[np.ndarray, np.ndarray]:
    """Application-shaped examples from the standard workload generators."""
    xs, ys = [], []
    for trace in _standard_traces(seeds):
        X, y = examples_from_trace(trace, window=window, hw=hw, geom=geom)
        xs.append(X)
        ys.append(y)
    return np.concatenate(xs), np.concatenate(ys)


def make_trace_test_set(
    seeds: Tuple[int, ...] = (101, 102, 103), window: int = 4,
    hw=TPU_V5E, geom: MeshGeom = MeshGeom(),
) -> Tuple[np.ndarray, np.ndarray]:
    """Held-out trace examples (disjoint generator seeds) — the
    application-distribution analogue of `make_test_set`."""
    return make_trace_training_set(seeds=seeds, window=window, hw=hw,
                                   geom=geom)


def make_mixed_training_set(
    hw=TPU_V5E, geom: MeshGeom = MeshGeom(),
) -> Tuple[np.ndarray, np.ndarray]:
    """Analytic grid + trace-derived examples: the regime boundaries of
    the grid plus the correlated feature paths real applications walk."""
    Xg, yg = make_training_set(hw=hw, geom=geom)
    Xt, yt = make_trace_training_set(hw=hw, geom=geom)
    return np.concatenate([Xg, Xt]), np.concatenate([yg, yt])


def make_test_set(
    n: int = 10780, seed: int = 7, hw=TPU_V5E, geom: MeshGeom = MeshGeom()
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Random off-grid workloads (paper §4.2.1: 10780).  Returns
    (features, labels, misprediction_cost_basis) where the basis row i holds
    the effective throughput of EVERY algorithmic mode (indexed by class id)
    for computing the paper's misprediction-cost metric ((X - Y)/Y)."""
    rng = np.random.default_rng(seed)
    feats, labels, basis = [], [], []
    for _ in range(n):
        d = int(rng.integers(1, geom.chips + 1))
        z = int(2 ** rng.uniform(6, 24))
        k = int(2 ** rng.uniform(8, 28))
        p = float(rng.uniform(0, 1))
        w = Workload(d, z, k, p)
        feats.append(featurize(d, z, k, p))
        labels.append(best_mode(w, hw, geom))
        basis.append(mode_throughputs(w, hw, geom))
    return np.stack(feats), np.asarray(labels, np.int32), np.asarray(basis)
