"""Training / test workload generation — §3.1.2 (3) of the paper.

The paper sweeps 5525 training workloads and 10780 random test workloads.
Same scale here: a structured grid for training (so the tree sees the regime
boundaries) and uniform-random tuples for testing (so accuracy is measured
off-grid, like the paper's random test set).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.classifier.cost_model import (
    MeshGeom,
    TPU_V5E,
    Workload,
    best_mode,
    mode_throughputs,
)
from repro.core.classifier.features import featurize

# Paper-aligned sweep values (§4 uses sizes 1K..8M, ranges 2K..200M,
# threads 1..64; rescaled to a 512-chip fleet).
TRAIN_CLIENTS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 384, 512)
TRAIN_SIZES = (256, 1024, 4096, 16384, 65536, 262144, 1048576, 8388608)
TRAIN_RANGES = (2048, 16384, 131072, 1048576, 16777216, 201326592)
TRAIN_MIXES = (0.0, 0.1, 0.25, 0.4, 0.5, 0.6, 0.75, 0.9, 1.0)
# 11 * 8 * 6 * 9 = 4752 training workloads (paper: 5525).


def make_training_set(
    hw=TPU_V5E, geom: MeshGeom = MeshGeom()
) -> Tuple[np.ndarray, np.ndarray]:
    feats, labels = [], []
    for d in TRAIN_CLIENTS:
        for z in TRAIN_SIZES:
            for k in TRAIN_RANGES:
                for p in TRAIN_MIXES:
                    w = Workload(d, z, k, p)
                    feats.append(featurize(d, z, k, p))
                    labels.append(best_mode(w, hw, geom))
    return np.stack(feats), np.asarray(labels, np.int32)


def make_test_set(
    n: int = 10780, seed: int = 7, hw=TPU_V5E, geom: MeshGeom = MeshGeom()
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Random off-grid workloads (paper §4.2.1: 10780).  Returns
    (features, labels, misprediction_cost_basis) where the basis row i holds
    the effective throughput of EVERY algorithmic mode (indexed by class id)
    for computing the paper's misprediction-cost metric ((X - Y)/Y)."""
    rng = np.random.default_rng(seed)
    feats, labels, basis = [], [], []
    for _ in range(n):
        d = int(rng.integers(1, geom.chips + 1))
        z = int(2 ** rng.uniform(6, 24))
        k = int(2 ** rng.uniform(8, 28))
        p = float(rng.uniform(0, 1))
        w = Workload(d, z, k, p)
        feats.append(featurize(d, z, k, p))
        labels.append(best_mode(w, hw, geom))
        basis.append(mode_throughputs(w, hw, geom))
    return np.stack(feats), np.asarray(labels, np.int32), np.asarray(basis)
