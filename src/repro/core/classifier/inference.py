"""Branchless on-device decision-tree inference.

The paper calls its (host-side) tree every second; traversal costs 2-4 ms.
Here the tree is packed into flat arrays and evaluated *inside* the jitted
step as `max_depth` gathers — no host round-trip, so SmartPQ's decision runs
at step frequency for free and the mode flip feeds `lax.switch` directly
(DESIGN.md §3).  Cost on TPU: 8 scalar gathers ≈ nanoseconds.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.classifier.tree import DecisionTree


class PackedTree(NamedTuple):
    feature: jnp.ndarray  # (N,) int32, -1 for leaves
    threshold: jnp.ndarray  # (N,) float32
    left: jnp.ndarray  # (N,) int32 (self-loop for leaves)
    right: jnp.ndarray  # (N,) int32
    label: jnp.ndarray  # (N,) int32
    depth: int


def pack_tree(tree: DecisionTree) -> PackedTree:
    n = tree.num_nodes
    feature = np.full(n, -1, np.int32)
    threshold = np.zeros(n, np.float32)
    left = np.arange(n, dtype=np.int32)  # leaves self-loop
    right = np.arange(n, dtype=np.int32)
    label = np.zeros(n, np.int32)
    for i, node in enumerate(tree.nodes):
        label[i] = node.label
        if node.feature >= 0:
            feature[i] = node.feature
            threshold[i] = node.threshold
            left[i] = node.left
            right[i] = node.right
    return PackedTree(
        feature=jnp.asarray(feature),
        threshold=jnp.asarray(threshold),
        left=jnp.asarray(left),
        right=jnp.asarray(right),
        label=jnp.asarray(label),
        depth=tree.max_depth,
    )


def tree_predict(packed: PackedTree, features: jnp.ndarray) -> jnp.ndarray:
    """features: (F,) float32 -> () int32 class.  Fixed `depth` iterations of
    gather-compare-select; leaves self-loop so early arrival is harmless.

    This is the whole device-side inference path: SmartPQ evaluates it
    inside the jitted step — and, fused-window form, inside every iteration
    of the `run_window` lax.scan — so mode decisions happen mid-window
    without leaving the device (`predict_mode_host` survives only as an
    offline/debug entry point)."""
    node = jnp.int32(0)
    for _ in range(packed.depth):
        f = packed.feature[node]
        thr = packed.threshold[node]
        x = features[jnp.maximum(f, 0)]
        go_left = x <= thr
        nxt = jnp.where(go_left, packed.left[node], packed.right[node])
        node = jnp.where(f >= 0, nxt, node)
    return packed.label[node]


def tree_predict_batch(packed: PackedTree, features: jnp.ndarray) -> jnp.ndarray:
    """Vectorized inference: (N, F) float32 -> (N,) int32 classes.  Used by
    offline evaluation sweeps and window-level decision traces; the in-step
    path stays scalar (one decision per step)."""
    import jax

    return jax.vmap(lambda f: tree_predict(packed, f))(features)
