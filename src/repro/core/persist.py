"""repro.core.persist — atomic, crash-consistent persistence primitives.

Every on-disk artifact the stack commits to (training checkpoints, serving
snapshots, traces, bench histories) goes through this module, so the
crash-consistency rules live in exactly one place:

  1. WRITE-NEW, NEVER IN-PLACE: content lands in a temp file/dir in the
     SAME directory as the destination (same filesystem, so the final
     ``os.replace``/``rename`` is atomic), is fsynced, then renamed over
     the destination.  A crash at any point leaves either the old artifact
     or the new one — never a torn hybrid (contrast: a crash inside
     ``np.savez`` produces exactly the truncated npz
     `faults.corrupt_trace_npz` simulates).
  2. MANIFEST LAST: multi-file artifacts (pytree snapshots) write their
     payload shards first and the manifest — which carries a CRC32 per
     shard — last, inside the temp dir; the rename publishes all of it at
     once, and the ``LATEST`` pointer flips only after the directory is
     durable.
  3. VALIDATE ON LOAD: `validate_step` re-checks manifest/shard
     consistency (missing shard, truncated shard, CRC mismatch, stale
     manifest naming files that do not exist) and raises a typed
     `SnapshotCorruptError` — a half-loaded snapshot is never returned.
     `newest_valid_step` walks steps newest-first and skips corrupt ones,
     which is the serving tier's recovery rule: load the newest snapshot
     that VALIDATES, not the newest directory that exists.

`train/checkpoint.py` (1-GiB-sharded training checkpoints with elastic
resharding) and `serve/durability.py` (scheduler/engine snapshots under
the write-ahead log) are both thin layers over `save_tree`/`load_tree`.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.errors import SnapshotCorruptError

SHARD_BYTES = 1 << 30  # 1 GiB per npz shard (train checkpoint default)


# ---------------------------------------------------------------------------
# single-file atomic writes
# ---------------------------------------------------------------------------


def fsync_file(path: Path | str) -> None:
    fd = os.open(str(path), os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: Path | str) -> None:
    """Durably record a directory entry (the rename itself) — without this
    the atomic replace can be undone by a crash even though the file data
    survived."""
    fd = os.open(str(path), os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: Path | str, blob: bytes, *,
                       fsync: bool = True) -> Path:
    """tmp + fsync + os.replace: the destination is either the old content
    or the complete new content, never a truncated mix."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        prefix=f".{path.name}.", suffix=".tmp", dir=path.parent
    )
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
            f.flush()
            if fsync:
                os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    if fsync:
        fsync_dir(path.parent)
    return path


def atomic_write_text(path: Path | str, text: str, *,
                      fsync: bool = True) -> Path:
    return atomic_write_bytes(path, text.encode("utf-8"), fsync=fsync)


def atomic_write_json(path: Path | str, obj: Any, *, fsync: bool = True,
                      indent: Optional[int] = None) -> Path:
    return atomic_write_text(
        path, json.dumps(obj, indent=indent) + "\n", fsync=fsync
    )


def atomic_savez(path: Path | str, *, compressed: bool = False,
                 fsync: bool = True, **arrays: np.ndarray) -> Path:
    """Atomic `np.savez[_compressed]`.  Mirrors numpy's name handling (a
    missing ``.npz`` suffix is appended) so callers can swap it in for
    `np.savez` without changing the paths they later `np.load`."""
    path = Path(path)
    if path.suffix != ".npz":
        path = path.with_name(path.name + ".npz")
    import io

    buf = io.BytesIO()
    (np.savez_compressed if compressed else np.savez)(buf, **arrays)
    return atomic_write_bytes(path, buf.getvalue(), fsync=fsync)


# ---------------------------------------------------------------------------
# manifest-directory pytree snapshots (generalized from train/checkpoint.py)
# ---------------------------------------------------------------------------


def flatten_with_paths(tree) -> Tuple[List[str], list, Any]:
    # jax.tree.flatten_with_path is a late alias of
    # jax.tree_util.tree_flatten_with_path — use the long-lived spelling.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _host_leaf(x) -> Tuple[np.ndarray, str]:
    """(storable array, original dtype tag).  npz can't serialize ml_dtypes
    (bf16 etc.) — store as f32 + dtype tag; load casts back."""
    arr = np.asarray(x)
    tag = str(arr.dtype)
    if arr.dtype.kind not in "fiub" or tag == "bfloat16":
        arr = arr.astype(np.float32)
    return arr, tag


def step_dir(root: Path | str, step: int, prefix: str = "step") -> Path:
    return Path(root) / f"{prefix}_{step}"


def save_tree(
    root: Path | str,
    step: int,
    tree: Any,
    *,
    extra: Optional[Dict[str, Any]] = None,
    fsync: bool = True,
    prefix: str = "step",
    shard_bytes: int = SHARD_BYTES,
) -> Path:
    """Write ``<root>/<prefix>_<step>/`` (shards + manifest) atomically and
    flip ``<root>/LATEST`` to it.  `extra` is an arbitrary JSON-able dict
    stored inside the manifest — the serving snapshot keeps its host-side
    scheduler/engine state there, next to the array shards."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    paths, leaves, _ = flatten_with_paths(tree)
    host_leaves, dtypes = [], []
    for x in leaves:
        arr, tag = _host_leaf(x)
        host_leaves.append(arr)
        dtypes.append(tag)

    tmp = root / f".tmp_{prefix}_{step}_{os.getpid()}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    shards, cur, cur_bytes, idx = [], {}, 0, {}
    for name, arr in zip(paths, host_leaves):
        key = f"leaf_{len(cur)}"
        cur[key] = arr
        idx[name] = (len(shards), key)
        cur_bytes += arr.nbytes
        if cur_bytes >= shard_bytes:
            shards.append(cur)
            cur, cur_bytes = {}, 0
    shards.append(cur)
    shard_crc = []
    for i, sh in enumerate(shards):
        p = tmp / f"shard_{i}.npz"
        np.savez(p, **sh)
        shard_crc.append(zlib.crc32(p.read_bytes()) & 0xFFFFFFFF)
        if fsync:
            fsync_file(p)
    manifest = {
        "step": step,
        "leaves": {n: list(v) for n, v in idx.items()},
        "dtypes": dict(zip(paths, dtypes)),
        "n_shards": len(shards),
        "shard_crc": shard_crc,
        "extra": extra if extra is not None else {},
        "time": time.time(),
    }
    mpath = tmp / "manifest.json"
    mpath.write_text(json.dumps(manifest))
    if fsync:
        fsync_file(mpath)
    final = step_dir(root, step, prefix)
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    if fsync:
        fsync_dir(root)
    atomic_write_text(root / "LATEST", final.name, fsync=fsync)
    return final


def latest_step(root: Path | str, prefix: str = "step") -> Optional[int]:
    """The step the LATEST pointer names — without validating it (use
    `newest_valid_step` when the directory may have been damaged)."""
    p = Path(root) / "LATEST"
    if not p.exists():
        return None
    name = p.read_text().strip()
    try:
        return int(name.rsplit("_", 1)[1])
    except (IndexError, ValueError):
        return None


def available_steps(root: Path | str, prefix: str = "step") -> List[int]:
    """All on-disk step numbers under root, descending (newest first)."""
    root = Path(root)
    if not root.exists():
        return []
    out = []
    for d in root.iterdir():
        if d.is_dir() and d.name.startswith(f"{prefix}_"):
            try:
                out.append(int(d.name.rsplit("_", 1)[1]))
            except ValueError:
                continue
    return sorted(out, reverse=True)


def validate_step(root: Path | str, step: int,
                  prefix: str = "step") -> Dict[str, Any]:
    """Check one snapshot directory end to end; return its manifest or
    raise `SnapshotCorruptError` (missing/unparseable manifest, missing
    shard, shard CRC mismatch, manifest naming leaves its shards lack)."""
    d = step_dir(root, step, prefix)
    mpath = d / "manifest.json"
    if not mpath.exists():
        raise SnapshotCorruptError("manifest.json missing", path=str(d))
    try:
        manifest = json.loads(mpath.read_text())
    except (ValueError, OSError) as e:
        raise SnapshotCorruptError(
            f"unreadable manifest ({e})", path=str(d)
        ) from e
    n = manifest.get("n_shards")
    crcs = manifest.get("shard_crc")
    if not isinstance(n, int) or n < 1:
        raise SnapshotCorruptError("manifest lacks n_shards", path=str(d))
    for i in range(n):
        p = d / f"shard_{i}.npz"
        if not p.exists():
            raise SnapshotCorruptError(
                f"shard_{i}.npz missing", path=str(d)
            )
        if crcs is not None:
            got = zlib.crc32(p.read_bytes()) & 0xFFFFFFFF
            if got != crcs[i]:
                raise SnapshotCorruptError(
                    f"shard_{i}.npz CRC mismatch "
                    f"(manifest {crcs[i]:#x}, file {got:#x})",
                    path=str(d),
                )
    for name, (shard_i, _key) in manifest.get("leaves", {}).items():
        if not isinstance(shard_i, int) or shard_i >= n:
            raise SnapshotCorruptError(
                f"stale manifest: leaf {name!r} names shard {shard_i} "
                f"of {n}", path=str(d),
            )
    return manifest


def newest_valid_step(root: Path | str,
                      prefix: str = "step") -> Optional[int]:
    """Newest step that VALIDATES: tries the LATEST pointer first, then
    every on-disk step newest-first, skipping corrupt ones.  None when no
    valid snapshot exists (recovery then starts from a fresh init)."""
    candidates = available_steps(root, prefix)
    pointed = latest_step(root, prefix)
    if pointed is not None and pointed in candidates:
        candidates.remove(pointed)
        candidates.insert(0, pointed)
    elif pointed is not None:
        # stale LATEST: points at a step that is not on disk — fall
        # through to the scan
        pass
    for step in candidates:
        try:
            validate_step(root, step, prefix)
            return step
        except SnapshotCorruptError:
            continue
    return None


def load_tree(
    root: Path | str,
    like: Any,
    step: Optional[int] = None,
    *,
    prefix: str = "step",
    place: Optional[Callable[[int, np.ndarray, Any], Any]] = None,
    validate: bool = True,
) -> Tuple[Any, Dict[str, Any]]:
    """Load a snapshot into the structure of `like` (pytree of arrays or
    ShapeDtypeStructs); returns ``(tree, manifest)``.  `place(i, arr,
    leaf)` maps each loaded numpy leaf onto its device/dtype target — the
    default casts to the `like` leaf's dtype and wraps in `jnp.asarray`
    (train/checkpoint.py passes a sharding-aware placer)."""
    root = Path(root)
    if step is None:
        step = latest_step(root, prefix)
        if step is None:
            raise FileNotFoundError(f"no LATEST in {root}")
    manifest = (
        validate_step(root, step, prefix) if validate
        else json.loads((step_dir(root, step, prefix)
                         / "manifest.json").read_text())
    )
    d = step_dir(root, step, prefix)
    shard_cache: Dict[int, Any] = {}

    paths, leaves, treedef = flatten_with_paths(like)
    out = []
    for i, (name, leaf) in enumerate(zip(paths, leaves)):
        if name not in manifest["leaves"]:
            raise SnapshotCorruptError(
                f"manifest lacks leaf {name!r}", path=str(d)
            )
        shard_i, key = manifest["leaves"][name]
        if shard_i not in shard_cache:
            try:
                shard_cache[shard_i] = np.load(d / f"shard_{shard_i}.npz")
            except Exception as e:
                raise SnapshotCorruptError(
                    f"unreadable shard_{shard_i}.npz "
                    f"({type(e).__name__}: {e})", path=str(d),
                ) from e
        arr = shard_cache[shard_i][key]
        if place is not None:
            out.append(place(i, arr, leaf))
        else:
            if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
                arr = arr.astype(leaf.dtype)
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest


def prune_steps(root: Path | str, keep: int, prefix: str = "step") -> int:
    """Delete all but the newest `keep` snapshot dirs; returns the number
    removed.  Never removes the step LATEST points at."""
    steps = available_steps(root, prefix)
    pointed = latest_step(root, prefix)
    removed = 0
    for step in steps[max(keep, 1):]:
        if step == pointed:
            continue
        shutil.rmtree(step_dir(root, step, prefix), ignore_errors=True)
        removed += 1
    return removed


__all__ = [
    "SHARD_BYTES",
    "fsync_file", "fsync_dir",
    "atomic_write_bytes", "atomic_write_text", "atomic_write_json",
    "atomic_savez",
    "flatten_with_paths", "step_dir", "save_tree", "load_tree",
    "latest_step", "available_steps", "newest_valid_step",
    "validate_step", "prune_steps",
]
