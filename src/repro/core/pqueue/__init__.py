from repro.core.pqueue.state import PQState, make_state, INF_KEY  # noqa: F401
from repro.core.pqueue.ops import (  # noqa: F401
    Schedule,
    insert,
    delete_min,
    peek_min,
    apply_op_batch,
)
