"""Distributed PQ backend — the schedules as real collectives under shard_map.

The single-controller functions in `schedules.py` define the semantics; this
module emits the actual communication patterns on a device mesh, which is
what the roofline analysis and the dry-run measure:

  STRICT_FLAT : one all_gather of every shard's candidate run over ALL mesh
                axes (pod axis included — candidates cross the slow tier).
  HIER        : all_gather over intra-pod axes only, replicated pod-local
                select, then a second all_gather over the POD AXIS ONLY of
                the compact pod-winner frame (the Nuddle request/response
                frames), final replicated select.
  FFWD        : log2(n)-step ppermute tree funnel of candidate frames into
                device 0 (the single server), then a reverse-tree broadcast
                of the verdict.
  SPRAY       : no collectives; each client pops from its own local shards
                (hash placement makes local pops a uniform sample of the
                global population — the SprayList random-walk analogue).
  MULTIQ      : no collectives; each device runs the two-choice MultiQueue
                schedule over its own local shards (the sub-queues).  Hash
                placement again makes the device-local sub-queue population
                a uniform sample, so the global rank-error envelope is the
                local one scaled by the device count.

All schedules mutate the SAME device-local state layout `(S_loc, C)` so a
mode switch never moves queue data (the paper's zero-sync-transition
property, now at mesh scale).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue import local as L
from repro.core.pqueue.partition import route_dense
from repro.core.pqueue.schedules import Schedule, ensure_head
from repro.core.pqueue.state import INF_KEY, PQState
from repro.utils.hashing import shard_of_key


@dataclasses.dataclass(frozen=True)
class AxisCfg:
    """Mesh-axis roles for the queue.

    shard_axes: intra-pod axes the shards are distributed over (fast tier).
    pod_axis:   the slow-tier axis (None => single pod; HIER degrades to a
                single-phase gather, matching the paper's observation that
                NUMA-aware == NUMA-oblivious on one socket).
    """

    shard_axes: Tuple[str, ...]
    pod_axis: str | None = None

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return ((self.pod_axis,) if self.pod_axis else ()) + tuple(self.shard_axes)


def _one_axis_size(a: str) -> int:
    # jax.lax.axis_size is a late addition; psum of the literal 1 is the
    # long-lived spelling and folds to a static Python int inside shard_map.
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(a)
    return jax.lax.psum(1, a)


def _axis_size(axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= _one_axis_size(a)
    return n


def _device_rank(axes: Sequence[str]) -> jnp.ndarray:
    """Row-major rank over the given axes."""
    rank = jnp.int32(0)
    for a in axes:
        rank = rank * _one_axis_size(a) + jax.lax.axis_index(a)
    return rank


# ---------------------------------------------------------------------------
# insert: hash-route over the full mesh (identical in both modes)
# ---------------------------------------------------------------------------


def insert_dist(
    state: PQState,
    keys: jnp.ndarray,  # (B_loc,) this device's insert requests
    vals: jnp.ndarray,
    mask: jnp.ndarray,  # (B_loc,) valid
    cfg: AxisCfg,
    capacity_factor: float = 2.0,
) -> Tuple[PQState, jnp.ndarray, jnp.ndarray]:
    """Returns (state, dropped_per_local_shard, rejected mask (B_loc,)).

    Rejected ops (per-destination overflow of the all_to_all frame) are the
    caller's to retry — the serving scheduler re-enqueues them next step.
    """
    B = keys.shape[0]
    axes = cfg.all_axes
    n_dev = _axis_size(axes)
    S_loc, C = state.num_shards, state.capacity
    S_total = n_dev * S_loc

    gshard = shard_of_key(keys, S_total)
    dest_dev = gshard // S_loc
    dest_dev = jnp.where(mask, dest_dev, n_dev)

    # (n_dev, cap) send frame, MoE-dispatch style.
    cap = max(1, min(B, int(-(-B * capacity_factor // n_dev))))
    hit = dest_dev[None, :] == jnp.arange(n_dev, dtype=jnp.int32)[:, None]
    pos = jnp.cumsum(hit, axis=1) - 1
    pos_of = jnp.sum(jnp.where(hit, pos, 0), axis=0)
    keep = mask & (pos_of < cap)
    rejected = mask & ~keep

    send_k = jnp.full((n_dev, cap), INF_KEY, jnp.int32)
    send_v = jnp.zeros((n_dev, cap), jnp.int32)
    d = jnp.where(keep, dest_dev, n_dev)
    p = jnp.where(keep, pos_of, 0)
    send_k = send_k.at[d, p].set(jnp.where(keep, keys, INF_KEY), mode="drop")
    send_v = send_v.at[d, p].set(jnp.where(keep, vals, 0), mode="drop")

    recv_k = jax.lax.all_to_all(send_k, axes, split_axis=0, concat_axis=0, tiled=True)
    recv_v = jax.lax.all_to_all(send_v, axes, split_axis=0, concat_axis=0, tiled=True)

    flat_k, flat_v = recv_k.reshape(-1), recv_v.reshape(-1)
    # Local sub-shard routing + tiered head/tail insert (windowed-merge
    # Pallas kernel on TPU).
    rk, rv, counts = route_dense(flat_k, flat_v, flat_k < INF_KEY, S_loc)
    new_state, dropped = L.tiered_insert(state, rk, rv, counts)
    return new_state, dropped, rejected


# ---------------------------------------------------------------------------
# deleteMin schedules
# ---------------------------------------------------------------------------


def _local_candidates(state: PQState, m: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """This device's m smallest across its local shards (ascending run) —
    head prefixes only; callers ensure_head first."""
    ck = state.head_keys[:, :m].ravel()
    cv = state.head_vals[:, :m].ravel()
    return L.topk_of_merged(ck, cv, m)


def _take_from_gathered(
    gk: jnp.ndarray,  # (n_frames, m) gathered candidate runs (ascending each)
    my_frame: jnp.ndarray,  # () index of this device's frame
    my_run: jnp.ndarray,  # (m,) this device's run
    n: jnp.ndarray,  # () winners to remove globally
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Given all frames, return (winners_k, winners_v_order, my_take):
    my_take = how many of this device's candidates won (always a prefix)."""
    flat = gk.reshape(-1)
    order = jnp.argsort(flat, stable=True)  # ties: lower frame id wins
    win_k = flat[order[: my_run.shape[0]]]
    cutoff = win_k[jnp.maximum(n - 1, 0)]
    below = jnp.sum(my_run < cutoff)
    at_mine = jnp.sum(my_run == cutoff)
    # Prefix allocation of tie slots by frame id (matches argsort stability).
    at_per_frame = jnp.sum(gk == cutoff, axis=1)  # (n_frames,)
    below_total = jnp.sum(flat < cutoff)
    remaining = n - below_total
    tie_prefix = jnp.cumsum(at_per_frame) - at_per_frame
    tie_take = jnp.clip(remaining - tie_prefix[my_frame], 0, at_mine)
    take = jnp.where(n > 0, below + tie_take, 0).astype(jnp.int32)
    return win_k, order, take


def _apply_take(state: PQState, my_take: jnp.ndarray, m: int) -> PQState:
    """Remove `my_take` smallest elements from this device's shards — they
    are exactly the first my_take entries of the device-local candidate
    order, i.e. prefixes of each local shard determined by a second local
    tournament-threshold computation."""
    ck = state.head_keys[:, :m]  # (S_loc, m)
    flat = ck.ravel()
    kth = jnp.sort(flat)[jnp.maximum(my_take - 1, 0)]
    below = jnp.sum(ck < kth, axis=1).astype(jnp.int32)
    at = jnp.sum(ck == kth, axis=1).astype(jnp.int32)
    rem = my_take - jnp.sum(below)
    tie_prefix = jnp.cumsum(at) - at
    tie_take = jnp.clip(rem - tie_prefix, 0, at).astype(jnp.int32)
    take = jnp.where(my_take > 0, below + tie_take, 0)
    nk, nv, nq, ns = L.remove_prefix(
        state.head_keys, state.head_vals, state.head_seq, state.head_size,
        take,
    )
    return dataclasses.replace(
        state, head_keys=nk, head_vals=nv, head_seq=nq, head_size=ns
    )


def delete_flat_dist(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, cfg: AxisCfg
) -> Tuple[PQState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """lotan_shavit: single global gather over every axis (pod included)."""
    state = ensure_head(state, m)
    axes = cfg.all_axes
    run_k, run_v = _local_candidates(state, m)
    gk = jax.lax.all_gather(run_k, axes, tiled=False).reshape(-1, m)
    gv = jax.lax.all_gather(run_v, axes, tiled=False).reshape(-1, m)
    total = jax.lax.psum(state.total_size, axes)
    n = jnp.minimum(active, total).astype(jnp.int32)

    me = _device_rank(axes)
    win_k, order, take = _take_from_gathered(gk, me, run_k, n)
    win_v = gv.reshape(-1)[order[:m]]
    state = _apply_take(state, take, m)
    lane = jnp.arange(m, dtype=jnp.int32)
    return (
        state,
        jnp.where(lane < n, win_k, INF_KEY),
        jnp.where(lane < n, win_v, 0),
        n,
    )


def delete_hier_dist(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, cfg: AxisCfg
) -> Tuple[PQState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Nuddle: intra-pod semifinal on ICI, pod-axis final on the slow tier."""
    if cfg.pod_axis is None:
        return delete_flat_dist(state, m, active, rng, cfg)

    state = ensure_head(state, m)
    run_k, run_v = _local_candidates(state, m)
    # Phase 1: gather within the pod (fast tier), pod-local select.
    pk = jax.lax.all_gather(run_k, cfg.shard_axes, tiled=False).reshape(-1, m)
    pv = jax.lax.all_gather(run_v, cfg.shard_axes, tiled=False).reshape(-1, m)
    pod_k, pod_v = L.topk_of_merged(pk.reshape(-1), pv.reshape(-1), m)

    # Phase 2: ONLY the compact pod-winner frame crosses the pod axis.
    gk = jax.lax.all_gather(pod_k, cfg.pod_axis, tiled=False)  # (npods, m)
    gv = jax.lax.all_gather(pod_v, cfg.pod_axis, tiled=False)
    total = jax.lax.psum(state.total_size, cfg.all_axes)
    n = jnp.minimum(active, total).astype(jnp.int32)

    flat = gk.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    win_k = flat[order[:m]]
    win_v = gv.reshape(-1)[order[:m]]

    # Commit: per-device take derives from the GLOBAL cutoff applied to the
    # device's own candidates, with tie slots allocated by global shard order
    # (device rank over all axes, then local position) — identical resolution
    # to the flat schedule, so HIER == FLAT result-wise (tested).
    cutoff = win_k[jnp.maximum(n - 1, 0)]
    my_below = jnp.sum(run_k < cutoff)
    my_at = jnp.sum(run_k == cutoff)
    at_all = jax.lax.all_gather(my_at, cfg.all_axes, tiled=False)  # (n_dev,)
    below_all = jax.lax.psum(my_below, cfg.all_axes)
    remaining = n - below_all
    me = _device_rank(cfg.all_axes)
    tie_prefix = jnp.cumsum(at_all) - at_all
    tie_take = jnp.clip(remaining - tie_prefix[me], 0, my_at)
    take = jnp.where(n > 0, my_below + tie_take, 0).astype(jnp.int32)

    state = _apply_take(state, take, m)
    lane = jnp.arange(m, dtype=jnp.int32)
    return (
        state,
        jnp.where(lane < n, win_k, INF_KEY),
        jnp.where(lane < n, win_v, 0),
        n,
    )


def delete_ffwd_dist(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, cfg: AxisCfg
) -> Tuple[PQState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """ffwd: tree-funnel candidate frames into device 0 (the single server),
    which resolves the tournament; verdict broadcast back down the tree.
    Cost shape: 2*log2(n) ppermute phases, all converging on one device —
    the single-server ceiling of the paper's ffwd baseline."""
    axes = cfg.all_axes
    n_dev = _axis_size(axes)
    assert n_dev & (n_dev - 1) == 0, "ffwd funnel requires power-of-two mesh"
    state = ensure_head(state, m)
    run_k, run_v = _local_candidates(state, m)
    me = _device_rank(axes)

    # Funnel up: at step s, ranks r with r % 2^(s+1) == 2^s send to r - 2^s.
    buf_k, buf_v = run_k, run_v
    steps = n_dev.bit_length() - 1
    flat_axis = axes  # ppermute over the flattened device order
    for s in range(steps):
        stride = 1 << s
        perm = [(r + stride, r) for r in range(0, n_dev, 2 * stride)]
        rk = _ppermute_multi(buf_k, flat_axis, perm, n_dev)
        rv = _ppermute_multi(buf_v, flat_axis, perm, n_dev)
        is_recv = (me % (2 * stride)) == 0
        mk = jnp.where(is_recv, rk, INF_KEY)
        mv = jnp.where(is_recv, rv, 0)
        buf_k, buf_v = L.topk_of_merged(
            jnp.concatenate([buf_k, mk]), jnp.concatenate([buf_v, mv]), m
        )

    total = jax.lax.psum(state.total_size, axes)
    n = jnp.minimum(active, total).astype(jnp.int32)
    # Broadcast verdict down the reversed tree.
    win_k, win_v = buf_k, buf_v
    for s in reversed(range(steps)):
        stride = 1 << s
        perm = [(r, r + stride) for r in range(0, n_dev, 2 * stride)]
        rk = _ppermute_multi(win_k, flat_axis, perm, n_dev)
        rv = _ppermute_multi(win_v, flat_axis, perm, n_dev)
        is_recv = (me % (2 * stride)) == stride
        win_k = jnp.where(is_recv, rk, win_k)
        win_v = jnp.where(is_recv, rv, win_v)

    cutoff = win_k[jnp.maximum(n - 1, 0)]
    my_below = jnp.sum(run_k < cutoff)
    my_at = jnp.sum(run_k == cutoff)
    at_all = jax.lax.all_gather(my_at, axes, tiled=False)
    below_all = jax.lax.psum(my_below, axes)
    tie_prefix = jnp.cumsum(at_all) - at_all
    tie_take = jnp.clip((n - below_all) - tie_prefix[me], 0, my_at)
    take = jnp.where(n > 0, my_below + tie_take, 0).astype(jnp.int32)
    state = _apply_take(state, take, m)
    lane = jnp.arange(m, dtype=jnp.int32)
    return (
        state,
        jnp.where(lane < n, win_k, INF_KEY),
        jnp.where(lane < n, win_v, 0),
        n,
    )


def _ppermute_multi(x, axes, perm, n_dev):
    """collective_permute over the flattened multi-axis device order."""
    return jax.lax.ppermute(x, axes, perm)


def delete_spray_dist(
    state: PQState, m_loc: int, active_loc: jnp.ndarray, rng: jax.Array, cfg: AxisCfg
) -> Tuple[PQState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """SprayList mode: every device serves its local deleters from its own
    shards.  ZERO collectives — this branch's HLO contains no channel ops,
    which is exactly the scaling property the oblivious mode trades quality
    for."""
    from repro.core.pqueue.schedules import delete_spray_herlihy

    res = delete_spray_herlihy(state, m_loc, active_loc, rng, npods=1)
    return res.state, res.keys, res.vals, res.n_out


def delete_multiq_dist(
    state: PQState, m_loc: int, active_loc: jnp.ndarray, rng: jax.Array, cfg: AxisCfg
) -> Tuple[PQState, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """MultiQueue mode: every device serves its local deleters by two-choice
    sampling over its OWN local shards (the sub-queues), consulting the
    per-shard min cache.  Like spray, ZERO collectives in the delete path —
    but the two-choice probe keeps each device's pops within shard-rank <
    m_loc, so the mode keeps a bounded rank error at mesh scale."""
    from repro.core.pqueue.schedules import delete_multiq

    res = delete_multiq(state, m_loc, active_loc, rng, npods=1)
    return res.state, res.keys, res.vals, res.n_out


DIST_SCHEDULE_FNS = {
    Schedule.STRICT_FLAT: delete_flat_dist,
    Schedule.HIER: delete_hier_dist,
    Schedule.FFWD: delete_ffwd_dist,
    Schedule.SPRAY_HERLIHY: delete_spray_dist,
    Schedule.MULTIQ: delete_multiq_dist,
}
