"""Numpy oracle for the sharded bulk priority queue.

Models the exact linearized semantics the JAX implementation promises:
  * insert batch: all valid keys added (minus reported drops)
  * exact deleteMin batch: the n smallest (key, tie by owning shard id, then
    insertion-order-within-shard) removed and returned ascending
  * spray deleteMin batch: any multiset of n keys drawn from the global top
    `spray_bound(S, m)` is admissible — the oracle checks the envelope and
    multiset conservation instead of exact equality.

Used by unit tests, hypothesis properties, and the SSSP example's checker.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.pqueue.schedules import (  # noqa: F401  (re-export)
    multiq_bound,
    spray_bound,
)
from repro.core.pqueue.state import INF_KEY
from repro.utils.hashing import shard_of_key


def _shard_of_key_np(keys: np.ndarray, num_shards: int) -> np.ndarray:
    import jax.numpy as jnp

    return np.asarray(shard_of_key(jnp.asarray(keys, jnp.int32), num_shards))


class RefPQ:
    """Exact reference: a sorted multiset of (key, shard, seq, val)."""

    def __init__(self, num_shards: int, capacity: int):
        self.S = num_shards
        self.C = capacity
        self._items: List[Tuple[int, int, int, int]] = []  # (key, shard, seq, val)
        self._seq_per_shard = [0] * num_shards
        self.total_dropped = 0

    # -- operations ---------------------------------------------------------

    def insert_batch(self, keys, vals, mask=None) -> int:
        keys = np.asarray(keys)
        vals = np.asarray(vals)
        if mask is None:
            mask = keys < INF_KEY
        mask = np.asarray(mask, bool)
        shards = _shard_of_key_np(keys, self.S)
        # Match the JAX merge order: within a batch, routed runs are sorted by
        # key before merging, and ties against existing elements go AFTER the
        # existing ones (stable, side='right' in merge_sorted).  Sequence ids
        # reproduce that: existing elements have lower seq.
        order = np.lexsort((np.arange(len(keys)), keys))
        dropped = 0
        per_shard_count = {s: self.shard_size(s) for s in range(self.S)}
        for i in order:
            if not mask[i]:
                continue
            s = int(shards[i])
            if per_shard_count[s] >= self.C:
                dropped += 1
                continue
            self._items.append(
                (int(keys[i]), s, self._seq_per_shard[s], int(vals[i]))
            )
            self._seq_per_shard[s] += 1
            per_shard_count[s] += 1
        self._items.sort()
        self.total_dropped += dropped
        return dropped

    def delete_min_exact(self, n: int):
        """Remove and return the n globally smallest, ascending.
        Tie-break (key, shard, seq) matches the JAX tournament."""
        n = min(n, len(self._items))
        taken = self._items[:n]
        self._items = self._items[n:]
        return (
            np.array([t[0] for t in taken], np.int64),
            np.array([t[3] for t in taken], np.int64),
        )

    def check_spray_result(self, returned_keys, m: int) -> Tuple[bool, str]:
        """Validate a spray batch AGAINST THE PRE-DELETE STATE.

        Deterministic guarantee of the window policy: every returned key is
        within the first (m + pad) elements OF SOME SHARD, where
        pad = (ilog2(S)+1)^2 — collective-free spray cannot promise a
        deterministic GLOBAL rank (a deleter landing on a large-key shard
        pops that shard's head); the global O(m + S log^2 S) envelope
        (`spray_bound`) holds with high probability over hash placement and
        is validated statistically by `global_envelope_violations`."""
        returned_keys = [int(k) for k in returned_keys if k < INF_KEY]
        if not returned_keys:
            return True, "empty"
        pad = (max(int(self.S - 1).bit_length(), 1) + 1) ** 2
        window = m + pad
        per_shard: dict = {}
        for key, shard, _seq, _v in self._items:
            per_shard.setdefault(shard, []).append(key)
        for s in per_shard:
            per_shard[s].sort()
        for k in returned_keys:
            ranks = [
                keys.index(k) for keys in per_shard.values() if k in keys
            ]
            if not ranks:
                return False, f"key {k} not present pre-delete"
            if min(ranks) >= window:
                return False, (
                    f"key {k} at best shard-rank {min(ranks)} >= window {window}"
                )
        return True, "ok"

    def check_multiq_result(self, returned_keys, m: int) -> Tuple[bool, str]:
        """Validate a MULTIQ batch AGAINST THE PRE-DELETE STATE.

        Deterministic guarantee of two-choice prefix pops: at most m lanes
        commit per step, so every returned key sits within the first m
        entries OF SOME shard — a strictly tighter window than the spray
        check's m + (ilog2(S)+1)^2 (the probabilistic m + O(S log log S)
        GLOBAL envelope, `multiq_bound`, is validated statistically by
        `global_envelope_violations(..., bound=multiq_bound(S, m))`)."""
        returned_keys = [int(k) for k in returned_keys if k < INF_KEY]
        if not returned_keys:
            return True, "empty"
        per_shard: dict = {}
        for key, shard, _seq, _v in self._items:
            per_shard.setdefault(shard, []).append(key)
        for s in per_shard:
            per_shard[s].sort()
        for k in returned_keys:
            ranks = [
                keys.index(k) for keys in per_shard.values() if k in keys
            ]
            if not ranks:
                return False, f"key {k} not present pre-delete"
            if min(ranks) >= m:
                return False, (
                    f"key {k} at best shard-rank {min(ranks)} >= window {m}"
                )
        return True, "ok"

    def global_envelope_violations(
        self, returned_keys, m: int, bound: int | None = None
    ) -> Tuple[int, int]:
        """(violations, total): returned keys beyond the probabilistic
        global top-`bound` envelope (default: spray_bound(S, m); pass
        multiq_bound(S, m) for the MULTIQ schedule)."""
        returned_keys = [int(k) for k in returned_keys if k < INF_KEY]
        if not returned_keys:
            return 0, 0
        if bound is None:
            bound = spray_bound(self.S, m)
        all_keys = sorted(t[0] for t in self._items)
        if len(all_keys) <= bound:
            return 0, len(returned_keys)
        cutoff = all_keys[bound - 1]
        return sum(1 for k in returned_keys if k > cutoff), len(returned_keys)

    def remove_multiset(self, keys) -> bool:
        """Remove an arbitrary returned multiset (for relaxed schedules).
        Returns False if a key wasn't present (conservation violation)."""
        from collections import Counter

        want = Counter(int(k) for k in keys if k < INF_KEY)
        kept = []
        for item in self._items:
            if want.get(item[0], 0) > 0:
                want[item[0]] -= 1
            else:
                kept.append(item)
        if any(v > 0 for v in want.values()):
            return False
        self._items = kept
        return True

    # -- views --------------------------------------------------------------

    def shard_size(self, s: int) -> int:
        return sum(1 for it in self._items if it[1] == s)

    def key_multiset(self) -> np.ndarray:
        return np.array(sorted(t[0] for t in self._items), np.int64)

    def __len__(self) -> int:
        return len(self._items)
