"""Sharded bulk-synchronous priority-queue state — tiered head/tail layout.

The paper's concurrent priority queue holds (key, value) pairs accessed by p
threads.  The TPU adaptation holds the pairs in S shards.  The paper's whole
premise is that contention concentrates at the *head*: deleteMin only ever
touches the highest-priority elements (PAPER §2).  The state layout mirrors
that: each shard is split into

  * a **hot head block** ``(S, H)`` — ascending-sorted, INF-padded, holding
    the shard's smallest ``head_size`` elements.  Every deleteMin schedule
    (candidate windows, spray windows, prefix pops) and every insert merge
    operates on this tier only, so per-step cost scales with the batch /
    head-window size, not with the capacity;
  * a **cold tail arena** ``(S, T)`` with ``T = C - H`` — an *unsorted*
    dense-prefix append region.  Inserts whose key lands beyond the head
    boundary are appended here in O(batch); head-merge overflow (the largest
    elements) spills here.  The tail is only ever scanned by the rare,
    ``lax.cond``-guarded rebalance (refill on head underflow, drop-compaction
    on capacity overflow).

Head sizing rule: ``H`` must cover every schedule's per-step draw window —
``H >= m + (ilog2(S)+1)**2`` (the spray window bound; exact and MULTIQ
schedules need only ``H >= m``, see ``schedules.spray_bound`` /
``schedules.multiq_bound``).  ``make_state`` clamps ``H`` to the capacity, so
small-capacity queues degenerate to the classic single-tier sorted buffer.

The shards remain the unit of placement: mapped onto mesh devices and NEVER
migrated between algorithmic modes — this is what makes SmartPQ's mode
switch a zero-copy predicate flip (paper §3, key idea 3).  ``shard_mins``
(the MultiQueue min cache) is still column 0 of the head, maintained for
free.

Per-shard insertion sequence numbers (``head_seq`` / ``tail_seq`` /
``next_seq``) record the stable linearization order.  The head keeps them
implicitly ordered (stable merges + the strict boundary split guarantee
equal-key head entries are in seq order, and every equal-key tail entry has
a larger seq than any head entry), so the hot path never sorts by seq; the
rare rebalance sorts the tail by ``(key, seq)``, which is exactly what makes
the exact schedules bit-identical to the oracle's (key, shard, seq)
linearization even when elements bounce head -> tail -> head.

Invariants (property-tested in tests/test_pqueue_property.py):
  I1  head_keys[s] is ascending for every shard s
  I2  head_keys[s, head_size[s]:] == INF_KEY and the valid prefix < INF_KEY
  I3  multiset of valid (key, value) pairs is conserved by every op batch
      (inserted - deleted, up to reported drops on capacity overflow)
  I4  head/tail boundary: max(valid head keys) <= min(valid tail keys); for
      equal keys the head holds the smaller sequence numbers
  I5  staging accounting: tail valid entries are exactly the dense prefix
      [0, tail_size), INF beyond; all seq numbers are unique and < next_seq

Known bound: ``next_seq`` is a monotone per-shard int32 counter — after
~2.1e9 cumulative inserts routed to ONE shard it would wrap negative and
break the (key, seq) order (far beyond any current workload: ~500M serving
steps at the benchmark shapes).  A seq renumbering pass in the rebalance is
the designated fix if that horizon ever matters (see ROADMAP).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

# Largest int32. Valid keys must be < INF_KEY. Using the dtype max lets the
# "compact by re-sorting" trick work: removed slots become INF and sort to the
# tail, indistinguishable from padding (by design).
INF_KEY = jnp.iinfo(jnp.int32).max

# Default hot-head width: covers every shipped schedule's per-step window
# (delete batches up to m=192 with the spray pad at S<=64 shards).
DEFAULT_HEAD_WIDTH = 256


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQState:
    """Tiered shard state.

    head_*: (S, H) sorted hot tier; tail_*: (S, T) unsorted cold arena;
    head_size/tail_size: (S,) valid counts; next_seq: (S,) per-shard
    insertion counter (the stable-linearization clock).
    """

    head_keys: jnp.ndarray  # (S, H) int32, ascending, INF-padded
    head_vals: jnp.ndarray  # (S, H) int32 payload
    head_seq: jnp.ndarray  # (S, H) int32 per-shard insertion seq
    tail_keys: jnp.ndarray  # (S, T) int32, dense prefix, INF beyond
    tail_vals: jnp.ndarray  # (S, T) int32
    tail_seq: jnp.ndarray  # (S, T) int32
    head_size: jnp.ndarray  # (S,) int32
    tail_size: jnp.ndarray  # (S,) int32
    next_seq: jnp.ndarray  # (S,) int32

    @property
    def num_shards(self) -> int:
        return self.head_keys.shape[0]

    @property
    def head_width(self) -> int:
        return self.head_keys.shape[1]

    @property
    def tail_width(self) -> int:
        return self.tail_keys.shape[1]

    @property
    def capacity(self) -> int:
        return self.head_width + self.tail_width

    @property
    def size(self) -> jnp.ndarray:
        """(S,) valid entries per shard across both tiers."""
        return self.head_size + self.tail_size

    @property
    def total_size(self) -> jnp.ndarray:
        return jnp.sum(self.head_size + self.tail_size)

    @property
    def keys(self) -> jnp.ndarray:
        """(S, C) concatenated view (head then tail arena).  NOT globally
        sorted per row when the tail is non-empty — use for multiset-style
        reads (``state.keys[state.keys < INF_KEY]``), not for order."""
        return jnp.concatenate([self.head_keys, self.tail_keys], axis=1)

    @property
    def vals(self) -> jnp.ndarray:
        """(S, C) concatenated payload view matching ``keys``."""
        return jnp.concatenate([self.head_vals, self.tail_vals], axis=1)

    @property
    def shard_mins(self) -> jnp.ndarray:
        """(S,) cached per-shard minimum — the MultiQueue min cache.

        The head tier is kept ascending-sorted (I1) with INF padding (I2)
        and always holds the shard's smallest elements (I4), so the cache is
        simply head column 0: maintained for free by every insert/delete,
        never stale, and INF exactly for empty shards.  This is what makes
        the two-choice MULTIQ schedule's probe step a pair of O(1) reads
        instead of a scan."""
        return self.head_keys[:, 0]


def make_state(
    num_shards: int, capacity: int, head_width: int | None = None
) -> PQState:
    """Empty queue: S shards of capacity C, head tier of min(H, C)."""
    H = min(head_width if head_width is not None else DEFAULT_HEAD_WIDTH,
            capacity)
    T = capacity - H
    return PQState(
        head_keys=jnp.full((num_shards, H), INF_KEY, dtype=jnp.int32),
        head_vals=jnp.zeros((num_shards, H), dtype=jnp.int32),
        head_seq=jnp.zeros((num_shards, H), dtype=jnp.int32),
        tail_keys=jnp.full((num_shards, T), INF_KEY, dtype=jnp.int32),
        tail_vals=jnp.zeros((num_shards, T), dtype=jnp.int32),
        tail_seq=jnp.zeros((num_shards, T), dtype=jnp.int32),
        head_size=jnp.zeros((num_shards,), dtype=jnp.int32),
        tail_size=jnp.zeros((num_shards,), dtype=jnp.int32),
        next_seq=jnp.zeros((num_shards,), dtype=jnp.int32),
    )


def fill_state(
    state: PQState, keys: jnp.ndarray, vals: jnp.ndarray
) -> PQState:
    """Bulk-initialize (used by benchmarks to mirror the paper's 'initialized
    with N keys' setup).  Routes by hash like normal inserts."""
    from repro.core.pqueue.ops import insert  # local import to avoid cycle

    new_state, _ = insert(state, keys, vals)
    return new_state


def check_invariants(state: PQState) -> Tuple[bool, str]:
    """Host-side invariant checker (I1, I2, I4, I5). Returns (ok, message)."""
    import numpy as np

    hk = np.asarray(state.head_keys)
    hq = np.asarray(state.head_seq)
    tk = np.asarray(state.tail_keys)
    tq = np.asarray(state.tail_seq)
    hsize = np.asarray(state.head_size)
    tsize = np.asarray(state.tail_size)
    nseq = np.asarray(state.next_seq)
    S, H = hk.shape
    T = tk.shape[1]
    for s in range(S):
        row, n = hk[s], int(hsize[s])
        if not np.all(row[:-1] <= row[1:]):
            return False, f"shard {s}: head keys not ascending (I1)"
        if n < H and not np.all(row[n:] == INF_KEY):
            return False, f"shard {s}: head padding not INF beyond size={n} (I2)"
        if np.any(row[:n] == INF_KEY):
            return False, f"shard {s}: INF sentinel inside head prefix (I2)"
        tn = int(tsize[s])
        tvalid = tk[s, :tn]
        if np.any(tvalid == INF_KEY):
            return False, f"shard {s}: INF inside tail prefix [0,{tn}) (I5)"
        if tn < T and not np.all(tk[s, tn:] == INF_KEY):
            return False, f"shard {s}: tail not INF beyond size={tn} (I5)"
        if tn > 0 and n > 0:
            hmax, tmin = int(row[n - 1]), int(tvalid.min())
            if hmax > tmin:
                return False, (
                    f"shard {s}: head max {hmax} > tail min {tmin} (I4)"
                )
            # equal keys straddling the boundary: head seqs must be smaller
            at_h = hq[s, :n][row[:n] == tmin]
            at_t = tq[s, :tn][tvalid == tmin]
            if at_h.size and at_t.size and at_h.max() > at_t.min():
                return False, f"shard {s}: boundary-tie seq inversion (I4)"
        # (an empty head over a non-empty tail is legal between steps — the
        # next delete's cond-guarded refill restores the hot tier lazily)
        # seq accounting: unique, < next_seq, and head equal-key runs ordered
        seqs = np.concatenate([hq[s, :n], tq[s, :tn]])
        if seqs.size and (seqs.max() >= int(nseq[s]) or
                          np.unique(seqs).size != seqs.size):
            return False, f"shard {s}: seq not unique/bounded (I5)"
        for k in np.unique(row[:n][np.r_[False, row[1:n] == row[: n - 1]]]
                           if n > 1 else []):
            grp = hq[s, :n][row[:n] == k]
            if np.any(np.diff(grp) < 0):
                return False, f"shard {s}: head equal-key seq disorder (I4)"
    return True, "ok"
