"""Sharded bulk-synchronous priority-queue state.

The paper's concurrent priority queue holds (key, value) pairs accessed by p
threads.  The TPU adaptation holds the pairs in S shards, each an
ascending-sorted fixed-capacity buffer padded with the INF sentinel.  The
shards are the unit of placement: mapped onto mesh devices (one or more rows
per device) and NEVER migrated between algorithmic modes — this is what makes
SmartPQ's mode switch a zero-copy predicate flip (paper §3, key idea 3).

Invariants (property-tested in tests/test_pqueue_property.py):
  I1  keys[s] is ascending for every shard s
  I2  keys[s, size[s]:] == INF_KEY and keys[s, :size[s]] < INF_KEY
  I3  multiset of valid (key, value) pairs is conserved by every op batch
      (inserted - deleted, up to reported drops on capacity overflow)
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

# Largest int32. Valid keys must be < INF_KEY. Using the dtype max lets the
# "compact by re-sorting" trick work: removed slots become INF and sort to the
# tail, indistinguishable from padding (by design).
INF_KEY = jnp.iinfo(jnp.int32).max


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQState:
    """keys/vals: (S, C); size: (S,) count of valid entries per shard."""

    keys: jnp.ndarray  # (S, C) int32, ascending, INF-padded
    vals: jnp.ndarray  # (S, C) int32 payload (request-id / vertex-id / ...)
    size: jnp.ndarray  # (S,)   int32

    @property
    def num_shards(self) -> int:
        return self.keys.shape[0]

    @property
    def capacity(self) -> int:
        return self.keys.shape[1]

    @property
    def total_size(self) -> jnp.ndarray:
        return jnp.sum(self.size)

    @property
    def shard_mins(self) -> jnp.ndarray:
        """(S,) cached per-shard minimum — the MultiQueue min cache.

        Because every shard buffer is kept ascending-sorted (I1) with INF
        padding (I2), the cache is simply column 0: maintained for free by
        every insert/delete, never stale, and INF exactly for empty shards.
        This is what makes the two-choice MULTIQ schedule's probe step a
        pair of O(1) reads instead of a scan."""
        return self.keys[:, 0]


def make_state(num_shards: int, capacity: int) -> PQState:
    """Empty queue: S shards of capacity C."""
    keys = jnp.full((num_shards, capacity), INF_KEY, dtype=jnp.int32)
    vals = jnp.zeros((num_shards, capacity), dtype=jnp.int32)
    size = jnp.zeros((num_shards,), dtype=jnp.int32)
    return PQState(keys=keys, vals=vals, size=size)


def fill_state(
    state: PQState, keys: jnp.ndarray, vals: jnp.ndarray
) -> PQState:
    """Bulk-initialize (used by benchmarks to mirror the paper's 'initialized
    with N keys' setup).  Routes by hash like normal inserts."""
    from repro.core.pqueue.ops import insert  # local import to avoid cycle

    new_state, _ = insert(state, keys, vals)
    return new_state


def check_invariants(state: PQState) -> Tuple[bool, str]:
    """Host-side invariant checker (I1, I2). Returns (ok, message)."""
    import numpy as np

    keys = np.asarray(state.keys)
    size = np.asarray(state.size)
    for s in range(keys.shape[0]):
        row = keys[s]
        if not np.all(row[:-1] <= row[1:]):
            return False, f"shard {s}: keys not ascending"
        n = int(size[s])
        if n < keys.shape[1] and not np.all(row[n:] == INF_KEY):
            return False, f"shard {s}: padding not INF beyond size={n}"
        if np.any(row[:n] == INF_KEY):
            return False, f"shard {s}: INF sentinel inside valid prefix"
    return True, "ok"
