"""Sharded bulk-synchronous priority-queue state — tiered head/tail layout.

The paper's concurrent priority queue holds (key, value) pairs accessed by p
threads.  The TPU adaptation holds the pairs in S shards.  The paper's whole
premise is that contention concentrates at the *head*: deleteMin only ever
touches the highest-priority elements (PAPER §2).  The state layout mirrors
that: each shard is split into

  * a **hot head block** ``(S, H)`` — ascending-sorted, INF-padded, holding
    the shard's smallest ``head_size`` elements.  Every deleteMin schedule
    (candidate windows, spray windows, prefix pops) and every insert merge
    operates on this tier only, so per-step cost scales with the batch /
    head-window size, not with the capacity;
  * a **cold tail arena** ``(S, T)`` with ``T = C - H`` — a *bucketed sliding
    window*: the shard's tail elements live at ``[tail_start, tail_start +
    tail_size)`` as a leading ``(key, seq)``-sorted run of ``tail_sorted``
    entries followed by an unsorted append bucket.  Inserts whose key lands
    beyond the head boundary are appended at the window end in O(batch);
    head-merge overflow (the largest elements) spills there too.  The head
    refill CONSUMES the sorted run from the front by advancing
    ``tail_start`` — O(1), no tail traffic (slots left behind are stale and
    simply ignored; the ``keys``/``vals`` views and the invariant checker
    mask them).  The tail arrays themselves are only rewritten by the rare,
    ``lax.cond``-guarded rebalances: when the bucket would outgrow its
    static width — or the window would slide off the arena end — the bucket
    alone is sorted (O(U log U)) and rank-merged into the run (O(T)),
    re-anchoring the window at 0.  A full O(T log T) tail sort survives only
    as the fallback for over-wide buckets and the capacity-overflow
    drop-compaction.

Head sizing rule: ``H`` must cover every schedule's per-step draw window —
``H >= m + (ilog2(S)+1)**2`` (the spray window bound; exact and MULTIQ
schedules need only ``H >= m``, see ``schedules.spray_bound`` /
``schedules.multiq_bound``).  ``make_state`` clamps ``H`` to the capacity, so
small-capacity queues degenerate to the classic single-tier sorted buffer.

The shards remain the unit of placement: mapped onto mesh devices and NEVER
migrated between algorithmic modes — this is what makes SmartPQ's mode
switch a zero-copy predicate flip (paper §3, key idea 3).  ``shard_mins``
(the MultiQueue min cache) is still column 0 of the head, maintained for
free.

Per-shard insertion sequence numbers (``head_seq`` / ``tail_seq`` /
``next_seq``) record the stable linearization order.  The head keeps them
implicitly ordered (stable merges + the strict boundary split guarantee
equal-key head entries are in seq order, and every equal-key tail entry has
a larger seq than any head entry), so the hot path never sorts by seq; the
rare rebalance sorts only the tail's append bucket by ``(key, seq)`` and
merges it into the sorted run, which is exactly what makes the exact
schedules bit-identical to the oracle's (key, shard, seq) linearization
even when elements bounce head -> tail -> head.

Every rebalance that produces a fully sorted tail also RENUMBERS the
shard's seqs positionally (head slot i -> i, tail slot j -> head_size + j;
``next_seq = head_size + tail_size``).  Renumbering preserves the relative
(key, seq) order — the only thing the linearization reads — while (a)
bounding ``next_seq`` far below the int32 wrap horizon (a near-wrap guard
in ``tiered_insert`` forces a rebalance before ~2.1e9 cumulative inserts to
one shard could overflow the counter) and (b) keeping the sorted run's seq
column globally ascending, which is what lets the bucket merge compare
(key, seq) pairs with three plain ``searchsorted`` calls instead of a
packed-int64 sort (x64 is disabled here).

Invariants (property-tested in tests/test_pqueue_property.py):
  I1  head_keys[s] is ascending for every shard s
  I2  head_keys[s, head_size[s]:] == INF_KEY and the valid prefix < INF_KEY
  I3  multiset of valid (key, value) pairs is conserved by every op batch
      (inserted - deleted, up to reported drops on capacity overflow)
  I4  head/tail boundary: max(valid head keys) <= min(valid tail keys); for
      equal keys the head holds the smaller sequence numbers
  I5  staging accounting: tail valid entries are exactly the window
      [tail_start, tail_start + tail_size) (slots outside the window are
      stale and masked by every reader); all seq numbers are unique and
      < next_seq
  I6  bucketed tail: the window's leading tail_sorted entries are
      (key, seq)-lex sorted with the seq column ascending, and
      tail_sorted <= tail_size
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

# Largest int32. Valid keys must be < INF_KEY. Using the dtype max lets the
# "compact by re-sorting" trick work: removed slots become INF and sort to the
# tail, indistinguishable from padding (by design).
INF_KEY = jnp.iinfo(jnp.int32).max

# Default hot-head width: covers every shipped schedule's per-step window
# (delete batches up to m=192 with the spray pad at S<=64 shards).
DEFAULT_HEAD_WIDTH = 256


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PQState:
    """Tiered shard state.

    head_*: (S, H) sorted hot tier; tail_*: (S, T) unsorted cold arena;
    head_size/tail_size: (S,) valid counts; next_seq: (S,) per-shard
    insertion counter (the stable-linearization clock).
    """

    head_keys: jnp.ndarray  # (S, H) int32, ascending, INF-padded
    head_vals: jnp.ndarray  # (S, H) int32 payload
    head_seq: jnp.ndarray  # (S, H) int32 per-shard insertion seq
    tail_keys: jnp.ndarray  # (S, T) int32, valid in the sliding window only
    tail_vals: jnp.ndarray  # (S, T) int32
    tail_seq: jnp.ndarray  # (S, T) int32
    head_size: jnp.ndarray  # (S,) int32
    tail_size: jnp.ndarray  # (S,) int32
    tail_start: jnp.ndarray  # (S,) int32 window origin in the arena
    tail_sorted: jnp.ndarray  # (S,) int32 length of the window's sorted run
    next_seq: jnp.ndarray  # (S,) int32

    @property
    def num_shards(self) -> int:
        return self.head_keys.shape[0]

    @property
    def head_width(self) -> int:
        return self.head_keys.shape[1]

    @property
    def tail_width(self) -> int:
        return self.tail_keys.shape[1]

    @property
    def capacity(self) -> int:
        return self.head_width + self.tail_width

    @property
    def size(self) -> jnp.ndarray:
        """(S,) valid entries per shard across both tiers."""
        return self.head_size + self.tail_size

    @property
    def total_size(self) -> jnp.ndarray:
        return jnp.sum(self.head_size + self.tail_size)

    def _tail_window_mask(self) -> jnp.ndarray:
        """(S, T) bool — True inside the valid sliding window."""
        col = jnp.arange(self.tail_width, dtype=jnp.int32)[None, :]
        return (col >= self.tail_start[:, None]) & (
            col < (self.tail_start + self.tail_size)[:, None]
        )

    @property
    def keys(self) -> jnp.ndarray:
        """(S, C) concatenated view (head, then the tail window; stale
        out-of-window slots read INF).  NOT globally sorted per row when the
        tail is non-empty — use for multiset-style reads
        (``state.keys[state.keys < INF_KEY]``), not for order."""
        if self.tail_width == 0:
            return self.head_keys
        tail_view = jnp.where(self._tail_window_mask(), self.tail_keys,
                              INF_KEY)
        return jnp.concatenate([self.head_keys, tail_view], axis=1)

    @property
    def vals(self) -> jnp.ndarray:
        """(S, C) concatenated payload view matching ``keys``."""
        if self.tail_width == 0:
            return self.head_vals
        tail_view = jnp.where(self._tail_window_mask(), self.tail_vals, 0)
        return jnp.concatenate([self.head_vals, tail_view], axis=1)

    @property
    def shard_mins(self) -> jnp.ndarray:
        """(S,) cached per-shard minimum — the MultiQueue min cache.

        The head tier is kept ascending-sorted (I1) with INF padding (I2)
        and always holds the shard's smallest elements (I4), so the cache is
        simply head column 0: maintained for free by every insert/delete,
        never stale, and INF exactly for empty shards.  This is what makes
        the two-choice MULTIQ schedule's probe step a pair of O(1) reads
        instead of a scan."""
        return self.head_keys[:, 0]


def make_state(
    num_shards: int, capacity: int, head_width: int | None = None
) -> PQState:
    """Empty queue: S shards of capacity C, head tier of min(H, C)."""
    H = min(head_width if head_width is not None else DEFAULT_HEAD_WIDTH,
            capacity)
    T = capacity - H
    return PQState(
        head_keys=jnp.full((num_shards, H), INF_KEY, dtype=jnp.int32),
        head_vals=jnp.zeros((num_shards, H), dtype=jnp.int32),
        head_seq=jnp.zeros((num_shards, H), dtype=jnp.int32),
        tail_keys=jnp.full((num_shards, T), INF_KEY, dtype=jnp.int32),
        tail_vals=jnp.zeros((num_shards, T), dtype=jnp.int32),
        tail_seq=jnp.zeros((num_shards, T), dtype=jnp.int32),
        head_size=jnp.zeros((num_shards,), dtype=jnp.int32),
        tail_size=jnp.zeros((num_shards,), dtype=jnp.int32),
        tail_start=jnp.zeros((num_shards,), dtype=jnp.int32),
        tail_sorted=jnp.zeros((num_shards,), dtype=jnp.int32),
        next_seq=jnp.zeros((num_shards,), dtype=jnp.int32),
    )


def fill_state(
    state: PQState, keys: jnp.ndarray, vals: jnp.ndarray
) -> PQState:
    """Bulk-initialize (used by benchmarks to mirror the paper's 'initialized
    with N keys' setup).  Routes by hash like normal inserts."""
    from repro.core.pqueue.ops import insert  # local import to avoid cycle

    new_state, _ = insert(state, keys, vals)
    return new_state


def invariant_violations(state: PQState, first_only: bool = True):
    """Host-side runtime validation pass (I1, I2, I4, I5, I6).

    Returns a list of `repro.core.errors.InvariantViolation` (empty when the
    state is healthy).  This is the structured form behind both
    `check_invariants` (the legacy (ok, msg) surface) and the
    `SmartPQConfig.validate` guard tier: the serving scheduler runs it after
    every validated window and keys its rollback/retry decision off the
    result.  ``first_only`` stops at the first violation (the guard tier's
    fast path); pass False for a full report."""
    import numpy as np

    from repro.core.errors import InvariantViolation

    out: list = []

    def _bad(invariant: str, shard: int, detail: str) -> bool:
        out.append(InvariantViolation(invariant, shard, detail))
        return first_only

    hk = np.asarray(state.head_keys)
    hq = np.asarray(state.head_seq)
    tk = np.asarray(state.tail_keys)
    tq = np.asarray(state.tail_seq)
    hsize = np.asarray(state.head_size)
    tsize = np.asarray(state.tail_size)
    tstart = np.asarray(state.tail_start)
    tsorted = np.asarray(state.tail_sorted)
    nseq = np.asarray(state.next_seq)
    S, H = hk.shape
    T = tk.shape[1]
    for s in range(S):
        row, n = hk[s], int(hsize[s])
        if not np.all(row[:-1] <= row[1:]):
            if _bad("I1", s, f"shard {s}: head keys not ascending (I1)"):
                return out
        if n < H and not np.all(row[n:] == INF_KEY):
            if _bad("I2", s,
                    f"shard {s}: head padding not INF beyond size={n} (I2)"):
                return out
        if np.any(row[:n] == INF_KEY):
            if _bad("I2", s, f"shard {s}: INF sentinel inside head prefix (I2)"):
                return out
        tn = int(tsize[s])
        t0 = int(tstart[s])
        if t0 < 0 or t0 + tn > T:
            if _bad("I5", s,
                    f"shard {s}: tail window [{t0},{t0 + tn}) outside arena "
                    f"[0,{T}) (I5)"):
                return out
            tn = 0  # window unreadable: skip the window-dependent checks
        tvalid = tk[s, t0 : t0 + tn]
        tqwin = tq[s, t0 : t0 + tn]
        if np.any(tvalid == INF_KEY):
            if _bad("I5", s, f"shard {s}: INF inside tail window (I5)"):
                return out
        if tn > 0 and n > 0:
            hmax, tmin = int(row[n - 1]), int(tvalid.min())
            if hmax > tmin:
                if _bad("I4", s,
                        f"shard {s}: head max {hmax} > tail min {tmin} (I4)"):
                    return out
            # equal keys straddling the boundary: head seqs must be smaller
            at_h = hq[s, :n][row[:n] == tmin]
            at_t = tqwin[tvalid == tmin]
            if at_h.size and at_t.size and at_h.max() > at_t.min():
                if _bad("I4", s,
                        f"shard {s}: boundary-tie seq inversion (I4)"):
                    return out
        # (an empty head over a non-empty tail is legal between steps — the
        # next delete's cond-guarded refill restores the hot tier lazily)
        # bucketed tail: the window's leading run is (key, seq)-lex sorted
        # with the seq column globally ascending (I6)
        srt = int(tsorted[s])
        if srt < 0 or srt > tn:
            if _bad("I6", s,
                    f"shard {s}: tail_sorted {srt} outside [0,{tn}] (I6)"):
                return out
            srt = 0
        if srt > 1:
            rk_ = tvalid[:srt].astype(np.int64)
            rq_ = tqwin[:srt].astype(np.int64)
            if np.any(np.diff(rk_) < 0):
                if _bad("I6", s,
                        f"shard {s}: tail sorted run keys descend (I6)"):
                    return out
            if np.any(np.diff(rq_) < 0):
                if _bad("I6", s,
                        f"shard {s}: tail sorted run seqs descend (I6)"):
                    return out
        # seq accounting: unique, < next_seq, and head equal-key runs ordered
        seqs = np.concatenate([hq[s, :n], tqwin])
        if seqs.size and (seqs.max() >= int(nseq[s]) or
                          np.unique(seqs).size != seqs.size):
            if _bad("I5", s, f"shard {s}: seq not unique/bounded (I5)"):
                return out
        for k in np.unique(row[:n][np.r_[False, row[1:n] == row[: n - 1]]]
                           if n > 1 else []):
            grp = hq[s, :n][row[:n] == k]
            if np.any(np.diff(grp) < 0):
                if _bad("I4", s,
                        f"shard {s}: head equal-key seq disorder (I4)"):
                    return out
    return out


def check_invariants(state: PQState) -> Tuple[bool, str]:
    """Legacy (ok, message) surface over `invariant_violations` (I1, I2,
    I4, I5, I6) — message is the first violation's detail."""
    viols = invariant_violations(state, first_only=True)
    if viols:
        return False, viols[0].detail
    return True, "ok"


def state_fingerprint(state: PQState) -> int:
    """Order-stable CRC32 over the state's physical content (every field's
    canonical bytes, field order fixed by the dataclass).  Two states are
    bit-identical iff their fingerprints match buffer-for-buffer — the
    cheap equality the durability layer stamps into snapshot manifests and
    the crash-recovery tests assert across interrupted vs. uninterrupted
    runs.  Physical, not logical: garbage beyond `head_size`/the tail
    window is included, which is exactly what bit-identity means."""
    import zlib

    import numpy as np

    crc = 0
    for f in dataclasses.fields(state):
        arr = np.ascontiguousarray(np.asarray(getattr(state, f.name)))
        crc = zlib.crc32(arr.tobytes(), zlib.crc32(f.name.encode(), crc))
    return crc & 0xFFFFFFFF
