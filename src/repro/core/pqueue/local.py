"""Per-shard local primitives, vectorized over the shard axis.

These are the jnp reference paths; `repro.kernels` provides Pallas TPU
kernels for the hot spots (windowed head merge for insert, bitonic top-k for
the deleteMin tournament) that bit-match these functions (tests sweep both).

All hot-path functions operate on the **head tier** ``(S, H)`` of the tiered
`PQState` (H static, small) so per-step cost scales with the batch /
head-window size rather than the queue capacity.  The cold tail arena
``(S, T)`` is touched only by O(batch) appends and by the rare,
``lax.cond``-guarded rebalances (`refill_head`, the overflow branch of
`tiered_insert`), which are the only O(capacity) code paths left.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue.state import INF_KEY, PQState

_INT32_MIN = jnp.iinfo(jnp.int32).min

# Kernel dispatch: the Pallas kernels run on TPU; the jnp paths are the
# oracle (and the CPU default — interpret-mode kernels are Python-slow).
# REPRO_PQ_KERNELS=1 forces the kernel path.
_USE_KERNELS_ENV = os.environ.get("REPRO_PQ_KERNELS", "") == "1"


def _kernels_enabled() -> bool:
    if _USE_KERNELS_ENV:
        return True
    return jax.default_backend() == "tpu"


def _key_seq_order(keys: jnp.ndarray, seq: jnp.ndarray) -> jnp.ndarray:
    """Row-wise argsort by (key, seq) lexicographic — the stable
    linearization order.  (x64 is disabled in this container, so the order
    is two chained stable sorts rather than a packed int64 key.)"""
    return jnp.lexsort((seq, keys), axis=1)


# ---------------------------------------------------------------------------
# windowed merge — the insert hot spot
# ---------------------------------------------------------------------------


def merge_head_run(
    head_k: jnp.ndarray,  # (S, H) ascending, INF-padded
    head_v: jnp.ndarray,
    head_q: jnp.ndarray,
    run_k: jnp.ndarray,  # (S, R) ascending, INF-padded
    run_v: jnp.ndarray,
    run_q: jnp.ndarray,
    use_kernel: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-width merge of two ascending runs: (S, H) + (S, R) -> (S, H+R).

    Positional-stable (ties order head before run, in-position within each),
    which — together with the strict head/tail boundary split — keeps head
    equal-key entries in seq order without ever comparing seqs on the hot
    path.  Kernel path: bitonic windowed-merge network
    (`kernels.windowed_merge`); jnp path: the rank merge below.  Both are
    bit-identical (tested).

    Cost is O(H + R) per shard row — independent of the queue capacity.
    """
    if use_kernel is None:
        use_kernel = _kernels_enabled()
    if use_kernel:
        from repro.kernels.ops import windowed_merge

        return windowed_merge(head_k, head_v, head_q, run_k, run_v, run_q)

    S, H = head_k.shape
    R = run_k.shape[1]
    # searchsorted per row: rank of each head key among the run ('left':
    # count strictly less) and of each run key among the head ('right':
    # count <=, the stable head-before-run tie-break).  The resulting
    # positions are a permutation of [0, H+R) — no drop guard needed.
    rank_head = jax.vmap(
        lambda inc, k: jnp.searchsorted(inc, k, side="left")
    )(run_k, head_k).astype(jnp.int32)
    rank_run = jax.vmap(
        lambda k, inc: jnp.searchsorted(k, inc, side="right")
    )(head_k, run_k).astype(jnp.int32)
    pos_head = jnp.arange(H, dtype=jnp.int32)[None, :] + rank_head
    pos_run = jnp.arange(R, dtype=jnp.int32)[None, :] + rank_run

    row = jnp.arange(S, dtype=jnp.int32)[:, None]
    out_k = jnp.full((S, H + R), INF_KEY, dtype=head_k.dtype)
    out_v = jnp.zeros((S, H + R), dtype=head_v.dtype)
    out_q = jnp.zeros((S, H + R), dtype=head_q.dtype)
    out_k = out_k.at[row, pos_head].set(head_k).at[row, pos_run].set(run_k)
    out_v = out_v.at[row, pos_head].set(head_v).at[row, pos_run].set(run_v)
    out_q = out_q.at[row, pos_head].set(head_q).at[row, pos_run].set(run_q)
    return out_k, out_v, out_q


# ---------------------------------------------------------------------------
# head-tier removal primitives (O(H) per shard, H static)
# ---------------------------------------------------------------------------


def remove_prefix(
    keys: jnp.ndarray,  # (S, W) ascending head tier
    vals: jnp.ndarray,
    seq: jnp.ndarray,
    size: jnp.ndarray,  # (S,)
    take: jnp.ndarray,  # (S,) number of smallest elements to remove per shard
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Remove the `take[s]` smallest elements of shard s (always a prefix of
    the sorted head — the tournament only ever consumes head prefixes).
    Implemented as a per-row left shift."""
    S, W = keys.shape
    idx = jnp.arange(W, dtype=jnp.int32)[None, :] + take[:, None]  # (S, W)
    in_range = idx < W
    idx = jnp.minimum(idx, W - 1)
    new_keys = jnp.where(
        in_range, jnp.take_along_axis(keys, idx, axis=1), INF_KEY
    )
    new_vals = jnp.where(in_range, jnp.take_along_axis(vals, idx, axis=1), 0)
    new_seq = jnp.where(in_range, jnp.take_along_axis(seq, idx, axis=1), 0)
    new_size = jnp.maximum(size - take, 0).astype(jnp.int32)
    return new_keys, new_vals, new_seq, new_size


def remove_at(
    keys: jnp.ndarray,  # (S, H) head tier
    vals: jnp.ndarray,
    seq: jnp.ndarray,
    size: jnp.ndarray,
    remove_mask: jnp.ndarray,  # (S, W) bool, W <= H — positions to delete
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Remove arbitrary positions inside the static spray window W (spray
    pops random slots in the top region; columns beyond W are untouched by
    construction).  Compaction trick, windowed: removed window slots become
    INF, a stable argsort of ONLY the (S, W) window restores its order, and
    a single (S, H) gather splices the untouched suffix back after the
    surviving window entries — O(W log W + H) per row instead of the old
    O(C log C) full-row sort."""
    S, H = keys.shape
    W = remove_mask.shape[1]
    assert W <= H, (W, H)
    win_k = keys[:, :W]
    hit = remove_mask & (win_k != INF_KEY)
    n_removed = jnp.sum(hit, axis=1).astype(jnp.int32)

    masked_k = jnp.where(remove_mask, INF_KEY, win_k)
    order = jnp.argsort(masked_k, axis=1, stable=True)  # (S, W)
    win_sorted_k = jnp.take_along_axis(masked_k, order, axis=1)
    win_sorted_v = jnp.take_along_axis(
        jnp.where(remove_mask, 0, vals[:, :W]), order, axis=1
    )
    win_sorted_q = jnp.take_along_axis(
        jnp.where(remove_mask, 0, seq[:, :W]), order, axis=1
    )
    pad = H - W
    if pad:
        win_sorted_k = jnp.pad(win_sorted_k, ((0, 0), (0, pad)),
                               constant_values=INF_KEY)
        win_sorted_v = jnp.pad(win_sorted_v, ((0, 0), (0, pad)))
        win_sorted_q = jnp.pad(win_sorted_q, ((0, 0), (0, pad)))

    # survivors in the window, then the suffix shifted left to close the gap
    v_in_win = jnp.minimum(size, W) - n_removed  # (S,)
    shift = W - v_in_win  # = n_removed + window INF padding
    col = jnp.arange(H, dtype=jnp.int32)[None, :]
    suf_idx = col + shift[:, None]
    suf_ok = suf_idx < H
    suf_idx = jnp.minimum(suf_idx, H - 1)
    suf_k = jnp.where(suf_ok, jnp.take_along_axis(keys, suf_idx, axis=1),
                      INF_KEY)
    suf_v = jnp.where(suf_ok, jnp.take_along_axis(vals, suf_idx, axis=1), 0)
    suf_q = jnp.where(suf_ok, jnp.take_along_axis(seq, suf_idx, axis=1), 0)

    sel = col < v_in_win[:, None]
    new_keys = jnp.where(sel, win_sorted_k, suf_k)
    new_vals = jnp.where(sel, win_sorted_v, suf_v)
    new_seq = jnp.where(sel, win_sorted_q, suf_q)
    new_size = jnp.maximum(size - n_removed, 0).astype(jnp.int32)
    return new_keys, new_vals, new_seq, new_size


# ---------------------------------------------------------------------------
# tiered insert + rebalance (the only O(capacity) paths, cond-guarded)
# ---------------------------------------------------------------------------


def tiered_insert(
    state: PQState,
    rk: jnp.ndarray,  # (S, R) routed runs, ascending, INF-padded
    rv: jnp.ndarray,
    counts: jnp.ndarray,  # (S,) valid entries per run
) -> Tuple[PQState, jnp.ndarray]:
    """Insert routed runs into the tiered state.  Returns (state, dropped).

    Rank-split each run against the shard's head boundary key: head-bound
    keys (strictly below the boundary) merge into the (S, H) hot tier via
    the windowed merge; merge overflow (the largest elements) and tail-bound
    keys append to the tail arena in O(batch).  Only when a shard's arena
    cannot hold the append does the cond-guarded overflow branch run a full
    (key, seq) sort that keeps the C smallest of the union and reports the
    rest in `dropped` — the same semantics the old full-width merge had on
    every step, now paid only at capacity.
    """
    S, H = state.head_keys.shape
    T = state.tail_width
    R = rk.shape[1]
    col = jnp.arange(R, dtype=jnp.int32)[None, :]
    valid = col < counts[:, None]
    rq = jnp.where(valid, state.next_seq[:, None] + col, 0)

    if T == 0:
        # Single-tier degenerate case (capacity <= head width): plain
        # windowed merge, overflow (necessarily the largest) is dropped.
        mk, mv, mq = merge_head_run(
            state.head_keys, state.head_vals, state.head_seq, rk, rv, rq
        )
        dropped = jnp.maximum(state.head_size + counts - H, 0).astype(jnp.int32)
        new_state = dataclasses.replace(
            state,
            head_keys=mk[:, :H], head_vals=mv[:, :H], head_seq=mq[:, :H],
            head_size=jnp.minimum(state.head_size + counts, H).astype(jnp.int32),
            next_seq=state.next_seq + counts,
        )
        return new_state, dropped

    # -- strict boundary split ------------------------------------------------
    row = jnp.arange(S, dtype=jnp.int32)[:, None]
    hmax = jnp.take_along_axis(
        state.head_keys,
        jnp.clip(state.head_size - 1, 0, H - 1)[:, None], axis=1,
    )[:, 0]
    hmax = jnp.where(state.head_size > 0, hmax, _INT32_MIN)
    # tail empty: everything is head-bound (spill restores the boundary);
    # tail non-empty: only keys STRICTLY below the head max may enter the
    # head — ties go to the tail, which keeps equal-key seqs ordered across
    # the boundary (I4) without any hot-path seq comparison.
    bkey = jnp.where(state.tail_size > 0, hmax, INF_KEY)
    n_head = jax.vmap(
        lambda r, b: jnp.searchsorted(r, b, side="left")
    )(rk, bkey).astype(jnp.int32)

    hb_sel = col < n_head[:, None]
    hrun_k = jnp.where(hb_sel, rk, INF_KEY)
    hrun_v = jnp.where(hb_sel, rv, 0)
    hrun_q = jnp.where(hb_sel, rq, 0)

    n_tail_inc = counts - n_head
    t_idx = jnp.minimum(col + n_head[:, None], R - 1)
    tb_sel = col < n_tail_inc[:, None]
    trun_k = jnp.where(tb_sel, jnp.take_along_axis(rk, t_idx, axis=1), INF_KEY)
    trun_v = jnp.where(tb_sel, jnp.take_along_axis(rv, t_idx, axis=1), 0)
    trun_q = jnp.where(tb_sel, jnp.take_along_axis(rq, t_idx, axis=1), 0)

    # -- hot-tier merge + spill ----------------------------------------------
    mk, mv, mq = merge_head_run(
        state.head_keys, state.head_vals, state.head_seq,
        hrun_k, hrun_v, hrun_q,
    )
    nh_k, nh_v, nh_q = mk[:, :H], mv[:, :H], mq[:, :H]
    sp_k, sp_v, sp_q = mk[:, H:], mv[:, H:], mq[:, H:]  # (S, R) spill run
    n_spill = jnp.maximum(state.head_size + n_head - H, 0).astype(jnp.int32)
    new_hsize = jnp.minimum(state.head_size + n_head, H).astype(jnp.int32)

    n_append = n_tail_inc + n_spill
    valid_total = state.head_size + state.tail_size + counts

    def no_overflow(op):
        tk, tv, tq, tsize = op
        pos1 = jnp.where(tb_sel, tsize[:, None] + col, T + R)
        pos2 = jnp.where(
            col < n_spill[:, None], tsize[:, None] + n_tail_inc[:, None] + col,
            T + R,
        )
        tk = tk.at[row, pos1].set(trun_k, mode="drop")
        tk = tk.at[row, pos2].set(sp_k, mode="drop")
        tv = tv.at[row, pos1].set(trun_v, mode="drop")
        tv = tv.at[row, pos2].set(sp_v, mode="drop")
        tq = tq.at[row, pos1].set(trun_q, mode="drop")
        tq = tq.at[row, pos2].set(sp_q, mode="drop")
        return (
            nh_k, nh_v, nh_q, tk, tv, tq,
            new_hsize, (tsize + n_append).astype(jnp.int32),
            jnp.zeros((S,), jnp.int32),
        )

    def overflow(op):
        tk, tv, tq, tsize = op
        cat_k = jnp.concatenate([nh_k, tk, trun_k, sp_k], axis=1)
        cat_v = jnp.concatenate([nh_v, tv, trun_v, sp_v], axis=1)
        cat_q = jnp.concatenate([nh_q, tq, trun_q, sp_q], axis=1)
        order = _key_seq_order(cat_k, cat_q)
        sk = jnp.take_along_axis(cat_k, order, axis=1)[:, : H + T]
        sv = jnp.take_along_axis(cat_v, order, axis=1)[:, : H + T]
        sq = jnp.take_along_axis(cat_q, order, axis=1)[:, : H + T]
        dropped = jnp.maximum(valid_total - (H + T), 0).astype(jnp.int32)
        return (
            sk[:, :H], sv[:, :H], sq[:, :H],
            sk[:, H:], sv[:, H:], sq[:, H:],
            jnp.minimum(valid_total, H).astype(jnp.int32),
            jnp.clip(valid_total - H, 0, T).astype(jnp.int32),
            dropped,
        )

    out = jax.lax.cond(
        jnp.any(state.tail_size + n_append > T),
        overflow,
        no_overflow,
        (state.tail_keys, state.tail_vals, state.tail_seq, state.tail_size),
    )
    hk, hv, hq, tk, tv, tq, hsize, tsize, dropped = out
    new_state = dataclasses.replace(
        state,
        head_keys=hk, head_vals=hv, head_seq=hq,
        tail_keys=tk, tail_vals=tv, tail_seq=tq,
        head_size=hsize, tail_size=tsize,
        next_seq=state.next_seq + counts,
    )
    return new_state, dropped


def refill_head(state: PQState) -> PQState:
    """Restore the hot tier: pull the tail's (key, seq)-smallest elements
    into the head until it is full (or the tail is drained).  O(T log T) —
    called only from the cond-guarded `ensure_head` when a shard's head
    underflows below its per-step draw bound, so the cost amortizes over the
    many O(H) steps in between."""
    S, H = state.head_keys.shape
    T = state.tail_width
    if T == 0:
        return state
    order = _key_seq_order(state.tail_keys, state.tail_seq)
    st_k = jnp.take_along_axis(state.tail_keys, order, axis=1)
    st_v = jnp.take_along_axis(state.tail_vals, order, axis=1)
    st_q = jnp.take_along_axis(state.tail_seq, order, axis=1)

    take = jnp.minimum(H - state.head_size, state.tail_size).astype(jnp.int32)
    Wr = min(H, T)
    col = jnp.arange(Wr, dtype=jnp.int32)[None, :]
    sel = col < take[:, None]
    run_k = jnp.where(sel, st_k[:, :Wr], INF_KEY)
    run_v = jnp.where(sel, st_v[:, :Wr], 0)
    run_q = jnp.where(sel, st_q[:, :Wr], 0)

    mk, mv, mq = merge_head_run(
        state.head_keys, state.head_vals, state.head_seq, run_k, run_v, run_q
    )  # head_size + take <= H, so the spill region is empty by construction

    colT = jnp.arange(T, dtype=jnp.int32)[None, :]
    idx = colT + take[:, None]
    in_range = idx < T
    idx = jnp.minimum(idx, T - 1)
    nt_k = jnp.where(in_range, jnp.take_along_axis(st_k, idx, axis=1), INF_KEY)
    nt_v = jnp.where(in_range, jnp.take_along_axis(st_v, idx, axis=1), 0)
    nt_q = jnp.where(in_range, jnp.take_along_axis(st_q, idx, axis=1), 0)

    return dataclasses.replace(
        state,
        head_keys=mk[:, :H], head_vals=mv[:, :H], head_seq=mq[:, :H],
        tail_keys=nt_k, tail_vals=nt_v, tail_seq=nt_q,
        head_size=(state.head_size + take).astype(jnp.int32),
        tail_size=(state.tail_size - take).astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# legacy full-width merge (kept as the reference for the capacity-wide
# Pallas kernel in kernels/sorted_merge.py; the insert hot path now uses
# merge_head_run + tiered_insert)
# ---------------------------------------------------------------------------


def merge_sorted(
    keys: jnp.ndarray,  # (S, C) ascending, INF-padded
    vals: jnp.ndarray,  # (S, C)
    inc_keys: jnp.ndarray,  # (S, R) ascending, INF-padded
    inc_vals: jnp.ndarray,  # (S, R)
    size: jnp.ndarray,  # (S,)
    inc_count: jnp.ndarray,  # (S,)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Merge a sorted incoming run into each shard's sorted buffer, keeping
    the C smallest (rank-based merge, stable toward existing elements).
    Returns (new_keys, new_vals, new_size, dropped)."""
    S, C = keys.shape
    R = inc_keys.shape[1]

    rank_exist = jax.vmap(
        lambda inc, k: jnp.searchsorted(inc, k, side="left")
    )(inc_keys, keys).astype(jnp.int32)
    rank_inc = jax.vmap(
        lambda k, inc: jnp.searchsorted(k, inc, side="right")
    )(keys, inc_keys).astype(jnp.int32)

    pos_exist = jnp.arange(C, dtype=jnp.int32)[None, :] + rank_exist  # (S, C)
    pos_inc = jnp.arange(R, dtype=jnp.int32)[None, :] + rank_inc  # (S, R)

    out_keys = jnp.full((S, C), INF_KEY, dtype=keys.dtype)
    out_vals = jnp.zeros((S, C), dtype=vals.dtype)
    row = jnp.arange(S, dtype=jnp.int32)[:, None]

    out_keys = out_keys.at[row, pos_exist].set(keys, mode="drop")
    out_vals = out_vals.at[row, pos_exist].set(vals, mode="drop")
    inc_is_pad = inc_keys == INF_KEY
    pos_inc = jnp.where(inc_is_pad, C + R, pos_inc)
    out_keys = out_keys.at[row, pos_inc].set(inc_keys, mode="drop")
    out_vals = out_vals.at[row, pos_inc].set(inc_vals, mode="drop")

    new_size = jnp.minimum(size + inc_count, C).astype(jnp.int32)
    dropped = jnp.maximum(size + inc_count - C, 0).astype(jnp.int32)
    return out_keys, out_vals, new_size, dropped


# ---------------------------------------------------------------------------
# tournament / probe primitives (unchanged semantics, head-tier operands)
# ---------------------------------------------------------------------------


def topk_of_merged(
    cand_keys: jnp.ndarray,  # (N,) unsorted or blockwise-sorted candidates
    cand_vals: jnp.ndarray,  # (N,)
    m: int,
    use_kernel: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Global tournament: the m smallest of N candidates, ascending.

    Kernel path: the bitonic network sorts (key, position-tag) pairs
    lexicographically, then payloads are gathered by tag — bit-identical to
    the stable argsort (ties break by position in both)."""
    if use_kernel is None:
        use_kernel = _kernels_enabled()
    if use_kernel and cand_keys.dtype == jnp.int32:
        from repro.kernels.ops import topk_smallest

        n = cand_keys.shape[0]
        tags = jnp.arange(n, dtype=jnp.int32)
        kk, kt = topk_smallest(cand_keys[None, :], tags[None, :], m)
        return kk[0], cand_vals[kt[0]]
    order = jnp.argsort(cand_keys, stable=True)[:m]
    return cand_keys[order], cand_vals[order]


def twochoice_pick(
    shard_mins: jnp.ndarray,  # (S,) cached per-shard minima (INF when empty)
    choice_a: jnp.ndarray,  # (m,) sampled shard ids
    choice_b: jnp.ndarray,  # (m,)
    act: jnp.ndarray,  # (m,) bool — inactive lanes commit nowhere
    use_kernel: bool | None = None,
) -> jnp.ndarray:
    """MULTIQ probe/commit: each lane commits to the sampled shard with the
    smaller cached min (tie: lower id); returns per-shard commit counts.
    Kernel path is the gather-free Pallas one-hot formulation."""
    if use_kernel is None:
        use_kernel = _kernels_enabled()
    from repro.kernels.ops import twochoice_counts

    return twochoice_counts(
        shard_mins, choice_a, choice_b, act, use_kernel=use_kernel
    )


def multiq_select(
    win_k: jnp.ndarray,  # (S, m) ascending head windows
    win_v: jnp.ndarray,  # (S, m) payloads
    take: jnp.ndarray,  # (S,) commit counts (prefix pops)
    use_kernel: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """m smallest of the masked head windows, ascending — the MULTIQ
    commit-side tournament (bitonic merge network on TPU)."""
    if use_kernel is None:
        use_kernel = _kernels_enabled()
    from repro.kernels.ops import multiq_select_topm

    return multiq_select_topm(win_k, win_v, take, use_kernel=use_kernel)


def count_winners_per_shard(
    cand_keys: jnp.ndarray,  # (S, m) each shard's candidate prefix
    threshold_key: jnp.ndarray,  # () the m-th smallest (winner cutoff)
    winners_needed: jnp.ndarray,  # () total winners to take (== active m)
) -> jnp.ndarray:
    """How many elements each shard loses to the tournament.

    Elements strictly below the cutoff always win.  Ties at the cutoff are
    broken by shard id (lower shard wins) so that exactly `winners_needed`
    elements are removed globally — the same resolution the oracle uses.
    """
    S, m = cand_keys.shape
    below = jnp.sum(cand_keys < threshold_key, axis=1).astype(jnp.int32)  # (S,)
    at = jnp.sum(cand_keys == threshold_key, axis=1).astype(jnp.int32)  # (S,)
    remaining = winners_needed - jnp.sum(below)
    # Prefix allocation of tie slots by shard id.
    tie_prefix = jnp.cumsum(at) - at
    tie_take = jnp.clip(remaining - tie_prefix, 0, at)
    return below + tie_take.astype(jnp.int32)
