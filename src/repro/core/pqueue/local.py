"""Per-shard local primitives, vectorized over the shard axis.

These are the jnp reference paths; `repro.kernels` provides Pallas TPU
kernels for the two hot spots (sorted merge for insert, bitonic top-k for the
deleteMin tournament) that bit-match these functions (tests sweep both).

All functions operate on (S, C) shard-major arrays so a single call covers
every shard a device owns — on TPU this keeps the VPU lanes full and lets the
Pallas kernels tile (shard, capacity) blocks into VMEM.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue.state import INF_KEY


def merge_sorted(
    keys: jnp.ndarray,  # (S, C) ascending, INF-padded
    vals: jnp.ndarray,  # (S, C)
    inc_keys: jnp.ndarray,  # (S, R) ascending, INF-padded
    inc_vals: jnp.ndarray,  # (S, R)
    size: jnp.ndarray,  # (S,)
    inc_count: jnp.ndarray,  # (S,)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Merge a sorted incoming run into each shard's sorted buffer.

    Rank-based merge (no data-dependent control flow — TPU friendly):
      out_pos(existing_i) = i + #incoming strictly-less-than existing_i
      out_pos(incoming_j) = j + #existing less-or-equal incoming_j
    Ties break toward existing elements (stable). Elements ranked beyond C
    are dropped (largest ones) and counted in `dropped`.

    Returns (new_keys, new_vals, new_size, dropped).
    """
    S, C = keys.shape
    R = inc_keys.shape[1]

    # searchsorted per row: rank of each existing key among incoming ('left'
    # side: count of incoming strictly less) and of each incoming key among
    # existing ('right' side: count of existing <=, giving stable tie-break).
    rank_exist = jax.vmap(
        lambda inc, k: jnp.searchsorted(inc, k, side="left")
    )(inc_keys, keys).astype(jnp.int32)
    rank_inc = jax.vmap(
        lambda k, inc: jnp.searchsorted(k, inc, side="right")
    )(keys, inc_keys).astype(jnp.int32)

    pos_exist = jnp.arange(C, dtype=jnp.int32)[None, :] + rank_exist  # (S, C)
    pos_inc = jnp.arange(R, dtype=jnp.int32)[None, :] + rank_inc  # (S, R)

    # INF sentinels must stay at the tail; rank math already guarantees that
    # (INF >= everything), but positions may exceed C — scatter with drop.
    out_keys = jnp.full((S, C), INF_KEY, dtype=keys.dtype)
    out_vals = jnp.zeros((S, C), dtype=vals.dtype)
    row = jnp.arange(S, dtype=jnp.int32)[:, None]

    out_keys = out_keys.at[row, pos_exist].set(keys, mode="drop")
    out_vals = out_vals.at[row, pos_exist].set(vals, mode="drop")
    # Guard incoming INF padding: give it an out-of-range position so it can
    # never overwrite a real element that also ranked near the tail.
    inc_is_pad = inc_keys == INF_KEY
    pos_inc = jnp.where(inc_is_pad, C + R, pos_inc)
    out_keys = out_keys.at[row, pos_inc].set(inc_keys, mode="drop")
    out_vals = out_vals.at[row, pos_inc].set(inc_vals, mode="drop")

    new_size = jnp.minimum(size + inc_count, C).astype(jnp.int32)
    dropped = jnp.maximum(size + inc_count - C, 0).astype(jnp.int32)
    return out_keys, out_vals, new_size, dropped


def remove_prefix(
    keys: jnp.ndarray,  # (S, C)
    vals: jnp.ndarray,
    size: jnp.ndarray,  # (S,)
    take: jnp.ndarray,  # (S,) number of smallest elements to remove per shard
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Remove the `take[s]` smallest elements of shard s (always a prefix of
    the sorted buffer — the tournament only ever consumes shard prefixes).
    Implemented as a per-row left shift."""
    S, C = keys.shape
    idx = jnp.arange(C, dtype=jnp.int32)[None, :] + take[:, None]  # (S, C)
    in_range = idx < C
    idx = jnp.minimum(idx, C - 1)
    new_keys = jnp.where(
        in_range, jnp.take_along_axis(keys, idx, axis=1), INF_KEY
    )
    new_vals = jnp.where(
        in_range, jnp.take_along_axis(vals, idx, axis=1), 0
    )
    new_size = jnp.maximum(size - take, 0).astype(jnp.int32)
    return new_keys, new_vals, new_size


def remove_at(
    keys: jnp.ndarray,  # (S, C)
    vals: jnp.ndarray,
    size: jnp.ndarray,
    remove_mask: jnp.ndarray,  # (S, C) bool — positions to delete
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Remove arbitrary positions (spray pops random slots in the top
    region).  Compaction trick: removed slots become INF, then a full-row
    sort restores I1/I2 because the sentinel equals the padding value."""
    n_removed = jnp.sum(remove_mask & (keys != INF_KEY), axis=1).astype(jnp.int32)
    k = jnp.where(remove_mask, INF_KEY, keys)
    # Stable single-key sort carrying vals along.
    order = jnp.argsort(k, axis=1, stable=True)
    new_keys = jnp.take_along_axis(k, order, axis=1)
    new_vals = jnp.take_along_axis(jnp.where(remove_mask, 0, vals), order, axis=1)
    new_size = jnp.maximum(size - n_removed, 0).astype(jnp.int32)
    return new_keys, new_vals, new_size


import os

# Kernel dispatch: the Pallas bitonic_topk runs the tournament on TPU; the
# jnp stable-argsort is the oracle (and the CPU default — interpret-mode
# kernels are Python-slow).  REPRO_PQ_KERNELS=1 forces the kernel path.
_USE_KERNELS_ENV = os.environ.get("REPRO_PQ_KERNELS", "") == "1"


def _kernels_enabled() -> bool:
    if _USE_KERNELS_ENV:
        return True
    return jax.default_backend() == "tpu"


def topk_of_merged(
    cand_keys: jnp.ndarray,  # (N,) unsorted or blockwise-sorted candidates
    cand_vals: jnp.ndarray,  # (N,)
    m: int,
    use_kernel: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Global tournament: the m smallest of N candidates, ascending.

    Kernel path: the bitonic network sorts (key, position-tag) pairs
    lexicographically, then payloads are gathered by tag — bit-identical to
    the stable argsort (ties break by position in both)."""
    if use_kernel is None:
        use_kernel = _kernels_enabled()
    if use_kernel and cand_keys.dtype == jnp.int32:
        from repro.kernels.ops import topk_smallest

        n = cand_keys.shape[0]
        tags = jnp.arange(n, dtype=jnp.int32)
        kk, kt = topk_smallest(cand_keys[None, :], tags[None, :], m)
        return kk[0], cand_vals[kt[0]]
    order = jnp.argsort(cand_keys, stable=True)[:m]
    return cand_keys[order], cand_vals[order]


def twochoice_pick(
    shard_mins: jnp.ndarray,  # (S,) cached per-shard minima (INF when empty)
    choice_a: jnp.ndarray,  # (m,) sampled shard ids
    choice_b: jnp.ndarray,  # (m,)
    act: jnp.ndarray,  # (m,) bool — inactive lanes commit nowhere
    use_kernel: bool | None = None,
) -> jnp.ndarray:
    """MULTIQ probe/commit: each lane commits to the sampled shard with the
    smaller cached min (tie: lower id); returns per-shard commit counts.
    Kernel path is the gather-free Pallas one-hot formulation."""
    if use_kernel is None:
        use_kernel = _kernels_enabled()
    from repro.kernels.ops import twochoice_counts

    return twochoice_counts(
        shard_mins, choice_a, choice_b, act, use_kernel=use_kernel
    )


def multiq_select(
    win_k: jnp.ndarray,  # (S, m) ascending head windows
    win_v: jnp.ndarray,  # (S, m) payloads
    take: jnp.ndarray,  # (S,) commit counts (prefix pops)
    use_kernel: bool | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """m smallest of the masked head windows, ascending — the MULTIQ
    commit-side tournament (bitonic merge network on TPU)."""
    if use_kernel is None:
        use_kernel = _kernels_enabled()
    from repro.kernels.ops import multiq_select_topm

    return multiq_select_topm(win_k, win_v, take, use_kernel=use_kernel)


def count_winners_per_shard(
    cand_keys: jnp.ndarray,  # (S, m) each shard's candidate prefix
    threshold_key: jnp.ndarray,  # () the m-th smallest (winner cutoff)
    winners_needed: jnp.ndarray,  # () total winners to take (== active m)
) -> jnp.ndarray:
    """How many elements each shard loses to the tournament.

    Elements strictly below the cutoff always win.  Ties at the cutoff are
    broken by shard id (lower shard wins) so that exactly `winners_needed`
    elements are removed globally — the same resolution the oracle uses.
    """
    S, m = cand_keys.shape
    below = jnp.sum(cand_keys < threshold_key, axis=1).astype(jnp.int32)  # (S,)
    at = jnp.sum(cand_keys == threshold_key, axis=1).astype(jnp.int32)  # (S,)
    remaining = winners_needed - jnp.sum(below)
    # Prefix allocation of tie slots by shard id.
    tie_prefix = jnp.cumsum(at) - at
    tie_take = jnp.clip(remaining - tie_prefix, 0, at)
    return below + tie_take.astype(jnp.int32)
