"""Per-shard local primitives, vectorized over the shard axis.

The hot-spot primitives (windowed head merge for insert, bitonic top-k for
the deleteMin tournament, the elimination-match sort, MULTIQ probe/select)
dispatch through `repro.kernels.registry` — per-(platform, shape) arm
choice between the jnp paths and the Pallas networks, all bit-identical
(tests sweep every arm).

All hot-path functions operate on the **head tier** ``(S, H)`` of the tiered
`PQState` (H static, small) so per-step cost scales with the batch /
head-window size rather than the queue capacity.  The cold tail arena
``(S, T)`` is touched only by O(batch) appends and by the rare,
``lax.cond``-guarded rebalances (`refill_head`, the overflow branch of
`tiered_insert`), which are the only O(capacity) code paths left.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue.state import INF_KEY, PQState

_INT32_MIN = jnp.iinfo(jnp.int32).min
_INT32_MAX = jnp.iinfo(jnp.int32).max

# Static width of the tail's unsorted append bucket.  When a shard's bucket
# would outgrow it, the cond-guarded compaction sorts the BUCKET only
# (O(U log U), U static) and rank-merges it into the leading sorted run
# (O(T)) — replacing the old full O(T log T) tail sort on every refill.
TAIL_BUCKET_WIDTH = 256

# Renumber horizon: force a rebalance (which renumbers seqs positionally)
# well before a shard's monotone next_seq could wrap int32.
SEQ_RENUMBER_THRESHOLD = _INT32_MAX - (1 << 24)

# Kernel dispatch lives in `repro.kernels.registry`: every hot-path
# primitive below forwards to its `repro.kernels.ops` wrapper, which picks
# an implementation arm per (platform, shape) — tuned winner when the
# tuning cache has one, safe jnp default otherwise.  Pass ``arm=`` (or use
# `registry.force_arms`) to pin a specific arm in tests/benchmarks.


def _key_seq_order(keys: jnp.ndarray, seq: jnp.ndarray) -> jnp.ndarray:
    """Row-wise argsort by (key, seq) lexicographic — the stable
    linearization order.  (x64 is disabled in this container, so the order
    is two chained stable sorts rather than a packed int64 key.)"""
    return jnp.lexsort((seq, keys), axis=1)


# ---------------------------------------------------------------------------
# windowed merge — the insert hot spot
# ---------------------------------------------------------------------------


def merge_head_run(
    head_k: jnp.ndarray,  # (S, H) ascending, INF-padded
    head_v: jnp.ndarray,
    head_q: jnp.ndarray,
    run_k: jnp.ndarray,  # (S, R) ascending, INF-padded
    run_v: jnp.ndarray,
    run_q: jnp.ndarray,
    arm: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-width merge of two ascending runs: (S, H) + (S, R) -> (S, H+R).

    Positional-stable (ties order head before run, in-position within each),
    which — together with the strict head/tail boundary split — keeps head
    equal-key entries in seq order without ever comparing seqs on the hot
    path.  Dispatches through the `windowed_merge` registry entry: the
    ``rank`` arm is `rank_merge_head_run` below (the XLA:CPU production
    path); the Pallas arms run the bitonic windowed-merge network
    (`kernels.windowed_merge`).  All arms are bit-identical (tested).

    Cost is O(H + R) per shard row — independent of the queue capacity.
    """
    from repro.kernels.ops import windowed_merge

    return windowed_merge(head_k, head_v, head_q, run_k, run_v, run_q,
                          arm=arm)


def rank_merge_head_run(
    head_k: jnp.ndarray,  # (S, H) ascending, INF-padded
    head_v: jnp.ndarray,
    head_q: jnp.ndarray,
    run_k: jnp.ndarray,  # (S, R) ascending, INF-padded
    run_v: jnp.ndarray,
    run_q: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The ``rank`` arm of `merge_head_run` — scatter- and sort-free
    searchsorted rank merge (registered in `repro.kernels.registry`)."""
    S, H = head_k.shape
    R = run_k.shape[1]
    # Gather formulation (XLA:CPU scatter is a serialized per-index loop —
    # the old position-scatter was the single hottest op of the step; wide
    # variadic sorts degrade superlinearly, so a concat-and-stable-sort is
    # no better).  Each head element's output position is its own index
    # plus its rank among the run ('left': count strictly less — the stable
    # head-before-run tie break); pos_head is strictly increasing, so for
    # every output slot p a searchsorted finds whether p is a head slot
    # (and which), else p is the (p - #head-before)th run element.  Pure
    # searchsorted + gather + where; bit-identical to the scatter form (the
    # positions are the same permutation of [0, H+R)).
    rank_head = jax.vmap(
        lambda inc, k: jnp.searchsorted(inc, k, side="left")
    )(run_k, head_k).astype(jnp.int32)
    pos_head = jnp.arange(H, dtype=jnp.int32)[None, :] + rank_head  # (S, H)

    p = jnp.broadcast_to(
        jnp.arange(H + R, dtype=jnp.int32)[None, :], (S, H + R)
    )
    ia = jax.vmap(
        lambda ph, q: jnp.searchsorted(ph, q, side="left")
    )(pos_head, p).astype(jnp.int32)
    ia_c = jnp.minimum(ia, H - 1)
    from_head = (ia < H) & (jnp.take_along_axis(pos_head, ia_c, axis=1) == p)
    ib = jnp.clip(p - ia, 0, R - 1)

    def pick(head_x, run_x):
        return jnp.where(
            from_head,
            jnp.take_along_axis(head_x, ia_c, axis=1),
            jnp.take_along_axis(run_x, ib, axis=1),
        )

    out_k = pick(head_k, run_k)
    # arm-equality contract (kernels/ops.py): payloads on INF sentinel
    # lanes are zeroed by every arm, so tuning can swap arms without
    # changing a single downstream state byte
    valid = out_k < INF_KEY
    out_v = jnp.where(valid, pick(head_v, run_v), 0)
    out_q = jnp.where(valid, pick(head_q, run_q), 0)
    return out_k, out_v, out_q


# ---------------------------------------------------------------------------
# head-tier removal primitives (O(H) per shard, H static)
# ---------------------------------------------------------------------------


def remove_prefix(
    keys: jnp.ndarray,  # (S, W) ascending head tier
    vals: jnp.ndarray,
    seq: jnp.ndarray,
    size: jnp.ndarray,  # (S,)
    take: jnp.ndarray,  # (S,) number of smallest elements to remove per shard
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Remove the `take[s]` smallest elements of shard s (always a prefix of
    the sorted head — the tournament only ever consumes head prefixes).
    Implemented as a per-row left shift."""
    S, W = keys.shape
    idx = jnp.arange(W, dtype=jnp.int32)[None, :] + take[:, None]  # (S, W)
    in_range = idx < W
    idx = jnp.minimum(idx, W - 1)
    new_keys = jnp.where(
        in_range, jnp.take_along_axis(keys, idx, axis=1), INF_KEY
    )
    new_vals = jnp.where(in_range, jnp.take_along_axis(vals, idx, axis=1), 0)
    new_seq = jnp.where(in_range, jnp.take_along_axis(seq, idx, axis=1), 0)
    new_size = jnp.maximum(size - take, 0).astype(jnp.int32)
    return new_keys, new_vals, new_seq, new_size


def remove_at(
    keys: jnp.ndarray,  # (S, H) head tier
    vals: jnp.ndarray,
    seq: jnp.ndarray,
    size: jnp.ndarray,
    remove_mask: jnp.ndarray,  # (S, W) bool, W <= H — positions to delete
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Remove arbitrary positions inside the static spray window W (spray
    pops random slots in the top region; columns beyond W are untouched by
    construction).  Scatter- and sort-free compaction: survivor p's source
    slot is the first window index whose inclusive keep-count reaches p+1 —
    a row-wise searchsorted over the cumulative keep mask, followed by
    take_along gathers (XLA:CPU runs sorts with payload operands orders of
    magnitude slower than this).  The untouched suffix then splices back
    behind the survivors with affine shifted gathers — O(W log W + H) per
    row."""
    S, H = keys.shape
    W = remove_mask.shape[1]
    assert W <= H, (W, H)
    win_k = keys[:, :W]
    hit = remove_mask & (win_k != INF_KEY)
    n_removed = jnp.sum(hit, axis=1).astype(jnp.int32)

    keep_rank = jnp.cumsum(~remove_mask, axis=1).astype(jnp.int32)  # (S, W)
    q = jnp.broadcast_to(jnp.arange(1, W + 1, dtype=jnp.int32)[None, :],
                         (S, W))
    src = jax.vmap(
        lambda kr, qq: jnp.searchsorted(kr, qq, side="left")
    )(keep_rank, q).astype(jnp.int32)
    src_ok = src < W
    src = jnp.minimum(src, W - 1)
    win_sorted_k = jnp.where(
        src_ok, jnp.take_along_axis(win_k, src, axis=1), INF_KEY
    )
    win_sorted_v = jnp.where(
        src_ok, jnp.take_along_axis(vals[:, :W], src, axis=1), 0
    )
    win_sorted_q = jnp.where(
        src_ok, jnp.take_along_axis(seq[:, :W], src, axis=1), 0
    )
    pad = H - W
    if pad:
        win_sorted_k = jnp.pad(win_sorted_k, ((0, 0), (0, pad)),
                               constant_values=INF_KEY)
        win_sorted_v = jnp.pad(win_sorted_v, ((0, 0), (0, pad)))
        win_sorted_q = jnp.pad(win_sorted_q, ((0, 0), (0, pad)))

    # survivors in the window, then the suffix shifted left to close the gap
    v_in_win = jnp.minimum(size, W) - n_removed  # (S,)
    shift = W - v_in_win  # = n_removed + window INF padding
    col = jnp.arange(H, dtype=jnp.int32)[None, :]
    suf_idx = col + shift[:, None]
    suf_ok = suf_idx < H
    suf_idx = jnp.minimum(suf_idx, H - 1)
    suf_k = jnp.where(suf_ok, jnp.take_along_axis(keys, suf_idx, axis=1),
                      INF_KEY)
    suf_v = jnp.where(suf_ok, jnp.take_along_axis(vals, suf_idx, axis=1), 0)
    suf_q = jnp.where(suf_ok, jnp.take_along_axis(seq, suf_idx, axis=1), 0)

    sel = col < v_in_win[:, None]
    new_keys = jnp.where(sel, win_sorted_k, suf_k)
    new_vals = jnp.where(sel, win_sorted_v, suf_v)
    new_seq = jnp.where(sel, win_sorted_q, suf_q)
    new_size = jnp.maximum(size - n_removed, 0).astype(jnp.int32)
    return new_keys, new_vals, new_seq, new_size


# ---------------------------------------------------------------------------
# bucketed tail arena: sorted run + append bucket, merge-on-rebalance
# ---------------------------------------------------------------------------


def _renumber_seqs(
    head_seq: jnp.ndarray,  # (S, H)
    tail_seq: jnp.ndarray,  # (S, T)
    head_size: jnp.ndarray,  # (S,)
    tail_size: jnp.ndarray,  # (S,)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Positional seq renumbering — the int32-wrap fix (ROADMAP item).

    Precondition: slot order == linearization order in BOTH tiers (head
    sorted with equal-key runs in seq order; tail fully (key, seq)-lex
    sorted) — exactly the state every rebalance sort produces.  Then
    ``head slot i -> seq i`` and ``tail slot j -> seq head_size + j``
    preserves every relative (key, seq) comparison while resetting
    ``next_seq`` to the shard population.  Side effect the bucket merge
    relies on: the sorted run's seq column becomes globally ascending."""
    S, H = head_seq.shape
    T = tail_seq.shape[1]
    col_h = jnp.arange(H, dtype=jnp.int32)[None, :]
    new_hq = jnp.where(col_h < head_size[:, None], col_h, 0)
    if T:
        col_t = jnp.arange(T, dtype=jnp.int32)[None, :]
        new_tq = jnp.where(
            col_t < tail_size[:, None], head_size[:, None] + col_t, 0
        )
    else:
        new_tq = tail_seq
    return new_hq, new_tq, (head_size + tail_size).astype(jnp.int32)


def _tail_window(state: PQState):
    """Masked (key, val, seq) views of the tail's sliding window: stale
    out-of-window slots read (INF, 0, 0).  The validity predicate is owned
    by `PQState._tail_window_mask` (shared with the keys/vals views and the
    invariant checker)."""
    win = state._tail_window_mask()
    return (
        jnp.where(win, state.tail_keys, INF_KEY),
        jnp.where(win, state.tail_vals, 0),
        jnp.where(win, state.tail_seq, 0),
    )


def _full_sort_tail(state: PQState) -> PQState:
    """Fallback compaction: (key, seq)-lex sort of the tail window, then
    renumber; the window re-anchors at 0.  O(T log T) — taken only when the
    append bucket exceeded its static width (batches wider than
    TAIL_BUCKET_WIDTH)."""
    wk, wv, wq = _tail_window(state)
    order = _key_seq_order(wk, wq)
    tk = jnp.take_along_axis(wk, order, axis=1)
    tv = jnp.take_along_axis(wv, order, axis=1)
    tq = jnp.take_along_axis(wq, order, axis=1)
    hq, tq, nseq = _renumber_seqs(
        state.head_seq, tq, state.head_size, state.tail_size
    )
    return dataclasses.replace(
        state, tail_keys=tk, tail_vals=tv, tail_seq=tq, head_seq=hq,
        tail_start=jnp.zeros_like(state.tail_start),
        tail_sorted=state.tail_size, next_seq=nseq,
    )


def _bucket_merge_tail(state: PQState) -> PQState:
    """Sort the append bucket and rank-merge it into the sorted run.

    Cost per shard row: O(U log U) for the bucket sort (U = static
    TAIL_BUCKET_WIDTH) + O(T + U log T) for the merge — the O(T) tail
    rebalance the ROADMAP asked for.  The lexicographic (key, seq) merge
    needs no packed 64-bit keys: the run's seq column is globally ascending
    (renumbering invariant), so the count of run elements lex-below a bucket
    element is ``clip(ss(run.seq, b.seq), ss(run.key, b.key, L),
    ss(run.key, b.key, R))`` — three searchsorteds."""
    S, T = state.tail_keys.shape
    U = min(T, TAIL_BUCKET_WIDTH)
    a_len = state.tail_sorted  # (S,) sorted-run lengths
    b_len = state.tail_size - a_len  # (S,) bucket lengths, <= U (guarded)
    t0 = state.tail_start
    col_t = jnp.arange(T, dtype=jnp.int32)[None, :]
    col_u = jnp.arange(U, dtype=jnp.int32)[None, :]
    row = jnp.arange(S, dtype=jnp.int32)[:, None]

    # -- extract + lex-sort the bucket (window offset t0 + a_len) ------------
    gidx = jnp.clip(t0[:, None] + a_len[:, None] + col_u, 0, T - 1)
    b_valid = col_u < b_len[:, None]
    bk = jnp.where(b_valid, jnp.take_along_axis(state.tail_keys, gidx, axis=1),
                   INF_KEY)
    bv = jnp.where(b_valid, jnp.take_along_axis(state.tail_vals, gidx, axis=1),
                   0)
    bq = jnp.where(b_valid, jnp.take_along_axis(state.tail_seq, gidx, axis=1),
                   _INT32_MAX)
    order = _key_seq_order(bk, bq)
    bk = jnp.take_along_axis(bk, order, axis=1)
    bv = jnp.take_along_axis(bv, order, axis=1)
    bq = jnp.take_along_axis(bq, order, axis=1)

    # -- 0-aligned view of the sorted run (gather from the window) -----------
    a_idx = jnp.clip(t0[:, None] + col_t, 0, T - 1)
    a_valid = col_t < a_len[:, None]
    ak = jnp.where(
        a_valid, jnp.take_along_axis(state.tail_keys, a_idx, axis=1), INF_KEY
    )
    av = jnp.where(
        a_valid, jnp.take_along_axis(state.tail_vals, a_idx, axis=1), 0
    )
    aq = jnp.where(
        a_valid, jnp.take_along_axis(state.tail_seq, a_idx, axis=1),
        _INT32_MAX,
    )  # ascending overall

    # -- lexicographic ranks of bucket elements in the run -------------------
    lo = jax.vmap(lambda a, b: jnp.searchsorted(a, b, side="left"))(ak, bk)
    hi = jax.vmap(lambda a, b: jnp.searchsorted(a, b, side="right"))(ak, bk)
    sq = jax.vmap(lambda a, b: jnp.searchsorted(a, b, side="left"))(aq, bq)
    pos_b = jnp.clip(sq, lo, hi).astype(jnp.int32) + col_u  # (S, U)

    # -- scatter bucket, fill run into the complement slots ------------------
    occ = jnp.zeros((S, T), jnp.int32).at[row, pos_b].set(1, mode="drop")
    sk = jnp.full((S, T), INF_KEY, jnp.int32).at[row, pos_b].set(bk, mode="drop")
    sv = jnp.zeros((S, T), jnp.int32).at[row, pos_b].set(bv, mode="drop")
    sq_out = jnp.zeros((S, T), jnp.int32).at[row, pos_b].set(bq, mode="drop")
    run_idx = jnp.clip(col_t - jnp.cumsum(occ, axis=1), 0, T - 1)
    is_b = occ == 1
    mk = jnp.where(is_b, sk, jnp.take_along_axis(ak, run_idx, axis=1))
    mv = jnp.where(is_b, sv, jnp.take_along_axis(av, run_idx, axis=1))
    mq = jnp.where(is_b, sq_out, jnp.take_along_axis(aq, run_idx, axis=1))

    out_valid = col_t < state.tail_size[:, None]
    mk = jnp.where(out_valid, mk, INF_KEY)
    mv = jnp.where(out_valid, mv, 0)
    mq = jnp.where(out_valid, mq, 0)
    hq, mq, nseq = _renumber_seqs(
        state.head_seq, mq, state.head_size, state.tail_size
    )
    return dataclasses.replace(
        state, tail_keys=mk, tail_vals=mv, tail_seq=mq, head_seq=hq,
        tail_start=jnp.zeros_like(state.tail_start),
        tail_sorted=state.tail_size, next_seq=nseq,
    )


def compact_tail(state: PQState) -> PQState:
    """Make the tail fully sorted (tail_sorted == tail_size) and renumber
    seqs.  Bucket path when every shard's bucket fits the static window,
    full-sort fallback otherwise.  Callers cond-guard the invocation."""
    if state.tail_width == 0:
        return state
    U = min(state.tail_width, TAIL_BUCKET_WIDTH)
    fits = jnp.all(state.tail_size - state.tail_sorted <= U)
    return jax.lax.cond(fits, _bucket_merge_tail, _full_sort_tail, state)


# ---------------------------------------------------------------------------
# tiered insert + rebalance (the only O(capacity) paths, cond-guarded)
# ---------------------------------------------------------------------------


def tiered_insert(
    state: PQState,
    rk: jnp.ndarray,  # (S, R) routed runs, ascending, INF-padded
    rv: jnp.ndarray,
    counts: jnp.ndarray,  # (S,) valid entries per run
) -> Tuple[PQState, jnp.ndarray]:
    """Insert routed runs into the tiered state.  Returns (state, dropped).

    Rank-split each run against the shard's head boundary key: head-bound
    keys (strictly below the boundary) merge into the (S, H) hot tier via
    the windowed merge; merge overflow (the largest elements) and tail-bound
    keys append to the tail's unsorted bucket in O(batch).  Two cond-guarded
    rebalances cover the rare paths: (a) when a shard's append bucket would
    outgrow its static width — or next_seq nears the int32 wrap — the tail
    is compacted (bucket sort + O(T) rank merge, seqs renumbered); (b) only
    when a shard's arena cannot hold the append does the overflow branch run
    a full (key, seq) sort that keeps the C smallest of the union and
    reports the rest in `dropped` — the same semantics the old full-width
    merge had on every step, now paid only at capacity.
    """
    S, H = state.head_keys.shape
    T = state.tail_width
    R = rk.shape[1]
    col = jnp.arange(R, dtype=jnp.int32)[None, :]
    valid = col < counts[:, None]

    if T == 0:
        rq = jnp.where(valid, state.next_seq[:, None] + col, 0)
        # Single-tier degenerate case (capacity <= head width): plain
        # windowed merge, overflow (necessarily the largest) is dropped.
        mk, mv, mq = merge_head_run(
            state.head_keys, state.head_vals, state.head_seq, rk, rv, rq
        )
        dropped = jnp.maximum(state.head_size + counts - H, 0).astype(jnp.int32)
        new_state = dataclasses.replace(
            state,
            head_keys=mk[:, :H], head_vals=mv[:, :H], head_seq=mq[:, :H],
            head_size=jnp.minimum(state.head_size + counts, H).astype(jnp.int32),
            next_seq=state.next_seq + counts,
        )
        return new_state, dropped

    # -- cond-guarded bucket compaction (before seq assignment so the run's
    # fresh seqs come from the renumbered counter).  Fires when the append
    # bucket would outgrow its static width, when the sliding window would
    # creep off the arena end, or when next_seq nears the int32 wrap.
    U = min(T, TAIL_BUCKET_WIDTH)
    bucket_after = state.tail_size - state.tail_sorted + counts
    need_compact = (
        jnp.any(bucket_after > U)
        | jnp.any(state.tail_start + state.tail_size + counts > T)
        | jnp.any(state.next_seq + counts > SEQ_RENUMBER_THRESHOLD)
    )
    state = jax.lax.cond(need_compact, compact_tail, lambda s: s, state)
    rq = jnp.where(valid, state.next_seq[:, None] + col, 0)

    # -- strict boundary split ------------------------------------------------
    row = jnp.arange(S, dtype=jnp.int32)[:, None]
    hmax = jnp.take_along_axis(
        state.head_keys,
        jnp.clip(state.head_size - 1, 0, H - 1)[:, None], axis=1,
    )[:, 0]
    hmax = jnp.where(state.head_size > 0, hmax, _INT32_MIN)
    # tail empty: everything is head-bound (spill restores the boundary);
    # tail non-empty: only keys STRICTLY below the head max may enter the
    # head — ties go to the tail, which keeps equal-key seqs ordered across
    # the boundary (I4) without any hot-path seq comparison.
    bkey = jnp.where(state.tail_size > 0, hmax, INF_KEY)
    n_head = jax.vmap(
        lambda r, b: jnp.searchsorted(r, b, side="left")
    )(rk, bkey).astype(jnp.int32)

    hb_sel = col < n_head[:, None]
    hrun_k = jnp.where(hb_sel, rk, INF_KEY)
    hrun_v = jnp.where(hb_sel, rv, 0)
    hrun_q = jnp.where(hb_sel, rq, 0)

    n_tail_inc = counts - n_head
    t_idx = jnp.minimum(col + n_head[:, None], R - 1)
    tb_sel = col < n_tail_inc[:, None]
    trun_k = jnp.where(tb_sel, jnp.take_along_axis(rk, t_idx, axis=1), INF_KEY)
    trun_v = jnp.where(tb_sel, jnp.take_along_axis(rv, t_idx, axis=1), 0)
    trun_q = jnp.where(tb_sel, jnp.take_along_axis(rq, t_idx, axis=1), 0)

    # -- hot-tier merge + spill ----------------------------------------------
    mk, mv, mq = merge_head_run(
        state.head_keys, state.head_vals, state.head_seq,
        hrun_k, hrun_v, hrun_q,
    )
    nh_k, nh_v, nh_q = mk[:, :H], mv[:, :H], mq[:, :H]
    sp_k, sp_v, sp_q = mk[:, H:], mv[:, H:], mq[:, H:]  # (S, R) spill run
    n_spill = jnp.maximum(state.head_size + n_head - H, 0).astype(jnp.int32)
    new_hsize = jnp.minimum(state.head_size + n_head, H).astype(jnp.int32)

    n_append = n_tail_inc + n_spill
    valid_total = state.head_size + state.tail_size + counts

    def no_overflow(op):
        tk, tv, tq, tsize = op
        # Gather append (scatter-free — see merge_head_run): the combined
        # append run is trun ++ spill (width 2R); tail slot t takes
        # arun[t - tail_size] when it lands in the append window, else
        # keeps its value.
        col2 = jnp.arange(2 * R, dtype=jnp.int32)[None, :]
        in_trun = col2 < n_tail_inc[:, None]
        idx_tr = jnp.clip(col2, 0, R - 1)
        idx_sp = jnp.clip(col2 - n_tail_inc[:, None], 0, R - 1)

        def arun(trun_x, sp_x):
            return jnp.where(
                in_trun,
                jnp.take_along_axis(trun_x, idx_tr, axis=1),
                jnp.take_along_axis(sp_x, idx_sp, axis=1),
            )

        colT = jnp.arange(T, dtype=jnp.int32)[None, :]
        rel = colT - (state.tail_start + tsize)[:, None]  # window-end slot
        in_app = (rel >= 0) & (rel < n_append[:, None])
        rel_c = jnp.clip(rel, 0, 2 * R - 1)

        def splice(tail_x, trun_x, sp_x):
            return jnp.where(
                in_app,
                jnp.take_along_axis(arun(trun_x, sp_x), rel_c, axis=1),
                tail_x,
            )

        return (
            nh_k, nh_v, nh_q,
            splice(tk, trun_k, sp_k),
            splice(tv, trun_v, sp_v),
            splice(tq, trun_q, sp_q),
            new_hsize, (tsize + n_append).astype(jnp.int32),
            state.tail_start,
            state.tail_sorted,  # appends only grow the unsorted bucket
            state.next_seq + counts,
            jnp.zeros((S,), jnp.int32),
        )

    def overflow(op):
        tk, tv, tq, tsize = op
        wk, wv, wq = _tail_window(state)  # stale slots masked out
        cat_k = jnp.concatenate([nh_k, wk, trun_k, sp_k], axis=1)
        cat_v = jnp.concatenate([nh_v, wv, trun_v, sp_v], axis=1)
        cat_q = jnp.concatenate([nh_q, wq, trun_q, sp_q], axis=1)
        order = _key_seq_order(cat_k, cat_q)
        sk = jnp.take_along_axis(cat_k, order, axis=1)[:, : H + T]
        sv = jnp.take_along_axis(cat_v, order, axis=1)[:, : H + T]
        sq = jnp.take_along_axis(cat_q, order, axis=1)[:, : H + T]
        dropped = jnp.maximum(valid_total - (H + T), 0).astype(jnp.int32)
        hsize_new = jnp.minimum(valid_total, H).astype(jnp.int32)
        tsize_new = jnp.clip(valid_total - H, 0, T).astype(jnp.int32)
        # The sort put both tiers in linearization order — renumber.
        hq_new, tq_new, nseq_new = _renumber_seqs(
            sq[:, :H], sq[:, H:], hsize_new, tsize_new
        )
        return (
            sk[:, :H], sv[:, :H], hq_new,
            sk[:, H:], sv[:, H:], tq_new,
            hsize_new, tsize_new,
            jnp.zeros((S,), jnp.int32),  # window re-anchored at 0
            tsize_new,  # fully sorted tail
            nseq_new,
            dropped,
        )

    out = jax.lax.cond(
        jnp.any(state.tail_size + n_append > T),
        overflow,
        no_overflow,
        (state.tail_keys, state.tail_vals, state.tail_seq, state.tail_size),
    )
    hk, hv, hq, tk, tv, tq, hsize, tsize, tstart, tsorted, nseq, dropped = out
    new_state = dataclasses.replace(
        state,
        head_keys=hk, head_vals=hv, head_seq=hq,
        tail_keys=tk, tail_vals=tv, tail_seq=tq,
        head_size=hsize, tail_size=tsize,
        tail_start=tstart, tail_sorted=tsorted, next_seq=nseq,
    )
    return new_state, dropped


def _consume_run(state: PQState) -> PQState:
    """Pull the sorted run's front into the head and advance the window
    origin — the tail arrays are READ but never rewritten.  Precondition:
    the append bucket is empty (compact_tail ran if needed).

    No merge network is needed: the boundary invariant I4 guarantees every
    tail key >= the head's max (boundary ties carry LARGER seqs in the
    tail), so the consumed run CONCATENATES after the head prefix — head
    slot p takes run element p - head_size, an affine per-row gather."""
    S, H = state.head_keys.shape
    T = state.tail_width
    take = jnp.minimum(H - state.head_size, state.tail_size).astype(jnp.int32)

    col = jnp.arange(H, dtype=jnp.int32)[None, :]
    rel = col - state.head_size[:, None]
    use_run = (rel >= 0) & (rel < take[:, None])
    ridx = jnp.clip(state.tail_start[:, None] + rel, 0, T - 1)

    def splice(head_x, tail_x):
        return jnp.where(
            use_run, jnp.take_along_axis(tail_x, ridx, axis=1), head_x
        )

    return dataclasses.replace(
        state,
        head_keys=splice(state.head_keys, state.tail_keys),
        head_vals=splice(state.head_vals, state.tail_vals),
        head_seq=splice(state.head_seq, state.tail_seq),
        head_size=(state.head_size + take).astype(jnp.int32),
        tail_size=(state.tail_size - take).astype(jnp.int32),
        tail_start=(state.tail_start + take).astype(jnp.int32),
        tail_sorted=(state.tail_size - take).astype(jnp.int32),
    )


def refill_head(state: PQState) -> PQState:
    """Restore the hot tier: pull the tail's (key, seq)-smallest elements
    into the head until it is full (or the tail is drained).

    With the sliding-window tail this CONSUMES the sorted run in place: the
    smallest elements are the run's front (gathered into the head merge),
    and the window origin just advances — the tail arrays are never
    rewritten.  Cost: O(H) for the merge + O(U log U + T) bucket compaction
    only when appends happened since the last rebalance.  `ensure_head`
    inlines this as two separately-guarded conds (see `refill_head_guarded`)
    so the common consume path's cond returns only head-sized buffers."""
    if state.tail_width == 0:
        return state
    state = jax.lax.cond(
        jnp.any(state.tail_size > state.tail_sorted),
        compact_tail, lambda s: s, state,
    )  # tail window now fully (key, seq)-lex sorted
    return _consume_run(state)


def refill_head_guarded(state: PQState, pred: jnp.ndarray) -> PQState:
    """`refill_head` under a predicate, structured so the common firing
    never copies the cold tail: (a) a full-state compact cond that only
    fires when appends left a bucket since the last rebalance; (b) a
    consume cond whose branches RETURN only the head tier + window scalars
    — the (S, T) tail arrays enter as read-only captures, so XLA's
    conditional materializes head-sized results instead of a capacity-sized
    state copy.  This is what keeps the fused window's steady drain cheap."""
    if state.tail_width == 0:
        return state
    state = jax.lax.cond(
        pred & jnp.any(state.tail_size > state.tail_sorted),
        compact_tail, lambda s: s, state,
    )

    def do(op):
        del op
        st = _consume_run(state)
        return (st.head_keys, st.head_vals, st.head_seq, st.head_size,
                st.tail_size, st.tail_start, st.tail_sorted)

    def skip(op):
        return op

    hk, hv, hq, hs, tsize, tstart, tsorted = jax.lax.cond(
        pred, do, skip,
        (state.head_keys, state.head_vals, state.head_seq, state.head_size,
         state.tail_size, state.tail_start, state.tail_sorted),
    )
    return dataclasses.replace(
        state, head_keys=hk, head_vals=hv, head_seq=hq, head_size=hs,
        tail_size=tsize, tail_start=tstart, tail_sorted=tsorted,
    )


# ---------------------------------------------------------------------------
# legacy full-width merge (kept as the reference for the capacity-wide
# Pallas kernel in kernels/sorted_merge.py; the insert hot path now uses
# merge_head_run + tiered_insert)
# ---------------------------------------------------------------------------


def merge_sorted(
    keys: jnp.ndarray,  # (S, C) ascending, INF-padded
    vals: jnp.ndarray,  # (S, C)
    inc_keys: jnp.ndarray,  # (S, R) ascending, INF-padded
    inc_vals: jnp.ndarray,  # (S, R)
    size: jnp.ndarray,  # (S,)
    inc_count: jnp.ndarray,  # (S,)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Merge a sorted incoming run into each shard's sorted buffer, keeping
    the C smallest (rank-based merge, stable toward existing elements).
    Returns (new_keys, new_vals, new_size, dropped)."""
    S, C = keys.shape
    R = inc_keys.shape[1]

    rank_exist = jax.vmap(
        lambda inc, k: jnp.searchsorted(inc, k, side="left")
    )(inc_keys, keys).astype(jnp.int32)
    rank_inc = jax.vmap(
        lambda k, inc: jnp.searchsorted(k, inc, side="right")
    )(keys, inc_keys).astype(jnp.int32)

    pos_exist = jnp.arange(C, dtype=jnp.int32)[None, :] + rank_exist  # (S, C)
    pos_inc = jnp.arange(R, dtype=jnp.int32)[None, :] + rank_inc  # (S, R)

    out_keys = jnp.full((S, C), INF_KEY, dtype=keys.dtype)
    out_vals = jnp.zeros((S, C), dtype=vals.dtype)
    row = jnp.arange(S, dtype=jnp.int32)[:, None]

    out_keys = out_keys.at[row, pos_exist].set(keys, mode="drop")
    out_vals = out_vals.at[row, pos_exist].set(vals, mode="drop")
    inc_is_pad = inc_keys == INF_KEY
    pos_inc = jnp.where(inc_is_pad, C + R, pos_inc)
    out_keys = out_keys.at[row, pos_inc].set(inc_keys, mode="drop")
    out_vals = out_vals.at[row, pos_inc].set(inc_vals, mode="drop")

    new_size = jnp.minimum(size + inc_count, C).astype(jnp.int32)
    dropped = jnp.maximum(size + inc_count - C, 0).astype(jnp.int32)
    return out_keys, out_vals, new_size, dropped


# ---------------------------------------------------------------------------
# elimination pre-pass primitive
# ---------------------------------------------------------------------------


def sort_op_log(
    masked_keys: jnp.ndarray,  # (B,) or (K, B) insert keys, INF for non-inserts
    arm: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable ascending sort of each row of an operation log, returning
    (sorted_keys, sorted_lane_tags).  State-independent, so a K-step fused
    window sorts its whole (K, B) log in ONE call in front of the scan.
    Dispatches through the `elim_sort` registry entry (stable per-row
    argsort vs the bitonic elimination-match network — all arms compare
    (key, lane-tag) lexicographically, so bit-identical)."""
    from repro.kernels.ops import elim_sort

    single = masked_keys.ndim == 1
    rows = masked_keys[None, :] if single else masked_keys
    K, B = rows.shape
    tags = jnp.broadcast_to(jnp.arange(B, dtype=jnp.int32)[None, :], (K, B))
    sk, st = elim_sort(rows, tags, arm=arm)
    return (sk[0], st[0]) if single else (sk, st)


# ---------------------------------------------------------------------------
# tournament / probe primitives (unchanged semantics, head-tier operands)
# ---------------------------------------------------------------------------


def topk_of_merged(
    cand_keys: jnp.ndarray,  # (N,) unsorted or blockwise-sorted candidates
    cand_vals: jnp.ndarray,  # (N,)
    m: int,
    arm: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Global tournament: the m smallest of N candidates, ascending.

    int32 keys dispatch through the `topk_smallest` registry entry (every
    arm sorts (key, position-tag) pairs lexicographically, then payloads
    are gathered by tag — bit-identical across arms, ties break by
    position).  Non-int32 keys take the plain stable argsort (no registered
    arms at other dtypes)."""
    if cand_keys.dtype == jnp.int32:
        from repro.kernels.ops import topk_smallest

        n = cand_keys.shape[0]
        tags = jnp.arange(n, dtype=jnp.int32)
        kk, kt = topk_smallest(cand_keys[None, :], tags[None, :], m, arm=arm)
        return kk[0], cand_vals[kt[0]]
    order = jnp.argsort(cand_keys, stable=True)[:m]
    return cand_keys[order], cand_vals[order]


def twochoice_pick(
    shard_mins: jnp.ndarray,  # (S,) cached per-shard minima (INF when empty)
    choice_a: jnp.ndarray,  # (m,) sampled shard ids
    choice_b: jnp.ndarray,  # (m,)
    act: jnp.ndarray,  # (m,) bool — inactive lanes commit nowhere
    arm: Optional[str] = None,
) -> jnp.ndarray:
    """MULTIQ probe/commit: each lane commits to the sampled shard with the
    smaller cached min (tie: lower id); returns per-shard commit counts.
    Dispatches through the `twochoice_counts` registry entry."""
    from repro.kernels.ops import twochoice_counts

    return twochoice_counts(shard_mins, choice_a, choice_b, act, arm=arm)


def multiq_select(
    win_k: jnp.ndarray,  # (S, m) ascending head windows
    win_v: jnp.ndarray,  # (S, m) payloads
    take: jnp.ndarray,  # (S,) commit counts (prefix pops)
    arm: Optional[str] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """m smallest of the masked head windows, ascending — the MULTIQ
    commit-side tournament.  Dispatches through the `multiq_select_topm`
    registry entry."""
    from repro.kernels.ops import multiq_select_topm

    return multiq_select_topm(win_k, win_v, take, arm=arm)


def count_winners_per_shard(
    cand_keys: jnp.ndarray,  # (S, m) each shard's candidate prefix
    threshold_key: jnp.ndarray,  # () the m-th smallest (winner cutoff)
    winners_needed: jnp.ndarray,  # () total winners to take (== active m)
) -> jnp.ndarray:
    """How many elements each shard loses to the tournament.

    Elements strictly below the cutoff always win.  Ties at the cutoff are
    broken by shard id (lower shard wins) so that exactly `winners_needed`
    elements are removed globally — the same resolution the oracle uses.
    """
    S, m = cand_keys.shape
    below = jnp.sum(cand_keys < threshold_key, axis=1).astype(jnp.int32)  # (S,)
    at = jnp.sum(cand_keys == threshold_key, axis=1).astype(jnp.int32)  # (S,)
    remaining = winners_needed - jnp.sum(below)
    # Prefix allocation of tie slots by shard id.
    tie_prefix = jnp.cumsum(at) - at
    tie_take = jnp.clip(remaining - tie_prefix, 0, at)
    return below + tie_take.astype(jnp.int32)
