"""Public batched priority-queue API (paper Fig. 6's insert/deleteMin pair).

Op batches are the bulk-synchronous translation of "p threads each issue one
operation": a step applies a vector of B ops.  The linearization applied is
inserts-before-deletes within a batch (any linearization of concurrent ops is
admissible for a concurrent PQ; this one is fixed and matched by the oracle).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue import schedules as SCH
from repro.core.pqueue.local import tiered_insert, topk_of_merged
from repro.core.pqueue.partition import route_capped, route_dense
from repro.core.pqueue.schedules import DeleteResult, Schedule, ensure_head
from repro.core.pqueue.state import INF_KEY, PQState

OP_INSERT = 0
OP_DELETE_MIN = 1


def insert(
    state: PQState,
    keys: jnp.ndarray,
    vals: jnp.ndarray,
    mask: jnp.ndarray | None = None,
    capacity_factor: float | None = None,
) -> Tuple[PQState, jnp.ndarray]:
    """Insert a batch.  Returns (state, dropped_per_shard).

    capacity_factor=None -> exact dense routing (no drops besides shard
    overflow); otherwise MoE-style capped routing (rejected ops reported in
    dropped accounting is the caller's to retry — used by the serving
    scheduler's admission path).
    """
    if mask is None:
        mask = keys < INF_KEY
    else:
        mask = mask & (keys < INF_KEY)  # INF is the reserved sentinel
    S = state.num_shards
    if capacity_factor is None:
        rk, rv, counts = route_dense(keys, vals, mask, S)
    else:
        rk, rv, counts, _rejected = route_capped(
            keys, vals, mask, S, capacity_factor
        )
    return tiered_insert(state, rk, rv, counts)


def delete_min(
    state: PQState,
    m: int,
    schedule: Schedule | int = Schedule.STRICT_FLAT,
    active: jnp.ndarray | int | None = None,
    rng: jax.Array | None = None,
    npods: int = 1,
) -> DeleteResult:
    """Delete (up to) `active` minima with a static bound of m.

    `schedule` may be a Python enum (static dispatch — separate XLA programs)
    — the dynamic lax.switch dispatch lives in SmartPQ, which is the paper's
    adaptive contribution.
    """
    if active is None:
        active = m
    active = jnp.asarray(active, jnp.int32)
    if rng is None:
        rng = jax.random.key(0)
    fn = SCH.SCHEDULE_FNS[Schedule(int(schedule))]
    return fn(state, m, active, rng, npods)


def peek_min(state: PQState, m: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-m (ascending) without removal — exact.  The (discarded) refill
    makes the head candidacy exact even when the hot tier has drained."""
    state = ensure_head(state, m)
    cand_k = state.head_keys[:, :m].ravel()
    cand_v = state.head_vals[:, :m].ravel()
    return topk_of_merged(cand_k, cand_v, m)


class OpBatchResult(NamedTuple):
    state: PQState
    deleted_keys: jnp.ndarray  # (B,) ascending, INF-padded
    deleted_vals: jnp.ndarray  # (B,)
    n_deleted: jnp.ndarray  # ()
    dropped: jnp.ndarray  # (S,) inserts lost to capacity overflow


def apply_op_batch(
    state: PQState,
    ops: jnp.ndarray,  # (B,) OP_INSERT / OP_DELETE_MIN
    keys: jnp.ndarray,  # (B,) insert keys (ignored for deletes)
    vals: jnp.ndarray,  # (B,)
    schedule: Schedule | int = Schedule.STRICT_FLAT,
    rng: jax.Array | None = None,
    npods: int = 1,
) -> OpBatchResult:
    """One bulk step of mixed operations — the unit the paper's
    serve_requests() loop processes per client group (Fig. 6 lines 86-97)."""
    B = ops.shape[0]
    ins_mask = ops == OP_INSERT
    n_del = jnp.sum(ops == OP_DELETE_MIN).astype(jnp.int32)

    state, dropped = insert(state, keys, vals, mask=ins_mask)
    res = delete_min(state, B, schedule=schedule, active=n_del, rng=rng, npods=npods)
    return OpBatchResult(res.state, res.keys, res.vals, res.n_out, dropped)
