"""Public batched priority-queue API (paper Fig. 6's insert/deleteMin pair).

Op batches are the bulk-synchronous translation of "p threads each issue one
operation": a step applies a vector of B ops.  The linearization applied is
inserts-before-deletes within a batch (any linearization of concurrent ops is
admissible for a concurrent PQ; this one is fixed and matched by the oracle).

Elimination/combining (Calciu et al.'s adaptive PQ, bulk-synchronous form):
a batch's inserts whose keys beat the current queue minimum are matched
against the SAME batch's deleteMins and served directly — the pairs never
touch `PQState`.  Under the inserts-before-deletes linearization this is
EXACT, not relaxed: an insert strictly below min(queue) is, post-insert,
among the n_del globally smallest whenever it is among the n_del smallest of
the batch's below-cutoff inserts, so the eliminated prefix (sorted by
(key, batch position) — the same tie order the oracle's routed-run seqs
realize) is exactly the prefix of the linearized delete result, and the
surviving inserts keep their relative seq order.  Exact schedules therefore
stay bit-identical to the oracle with elimination on (tested); relaxed
schedules only tighten their envelope (eliminated pairs have global rank
below every queue element).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue import local as L
from repro.core.pqueue import schedules as SCH
from repro.core.pqueue.local import tiered_insert, topk_of_merged
from repro.core.pqueue.partition import route_capped, route_dense
from repro.core.pqueue.schedules import DeleteResult, Schedule, ensure_head
from repro.core.pqueue.state import INF_KEY, PQState

OP_INSERT = 0
OP_DELETE_MIN = 1
# Padding sentinel for op batches of non-uniform width (trace lanes beyond
# the step's active client count).  Every consumer tests ops by equality
# against OP_INSERT / OP_DELETE_MIN, so a NOP lane is inert everywhere:
# excluded from insert masks, delete counts, AND the workload statistics
# SmartPQ's decision features are derived from.
OP_NOP = 2

_INT32_MIN = jnp.iinfo(jnp.int32).min

# Largest float32 value that casts into the valid int32 key range without
# overflow (float32 can't represent INF_KEY - 1 exactly; the nearest safely
# representable bound below 2**31 is 2**31 - 256).
_MAX_FINITE_KEY_F32 = float(2**31 - 256)


def sanitize_keys(
    keys: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Admission-boundary key sanitizer: (keys_int32, rejected_mask).

    Floating-point key batches are the adversarial entry: IEEE ordering would
    silently sort NaN/±inf keys *somewhere* (NaN placement is sort-
    implementation-defined), poisoning the queue order.  Instead, non-finite
    lanes are REJECTED — mapped to the inert `INF_KEY` sentinel (excluded
    from every insert mask) and reported in the returned mask so callers
    count them (`SmartPQStats.rejected`).  Finite float keys clamp into the
    representable int32 key range and cast.  Integer batches pass through
    unchanged with an all-False mask (INF_KEY is already the reserved
    sentinel and negative keys are legal), so the hot int path costs
    nothing — the dtype test is trace-time, never in the compiled graph.
    """
    if not jnp.issubdtype(keys.dtype, jnp.floating):
        return keys.astype(jnp.int32), jnp.zeros(keys.shape, bool)
    bad = ~jnp.isfinite(keys)
    clamped = jnp.clip(
        jnp.where(bad, 0.0, keys), float(_INT32_MIN), _MAX_FINITE_KEY_F32
    ).astype(jnp.int32)
    return jnp.where(bad, INF_KEY, clamped), bad


def insert(
    state: PQState,
    keys: jnp.ndarray,
    vals: jnp.ndarray,
    mask: jnp.ndarray | None = None,
    capacity_factor: float | None = None,
) -> Tuple[PQState, jnp.ndarray]:
    """Insert a batch.  Returns (state, dropped_per_shard).

    capacity_factor=None -> exact dense routing (no drops besides shard
    overflow); otherwise MoE-style capped routing (rejected ops reported in
    dropped accounting is the caller's to retry — used by the serving
    scheduler's admission path).

    The whole route+merge+append pipeline is `lax.cond`-guarded on the batch
    carrying ANY live insert: a delete-only step (the fig9 ins0 regime, and
    every post-elimination batch whose inserts were all matched) passes the
    state through untouched instead of merging an empty run.
    """
    if mask is None:
        mask = keys < INF_KEY
    else:
        mask = mask & (keys < INF_KEY)  # INF is the reserved sentinel
    S = state.num_shards

    def do_insert(st):
        if capacity_factor is None:
            rk, rv, counts = route_dense(keys, vals, mask, S)
        else:
            rk, rv, counts, _rejected = route_capped(
                keys, vals, mask, S, capacity_factor
            )
        return tiered_insert(st, rk, rv, counts)

    def skip(st):
        return st, jnp.zeros((S,), jnp.int32)

    return jax.lax.cond(jnp.any(mask), do_insert, skip, state)


# ---------------------------------------------------------------------------
# elimination/combining pre-pass
# ---------------------------------------------------------------------------


def elim_cutoff(state: PQState) -> jnp.ndarray:
    """The elimination threshold: the current global queue minimum, read
    from the head min cache in O(S).  When any shard's head has drained over
    a non-empty tail the cache may be stale, so elimination is disabled for
    the step (cutoff INT32_MIN eliminates nothing — `key < cutoff` is the
    strict test).  An empty queue yields INF: every insert beats it, which
    is exactly right (deletes would return the batch's own minima)."""
    stale = jnp.any((state.head_size == 0) & (state.tail_size > 0))
    return jnp.where(stale, jnp.int32(_INT32_MIN), jnp.min(state.shard_mins))


def elim_split(
    state: PQState,
    sorted_keys: jnp.ndarray,  # (B,) insert log sorted ascending, INF-masked
    sorted_tags: jnp.ndarray,  # (B,) originating lane of each sorted entry
    vals: jnp.ndarray,  # (B,) lane payloads
    b_del: jnp.ndarray,  # () deleteMins in the batch
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Match the sorted insert log against the batch's deleteMins.

    Returns (elim_keys (B,) ascending INF-padded, elim_vals, n_elim,
    keep_mask (B,) by lane).  The eliminated set is the n_elim = min(#below
    cutoff, b_del) smallest below-cutoff inserts in (key, batch-position)
    order — the exact prefix of the linearized delete result (module
    docstring)."""
    B = sorted_keys.shape[0]
    cutoff = elim_cutoff(state)
    n_below = jnp.searchsorted(sorted_keys, cutoff, side="left").astype(
        jnp.int32
    )
    n_elim = jnp.minimum(n_below, b_del).astype(jnp.int32)
    lane = jnp.arange(B, dtype=jnp.int32)
    elim_k = jnp.where(lane < n_elim, sorted_keys, INF_KEY)
    elim_v = jnp.where(
        lane < n_elim, vals[jnp.clip(sorted_tags, 0, B - 1)], 0
    )
    # A lane is eliminated iff its sorted position ranks inside the prefix.
    rank = jnp.zeros((B,), jnp.int32).at[sorted_tags].set(lane)
    keep = rank >= n_elim
    return elim_k, elim_v, n_elim, keep


def merge_eliminated(
    elim_k: jnp.ndarray,  # (B,) ascending, INF-padded
    elim_v: jnp.ndarray,
    n_elim: jnp.ndarray,  # ()
    res: DeleteResult,
) -> DeleteResult:
    """Prepend the eliminated pairs to a schedule's delete result.  Every
    eliminated key is strictly below the cutoff <= every key the schedule
    could return, so the merge is a concatenation-with-shift — the combined
    output stays ascending with the oracle's tie order."""
    B = res.keys.shape[0]
    lane = jnp.arange(B, dtype=jnp.int32)
    idx = jnp.clip(lane - n_elim, 0, B - 1)
    out_k = jnp.where(lane < n_elim, elim_k, res.keys[idx])
    out_v = jnp.where(lane < n_elim, elim_v, res.vals[idx])
    return DeleteResult(res.state, out_k, out_v, res.n_out + n_elim)


def delete_min(
    state: PQState,
    m: int,
    schedule: Schedule | int = Schedule.STRICT_FLAT,
    active: jnp.ndarray | int | None = None,
    rng: jax.Array | None = None,
    npods: int = 1,
) -> DeleteResult:
    """Delete (up to) `active` minima with a static bound of m.

    `schedule` may be a Python enum (static dispatch — separate XLA programs)
    — the dynamic lax.switch dispatch lives in SmartPQ, which is the paper's
    adaptive contribution.
    """
    if active is None:
        active = m
    active = jnp.asarray(active, jnp.int32)
    if rng is None:
        rng = jax.random.key(0)
    fn = SCH.SCHEDULE_FNS[Schedule(int(schedule))]
    return fn(state, m, active, rng, npods)


def peek_min(state: PQState, m: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-m (ascending) without removal — exact.  The (discarded) refill
    makes the head candidacy exact even when the hot tier has drained."""
    state = ensure_head(state, m)
    cand_k = state.head_keys[:, :m].ravel()
    cand_v = state.head_vals[:, :m].ravel()
    return topk_of_merged(cand_k, cand_v, m)


class OpBatchResult(NamedTuple):
    state: PQState
    deleted_keys: jnp.ndarray  # (B,) ascending, INF-padded
    deleted_vals: jnp.ndarray  # (B,)
    n_deleted: jnp.ndarray  # ()
    dropped: jnp.ndarray  # (S,) inserts lost to capacity overflow


def apply_op_batch(
    state: PQState,
    ops: jnp.ndarray,  # (B,) OP_INSERT / OP_DELETE_MIN
    keys: jnp.ndarray,  # (B,) insert keys (ignored for deletes)
    vals: jnp.ndarray,  # (B,)
    schedule: Schedule | int = Schedule.STRICT_FLAT,
    rng: jax.Array | None = None,
    npods: int = 1,
    eliminate: bool = False,
) -> OpBatchResult:
    """One bulk step of mixed operations — the unit the paper's
    serve_requests() loop processes per client group (Fig. 6 lines 86-97).

    eliminate=True runs the elimination/combining pre-pass first: matched
    insert/deleteMin pairs are served without touching the queue (module
    docstring); exact schedules remain bit-identical to the oracle."""
    B = ops.shape[0]
    ins_mask = ops == OP_INSERT
    n_del = jnp.sum(ops == OP_DELETE_MIN).astype(jnp.int32)

    if eliminate:
        sk, st = L.sort_op_log(jnp.where(ins_mask, keys, INF_KEY))
        elim_k, elim_v, n_elim, keep = elim_split(state, sk, st, vals, n_del)
        state, dropped = insert(state, keys, vals, mask=ins_mask & keep)
        res = delete_min(
            state, B, schedule=schedule, active=n_del - n_elim, rng=rng,
            npods=npods,
        )
        res = merge_eliminated(elim_k, elim_v, n_elim, res)
    else:
        state, dropped = insert(state, keys, vals, mask=ins_mask)
        res = delete_min(
            state, B, schedule=schedule, active=n_del, rng=rng, npods=npods
        )
    return OpBatchResult(res.state, res.keys, res.vals, res.n_out, dropped)
