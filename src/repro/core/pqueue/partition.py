"""Routing a batch of (key, value) ops to their owning shards.

Mirrors MoE token dispatch: compute the destination shard per key, then build
a dense (S, R) routed matrix (INF-padded, ascending per row).  On the
distributed backend the same layout feeds `all_to_all`; on the single-device
semantic backend it feeds the vectorized per-shard merge directly.

R (per-shard receive capacity) is static.  `route_dense` uses R = B (exact,
no drops — used by tests/benchmarks).  `route_capped` uses a capacity factor
like MoE dispatch and reports overflow, which is what the serving scheduler
uses at scale.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue.state import INF_KEY
from repro.utils.hashing import shard_of_key


def route_dense(
    keys: jnp.ndarray,  # (B,) int32
    vals: jnp.ndarray,  # (B,) int32
    mask: jnp.ndarray,  # (B,) bool — valid ops
    num_shards: int,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Exact routing. Returns (routed_keys (S, B), routed_vals (S, B),
    counts (S,)). Each row ascending, INF-padded."""
    B = keys.shape[0]
    dest = shard_of_key(keys, num_shards)
    dest = jnp.where(mask, dest, num_shards)  # invalid -> virtual shard S

    # (S, B) one-hot placement, then per-row sort pulls real keys to front in
    # ascending order (INF sentinel tails).
    hit = dest[None, :] == jnp.arange(num_shards, dtype=jnp.int32)[:, None]
    routed_keys = jnp.where(hit, keys[None, :], INF_KEY)
    order = jnp.argsort(routed_keys, axis=1)
    routed_keys = jnp.take_along_axis(routed_keys, order, axis=1)
    routed_vals = jnp.take_along_axis(
        jnp.where(hit, vals[None, :], 0), order, axis=1
    )
    counts = jnp.sum(hit & mask[None, :], axis=1).astype(jnp.int32)
    return routed_keys, routed_vals, counts


def route_capped(
    keys: jnp.ndarray,
    vals: jnp.ndarray,
    mask: jnp.ndarray,
    num_shards: int,
    capacity_factor: float = 2.0,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """MoE-style capped routing: per-shard receive slots
    R = ceil(B / S * capacity_factor).  Ops beyond R for a shard are dropped
    and reported via `rejected` so the caller can retry next step (the serving
    scheduler re-enqueues).  Returns (routed_keys (S, R), routed_vals (S, R),
    counts (S,), rejected (B,) bool)."""
    B = keys.shape[0]
    R = max(1, int(-(-B * capacity_factor // num_shards)))
    R = min(R, B)
    dest = shard_of_key(keys, num_shards)
    dest = jnp.where(mask, dest, num_shards)

    hit = dest[None, :] == jnp.arange(num_shards, dtype=jnp.int32)[:, None]
    # Position of each op within its destination shard's receive buffer.
    pos_in_shard = jnp.cumsum(hit, axis=1) - 1  # (S, B)
    pos = jnp.sum(jnp.where(hit, pos_in_shard, 0), axis=0)  # (B,)
    keep = mask & (pos < R)
    rejected = mask & ~keep

    # Scatter into (S, R).
    routed_keys = jnp.full((num_shards, R), INF_KEY, dtype=keys.dtype)
    routed_vals = jnp.zeros((num_shards, R), dtype=vals.dtype)
    d = jnp.where(keep, dest, num_shards)  # drop rejected
    routed_keys = routed_keys.at[d, jnp.where(keep, pos, 0)].set(
        jnp.where(keep, keys, INF_KEY), mode="drop"
    )
    routed_vals = routed_vals.at[d, jnp.where(keep, pos, 0)].set(
        jnp.where(keep, vals, 0), mode="drop"
    )
    # Ascending per row for the merge.
    order = jnp.argsort(routed_keys, axis=1)
    routed_keys = jnp.take_along_axis(routed_keys, order, axis=1)
    routed_vals = jnp.take_along_axis(routed_vals, order, axis=1)
    counts = jnp.minimum(jnp.sum(hit, axis=1), R).astype(jnp.int32)
    return routed_keys, routed_vals, counts, rejected
