"""deleteMin schedules — the paper's evaluation cast, translated to TPU.

Paper implementation        -> schedule here      semantics   comm pattern
---------------------------------------------------------------------------
lotan_shavit  (exact obliv) -> STRICT_FLAT        exact       1 global gather of S*m cands
alistarh_herlihy (SprayList)-> SPRAY_HERLIHY      relaxed     none (adaptive window)
alistarh_fraser  (SprayList)-> SPRAY_FRASER       relaxed     none (uniform window)
Nuddle (delegation)         -> HIER               exact       intra-pod gather + pod-axis-only
                                                              exchange of npods*m cands
ffwd (single server)        -> FFWD               exact       tree-funnel to shard 0
(ablation lower bound)      -> LOCAL              per-shard   none, no global order
MultiQueue (two-choice,     -> MULTIQ             relaxed     none (min-cache probes)
 Williams & Sanders)

This module implements the *semantics* vectorized over the hot head tier
(S, H) of the tiered state — every schedule begins with the cond-guarded
`ensure_head`, after which candidate windows, spray windows, and prefix pops
touch only (S, <= m + pad) head columns, so per-step cost scales with the
batch, not the capacity.  This is the single-controller path used by tests,
benchmarks, and the oracle diff;
`repro.core.pqueue.dist` implements the same schedules with real collectives
under shard_map.  STRICT_FLAT / HIER / FFWD are bit-identical in outcome and
differ only in communication — exactly the paper's "same structure, different
access path" property that makes SmartPQ's mode switch free.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue import local as L
from repro.core.pqueue.state import INF_KEY, PQState


class Schedule(enum.IntEnum):
    STRICT_FLAT = 0  # lotan_shavit analogue (exact, oblivious)
    SPRAY_HERLIHY = 1  # alistarh_herlihy analogue (relaxed, adaptive window)
    HIER = 2  # Nuddle analogue (exact, pod-hierarchical delegation)
    FFWD = 3  # ffwd analogue (exact, single-server funnel)
    LOCAL = 4  # ablation: per-shard pops, no global order
    SPRAY_FRASER = 5  # alistarh_fraser analogue (relaxed, uniform window)
    MULTIQ = 6  # MultiQueue analogue (relaxed, two-choice min-cache probes)


class DeleteResult(NamedTuple):
    state: PQState
    keys: jnp.ndarray  # (m,) ascending; INF-padded beyond n_out
    vals: jnp.ndarray  # (m,)
    n_out: jnp.ndarray  # () actual number returned


class HotTier(NamedTuple):
    """The head-tier slice every schedule's post-`ensure_head` core reads
    and writes: (S, H) sorted arrays + per-shard sizes.  This is what
    SmartPQ's `lax.switch` threads through its branches — a few hundred KB
    instead of the full state, so branch result copies cost nothing (the
    cold tail never crosses the switch boundary)."""

    keys: jnp.ndarray  # (S, H)
    vals: jnp.ndarray  # (S, H)
    seq: jnp.ndarray  # (S, H)
    size: jnp.ndarray  # (S,)


def hot_tier(state: PQState) -> HotTier:
    return HotTier(state.head_keys, state.head_vals, state.head_seq,
                   state.head_size)


def attach_hot(state: PQState, hot: HotTier) -> PQState:
    return dataclasses.replace(
        state, head_keys=hot.keys, head_vals=hot.vals, head_seq=hot.seq,
        head_size=hot.size,
    )


def _ilog2(n: int) -> int:
    return max(int(n - 1).bit_length(), 1)


def spray_bound(num_shards: int, m: int) -> int:
    """Relaxation envelope: every key returned by a spray deleteMin of batch m
    is among the smallest `spray_bound(S, m)` keys of the queue (property-
    tested).  Mirrors SprayList's O(p log^3 p) guarantee with p deleters: here
    the batch of m deleters spreads over S shards, each spraying a window of
    at most ceil(m/S) + (log2 S + 1)^2 entries."""
    per_shard = -(-m // num_shards) + (_ilog2(num_shards) + 1) ** 2
    return min(num_shards * per_shard, 1 << 30)


def multiq_bound(num_shards: int, m: int) -> int:
    """Relaxation envelope of the two-choice MULTIQ deleteMin of batch m.

    Two-choice load balancing bounds the per-shard load at m/S + O(log log S)
    w.h.p. (balls-into-bins with the power of two choices), and a pop at
    local rank r has global rank < S*(r+1), so the envelope is
    m + O(S log log S) — asymptotically tighter than spray_bound's
    m + O(S log^2 S).  The deterministic (any-rng) fallback is per-shard:
    every returned key sits within the first m entries of SOME shard."""
    loglog = _ilog2(_ilog2(max(num_shards, 2)) + 1) + 1
    return min(m + num_shards * (loglog + 2), 1 << 30)


# ---------------------------------------------------------------------------
# Hot-tier precondition shared by every schedule.
# ---------------------------------------------------------------------------


def _head_pad(num_shards: int) -> int:
    """The spray window padding — also the refill hysteresis margin."""
    return (_ilog2(num_shards) + 1) ** 2


def ensure_head(state: PQState, m: int) -> PQState:
    """Restore the hot-tier precondition before a delete batch of bound m:
    every shard's head must hold its smallest min(H, shard size) elements
    and be at least `m + pad` deep (the widest per-step draw window) unless
    the shard is smaller than that.  The refill is `lax.cond`-guarded — and
    split so its common firing (consume the sorted run's front) returns
    only head-sized buffers (`local.refill_head_guarded`): neither the
    steady state NOR the refill itself does O(capacity) work unless appends
    actually left an unsorted bucket behind."""
    H = state.head_width
    if m > H:
        raise ValueError(
            f"delete batch bound m={m} exceeds the hot head tier width "
            f"H={H}; raise head_width (H-sizing rule: H >= m + "
            f"(ilog2(S)+1)^2 for spray, H >= m for exact/MULTIQ — see "
            f"state.py)"
        )
    if state.tail_width == 0:
        return state
    return L.refill_head_guarded(state, head_refill_pred(state, m))


def head_refill_pred(state: PQState, m: int) -> jnp.ndarray:
    """`ensure_head`'s refill trigger as a standalone () bool — whether a
    delete batch of bound m would fire the guarded hot-tier refill.  The
    stats layer counts it (`SmartPQStats.head_refills`) from exactly this
    predicate, so the counter can never drift from the actual `lax.cond`
    firing.  Always False for head-only states (tail_width == 0): there is
    no cold tier to refill from."""
    if state.tail_width == 0:
        return jnp.bool_(False)
    need = min(state.head_width, m + _head_pad(state.num_shards))
    return jnp.any((state.head_size < need) & (state.tail_size > 0))


def _pop_hot_prefix(hot: HotTier, take: jnp.ndarray) -> HotTier:
    """Remove per-shard head prefixes (the only way any schedule removes)."""
    return HotTier(*L.remove_prefix(hot.keys, hot.vals, hot.seq, hot.size,
                                    take))


# Every schedule below is split into a `hot_*` core — the post-`ensure_head`
# computation, reading/writing ONLY the HotTier (plus the scalar total) — and
# a full-state `delete_*` wrapper.  SmartPQ's lax.switch dispatches over the
# hot cores directly (ensure_head hoisted out), so the cold tail never
# crosses the switch boundary; `ops.delete_min` uses the wrappers.


def _hot_tournament(
    hot: HotTier, total: jnp.ndarray, m: int, active: jnp.ndarray
):
    """Exact top-`active` removal (active <= m static bound).

    Each shard nominates its m smallest (a prefix of the sorted head, which
    `ensure_head` guarantees holds the shard's true smallest-m), a global
    tournament selects the winners, and every shard removes the prefix it
    lost.  Tie-break: (key, shard, slot) lexicographic; head slot order is
    seq order (I4), so this matches the oracle's (key, shard, seq).
    """
    cand_k = hot.keys[:, :m]  # (S, m)
    cand_v = hot.vals[:, :m]

    n = jnp.minimum(active, total).astype(jnp.int32)
    win_k, win_v = L.topk_of_merged(cand_k.ravel(), cand_v.ravel(), m)

    cutoff = win_k[jnp.maximum(n - 1, 0)]
    take = L.count_winners_per_shard(cand_k, cutoff, n)
    take = jnp.where(n > 0, take, 0)

    hot = _pop_hot_prefix(hot, take)
    lane = jnp.arange(m, dtype=jnp.int32)
    out_k = jnp.where(lane < n, win_k, INF_KEY)
    out_v = jnp.where(lane < n, win_v, 0)
    return hot, out_k, out_v, n


def hot_strict_flat(hot, total, m, active, rng, npods=1):
    """lotan_shavit: one flat global tournament (all S*m candidates meet)."""
    del rng, npods
    return _hot_tournament(hot, total, m, active)


def hot_hier(hot, total, m, active, rng, npods=1):
    """Nuddle: two-phase tournament — pod-local semifinal, then only pod
    winners cross the slow tier.  Semantically identical to STRICT_FLAT (the
    semifinal never eliminates a global winner: a pod's top-m contains every
    candidate that can rank in the global top-m)."""
    del rng
    S = hot.keys.shape[0]
    assert S % npods == 0, f"shards {S} must split evenly over {npods} pods"
    # Phase 1 (intra-pod, fast ICI): per-pod top-m.   Phase 2 (pod axis only):
    # npods*m candidates.  The single-controller path computes the same values
    # the two-phase collective computes; dist.py issues the real collectives.
    cand_k = hot.keys[:, :m].reshape(npods, -1)
    cand_v = hot.vals[:, :m].reshape(npods, -1)
    pod_k, pod_v = jax.vmap(lambda k, v: L.topk_of_merged(k, v, m))(cand_k, cand_v)
    win_k, win_v = L.topk_of_merged(pod_k.ravel(), pod_v.ravel(), m)

    n = jnp.minimum(active, total).astype(jnp.int32)
    cutoff = win_k[jnp.maximum(n - 1, 0)]
    take = L.count_winners_per_shard(hot.keys[:, :m], cutoff, n)
    take = jnp.where(n > 0, take, 0)
    hot = _pop_hot_prefix(hot, take)
    lane = jnp.arange(m, dtype=jnp.int32)
    out_k = jnp.where(lane < n, win_k, INF_KEY)
    out_v = jnp.where(lane < n, win_v, 0)
    return hot, out_k, out_v, n


def hot_ffwd(hot, total, m, active, rng, npods=1):
    """ffwd: every shard's candidates funnel to the single server (shard 0),
    which runs the whole tournament alone.  Single-controller semantics equal
    STRICT_FLAT; dist.py realizes the log-depth tree funnel + broadcast."""
    del rng, npods
    return _hot_tournament(hot, total, m, active)


def _hot_spray(hot, m, active, rng, adaptive_window: bool):
    """Each of the `active` deleters lands on a uniform random shard; each
    shard pops its deleters' picks from a bounded window at the head of its
    sorted buffer.  No cross-shard coordination of any kind.

    adaptive_window=True (herlihy flavour): window ~ m_s + (log2 S + 1)^2 —
      tight when few deleters land on the shard.
    adaptive_window=False (fraser flavour): uniform window spray_bound/S —
      wider, cheaper to compute, slightly worse envelope constants.

    All randomness, ranking, and compaction are bounded by the static spray
    window W = min(m + pad, H): the uniform draw is (S, W), the double
    argsort is over W columns, and `remove_at` compacts only the window —
    nothing in this schedule scales with the capacity.
    """
    S, H = hot.keys.shape
    k_shard, k_pos = jax.random.split(rng)

    lane = jnp.arange(m, dtype=jnp.int32)
    act = lane < jnp.minimum(active, m)
    shard_choice = jax.random.randint(k_shard, (m,), 0, S)
    shard_choice = jnp.where(act, shard_choice, S)  # park inactive lanes
    m_s = jnp.zeros((S,), jnp.int32).at[shard_choice].add(1, mode="drop")

    pad = _head_pad(S)
    W = min(m + pad, H)  # static bound on every per-shard window
    if adaptive_window:
        window = m_s + pad
    else:
        window = jnp.full((S,), -(-m // S) + pad, jnp.int32)
    window = jnp.minimum(jnp.minimum(window, hot.size), W)

    # Distinct random positions inside each shard's window: draw UNIQUE
    # integer scores (random high bits, slot index low bits — collision
    # free by construction) and remove the slots scoring at or below the
    # takeable-th smallest.  One single-operand row sort; XLA:CPU executes
    # multi-operand sorts (argsort ranking included) orders of magnitude
    # slower, which made the old double-argsort the spray hot spot.
    col = jnp.arange(W, dtype=jnp.int32)[None, :]
    hi = jax.random.randint(k_pos, (S, W), 0, (1 << 31) // (W + 1) - 1,
                            dtype=jnp.int32)
    u = hi * (W + 1) + col  # unique within a row
    score = jnp.where(col < window[:, None], u, jnp.iinfo(jnp.int32).max)
    sorted_score = jnp.sort(score, axis=1)
    takeable = jnp.minimum(m_s, window)
    kth = jnp.take_along_axis(
        sorted_score, jnp.clip(takeable - 1, 0, W - 1)[:, None], axis=1
    )
    remove_mask = (
        (score <= kth) & (takeable > 0)[:, None] & (col < window[:, None])
    )

    removed_k = jnp.where(remove_mask, hot.keys[:, :W], INF_KEY)
    removed_v = jnp.where(remove_mask, hot.vals[:, :W], 0)
    out_k, out_v = L.topk_of_merged(removed_k.ravel(), removed_v.ravel(), m)

    hot = HotTier(*L.remove_at(hot.keys, hot.vals, hot.seq, hot.size,
                               remove_mask))
    n = jnp.sum(takeable).astype(jnp.int32)
    return hot, out_k, out_v, n


def hot_spray_herlihy(hot, total, m, active, rng, npods=1):
    del total, npods
    return _hot_spray(hot, m, active, rng, adaptive_window=True)


def hot_spray_fraser(hot, total, m, active, rng, npods=1):
    del total, npods
    return _hot_spray(hot, m, active, rng, adaptive_window=False)


def hot_multiq(hot, total, m, active, rng, npods=1):
    """Relaxed MultiQueue (Williams & Sanders): the S shards are the c*S
    sharded sub-queues; each of the `active` deleters samples TWO of them
    uniformly, reads their cached minima (`state.shard_mins` — column 0 of
    the sorted buffers, maintained for free), and commits to the sub-queue
    whose cached minimum is smaller.  Every chosen sub-queue then serves its
    deleters from the head — a plain prefix pop, exactly the structure the
    exact schedules already use, so the removal path is shared.

    No cross-shard coordination of any kind (the oblivious scaling property),
    but the two-choice probe keeps every pop within shard-rank < m
    deterministically and within `multiq_bound(S, m)` global rank w.h.p. —
    the paper's missing mixed-contention mode."""
    del total, npods
    S = hot.keys.shape[0]
    k_a, k_b = jax.random.split(rng)

    lane = jnp.arange(m, dtype=jnp.int32)
    act = lane < jnp.minimum(active, m)
    choice_a = jax.random.randint(k_a, (m,), 0, S)
    choice_b = jax.random.randint(k_b, (m,), 0, S)
    counts = L.twochoice_pick(hot.keys[:, 0], choice_a, choice_b, act)
    take = jnp.minimum(counts, hot.size)

    # Pops are head prefixes: the (S, m) head window masked to `take` feeds
    # the commit-side tournament (fused mask+merge Pallas kernel on TPU).
    out_k, out_v = L.multiq_select(hot.keys[:, :m], hot.vals[:, :m], take)

    hot = _pop_hot_prefix(hot, take)
    n = jnp.sum(take).astype(jnp.int32)
    return hot, out_k, out_v, n


def hot_local(hot, total, m, active, rng, npods=1):
    """Ablation lower bound: split the batch evenly, pop per-shard prefixes,
    no ordering between shards at all."""
    del total, rng, npods
    S, H = hot.keys.shape
    base, rem = divmod(m, S)
    quota = base + (jnp.arange(S, dtype=jnp.int32) < rem).astype(jnp.int32)
    # Respect the dynamic active count: shrink quotas from the tail.
    excess = jnp.maximum(m - active, 0)
    cum_from_tail = jnp.cumsum(quota[::-1])[::-1]
    shrink = jnp.clip(quota - (cum_from_tail - excess), 0, quota)
    quota = quota - shrink
    take = jnp.minimum(quota, hot.size)

    W = min(m, H)  # per-shard take <= quota <= m
    taken_mask = jnp.arange(W)[None, :] < take[:, None]
    removed_k = jnp.where(taken_mask, hot.keys[:, :W], INF_KEY)
    removed_v = jnp.where(taken_mask, hot.vals[:, :W], 0)
    out_k, out_v = L.topk_of_merged(removed_k.ravel(), removed_v.ravel(), m)

    hot = _pop_hot_prefix(hot, take)
    n = jnp.sum(take).astype(jnp.int32)
    return hot, out_k, out_v, n


HOT_SCHEDULE_FNS = {
    Schedule.STRICT_FLAT: hot_strict_flat,
    Schedule.SPRAY_HERLIHY: hot_spray_herlihy,
    Schedule.HIER: hot_hier,
    Schedule.FFWD: hot_ffwd,
    Schedule.LOCAL: hot_local,
    Schedule.SPRAY_FRASER: hot_spray_fraser,
    Schedule.MULTIQ: hot_multiq,
}


def _wrap(hot_fn):
    def delete_fn(state: PQState, m: int, active: jnp.ndarray,
                  rng: jax.Array, npods: int = 1) -> DeleteResult:
        state = ensure_head(state, m)
        hot, out_k, out_v, n = hot_fn(
            hot_tier(state), state.total_size, m, active, rng, npods
        )
        return DeleteResult(attach_hot(state, hot), out_k, out_v, n)

    delete_fn.__doc__ = hot_fn.__doc__
    return delete_fn


delete_strict_flat = _wrap(hot_strict_flat)
delete_spray_herlihy = _wrap(hot_spray_herlihy)
delete_hier = _wrap(hot_hier)
delete_ffwd = _wrap(hot_ffwd)
delete_local = _wrap(hot_local)
delete_spray_fraser = _wrap(hot_spray_fraser)
delete_multiq = _wrap(hot_multiq)

SCHEDULE_FNS = {
    Schedule.STRICT_FLAT: delete_strict_flat,
    Schedule.SPRAY_HERLIHY: delete_spray_herlihy,
    Schedule.HIER: delete_hier,
    Schedule.FFWD: delete_ffwd,
    Schedule.LOCAL: delete_local,
    Schedule.SPRAY_FRASER: delete_spray_fraser,
    Schedule.MULTIQ: delete_multiq,
}
