"""deleteMin schedules — the paper's evaluation cast, translated to TPU.

Paper implementation        -> schedule here      semantics   comm pattern
---------------------------------------------------------------------------
lotan_shavit  (exact obliv) -> STRICT_FLAT        exact       1 global gather of S*m cands
alistarh_herlihy (SprayList)-> SPRAY_HERLIHY      relaxed     none (adaptive window)
alistarh_fraser  (SprayList)-> SPRAY_FRASER       relaxed     none (uniform window)
Nuddle (delegation)         -> HIER               exact       intra-pod gather + pod-axis-only
                                                              exchange of npods*m cands
ffwd (single server)        -> FFWD               exact       tree-funnel to shard 0
(ablation lower bound)      -> LOCAL              per-shard   none, no global order
MultiQueue (two-choice,     -> MULTIQ             relaxed     none (min-cache probes)
 Williams & Sanders)

This module implements the *semantics* vectorized over the hot head tier
(S, H) of the tiered state — every schedule begins with the cond-guarded
`ensure_head`, after which candidate windows, spray windows, and prefix pops
touch only (S, <= m + pad) head columns, so per-step cost scales with the
batch, not the capacity.  This is the single-controller path used by tests,
benchmarks, and the oracle diff;
`repro.core.pqueue.dist` implements the same schedules with real collectives
under shard_map.  STRICT_FLAT / HIER / FFWD are bit-identical in outcome and
differ only in communication — exactly the paper's "same structure, different
access path" property that makes SmartPQ's mode switch free.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.pqueue import local as L
from repro.core.pqueue.state import INF_KEY, PQState


class Schedule(enum.IntEnum):
    STRICT_FLAT = 0  # lotan_shavit analogue (exact, oblivious)
    SPRAY_HERLIHY = 1  # alistarh_herlihy analogue (relaxed, adaptive window)
    HIER = 2  # Nuddle analogue (exact, pod-hierarchical delegation)
    FFWD = 3  # ffwd analogue (exact, single-server funnel)
    LOCAL = 4  # ablation: per-shard pops, no global order
    SPRAY_FRASER = 5  # alistarh_fraser analogue (relaxed, uniform window)
    MULTIQ = 6  # MultiQueue analogue (relaxed, two-choice min-cache probes)


class DeleteResult(NamedTuple):
    state: PQState
    keys: jnp.ndarray  # (m,) ascending; INF-padded beyond n_out
    vals: jnp.ndarray  # (m,)
    n_out: jnp.ndarray  # () actual number returned


def _ilog2(n: int) -> int:
    return max(int(n - 1).bit_length(), 1)


def spray_bound(num_shards: int, m: int) -> int:
    """Relaxation envelope: every key returned by a spray deleteMin of batch m
    is among the smallest `spray_bound(S, m)` keys of the queue (property-
    tested).  Mirrors SprayList's O(p log^3 p) guarantee with p deleters: here
    the batch of m deleters spreads over S shards, each spraying a window of
    at most ceil(m/S) + (log2 S + 1)^2 entries."""
    per_shard = -(-m // num_shards) + (_ilog2(num_shards) + 1) ** 2
    return min(num_shards * per_shard, 1 << 30)


def multiq_bound(num_shards: int, m: int) -> int:
    """Relaxation envelope of the two-choice MULTIQ deleteMin of batch m.

    Two-choice load balancing bounds the per-shard load at m/S + O(log log S)
    w.h.p. (balls-into-bins with the power of two choices), and a pop at
    local rank r has global rank < S*(r+1), so the envelope is
    m + O(S log log S) — asymptotically tighter than spray_bound's
    m + O(S log^2 S).  The deterministic (any-rng) fallback is per-shard:
    every returned key sits within the first m entries of SOME shard."""
    loglog = _ilog2(_ilog2(max(num_shards, 2)) + 1) + 1
    return min(m + num_shards * (loglog + 2), 1 << 30)


# ---------------------------------------------------------------------------
# Hot-tier precondition shared by every schedule.
# ---------------------------------------------------------------------------


def _head_pad(num_shards: int) -> int:
    """The spray window padding — also the refill hysteresis margin."""
    return (_ilog2(num_shards) + 1) ** 2


def ensure_head(state: PQState, m: int) -> PQState:
    """Restore the hot-tier precondition before a delete batch of bound m:
    every shard's head must hold its smallest min(H, shard size) elements
    and be at least `m + pad` deep (the widest per-step draw window) unless
    the shard is smaller than that.  The refill is `lax.cond`-guarded: in
    steady state the predicate is false and the step does no O(capacity)
    work at all."""
    H = state.head_width
    if m > H:
        raise ValueError(
            f"delete batch bound m={m} exceeds the hot head tier width "
            f"H={H}; raise head_width (H-sizing rule: H >= m + "
            f"(ilog2(S)+1)^2 for spray, H >= m for exact/MULTIQ — see "
            f"state.py)"
        )
    if state.tail_width == 0:
        return state
    need = min(H, m + _head_pad(state.num_shards))
    pred = jnp.any((state.head_size < need) & (state.tail_size > 0))
    return jax.lax.cond(pred, L.refill_head, lambda s: s, state)


def _pop_head_prefix(state: PQState, take: jnp.ndarray) -> PQState:
    """Remove per-shard head prefixes (the only way any schedule removes)."""
    hk, hv, hq, hsize = L.remove_prefix(
        state.head_keys, state.head_vals, state.head_seq, state.head_size,
        take,
    )
    return dataclasses.replace(
        state, head_keys=hk, head_vals=hv, head_seq=hq, head_size=hsize
    )


# ---------------------------------------------------------------------------
# Exact schedules (STRICT_FLAT / HIER / FFWD share the tournament semantics).
# ---------------------------------------------------------------------------


def _tournament(
    state: PQState, m: int, active: jnp.ndarray
) -> DeleteResult:
    """Exact top-`active` removal (active <= m static bound).

    Each shard nominates its m smallest (a prefix of the sorted head, which
    `ensure_head` guarantees holds the shard's true smallest-m), a global
    tournament selects the winners, and every shard removes the prefix it
    lost.  Tie-break: (key, shard, slot) lexicographic; head slot order is
    seq order (I4), so this matches the oracle's (key, shard, seq).
    """
    state = ensure_head(state, m)
    cand_k = state.head_keys[:, :m]  # (S, m)
    cand_v = state.head_vals[:, :m]

    n = jnp.minimum(active, state.total_size).astype(jnp.int32)
    win_k, win_v = L.topk_of_merged(cand_k.ravel(), cand_v.ravel(), m)

    cutoff = win_k[jnp.maximum(n - 1, 0)]
    take = L.count_winners_per_shard(cand_k, cutoff, n)
    take = jnp.where(n > 0, take, 0)

    state = _pop_head_prefix(state, take)
    lane = jnp.arange(m, dtype=jnp.int32)
    out_k = jnp.where(lane < n, win_k, INF_KEY)
    out_v = jnp.where(lane < n, win_v, 0)
    return DeleteResult(state, out_k, out_v, n)


def delete_strict_flat(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, npods: int = 1
) -> DeleteResult:
    """lotan_shavit: one flat global tournament (all S*m candidates meet)."""
    del rng, npods
    return _tournament(state, m, active)


def delete_hier(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, npods: int = 1
) -> DeleteResult:
    """Nuddle: two-phase tournament — pod-local semifinal, then only pod
    winners cross the slow tier.  Semantically identical to STRICT_FLAT (the
    semifinal never eliminates a global winner: a pod's top-m contains every
    candidate that can rank in the global top-m)."""
    del rng
    state = ensure_head(state, m)
    S = state.num_shards
    assert S % npods == 0, f"shards {S} must split evenly over {npods} pods"
    # Phase 1 (intra-pod, fast ICI): per-pod top-m.   Phase 2 (pod axis only):
    # npods*m candidates.  The single-controller path computes the same values
    # the two-phase collective computes; dist.py issues the real collectives.
    cand_k = state.head_keys[:, :m].reshape(npods, -1)
    cand_v = state.head_vals[:, :m].reshape(npods, -1)
    pod_k, pod_v = jax.vmap(lambda k, v: L.topk_of_merged(k, v, m))(cand_k, cand_v)
    win_k, win_v = L.topk_of_merged(pod_k.ravel(), pod_v.ravel(), m)

    n = jnp.minimum(active, state.total_size).astype(jnp.int32)
    cutoff = win_k[jnp.maximum(n - 1, 0)]
    take = L.count_winners_per_shard(state.head_keys[:, :m], cutoff, n)
    take = jnp.where(n > 0, take, 0)
    state = _pop_head_prefix(state, take)
    lane = jnp.arange(m, dtype=jnp.int32)
    out_k = jnp.where(lane < n, win_k, INF_KEY)
    out_v = jnp.where(lane < n, win_v, 0)
    return DeleteResult(state, out_k, out_v, n)


def delete_ffwd(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, npods: int = 1
) -> DeleteResult:
    """ffwd: every shard's candidates funnel to the single server (shard 0),
    which runs the whole tournament alone.  Single-controller semantics equal
    STRICT_FLAT; dist.py realizes the log-depth tree funnel + broadcast."""
    del rng, npods
    return _tournament(state, m, active)


# ---------------------------------------------------------------------------
# Relaxed schedules (SprayList analogues) — collective-free.
# ---------------------------------------------------------------------------


def _spray(
    state: PQState,
    m: int,
    active: jnp.ndarray,
    rng: jax.Array,
    adaptive_window: bool,
) -> DeleteResult:
    """Each of the `active` deleters lands on a uniform random shard; each
    shard pops its deleters' picks from a bounded window at the head of its
    sorted buffer.  No cross-shard coordination of any kind.

    adaptive_window=True (herlihy flavour): window ~ m_s + (log2 S + 1)^2 —
      tight when few deleters land on the shard.
    adaptive_window=False (fraser flavour): uniform window spray_bound/S —
      wider, cheaper to compute, slightly worse envelope constants.

    All randomness, ranking, and compaction are bounded by the static spray
    window W = min(m + pad, H): the uniform draw is (S, W), the double
    argsort is over W columns, and `remove_at` compacts only the window —
    nothing in this schedule scales with the capacity.
    """
    state = ensure_head(state, m)
    S, H = state.head_keys.shape
    k_shard, k_pos = jax.random.split(rng)

    lane = jnp.arange(m, dtype=jnp.int32)
    act = lane < jnp.minimum(active, m)
    shard_choice = jax.random.randint(k_shard, (m,), 0, S)
    shard_choice = jnp.where(act, shard_choice, S)  # park inactive lanes
    m_s = jnp.zeros((S,), jnp.int32).at[shard_choice].add(1, mode="drop")

    pad = _head_pad(S)
    W = min(m + pad, H)  # static bound on every per-shard window
    if adaptive_window:
        window = m_s + pad
    else:
        window = jnp.full((S,), -(-m // S) + pad, jnp.int32)
    window = jnp.minimum(jnp.minimum(window, state.head_size), W)

    # Distinct random positions inside each shard's window: rank the uniform
    # scores and keep the m_s smallest ranks that fall inside the window.
    u = jax.random.uniform(k_pos, (S, W))
    col = jnp.arange(W, dtype=jnp.int32)[None, :]
    score = jnp.where(col < window[:, None], u, 2.0)
    order = jnp.argsort(score, axis=1)
    rank = jnp.argsort(order, axis=1)
    takeable = jnp.minimum(m_s, window)
    remove_mask = rank < takeable[:, None]

    removed_k = jnp.where(remove_mask, state.head_keys[:, :W], INF_KEY)
    removed_v = jnp.where(remove_mask, state.head_vals[:, :W], 0)
    out_k, out_v = L.topk_of_merged(removed_k.ravel(), removed_v.ravel(), m)

    hk, hv, hq, hsize = L.remove_at(
        state.head_keys, state.head_vals, state.head_seq, state.head_size,
        remove_mask,
    )
    state = dataclasses.replace(
        state, head_keys=hk, head_vals=hv, head_seq=hq, head_size=hsize
    )
    n = jnp.sum(takeable).astype(jnp.int32)
    return DeleteResult(state, out_k, out_v, n)


def delete_spray_herlihy(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, npods: int = 1
) -> DeleteResult:
    del npods
    return _spray(state, m, active, rng, adaptive_window=True)


def delete_spray_fraser(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, npods: int = 1
) -> DeleteResult:
    del npods
    return _spray(state, m, active, rng, adaptive_window=False)


def delete_multiq(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, npods: int = 1
) -> DeleteResult:
    """Relaxed MultiQueue (Williams & Sanders): the S shards are the c*S
    sharded sub-queues; each of the `active` deleters samples TWO of them
    uniformly, reads their cached minima (`state.shard_mins` — column 0 of
    the sorted buffers, maintained for free), and commits to the sub-queue
    whose cached minimum is smaller.  Every chosen sub-queue then serves its
    deleters from the head — a plain prefix pop, exactly the structure the
    exact schedules already use, so the removal path is shared.

    No cross-shard coordination of any kind (the oblivious scaling property),
    but the two-choice probe keeps every pop within shard-rank < m
    deterministically and within `multiq_bound(S, m)` global rank w.h.p. —
    the paper's missing mixed-contention mode."""
    del npods
    state = ensure_head(state, m)
    S = state.num_shards
    k_a, k_b = jax.random.split(rng)

    lane = jnp.arange(m, dtype=jnp.int32)
    act = lane < jnp.minimum(active, m)
    choice_a = jax.random.randint(k_a, (m,), 0, S)
    choice_b = jax.random.randint(k_b, (m,), 0, S)
    counts = L.twochoice_pick(state.shard_mins, choice_a, choice_b, act)
    take = jnp.minimum(counts, state.head_size)

    # Pops are head prefixes: the (S, m) head window masked to `take` feeds
    # the commit-side tournament (fused mask+merge Pallas kernel on TPU).
    out_k, out_v = L.multiq_select(
        state.head_keys[:, :m], state.head_vals[:, :m], take
    )

    state = _pop_head_prefix(state, take)
    n = jnp.sum(take).astype(jnp.int32)
    return DeleteResult(state, out_k, out_v, n)


def delete_local(
    state: PQState, m: int, active: jnp.ndarray, rng: jax.Array, npods: int = 1
) -> DeleteResult:
    """Ablation lower bound: split the batch evenly, pop per-shard prefixes,
    no ordering between shards at all."""
    del rng, npods
    state = ensure_head(state, m)
    S = state.num_shards
    base, rem = divmod(m, S)
    quota = base + (jnp.arange(S, dtype=jnp.int32) < rem).astype(jnp.int32)
    # Respect the dynamic active count: shrink quotas from the tail.
    excess = jnp.maximum(m - active, 0)
    cum_from_tail = jnp.cumsum(quota[::-1])[::-1]
    shrink = jnp.clip(quota - (cum_from_tail - excess), 0, quota)
    quota = quota - shrink
    take = jnp.minimum(quota, state.head_size)

    W = min(m, state.head_width)  # per-shard take <= quota <= m
    taken_mask = jnp.arange(W)[None, :] < take[:, None]
    removed_k = jnp.where(taken_mask, state.head_keys[:, :W], INF_KEY)
    removed_v = jnp.where(taken_mask, state.head_vals[:, :W], 0)
    out_k, out_v = L.topk_of_merged(removed_k.ravel(), removed_v.ravel(), m)

    state = _pop_head_prefix(state, take)
    n = jnp.sum(take).astype(jnp.int32)
    return DeleteResult(state, out_k, out_v, n)


SCHEDULE_FNS = {
    Schedule.STRICT_FLAT: delete_strict_flat,
    Schedule.SPRAY_HERLIHY: delete_spray_herlihy,
    Schedule.HIER: delete_hier,
    Schedule.FFWD: delete_ffwd,
    Schedule.LOCAL: delete_local,
    Schedule.SPRAY_FRASER: delete_spray_fraser,
    Schedule.MULTIQ: delete_multiq,
}
