"""SmartPQ — the paper's adaptive priority queue (§3), TPU form, N modes.

Three key ideas of the paper, and where they live here:
  1. Every algorithmic mode operates on the *same* underlying concurrent
     structure  ->  all branches of `lax.switch` read/write the identical
     PQState pytree; the sharding never changes with the mode.
  2. A decision mechanism picks the mode  ->  packed decision tree evaluated
     on-device every `decision_interval` steps (paper: every second, host
     side; here: in-graph, zero host round-trip).
  3. Transitions need no synchronization point  ->  the mode is a traced
     int32 in the carry; "switching" is literally the predicate of
     `lax.switch` changing value between two steps of one compiled program.

N-mode architecture (generalized from the paper's 2-mode oblivious/aware
choice).  The mode set is `SmartPQConfig.mode_schedules`: a tuple of
`Schedule`s indexed by mode id, which is simultaneously (a) the classifier
class id, (b) the `lax.switch` branch index, and (c) the `make_mode_steps`
dict key.  Shipped modes:

    0 MODE_OBLIVIOUS -> SPRAY_HERLIHY  relaxed, collective-free spray
    1 MODE_MULTIQ    -> MULTIQ         relaxed MultiQueue: two-choice
                                       min-cache sampling, bounded rank error
    2 MODE_AWARE     -> HIER           exact Nuddle pod-delegation

Adding a fourth mode (e.g. elimination/combining a la Calciu et al.) is a
three-step recipe, no decision-plumbing changes:
  1. implement the schedule in `pqueue.schedules` and register it in
     `SCHEDULE_FNS` (plus `pqueue.dist` if it needs real collectives);
  2. append a class id for it in `classifier.features` (before
     CLASS_NEUTRAL, bumping NUM_MODES) and give `classifier.cost_model` a
     `_delete_cost_*` arm so training labels exist;
  3. append its Schedule to `mode_schedules`.  The switch, the stats loop,
     `make_mode_steps`, and the decision tree all size off NUM_MODES /
     len(mode_schedules) automatically.

Workload statistics (paper §5's future-work sketch — implemented here): the
step tracks completed insert/delete counts, min/max requested key, and the
caller-supplied active-client count, and derives Table-1 features on the fly.

Fused-window execution (`run_window` / `jit_run_window`): K steps roll into
ONE donated `lax.scan` whose body contains the full adaptive loop — jnp
featurization, on-device tree inference, the N-mode `lax.switch`, and the
schedule — so mode transitions happen mid-window without leaving the device
and per-operation cost amortizes K steps of dispatch into one.  In front of
the scan, the elimination/combining pre-pass sorts the whole (K, B)
operation log in one vectorized call (the sort is state-independent; only
the cutoff compare stays in the body), and matched insert/deleteMin pairs
are served without ever touching PQState.  The window trace is bit-identical
to K sequential `jit_step` calls (same code path, same rngs — tested), and
exact schedules remain bit-identical to the oracle linearization.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.classifier.dataset import make_training_set
from repro.core.classifier.features import (
    CLASS_AWARE,
    CLASS_MULTIQ,
    CLASS_NEUTRAL,
    CLASS_OBLIVIOUS,
    NUM_CLASSES,
    NUM_MODES,
    featurize_jnp,
)
from repro.core.classifier.inference import PackedTree, pack_tree, tree_predict
from repro.core.classifier.tree import DecisionTree, train_tree
from repro.core.pqueue import local as L
from repro.core.pqueue import ops as O
from repro.core.pqueue import schedules as SCH
from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT, insert
from repro.core.pqueue.schedules import DeleteResult, Schedule
from repro.core.pqueue.state import INF_KEY, PQState, make_state

# Mode encoding in the carry (== classifier class ids == switch branch ids).
MODE_OBLIVIOUS = CLASS_OBLIVIOUS  # 0: base algorithm directly (spray)
MODE_MULTIQ = CLASS_MULTIQ  # 1: relaxed MultiQueue (two-choice sampling)
MODE_AWARE = CLASS_AWARE  # 2: Nuddle delegation (hier)


class SmartPQStats(NamedTuple):
    """Replicated workload statistics (paper §5)."""

    step: jnp.ndarray  # () int32
    mode: jnp.ndarray  # () int32 — current algorithmic mode
    n_insert: jnp.ndarray  # () int32 ops since last decision
    n_delete: jnp.ndarray  # () int32
    min_key: jnp.ndarray  # () int32 smallest key requested so far
    max_key: jnp.ndarray  # () int32 largest
    transitions: jnp.ndarray  # () int32 — mode flips (overhead accounting)
    eliminated: jnp.ndarray  # () int32 — pairs served by the pre-pass
    rejected: jnp.ndarray  # () int32 — non-finite keys refused at admission
    mode_steps: jnp.ndarray  # (NUM_MODES,) int32 — steps spent per mode
    head_refills: jnp.ndarray  # () int32 — guarded hot-tier refill firings
    ring_deferred: jnp.ndarray  # () int32 — ring entries past their arrival
    # tick a window could not lane-admit yet (written by the serving
    # scheduler's fused scan; plain `step` threads it through unchanged)


class SmartPQCarry(NamedTuple):
    state: PQState
    stats: SmartPQStats


class WindowResult(NamedTuple):
    """Per-step delete outputs of a fused K-step window (state lives in the
    returned carry)."""

    keys: jnp.ndarray  # (K, B) ascending per step, INF-padded
    vals: jnp.ndarray  # (K, B)
    n_out: jnp.ndarray  # (K,)
    mode: jnp.ndarray  # (K,) mode AFTER each step (the on-device trace)


@dataclasses.dataclass(frozen=True)
class SmartPQConfig:
    num_shards: int = 64
    capacity: int = 4096
    # hot head tier width (None -> state.DEFAULT_HEAD_WIDTH, clamped to
    # capacity).  H-sizing rule: H >= batch + (ilog2(S)+1)^2 (see state.py).
    head_width: int | None = None
    npods: int = 2
    decision_interval: int = 8  # steps between classifier calls
    # Schedule per mode id — index == classifier class == switch branch.
    mode_schedules: Tuple[Schedule, ...] = (
        Schedule.SPRAY_HERLIHY,  # MODE_OBLIVIOUS
        Schedule.MULTIQ,  # MODE_MULTIQ
        Schedule.HIER,  # MODE_AWARE
    )
    initial_mode: int = MODE_OBLIVIOUS  # paper Fig. 8 line 106: default 1
    # Elimination/combining pre-pass (Calciu et al.): serve matched
    # insert/deleteMin pairs of a batch without touching PQState.  Exact for
    # exact schedules (ops.py docstring), envelope-tightening for relaxed
    # ones.  Off -> the plain insert-then-schedule step, bit for bit.
    eliminate: bool = True
    # Runtime guard tier: when True, validated callers (the serving
    # scheduler's tick/tick_window, `traces.replay`) run the host-side
    # invariant checker (`state.invariant_violations`) after every
    # step/window and surface a structured `InvariantViolation` — the
    # serving scheduler additionally checkpoints before the call and, on a
    # trip, rolls back and retries once in a conservative fallback (STRICT
    # schedule, elimination off) before raising the typed error.  Off
    # (default) costs nothing; on costs one host sync + one state copy per
    # validated call.
    validate: bool = False

    def __post_init__(self):
        assert len(self.mode_schedules) == NUM_MODES, (
            f"mode_schedules must give one Schedule per classifier mode "
            f"({NUM_MODES}); got {len(self.mode_schedules)} — did you add a "
            f"mode without appending its class id in classifier.features?"
        )


class SmartPQ:
    """Adaptive PQ facade.  Construct once (trains or accepts a tree), then
    drive `.step` (jittable, donatable), `.run_window` (K steps fused into
    one donated lax.scan — the dispatch-amortized serving path), or
    `make_mode_steps` (pre-compiled per-mode dispatch — for runtimes that
    prefer not to carry all branches)."""

    def __init__(
        self,
        config: SmartPQConfig = SmartPQConfig(),
        tree: Optional[DecisionTree] = None,
    ):
        self.config = config
        if tree is None:
            X, y = make_training_set()
            tree = train_tree(X, y, NUM_CLASSES, max_depth=8)
        self.tree = tree
        self.packed: PackedTree = pack_tree(tree)

    # -- lifecycle -----------------------------------------------------------

    def init(self) -> SmartPQCarry:
        c = self.config
        stats = SmartPQStats(
            step=jnp.int32(0),
            mode=jnp.int32(c.initial_mode),
            n_insert=jnp.int32(0),
            n_delete=jnp.int32(0),
            min_key=jnp.int32(INF_KEY),
            max_key=jnp.int32(0),
            transitions=jnp.int32(0),
            eliminated=jnp.int32(0),
            rejected=jnp.int32(0),
            mode_steps=jnp.zeros((NUM_MODES,), jnp.int32),
            head_refills=jnp.int32(0),
            ring_deferred=jnp.int32(0),
        )
        return SmartPQCarry(
            make_state(c.num_shards, c.capacity, head_width=c.head_width),
            stats,
        )

    # -- the adaptive step ----------------------------------------------------

    @functools.cached_property
    def jit_step(self):
        """`step` jitted with the carry DONATED: XLA aliases every PQState /
        stats buffer input->output (asserted via `utils.hlo.donation_aliases`
        in tests), so a steady-state step moves the queue zero times.  The
        caller must thread the returned carry and never reuse the argument
        (its buffers are deleted) — exactly the scan/serving-loop pattern."""
        return jax.jit(self.step, donate_argnums=(0,),
                       static_argnames=("return_features",))

    def step(
        self,
        carry: SmartPQCarry,
        ops: jnp.ndarray,  # (B,)
        keys: jnp.ndarray,  # (B,)
        vals: jnp.ndarray,  # (B,)
        rng: jax.Array,
        num_clients: jnp.ndarray | int | None = None,
        presorted: Tuple[jnp.ndarray, jnp.ndarray] | None = None,
        mode_override: jnp.ndarray | None = None,
        return_features: bool = False,
    ) -> Tuple[SmartPQCarry, DeleteResult] | Tuple[
        SmartPQCarry, DeleteResult, jnp.ndarray
    ]:
        """One bulk step: update stats -> (maybe) re-decide mode -> eliminate
        matched pairs -> apply the rest under the selected mode.  Pure
        function; jit/scan friendly.  `presorted` is the (sorted_keys,
        sorted_tags) insert log from `run_window`'s vectorized pre-pass —
        it is bit-identical to the in-step sort, just hoisted out of the
        scan.  `mode_override` (scalar int32, -1 = none) pins the mode for
        this step regardless of the classifier — the serving tier's
        graceful-degradation hook (force the relaxed MULTIQ mode under
        overload); None compiles the exact pre-override graph.
        `return_features` (static) appends the step's classifier feature
        vector (4,) float32 to the return — the observability layer's
        mode-transition trace attaches it to transition events; it is an
        extra OUTPUT of values the graph computes anyway, so the dispatch
        stream is untouched."""
        c = self.config
        state, stats = carry
        B = ops.shape[0]
        if num_clients is None:
            num_clients = c.num_shards
        num_clients = jnp.asarray(num_clients, jnp.int32)

        ins_mask = ops == OP_INSERT
        n_rejected = stats.rejected
        if jnp.issubdtype(jnp.asarray(keys).dtype, jnp.floating):
            # Admission-boundary sanitization: float key batches may carry
            # NaN/±inf — reject (-> INF sentinel, counted) instead of
            # letting IEEE sort semantics order them into the queue.  The
            # dtype test is trace-time: integer batches compile the exact
            # pre-sanitizer graph.
            keys, bad_keys = O.sanitize_keys(keys)
            n_rejected = n_rejected + jnp.sum(
                bad_keys & ins_mask
            ).astype(jnp.int32)
            ins_mask = ins_mask & ~bad_keys
        b_ins = jnp.sum(ins_mask).astype(jnp.int32)
        b_del = jnp.sum(ops == OP_DELETE_MIN).astype(jnp.int32)

        batch_min = jnp.min(jnp.where(ins_mask, keys, INF_KEY))
        batch_max = jnp.max(jnp.where(ins_mask, keys, 0))
        n_insert = stats.n_insert + b_ins
        n_delete = stats.n_delete + b_del
        min_key = jnp.minimum(stats.min_key, batch_min)
        max_key = jnp.maximum(stats.max_key, batch_max)

        # -- decision (paper Fig. 8 decisionTree(), on-device) ---------------
        do_decide = (stats.step % c.decision_interval) == 0
        total_ops = jnp.maximum(n_insert + n_delete, 1)
        key_range = jnp.where(
            min_key <= max_key, jnp.maximum(max_key - min_key, 1), 1
        )
        feats = featurize_jnp(
            num_clients,
            state.total_size,
            key_range,
            n_insert.astype(jnp.float32) / total_ops.astype(jnp.float32),
        )
        pred = tree_predict(self.packed, feats)
        # NEUTRAL (and any future >= NUM_MODES sentinel) keeps the mode; a
        # NEGATIVE class (possible only from a corrupted packed tree) must
        # not reach the switch either.
        keep = (~do_decide) | (pred >= NUM_MODES) | (pred < 0)
        new_mode = jnp.where(keep, stats.mode, pred).astype(jnp.int32)
        if mode_override is not None:
            ov = jnp.asarray(mode_override, jnp.int32)
            new_mode = jnp.where(ov >= 0, ov, new_mode)
        # Hard clamp before `lax.switch`: an out-of-range branch index —
        # whether from a corrupt tree label, a corrupt carry, or a bad
        # override — degrades to the nearest valid mode instead of UB.
        new_mode = jnp.clip(new_mode, 0, NUM_MODES - 1)
        transitions = stats.transitions + (new_mode != stats.mode).astype(jnp.int32)
        # Reset windowed op counters after each decision.
        n_insert = jnp.where(do_decide, 0, n_insert)
        n_delete = jnp.where(do_decide, 0, n_delete)

        # -- elimination/combining pre-pass ----------------------------------
        if c.eliminate:
            if presorted is None:
                presorted = L.sort_op_log(jnp.where(ins_mask, keys, INF_KEY))
            sk, stg = presorted
            elim_k, elim_v, n_elim, keep_lane = O.elim_split(
                state, sk, stg, vals, b_del
            )
            ins_mask = ins_mask & keep_lane
            active = b_del - n_elim
        else:
            n_elim = jnp.int32(0)
            active = b_del

        # -- apply batch under the selected mode ------------------------------
        # ensure_head is mode-independent (same bound m=B for every branch),
        # so it hoists OUT of the switch; the branches then read/write only
        # the HotTier — the cold tail never crosses the switch boundary, so
        # the conditional's operand/result copies are head-sized, not
        # capacity-sized (the big CPU win of the fused window).
        state, dropped = insert(state, keys, vals, mask=ins_mask)
        # Count the refill BEFORE ensure_head consumes the predicate — the
        # same expression gates the lax.cond inside, so the counter tracks
        # actual guarded-refill firings, not an approximation.
        head_refills = stats.head_refills + SCH.head_refill_pred(
            state, B
        ).astype(jnp.int32)
        state = SCH.ensure_head(state, B)
        total = state.total_size

        def run(schedule: Schedule):
            fn = SCH.HOT_SCHEDULE_FNS[schedule]

            def branch(operand):
                hot_in, rng_ = operand
                return fn(hot_in, total, B, active, rng_, c.npods)

            return branch

        hot, out_k, out_v, n_out = jax.lax.switch(
            new_mode,
            [run(s) for s in c.mode_schedules],
            (SCH.hot_tier(state), rng),
        )
        res = DeleteResult(SCH.attach_hot(state, hot), out_k, out_v, n_out)
        if c.eliminate:
            res = O.merge_eliminated(elim_k, elim_v, n_elim, res)

        new_stats = SmartPQStats(
            step=stats.step + 1,
            mode=new_mode,
            n_insert=n_insert,
            n_delete=n_delete,
            min_key=min_key,
            max_key=max_key,
            transitions=transitions,
            eliminated=stats.eliminated + n_elim,
            rejected=n_rejected,
            mode_steps=stats.mode_steps + (
                jnp.arange(NUM_MODES, dtype=jnp.int32) == new_mode
            ).astype(jnp.int32),
            head_refills=head_refills,
            ring_deferred=stats.ring_deferred,
        )
        out_carry = SmartPQCarry(res.state, new_stats)
        if return_features:
            return out_carry, res, feats
        return out_carry, res

    # -- the fused-window engine ----------------------------------------------

    @functools.cached_property
    def jit_run_window(self):
        """`run_window` jitted with the carry DONATED — the scan threads the
        PQState buffers in place, so a K-step window moves the queue zero
        times (asserted via `utils.hlo.donation_aliases` in tests).  Same
        threading contract as `jit_step`."""
        return jax.jit(self.run_window, donate_argnums=(0,))

    def run_window(
        self,
        carry: SmartPQCarry,
        ops: jnp.ndarray,  # (K, B)
        keys: jnp.ndarray,  # (K, B)
        vals: jnp.ndarray,  # (K, B)
        rngs: jax.Array,  # (K,) key array, one per step
        num_clients: jnp.ndarray | int | None = None,  # scalar or (K,)
        mode_override: jnp.ndarray | int | None = None,  # scalar or (K,)
    ) -> Tuple[SmartPQCarry, WindowResult]:
        """K adaptive steps fused into one `lax.scan` — ONE device dispatch
        for K * B operations.  The body is exactly `step` (decisions, mode
        switch, elimination), so the trace is bit-identical to K sequential
        `jit_step` calls with the same rngs; only the elimination pre-pass's
        operation-log sort is hoisted in front of the scan, where it
        vectorizes over the whole (K, B) window (Pallas match kernel on
        TPU).  Float key batches are sanitized once up front (non-finite
        lanes rejected into `stats.rejected`, exactly as `step` would
        per-batch); `mode_override` (scalar or (K,), -1 = none) pins the
        mode per step — the overload controller's degradation hook."""
        c = self.config
        K, B = ops.shape
        if num_clients is None:
            num_clients = c.num_shards
        nc = jnp.broadcast_to(
            jnp.asarray(num_clients, jnp.int32), (K,)
        )

        if jnp.issubdtype(jnp.asarray(keys).dtype, jnp.floating):
            keys, bad = O.sanitize_keys(keys)
            n_rej = jnp.sum(bad & (ops == OP_INSERT)).astype(jnp.int32)
            carry = carry._replace(
                stats=carry.stats._replace(
                    rejected=carry.stats.rejected + n_rej
                )
            )

        if c.eliminate:
            ins = ops == OP_INSERT
            sk, stg = L.sort_op_log(jnp.where(ins, keys, INF_KEY))
        else:  # placeholder lanes keep the scan xs structure static
            sk = jnp.zeros((K, B), jnp.int32)
            stg = jnp.zeros((K, B), jnp.int32)

        if mode_override is None:

            def body(cr, x):
                o, k, v, r, d, sk_t, stg_t = x
                cr2, res = self.step(
                    cr, o, k, v, r, d, presorted=(sk_t, stg_t)
                )
                return cr2, (res.keys, res.vals, res.n_out, cr2.stats.mode)

            xs = (ops, keys, vals, rngs, nc, sk, stg)
        else:
            ovs = jnp.broadcast_to(
                jnp.asarray(mode_override, jnp.int32), (K,)
            )

            def body(cr, x):
                o, k, v, r, d, sk_t, stg_t, ov = x
                cr2, res = self.step(
                    cr, o, k, v, r, d, presorted=(sk_t, stg_t),
                    mode_override=ov,
                )
                return cr2, (res.keys, res.vals, res.n_out, cr2.stats.mode)

            xs = (ops, keys, vals, rngs, nc, sk, stg, ovs)

        carry, (dk, dv, dn, dm) = jax.lax.scan(body, carry, xs)
        return carry, WindowResult(dk, dv, dn, dm)

    # -- the runtime guard tier -------------------------------------------------

    def validate_carry(self, carry: SmartPQCarry) -> None:
        """Run the host-side invariant checker over the carry's state and
        raise the first structured `InvariantViolation` found.  This is the
        `SmartPQConfig.validate` guard tier's primitive: one host sync per
        call — validated serving windows and `traces.replay` use it; the
        default (validate=False) path never does."""
        from repro.core.pqueue.state import invariant_violations

        viols = invariant_violations(carry.state, first_only=True)
        if viols:
            raise viols[0]

    # -- host-dispatch variant -------------------------------------------------

    def make_mode_steps(self):
        """One independently-jitted step function per mode + the host-side
        predictor.  State layout is identical between them, so the host
        dispatcher can flip modes between calls with zero copies — the same
        no-synchronization-point property, for runtimes that want smaller
        programs than the fused lax.switch one.  The state argument is
        donated (buffer-aliased in place); callers that need to keep a state
        across a call must `jax.tree.map(jnp.copy, state)` first."""
        c = self.config

        def _mk(schedule: Schedule):
            fn = SCH.SCHEDULE_FNS[schedule]

            @functools.partial(jax.jit, donate_argnums=(0,))
            def mode_step(state: PQState, ops, keys, vals, rng):
                B = ops.shape[0]
                ins_mask = ops == OP_INSERT
                b_del = jnp.sum(ops == OP_DELETE_MIN).astype(jnp.int32)
                active = b_del
                if c.eliminate:
                    sk, stg = L.sort_op_log(
                        jnp.where(ins_mask, keys, INF_KEY)
                    )
                    elim_k, elim_v, n_elim, keep_lane = O.elim_split(
                        state, sk, stg, vals, b_del
                    )
                    ins_mask = ins_mask & keep_lane
                    active = b_del - n_elim
                st, _ = insert(state, keys, vals, mask=ins_mask)
                res = fn(st, B, active, rng, c.npods)
                if c.eliminate:
                    res = O.merge_eliminated(elim_k, elim_v, n_elim, res)
                return res

            return mode_step

        return {mode: _mk(s) for mode, s in enumerate(c.mode_schedules)}

    def predict_mode_host(
        self, num_clients: int, size: int, key_range: int, insert_frac: float
    ) -> int:
        """Offline/debug inference only — the hot path never round-trips to
        the host: `step` (and the `run_window` scan body) evaluates the same
        packed tree on-device via `classifier.inference.tree_predict`."""
        from repro.core.classifier.features import featurize

        return int(self.tree.predict(featurize(num_clients, size, key_range, insert_frac))[0])


def carry_fingerprint(carry: SmartPQCarry) -> int:
    """CRC32 over the whole carry — the PQState's physical buffers
    (`state.state_fingerprint`) chained with every stats scalar.  The
    durability layer stamps this into snapshot manifests (an end-to-end
    integrity check on top of the per-shard file CRCs) and the crash
    recovery tests use it to assert an interrupted-then-replayed run
    reconverges bit-for-bit with an uninterrupted one."""
    import zlib

    import numpy as np

    from repro.core.pqueue.state import state_fingerprint

    crc = state_fingerprint(carry.state)
    for name, leaf in zip(SmartPQStats._fields, carry.stats):
        arr = np.ascontiguousarray(np.asarray(leaf))
        crc = zlib.crc32(arr.tobytes(), zlib.crc32(name.encode(), crc))
    return crc & 0xFFFFFFFF
