from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update  # noqa: F401
from repro.train.steps import make_train_step, make_eval_step  # noqa: F401
