"""Sharded checkpointing with atomic manifests, async writes, and elastic
resharding on restore.

Layout:
  <dir>/step_<N>/manifest.json       — step, tree structure, leaf index
  <dir>/step_<N>/shard_<i>.npz       — flat leaves, chunked by byte budget
  <dir>/LATEST                       — atomic pointer (rename) to step_<N>

Restore targets ANY mesh/device count: leaves are saved unsharded per host
(this is a single-controller runtime; a multi-host deployment would write
per-host shards keyed by process index — the manifest format already
carries the leaf index needed to reassemble).  `restore(..., shardings=)`
re-places every leaf onto the new mesh, which is the elastic-rescale path:
checkpoints taken on 512 chips restore onto 256 (or 8) without conversion.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

_SHARD_BYTES = 1 << 30  # 1 GiB per npz shard


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path is a late alias of
    # jax.tree_util.tree_flatten_with_path — use the long-lived spelling.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str | Path, step: int, tree: Any, *, async_write: bool = False):
    """Write a checkpoint; atomic LATEST pointer flips only after fsync."""
    ckpt_dir = Path(ckpt_dir)

    paths, leaves, _ = _flatten_with_paths(tree)
    # npz can't serialize ml_dtypes (bf16 etc.) — store as f32 + dtype tag;
    # restore() casts back to the target structure's dtype.
    host_leaves, dtypes = [], []
    for x in leaves:
        arr = np.asarray(x)
        dtypes.append(str(arr.dtype))
        if arr.dtype.kind not in "fiub" or str(arr.dtype) == "bfloat16":
            arr = arr.astype(np.float32)
        host_leaves.append(arr)

    def _write():
        tmp = ckpt_dir / f".tmp_step_{step}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        shards, cur, cur_bytes, idx = [], {}, 0, {}
        for name, arr in zip(paths, host_leaves):
            key = f"leaf_{len(cur)}"
            cur[key] = arr
            idx[name] = (len(shards), key)
            cur_bytes += arr.nbytes
            if cur_bytes >= _SHARD_BYTES:
                shards.append(cur)
                cur, cur_bytes = {}, 0
        shards.append(cur)
        for i, sh in enumerate(shards):
            np.savez(tmp / f"shard_{i}.npz", **sh)
        manifest = {
            "step": step,
            "leaves": {n: list(v) for n, v in idx.items()},
            "dtypes": dict(zip(paths, dtypes)),
            "n_shards": len(shards),
            "time": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = ckpt_dir / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        latest_tmp = ckpt_dir / ".LATEST.tmp"
        latest_tmp.write_text(f"step_{step}")
        latest_tmp.rename(ckpt_dir / "LATEST")  # atomic pointer flip

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    p = Path(ckpt_dir) / "LATEST"
    if not p.exists():
        return None
    return int(p.read_text().strip().split("_")[1])


def restore(
    ckpt_dir: str | Path,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of `like` (pytree of arrays or
    ShapeDtypeStructs).  `shardings` (same structure, optional) re-places
    leaves on the current mesh — the elastic-restore path."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no LATEST in {ckpt_dir}")
    d = ckpt_dir / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    shard_cache: Dict[int, Any] = {}

    paths, leaves, treedef = _flatten_with_paths(like)
    sh_flat = None
    if shardings is not None:
        sh_flat = treedef.flatten_up_to(shardings)

    out = []
    for i, (name, leaf) in enumerate(zip(paths, leaves)):
        shard_i, key = manifest["leaves"][name]
        if shard_i not in shard_cache:
            shard_cache[shard_i] = np.load(d / f"shard_{shard_i}.npz")
        arr = shard_cache[shard_i][key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        if sh_flat is not None:
            out.append(jax.device_put(arr, sh_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)
