"""Sharded checkpointing with atomic manifests, async writes, and elastic
resharding on restore — a thin training-flavored layer over
`repro.core.persist` (which owns the tmp+rename+manifest+CRC pattern,
shared with the serving tier's crash-consistent snapshots).

Layout (written by `persist.save_tree`):
  <dir>/step_<N>/manifest.json       — step, tree structure, leaf index,
                                       per-shard CRC32
  <dir>/step_<N>/shard_<i>.npz       — flat leaves, chunked by byte budget
  <dir>/LATEST                       — atomic pointer (rename) to step_<N>

Restore targets ANY mesh/device count: leaves are saved unsharded per host
(this is a single-controller runtime; a multi-host deployment would write
per-host shards keyed by process index — the manifest format already
carries the leaf index needed to reassemble).  `restore(..., shardings=)`
re-places every leaf onto the new mesh, which is the elastic-rescale path:
checkpoints taken on 512 chips restore onto 256 (or 8) without conversion.
"""

from __future__ import annotations

import threading
from pathlib import Path
from typing import Any, Optional

import jax

from repro.core import persist

_SHARD_BYTES = persist.SHARD_BYTES  # 1 GiB per npz shard


def save(ckpt_dir: str | Path, step: int, tree: Any, *, async_write: bool = False):
    """Write a checkpoint; atomic LATEST pointer flips only after fsync."""
    ckpt_dir = Path(ckpt_dir)
    # Snapshot leaves to host BEFORE returning (or spawning the writer
    # thread): the caller may donate/mutate the live tree right after.
    import numpy as np

    host_tree = jax.tree.map(np.asarray, tree)

    def _write():
        persist.save_tree(
            ckpt_dir, step, host_tree, shard_bytes=_SHARD_BYTES
        )

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str | Path) -> Optional[int]:
    return persist.latest_step(ckpt_dir)


def restore(
    ckpt_dir: str | Path,
    like: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of `like` (pytree of arrays or
    ShapeDtypeStructs).  `shardings` (same structure, optional) re-places
    leaves on the current mesh — the elastic-restore path."""
    sh_flat = None
    if shardings is not None:
        _, _, treedef = persist.flatten_with_paths(like)
        sh_flat = treedef.flatten_up_to(shardings)

    def place(i, arr, leaf):
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)
        if sh_flat is not None:
            return jax.device_put(arr, sh_flat[i])
        return jax.numpy.asarray(arr)

    tree, _manifest = persist.load_tree(ckpt_dir, like, step, place=place)
    return tree
