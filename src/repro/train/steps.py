"""Step-function builders: train / eval / prefill / serve.

These are what the launcher jits and the dry-run lowers.  One builder per
step kind; each returns (fn, in_shardings, out_shardings, input_specs).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD
from repro.distributed.sharding import ShardingRules, strip_pod
from repro.models.io import cache_specs, input_specs
from repro.models.model import Model, cross_entropy_loss
from repro.models.registry import build_model
from repro.train.optimizer import (
    AdamWConfig,
    OptState,
    adamw_init,
    adamw_update,
    opt_state_specs,
)

Tree = Dict[str, Any]


def _shardings_of(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _drop_batch_axes(spec_tree):
    """Replace the ('pod','data') batch group with None in every spec —
    used when global_batch doesn't divide the batch-device count (e.g. the
    long_500k cell's batch of 1)."""
    batch_group = {AXIS_POD, AXIS_DATA}

    def fix(spec):
        out = []
        for e in spec:
            if isinstance(e, tuple) and set(e) & batch_group:
                kept = tuple(a for a in e if a not in batch_group)
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            elif e in batch_group:
                out.append(None)
            else:
                out.append(e)
        return P(*out)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))


def batch_spec_tree(
    cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules, mesh=None,
    kv_int8: bool = False,
):
    """PartitionSpec tree matching models/io.input_specs structure."""
    tree = _batch_spec_tree(cfg, shape, rules, kv_int8)
    if mesh is not None:
        n_batch = 1
        for a in (AXIS_POD, AXIS_DATA):
            n_batch *= mesh.shape.get(a, 1)
        if shape.global_batch % n_batch != 0:
            tree = _drop_batch_axes(tree)
    return tree


def _batch_spec_tree(cfg: ModelConfig, shape: ShapeConfig, rules: ShardingRules,
                     kv_int8: bool = False):
    b = rules.tokens
    out: Tree = {}
    if shape.kind in ("train", "prefill"):
        out["tokens"] = b
        if shape.kind == "train":
            out["labels"] = b
        if cfg.family == "encdec":
            out["enc_embeds"] = rules.act_btd
        if cfg.family == "vlm":
            out["image_embeds"] = rules.act_btd
        return out
    # decode
    fam = cfg.family
    caches: Tree = {}
    if fam in ("dense", "moe", "encdec"):
        caches["k"] = rules.kv_cache
        caches["v"] = rules.kv_cache
        if kv_int8 and fam in ("dense", "moe"):
            scale_spec = P(*tuple(rules.kv_cache)[:-1])
            caches["k_scale"] = scale_spec
            caches["v_scale"] = scale_spec
        if fam == "encdec":
            caches["xk"] = rules.kv_cache
            caches["xv"] = rules.kv_cache
    elif fam == "ssm":
        caches["ssm_h"] = rules.ssm_state
        caches["ssm_conv"] = P(None, (AXIS_POD, AXIS_DATA), None, AXIS_MODEL)
    elif fam == "hybrid":
        caches["k"] = rules.kv_cache
        caches["v"] = rules.kv_cache
        caches["ssm_h"] = P(None, None, (AXIS_POD, AXIS_DATA), AXIS_MODEL, None, None)
        caches["ssm_conv"] = P(None, None, (AXIS_POD, AXIS_DATA), None, AXIS_MODEL)
    elif fam == "vlm":
        caches["k"] = P(None, None, (AXIS_POD, AXIS_DATA), AXIS_MODEL, None, None)
        caches["v"] = P(None, None, (AXIS_POD, AXIS_DATA), AXIS_MODEL, None, None)
        caches["xk"] = P(None, (AXIS_POD, AXIS_DATA), None, None, None)
        caches["xv"] = P(None, (AXIS_POD, AXIS_DATA), None, None, None)
    return {
        "tokens": P((AXIS_POD, AXIS_DATA), None),
        "lengths": P((AXIS_POD, AXIS_DATA)),
        "caches": caches,
    }


def make_train_step(
    cfg: ModelConfig,
    mesh: Optional[Mesh],
    opt_cfg: AdamWConfig = AdamWConfig(),
    rules: Optional[ShardingRules] = None,
    remat: bool = True,
    kv_chunk: int = 2048,
    microbatches: int = 1,
    **model_kwargs,
):
    """Returns (train_step, model).  train_step(params, opt_state, batch) ->
    (params, opt_state, metrics).

    microbatches > 1: gradient accumulation — the global batch is scanned
    in `microbatches` slices with fp32 grad accumulation, dividing
    activation memory by the same factor (the production answer for cells
    whose per-device activations exceed HBM; EXPERIMENTS.md §Perf It-5).
    """
    model = build_model(cfg, mesh=mesh, remat=remat, kv_chunk=kv_chunk,
                        rules=rules, **model_kwargs)
    if rules is None:
        rules = model.rules or ShardingRules()

    def loss_fn(params, batch):
        logits, aux = model.train_logits(params, batch)
        loss = cross_entropy_loss(logits, batch["labels"], cfg.vocab)
        return loss + 0.01 * aux, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: OptState, batch: Tree):
        if microbatches == 1:
            (total, (loss, aux)), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]),
                batch,
            )

            def acc_body(carry, mb):
                g_acc, l_acc, a_acc = carry
                (t, (l, a)), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda acc, gi: acc + gi.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l, a_acc + a), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss, aux), _ = jax.lax.scan(
                acc_body, (zeros, jnp.float32(0), jnp.float32(0)), micro
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss, aux = loss / microbatches, aux / microbatches
            total = loss + 0.01 * aux
        new_params, new_opt = adamw_update(params, grads, opt_state, opt_cfg)
        metrics = {
            "loss": loss,
            "aux_loss": aux,
            "total_loss": total,
            "step": new_opt.step,
        }
        return new_params, new_opt, metrics

    return train_step, model


def make_eval_step(cfg: ModelConfig, mesh, remat=False, kv_chunk: int = 2048):
    model = build_model(cfg, mesh=mesh, remat=remat, kv_chunk=kv_chunk)

    def eval_step(params, batch):
        logits, _ = model.train_logits(params, batch)
        return cross_entropy_loss(logits, batch["labels"], cfg.vocab)

    return eval_step, model


def make_prefill_step(cfg: ModelConfig, mesh, kv_chunk: int = 2048, rules=None,
                      **model_kwargs):
    model = build_model(cfg, mesh=mesh, remat=False, kv_chunk=kv_chunk,
                        rules=rules, **model_kwargs)

    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step, model


def make_serve_step(cfg: ModelConfig, mesh, kv_chunk: int = 4096, rules=None,
                    kv_int8: bool = False, **model_kwargs):
    """Decode step + greedy sampling + length bump — the serving inner loop."""
    model = build_model(cfg, mesh=mesh, remat=False, kv_chunk=kv_chunk,
                        rules=rules, kv_int8=kv_int8, **model_kwargs)

    def serve_step(params, batch):
        logits, caches = model.decode_step(
            params, batch["caches"], batch["tokens"], batch["lengths"]
        )
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return {
            "tokens": next_tokens,
            "lengths": batch["lengths"] + 1,
            "caches": caches,
        }

    return serve_step, model


def training_state_shardings(
    cfg: ModelConfig, mesh: Mesh, opt_cfg: AdamWConfig, params, param_specs
):
    rules = strip_pod(ShardingRules(), mesh)
    p_sh = _shardings_of(mesh, param_specs)
    o_specs = opt_state_specs(params, param_specs, opt_cfg)
    o_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        o_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
    return p_sh, o_sh
