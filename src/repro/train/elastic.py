"""Elastic rescaling: move a training state between meshes/device counts.

The scenario: a 512-chip job loses a pod (or gains one back) — the
replacement job builds whatever mesh its surviving devices support and
resumes from the checkpoint.  Because checkpoints store unsharded leaves
keyed by tree path (train/checkpoint.py), restore is placement-agnostic;
this module adds the explicit API and the live (no-checkpoint) device_put
path for in-process rescale.

Semantics guarantee: optimizer state and params are placement-invariant
(pure data), so training continues bit-identically modulo batch-sharding
summation order.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ShardingRules, strip_pod


def shardings_for(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def reshard_state(state: Any, new_shardings: Any) -> Any:
    """Live rescale: re-place every leaf onto the new mesh.  Works across
    device counts (jax gathers + redistributes)."""
    return jax.tree.map(jax.device_put, state, new_shardings)


def resume_on_new_mesh(
    ckpt_dir: str,
    like: Any,
    new_mesh: Mesh,
    spec_tree: Any,
    step: Optional[int] = None,
) -> Any:
    """Checkpoint-mediated rescale (the crash-recovery path)."""
    from repro.train import checkpoint as ckpt

    sh = shardings_for(new_mesh, spec_tree)
    return ckpt.restore(ckpt_dir, like, step=step, shardings=sh)


def fit_spec_to_mesh(spec_tree: Any, mesh: Mesh) -> Any:
    """Drop axes the new mesh doesn't have (e.g. 'pod' after losing one)."""
    names = set(mesh.axis_names)

    def fix(spec: P) -> P:
        out = []
        for e in spec:
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a in names)
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            else:
                out.append(e if e in names else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda x: isinstance(x, P))
