"""AdamW with sharded states (ZeRO-3 storage via param specs) and optional
low-precision moments.

State dtypes:
  fp32 — exact (small models)
  bf16 — halves optimizer memory (8B-class models)
  int8 — 8-bit-Adam style: the FIRST moment is blockwise int8 (symmetric,
         sign-balanced — linear quantization suffices); the SECOND moment
         stays bf16 (its dynamic range spans decades — linear int8 rounds
         small entries to zero and 1/sqrt(v) explodes; Dettmers et al. use
         nonlinear maps for exactly this reason).  jamba-398B on a single
         256-chip pod: 398e9 * (4 + 1 + 2 + 2) B / 256 ≈ 14 GB/chip.

Quantization is shape-preserving (blocks along the last dim), so the int8
payload inherits the parameter's PartitionSpec unchanged and optimizer
memory stays fully sharded over ('data', 'model') — the ZeRO trick falls
out of the sharding system.  Leaves whose last dim doesn't block-align
(scalars, tiny vectors) silently stay fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256  # int8 quantization block (last-dim groups)


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "fp32"  # fp32 | bf16 | int8


class OptState(NamedTuple):
    m: Any  # pytree; int8 leaves are (q int8 [param shape], scale fp32) pairs
    v: Any
    step: jnp.ndarray


def _int8_eligible(shape) -> bool:
    return len(shape) >= 1 and shape[-1] % BLOCK == 0


def _q8(x: jnp.ndarray):
    shape = x.shape
    blocks = x.reshape(shape[:-1] + (shape[-1] // BLOCK, BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0 + 1e-20  # (..., nb)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127)
    return q.reshape(shape).astype(jnp.int8), scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    shape = q.shape
    blocks = q.reshape(shape[:-1] + (shape[-1] // BLOCK, BLOCK)).astype(jnp.float32)
    return (blocks * scale[..., None]).reshape(shape)


def _encode(x: jnp.ndarray, dtype: str, moment: str = "m"):
    if dtype == "bf16":
        return x.astype(jnp.bfloat16)
    if dtype == "int8":
        if moment == "v":
            return x.astype(jnp.bfloat16)  # see module doc
        if _int8_eligible(x.shape):
            return _q8(x)
    return x  # fp32 (also the int8 fallback for tiny leaves)


def _decode(e, dtype: str) -> jnp.ndarray:
    if isinstance(e, tuple):
        return _dq8(*e)
    return e.astype(jnp.float32)


def adamw_init(params, cfg: AdamWConfig) -> OptState:
    def z(moment):
        return lambda p: _encode(
            jnp.zeros(p.shape, jnp.float32), cfg.state_dtype, moment
        )

    return OptState(
        m=jax.tree.map(z("m"), params),
        v=jax.tree.map(z("v"), params),
        step=jnp.zeros((), jnp.int32),
    )


def adamw_update(
    params, grads, state: OptState, cfg: AdamWConfig
) -> Tuple[Any, OptState]:
    """Returns (new_params, new_state).  Grads may be bf16; math is fp32."""
    step = state.step + 1
    gsq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads)
    )
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)

    new_p, new_m, new_v = [], [], []
    for p, g, me, ve in zip(flat_p, flat_g, flat_m, flat_v):
        g32 = g.astype(jnp.float32) * scale
        m = cfg.b1 * _decode(me, cfg.state_dtype) + (1 - cfg.b1) * g32
        v = cfg.b2 * _decode(ve, cfg.state_dtype) + (1 - cfg.b2) * g32 * g32
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        p32 = p32 - cfg.lr * (update + decay * p32)
        new_p.append(p32.astype(p.dtype))
        new_m.append(_encode(m, cfg.state_dtype, "m"))
        new_v.append(_encode(v, cfg.state_dtype, "v"))

    return (
        jax.tree.unflatten(treedef, new_p),
        OptState(
            m=jax.tree.unflatten(treedef, new_m),
            v=jax.tree.unflatten(treedef, new_v),
            step=step,
        ),
    )


def opt_state_specs(params, param_specs, cfg: AdamWConfig):
    """Spec tree mirroring OptState: int8 leaves -> (param_spec, scale_spec)
    where the scale replicates the (blocked) last dim."""
    from jax.sharding import PartitionSpec as P

    flat_p, treedef = jax.tree.flatten(params)
    flat_s = treedef.flatten_up_to(param_specs)

    def leaf_m(p, spec):
        if cfg.state_dtype == "int8" and _int8_eligible(p.shape):
            entries = list(spec) + [None] * (p.ndim - len(spec))
            scale_spec = P(*(entries[:-1] + [None]))
            return (spec, scale_spec)
        return spec

    m_specs = jax.tree.unflatten(
        treedef, [leaf_m(p, s) for p, s in zip(flat_p, flat_s)]
    )
    v_specs = param_specs  # v is plain (fp32/bf16) in every mode
    return OptState(m=m_specs, v=v_specs, step=P())
