"""Fault tolerance: straggler watchdog, failure injection, preemption.

At 1000+ nodes the common failures are (a) slow hosts (stragglers), (b)
preemptions, (c) hard node loss.  The runtime pieces here are host-side —
they wrap the jitted step, so they work identically under multi-host
jax.distributed:

  * StragglerWatchdog — EWMA of step wall-times; a step slower than
    `threshold x` the EWMA raises a StragglerEvent (the loop logs it and,
    on repeated events, triggers a checkpoint so a replacement can join —
    at real scale the detection signal comes per-host from the coordinator).
  * PreemptionGuard — converts SIGTERM/SIGINT into a "checkpoint now, then
    exit cleanly" request checked once per step.
  * FailureInjector — deterministic fault schedule for tests (step k ->
    raise), proving the restart path end-to-end.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, List, Optional


class StragglerEvent(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerWatchdog:
    threshold: float = 3.0  # x EWMA
    alpha: float = 0.2
    warmup_steps: int = 3
    _ewma: Optional[float] = None
    _seen: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> Optional[dict]:
        self._seen += 1
        if self._ewma is None:
            self._ewma = dt
            return None
        is_slow = self._seen > self.warmup_steps and dt > self.threshold * self._ewma
        event = None
        if is_slow:
            event = {"step": step, "dt": dt, "ewma": self._ewma}
            self.events.append(event)
        else:
            # Stragglers don't poison the baseline.
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * dt
        return event


class PreemptionGuard:
    """SIGTERM/SIGINT -> graceful 'save and exit' at the next step edge."""

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:
                    pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, h in self._prev.items():
            signal.signal(sig, h)


@dataclasses.dataclass
class FailureInjector:
    """fail_at: steps at which to raise (each fires once)."""

    fail_at: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise RuntimeError(f"injected failure at step {step}")
