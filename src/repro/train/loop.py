"""Training loop with checkpoint/restart, straggler watchdog, preemption
handling, and failure injection — the fault-tolerance story end-to-end.

Restart contract: `run()` called with the same `ckpt_dir` resumes from
LATEST (params + optimizer + data step), so a killed job loses at most
`ckpt_every` steps.  Elastic rescale: restore() re-places the saved leaves
onto whatever mesh the new process built (tests restore a 4-device-trained
state onto 1 device).
"""

from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.data.synthetic import SyntheticLMDataset
from repro.train import checkpoint as ckpt
from repro.train.fault import FailureInjector, PreemptionGuard, StragglerWatchdog
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.steps import make_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    batch_size: int = 8
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    async_ckpt: bool = False
    log_every: int = 10
    seed: int = 0
    straggler_threshold: float = 3.0


def run(
    cfg,  # ModelConfig
    loop: LoopConfig,
    mesh=None,
    opt_cfg: AdamWConfig = AdamWConfig(lr=1e-3),
    injector: Optional[FailureInjector] = None,
    data: Optional[SyntheticLMDataset] = None,
    install_signals: bool = False,
) -> Dict[str, Any]:
    """Train; returns summary (losses, events, resumed_from)."""
    train_step, model = make_train_step(cfg, mesh, opt_cfg, remat=True)
    jitted = jax.jit(train_step, donate_argnums=(0, 1))

    params, _specs = model.init(jax.random.key(loop.seed))
    opt_state = adamw_init(params, opt_cfg)
    start_step = 0
    resumed_from = None

    if loop.ckpt_dir and ckpt.latest_step(loop.ckpt_dir) is not None:
        state = ckpt.restore(
            loop.ckpt_dir, {"params": params, "opt": opt_state}
        )
        params, opt_state = state["params"], state["opt"]
        start_step = int(np.asarray(jax.tree.leaves(opt_state.step)[0]))
        resumed_from = start_step

    data = data or SyntheticLMDataset(vocab=cfg.vocab, seq_len=128, seed=loop.seed)
    watchdog = StragglerWatchdog(threshold=loop.straggler_threshold)
    guard = PreemptionGuard(install=install_signals)
    losses: List[float] = []
    events: List[dict] = []
    pending_ckpt = None

    step = start_step
    try:
        while step < loop.steps:
            if injector:
                injector.maybe_fail(step)
            batch = jax.tree.map(
                jax.numpy.asarray, data.batch(step, loop.batch_size)
            )
            t0 = time.time()
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            ev = watchdog.observe(step, dt)
            if ev:
                events.append({"kind": "straggler", **ev})
            losses.append(loss)
            step += 1

            want_ckpt = loop.ckpt_dir and (
                step % loop.ckpt_every == 0 or guard.requested
            )
            if want_ckpt:
                if pending_ckpt is not None:
                    pending_ckpt.join()
                pending_ckpt = ckpt.save(
                    loop.ckpt_dir,
                    step,
                    {"params": params, "opt": opt_state},
                    async_write=loop.async_ckpt,
                )
            if guard.requested:
                events.append({"kind": "preempted", "step": step})
                break
    finally:
        if pending_ckpt is not None:
            pending_ckpt.join()
        if install_signals:
            guard.restore()

    return {
        "losses": losses,
        "steps_done": step,
        "resumed_from": resumed_from,
        "events": events,
        "params": params,
        "opt_state": opt_state,
    }
