"""Two-choice min-sampling Pallas kernels — the MULTIQ deleteMin hot path.

Two kernels back `Schedule.MULTIQ` (the relaxed MultiQueue schedule):

  * `twochoice_pick_pallas`: the probe/commit step.  Every deleter lane
    holds two uniformly-sampled sub-queue (shard) ids; the kernel reads the
    cached per-shard minima, commits each lane to the shard whose cached min
    is smaller (ties toward the lower shard id — deterministic), and counts
    how many lanes landed on each shard.  Gather-free formulation: shard ids
    become one-hot masks via broadcasted_iota compares, so the VPU sees only
    (m, S) elementwise compare/select/reduce — no dynamic indexing, which
    Mosaic cannot lower for int gathers.

  * `multiq_select_pallas`: the commit-side tournament.  Each shard serves
    its committed lanes from a head-prefix window; the kernel masks the
    (S, m) windows to the per-shard take counts and reduces them to the m
    globally-smallest removed pairs, ascending — REUSING
    `bitonic_merge_topk` from `bitonic_topk` as the inner merge network
    (same O(S*m log m) compare structure, same lexicographic (key, tag)
    determinism contract as the exact-tournament kernel).

Both follow the repo kernel conventions: jnp references in `kernels.ref`,
padding/dispatch in `kernels.ops`, interpret mode on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic_topk import bitonic_merge_topk

INT32_MAX = jnp.iinfo(jnp.int32).max


def _twochoice_kernel(
    mins_ref, choice_a_ref, choice_b_ref, act_ref, counts_ref
):
    """(1, S) mins + (1, m) choices/mask -> (1, S) per-shard commit counts."""
    mins = mins_ref[...]  # (1, S)
    a = choice_a_ref[...]  # (1, m)
    b = choice_b_ref[...]
    act = act_ref[...] != 0  # (1, m)
    S = mins.shape[-1]
    m = a.shape[-1]

    shard_ids = jax.lax.broadcasted_iota(jnp.int32, (m, S), 1)  # (m, S)
    oh_a = shard_ids == a.reshape(m, 1)
    oh_b = shard_ids == b.reshape(m, 1)
    min_a = jnp.min(jnp.where(oh_a, mins, INT32_MAX), axis=1)  # (m,)
    min_b = jnp.min(jnp.where(oh_b, mins, INT32_MAX), axis=1)

    af = a.reshape(m)
    bf = b.reshape(m)
    pick_a = (min_a < min_b) | ((min_a == min_b) & (af <= bf))
    chosen = jnp.where(pick_a, af, bf)
    chosen = jnp.where(act.reshape(m), chosen, S)  # park inactive lanes

    committed = shard_ids == chosen.reshape(m, 1)  # (m, S) one-hot
    counts_ref[...] = jnp.sum(committed.astype(jnp.int32), axis=0).reshape(1, S)


@functools.partial(jax.jit, static_argnames=("interpret",))
def twochoice_pick_pallas(
    mins: jnp.ndarray,  # (S,) int32 cached per-shard minima
    choice_a: jnp.ndarray,  # (m,) int32 in [0, S)
    choice_b: jnp.ndarray,  # (m,) int32 in [0, S)
    act: jnp.ndarray,  # (m,) int32 — 0 parks the lane
    interpret: bool = True,
) -> jnp.ndarray:
    """Per-shard commit counts of the two-choice probe step.  (S,) int32."""
    S = mins.shape[0]
    m = choice_a.shape[0]
    return pl.pallas_call(
        _twochoice_kernel,
        in_specs=[
            pl.BlockSpec((1, S), lambda: (0, 0)),
            pl.BlockSpec((1, m), lambda: (0, 0)),
            pl.BlockSpec((1, m), lambda: (0, 0)),
            pl.BlockSpec((1, m), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, S), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, S), jnp.int32),
        interpret=interpret,
    )(
        mins.reshape(1, S),
        choice_a.reshape(1, m).astype(jnp.int32),
        choice_b.reshape(1, m).astype(jnp.int32),
        act.reshape(1, m).astype(jnp.int32),
    )[0]


def _multiq_select_kernel(win_k_ref, win_v_ref, take_ref, out_k_ref, out_v_ref):
    """(S, m) head windows + (S, 1) takes -> (1, m) smallest removed pairs."""
    win_k = win_k_ref[...]  # (S, m)
    win_v = win_v_ref[...]
    take = take_ref[...]  # (S, 1)
    S, m = win_k.shape

    col = jax.lax.broadcasted_iota(jnp.int32, (S, m), 1)
    mask = col < take  # head-prefix pops only
    masked_k = jnp.where(mask, win_k, INT32_MAX)
    masked_v = jnp.where(mask, win_v, INT32_MAX)

    # Each row is already an ascending m-run (sorted shard buffer head;
    # masking a prefix keeps it ascending — INF holes sort to the tail by
    # construction), so no per-row sort is needed: fold the S runs straight
    # through the same bitonic merge network the exact tournament uses.
    acc_k, acc_v = masked_k[0:1, :], masked_v[0:1, :]
    for s in range(1, S):
        acc_k, acc_v = bitonic_merge_topk(
            acc_k, acc_v, masked_k[s : s + 1, :], masked_v[s : s + 1, :]
        )
    out_k_ref[...] = acc_k
    out_v_ref[...] = acc_v


@functools.partial(jax.jit, static_argnames=("interpret",))
def multiq_select_pallas(
    win_k: jnp.ndarray,  # (S, m) head windows, each ascending; m power of two
    win_v: jnp.ndarray,  # (S, m) position tags (lexicographic determinism)
    take: jnp.ndarray,  # (S,) int32 commit counts, <= m
    interpret: bool = True,
):
    """m smallest (key, tag) pairs of the masked windows, ascending."""
    S, m = win_k.shape
    assert m & (m - 1) == 0, f"multiq_select needs power-of-two m, got {m}"
    return pl.pallas_call(
        _multiq_select_kernel,
        in_specs=[
            pl.BlockSpec((S, m), lambda: (0, 0)),
            pl.BlockSpec((S, m), lambda: (0, 0)),
            pl.BlockSpec((S, 1), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, m), lambda: (0, 0)),
            pl.BlockSpec((1, m), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, m), win_k.dtype),
            jax.ShapeDtypeStruct((1, m), win_v.dtype),
        ],
        interpret=interpret,
    )(win_k, win_v, take.reshape(S, 1).astype(jnp.int32))
