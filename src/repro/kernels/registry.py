"""Kernel registry — every kernel's arms, tuning axes, and dispatch rule
declared in ONE place.

Each kernel the PQ hot paths use is a `KernelSpec`: its reference (jnp)
arms, its Pallas arms (interpret / compiled, crossed with static tuning
axes such as ``rows_per_block``), the validation shapes the parity tests
sweep, the tuning shapes the autotune harness benchmarks, and an analytic
cost model (bytes / compare-ops) for the roofline records.

`resolve` is the single dispatch rule every public wrapper in
`kernels.ops` goes through (the hygiene gate enforces this — no stray
``interpret=`` branches outside ``kernels/``):

    explicit ``arm=`` argument              (tests, benchmarks)
    > force override                        (`force_arms` / REPRO_PQ_KERNEL_ARM)
    > tuning-cache winner                   (`kernels.tuning`, keyed by
                                             backend + jax version + shape)
    > legacy REPRO_PQ_KERNELS=1             (first Pallas arm available)
    > the spec's safe default               (a jnp arm — today's behavior
                                             when no tuning record exists)

Platform awareness lives in `supports_compiled`: compiled (non-interpret)
Pallas arms are only offered on TPU.  GPU deliberately gets the jnp arms —
the Mosaic kernels do not lower to Triton, and the old
``interpret=not _on_tpu()`` rule silently handed GPU the Python-interpreted
kernel bodies, which is never the fast choice.

Arm naming: ``ref`` / ``argsort`` / ``rank`` / ``scatter`` / ``sorted`` are
jnp arms; Pallas arms are ``interpret`` / ``compiled`` with tuning-axis
values appended as ``@axis=value`` (e.g. ``interpret@rows_per_block=8``).
All arms of a kernel are bit-identical on its contract inputs (parity-swept
by tests/test_kernel_registry.py); tuning only ever changes speed.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import os
from typing import Callable, Dict, Mapping, Optional, Tuple

import numpy as np
import jax

INT32_MAX = np.iinfo(np.int32).max


# ---------------------------------------------------------------------------
# platform predicate
# ---------------------------------------------------------------------------


def supports_compiled(backend: Optional[str] = None) -> bool:
    """Can this backend run the Pallas kernels compiled (non-interpret)?

    cpu — no: interpret mode only (the validation mode; the jnp arms are
          the production CPU paths).
    gpu — no: the kernels are written for Mosaic; there is no Triton
          lowering yet, so GPU routes to the jnp arms instead of silently
          falling back to interpret mode (the old ``_on_tpu()`` bug).
    tpu — yes: Mosaic lowering.
    """
    backend = backend or jax.default_backend()
    return backend == "tpu"


# ---------------------------------------------------------------------------
# arms
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Arm:
    """One implementation choice for a kernel.

    kind: "jnp" (reference-class, always available), "interpret" (Pallas
    in interpret mode, always available), "compiled" (Pallas lowered —
    requires `supports_compiled()`).
    params: static tuning-axis values forwarded to the Pallas wrapper
    (e.g. rows_per_block).  jnp arms carry no params.
    """

    name: str
    kind: str  # "jnp" | "interpret" | "compiled"
    params: Tuple[Tuple[str, int], ...] = ()

    def available(self, backend: Optional[str] = None) -> bool:
        if self.kind == "compiled":
            return supports_compiled(backend)
        return True

    @property
    def kwargs(self) -> Dict[str, int]:
        return dict(self.params)


def _pallas_arms(axes: Mapping[str, Tuple[int, ...]]) -> Tuple[Arm, ...]:
    """interpret + compiled arms crossed with the static tuning axes."""
    combos: Tuple[Tuple[Tuple[str, int], ...], ...] = ((),)
    for axis, values in axes.items():
        combos = tuple(c + ((axis, v),) for c in combos for v in values)
    arms = []
    for kind in ("interpret", "compiled"):
        for params in combos:
            suffix = "".join(f"@{k}={v}" for k, v in params)
            arms.append(Arm(f"{kind}{suffix}", kind, params))
    return tuple(arms)


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One kernel, declared once.

    name:    the public wrapper name in `kernels.ops`.
    arms:    every implementation choice (jnp + Pallas × axes).
    default: the safe arm used when nothing forces or tunes the choice —
             always a jnp arm, so a missing/corrupt tuning cache can never
             pick a slower-or-unavailable path.
    validation_shapes: coordinate dicts the parity tests sweep (small).
    tuning_shapes:     coordinate dicts the autotune harness benchmarks
                       (the hot-path shapes the PQ actually runs).
    make_inputs: (coords, rng) -> (args, static_kwargs) for the wrapper.
    cost_model:  coords -> {"bytes": int, "cmp_ops": float} roofline terms.
    """

    name: str
    arms: Tuple[Arm, ...]
    default: str
    validation_shapes: Tuple[Mapping[str, object], ...]
    tuning_shapes: Tuple[Mapping[str, object], ...]
    make_inputs: Callable
    cost_model: Callable

    def arm(self, name: str) -> Arm:
        for a in self.arms:
            if a.name == name:
                return a
        raise KeyError(f"{self.name}: unknown arm {name!r} "
                       f"(have {[a.name for a in self.arms]})")

    def available_arms(self, backend: Optional[str] = None) -> Tuple[Arm, ...]:
        return tuple(a for a in self.arms if a.available(backend))


def sig(coords: Mapping[str, object]) -> str:
    """Canonical shape signature — the per-shape tuning-cache key part."""
    return ",".join(f"{k}={coords[k]}" for k in sorted(coords))


# ---------------------------------------------------------------------------
# force overrides
# ---------------------------------------------------------------------------

# kernel name (or "*") -> arm name.  Seeded from REPRO_PQ_KERNEL_ARM, which
# accepts a bare arm name (applies to every kernel) or a comma list of
# kernel=arm entries.
_FORCED: Dict[str, str] = {}


def _parse_force_env() -> None:
    raw = os.environ.get("REPRO_PQ_KERNEL_ARM", "")
    if not raw:
        return
    for part in raw.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part and "@" not in part.split("=", 1)[0]:
            k, _, v = part.partition("=")
            _FORCED[k.strip()] = v.strip()
        else:
            _FORCED["*"] = part


_parse_force_env()

# Legacy escape hatch (pre-registry): force the Pallas path everywhere.
_LEGACY_FORCE_KERNELS = os.environ.get("REPRO_PQ_KERNELS", "") == "1"


def set_force_arm(kernel: str, arm: Optional[str]) -> None:
    """Force `kernel` (or "*" for all) to `arm`; None clears the override.
    An override naming an arm unavailable on this backend is ignored at
    resolve time (falls through to the default) rather than crashing."""
    if arm is None:
        _FORCED.pop(kernel, None)
    else:
        _FORCED[kernel] = arm


@contextlib.contextmanager
def force_arms(mapping: Mapping[str, str]):
    """Scoped force overrides: {"windowed_merge": "interpret@...", ...} or
    {"*": "ref"}.  Restores the previous overrides on exit."""
    saved = dict(_FORCED)
    try:
        _FORCED.update(mapping)
        yield
    finally:
        _FORCED.clear()
        _FORCED.update(saved)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------


# (kernel, shape sig, arm, source) tuples already reported to telemetry —
# resolve() runs inside hot dispatch wrappers, so each distinct resolution
# is noted ONCE per process, not per call.
_NOTED: set = set()


def _note_resolution(name: str, shape_sig: str, arm: str,
                     source: str) -> str:
    """Record an arm resolution in the process-global observability bundle
    (counter + one timeline instant per distinct resolution).  Telemetry
    must never break dispatch: any obs failure is swallowed."""
    key = (name, shape_sig, arm, source)
    if key in _NOTED:
        return arm
    _NOTED.add(key)
    try:
        from repro.obs import get_default

        obs = get_default()
        obs.metrics.inc("kernel_resolutions_total", kernel=name, arm=arm,
                        source=source)
        obs.tracer.instant("kernel_arm_resolved", cat="kernels",
                           kernel=name, sig=shape_sig, arm=arm,
                           source=source)
    except Exception:  # pragma: no cover — obs must not affect dispatch
        pass
    return arm


def resolve(name: str, coords: Mapping[str, object],
            arm: Optional[str] = None) -> str:
    """The dispatch rule (module docstring).  Returns an arm NAME that is
    guaranteed available on the current backend.  Every distinct
    (kernel, shape, arm, source) resolution is noted once in the default
    observability registry — dispatch decisions are part of the run's
    telemetry story, not invisible env-dependent magic."""
    spec = REGISTRY[name]
    backend = jax.default_backend()
    avail = {a.name for a in spec.arms if a.available(backend)}
    s = sig(coords)

    if arm is not None:  # explicit wins, and must be real
        if arm not in avail:
            raise ValueError(
                f"{name}: arm {arm!r} is not available on backend "
                f"{backend!r} (available: {sorted(avail)})"
            )
        return _note_resolution(name, s, arm, "explicit")

    forced = _FORCED.get(name, _FORCED.get("*"))
    if forced is not None and forced in avail:
        return _note_resolution(name, s, forced, "forced")

    from repro.kernels import tuning  # function-level: tuning imports us

    winner = tuning.cached_winner(name, s)
    if winner is not None and winner in avail:
        return _note_resolution(name, s, winner, "tuned")

    if _LEGACY_FORCE_KERNELS:
        for a in spec.arms:
            if a.kind != "jnp" and a.name in avail:
                return _note_resolution(name, s, a.name, "legacy_env")

    return _note_resolution(name, s, spec.default, "default")


def arm_kwargs(name: str, arm: str) -> Dict[str, int]:
    """Static Pallas kwargs for a named arm (interpret flag + axis values)."""
    a = REGISTRY[name].arm(arm)
    kw = a.kwargs
    if a.kind in ("interpret", "compiled"):
        kw["interpret"] = a.kind == "interpret"
    return kw


# ---------------------------------------------------------------------------
# input makers (validation + tuning harness)
# ---------------------------------------------------------------------------


def _mk_topk(coords, rng):
    import jax.numpy as jnp

    R, N, k = coords["R"], coords["N"], coords["k"]
    dtype = np.dtype(coords.get("dtype", "int32"))
    lo, hi = (0, 1 << 20) if dtype == np.int32 else (-30, 30)
    keys = rng.integers(lo, hi, (R, N)).astype(dtype)
    vals = np.tile(np.arange(N, dtype=np.int32), (R, 1))
    return (jnp.asarray(keys), jnp.asarray(vals)), {"k": k}


def _mk_elim_sort(coords, rng):
    import jax.numpy as jnp

    from repro.core.pqueue.state import INF_KEY

    R, B = coords["R"], coords["B"]
    keys = rng.integers(0, 64, (R, B)).astype(np.int32)  # heavy ties
    keys[rng.random((R, B)) < 0.3] = INF_KEY  # masked non-insert lanes
    tags = np.tile(np.arange(B, dtype=np.int32), (R, 1))
    return (jnp.asarray(keys), jnp.asarray(tags)), {}


def _mk_twochoice(coords, rng):
    import jax.numpy as jnp

    S, m = coords["S"], coords["m"]
    mins = rng.integers(0, 1 << 20, S).astype(np.int32)
    a = rng.integers(0, S, m).astype(np.int32)
    b = rng.integers(0, S, m).astype(np.int32)
    act = (rng.random(m) < 0.8).astype(np.int32)
    return tuple(jnp.asarray(x) for x in (mins, a, b, act)), {}


def _mk_multiq_select(coords, rng):
    import jax.numpy as jnp

    from repro.core.pqueue.state import INF_KEY

    S, m = coords["S"], coords["m"]
    win_k = np.full((S, m), INF_KEY, np.int32)
    win_v = np.zeros((S, m), np.int32)
    for s in range(S):
        n = rng.integers(0, m + 1)
        win_k[s, :n] = np.sort(rng.integers(0, 200, n)).astype(np.int32)
        win_v[s, :n] = rng.integers(0, 1 << 20, n)
    take = rng.integers(0, m + 1, S).astype(np.int32)
    return tuple(jnp.asarray(x) for x in (win_k, win_v, take)), {}


def _sorted_rows(rng, S, W, fill, lo=0, hi=200):
    out = np.full((S, W), fill, np.int32)
    for s in range(S):
        n = rng.integers(0, W + 1)
        out[s, :n] = np.sort(rng.integers(lo, hi, n)).astype(np.int32)
    return out


def _mk_windowed_merge(coords, rng):
    import jax.numpy as jnp

    from repro.core.pqueue.state import INF_KEY

    S, H, R = coords["S"], coords["H"], coords["R"]
    head_k = _sorted_rows(rng, S, H, INF_KEY)
    run_k = _sorted_rows(rng, S, R, INF_KEY)
    head_v = rng.integers(0, 1 << 20, (S, H)).astype(np.int32)
    run_v = rng.integers(0, 1 << 20, (S, R)).astype(np.int32)
    head_q = np.tile(np.arange(H, dtype=np.int32), (S, 1))
    run_q = 1000 + np.tile(np.arange(R, dtype=np.int32), (S, 1))
    args = (head_k, head_v, head_q, run_k, run_v, run_q)
    return tuple(jnp.asarray(x) for x in args), {}


def _mk_merge_sorted(coords, rng):
    import jax.numpy as jnp

    from repro.core.pqueue.state import INF_KEY

    S, C, R = coords["S"], coords["C"], coords["R"]
    buf_k = _sorted_rows(rng, S, C, INF_KEY)
    run_k = _sorted_rows(rng, S, R, INF_KEY)
    buf_v = np.zeros((S, C), np.int32)
    run_v = np.full((S, R), 1 << 20, np.int32)
    for s in range(S):
        buf_v[s] = np.arange(C)
        run_v[s] = (1 << 20) + np.arange(R)
    args = (buf_k, buf_v, run_k, run_v)
    return tuple(jnp.asarray(x) for x in args), {}


def _mk_segmin(coords, rng):
    import jax.numpy as jnp

    from repro.core.pqueue.state import INF_KEY

    E, n = coords["E"], coords["n"]
    dist = rng.integers(0, 1 << 20, n).astype(np.int32)
    # targets include the out-of-range drop sentinel n, like the SSSP relax
    tgt = rng.integers(0, n + 1, E).astype(np.int32)
    vals = np.where(
        rng.random(E) < 0.2, INF_KEY,
        rng.integers(0, 1 << 20, E),
    ).astype(np.int32)
    return tuple(jnp.asarray(x) for x in (dist, tgt, vals)), {}


# ---------------------------------------------------------------------------
# cost models (roofline terms; int32 operands -> 4 bytes)
# ---------------------------------------------------------------------------


def _log2(x: int) -> float:
    return math.log2(max(x, 2))


def _cost_topk(c):
    R, N, k = c["R"], c["N"], c["k"]
    return {"bytes": 4 * (2 * R * N + 2 * R * k),
            "cmp_ops": R * N * (_log2(k) + 1)}


def _cost_elim_sort(c):
    R, B = c["R"], c["B"]
    lg = _log2(B)
    return {"bytes": 4 * 4 * R * B,
            "cmp_ops": R * (B / 2) * lg * (lg + 1) / 2}


def _cost_twochoice(c):
    S, m = c["S"], c["m"]
    return {"bytes": 4 * (S + 3 * m + S), "cmp_ops": 2.0 * m * S}


def _cost_multiq_select(c):
    S, m = c["S"], c["m"]
    return {"bytes": 4 * (2 * S * m + S + 2 * m),
            "cmp_ops": S * m * _log2(m)}


def _cost_windowed_merge(c):
    S, H, R = c["S"], c["H"], c["R"]
    W = H + R
    return {"bytes": 4 * (3 * S * (H + R) + 3 * S * W),
            "cmp_ops": S * (W / 2) * _log2(W)}


def _cost_merge_sorted(c):
    S, C = c["S"], c["C"]
    return {"bytes": 4 * (2 * S * C + 2 * S * c["R"] + 2 * S * C),
            "cmp_ops": S * C * _log2(2 * C)}


def _cost_segmin(c):
    E, n = c["E"], c["n"]
    return {"bytes": 4 * (2 * n + 2 * E),
            "cmp_ops": E * (_log2(E) + 1)}


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------


def _spec(name, jnp_arms, default, axes, validation, tuning_shapes,
          make_inputs, cost_model) -> KernelSpec:
    # axes=None: jnp-only kernel (no Pallas path); axes={}: Pallas arms
    # with no tuning axes beyond interpret/compiled.
    pallas = _pallas_arms(axes) if axes is not None else ()
    arms = tuple(Arm(n, "jnp") for n in jnp_arms) + pallas
    return KernelSpec(
        name=name, arms=arms, default=default,
        validation_shapes=tuple(validation),
        tuning_shapes=tuple(tuning_shapes),
        make_inputs=make_inputs, cost_model=cost_model,
    )


REGISTRY: Dict[str, KernelSpec] = {
    s.name: s
    for s in (
        _spec(
            "topk_smallest",
            jnp_arms=("ref", "argsort"), default="argsort",
            axes={"rows_per_block": (1, 8)},
            validation=(
                {"R": 8, "N": 256, "k": 16, "dtype": "int32"},
                {"R": 3, "N": 100, "k": 7, "dtype": "int32"},
                {"R": 1, "N": 64, "k": 64, "dtype": "int32"},
                {"R": 5, "N": 1024, "k": 128, "dtype": "int32"},
            ),
            tuning_shapes=(
                # the deleteMin tournaments the fig9 cast actually runs
                # (R=1, k=64, N = candidate count per schedule), plus one
                # batched discriminator where the network's win is large
                {"R": 1, "N": 1024, "k": 64, "dtype": "int32"},
                {"R": 1, "N": 1424, "k": 64, "dtype": "int32"},
                {"R": 1, "N": 512, "k": 64, "dtype": "int32"},
                {"R": 1, "N": 128, "k": 64, "dtype": "int32"},
                {"R": 16, "N": 4096, "k": 64, "dtype": "int32"},
            ),
            make_inputs=_mk_topk, cost_model=_cost_topk,
        ),
        _spec(
            "elim_sort",
            jnp_arms=("ref", "argsort"), default="argsort",
            axes={"rows_per_block": (1, 8)},
            validation=(
                {"R": 1, "B": 16}, {"R": 4, "B": 64}, {"R": 6, "B": 37},
                {"R": 8, "B": 128},
            ),
            tuning_shapes=(
                # the K-step window op-log sort (K rows of B lanes)
                {"R": 64, "B": 64},
                {"R": 16, "B": 64},
                {"R": 256, "B": 64},
            ),
            make_inputs=_mk_elim_sort, cost_model=_cost_elim_sort,
        ),
        _spec(
            "twochoice_counts",
            jnp_arms=("ref",), default="ref", axes={},
            validation=(
                {"S": 4, "m": 16}, {"S": 16, "m": 64}, {"S": 8, "m": 5},
            ),
            tuning_shapes=({"S": 16, "m": 64},),
            make_inputs=_mk_twochoice, cost_model=_cost_twochoice,
        ),
        _spec(
            "multiq_select_topm",
            jnp_arms=("ref",), default="ref", axes={},
            validation=(
                {"S": 4, "m": 16}, {"S": 16, "m": 64}, {"S": 2, "m": 8},
            ),
            tuning_shapes=({"S": 16, "m": 64},),
            make_inputs=_mk_multiq_select, cost_model=_cost_multiq_select,
        ),
        _spec(
            "windowed_merge",
            jnp_arms=("ref", "rank"), default="rank",
            axes={"rows_per_block": (1, 4)},
            validation=(
                {"S": 4, "H": 64, "R": 16}, {"S": 2, "H": 256, "R": 7},
                {"S": 6, "H": 100, "R": 60}, {"S": 3, "H": 8, "R": 8},
            ),
            tuning_shapes=(
                # the tiered-insert head merge (H=256 default head tier)
                {"S": 16, "H": 256, "R": 64},
                {"S": 16, "H": 256, "R": 256},
            ),
            make_inputs=_mk_windowed_merge, cost_model=_cost_windowed_merge,
        ),
        _spec(
            "merge_sorted_runs",
            jnp_arms=("ref",), default="ref",
            axes={"rows_per_block": (1, 4)},
            validation=(
                {"S": 4, "C": 64, "R": 16}, {"S": 2, "C": 256, "R": 7},
                {"S": 1, "C": 64, "R": 1},
            ),
            tuning_shapes=({"S": 8, "C": 1024, "R": 128},),
            make_inputs=_mk_merge_sorted, cost_model=_cost_merge_sorted,
        ),
        _spec(
            "segment_min_into",
            jnp_arms=("scatter", "sorted"), default="scatter", axes=None,
            validation=(
                {"E": 64, "n": 32}, {"E": 256, "n": 512}, {"E": 7, "n": 5},
                {"E": 2048, "n": 512},
            ),
            tuning_shapes=(
                # SSSP relax: E = m * deg_cap candidates into n vertices
                {"E": 256, "n": 512},
                {"E": 2048, "n": 512},
            ),
            make_inputs=_mk_segmin, cost_model=_cost_segmin,
        ),
    )
}
