"""Sorted-merge Pallas kernel — the insert path's hot spot.

Merges an ascending incoming run (R wide, INF-padded) into each shard's
ascending capacity-C buffer, keeping the C smallest of the union (overflow
— necessarily the largest elements — is dropped; the wrapper reports the
count, mirroring `local.merge_sorted`).

TPU adaptation: a CPU/GPU merge walks two pointers (data-dependent control
flow — hostile to the VPU) or rank-scatters (dynamic scatter — hostile to
Mosaic).  Instead we use a single bitonic MERGE network:

    concat(buffer_asc, reverse(pad(run)_asc))  is bitonic (2C wide)
    -> log2(2C) static clean stages sort it ascending
    -> the first C lanes are exactly the merge result.

All stages are static reshapes + selects on a VMEM-resident (rows, 2C)
tile.  Compare ops: 2C * log2(2C) per shard row vs. C*R for the
broadcast-compare rank method — for C=4096, R=256 that is 106K vs. 1M.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic_topk import clean_bitonic


def _merge_kernel(buf_k_ref, buf_v_ref, run_k_ref, run_v_ref, out_k_ref, out_v_ref):
    """Row-block kernel: buffer (rows, C) + run (rows, C, INF-padded from R)
    -> merged (rows, C) ascending (smallest C of the union)."""
    buf_k = buf_k_ref[...]
    buf_v = buf_v_ref[...]
    run_k = run_k_ref[...]
    run_v = run_v_ref[...]

    cat_k = jnp.concatenate([buf_k, jnp.flip(run_k, axis=-1)], axis=-1)
    cat_v = jnp.concatenate([buf_v, jnp.flip(run_v, axis=-1)], axis=-1)
    merged_k, merged_v = clean_bitonic(cat_k, cat_v)

    C = buf_k.shape[-1]
    out_k_ref[...] = merged_k[:, :C]
    out_v_ref[...] = merged_v[:, :C]


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def merge_sorted_pallas(
    buf_k: jnp.ndarray,  # (S, C) ascending, INF-padded
    buf_v: jnp.ndarray,
    run_k: jnp.ndarray,  # (S, C) ascending, INF-padded (R <= C padded up)
    run_v: jnp.ndarray,
    rows_per_block: int = 4,
    interpret: bool = True,
):
    """pallas_call wrapper.  C must be a power of two; the run array must
    already be padded to width C (ops.py handles padding from R)."""
    S, C = buf_k.shape
    assert C & (C - 1) == 0, f"capacity must be a power of two, got {C}"
    assert run_k.shape == (S, C), (run_k.shape, (S, C))
    while S % rows_per_block:
        rows_per_block //= 2
    grid = (S // rows_per_block,)

    spec = pl.BlockSpec((rows_per_block, C), lambda i: (i, 0))
    return pl.pallas_call(
        _merge_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((S, C), buf_k.dtype),
            jax.ShapeDtypeStruct((S, C), buf_v.dtype),
        ],
        interpret=interpret,
    )(buf_k, buf_v, run_k, run_v)
