"""Per-shape kernel tuning: benchmark every arm, persist the winners.

The tuner times every available arm of every registered kernel on its
declared tuning shapes (`KernelSpec.tuning_shapes`) and writes the winners
to an on-disk cache that `registry.resolve` consults at dispatch time.

Cache contract (the autotune-and-cache shape):

  * one JSON file per backend (``experiments/tuning/kernels_<backend>.json``
    by default, REPRO_PQ_TUNING_CACHE overrides), written atomically via
    `repro.core.persist.atomic_write_json` — a crash mid-tune never leaves
    a torn cache;
  * the file is keyed by ``backend`` + ``jax`` version: records tuned under
    a different backend or jax version are IGNORED on load (stale timings
    must never steer dispatch), which is also the re-tune rule after a jax
    upgrade — the old file simply stops matching and the defaults apply
    until ``python -m repro.kernels.tuning`` refreshes it;
  * a missing, corrupt, or mismatched cache degrades to "no records":
    dispatch falls back to each spec's safe jnp default and NOTHING
    crashes (chaos-tested in tests/test_kernel_registry.py).

Record key: ``<kernel>|<shape sig>`` with per-arm median microseconds, so
the kernels_autotune benchmark suite can prove the dispatched arm is
within noise of the best static arm per shape.
"""

from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Dict, Mapping, Optional

import numpy as np
import jax

from repro.kernels import registry as REG

CACHE_ENV = "REPRO_PQ_TUNING_CACHE"
CACHE_SCHEMA = 1

# Significance margin: a non-default arm only becomes the recorded winner
# when it beats the spec's safe default by at least this factor of median
# runtime.  Below it, (a) run-to-run tuner variance (~15% observed on this
# backend) exceeds the win, so the "winner" flaps between runs, and (b) the
# interpret-mode Pallas arms carry a multi-second jit trace/compile tax per
# program that a marginal runtime win never amortizes in short-lived
# programs (measured: 7.8s first-call for the 512-wide topk network that
# wins by 18us/call).  Big wins (2-20x: elim_sort, windowed_merge,
# multiq_select) clear this bar easily.
MIN_SPEEDUP = 1.25

# ...and by at least this many microseconds of median: sub-150us shapes
# are eager-dispatch-overhead-dominated (~50-100us call floor), where a
# "1.3x" is a handful of microseconds of noise that flaps across tuner
# runs.  Both gates must pass for a non-default winner to be recorded.
MIN_GAIN_US = 50.0


def default_cache_path(backend: Optional[str] = None) -> Path:
    override = os.environ.get(CACHE_ENV)
    if override:
        return Path(override)
    backend = backend or jax.default_backend()
    root = Path(__file__).resolve().parents[3]
    return root / "experiments" / "tuning" / f"kernels_{backend}.json"


class TuningCache:
    """Tolerant load / atomic save of the per-shape winner table."""

    def __init__(self, path: Optional[Path] = None):
        self.path = Path(path) if path is not None else default_cache_path()
        self.records: Dict[str, Dict] = {}
        self.stale_reason: Optional[str] = None
        self._load()

    def _load(self) -> None:
        if not self.path.exists():
            self.stale_reason = "missing"
            return
        try:
            payload = json.loads(self.path.read_text())
        except (json.JSONDecodeError, OSError, UnicodeDecodeError) as e:
            self.stale_reason = f"corrupt: {type(e).__name__}"
            return
        if not isinstance(payload, dict) or "records" not in payload:
            self.stale_reason = "corrupt: not a cache payload"
            return
        if payload.get("backend") != jax.default_backend():
            self.stale_reason = (
                f"backend mismatch: tuned on {payload.get('backend')!r}"
            )
            return
        if payload.get("jax") != jax.__version__:
            self.stale_reason = (
                f"jax version mismatch: tuned under {payload.get('jax')!r}"
            )
            return
        recs = payload["records"]
        if not isinstance(recs, dict):
            self.stale_reason = "corrupt: records not a mapping"
            return
        self.records = {
            k: v for k, v in recs.items()
            if isinstance(v, dict) and isinstance(v.get("arm"), str)
        }

    @staticmethod
    def key(kernel: str, sig: str) -> str:
        return f"{kernel}|{sig}"

    def get(self, kernel: str, sig: str) -> Optional[Dict]:
        return self.records.get(self.key(kernel, sig))

    def put(self, kernel: str, sig: str, record: Dict) -> None:
        self.records[self.key(kernel, sig)] = record

    def save(self) -> Path:
        from repro.core.persist import atomic_write_json

        payload = {
            "schema": CACHE_SCHEMA,
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "records": dict(sorted(self.records.items())),
        }
        return atomic_write_json(self.path, payload, indent=1)


_CACHE: Optional[TuningCache] = None


def get_cache(reload: bool = False) -> TuningCache:
    global _CACHE
    if _CACHE is None or reload:
        _CACHE = TuningCache()
    return _CACHE


def invalidate_cache() -> None:
    """Drop the in-process cache singleton (tests; after re-tuning)."""
    global _CACHE
    _CACHE = None


def cached_winner(kernel: str, sig: str) -> Optional[str]:
    """The tuned arm for this (kernel, shape) on this backend+jax, else
    None.  Never raises — any cache trouble means 'no record'."""
    try:
        rec = get_cache().get(kernel, sig)
    except Exception:  # pragma: no cover — cache access must never crash
        return None
    return rec["arm"] if rec else None


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------


def _time_arm(fn, args, kwargs, arm: str, iters: int, warmup: int) -> float:
    out = None
    for _ in range(warmup):
        out = fn(*args, arm=arm, **kwargs)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, arm=arm, **kwargs)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def tune_kernel(name: str, coords: Mapping[str, object], *,
                iters: int = 20, warmup: int = 3,
                seed: int = 0) -> Dict:
    """Benchmark every available arm of `name` on one shape; returns
    {"arm": winner, "us": winner_us, "timings": {arm: us}}.

    The winner is the fastest arm, EXCEPT that the spec's safe default is
    kept unless the fastest beats it by `MIN_SPEEDUP` (see that constant's
    rationale: noise floor + the interpret arms' compile tax)."""
    from repro.kernels import ops as K

    spec = REG.REGISTRY[name]
    rng = np.random.default_rng(seed)
    args, kwargs = spec.make_inputs(coords, rng)
    fn = getattr(K, name)
    timings = {
        a.name: _time_arm(fn, args, kwargs, a.name, iters, warmup)
        for a in spec.available_arms()
    }
    best = min(timings, key=timings.get)
    winner = best
    if spec.default in timings and (
            timings[spec.default] < timings[best] * MIN_SPEEDUP
            or timings[spec.default] - timings[best] < MIN_GAIN_US):
        winner = spec.default
    return {"arm": winner, "us": round(timings[winner], 3),
            "best": best,
            "timings": {k: round(v, 3) for k, v in timings.items()}}


def tune_all(*, iters: int = 20, warmup: int = 3, quick: bool = False,
             save: bool = True,
             cache: Optional[TuningCache] = None) -> Dict[str, Dict]:
    """Tune every registered kernel on its declared tuning shapes and
    persist the winners.  Returns {cache key: record}."""
    cache = cache or get_cache()
    out = {}
    for spec in REG.REGISTRY.values():
        shapes = spec.tuning_shapes[:1] if quick else spec.tuning_shapes
        for coords in shapes:
            sig = REG.sig(coords)
            rec = tune_kernel(spec.name, coords, iters=iters, warmup=warmup)
            cache.put(spec.name, sig, rec)
            out[cache.key(spec.name, sig)] = rec
    if save:
        cache.save()
        invalidate_cache()  # the next resolve() sees the fresh winners
    return out


def main() -> None:  # pragma: no cover — CLI convenience
    import argparse

    ap = argparse.ArgumentParser(
        description="Re-tune the kernel dispatch cache for this backend "
                    "(run after a jax upgrade or on new hardware)."
    )
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    recs = tune_all(iters=args.iters, quick=args.quick)
    path = get_cache().path
    print(f"tuned {len(recs)} (kernel, shape) keys -> {path}")
    for key, rec in recs.items():
        print(f"  {key}: {rec['arm']} ({rec['us']}us) "
              f"{rec['timings']}")


if __name__ == "__main__":  # pragma: no cover
    main()
