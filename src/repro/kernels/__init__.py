"""Pallas TPU kernels for the PQ hot spots.

bitonic_topk  — the deleteMin tournament's candidate selection
sorted_merge  — the insert path's run-into-buffer merge

Each kernel ships with a pure-jnp oracle in ref.py and a jit'd public
wrapper in ops.py that dispatches kernel vs. reference (interpret=True on
CPU).  Networks are fully static (directions precomputed with numpy), so the
kernels lower to reshapes + selects only — no gathers, no data-dependent
control flow: MXU-free, VPU-saturating, VMEM-resident.
"""

from repro.kernels.ops import topk_smallest, merge_sorted_runs  # noqa: F401
