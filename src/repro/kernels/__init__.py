"""Pallas TPU kernels for the PQ hot spots, behind a tuned dispatch layer.

bitonic_topk   — the deleteMin tournament's candidate selection
sorted_merge   — legacy capacity-wide run-into-buffer merge (keeps C smallest)
windowed_merge — tiered insert's head-tier merge (full H+R window, no drop)
elim_match     — the elimination pre-pass (key, lane-tag) sort
twochoice      — MULTIQ probe counts + commit-side select
segmin         — SSSP relax segment-min (scatter vs sort-dedup arms)

Each kernel ships with a pure-jnp oracle in ref.py and a public wrapper in
ops.py that dispatches through `registry` (per-platform, per-shape arm
choice; `tuning` benchmarks the arms and caches the winners on disk).
Networks are fully static (directions precomputed with numpy), so the
kernels lower to reshapes + selects only — no gathers, no data-dependent
control flow: MXU-free, VPU-saturating, VMEM-resident.
"""

from repro.kernels.ops import (  # noqa: F401
    merge_sorted_runs,
    segment_min_into,
    topk_smallest,
    windowed_merge,
)
from repro.kernels.registry import (  # noqa: F401
    REGISTRY,
    force_arms,
    set_force_arm,
    supports_compiled,
)
