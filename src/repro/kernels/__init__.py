"""Pallas TPU kernels for the PQ hot spots.

bitonic_topk   — the deleteMin tournament's candidate selection
sorted_merge   — legacy capacity-wide run-into-buffer merge (keeps C smallest)
windowed_merge — tiered insert's head-tier merge (full H+R window, no drop)

Each kernel ships with a pure-jnp oracle in ref.py and a jit'd public
wrapper in ops.py that dispatches kernel vs. reference (interpret=True on
CPU).  Networks are fully static (directions precomputed with numpy), so the
kernels lower to reshapes + selects only — no gathers, no data-dependent
control flow: MXU-free, VPU-saturating, VMEM-resident.
"""

from repro.kernels.ops import (  # noqa: F401
    merge_sorted_runs,
    topk_smallest,
    windowed_merge,
)
