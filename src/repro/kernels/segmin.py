"""Segment-min arms for the SSSP relax step (and any dense scatter-min).

The wavefront relax folds E = m * deg_cap candidate (target, distance)
pairs into the dense (n,) distance array.  XLA:CPU lowers a scatter-min as
a serialized per-index loop, so the naive arm costs O(E) *sequential*
combines — the reason wavefront width m could not grow past a few hundred
(ROADMAP "SSSP at scale").

Two arms, registered as `segment_min_into` in the kernel registry:

  scatter — the direct ``dist.at[tgt].min(vals, mode="drop")``.  Fastest
            at small E (no sort overhead).
  sorted  — sort-based segment-min: lexsort the (target, value) pairs, so
            each segment's minimum is its FIRST element; non-first entries
            are retargeted to the drop sentinel.  The scatter then touches
            at most min(E, n+1) unique indices — the serialized loop
            shrinks from "every edge" to "every touched vertex", while the
            sort itself is vectorized.  Wins once E outgrows the touched
            vertex set (wide wavefronts, dense graphs).

Both arms compute exactly elementwise ``min`` over the same candidate
multiset with an associative, commutative combiner on int32, so they are
bit-identical for ANY evaluation order — the property that lets SSSP stay
bit-equal to the Bellman-Ford oracle whichever arm tuning picks.

Contract: ``tgt`` entries equal to ``dist.shape[0]`` (or beyond) are drop
sentinels; ``vals`` may carry INF_KEY for masked lanes (INF never lowers a
distance, so masked lanes are inert in both arms).
"""

from __future__ import annotations

import jax.numpy as jnp


def segment_min_scatter(dist: jnp.ndarray, tgt: jnp.ndarray,
                        vals: jnp.ndarray) -> jnp.ndarray:
    """(n,) dist, (E,) targets (n = drop sentinel), (E,) candidate values
    -> dist with each target lowered to min(dist[t], candidates at t)."""
    return dist.at[tgt].min(vals, mode="drop")


def segment_min_sorted(dist: jnp.ndarray, tgt: jnp.ndarray,
                       vals: jnp.ndarray) -> jnp.ndarray:
    """Sort-based segment-min (module docstring): dedup to one scatter
    entry per touched target before the serialized scatter."""
    n = dist.shape[0]
    order = jnp.lexsort((vals, tgt))
    st = tgt[order]
    sv = vals[order]
    # segment heads: the first (smallest-value) entry of each target run
    first = jnp.concatenate(
        [jnp.ones((1,), bool), st[1:] != st[:-1]]
    )
    st = jnp.where(first, st, n)  # non-heads fall to the drop sentinel
    return dist.at[st].min(sv, mode="drop")
