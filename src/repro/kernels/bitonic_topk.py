"""Bitonic top-k Pallas kernel — the deleteMin tournament hot spot.

Selects the k smallest (key, value) pairs of each row of an (R, N) batch,
returning them ascending.  This is the compute core of every exact deleteMin
schedule (flat / hier / ffwd all run it over gathered candidate frames) and
of MoE expert-capacity overflow resolution.

TPU adaptation of the classic GPU bitonic top-k:
  * the row block lives in VMEM (BlockSpec tiles (rows_per_block, N));
  * a running top-k accumulator merges with successive k-wide column chunks
    via a bitonic MERGE network (not a full sort): O(N log k) compare ops
    per row instead of O(N log^2 N);
  * direction-free formulation: GPU bitonic networks alternate compare
    directions (a per-element direction mask — a constant Mosaic cannot
    capture).  Instead every compare-exchange here is ascending and the
    second operand run is *data-flipped* before concatenation, which turns
    the full sort into a merge-sort of bitonic merges.  The kernel body is
    pure reshape/flip/where — VPU lanes stay full, no scalar core
    round-trips, no dynamic gathers, no captured constants.

Constraints handled by ops.py padding: N % k == 0, k a power of two.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmp_exchange_asc(keys, vals, stride: int):
    """One ascending compare-exchange stage over the last axis.
    Pairs are (i, i+stride) within blocks of 2*stride.

    Comparison is LEXICOGRAPHIC on (key, val): callers pass unique position
    tags as vals, which makes the whole network deterministic ("stable")
    despite bitonic networks being unstable — required so the tournament's
    returned instances match the instances the shards remove."""
    n = keys.shape[-1]
    nb = n // (2 * stride)
    shape = keys.shape[:-1]
    k2 = keys.reshape(shape + (nb, 2, stride))
    v2 = vals.reshape(shape + (nb, 2, stride))
    lo_k, hi_k = k2[..., 0, :], k2[..., 1, :]
    lo_v, hi_v = v2[..., 0, :], v2[..., 1, :]

    swap = (lo_k > hi_k) | ((lo_k == hi_k) & (lo_v > hi_v))
    new_lo_k = jnp.where(swap, hi_k, lo_k)
    new_hi_k = jnp.where(swap, lo_k, hi_k)
    new_lo_v = jnp.where(swap, hi_v, lo_v)
    new_hi_v = jnp.where(swap, lo_v, hi_v)

    out_k = jnp.stack([new_lo_k, new_hi_k], axis=-2).reshape(shape + (n,))
    out_v = jnp.stack([new_lo_v, new_hi_v], axis=-2).reshape(shape + (n,))
    return out_k, out_v


def clean_bitonic(keys, vals):
    """Sort a bitonic sequence (last axis, power-of-two length) ascending:
    log2(n) ascending compare-exchange stages."""
    n = keys.shape[-1]
    stride = n // 2
    while stride >= 1:
        keys, vals = _cmp_exchange_asc(keys, vals, stride)
        stride //= 2
    return keys, vals


def bitonic_sort(keys, vals):
    """Ascending sort over the last axis (power-of-two length) as a
    merge-sort of bitonic merges: at run length r, adjacent ascending runs
    (a, b) become concat(a, flip(b)) — a bitonic sequence — then a clean
    merge sorts them into one ascending 2r-run.  Direction-mask free."""
    n = keys.shape[-1]
    assert n & (n - 1) == 0, f"bitonic_sort needs power-of-two n, got {n}"
    shape = keys.shape[:-1]
    run = 1
    while run < n:
        nb = n // (2 * run)
        k2 = keys.reshape(shape + (nb, 2, run))
        v2 = vals.reshape(shape + (nb, 2, run))
        cat_k = jnp.concatenate(
            [k2[..., 0, :], jnp.flip(k2[..., 1, :], axis=-1)], axis=-1
        )
        cat_v = jnp.concatenate(
            [v2[..., 0, :], jnp.flip(v2[..., 1, :], axis=-1)], axis=-1
        )
        cat_k, cat_v = clean_bitonic(cat_k, cat_v)
        keys = cat_k.reshape(shape + (n,))
        vals = cat_v.reshape(shape + (n,))
        run *= 2
    return keys, vals


def bitonic_merge_topk(acc_k, acc_v, run_k, run_v):
    """Merge two ascending k-runs, keep the k smallest, ascending.

    concat(acc, flip(run)) is bitonic; the elementwise min of the halves is
    the smallest-k set (still bitonic); log2(k) clean stages sort it."""
    rr_k = jnp.flip(run_k, axis=-1)
    rr_v = jnp.flip(run_v, axis=-1)
    take_acc = (acc_k < rr_k) | ((acc_k == rr_k) & (acc_v <= rr_v))
    small_k = jnp.where(take_acc, acc_k, rr_k)
    small_v = jnp.where(take_acc, acc_v, rr_v)
    return clean_bitonic(small_k, small_v)


def _topk_kernel(keys_ref, vals_ref, out_k_ref, out_v_ref, *, k: int):
    """Row-block kernel: (rows, N) VMEM tile -> (rows, k) smallest."""
    keys = keys_ref[...]
    vals = vals_ref[...]
    _, n = keys.shape
    n_chunks = n // k

    acc_k, acc_v = bitonic_sort(keys[:, :k], vals[:, :k])
    for c in range(1, n_chunks):
        chunk_k, chunk_v = bitonic_sort(
            keys[:, c * k : (c + 1) * k], vals[:, c * k : (c + 1) * k]
        )
        acc_k, acc_v = bitonic_merge_topk(acc_k, acc_v, chunk_k, chunk_v)
    out_k_ref[...] = acc_k
    out_v_ref[...] = acc_v


@functools.partial(jax.jit, static_argnames=("k", "rows_per_block", "interpret"))
def topk_smallest_pallas(
    keys: jnp.ndarray,  # (R, N)
    vals: jnp.ndarray,  # (R, N)
    k: int,
    rows_per_block: int = 8,
    interpret: bool = True,
):
    """pallas_call wrapper.  N % k == 0, k power of two, R % rows_per_block == 0."""
    R, N = keys.shape
    assert N % k == 0 and k & (k - 1) == 0, (N, k)
    assert R % rows_per_block == 0, (R, rows_per_block)
    grid = (R // rows_per_block,)

    return pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_block, N), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, N), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_block, k), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_block, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, k), keys.dtype),
            jax.ShapeDtypeStruct((R, k), vals.dtype),
        ],
        interpret=interpret,
    )(keys, vals)
