"""Elimination-match Pallas kernel — the fused-window pre-pass hot spot.

The elimination/combining pre-pass (Calciu et al.'s adaptive PQ technique,
bulk-synchronous form) matches a step's pending inserts against its
deleteMins: once the insert log is sorted ascending, the matched set is just
the prefix below the queue-min cutoff, so the whole match reduces to ONE
row-wise sort of the (masked) insert keys with their lane tags.

This kernel is that sort: a full bitonic sort of (key, tag) rows, reusing
the direction-free merge network of `bitonic_topk` (every compare-exchange
ascending, second run data-flipped — see that module's header for why Mosaic
wants it this way).  Comparison is lexicographic on (key, tag) with unique
lane tags, which makes the network bit-identical to a stable argsort — the
property the exact schedules need so the eliminated prefix matches the
oracle's (key, batch-position) linearization.

The window engine sorts the whole (K, B) operation log of a K-step window in
one call (rows = steps) in front of the `lax.scan`; the sort is
state-independent, so only the cheap cutoff compare stays inside the scan
body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic_topk import bitonic_sort


def _elim_sort_kernel(keys_ref, tags_ref, out_k_ref, out_t_ref):
    """Row-block kernel: full ascending sort of (rows, N) (key, tag) pairs."""
    out_k, out_t = bitonic_sort(keys_ref[...], tags_ref[...])
    out_k_ref[...] = out_k
    out_t_ref[...] = out_t


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def elim_sort_pallas(
    keys: jnp.ndarray,  # (R, N) int32, N power of two
    tags: jnp.ndarray,  # (R, N) int32 unique lane tags
    rows_per_block: int = 8,
    interpret: bool = True,
):
    """pallas_call wrapper.  N must be a power of two (ops.py pads with
    (INF, INT32_MAX) sentinels); R % rows_per_block handled by the caller."""
    R, N = keys.shape
    assert N & (N - 1) == 0, f"elim sort needs power-of-two width, got {N}"
    assert R % rows_per_block == 0, (R, rows_per_block)
    grid = (R // rows_per_block,)

    spec = pl.BlockSpec((rows_per_block, N), lambda i: (i, 0))
    return pl.pallas_call(
        _elim_sort_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((R, N), keys.dtype),
            jax.ShapeDtypeStruct((R, N), tags.dtype),
        ],
        interpret=interpret,
    )(keys, tags)
