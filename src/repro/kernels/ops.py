"""Public kernel wrappers — registry-dispatched, arm-parameterized.

Every wrapper resolves its implementation arm through
`repro.kernels.registry.resolve` (explicit ``arm=`` > force override >
tuning-cache winner > safe jnp default; see that module's docstring) and
then runs a jitted implementation keyed on the resolved arm, so forcing or
re-tuning an arm never collides with a stale jit cache.  Padding (arbitrary
N/R/k up to power-of-two network sizes) and dtype plumbing happen here;
platform policy (which arms exist where) lives entirely in the registry —
there is deliberately not a single backend check in this file.

Arm-equality contract: the jnp reference arms order lexicographically on
(key, val); the position-stable arms (``argsort``, ``rank``) and the Pallas
networks match them bit-for-bit whenever vals are position-monotone tags —
which every call site passes (the tag trick: sort (key, tag), gather
payloads by tag afterwards).  tests/test_kernel_registry.py sweeps every
arm of every kernel against the reference on the registry's validation
shapes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pqueue.state import INF_KEY
from repro.kernels import ref as R
from repro.kernels import registry as REG
from repro.kernels.bitonic_topk import topk_smallest_pallas
from repro.kernels.elim_match import elim_sort_pallas
from repro.kernels.segmin import segment_min_scatter, segment_min_sorted
from repro.kernels.sorted_merge import merge_sorted_pallas
from repro.kernels.twochoice import multiq_select_pallas, twochoice_pick_pallas
from repro.kernels.windowed_merge import windowed_merge_pallas

_INT32_MAX = jnp.iinfo(jnp.int32).max


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


def _rows_per_block(kw: dict, rows: int) -> int:
    """Clamp an arm's rows_per_block axis down to a divisor of `rows`."""
    rpb = kw.pop("rows_per_block", 8)
    while rows % rpb:
        rpb //= 2
    return max(rpb, 1)


# ---------------------------------------------------------------------------
# bitonic top-k — the deleteMin tournament
# ---------------------------------------------------------------------------


def topk_smallest(
    keys: jnp.ndarray,  # (R, N) any int dtype
    vals: jnp.ndarray,  # (R, N) position-monotone tags (or payloads)
    k: int,
    arm: Optional[str] = None,
):
    """k smallest per row, ascending.  Pallas arms pad N up to a multiple
    of the power-of-two k' >= k with INF sentinels, then slice back."""
    coords = {"R": keys.shape[0], "N": keys.shape[1], "k": k,
              "dtype": str(keys.dtype)}
    return _topk_dispatch(keys, vals, k, REG.resolve("topk_smallest",
                                                     coords, arm))


@functools.partial(jax.jit, static_argnames=("k", "arm"))
def _topk_dispatch(keys, vals, k, arm):
    if arm == "ref":
        return R.topk_smallest_ref(keys, vals, k)
    if arm == "argsort":
        order = jnp.argsort(keys, axis=-1, stable=True)[..., :k]
        return (jnp.take_along_axis(keys, order, axis=-1),
                jnp.take_along_axis(vals, order, axis=-1))
    kw = REG.arm_kwargs("topk_smallest", arm)
    Rr, N = keys.shape
    kp = _next_pow2(k)
    Np = max(_next_pow2(N), kp)
    if Np % kp:
        Np = (Np // kp + 1) * kp
    pad_n = Np - N
    if pad_n:
        keys = jnp.pad(keys, ((0, 0), (0, pad_n)), constant_values=INF_KEY)
        vals = jnp.pad(vals, ((0, 0), (0, pad_n)))
    out_k, out_v = topk_smallest_pallas(
        keys, vals, kp, rows_per_block=_rows_per_block(kw, Rr), **kw
    )
    return out_k[:, :k], out_v[:, :k]


# ---------------------------------------------------------------------------
# elimination-match sort — the fused-window pre-pass
# ---------------------------------------------------------------------------


def elim_sort(
    keys: jnp.ndarray,  # (R, B) int32 masked insert keys (INF for non-inserts)
    tags: jnp.ndarray,  # (R, B) int32 unique lane tags (position-monotone)
    arm: Optional[str] = None,
):
    """Row-wise full ascending sort of (key, tag) pairs — the elimination
    match pre-pass.  Pallas arms pad B up to a power of two with
    (INF, INT32_MAX) sentinels (real INF-keyed lanes carry tags < B, so
    they sort before the pads and survive the slice-back)."""
    coords = {"R": keys.shape[0], "B": keys.shape[1]}
    return _elim_dispatch(keys, tags, REG.resolve("elim_sort", coords, arm))


@functools.partial(jax.jit, static_argnames=("arm",))
def _elim_dispatch(keys, tags, arm):
    if arm == "ref":
        return R.elim_sort_ref(keys, tags)
    if arm == "argsort":
        order = jnp.argsort(keys, axis=1, stable=True).astype(jnp.int32)
        return (jnp.take_along_axis(keys, order, axis=1),
                jnp.take_along_axis(tags, order, axis=1))
    kw = REG.arm_kwargs("elim_sort", arm)
    Rr, B = keys.shape
    Bp = _next_pow2(B)
    if Bp != B:
        keys = jnp.pad(keys, ((0, 0), (0, Bp - B)), constant_values=INF_KEY)
        tags = jnp.pad(tags, ((0, 0), (0, Bp - B)),
                       constant_values=_INT32_MAX)
    out_k, out_t = elim_sort_pallas(
        keys, tags, rows_per_block=_rows_per_block(kw, Rr), **kw
    )
    return out_k[:, :B], out_t[:, :B]


# ---------------------------------------------------------------------------
# MULTIQ two-choice probe + commit-side tournament
# ---------------------------------------------------------------------------


def twochoice_counts(
    mins: jnp.ndarray,  # (S,) int32 cached per-shard minima
    choice_a: jnp.ndarray,  # (m,) int32
    choice_b: jnp.ndarray,  # (m,) int32
    act: jnp.ndarray,  # (m,) bool/int32 active-lane mask
    arm: Optional[str] = None,
) -> jnp.ndarray:
    """Per-shard commit counts of the MULTIQ two-choice probe.  (S,) int32."""
    coords = {"S": mins.shape[0], "m": choice_a.shape[0]}
    return _twochoice_dispatch(
        mins, choice_a, choice_b, act.astype(jnp.int32),
        REG.resolve("twochoice_counts", coords, arm),
    )


@functools.partial(jax.jit, static_argnames=("arm",))
def _twochoice_dispatch(mins, choice_a, choice_b, act, arm):
    if arm == "ref":
        return R.twochoice_counts_ref(mins, choice_a, choice_b, act)
    kw = REG.arm_kwargs("twochoice_counts", arm)
    return twochoice_pick_pallas(mins, choice_a, choice_b, act, **kw)


def multiq_select_topm(
    win_k: jnp.ndarray,  # (S, m) ascending head windows
    win_v: jnp.ndarray,  # (S, m) payloads
    take: jnp.ndarray,  # (S,) commit counts
    arm: Optional[str] = None,
):
    """m smallest masked (key, val) pairs ascending, INF-key padded.

    Tag trick as in `topk_smallest`: the merge network runs on (key,
    position-tag) pairs, payloads gathered by tag afterwards — bit-identical
    to the stable-argsort reference."""
    coords = {"S": win_k.shape[0], "m": win_k.shape[1]}
    return _multiq_dispatch(win_k, win_v, take,
                            REG.resolve("multiq_select_topm", coords, arm))


@functools.partial(jax.jit, static_argnames=("arm",))
def _multiq_dispatch(win_k, win_v, take, arm):
    S, m = win_k.shape
    tags = jnp.arange(S * m, dtype=jnp.int32).reshape(S, m)
    if arm == "ref":
        out_k, out_t = R.multiq_select_ref(win_k, tags, take)
    else:
        kw = REG.arm_kwargs("multiq_select_topm", arm)
        mp = _next_pow2(m)
        pk, pt = win_k, tags
        if mp != m:
            pk = jnp.pad(pk, ((0, 0), (0, mp - m)), constant_values=INF_KEY)
            pt = jnp.pad(pt, ((0, 0), (0, mp - m)),
                         constant_values=_INT32_MAX)
        out_k, out_t = multiq_select_pallas(pk, pt, take, **kw)
        out_k, out_t = out_k[0, :m], out_t[0, :m]
    safe_t = jnp.clip(out_t, 0, S * m - 1)
    out_v = jnp.where(out_k < INF_KEY, win_v.ravel()[safe_t], 0)
    out_k = jnp.where(out_k < INF_KEY, out_k, INF_KEY)
    return out_k, out_v


# ---------------------------------------------------------------------------
# windowed head merge — the tiered insert hot spot
# ---------------------------------------------------------------------------


def windowed_merge(
    head_k: jnp.ndarray,  # (S, H) ascending INF-padded hot tier
    head_v: jnp.ndarray,
    head_q: jnp.ndarray,  # (S, H) per-shard insertion seqs
    run_k: jnp.ndarray,  # (S, R) ascending INF-padded incoming run
    run_v: jnp.ndarray,
    run_q: jnp.ndarray,
    arm: Optional[str] = None,
):
    """Full (S, H+R) merge of head tier and incoming run, ascending —
    nothing dropped (the caller splits the result into new head [:H] and
    tail-bound spill [H:]).

    Arms: ``rank`` is the scatter-free searchsorted rank merge (the
    XLA:CPU production path, `local.rank_merge_head_run`); ``ref`` the
    lexicographic oracle; the Pallas arms run the bitonic network on
    (key, position-tag) pairs and gather val AND seq by tag — all
    bit-identical (positional-stable: head before run)."""
    coords = {"S": head_k.shape[0], "H": head_k.shape[1],
              "R": run_k.shape[1]}
    arm = REG.resolve("windowed_merge", coords, arm)
    if arm == "rank":
        from repro.core.pqueue.local import rank_merge_head_run

        return rank_merge_head_run(head_k, head_v, head_q,
                                   run_k, run_v, run_q)
    return _wmerge_dispatch(head_k, head_v, head_q, run_k, run_v, run_q, arm)


@functools.partial(jax.jit, static_argnames=("arm",))
def _wmerge_dispatch(head_k, head_v, head_q, run_k, run_v, run_q, arm):
    S, H = head_k.shape
    Rw = run_k.shape[1]
    W = H + Rw
    head_t = jnp.broadcast_to(jnp.arange(H, dtype=jnp.int32)[None, :], (S, H))
    run_t = jnp.broadcast_to(
        H + jnp.arange(Rw, dtype=jnp.int32)[None, :], (S, Rw)
    )
    if arm == "ref":
        out_k, out_t = R.windowed_merge_ref(head_k, head_t, run_k, run_t)
    else:
        kw = REG.arm_kwargs("windowed_merge", arm)
        Wp = _next_pow2(W)
        pad = Wp - W
        rk = run_k
        rt = H + jnp.arange(Rw + pad, dtype=jnp.int32)[None, :]
        rt = jnp.broadcast_to(rt, (S, Rw + pad))
        if pad:
            rk = jnp.pad(rk, ((0, 0), (0, pad)), constant_values=INF_KEY)
        kw["rows_per_block"] = _rows_per_block(
            {"rows_per_block": kw.get("rows_per_block", 4)}, S
        )
        out_k, out_t = windowed_merge_pallas(head_k, head_t, rk, rt, **kw)
        out_k, out_t = out_k[:, :W], out_t[:, :W]

    src_v = jnp.concatenate([head_v, run_v], axis=1)
    src_q = jnp.concatenate([head_q, run_q], axis=1)
    idx = jnp.clip(out_t, 0, W - 1)
    valid = out_k < INF_KEY
    out_v = jnp.where(valid, jnp.take_along_axis(src_v, idx, axis=1), 0)
    out_q = jnp.where(valid, jnp.take_along_axis(src_q, idx, axis=1), 0)
    return out_k, out_v, out_q


# ---------------------------------------------------------------------------
# legacy capacity-wide merge
# ---------------------------------------------------------------------------


def merge_sorted_runs(
    buf_k: jnp.ndarray,  # (S, C) ascending INF-padded — C power of two
    buf_v: jnp.ndarray,
    run_k: jnp.ndarray,  # (S, R) ascending INF-padded, R <= C
    run_v: jnp.ndarray,
    arm: Optional[str] = None,
):
    """Smallest C of (buffer ∪ run), ascending per row."""
    coords = {"S": buf_k.shape[0], "C": buf_k.shape[1],
              "R": run_k.shape[1]}
    return _msr_dispatch(buf_k, buf_v, run_k, run_v,
                         REG.resolve("merge_sorted_runs", coords, arm))


@functools.partial(jax.jit, static_argnames=("arm",))
def _msr_dispatch(buf_k, buf_v, run_k, run_v, arm):
    if arm == "ref":
        return R.merge_sorted_runs_ref(buf_k, buf_v, run_k, run_v)
    kw = REG.arm_kwargs("merge_sorted_runs", arm)
    S, C = buf_k.shape
    Rw = run_k.shape[1]
    assert Rw <= C, (Rw, C)
    if Rw < C:
        # (INF, INT32_MAX) pads are lexicographically largest, which keeps
        # the flipped run lex-descending — the merge network then matches
        # the (key, val)-lex reference bit-for-bit even on INF sentinels
        run_k = jnp.pad(run_k, ((0, 0), (0, C - Rw)), constant_values=INF_KEY)
        run_v = jnp.pad(run_v, ((0, 0), (0, C - Rw)),
                        constant_values=_INT32_MAX)
    kw["rows_per_block"] = _rows_per_block(
        {"rows_per_block": kw.get("rows_per_block", 4)}, S
    )
    return merge_sorted_pallas(buf_k, buf_v, run_k, run_v, **kw)


# ---------------------------------------------------------------------------
# segment-min — the SSSP relax scatter
# ---------------------------------------------------------------------------


def segment_min_into(
    dist: jnp.ndarray,  # (n,) dense int32 distances
    tgt: jnp.ndarray,  # (E,) targets; entries >= n drop
    vals: jnp.ndarray,  # (E,) candidate values (INF_KEY = inert lane)
    arm: Optional[str] = None,
) -> jnp.ndarray:
    """Fold E candidate (target, value) pairs into `dist` elementwise-min.
    Arms (`kernels.segmin`): direct scatter vs sort-dedup-scatter — an
    associative/commutative int32 min, so bit-identical either way."""
    coords = {"E": tgt.shape[0], "n": dist.shape[0]}
    return _segmin_dispatch(dist, tgt, vals,
                            REG.resolve("segment_min_into", coords, arm))


@functools.partial(jax.jit, static_argnames=("arm",))
def _segmin_dispatch(dist, tgt, vals, arm):
    if arm == "sorted":
        return segment_min_sorted(dist, tgt, vals)
    return segment_min_scatter(dist, tgt, vals)
