"""jit'd public wrappers for the Pallas kernels.

Handle padding (arbitrary N/R/k up to power-of-two network sizes), dtype
plumbing, and backend dispatch: `interpret=True` on CPU (kernel body runs in
Python — the validation mode for this container), compiled Mosaic on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.pqueue.state import INF_KEY
from repro.kernels import ref as R
from repro.kernels.bitonic_topk import topk_smallest_pallas
from repro.kernels.elim_match import elim_sort_pallas
from repro.kernels.sorted_merge import merge_sorted_pallas
from repro.kernels.twochoice import multiq_select_pallas, twochoice_pick_pallas
from repro.kernels.windowed_merge import windowed_merge_pallas


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("k", "use_kernel"))
def topk_smallest(
    keys: jnp.ndarray,  # (R, N) any int dtype
    vals: jnp.ndarray,
    k: int,
    use_kernel: bool = True,
):
    """k smallest per row, ascending.  Pads N up to a multiple of the
    power-of-two k' >= k with INF sentinels, then slices back."""
    if not use_kernel:
        return R.topk_smallest_ref(keys, vals, k)

    Rr, N = keys.shape
    kp = _next_pow2(k)
    Np = max(_next_pow2(N), kp)
    if Np % kp:
        Np = (Np // kp + 1) * kp
    pad_n = Np - N
    if pad_n:
        keys = jnp.pad(keys, ((0, 0), (0, pad_n)), constant_values=INF_KEY)
        vals = jnp.pad(vals, ((0, 0), (0, pad_n)))
    rows_per_block = 8
    while Rr % rows_per_block:
        rows_per_block //= 2
    out_k, out_v = topk_smallest_pallas(
        keys, vals, kp, rows_per_block=max(rows_per_block, 1),
        interpret=not _on_tpu(),
    )
    return out_k[:, :k], out_v[:, :k]


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def elim_sort(
    keys: jnp.ndarray,  # (R, B) int32 masked insert keys (INF for non-inserts)
    tags: jnp.ndarray,  # (R, B) int32 unique lane tags
    use_kernel: bool = True,
):
    """Row-wise full ascending sort of (key, tag) pairs — the elimination
    match pre-pass.  Pads B up to a power of two with (INF, INT32_MAX)
    sentinels (real INF-keyed lanes carry tags < B, so they sort before the
    pads and survive the slice-back)."""
    if not use_kernel:
        return R.elim_sort_ref(keys, tags)

    Rr, B = keys.shape
    Bp = _next_pow2(B)
    if Bp != B:
        keys = jnp.pad(keys, ((0, 0), (0, Bp - B)), constant_values=INF_KEY)
        tags = jnp.pad(
            tags, ((0, 0), (0, Bp - B)),
            constant_values=jnp.iinfo(jnp.int32).max,
        )
    rows_per_block = 8
    while Rr % rows_per_block:
        rows_per_block //= 2
    out_k, out_t = elim_sort_pallas(
        keys, tags, rows_per_block=max(rows_per_block, 1),
        interpret=not _on_tpu(),
    )
    return out_k[:, :B], out_t[:, :B]


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def twochoice_counts(
    mins: jnp.ndarray,  # (S,) int32 cached per-shard minima
    choice_a: jnp.ndarray,  # (m,) int32
    choice_b: jnp.ndarray,  # (m,) int32
    act: jnp.ndarray,  # (m,) bool/int32 active-lane mask
    use_kernel: bool = True,
) -> jnp.ndarray:
    """Per-shard commit counts of the MULTIQ two-choice probe.  (S,) int32."""
    act = act.astype(jnp.int32)
    if not use_kernel:
        return R.twochoice_counts_ref(mins, choice_a, choice_b, act)
    return twochoice_pick_pallas(
        mins, choice_a, choice_b, act, interpret=not _on_tpu()
    )


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def multiq_select_topm(
    win_k: jnp.ndarray,  # (S, m) ascending head windows
    win_v: jnp.ndarray,  # (S, m) payloads
    take: jnp.ndarray,  # (S,) commit counts
    use_kernel: bool = True,
):
    """m smallest masked (key, val) pairs ascending, INF-key padded.

    Tag trick as in `topk_smallest`: the merge network runs on (key,
    position-tag) pairs, payloads gathered by tag afterwards — bit-identical
    to the stable-argsort reference."""
    S, m = win_k.shape
    tags = jnp.arange(S * m, dtype=jnp.int32).reshape(S, m)
    if not use_kernel:
        out_k, out_t = R.multiq_select_ref(win_k, tags, take)
    else:
        mp = _next_pow2(m)
        if mp != m:
            win_k = jnp.pad(win_k, ((0, 0), (0, mp - m)), constant_values=INF_KEY)
            tags = jnp.pad(
                tags, ((0, 0), (0, mp - m)), constant_values=jnp.iinfo(jnp.int32).max
            )
        out_k, out_t = multiq_select_pallas(
            win_k, tags, take, interpret=not _on_tpu()
        )
        out_k, out_t = out_k[0, :m], out_t[0, :m]
    safe_t = jnp.clip(out_t, 0, S * m - 1)
    out_v = jnp.where(out_k < INF_KEY, win_v.ravel()[safe_t], 0)
    out_k = jnp.where(out_k < INF_KEY, out_k, INF_KEY)
    return out_k, out_v


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def windowed_merge(
    head_k: jnp.ndarray,  # (S, H) ascending INF-padded hot tier
    head_v: jnp.ndarray,
    head_q: jnp.ndarray,  # (S, H) per-shard insertion seqs
    run_k: jnp.ndarray,  # (S, R) ascending INF-padded incoming run
    run_v: jnp.ndarray,
    run_q: jnp.ndarray,
    use_kernel: bool = True,
):
    """Full (S, H+R) merge of head tier and incoming run, ascending —
    nothing dropped (the caller splits the result into new head [:H] and
    tail-bound spill [H:]).

    Tag trick as in `topk_smallest`: the network merges (key, position-tag)
    pairs (head tags 0..H-1, run tags H..H+R-1), payloads (val AND seq) are
    gathered by tag afterwards — bit-identical to the positional-stable
    rank merge in `local.merge_head_run`."""
    S, H = head_k.shape
    Rw = run_k.shape[1]
    W = H + Rw
    head_t = jnp.broadcast_to(jnp.arange(H, dtype=jnp.int32)[None, :], (S, H))
    run_t = jnp.broadcast_to(
        H + jnp.arange(Rw, dtype=jnp.int32)[None, :], (S, Rw)
    )
    if not use_kernel:
        out_k, out_t = R.windowed_merge_ref(head_k, head_t, run_k, run_t)
    else:
        Wp = _next_pow2(W)
        pad = Wp - W
        rk = run_k
        rt = H + jnp.arange(Rw + pad, dtype=jnp.int32)[None, :]
        rt = jnp.broadcast_to(rt, (S, Rw + pad))
        if pad:
            rk = jnp.pad(rk, ((0, 0), (0, pad)), constant_values=INF_KEY)
        out_k, out_t = windowed_merge_pallas(
            head_k, head_t, rk, rt, interpret=not _on_tpu()
        )
        out_k, out_t = out_k[:, :W], out_t[:, :W]

    src_v = jnp.concatenate([head_v, run_v], axis=1)
    src_q = jnp.concatenate([head_q, run_q], axis=1)
    idx = jnp.clip(out_t, 0, W - 1)
    valid = out_k < INF_KEY
    out_v = jnp.where(valid, jnp.take_along_axis(src_v, idx, axis=1), 0)
    out_q = jnp.where(valid, jnp.take_along_axis(src_q, idx, axis=1), 0)
    return out_k, out_v, out_q


@functools.partial(jax.jit, static_argnames=("use_kernel",))
def merge_sorted_runs(
    buf_k: jnp.ndarray,  # (S, C) ascending INF-padded — C power of two
    buf_v: jnp.ndarray,
    run_k: jnp.ndarray,  # (S, R) ascending INF-padded, R <= C
    run_v: jnp.ndarray,
    use_kernel: bool = True,
):
    """Smallest C of (buffer ∪ run), ascending per row."""
    if not use_kernel:
        return R.merge_sorted_runs_ref(buf_k, buf_v, run_k, run_v)

    S, C = buf_k.shape
    Rw = run_k.shape[1]
    assert Rw <= C, (Rw, C)
    if Rw < C:
        run_k = jnp.pad(run_k, ((0, 0), (0, C - Rw)), constant_values=INF_KEY)
        run_v = jnp.pad(run_v, ((0, 0), (0, C - Rw)))
    return merge_sorted_pallas(
        buf_k, buf_v, run_k, run_v, interpret=not _on_tpu()
    )
