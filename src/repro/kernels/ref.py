"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

Contract shared with the kernels: ordering is LEXICOGRAPHIC on (key, val).
Callers that need payloads pass a unique position tag as val and gather the
payload by tag afterwards — this is what makes the unstable bitonic networks
deterministic and lets tests demand exact equality.
"""

from __future__ import annotations

import jax.numpy as jnp


def _lex_order(keys: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    return jnp.lexsort((vals, keys), axis=-1)


def topk_smallest_ref(keys: jnp.ndarray, vals: jnp.ndarray, k: int):
    """(R, N) -> k lexicographically-smallest (key, val) per row, ascending."""
    order = _lex_order(keys, vals)[..., :k]
    return (
        jnp.take_along_axis(keys, order, axis=-1),
        jnp.take_along_axis(vals, order, axis=-1),
    )


def merge_sorted_runs_ref(buf_k, buf_v, run_k, run_v):
    """(S, C) buffer + (S, R) run (both ascending, INF-padded) -> smallest C
    of the union, ascending (lexicographic on (key, val))."""
    C = buf_k.shape[-1]
    cat_k = jnp.concatenate([buf_k, run_k], axis=-1)
    cat_v = jnp.concatenate([buf_v, run_v], axis=-1)
    order = _lex_order(cat_k, cat_v)[..., :C]
    return (
        jnp.take_along_axis(cat_k, order, axis=-1),
        jnp.take_along_axis(cat_v, order, axis=-1),
    )
