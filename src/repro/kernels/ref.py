"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

Contract shared with the kernels: ordering is LEXICOGRAPHIC on (key, val).
Callers that need payloads pass a unique position tag as val and gather the
payload by tag afterwards — this is what makes the unstable bitonic networks
deterministic and lets tests demand exact equality.
"""

from __future__ import annotations

import jax.numpy as jnp


def _lex_order(keys: jnp.ndarray, vals: jnp.ndarray) -> jnp.ndarray:
    return jnp.lexsort((vals, keys), axis=-1)


def elim_sort_ref(keys: jnp.ndarray, tags: jnp.ndarray):
    """(R, N) -> full row-wise ascending sort of (key, tag) pairs.  Tags are
    unique lane positions, so the lexicographic order equals a stable sort
    by key — the elimination pre-pass contract."""
    order = _lex_order(keys, tags)
    return (
        jnp.take_along_axis(keys, order, axis=-1),
        jnp.take_along_axis(tags, order, axis=-1),
    )


def topk_smallest_ref(keys: jnp.ndarray, vals: jnp.ndarray, k: int):
    """(R, N) -> k lexicographically-smallest (key, val) per row, ascending."""
    order = _lex_order(keys, vals)[..., :k]
    return (
        jnp.take_along_axis(keys, order, axis=-1),
        jnp.take_along_axis(vals, order, axis=-1),
    )


def twochoice_counts_ref(mins, choice_a, choice_b, act):
    """Two-choice probe/commit reference: per-shard commit counts (S,).

    Lane l commits to choice_a[l] iff its cached min is strictly smaller, or
    equal with choice_a[l] <= choice_b[l] (deterministic tie toward the lower
    shard id).  Inactive lanes are parked out of range."""
    S = mins.shape[0]
    min_a = mins[choice_a]
    min_b = mins[choice_b]
    pick_a = (min_a < min_b) | ((min_a == min_b) & (choice_a <= choice_b))
    chosen = jnp.where(pick_a, choice_a, choice_b)
    chosen = jnp.where(act != 0, chosen, S)
    return jnp.zeros((S,), jnp.int32).at[chosen].add(1, mode="drop")


def multiq_select_ref(win_k, win_v, take):
    """(S, m) head windows + (S,) takes -> m smallest masked (key, val)
    pairs, ascending (lexicographic on (key, val))."""
    S, m = win_k.shape
    col = jnp.arange(m, dtype=jnp.int32)[None, :]
    mask = col < take[:, None]
    INT32_MAX = jnp.iinfo(jnp.int32).max
    mk = jnp.where(mask, win_k, INT32_MAX).ravel()
    mv = jnp.where(mask, win_v, INT32_MAX).ravel()
    order = _lex_order(mk, mv)[:m]
    return mk[order], mv[order]


def merge_sorted_runs_ref(buf_k, buf_v, run_k, run_v):
    """(S, C) buffer + (S, R) run (both ascending, INF-padded) -> smallest C
    of the union, ascending (lexicographic on (key, val))."""
    C = buf_k.shape[-1]
    cat_k = jnp.concatenate([buf_k, run_k], axis=-1)
    cat_v = jnp.concatenate([buf_v, run_v], axis=-1)
    order = _lex_order(cat_k, cat_v)[..., :C]
    return (
        jnp.take_along_axis(cat_k, order, axis=-1),
        jnp.take_along_axis(cat_v, order, axis=-1),
    )


def windowed_merge_ref(head_k, head_t, run_k, run_t):
    """(S, H) head + (S, R) run (both ascending, INF-padded) -> the FULL
    (S, H+R) merged window, ascending (lexicographic on (key, tag) — tags
    are positions, head before run, so this equals the positional-stable
    rank merge)."""
    cat_k = jnp.concatenate([head_k, run_k], axis=-1)
    cat_t = jnp.concatenate([head_t, run_t], axis=-1)
    order = _lex_order(cat_k, cat_t)
    return (
        jnp.take_along_axis(cat_k, order, axis=-1),
        jnp.take_along_axis(cat_t, order, axis=-1),
    )
