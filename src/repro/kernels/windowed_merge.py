"""Windowed head-merge Pallas kernel — the tiered insert path's hot spot.

Merges an ascending incoming run (R wide, INF-padded) into each shard's
ascending head tier (H wide) producing the FULL (S, H+R) merged window —
unlike `sorted_merge.py` (which keeps the capacity-C smallest and drops the
rest), nothing is dropped here: the caller takes the first H columns as the
new hot tier and appends the suffix (the spill — necessarily the largest
elements) to the cold tail arena.  H and R are static and batch-sized, so
the network cost is O((H+R) log(H+R)) per shard row, independent of the
queue capacity.

Same TPU adaptation as `sorted_merge.py`:

    concat(head_asc, reverse(run_asc))  is bitonic (H+R wide)
    -> log2(H+R) static clean stages sort it ascending
    -> ALL H+R lanes are the merge result.

Comparison is lexicographic on (key, position-tag) — see kernels/ref.py —
which makes the network's tie resolution identical to the positional-stable
rank merge in `local.merge_head_run` (head before run, in-position within
each), so the two paths are bit-identical (tested).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bitonic_topk import clean_bitonic


def _wmerge_kernel(head_k_ref, head_t_ref, run_k_ref, run_t_ref,
                   out_k_ref, out_t_ref):
    """Row-block kernel: head (rows, H) + run (rows, R) -> merged
    (rows, H+R) ascending (full merge, nothing dropped)."""
    head_k = head_k_ref[...]
    head_t = head_t_ref[...]
    run_k = run_k_ref[...]
    run_t = run_t_ref[...]

    cat_k = jnp.concatenate([head_k, jnp.flip(run_k, axis=-1)], axis=-1)
    cat_t = jnp.concatenate([head_t, jnp.flip(run_t, axis=-1)], axis=-1)
    merged_k, merged_t = clean_bitonic(cat_k, cat_t)
    out_k_ref[...] = merged_k
    out_t_ref[...] = merged_t


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def windowed_merge_pallas(
    head_k: jnp.ndarray,  # (S, H) ascending, INF-padded
    head_t: jnp.ndarray,  # (S, H) position tags
    run_k: jnp.ndarray,  # (S, R) ascending, INF-padded
    run_t: jnp.ndarray,  # (S, R) position tags
    rows_per_block: int = 4,
    interpret: bool = True,
):
    """pallas_call wrapper.  H+R must be a power of two (ops.py pads the run
    up); returns the full (S, H+R) merged (key, tag) window."""
    S, H = head_k.shape
    R = run_k.shape[1]
    W = H + R
    assert W & (W - 1) == 0, f"window H+R must be a power of two, got {W}"
    while S % rows_per_block:
        rows_per_block //= 2
    rows_per_block = max(rows_per_block, 1)
    grid = (S // rows_per_block,)

    spec_h = pl.BlockSpec((rows_per_block, H), lambda i: (i, 0))
    spec_r = pl.BlockSpec((rows_per_block, R), lambda i: (i, 0))
    spec_o = pl.BlockSpec((rows_per_block, W), lambda i: (i, 0))
    return pl.pallas_call(
        _wmerge_kernel,
        grid=grid,
        in_specs=[spec_h, spec_h, spec_r, spec_r],
        out_specs=[spec_o, spec_o],
        out_shape=[
            jax.ShapeDtypeStruct((S, W), head_k.dtype),
            jax.ShapeDtypeStruct((S, W), head_t.dtype),
        ],
        interpret=interpret,
    )(head_k, head_t, run_k, run_t)
