"""Window-timeline tracer: structured spans/instants, Chrome-trace export.

Records the serving stack's control flow as trace events — window
dispatches, per-tick admission, mode transitions (with the classifier's
feature vector), elimination hits, overload state changes,
checkpoint/rollback/recovery, WAL fsyncs, snapshot writes, kernel-arm
resolutions — and exports them as Chrome trace-event JSON, loadable in
Perfetto / chrome://tracing, so a full serving run renders as a timeline.

Two span flavors:

  span(name)            context manager measuring real wall time — the
                        window dispatch envelope.
  span_at(name, ts, dur)  synthesized interval — the scheduler subdivides
                        one fused K-tick device call into K logical tick
                        spans (the device executes all K ticks in one
                        dispatch; per-tick host timestamps do not exist,
                        but per-tick ARGS — mode, dispatches, eliminations
                        — do, and the timeline stays navigable).

Rollback hygiene: guarded windows `mark()` before executing and
`truncate(mark)` on rollback, so a rolled-back window's events vanish
from the timeline exactly like its state changes vanish from the queue —
the trace shows a `rollback` instant instead of phantom work.

The buffer is bounded (`max_events`); overflow drops newest events with
an explicit `dropped` count (never silently).  A disabled tracer costs
one attribute load + branch per call site.
"""

from __future__ import annotations

import contextlib
import time
from pathlib import Path
from typing import Dict, List, Optional

DEFAULT_MAX_EVENTS = 500_000


class Tracer:
    """Append-only trace-event buffer with Chrome JSON export."""

    def __init__(self, enabled: bool = False,
                 max_events: int = DEFAULT_MAX_EVENTS):
        self.enabled = enabled
        self.max_events = max_events
        self.events: List[Dict[str, object]] = []
        self.dropped = 0
        self._t0 = time.perf_counter()

    # -- clock -------------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since tracer construction (trace-local clock)."""
        return (time.perf_counter() - self._t0) * 1e6

    # -- emission ----------------------------------------------------------

    def _emit(self, ev: Dict[str, object]) -> None:
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append(ev)

    def span_at(self, name: str, ts: float, dur: float,
                cat: str = "serve", **args) -> None:
        if not self.enabled:
            return
        ev: Dict[str, object] = {
            "name": name, "cat": cat, "ph": "X", "pid": 0, "tid": 0,
            "ts": float(ts), "dur": float(dur),
        }
        if args:
            ev["args"] = args
        self._emit(ev)

    def instant(self, name: str, cat: str = "serve",
                ts: Optional[float] = None, **args) -> None:
        if not self.enabled:
            return
        ev: Dict[str, object] = {
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "pid": 0, "tid": 0,
            "ts": self.now_us() if ts is None else float(ts),
        }
        if args:
            ev["args"] = args
        self._emit(ev)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "serve", **args):
        """Real-time complete span around the with-body."""
        if not self.enabled:
            yield None
            return
        t0 = self.now_us()
        try:
            yield None
        finally:
            self.span_at(name, t0, self.now_us() - t0, cat=cat, **args)

    # -- rollback hygiene --------------------------------------------------

    def mark(self) -> int:
        """Buffer position for `truncate` — call before a guarded window."""
        return len(self.events)

    def truncate(self, mark: int) -> None:
        """Discard everything emitted since `mark` (rolled-back work)."""
        del self.events[mark:]

    # -- export ------------------------------------------------------------

    def to_chrome(self) -> Dict[str, object]:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        return {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
            "otherData": {
                "producer": "repro.obs.tracing",
                "dropped_events": self.dropped,
            },
        }

    def export(self, path: str | Path, fsync: bool = False) -> Path:
        from repro.core.persist import atomic_write_json

        return atomic_write_json(Path(path), self.to_chrome(), fsync=fsync)

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0


__all__ = ["Tracer", "DEFAULT_MAX_EVENTS"]
