"""repro.obs — unified observability: metrics, tracing, profiling hooks.

One `Observability` facade bundles the three concerns the serving stack
threads through its layers:

  .metrics   `MetricsRegistry` — counters/gauges/histograms, the single
             source of truth behind `ServeEngine.health()`, the SLO
             benchmarks' percentile reads, and supervisor heartbeats.
  .tracer    `Tracer` — window-timeline spans/instants, Chrome trace
             export (off by default: tracing buffers grow with run
             length, so it is an explicit opt-in).

The facade is identity-preserving under deepcopy: scheduler checkpoints
deep-copy everything a window can mutate, but telemetry must NOT fork —
a rolled-back window's trace cleanup goes through `Tracer.truncate`, and
counters deliberately keep counting across rollbacks (the rollback itself
is an observable event).

`NULL` is the shared disabled instance (every write early-outs); layers
that receive no observability default to it.  `get_default()` is the
process-global registry for call sites with no instance to thread through
(the kernel registry's arm-resolution notes).
"""

from __future__ import annotations

from typing import Optional

from repro.obs.metrics import (
    LATENCY_STEP_EDGES, PER_TOKEN_EDGES, MetricsRegistry,
)
from repro.obs.tracing import Tracer


class Observability:
    """Metrics + tracer bundle (module docstring)."""

    def __init__(self, metrics: bool = True, tracing: bool = False,
                 max_trace_events: Optional[int] = None):
        self.metrics = MetricsRegistry(enabled=metrics)
        if max_trace_events is None:
            self.tracer = Tracer(enabled=tracing)
        else:
            self.tracer = Tracer(enabled=tracing,
                                 max_events=max_trace_events)

    @property
    def enabled(self) -> bool:
        return self.metrics.enabled or self.tracer.enabled

    def __deepcopy__(self, memo):
        # Telemetry is identity under checkpoint/restore: history must not
        # fork into checkpoint copies (rollback cleanup is explicit, via
        # Tracer.mark/truncate in the scheduler's guarded path).
        return self

    def __copy__(self):
        return self


#: Shared disabled instance — the default for layers given no obs.
NULL = Observability(metrics=False, tracing=False)

_DEFAULT: Optional[Observability] = None


def get_default() -> Observability:
    """Process-global observability (metrics on, tracing off) for call
    sites with nothing to thread through — created lazily."""
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Observability(metrics=True, tracing=False)
    return _DEFAULT


def set_default(obs: Observability) -> Observability:
    """Replace the process-global instance; returns the previous one."""
    global _DEFAULT
    prev = get_default()
    _DEFAULT = obs
    return prev


__all__ = [
    "Observability", "MetricsRegistry", "Tracer", "NULL",
    "LATENCY_STEP_EDGES", "PER_TOKEN_EDGES",
    "get_default", "set_default",
]
