"""Profiling hooks: jax.profiler annotations + opt-in xplane trace dumps.

Thin wrappers so the serving/bench layers never import `jax.profiler`
directly (the module is optional in stripped builds) and never pay the
annotation cost unless a dump directory armed the session:

  annotate(name)        TraceAnnotation context — labels the enclosing
                        host region in the xplane timeline, nesting the
                        device dispatches it issues under it.
  trace_session(dir)    jax.profiler.trace context writing an xplane dump
                        under `dir`; `None` -> no-op nullcontext, so call
                        sites wrap unconditionally.
"""

from __future__ import annotations

import contextlib
from typing import ContextManager, Optional


def annotate(name: str) -> ContextManager[None]:
    """A jax.profiler.TraceAnnotation, or a nullcontext when the profiler
    is unavailable."""
    try:
        from jax.profiler import TraceAnnotation
    except ImportError:  # pragma: no cover — stripped jax builds
        return contextlib.nullcontext()
    return TraceAnnotation(name)


def trace_session(dump_dir: Optional[str]) -> ContextManager[None]:
    """Profiler session writing an xplane dump under `dump_dir`; no-op
    when `dump_dir` is None (the default serving configuration)."""
    if dump_dir is None:
        return contextlib.nullcontext()
    try:
        from jax.profiler import trace
    except ImportError:  # pragma: no cover — stripped jax builds
        return contextlib.nullcontext()
    return trace(str(dump_dir))


__all__ = ["annotate", "trace_session"]
