"""Metrics registry: counters, gauges, fixed-bucket histograms.

One process-local registry unifies the serving stack's accounting surfaces
(SmartPQ device stats, scheduler conservation ledger, overload states,
durability WAL/snapshot counters, kernel-arm resolutions) behind three
primitive types:

  counter    monotone float, `inc(name, n, **labels)`
  gauge      last-write-wins float, `set_gauge(name, v, **labels)`
  histogram  fixed upper-edge buckets, `observe(name, v, edges, **labels)`
             with p50/p99 summaries via `percentile` (see below)

Labels are plain keyword arguments; each distinct label set is its own
series, keyed Prometheus-style (``errors_total{code="INVARIANT"}``).  All
series of one histogram name share the edges declared at first `observe`
— that is what makes `percentile(name, q)` with a PARTIAL label set
meaningful: bucket counts merge exactly across series, so the aggregate
percentile is computed from the true merged distribution, not from
averaging per-series percentiles (which is statistically wrong).

Percentile estimates are the UPPER EDGE of the bucket holding the rank-q
sample (the last, unbounded bucket reports the observed max): a
conservative bound, exact whenever the observations and edges are both
integers that coincide — which is why the serving-latency edges below
enumerate every small integer step count.  SLO gates compare against
edge-valued targets, so "estimate == true value" holds exactly where it
matters.

Cost contract: a disabled registry (`enabled=False`) early-outs every
write at one attribute load + branch — cheap enough to leave call sites
unconditional in hot host loops.  Reads (`to_dict`, `percentile`,
exposition, persistence) are assumed cold.

Persistence rides `repro.core.persist.atomic_write_json` (tmp + rename):
`save()`/`load()` round-trip the full registry, so a supervisor can
inspect the last flushed state of a hung or dead process.
"""

from __future__ import annotations

import bisect
import math
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

SCHEMA = 1

# Engine-step latency edges: every integer up to 64 (queueing delays and
# the per-class SLO targets 8/16/32 are all engine-step integers — upper-
# edge percentiles are EXACT there), then power-of-two-ish coarse tail.
LATENCY_STEP_EDGES: Tuple[float, ...] = tuple(
    float(x) for x in range(65)
) + (80.0, 96.0, 128.0, 192.0, 256.0, 384.0, 512.0, 768.0, 1024.0)

# Per-token latency (e2e steps / tokens emitted) is fractional: quarter-
# step resolution to 16, then half steps to 32, then the coarse tail.
PER_TOKEN_EDGES: Tuple[float, ...] = tuple(
    x / 4 for x in range(1, 65)
) + tuple(x / 2 for x in range(33, 65)) + (48.0, 64.0, 96.0, 128.0)


def _series_key(name: str, labels: Mapping[str, object]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class _Histogram:
    """One labeled histogram series: counts per bucket + sum/min/max."""

    __slots__ = ("edges", "counts", "count", "sum", "min", "max")

    def __init__(self, edges: Sequence[float]):
        self.edges = tuple(float(e) for e in edges)
        # counts[i] <= edges[i]; counts[-1] is the +inf overflow bucket
        self.counts = [0] * (len(self.edges) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def as_dict(self) -> Dict[str, object]:
        return {
            "edges": list(self.edges),
            "counts": list(self.counts),
            "count": self.count,
            "sum": self.sum,
            "min": None if self.count == 0 else self.min,
            "max": None if self.count == 0 else self.max,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, object]) -> "_Histogram":
        h = cls(d["edges"])
        h.counts = [int(c) for c in d["counts"]]
        h.count = int(d["count"])
        h.sum = float(d["sum"])
        h.min = math.inf if d.get("min") is None else float(d["min"])
        h.max = -math.inf if d.get("max") is None else float(d["max"])
        return h


class MetricsRegistry:
    """Counters + gauges + histograms with label support (module docstring).

    Thread-safety: the serving stack is a single-controller host loop, so
    the registry is deliberately lock-free; concurrent writers need their
    own registry instances.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Histogram] = {}
        # histogram name -> canonical edges (all series of a name share)
        self._hist_edges: Dict[str, Tuple[float, ...]] = {}

    # -- writes (hot path: one branch when disabled) -----------------------

    def inc(self, name: str, n: float = 1, **labels) -> None:
        if not self.enabled:
            return
        k = _series_key(name, labels)
        self._counters[k] = self._counters.get(k, 0.0) + n

    def set_gauge(self, name: str, value: float, **labels) -> None:
        if not self.enabled:
            return
        self._gauges[_series_key(name, labels)] = float(value)

    def observe(self, name: str, value: float,
                edges: Optional[Sequence[float]] = None, **labels) -> None:
        if not self.enabled:
            return
        k = _series_key(name, labels)
        h = self._hists.get(k)
        if h is None:
            canon = self._hist_edges.get(name)
            if canon is None:
                canon = tuple(
                    float(e) for e in (edges or LATENCY_STEP_EDGES)
                )
                self._hist_edges[name] = canon
            h = self._hists[k] = _Histogram(canon)
        h.observe(float(value))

    # -- reads (cold) ------------------------------------------------------

    def value(self, name: str, **labels) -> float:
        """Counter-or-gauge read; 0.0 when the series does not exist."""
        k = _series_key(name, labels)
        if k in self._counters:
            return self._counters[k]
        return self._gauges.get(k, 0.0)

    def _matching_hists(self, name: str,
                        labels: Mapping[str, object]) -> List[_Histogram]:
        """All series of `name` whose labels are a superset of `labels`
        (empty labels -> every series of the name)."""
        frags = [f'{k}="{v}"' for k, v in labels.items()]
        out = []
        for key, h in self._hists.items():
            base = key.split("{", 1)[0]
            if base != name:
                continue
            if all(f in key for f in frags):
                out.append(h)
        return out

    def hist_count(self, name: str, **labels) -> int:
        return sum(h.count for h in self._matching_hists(name, labels))

    def hist_sum(self, name: str, **labels) -> float:
        return sum(h.sum for h in self._matching_hists(name, labels))

    def percentile(self, name: str, q: float, **labels) -> float:
        """Upper-edge percentile over the MERGED bucket counts of every
        series of `name` matching the (possibly partial) label set.
        Returns nan when no observations exist."""
        hists = [h for h in self._matching_hists(name, labels) if h.count]
        if not hists:
            return float("nan")
        total = sum(h.count for h in hists)
        rank = max(math.ceil(q / 100.0 * total), 1)
        edges = hists[0].edges
        nbuckets = len(edges) + 1
        cum = 0
        for i in range(nbuckets):
            cum += sum(h.counts[i] for h in hists)
            if cum >= rank:
                if i < len(edges):
                    return edges[i]
                return max(h.max for h in hists)  # unbounded tail bucket
        return max(h.max for h in hists)  # pragma: no cover — unreachable

    def summary(self, name: str, **labels) -> Dict[str, float]:
        """The p50/p99 view the SLO benchmarks consume."""
        return {
            "count": self.hist_count(name, **labels),
            "p50": self.percentile(name, 50, **labels),
            "p99": self.percentile(name, 99, **labels),
        }

    # -- exposition --------------------------------------------------------

    def to_dict(self) -> Dict[str, object]:
        return {
            "schema": SCHEMA,
            "counters": dict(sorted(self._counters.items())),
            "gauges": dict(sorted(self._gauges.items())),
            "histograms": {
                k: h.as_dict() for k, h in sorted(self._hists.items())
            },
        }

    def compact(self) -> Dict[str, float]:
        """Counters + gauges only (no bucket arrays) — the heartbeat-sized
        snapshot the supervisor reads for hang diagnosis."""
        out: Dict[str, float] = {}
        out.update(sorted(self._counters.items()))
        out.update(sorted(self._gauges.items()))
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition (v0.0.4): counters, gauges, and
        cumulative `_bucket`/`_sum`/`_count` histogram series."""
        lines: List[str] = []
        seen_types: set = set()

        def _type(name: str, kind: str):
            if name not in seen_types:
                seen_types.add(name)
                lines.append(f"# TYPE {name} {kind}")

        def _fmt(v: float) -> str:
            return repr(int(v)) if float(v).is_integer() else repr(v)

        for key, v in sorted(self._counters.items()):
            _type(key.split("{", 1)[0], "counter")
            lines.append(f"{key} {_fmt(v)}")
        for key, v in sorted(self._gauges.items()):
            _type(key.split("{", 1)[0], "gauge")
            lines.append(f"{key} {_fmt(v)}")
        for key, h in sorted(self._hists.items()):
            name, _, rest = key.partition("{")
            inner = rest[:-1] if rest else ""
            _type(name, "histogram")
            cum = 0
            for i, e in enumerate(h.edges):
                cum += h.counts[i]
                le = f'le="{_fmt(e)}"'
                lab = f"{{{inner},{le}}}" if inner else f"{{{le}}}"
                lines.append(f"{name}_bucket{lab} {cum}")
            lab = f'{{{inner},le="+Inf"}}' if inner else '{le="+Inf"}'
            lines.append(f"{name}_bucket{lab} {h.count}")
            suffix = f"{{{inner}}}" if inner else ""
            lines.append(f"{name}_sum{suffix} {_fmt(h.sum)}")
            lines.append(f"{name}_count{suffix} {h.count}")
        return "\n".join(lines) + "\n"

    # -- persistence (atomic, via repro.core.persist) ----------------------

    def save(self, path: str | Path, fsync: bool = False) -> Path:
        from repro.core.persist import atomic_write_json

        return atomic_write_json(Path(path), self.to_dict(), fsync=fsync,
                                 indent=1)

    def load(self, path: str | Path) -> None:
        """Replace this registry's contents with a saved payload."""
        import json

        d = json.loads(Path(path).read_text())
        if d.get("schema") != SCHEMA:
            raise ValueError(
                f"metrics payload schema {d.get('schema')!r} != {SCHEMA}"
            )
        self._counters = {k: float(v) for k, v in d["counters"].items()}
        self._gauges = {k: float(v) for k, v in d["gauges"].items()}
        self._hists = {
            k: _Histogram.from_dict(h) for k, h in d["histograms"].items()
        }
        self._hist_edges = {
            k.split("{", 1)[0]: h.edges for k, h in self._hists.items()
        }

    def clear(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()
        self._hist_edges.clear()


__all__ = [
    "MetricsRegistry", "LATENCY_STEP_EDGES", "PER_TOKEN_EDGES", "SCHEMA",
]
