"""Mesh construction and logical axis conventions.

Physical axes (mandated by the production footprint):
    pod    — crosses the slow interconnect tier (2 pods in the multi-pod run)
    data   — intra-pod, batch / FSDP axis (16)
    model  — intra-pod, tensor/sequence/expert axis (16)

Logical use:
    batch                -> ('pod', 'data')
    sequence (attention) -> 'model'   (sequence parallelism: every sharded
                             dim must divide 16, head counts often don't)
    d_ff / flat qkv dims / experts / vocab -> 'model'
    param storage        -> 2D ('data', 'model') (ZeRO-3-style storage)
    PQ shards (serving)  -> ('pod', 'data', 'model') flattened
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

try:  # sharding-in-types churn: AxisType landed after jax 0.4.x
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto-typed
    AxisType = None

AXIS_POD = "pod"
AXIS_DATA = "data"
AXIS_MODEL = "model"


def _axis_type_kwargs(n_axes: int) -> dict:
    """axis_types=(Auto,)*n on jax versions that have it, {} otherwise —
    both spellings mean the same thing (fully Auto-partitioned mesh)."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_mesh(
    shape: Tuple[int, ...],
    axes: Tuple[str, ...],
    devices=None,
) -> Mesh:
    """Auto-typed mesh (sharding-in-types churn pinned down explicitly)."""
    if devices is not None:
        import numpy as np

        return Mesh(
            np.asarray(devices).reshape(shape),
            axes,
            **_axis_type_kwargs(len(axes)),
        )
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return tuple(a for a in (AXIS_POD, AXIS_DATA) if a in mesh.axis_names)


def mesh_geometry(mesh: Mesh) -> Tuple[int, int]:
    """(npods, chips_per_pod)."""
    npods = mesh.shape.get(AXIS_POD, 1)
    chips = 1
    for a, n in mesh.shape.items():
        chips *= n
    return npods, chips // npods


def local_fits(mesh: Mesh, dim: int, axis: str = AXIS_MODEL) -> bool:
    return dim % mesh.shape[axis] == 0
