"""Per-architecture parallelism policy — beyond-paper optimization.

The roofline table exposed the classic failure mode of one-size-fits-all
TP: whisper-base (d_model=512) on a 16-wide model axis spends 12x more
time in collectives than in compute (its largest matmul tile per device is
512x128 — too small to amortize anything).

Policy: when the model's feature dims are too small for the model axis,
REPLICATE the block weights over 'model' and keep it for what still needs
it (the padded-vocab embedding/unembedding, MoE experts).  Compute then
runs data-parallel inside the block (zero per-layer weight collectives)
and gradients sync once per step.  The ZeRO 'data' storage factor is kept.

Threshold: d_model/model_axis below one MXU tile (128 lanes) — i.e.
d_model < 128 * axis — marks the arch as TP-starved ONLY when the whole
block weight set is tiny anyway (< 64 MiB/device replicated); both hold
for whisper-base and the granite-moe attention stacks.
"""

from __future__ import annotations

import dataclasses

from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.mesh import AXIS_MODEL
from repro.distributed.sharding import ShardingRules

# Fields that stop being model-sharded under the replicated policy.
_BLOCK_PARAM_FIELDS = (
    "wq", "wkv", "wo", "qkv_bias", "w_in", "w_out",
    "ssm_in", "ssm_out", "ssm_small", "conv_kernel",
)
_BLOCK_ACT_FIELDS = ("act_seq", "act_ffn")


def tp_starved(cfg: ModelConfig, model_axis: int) -> bool:
    """True when per-device TP tiles fall under one MXU tile AND the
    replicated block weights stay tiny."""
    if cfg.family in ("ssm", "hybrid"):
        return False  # SSD head-sharding wants the model axis
    if cfg.moe is not None:
        return False  # expert parallelism owns the model axis
    tile = cfg.d_model / model_axis
    if tile >= 128:
        return False
    # block params per layer (attn + dense ffn), bf16, replicated:
    hd = cfg.resolved_head_dim
    per_layer = (
        cfg.d_model * hd * (cfg.n_heads + 2 * cfg.n_kv_heads)
        + cfg.n_heads * hd * cfg.d_model
        + 3 * cfg.d_model * cfg.d_ff
    )
    total = per_layer * (cfg.n_layers + cfg.n_encoder_layers) * 2  # bytes
    return total <= 512 * 2**20


def replicated_block_rules(rules: ShardingRules) -> ShardingRules:
    """Drop 'model' from block param specs AND re-purpose the idle model
    axis as extra DATA parallelism: the batch group of every activation
    spec grows to ('pod','data','model').  Without the second half the
    activations replicate 16x across the model axis (measured: whisper
    train ballooned 3.8 -> 49 GiB/device with weights-only replication).
    Embeddings/logits keep vocab@model (one resharding at the head)."""

    def drop_model(spec: P) -> P:
        out = []
        for e in spec:
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a != AXIS_MODEL)
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            elif e == AXIS_MODEL:
                out.append(None)
            else:
                out.append(e)
        return P(*out)

    def widen_batch(spec: P) -> P:
        out = []
        for e in spec:
            if isinstance(e, tuple) and "data" in e:
                out.append(tuple(e) + (AXIS_MODEL,))
            elif e == "data":
                out.append(("data", AXIS_MODEL))
            elif e == AXIS_MODEL:
                out.append(None)  # old model entry moves to the batch group
            else:
                out.append(e)
        return P(*out)

    updates = {}
    for f in dataclasses.fields(ShardingRules):
        spec = getattr(rules, f.name)
        if f.name in _BLOCK_PARAM_FIELDS:
            updates[f.name] = drop_model(spec)
        elif f.name in ("act_btd", "act_seq", "act_ffn", "tokens"):
            updates[f.name] = widen_batch(spec)
        else:
            updates[f.name] = spec
    return ShardingRules(**updates)


def apply_policy(cfg: ModelConfig, mesh, rules: ShardingRules,
                 global_batch: int | None = None) -> ShardingRules:
    model_axis = mesh.shape.get(AXIS_MODEL, 1)
    n_dev = 1
    for _, v in mesh.shape.items():
        n_dev *= v
    if global_batch is not None and global_batch % n_dev != 0:
        return rules  # widened batch group wouldn't divide
    if tp_starved(cfg, model_axis):
        return replicated_block_rules(rules)
    return rules
