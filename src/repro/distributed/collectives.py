"""Pod-aware collectives + gradient compression.

The paper's Nuddle insight applied to training: cross-pod traffic is the
scarce resource, so (a) reduce within the pod first and only ship the
already-reduced tensor across the pod axis (hierarchical all-reduce), and
(b) optionally compress the cross-pod hop with error-feedback int8 — the
slow tier carries 4x fewer bytes while the fast tier stays exact.

These run inside shard_map (the gradient sync of the train loop when
`hierarchical_grads=True`) — outside it, XLA's default all-reduce is used.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.mesh import AXIS_DATA, AXIS_POD


def hierarchical_psum(x: jnp.ndarray, shard_axes, pod_axis: Optional[str]):
    """Two-phase all-reduce: reduce-scatter+all-gather happens implicitly in
    XLA for flat psum; here we stage pod-local reduction first so only one
    pre-reduced tensor crosses the slow tier per pod."""
    x = jax.lax.psum(x, shard_axes)
    if pod_axis is not None:
        x = jax.lax.psum(x, pod_axis)
    return x


def int8_quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 with fp32 scale."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_cross_pod_psum(
    x: jnp.ndarray,
    shard_axes,
    pod_axis: Optional[str],
    error: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Hierarchical all-reduce with int8 error-feedback on the cross-pod hop.

    Returns (reduced, new_error).  The intra-pod reduction is exact; the
    cross-pod sum quantizes (x + carried_error), accumulating the residual
    for the next step (error feedback keeps the scheme unbiased over time).
    """
    x = jax.lax.psum(x, shard_axes)
    if pod_axis is None:
        return x, jnp.zeros_like(x) if error is None else error
    if error is not None:
        x = x + error
    # Shared scale across pods (one scalar pmax over the slow tier) so the
    # int32 payload sum dequantizes exactly.
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)) + 1e-12, pod_axis)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    summed = jax.lax.psum(q.astype(jnp.int32), pod_axis)
    total = summed.astype(jnp.float32) * scale
    new_error = x - q.astype(jnp.float32) * scale
    return total, new_error


def reduce_scatter_then_allgather(x: jnp.ndarray, axis: str, dim: int = 0):
    """Explicit two-step all-reduce (lets the scheduler overlap the halves
    with compute; XLA fuses them back when that is better)."""
    rs = jax.lax.psum_scatter(x, axis, scatter_dimension=dim, tiled=True)
    return jax.lax.all_gather(rs, axis, axis=dim, tiled=True)
