"""shard_map version compat — one import site for the whole repo.

jax moved shard_map twice during this repo's lifetime: old versions ship it
as `jax.experimental.shard_map.shard_map` with a `check_rep` kwarg; new
versions promote it to `jax.shard_map` and rename the kwarg `check_vma`.
Every caller here (models.layers.moe, the device scripts) imports this
wrapper, which speaks the NEW spelling and translates down when needed —
the same guarded-compat pattern as `distributed.mesh.AxisType`.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma,
        )

else:  # pre-promotion jax: experimental home, check_rep spelling
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map_experimental(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )
