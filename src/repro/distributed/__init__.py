from repro.distributed.mesh import (  # noqa: F401
    AXIS_POD,
    AXIS_DATA,
    AXIS_MODEL,
    batch_axes,
    make_mesh,
)
from repro.distributed.sharding import ShardingRules, default_rules  # noqa: F401
