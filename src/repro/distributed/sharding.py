"""Sharding rules: logical param/activation names -> PartitionSpec.

One place owns every sharding decision so the perf loop can flip a rule and
re-lower (EXPERIMENTS.md §Perf iterates exactly here).

Scheme (see mesh.py): 2D param storage over ('data', 'model') — the 'data'
factor is the ZeRO-3 storage shard (XLA materializes the gather at use),
the 'model' factor is Megatron-style tensor parallelism on dims that always
divide 16 (flat qkv out-dims, d_ff, padded experts, padded vocab).  The
residual stream is batch-sharded over ('pod', 'data') and sequence-sharded
over 'model' for attention blocks (head counts need not divide the mesh).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.mesh import AXIS_DATA, AXIS_MODEL, AXIS_POD


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """PartitionSpecs by logical tensor role.  `L` marks the scanned layer
    axis (always unsharded).  Trailing dims listed big-endian."""

    # -- params ---------------------------------------------------------------
    embed: P = P(AXIS_MODEL, AXIS_DATA)  # (V_pad, D)
    head: P = P(AXIS_DATA, AXIS_MODEL)  # (D, V_pad) unembedding
    norm_scale: P = P(None)  # (D,) replicated (tiny)
    # attention projections (flat feature dims)
    wq: P = P(None, AXIS_DATA, AXIS_MODEL)  # (L, D, Hq*hd)
    wkv: P = P(None, AXIS_DATA, AXIS_MODEL)  # (L, D, Hkv*hd)
    wo: P = P(None, AXIS_MODEL, AXIS_DATA)  # (L, Hq*hd, D) row-parallel
    qkv_bias: P = P(None, AXIS_MODEL)  # (L, F)
    # mlp
    w_in: P = P(None, AXIS_DATA, AXIS_MODEL)  # (L, D, d_ff) column-parallel
    w_out: P = P(None, AXIS_MODEL, AXIS_DATA)  # (L, d_ff, D) row-parallel
    # moe (E padded to a multiple of the model axis)
    router: P = P(None, AXIS_DATA, AXIS_MODEL)  # (L, D, E_pad)
    expert_in: P = P(None, AXIS_MODEL, AXIS_DATA, None)  # (L, E_pad, D, d_ff)
    expert_out: P = P(None, AXIS_MODEL, None, AXIS_DATA)  # (L, E_pad, d_ff, D)
    # ssm (mamba2): flat inner dims divide 16 everywhere
    ssm_in: P = P(None, AXIS_DATA, AXIS_MODEL)  # (L, D, 2*d_inner + ...)
    ssm_out: P = P(None, AXIS_MODEL, AXIS_DATA)  # (L, d_inner, D)
    ssm_small: P = P(None, AXIS_MODEL)  # (L, d_inner)-ish vectors
    conv_kernel: P = P(None, None, AXIS_MODEL)  # (L, K, d_conv_channels)

    # -- activations ----------------------------------------------------------
    act_btd: P = P((AXIS_POD, AXIS_DATA), None, None)  # (B, S, D) dense zones
    act_seq: P = P((AXIS_POD, AXIS_DATA), AXIS_MODEL, None)  # (B, S, D) attn zones
    act_ffn: P = P((AXIS_POD, AXIS_DATA), None, AXIS_MODEL)  # (B, S, d_ff)
    logits: P = P((AXIS_POD, AXIS_DATA), None, AXIS_MODEL)  # (B, S, V_pad)
    tokens: P = P((AXIS_POD, AXIS_DATA), None)  # (B, S)
    # KV cache: batch over data axes, sequence over model (decode SP)
    kv_cache: P = P(None, (AXIS_POD, AXIS_DATA), AXIS_MODEL, None, None)
    ssm_state: P = P(None, (AXIS_POD, AXIS_DATA), AXIS_MODEL, None)
    # (L, B, d_inner, d_state): d_inner over model
    scalar: P = P()


def default_rules(single_axis_fallback: bool = False) -> ShardingRules:
    return ShardingRules()


def strip_pod(rules: ShardingRules, mesh: Mesh) -> ShardingRules:
    """Drop the pod axis from every spec when the mesh has none (single-pod
    dry-run) — PartitionSpec axis names must exist in the mesh."""
    if AXIS_POD in mesh.axis_names:
        return rules

    def fix(spec: P) -> P:
        out = []
        for entry in spec:
            if isinstance(entry, tuple):
                kept = tuple(a for a in entry if a != AXIS_POD)
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            elif entry == AXIS_POD:
                out.append(None)
            else:
                out.append(entry)
        return P(*out)

    return ShardingRules(
        **{
            f.name: fix(getattr(rules, f.name))
            for f in dataclasses.fields(ShardingRules)
        }
    )


def drop_batch_axes(rules: ShardingRules) -> ShardingRules:
    """Strip ('pod','data') batch-group entries from ACTIVATION specs —
    for cells whose global batch doesn't divide the batch-device count
    (long_500k: batch 1).  Param specs keep their 'data' ZeRO factor."""
    batch_group = {AXIS_POD, AXIS_DATA}
    act_fields = {
        "act_btd", "act_seq", "act_ffn", "logits", "tokens",
        "kv_cache", "ssm_state",
    }

    def fix(spec: P) -> P:
        out = []
        for e in spec:
            if isinstance(e, tuple) and set(e) & batch_group:
                kept = tuple(a for a in e if a not in batch_group)
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            elif e in batch_group:
                out.append(None)
            else:
                out.append(e)
        return P(*out)

    updates = {}
    for f in dataclasses.fields(ShardingRules):
        spec = getattr(rules, f.name)
        updates[f.name] = fix(spec) if f.name in act_fields else spec
    return ShardingRules(**updates)


def tp_only_params(rules: ShardingRules) -> ShardingRules:
    """Serving-mode param placement: drop the 'data' (ZeRO) factor from
    PARAM specs so weights are stored TP-sharded + data-replicated.
    Inference has no optimizer states, so the ZeRO storage factor only buys
    per-step all-gathers (observed: GiBs of collectives per decoded token);
    replicating over 'data' eliminates them wherever the model fits."""
    param_fields = {
        "embed", "head", "wq", "wkv", "wo", "qkv_bias", "w_in", "w_out",
        "router", "expert_in", "expert_out", "ssm_in", "ssm_out",
        "ssm_small", "conv_kernel",
    }

    def fix(spec: P) -> P:
        out = []
        for e in spec:
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a != AXIS_DATA)
                out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
            elif e == AXIS_DATA:
                out.append(None)
            else:
                out.append(e)
        return P(*out)

    updates = {}
    for f in dataclasses.fields(ShardingRules):
        spec = getattr(rules, f.name)
        updates[f.name] = fix(spec) if f.name in param_fields else spec
    return ShardingRules(**updates)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def constraint(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that tolerates rank mismatch by right-padding
    the spec with None (scanned bodies see specs without the L dim)."""
    ndim = x.ndim
    entries = list(spec) + [None] * (ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries[:ndim]))
    )


def pad_to_multiple(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult
