"""End-to-end training driver: a ~100M-param llama-family model for a few
hundred steps on synthetic data, with checkpoint/restart demonstrated
mid-run.

    PYTHONPATH=src python examples/train_demo.py [--steps 200]
"""

import argparse
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import SyntheticLMDataset
from repro.train.loop import LoopConfig, run
from repro.train.optimizer import AdamWConfig

# ~100M params: 12L x 768 (llama-style)
CFG_100M = ModelConfig(
    name="demo-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab=8192,
    head_dim=64,
    act="silu",
    norm="rms",
    tie_embeddings=True,
    rope_theta=10000.0,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    print(f"model: {CFG_100M.name} ({CFG_100M.param_count() / 1e6:.0f}M params)")
    data = SyntheticLMDataset(vocab=CFG_100M.vocab, seq_len=256, seed=0,
                              fixed_map=True)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        half = args.steps // 2
        opt = AdamWConfig(lr=6e-4, state_dtype="bf16", weight_decay=0.01)
        print(f"phase 1: steps 0..{half} (will checkpoint every 25)")
        res1 = run(
            CFG_100M,
            LoopConfig(steps=half, batch_size=args.batch, ckpt_every=25,
                       ckpt_dir=ckpt_dir, log_every=20),
            opt_cfg=opt,
            data=data,
        )
        print(f"  loss {res1['losses'][0]:.3f} -> {res1['losses'][-1]:.3f}")

        print(f"phase 2: RESTART from checkpoint, continue to {args.steps}")
        res2 = run(
            CFG_100M,
            LoopConfig(steps=args.steps, batch_size=args.batch, ckpt_every=25,
                       ckpt_dir=ckpt_dir),
            opt_cfg=opt,
            data=data,
        )
        print(f"  resumed from step {res2['resumed_from']}")
        print(f"  final loss {res2['losses'][-1]:.3f}")
        first = np.mean(res1["losses"][:10])
        last = np.mean(res2["losses"][-10:])
        assert last < first, "training did not reduce loss"
        print(f"OK — loss {first:.3f} -> {last:.3f} across a restart boundary.")


if __name__ == "__main__":
    main()
