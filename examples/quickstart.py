"""Quickstart: the adaptive priority queue in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro import SmartPQ, SmartPQConfig
from repro.core.pqueue.ops import OP_DELETE_MIN, OP_INSERT
from repro.core.pqueue.state import INF_KEY


def main():
    pq = SmartPQ(SmartPQConfig(num_shards=16, capacity=4096, npods=2,
                               decision_interval=4))
    carry = pq.init()
    step = jax.jit(pq.step)
    rng = np.random.default_rng(0)
    key = jax.random.key(0)
    B = 64

    print("phase 1: insert burst (low contention -> oblivious mode expected)")
    for i in range(12):
        ops = jnp.full((B,), OP_INSERT, jnp.int32)
        keys = jnp.asarray(rng.integers(0, 1 << 20, B), jnp.int32)
        key, sub = jax.random.split(key)
        carry, _ = step(carry, ops, keys, jnp.arange(B, dtype=jnp.int32), sub, 512)
    print(f"  size={int(carry.state.total_size)} mode={int(carry.stats.mode)} "
          f"(0=oblivious/spray, 1=multiq, 2=aware/Nuddle)")

    print("phase 2: deleteMin storm (high contention -> aware mode expected)")
    drained = []
    for i in range(12):
        ops = jnp.full((B,), OP_DELETE_MIN, jnp.int32)
        key, sub = jax.random.split(key)
        carry, res = step(carry, ops, jnp.full((B,), INF_KEY, jnp.int32),
                          jnp.zeros(B, jnp.int32), sub, 512)
        drained.extend(np.asarray(res.keys)[: int(res.n_out)].tolist())
    print(f"  size={int(carry.state.total_size)} mode={int(carry.stats.mode)} "
          f"transitions={int(carry.stats.transitions)}")
    print(f"  first 10 drained keys (ascending-ish): {drained[:10]}")
    assert int(carry.stats.transitions) >= 1, "expected at least one adaptation"
    print("OK — SmartPQ adapted between algorithmic modes with zero data movement.")


if __name__ == "__main__":
    main()
