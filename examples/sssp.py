"""Single-Source Shortest Paths over the distributed PQ — the paper's
motivating graph application (§1).

Bulk-synchronous Dijkstra: each step deleteMin's a wavefront of m vertices,
relaxes their edges, and inserts improved tentative distances.  Run twice:

  * exact mode (HIER / Nuddle): every settled vertex is final -> zero wasted
    relaxations, but each step pays the hierarchical tournament;
  * relaxed mode (SPRAY / alistarh): collective-free deleteMin, but priority
    inversion causes re-relaxations (wasted work) — the quantity the
    SmartPQ cost model's `relax_alpha` captures (DESIGN.md §6).

    PYTHONPATH=src python examples/sssp.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.pqueue import ops as O
from repro.core.pqueue.schedules import Schedule
from repro.core.pqueue.state import INF_KEY, make_state


def random_graph(n=512, avg_deg=6, seed=0):
    rng = np.random.default_rng(seed)
    rows, cols, w = [], [], []
    for u in range(n):
        deg = rng.poisson(avg_deg) + 1
        vs = rng.choice(n, size=min(deg, n - 1), replace=False)
        for v in vs:
            if v != u:
                rows.append(u)
                cols.append(int(v))
                w.append(int(rng.integers(1, 64)))
    return np.asarray(rows), np.asarray(cols), np.asarray(w), n


def bellman_ford_ref(rows, cols, w, n, src=0):
    dist = np.full(n, np.iinfo(np.int64).max)
    dist[src] = 0
    for _ in range(n):
        nd = np.minimum.reduceat if False else None
        changed = False
        for u, v, wt in zip(rows, cols, w):
            if dist[u] != np.iinfo(np.int64).max and dist[u] + wt < dist[v]:
                dist[v] = dist[u] + wt
                changed = True
        if not changed:
            break
    return dist


def sssp_pq(rows, cols, w, n, schedule, m=32, seed=0, src=0):
    """Bulk Dijkstra.  Returns (dist, settles, steps) — `settles` counts
    deleteMin pops; pops of stale entries are the wasted work."""
    adj = {}
    for u, v, wt in zip(rows, cols, w):
        adj.setdefault(u, []).append((v, wt))

    st = make_state(16, 1 << 14)
    dist = np.full(n, np.iinfo(np.int64).max)
    dist[src] = 0
    # key packs (distance << 10 | vertex) so ties break deterministically.
    st, _ = O.insert(st, jnp.asarray([0], jnp.int32), jnp.asarray([src], jnp.int32))
    key = jax.random.key(seed)
    pops = wasted = steps = 0

    delete = jax.jit(
        lambda s, k: O.delete_min(s, m, schedule=schedule, active=m, rng=k,
                                  npods=2)
    )
    insert = jax.jit(O.insert)

    while int(st.total_size) > 0 and steps < 10_000:
        key, sub = jax.random.split(key)
        res = delete(st, sub)
        st = res.state
        got_k = np.asarray(res.keys)[: int(res.n_out)]
        got_v = np.asarray(res.vals)[: int(res.n_out)]
        new_k, new_v = [], []
        for d, u in zip(got_k.tolist(), got_v.tolist()):
            pops += 1
            if d > dist[u]:
                wasted += 1  # stale entry (priority inversion cost)
                continue
            for v, wt in adj.get(u, []):
                nd = d + wt
                if nd < dist[v]:
                    dist[v] = nd
                    new_k.append(nd)
                    new_v.append(v)
        if new_k:
            pad = (-len(new_k)) % m
            kb = jnp.asarray(new_k + [INF_KEY] * pad, jnp.int32)
            vb = jnp.asarray(new_v + [0] * pad, jnp.int32)
            st, _ = insert(st, kb, vb)
        steps += 1
    return dist, pops, wasted, steps


def main():
    rows, cols, w, n = random_graph()
    ref = bellman_ford_ref(rows, cols, w, n)
    print(f"graph: {n} vertices, {len(rows)} edges")
    for name, sched in (("exact/Nuddle(HIER)", Schedule.HIER),
                        ("relaxed/SprayList", Schedule.SPRAY_HERLIHY)):
        dist, pops, wasted, steps = sssp_pq(rows, cols, w, n, sched)
        ok = np.array_equal(dist, ref)
        print(f"{name:22s} correct={ok} steps={steps} pops={pops} "
              f"wasted={wasted} ({100.0 * wasted / max(pops, 1):.1f}% overhead)")
        assert ok, f"{name} produced wrong distances"
    print("OK — both modes correct; relaxed mode pays wasted re-relaxations,"
          " exact mode pays collectives: the SmartPQ trade-off.")


if __name__ == "__main__":
    main()
