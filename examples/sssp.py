"""Single-Source Shortest Paths over the distributed PQ — the paper's
motivating graph application (§1), now a thin wrapper over the on-device
driver in `repro.workloads.sssp`.

The driver runs the whole wavefront loop (deleteMin an m-wide wavefront,
scatter-min edge relaxation, re-insert improved tentative distances) inside
`lax.scan`; this script just compares the schedules:

  * exact mode (HIER / Nuddle): every wavefront is the true global minimum
    — wasted pops are only same-batch collisions, but each step pays the
    hierarchical tournament;
  * relaxed mode (SPRAY / MULTIQ): collective-free deleteMin, but priority
    inversion causes stale pops (wasted re-relaxations) — the quantity the
    SmartPQ cost model's `relax_alpha` captures, measured here empirically;
  * adaptive SmartPQ: the decision tree picks per-step, on-device.

The oracle is `repro.workloads.graphs.bellman_ford`; every schedule must
converge to its distances bit for bit.

    PYTHONPATH=src python examples/sssp.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core.pqueue.schedules import Schedule
from repro.workloads import (
    bellman_ford,
    default_pq,
    random_graph,
    run_sssp,
    run_sssp_smartpq,
)


def main():
    g = random_graph(n=512, seed=0)
    ref = bellman_ford(g)
    print(f"graph: {g.n} vertices, {g.num_edges} edges")
    for name, sched in (
        ("exact/Nuddle(HIER)", Schedule.HIER),
        ("relaxed/SprayList", Schedule.SPRAY_HERLIHY),
        ("relaxed/MultiQueue", Schedule.MULTIQ),
    ):
        r = run_sssp(g, sched, m=32, seed=1)
        ok = np.array_equal(r.dist, ref)
        print(f"{name:22s} correct={ok} steps={r.steps} pops={r.pops} "
              f"wasted={r.wasted} "
              f"({100.0 * r.wasted / max(r.pops, 1):.1f}% overhead)")
        assert ok, f"{name} produced wrong distances"

    pq = default_pq(head_width=256)
    r, _ = run_sssp_smartpq(g, pq, m=16, seed=1)
    ok = np.array_equal(r.dist, ref)
    print(f"{'adaptive/SmartPQ':22s} correct={ok} steps={r.steps} "
          f"pops={r.pops} wasted={r.wasted} "
          f"modes={sorted(set(r.modes.tolist()))} "
          f"transitions={r.transitions}")
    assert ok, "adaptive SmartPQ produced wrong distances"
    print("OK — every mode converges to Bellman-Ford; relaxed modes pay "
          "wasted re-relaxations, exact modes pay collectives: the SmartPQ "
          "trade-off.")


if __name__ == "__main__":
    main()
