"""Serving demo: continuous batching with the SmartPQ scheduler.

A small llama-family model serves a bursty multi-tenant workload
(interactive + batch SLO classes).  Watch the scheduler's PQ flip between
oblivious (arrival bursts) and delegation (drain) modes.

    PYTHONPATH=src python examples/serve_demo.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np
import jax

from repro.configs.registry import reduced_config
from repro.models.registry import build_model
from repro.serve.engine import EngineConfig, ServeEngine
from repro.serve.scheduler import Request


def bursty_workload(n_bursts=4, burst=6, seed=0):
    """Bursts of mixed-SLO requests with idle gaps (drain phases)."""
    rng = np.random.default_rng(seed)
    workload, uid = [], 0
    for b in range(n_bursts):
        arrivals = []
        for _ in range(burst):
            arrivals.append(
                Request(
                    uid=uid,
                    prompt_len=int(rng.integers(4, 16)),
                    max_new_tokens=int(rng.integers(2, 6)),
                    slo_class=int(rng.integers(0, 3)),
                )
            )
            uid += 1
        workload.append(arrivals)
        workload.extend([[]] * 6)  # drain gap
    return workload, uid


def main():
    cfg = reduced_config("llama3.2-3b")
    model = build_model(cfg, remat=False)
    params, _ = model.init(jax.random.key(0))
    engine = ServeEngine(cfg, params, EngineConfig(batch_size=4, max_seq=64))

    workload, total = bursty_workload()
    print(f"serving {total} requests across {len(workload)} ticks "
          f"(batch slots: 4)")
    summary = engine.run(workload, max_steps=400)
    trace = "".join(str(m) for m in summary["mode_trace"])
    print(f"completed: {summary['completed']}/{total} in {summary['steps']} steps "
          f"({summary['wall_s']:.1f}s)")
    print(f"scheduler mode trace (0=oblivious, 1=multiq, 2=Nuddle): {trace}")
    print(f"PQ mode transitions: {summary['pq_transitions']}")
    assert summary["completed"] == total
    sample = next(iter(engine.outputs.items()))
    print(f"sample output (uid {sample[0]}): {sample[1]}")
    print("OK — all requests served under SmartPQ continuous batching.")


if __name__ == "__main__":
    main()
