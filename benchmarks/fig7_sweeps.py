"""Figure 7: Nuddle vs its base algorithm across (a) #clients, (b) key range.

Reproduces the paper's observation that the winner depends on multiple
features simultaneously (the motivation for the learned classifier)."""

from benchmarks.common import PQWorkload, emit, throughput_mops
from repro.core.pqueue.schedules import Schedule


def run(quick: bool = False):
    # (a) vs number of clients, 80%-insert workload (paper Fig. 7a)
    clients = [8, 32, 128] if quick else [8, 16, 32, 64, 128, 256]
    for c in clients:
        w = PQWorkload(
            num_clients=c, size=65536, key_range=1 << 20, insert_frac=0.8,
            num_shards=16, npods=2, capacity=1 << 15,
        )
        t_obl = throughput_mops(w, Schedule.SPRAY_HERLIHY)
        t_aw = throughput_mops(w, Schedule.HIER)
        emit(f"fig7a/clients_{c}/oblivious", c / t_obl, f"mops={t_obl:.2f}")
        emit(f"fig7a/clients_{c}/nuddle", c / t_aw, f"mops={t_aw:.2f}")

    # (b) vs key range, insert-dominated (paper Fig. 7b)
    ranges = [2048, 1 << 20] if quick else [2048, 1 << 14, 1 << 20, 1 << 26]
    for kr in ranges:
        w = PQWorkload(
            num_clients=64, size=16384, key_range=kr, insert_frac=0.9,
            num_shards=16, npods=2, capacity=1 << 15,
        )
        t_obl = throughput_mops(w, Schedule.SPRAY_HERLIHY)
        t_aw = throughput_mops(w, Schedule.HIER)
        emit(f"fig7b/range_{kr}/oblivious", 64 / t_obl, f"mops={t_obl:.2f}")
        emit(f"fig7b/range_{kr}/nuddle", 64 / t_aw, f"mops={t_aw:.2f}")
