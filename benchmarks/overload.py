"""overload — graceful-degradation records for the serving tier.

Sweeps offered load from ~1x to ~4x the engine's service capacity
(capacity = decode slots / mean tokens-per-request on the synthetic
decode's deterministic clock) and records, per load point, a BASELINE row
(open-loop admission, the pre-overload engine: unbounded backlog, no
shedding) next to a SHED row (OverloadController with per-class p99
queueing targets).

The acceptance evidence the paired rows carry: at 2x offered load the
controlled run holds the highest SLO class's p99 queueing delay within its
target while the shed rate absorbs the excess — the baseline run, by
contrast, lets the backlog grow without bound and the tail degrade for
everyone.  Shed/evicted counts are explicit in every record: a dropped
request is an accounted decision, never a silent loss.
"""

import time

from benchmarks.common import emit
from repro.serve.engine import EngineConfig, ServeEngine
from repro.workloads.traces import open_loop_requests, poisson_arrival_counts

# Per-class p99 queueing-delay targets (engine steps) for the controlled
# rows — tight enough that a 2x storm trips degradation inside the sweep's
# horizon.  Class 0 is the protected interactive tier.
TARGETS = (8.0, 16.0, 32.0)
MEAN_TOKENS = 8.5  # open_loop_requests new_tokens_range=(2, 16) mean


def drive_overload(
    load_factor: float,
    control: bool,
    steps: int = 96,
    batch_size: int = 8,
    sched_window: int = 4,
    seed: int = 7,
):
    """One serving run at `load_factor` x capacity; returns summary + SLO
    tails.  `control=False` reproduces the open-loop baseline engine."""
    rate = load_factor * batch_size / MEAN_TOKENS
    workload = open_loop_requests(
        poisson_arrival_counts(steps, rate, seed=seed), seed=seed
    )
    total = sum(len(a) for a in workload)
    eng = ServeEngine(None, None, EngineConfig(
        batch_size=batch_size, max_seq=512, sched_window=sched_window,
        forecast=True,
        slo_targets=TARGETS if control else None,
        backlog_cap=512,
    ), seed=seed)
    t0 = time.perf_counter()
    # Bounded horizon: an uncontrolled overload run never drains — give it
    # the arrival span plus a drain margin and stop.
    summary = eng.run(workload, max_steps=steps * 3)
    wall_us = (time.perf_counter() - t0) * 1e6
    m = eng.obs.metrics  # per-class histograms + conservation gauges
    tokens = float(m.value("tokens_emitted_total"))
    health = eng.health()  # the one structured accounting surface
    shed = health["shed"] + health["evicted"]
    out = {
        "completed": summary["completed"],
        "total": total,
        "engine_steps": summary["steps"],
        "us_per_token": wall_us / max(tokens, 1.0),
        "shed": shed,
        "shed_rate": shed / max(total, 1),
        "pending": health["pending"] + health["admit_backlog"],
    }
    for c in range(3):
        # The registry's per-class percentile view (upper bucket edge —
        # exact on the integer step clock, and conservative otherwise, so
        # the class-0 target assert below can only get STRICTER).
        out[f"p99_queue_c{c}"] = m.percentile(
            "latency_queue_steps", 99, slo=c
        )
        out[f"completed_c{c}"] = m.hist_count("latency_queue_steps", slo=c)
    return out


def run(quick: bool = False):
    steps = 64 if quick else 96
    for load in (1.0, 2.0, 4.0):
        rows = {}
        for control in (False, True):
            tag = "shed" if control else "baseline"
            r = drive_overload(load, control, steps=steps)
            rows[tag] = r
            emit(
                f"overload/L{load:g}x/{tag}",
                r["us_per_token"],
                f"shed_rate={r['shed_rate']:.3f};"
                f"p99_c0={r['p99_queue_c0']:.1f};"
                f"p99_c1={r['p99_queue_c1']:.1f};"
                f"p99_c2={r['p99_queue_c2']:.1f};"
                f"completed={r['completed']}/{r['total']}",
                load_factor=load,
                control=control,
                completed=r["completed"],
                total=r["total"],
                shed=r["shed"],
                shed_rate=round(r["shed_rate"], 4),
                target_c0=TARGETS[0],
                **{
                    f"p99_queue_c{c}": round(r[f"p99_queue_c{c}"], 2)
                    for c in range(3)
                },
            )
        if load >= 2.0:
            # Under sustained overload the controller must engage.
            r = rows["shed"]
            assert r["shed_rate"] > 0.0, (
                f"no shedding at {load:g}x offered load — the controller "
                f"never engaged"
            )
        if load == 2.0:
            # The tentpole's acceptance bar: at 2x the protected class's
            # p99 holds within target while shed absorbs the excess.  (At
            # 4x class 0 ALONE offers ~1x capacity — no admission policy
            # can hold its target without preemption, so the bar is
            # engagement, not the class-0 target.)
            r = rows["shed"]
            assert r["p99_queue_c0"] <= TARGETS[0], (
                f"class-0 p99 {r['p99_queue_c0']:.1f} exceeds target "
                f"{TARGETS[0]} at {load:g}x with control on"
            )
