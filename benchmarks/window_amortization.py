"""fig9_window: dispatch amortization of the fused-window engine.

PR 2 made compiled per-step FLOPs capacity-independent, which left the fig9
ins0 medians dominated by per-step dispatch + host sync (4.6–6.4 ms/step at
B=64 — 72–100 us per OPERATION).  `SmartPQ.run_window` rolls K steps into
one donated `lax.scan`, so this suite's headline metric is per-operation
latency: one fused window of K steps, wall-clock / (K * B).

Cast mirrors the fig9/latency acceptance slice (same workload coordinates:
ins0, size 4096, C=1<<14) so BENCH_pq.json diffs read straight across:
per schedule, `us_per_op` for the fused window vs the sequential per-step
path, plus the adaptive engine itself.  Acceptance: fused K=64 per-op
latency >= 5x below the sequential per-step medians.
"""

from benchmarks.common import (
    PQWorkload,
    emit,
    step_latency_us,
    window_latency_us,
    workload_fields,
)
from repro.core.pqueue.schedules import Schedule

CAST = [
    ("lotan_shavit", Schedule.STRICT_FLAT),
    ("alistarh_herlihy", Schedule.SPRAY_HERLIHY),
    ("multiqueue", Schedule.MULTIQ),
    ("nuddle", Schedule.HIER),
    ("smartpq", None),  # the adaptive engine, switch predicate live
]


def run(quick: bool = False):
    w = PQWorkload(
        num_clients=64, size=4096, key_range=8192, insert_frac=0.0,
        num_shards=16, npods=2, capacity=1 << 14,
    )
    K = 16 if quick else 64
    iters = 4 if quick else 8
    for name, sched in CAST:
        us_win = window_latency_us(w, K=K, iters=iters, schedule=sched)
        us_op = us_win / (K * w.num_clients)
        seq_us_step = (
            step_latency_us(w, sched, iters=4 if quick else 8)
            if sched is not None else float("nan")
        )
        seq_us_op = seq_us_step / w.num_clients
        derived = (
            f"us_per_op={us_op:.2f};us_per_window={us_win:.0f}"
            + (
                f";seq_us_per_op={seq_us_op:.2f}"
                f";amortization={seq_us_op / us_op:.1f}x"
                if sched is not None else ""
            )
        )
        emit(
            f"fig9_window/size_4096/ins0/K{K}/{name}",
            us_op,
            derived,
            schedule=sched.name if sched is not None else "SMARTPQ",
            us_per_op=round(us_op, 3),
            us_per_window=round(us_win, 1),
            window=K,
            **workload_fields(w),
        )
